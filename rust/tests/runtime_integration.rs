//! Integration tests over the PJRT runtime + AOT artifacts: the full
//! L1 (Pallas) → L2 (JAX) → artifacts → L3 (Rust) chain. These require
//! `make artifacts` to have run; they are skipped (with a note) if the
//! artifacts directory is missing so bare `cargo test` stays green.

use spmvperf::coordinator::{BatchExecutor, PjrtExecutor, Service, ServiceConfig};
use spmvperf::eigen::{jacobi_eigen, lanczos, LanczosConfig};
use spmvperf::gen;
use spmvperf::matrix::{Crs, EllMatrix, SpMv};
use spmvperf::runtime::{default_artifacts_dir, PjrtOp, Runtime};
use spmvperf::util::rng::Rng;
use spmvperf::util::stats::max_abs_diff;

const D: usize = 24;
const N: usize = 540;

fn artifacts_ready() -> bool {
    let dir = default_artifacts_dir();
    let ok = dir.join(format!("spmv_d{D}_n{N}.hlo.txt")).exists();
    if !ok {
        eprintln!(
            "SKIP: artifacts missing under {} — run `make artifacts`",
            dir.display()
        );
    }
    ok
}

fn tiny_system() -> (Crs, EllMatrix) {
    let h = gen::holstein_hubbard(&gen::HolsteinHubbardParams::tiny());
    let crs = Crs::from_coo(&h);
    let ell = EllMatrix::from_crs(&crs, Some(D)).unwrap();
    assert_eq!(ell.n, N);
    (crs, ell)
}

#[test]
fn pjrt_spmv_matches_native() {
    if !artifacts_ready() {
        return;
    }
    let (crs, ell) = tiny_system();
    let rt = Runtime::new(&default_artifacts_dir()).unwrap();
    let bound = rt.bind(&ell, rt.load(&format!("spmv_d{D}_n{N}.hlo.txt")).unwrap()).unwrap();
    let mut rng = Rng::new(1);
    for _ in 0..3 {
        let mut x = vec![0.0; N];
        rng.fill_f64(&mut x, -1.0, 1.0);
        // native original-basis result
        let mut want = vec![0.0; N];
        crs.spmv(&x, &mut want);
        // PJRT path (permuted basis kernel wrapped by PjrtOp)
        let op = PjrtOp { bound: &bound, ell: &ell };
        use spmvperf::eigen::LinearOp;
        let mut got = vec![0.0; N];
        op.apply(&x, &mut got);
        assert!(
            max_abs_diff(&want, &got) < 1e-10,
            "PJRT SpMV deviates: {}",
            max_abs_diff(&want, &got)
        );
    }
}

#[test]
fn pjrt_batched_spmv_matches_native() {
    if !artifacts_ready() {
        return;
    }
    let (_, ell) = tiny_system();
    let rt = Runtime::new(&default_artifacts_dir()).unwrap();
    let bound = rt
        .bind(&ell, rt.load(&format!("spmv_b8_d{D}_n{N}.hlo.txt")).unwrap())
        .unwrap();
    let mut rng = Rng::new(2);
    let xs: Vec<Vec<f64>> = (0..5) // short batch: exercises padding
        .map(|_| {
            let mut x = vec![0.0; N];
            rng.fill_f64(&mut x, -1.0, 1.0);
            x
        })
        .collect();
    let got = bound.spmv_batched(&xs).unwrap();
    assert_eq!(got.len(), 5);
    let mut want = vec![0.0; N];
    for (x, y) in xs.iter().zip(&got) {
        ell.spmv_permuted(x, &mut want);
        assert!(max_abs_diff(&want, y) < 1e-10);
    }
}

#[test]
fn pjrt_lanczos_step_consistent_with_full_solver() {
    if !artifacts_ready() {
        return;
    }
    let (crs, ell) = tiny_system();
    let rt = Runtime::new(&default_artifacts_dir()).unwrap();
    let bound = rt
        .bind(&ell, rt.load(&format!("lanczos_step_d{D}_n{N}.hlo.txt")).unwrap())
        .unwrap();

    // Drive the plain three-term recurrence through the artifact.
    let mut rng = Rng::new(3);
    let mut v = vec![0.0; N];
    rng.fill_f64(&mut v, -1.0, 1.0);
    let norm: f64 = v.iter().map(|x| x * x).sum::<f64>().sqrt();
    v.iter_mut().for_each(|x| *x /= norm);
    let mut v_prev = vec![0.0; N];
    let mut beta = 0.0;
    let mut alphas = Vec::new();
    let mut betas = Vec::new();
    for _ in 0..60 {
        let (a, b, v_next) = bound.lanczos_step(&v_prev, &v, beta).unwrap();
        alphas.push(a);
        v_prev = v;
        v = v_next;
        beta = b;
        betas.push(b);
    }
    betas.pop();
    let evals = spmvperf::eigen::tridiag_eigenvalues(&alphas, &betas);
    // Reference: Rust Lanczos (full reorthogonalization) on native CRS.
    let reference = lanczos(&crs, 1, &LanczosConfig::default());
    // No reorthogonalization in the artifact loop: coarse tolerance.
    assert!(
        (evals[0] - reference.eigenvalues[0]).abs() < 1e-4,
        "artifact Lanczos {} vs native {}",
        evals[0],
        reference.eigenvalues[0]
    );
}

#[test]
fn pjrt_power_step_finds_extremal_eigenvalue() {
    if !artifacts_ready() {
        return;
    }
    let (crs, ell) = tiny_system();
    let rt = Runtime::new(&default_artifacts_dir()).unwrap();
    let bound = rt
        .bind(&ell, rt.load(&format!("power_step_d{D}_n{N}.hlo.txt")).unwrap())
        .unwrap();
    let mut rng = Rng::new(4);
    let mut v = vec![0.0; N];
    rng.fill_f64(&mut v, -1.0, 1.0);
    let norm: f64 = v.iter().map(|x| x * x).sum::<f64>().sqrt();
    v.iter_mut().for_each(|x| *x /= norm);
    // Power iteration on (shift - A) in the permuted basis.
    let shift = 30.0;
    let mut rayleigh = 0.0;
    for _ in 0..800 {
        let (v_next, r) = bound.power_step(&v, shift).unwrap();
        v = v_next;
        rayleigh = r;
    }
    let reference = lanczos(&crs, 1, &LanczosConfig::default());
    assert!(
        (rayleigh - reference.eigenvalues[0]).abs() < 1e-3,
        "power {} vs lanczos {}",
        rayleigh,
        reference.eigenvalues[0]
    );
}

#[test]
fn full_stack_eigensolver_matches_dense_reference() {
    if !artifacts_ready() {
        return;
    }
    // Small enough for dense Jacobi: L=3 chain inside the same artifact
    // shape is not possible (static shapes), so validate the tiny HH
    // system against the Rust Lanczos which is itself validated against
    // Jacobi elsewhere — and drive THIS solve fully through PJRT.
    let (crs, ell) = tiny_system();
    let rt = Runtime::new(&default_artifacts_dir()).unwrap();
    let bound = rt.bind(&ell, rt.load(&format!("spmv_d{D}_n{N}.hlo.txt")).unwrap()).unwrap();
    let op = PjrtOp { bound: &bound, ell: &ell };
    let via_pjrt = lanczos(&op, 1, &LanczosConfig::default());
    let via_native = lanczos(&crs, 1, &LanczosConfig::default());
    assert!(via_pjrt.converged);
    assert!(
        (via_pjrt.eigenvalues[0] - via_native.eigenvalues[0]).abs() < 1e-8,
        "pjrt {} vs native {}",
        via_pjrt.eigenvalues[0],
        via_native.eigenvalues[0]
    );
    // and sanity against dense on a really tiny system
    let p = gen::HolsteinHubbardParams {
        sites: 2,
        n_up: 1,
        n_down: 1,
        max_phonons: 1,
        ..gen::HolsteinHubbardParams::tiny()
    };
    let h = gen::holstein_hubbard(&p);
    let (dense_evals, _) = jacobi_eigen(&h.to_dense(), false);
    let lz = lanczos(&Crs::from_coo(&h), 1, &LanczosConfig::default());
    assert!((dense_evals[0] - lz.eigenvalues[0]).abs() < 1e-8);
}

#[test]
fn service_over_pjrt_executor() {
    if !artifacts_ready() {
        return;
    }
    let (_, ell) = tiny_system();
    let ell2 = ell.clone();
    let svc = Service::start(ServiceConfig::default(), N, move || {
        let rt = Runtime::new(&default_artifacts_dir())?;
        let bound = rt.bind(&ell2, rt.load(&format!("spmv_b8_d{D}_n{N}.hlo.txt"))?)?;
        Ok(Box::new(PjrtExecutor { bound }) as Box<dyn BatchExecutor>)
    })
    .unwrap();
    let mut rng = Rng::new(5);
    let mut want = vec![0.0; N];
    for _ in 0..10 {
        let mut x = vec![0.0; N];
        rng.fill_f64(&mut x, -1.0, 1.0);
        let y = svc.submit_wait(x.clone()).unwrap();
        ell.spmv_permuted(&x, &mut want);
        assert!(max_abs_diff(&want, &y) < 1e-10);
    }
    assert_eq!(svc.metrics.requests.load(std::sync::atomic::Ordering::Relaxed), 10);
}
