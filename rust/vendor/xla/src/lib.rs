//! Offline stub of the XLA/PJRT bindings used by `spmvperf::runtime`.
//!
//! The real bindings link against a prebuilt XLA C library that is not
//! present in this environment. This stub keeps the runtime layer
//! compiling with the same API surface; [`PjRtClient::cpu`] reports the
//! platform as unavailable, so every artifact-gated test and demo takes
//! its documented skip/fallback path. Swapping the real bindings back in
//! is a one-line change in `Cargo.toml`.

use std::borrow::Borrow;
use std::fmt;

/// Error type of the stubbed bindings.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>() -> Result<T> {
    Err(Error(
        "XLA/PJRT is not available in this offline build (stub backend)".to_string(),
    ))
}

/// Parsed HLO module (stub: never constructed successfully).
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<Self> {
        unavailable()
    }
}

/// An XLA computation (stub).
#[derive(Debug, Clone)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation { _private: () }
    }
}

/// Host-side literal value (stub: carries no data).
#[derive(Debug, Clone)]
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn vec1<T: Copy>(_data: &[T]) -> Literal {
        Literal { _private: () }
    }

    pub fn scalar(_v: f64) -> Literal {
        Literal { _private: () }
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        unavailable()
    }

    pub fn to_tuple1(&self) -> Result<Literal> {
        unavailable()
    }

    pub fn to_tuple2(&self) -> Result<(Literal, Literal)> {
        unavailable()
    }

    pub fn to_tuple3(&self) -> Result<(Literal, Literal, Literal)> {
        unavailable()
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable()
    }
}

/// Device buffer handle (stub).
#[derive(Debug, Clone)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable()
    }
}

/// Compiled executable handle (stub).
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

/// PJRT client handle. The stub cannot construct one.
#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        unavailable()
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let e = PjRtClient::cpu().err().expect("stub must not construct a client");
        assert!(e.to_string().contains("not available"));
    }

    #[test]
    fn literals_construct_but_do_not_execute() {
        let l = Literal::vec1(&[1.0f64, 2.0]);
        assert!(l.reshape(&[2, 1]).is_err());
        let c = Literal::vec1(&[1i32, 2]);
        assert!(c.to_vec::<f64>().is_err());
    }
}
