//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The real crate is unavailable in this offline build environment, so
//! this shim implements the subset of the API the workspace uses:
//!
//! - [`Error`]: a message-chain error type. `{}` prints the outermost
//!   message, `{:#}` prints the whole chain joined by `": "` (matching
//!   anyhow's alternate formatting).
//! - [`Result<T>`] with the `E = Error` default parameter.
//! - The [`Context`] extension trait (`context` / `with_context`) on
//!   both `Result` and `Option`.
//! - The [`anyhow!`], [`bail!`] and [`ensure!`] macros.
//! - A blanket `From<E: std::error::Error>` so `?` converts library
//!   errors, preserving their `source()` chain.

use std::fmt::{self, Debug, Display};

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A chain of error messages, outermost first.
pub struct Error {
    msgs: Vec<String>,
}

impl Error {
    /// Build an error from a single displayable message.
    pub fn msg<M: Display>(m: M) -> Self {
        Error { msgs: vec![m.to_string()] }
    }

    fn push_context(mut self, c: String) -> Self {
        self.msgs.insert(0, c);
        self
    }

    /// The messages of the chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.msgs.iter().map(|s| s.as_str())
    }

    /// The innermost (root) message.
    pub fn root_cause(&self) -> &str {
        self.msgs.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.msgs.join(": "))
        } else {
            write!(f, "{}", self.msgs.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msgs.first().map(String::as_str).unwrap_or(""))?;
        if self.msgs.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for m in &self.msgs[1..] {
                write!(f, "\n    {m}")?;
            }
        }
        Ok(())
    }
}

// Note: `Error` deliberately does NOT implement `std::error::Error`, so
// this blanket impl does not overlap with the reflexive `From<T> for T`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut msgs = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            msgs.push(s.to_string());
            src = s.source();
        }
        Error { msgs }
    }
}

/// Extension trait attaching context to errors (and to `None`).
pub trait Context<T>: Sized {
    fn context<C: Display>(self, c: C) -> Result<T, Error>;
    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: Display>(self, c: C) -> Result<T, Error> {
        self.map_err(|e| e.into().push_context(c.to_string()))
    }

    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().push_context(f().to_string()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: Display>(self, c: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or any displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_ctx(s: &str) -> Result<u32> {
        let v: u32 = s.parse().context("parsing a number")?;
        Ok(v)
    }

    #[test]
    fn context_and_alternate_format() {
        let e = parse_ctx("nope").unwrap_err();
        assert_eq!(format!("{e}"), "parsing a number");
        let full = format!("{e:#}");
        assert!(full.starts_with("parsing a number: "), "{full}");
    }

    #[test]
    fn option_context() {
        let x: Option<u32> = None;
        let e = x.context("missing value").unwrap_err();
        assert_eq!(format!("{e:#}"), "missing value");
    }

    #[test]
    fn macros_work() {
        fn f(flag: bool) -> Result<u32> {
            ensure!(flag, "flag was {}", flag);
            ensure!(flag);
            if !flag {
                bail!("unreachable");
            }
            Err(anyhow!("value {}", 42))
        }
        let e = f(true).unwrap_err();
        assert_eq!(format!("{e}"), "value 42");
        let e = f(false).unwrap_err();
        assert_eq!(format!("{e}"), "flag was false");
        let from_string = anyhow!(String::from("boxed message"));
        assert_eq!(format!("{from_string}"), "boxed message");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/a/file")?;
            Ok(s)
        }
        assert!(f().is_err());
    }
}
