//! Toolchain probe for the native AVX-512 kernel bodies.
//!
//! The `_mm512_*` intrinsics stabilized in Rust 1.89; this crate builds
//! offline on whatever toolchain is present, so instead of raising the
//! MSRV the build script asks the compiling rustc for its version and
//! sets the `spmv_avx512_native` cfg when the floor allows. The SIMD
//! module ([`kernels::simd`]) then compiles its `IsaLevel::Avx512` lane
//! bodies as native 512-bit FMAs; without the cfg the same entry points
//! compile as paired 256-bit AVX2 streams (stable since Rust 1.27).

use std::process::Command;

/// Minor version of a `1.x` rustc, `u32::MAX` for a post-1.x compiler,
/// `None` when the probe fails (unparsable / exotic wrapper) — the
/// caller then keeps the conservative paired-stream bodies.
fn rustc_minor() -> Option<u32> {
    let rustc = std::env::var_os("RUSTC")?;
    let out = Command::new(rustc).arg("--version").output().ok()?;
    let text = String::from_utf8(out.stdout).ok()?;
    // "rustc 1.89.0 (… 2025-08-04)" / "rustc 1.91.0-nightly (…)"
    let ver = text.split_whitespace().nth(1)?;
    let mut parts = ver.split(['.', '-', '+']);
    let major: u32 = parts.next()?.parse().ok()?;
    if major > 1 {
        return Some(u32::MAX);
    }
    parts.next()?.parse().ok()
}

fn main() {
    // Declare the custom cfg so rustc/clippy runs with `-D warnings`
    // accept it on toolchains where it stays unset (unexpected_cfgs).
    println!("cargo:rustc-check-cfg=cfg(spmv_avx512_native)");
    if rustc_minor().is_some_and(|minor| minor >= 89) {
        println!("cargo:rustc-cfg=spmv_avx512_native");
    }
    println!("cargo:rerun-if-changed=build.rs");
    println!("cargo:rerun-if-env-changed=RUSTC");
}
