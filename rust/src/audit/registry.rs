//! The `rust/audit.toml` atomic-ordering registry and its parser.
//!
//! The registry is deliberately a TOML *subset* — `[[atomic]]` array
//! tables with `key = "string"` / `key = integer` pairs and `#`
//! comments — parsed by hand so the audit stays dependency-free. The
//! parser is strict: unknown tables, unknown keys, malformed values,
//! and incomplete entries are hard errors, not findings, because a
//! registry that cannot be trusted silences the rule it backs.

use anyhow::{bail, Context, Result};

/// One registered atomic-ordering site group: all uses of one
/// `Ordering` variant in one file, with an exact count and a one-line
/// justification.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct AtomicEntry {
    pub file: String,
    pub ordering: String,
    pub count: usize,
    pub why: String,
    /// Line of the entry's `[[atomic]]` header, for diagnostics.
    pub line: usize,
}

#[derive(Default)]
struct Partial {
    file: Option<String>,
    ordering: Option<String>,
    count: Option<usize>,
    why: Option<String>,
    line: usize,
}

impl Partial {
    fn finish(self) -> Result<AtomicEntry> {
        let line = self.line;
        let missing = |k: &str| format!("audit.toml: [[atomic]] at line {line} missing `{k}`");
        Ok(AtomicEntry {
            file: self.file.with_context(|| missing("file"))?,
            ordering: self.ordering.with_context(|| missing("ordering"))?,
            count: self.count.with_context(|| missing("count"))?,
            why: self.why.with_context(|| missing("why"))?,
            line,
        })
    }
}

/// Parse registry text into entries.
pub fn parse(text: &str) -> Result<Vec<AtomicEntry>> {
    let mut entries = Vec::new();
    let mut current: Option<Partial> = None;
    for (idx, rawline) in text.lines().enumerate() {
        let num = idx + 1;
        let line = rawline.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        if line.starts_with('[') {
            if line != "[[atomic]]" {
                bail!("audit.toml:{num}: unknown table `{line}` (only [[atomic]] is allowed)");
            }
            if let Some(p) = current.take() {
                entries.push(p.finish()?);
            }
            current = Some(Partial { line: num, ..Partial::default() });
            continue;
        }
        let Some((key, value)) = line.split_once('=') else {
            bail!("audit.toml:{num}: expected `key = value`, got `{line}`");
        };
        let Some(p) = current.as_mut() else {
            bail!("audit.toml:{num}: `{}` outside any [[atomic]] entry", key.trim());
        };
        let (key, value) = (key.trim(), value.trim());
        let string = |v: &str| -> Result<String> {
            let inner = v
                .strip_prefix('"')
                .and_then(|v| v.strip_suffix('"'))
                .with_context(|| format!("audit.toml:{num}: `{key}` expects a quoted string"))?;
            Ok(inner.to_string())
        };
        match key {
            "file" => p.file = Some(string(value)?),
            "ordering" => p.ordering = Some(string(value)?),
            "why" => p.why = Some(string(value)?),
            "count" => {
                p.count = Some(value.parse::<usize>().with_context(|| {
                    format!("audit.toml:{num}: `count` expects an integer, got `{value}`")
                })?)
            }
            other => bail!("audit.toml:{num}: unknown key `{other}` in [[atomic]]"),
        }
    }
    if let Some(p) = current.take() {
        entries.push(p.finish()?);
    }
    Ok(entries)
}

#[cfg(test)]
mod tests {
    use super::*;

    const GOOD: &str = "\
# comment
[[atomic]]
file = \"src/engine/mod.rs\"
ordering = \"SeqCst\"
count = 6
why = \"latch poison flag\"

[[atomic]]
file = \"src/serve/mod.rs\"
ordering = \"Relaxed\"
count = 19
why = \"stats counters\"
";

    #[test]
    fn parses_entries_in_order() {
        let es = parse(GOOD).unwrap();
        assert_eq!(es.len(), 2);
        assert_eq!(es[0].file, "src/engine/mod.rs");
        assert_eq!(es[0].ordering, "SeqCst");
        assert_eq!(es[0].count, 6);
        assert_eq!(es[0].why, "latch poison flag");
        assert_eq!(es[1].ordering, "Relaxed");
        assert_eq!(es[1].count, 19);
    }

    #[test]
    fn unknown_key_is_an_error() {
        let e = parse("[[atomic]]\nfile = \"a\"\nbogus = 1\n").unwrap_err();
        assert!(format!("{e:#}").contains("unknown key"));
    }

    #[test]
    fn unknown_table_is_an_error() {
        let e = parse("[[other]]\n").unwrap_err();
        assert!(format!("{e:#}").contains("unknown table"));
    }

    #[test]
    fn missing_field_is_an_error() {
        let e = parse("[[atomic]]\nfile = \"a\"\nordering = \"Relaxed\"\ncount = 1\n")
            .unwrap_err();
        assert!(format!("{e:#}").contains("missing `why`"));
    }

    #[test]
    fn key_outside_entry_is_an_error() {
        let e = parse("file = \"a\"\n").unwrap_err();
        assert!(format!("{e:#}").contains("outside any"));
    }

    #[test]
    fn bad_count_is_an_error() {
        let e = parse("[[atomic]]\ncount = many\n").unwrap_err();
        assert!(format!("{e:#}").contains("expects an integer"));
    }
}
