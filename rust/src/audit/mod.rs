//! In-repo static analysis for the crate's own concurrency invariants.
//!
//! `spmvperf audit` (and the tier-1 self-test below) runs six rules
//! over `src/` and `benches/`:
//!
//! | rule             | contract |
//! |------------------|----------|
//! | `unsafe_safety`  | every `unsafe` carries a `// SAFETY:` comment within 8 lines |
//! | `atomic_registry`| every `Ordering::*` site is justified in `rust/audit.toml` |
//! | `thread_spawn`   | raw thread spawns only in `src/engine/` |
//! | `isa_dispatch`   | x86 intrinsics stay inside `kernels::simd` |
//! | `hot_path_panic` | no panicking calls in kernels/engine without a waiver |
//! | `bench_baseline` | BENCH emitters keep baseline twins and identity keys |
//!
//! A site can be exempted with `// audit:allow(<rule>): <reason>` on or up
//! to [`scanner::WAIVER_SPAN`] lines above it; the reason is mandatory.
//! The pass is a scanner, not a parser (see [`scanner`]) — it needs no
//! dependencies, runs offline, and is cheap enough to gate every build.

pub mod registry;
pub mod rules;
pub mod scanner;

use std::fs;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

pub use registry::AtomicEntry;
pub use rules::{Corpus, Finding, Rule, RULES};

/// Result of one audit run.
pub struct AuditReport {
    pub findings: Vec<Finding>,
    /// Number of source files scanned.
    pub files: usize,
}

/// The crate root this binary was built from — where `src/`,
/// `benches/`, `audit.toml`, and `results-baseline/` live.
pub fn crate_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    for entry in
        fs::read_dir(dir).with_context(|| format!("audit: reading {}", dir.display()))?
    {
        let path = entry?.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Load every rule input from disk: scanned sources under `src/` and
/// `benches/`, the atomic registry, and the committed baselines.
pub fn load_corpus(root: &Path) -> Result<Corpus> {
    let mut paths = Vec::new();
    for sub in ["src", "benches"] {
        let dir = root.join(sub);
        if dir.is_dir() {
            collect_rs(&dir, &mut paths)?;
        }
    }
    paths.sort();
    let mut files = Vec::new();
    for p in &paths {
        let rel = p.strip_prefix(root).unwrap_or(p).to_string_lossy().into_owned();
        let text =
            fs::read_to_string(p).with_context(|| format!("audit: reading {}", p.display()))?;
        files.push(scanner::scan_source(&rel, &text));
    }

    let reg_path = root.join("audit.toml");
    let reg_text = fs::read_to_string(&reg_path)
        .with_context(|| format!("audit: reading {}", reg_path.display()))?;
    let registry = registry::parse(&reg_text)?;

    let mut baselines = Vec::new();
    let bdir = root.join("results-baseline");
    if bdir.is_dir() {
        for entry in fs::read_dir(&bdir)? {
            let path = entry?.path();
            let name = path.file_name().unwrap_or_default().to_string_lossy().into_owned();
            if name.starts_with("BENCH_") && name.ends_with(".json") {
                let text = fs::read_to_string(&path)
                    .with_context(|| format!("audit: reading {}", path.display()))?;
                baselines.push((name, text));
            }
        }
    }
    baselines.sort();

    Ok(Corpus { files, registry, registry_path: "audit.toml".to_string(), baselines })
}

/// Run the audit over the crate at `root`, optionally restricted to one
/// rule. Unknown rule names are an error, not an empty pass.
pub fn audit_crate(root: &Path, rule: Option<&str>) -> Result<AuditReport> {
    if let Some(r) = rule {
        if !RULES.iter().any(|rl| rl.name == r) {
            let names: Vec<&str> = RULES.iter().map(|rl| rl.name).collect();
            bail!("audit: unknown rule `{r}` (rules: {})", names.join(", "));
        }
    }
    let corpus = load_corpus(root)?;
    let files = corpus.files.len();
    Ok(AuditReport { findings: rules::run(&corpus, rule), files })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn render(findings: &[Finding]) -> String {
        findings.iter().map(|f| format!("  {f}\n")).collect()
    }

    /// The audit is a tier-1 gate: the live crate must pass every rule.
    #[test]
    fn live_crate_audits_clean() {
        let report = audit_crate(&crate_root(), None).unwrap();
        assert!(
            report.findings.is_empty(),
            "live crate must audit clean; findings:\n{}",
            render(&report.findings)
        );
        assert!(report.files > 20, "walker found only {} files", report.files);
    }

    /// The registry must keep covering the concurrency-heavy modules —
    /// if one of these rows disappears, either the atomics were removed
    /// (update this list) or the walker/counter regressed.
    #[test]
    fn registry_covers_concurrency_modules() {
        let corpus = load_corpus(&crate_root()).unwrap();
        for file in ["src/engine/mod.rs", "src/serve/mod.rs", "src/coordinator/mod.rs"] {
            assert!(
                corpus.registry.iter().any(|e| e.file == file),
                "audit.toml lost its entry for {file}"
            );
        }
        // src/shard/mod.rs synchronizes through HaloGate (Mutex +
        // Condvar), not atomics — the audit proves that stays true.
        assert!(
            !corpus.registry.iter().any(|e| e.file.starts_with("src/shard/")),
            "shard grew atomics; justify them in audit.toml and update this test"
        );
    }

    #[test]
    fn single_rule_filter_and_unknown_rule() {
        let report = audit_crate(&crate_root(), Some("unsafe_safety")).unwrap();
        assert!(report.findings.is_empty());
        let err = audit_crate(&crate_root(), Some("bogus")).unwrap_err();
        assert!(format!("{err:#}").contains("unknown rule"));
    }
}
