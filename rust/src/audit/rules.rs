//! The six audit rules, run over a [`Corpus`] of scanned sources.
//!
//! Every rule matches on the scanner's *code* view (comments and
//! string/char literals blanked), skips `#[cfg(test)]` regions, and
//! honors `// audit:allow(<rule>): <reason>` waivers — except where a rule
//! explicitly reads raw literal content because the literal *is* the
//! signal (bench filenames and JSON identity keys in `bench_baseline`).

use std::collections::BTreeMap;

use super::registry::AtomicEntry;
use super::scanner::{waived_lines, waivers, ScannedFile};
use crate::util::bench::BENCH_IDENT_KEYS;

/// Everything a rule may look at. Built from disk by
/// [`super::load_corpus`], or from literals in fixture tests.
pub struct Corpus {
    /// Scanned sources, paths relative to the crate root
    /// (`src/...`, `benches/...`), sorted by path.
    pub files: Vec<ScannedFile>,
    /// Parsed `audit.toml` atomic-ordering entries.
    pub registry: Vec<AtomicEntry>,
    /// Display path of the registry, for diagnostics.
    pub registry_path: String,
    /// `(file name, contents)` of every `results-baseline/BENCH_*.json`.
    pub baselines: Vec<(String, String)>,
}

/// One diagnostic: `rule path:line msg`.
#[derive(Debug, Clone)]
pub struct Finding {
    pub rule: String,
    pub path: String,
    pub line: usize,
    pub msg: String,
}

impl std::fmt::Display for Finding {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{:16} {}:{}  {}", self.rule, self.path, self.line, self.msg)
    }
}

/// A rule's name and the one-line contract it enforces.
pub struct Rule {
    pub name: &'static str,
    pub desc: &'static str,
}

/// The checked-in rule set, in report order.
pub const RULES: &[Rule] = &[
    Rule {
        name: "unsafe_safety",
        desc: "every `unsafe` block/fn/impl carries a `// SAFETY:` comment within the 8 preceding lines",
    },
    Rule {
        name: "atomic_registry",
        desc: "every `Ordering::*` site matches a justified entry in audit.toml (per file x variant, exact count)",
    },
    Rule {
        name: "thread_spawn",
        desc: "no `thread::{spawn,Builder,scope}` outside src/engine/ (Engine/TaskPool are the sanctioned spawn sites)",
    },
    Rule {
        name: "isa_dispatch",
        desc: "x86 intrinsic surface stays inside kernels::simd; other modules go through the `*_isa` dispatch wrappers",
    },
    Rule {
        name: "hot_path_panic",
        desc: "no unwrap/expect/panic! family in kernels/engine hot paths (mutex/condvar poisoning propagation exempt)",
    },
    Rule {
        name: "bench_baseline",
        desc: "every BENCH_*.json emitter has a results-baseline/ twin whose identity keys are still produced",
    },
];

/// Lines a SAFETY comment may sit above its `unsafe` (matching the
/// retired awk gate's window).
const SAFETY_LOOKBACK: usize = 8;

const ORDERINGS: &[&str] = &["Relaxed", "Acquire", "Release", "AcqRel", "SeqCst"];

const PANIC_TOKENS: &[&str] =
    &[".unwrap()", ".expect(", "panic!(", "unreachable!(", "todo!(", "unimplemented!("];

const X86_TOKENS: &[&str] = &["_mm256_", "_mm512_", "core::arch::x86_64", "target_feature"];

fn is_ident(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Non-overlapping byte offsets of `pat` in `code`.
fn occurrences(code: &str, pat: &str) -> Vec<usize> {
    let mut out = Vec::new();
    let mut from = 0;
    while let Some(j) = code[from..].find(pat) {
        out.push(from + j);
        from += j + pat.len();
    }
    out
}

/// `word` present with non-identifier chars (or the line edge) on both
/// sides.
fn contains_word(code: &str, word: &str) -> bool {
    let b = code.as_bytes();
    occurrences(code, word).into_iter().any(|j| {
        let before_ok = j == 0 || !is_ident(b[j - 1]);
        let after = j + word.len();
        before_ok && (after >= b.len() || !is_ident(b[after]))
    })
}

fn enabled(rule: &str, filter: Option<&str>) -> bool {
    filter.is_none_or(|f| f == rule)
}

/// Run the rules (all, or just `filter`) over the corpus.
pub fn run(corpus: &Corpus, filter: Option<&str>) -> Vec<Finding> {
    let mut out = Vec::new();
    check_waiver_hygiene(corpus, filter, &mut out);
    if enabled("unsafe_safety", filter) {
        rule_unsafe_safety(corpus, &mut out);
    }
    if enabled("atomic_registry", filter) {
        rule_atomic_registry(corpus, &mut out);
    }
    if enabled("thread_spawn", filter) {
        rule_thread_spawn(corpus, &mut out);
    }
    if enabled("isa_dispatch", filter) {
        rule_isa_dispatch(corpus, &mut out);
    }
    if enabled("hot_path_panic", filter) {
        rule_hot_path_panic(corpus, &mut out);
    }
    if enabled("bench_baseline", filter) {
        rule_bench_baseline(corpus, &mut out);
    }
    out
}

/// A waiver must name a known rule and carry a non-empty reason — an
/// unexplained waiver is a violation of the rule it tries to silence.
fn check_waiver_hygiene(c: &Corpus, filter: Option<&str>, out: &mut Vec<Finding>) {
    for f in &c.files {
        for w in waivers(f) {
            let known = RULES.iter().any(|r| r.name == w.rule);
            if !known && filter.is_none() {
                out.push(Finding {
                    rule: w.rule.clone(),
                    path: f.path.clone(),
                    line: w.line,
                    msg: format!("waiver names unknown rule `{}`", w.rule),
                });
            } else if known && w.reason.is_empty() && enabled(&w.rule, filter) {
                out.push(Finding {
                    rule: w.rule.clone(),
                    path: f.path.clone(),
                    line: w.line,
                    msg: "waiver has no reason (audit:allow(rule): reason)".to_string(),
                });
            }
        }
    }
}

fn rule_unsafe_safety(c: &Corpus, out: &mut Vec<Finding>) {
    for f in &c.files {
        let waived = waived_lines(f, "unsafe_safety");
        for ln in &f.lines {
            if ln.in_test || waived.contains(&ln.num) || !contains_word(&ln.code, "unsafe") {
                continue;
            }
            let lo = ln.num.saturating_sub(SAFETY_LOOKBACK).max(1);
            let ok = f.lines[lo - 1..ln.num].iter().any(|b| b.comment.contains("SAFETY:"));
            if !ok {
                out.push(Finding {
                    rule: "unsafe_safety".to_string(),
                    path: f.path.clone(),
                    line: ln.num,
                    msg: format!(
                        "`unsafe` without a SAFETY: comment in the {SAFETY_LOOKBACK} preceding lines"
                    ),
                });
            }
        }
    }
}

/// `Ordering::<variant>` occurrences (identifier boundary after the
/// variant), blanked out of `masked` so the bare-variant pass cannot
/// recount them.
fn count_qualified(masked: &mut String, variant: &str) -> usize {
    let pat = format!("Ordering::{variant}");
    let mut n = 0;
    let mut from = 0;
    while let Some(j) = masked[from..].find(&pat) {
        let j = from + j;
        let after = j + pat.len();
        if after >= masked.len() || !is_ident(masked.as_bytes()[after]) {
            n += 1;
            masked.replace_range(j..after, &" ".repeat(pat.len()));
        }
        from = after;
    }
    n
}

/// Bare `variant` occurrences: identifier boundaries on both sides and
/// not preceded by `:` (which would be a path segment already counted
/// or masked).
fn count_bare(masked: &str, variant: &str) -> usize {
    let b = masked.as_bytes();
    occurrences(masked, variant)
        .into_iter()
        .filter(|&j| {
            let before_ok = j == 0 || (!is_ident(b[j - 1]) && b[j - 1] != b':');
            let after = j + variant.len();
            before_ok && (after >= b.len() || !is_ident(b[after]))
        })
        .count()
}

fn is_use_line(code: &str) -> bool {
    let t = code.trim_start();
    t.starts_with("use ") || t.starts_with("pub use ")
}

/// Variants a file's `use` lines bring into scope as bare names.
fn imported_orderings(f: &ScannedFile) -> Vec<&'static str> {
    ORDERINGS
        .iter()
        .copied()
        .filter(|o| {
            f.lines.iter().any(|l| {
                is_use_line(&l.code) && l.code.contains("Ordering") && contains_word(&l.code, o)
            })
        })
        .collect()
}

fn rule_atomic_registry(c: &Corpus, out: &mut Vec<Finding>) {
    // (file, variant) -> (count, first line)
    let mut observed: BTreeMap<(String, String), (usize, usize)> = BTreeMap::new();
    for f in &c.files {
        let waived = waived_lines(f, "atomic_registry");
        let imported = imported_orderings(f);
        for ln in &f.lines {
            if ln.in_test || waived.contains(&ln.num) || is_use_line(&ln.code) {
                continue;
            }
            let mut masked = ln.code.clone();
            let mut record = |variant: &str, k: usize| {
                if k > 0 {
                    let e = observed
                        .entry((f.path.clone(), variant.to_string()))
                        .or_insert((0, ln.num));
                    e.0 += k;
                }
            };
            for o in ORDERINGS {
                let k = count_qualified(&mut masked, o);
                record(o, k);
            }
            for o in &imported {
                record(o, count_bare(&masked, o));
            }
        }
    }
    for ((file, variant), (count, first)) in &observed {
        match c.registry.iter().find(|e| &e.file == file && &e.ordering == variant) {
            None => out.push(Finding {
                rule: "atomic_registry".to_string(),
                path: file.clone(),
                line: *first,
                msg: format!(
                    "{count} `{variant}` site(s) not registered in {} (first here)",
                    c.registry_path
                ),
            }),
            Some(e) if e.count != *count => out.push(Finding {
                rule: "atomic_registry".to_string(),
                path: file.clone(),
                line: *first,
                msg: format!(
                    "{count} `{variant}` site(s) but {} registers {} — update the entry and its `why`",
                    c.registry_path, e.count
                ),
            }),
            Some(e) if e.why.trim().is_empty() => out.push(Finding {
                rule: "atomic_registry".to_string(),
                path: c.registry_path.clone(),
                line: e.line,
                msg: format!("entry for {file} `{variant}` has an empty `why`"),
            }),
            Some(_) => {}
        }
    }
    for e in &c.registry {
        if !observed.contains_key(&(e.file.clone(), e.ordering.clone())) {
            out.push(Finding {
                rule: "atomic_registry".to_string(),
                path: c.registry_path.clone(),
                line: e.line,
                msg: format!("entry for {} `{}` matches no source site", e.file, e.ordering),
            });
        }
    }
}

fn has_thread_spawn(code: &str) -> bool {
    occurrences(code, "thread::").into_iter().any(|j| {
        let rest = &code[j + "thread::".len()..];
        ["spawn", "Builder", "scope"].iter().any(|cand| {
            rest.starts_with(cand)
                && rest.as_bytes().get(cand.len()).is_none_or(|&nb| !is_ident(nb))
        })
    })
}

fn rule_thread_spawn(c: &Corpus, out: &mut Vec<Finding>) {
    for f in &c.files {
        if f.path.starts_with("src/engine/") {
            continue;
        }
        let waived = waived_lines(f, "thread_spawn");
        for ln in &f.lines {
            if ln.in_test || waived.contains(&ln.num) {
                continue;
            }
            if has_thread_spawn(&ln.code) {
                out.push(Finding {
                    rule: "thread_spawn".to_string(),
                    path: f.path.clone(),
                    line: ln.num,
                    msg: "thread spawn outside src/engine/ (use Engine/TaskPool)".to_string(),
                });
            }
        }
    }
}

fn rule_isa_dispatch(c: &Corpus, out: &mut Vec<Finding>) {
    for f in &c.files {
        let in_simd = f.path.starts_with("src/kernels/simd");
        let in_kernels = f.path.starts_with("src/kernels/");
        if in_simd && in_kernels {
            continue;
        }
        let waived = waived_lines(f, "isa_dispatch");
        for ln in &f.lines {
            if ln.in_test || waived.contains(&ln.num) {
                continue;
            }
            if !in_simd {
                if let Some(tok) = X86_TOKENS.iter().find(|t| ln.code.contains(*t)) {
                    out.push(Finding {
                        rule: "isa_dispatch".to_string(),
                        path: f.path.clone(),
                        line: ln.num,
                        msg: format!("x86 intrinsic surface (`{tok}`) outside kernels::simd"),
                    });
                    continue;
                }
            }
            if !in_kernels {
                let b = ln.code.as_bytes();
                let direct = occurrences(&ln.code, "simd::")
                    .into_iter()
                    .any(|j| j == 0 || !is_ident(b[j - 1]));
                if direct {
                    out.push(Finding {
                        rule: "isa_dispatch".to_string(),
                        path: f.path.clone(),
                        line: ln.num,
                        msg: "direct simd:: call outside kernels (use the *_isa dispatch wrappers)"
                            .to_string(),
                    });
                }
            }
        }
    }
}

fn rule_hot_path_panic(c: &Corpus, out: &mut Vec<Finding>) {
    for f in &c.files {
        if !(f.path.starts_with("src/kernels/") || f.path.starts_with("src/engine/")) {
            continue;
        }
        let waived = waived_lines(f, "hot_path_panic");
        for ln in &f.lines {
            if ln.in_test || waived.contains(&ln.num) {
                continue;
            }
            // Mutex/Condvar poisoning propagation is the sanctioned
            // panic: a poisoned lock means a sibling already panicked.
            if ln.code.contains("lock().unwrap()") || ln.code.contains(".wait(") {
                continue;
            }
            if let Some(tok) = PANIC_TOKENS.iter().find(|t| ln.code.contains(*t)) {
                out.push(Finding {
                    rule: "hot_path_panic".to_string(),
                    path: f.path.clone(),
                    line: ln.num,
                    msg: format!("`{tok}` on a hot-path module without a waiver"),
                });
            }
        }
    }
}

/// `write_bench_json("BENCH_<stem>.json"` on a raw line — the literal
/// is the signal, so this reads `raw`, not `code`.
fn bench_emitter(raw: &str) -> Option<String> {
    let p = raw.find("write_bench_json(")?;
    let rest = raw[p + "write_bench_json(".len()..].trim_start();
    let rest = rest.strip_prefix('"')?;
    let name = &rest[..rest.find('"')?];
    let stem = name.strip_prefix("BENCH_")?.strip_suffix(".json")?;
    let stem_ok = !stem.is_empty()
        && stem.bytes().all(|b| b.is_ascii_lowercase() || b.is_ascii_digit() || b == b'_');
    stem_ok.then(|| name.to_string())
}

fn rule_bench_baseline(c: &Corpus, out: &mut Vec<Finding>) {
    let mut emitters: Vec<(String, String, usize)> = Vec::new();
    for f in &c.files {
        let waived = waived_lines(f, "bench_baseline");
        for ln in &f.lines {
            if ln.in_test || waived.contains(&ln.num) {
                continue;
            }
            if let Some(name) = bench_emitter(&ln.raw) {
                emitters.push((name, f.path.clone(), ln.num));
            }
        }
    }
    for (name, path, line) in &emitters {
        let Some((_, content)) = c.baselines.iter().find(|(b, _)| b == name) else {
            out.push(Finding {
                rule: "bench_baseline".to_string(),
                path: path.clone(),
                line: *line,
                msg: format!("{name} has no results-baseline/ twin for the benchdiff gate"),
            });
            continue;
        };
        // Identity keys the committed baseline relies on to match
        // entries across runs; each must still appear in a produced
        // JSON literal somewhere in the crate, or the benchdiff gate
        // rots silently (entries stop matching and nothing fails).
        let mut keys: Vec<&str> = Vec::new();
        for bl in content.lines().filter(|l| l.contains("\"mflops\"")) {
            for k in BENCH_IDENT_KEYS {
                if bl.contains(&format!("\"{k}\"")) && !keys.contains(k) {
                    keys.push(k);
                }
            }
        }
        for k in keys {
            let escaped = format!("{k}\\\":");
            let plain = format!("{k}\":");
            let produced = c.files.iter().any(|f2| {
                f2.lines.iter().any(|l| {
                    !l.in_test && (l.raw.contains(&escaped) || l.raw.contains(&plain))
                })
            });
            if !produced {
                out.push(Finding {
                    rule: "bench_baseline".to_string(),
                    path: path.clone(),
                    line: *line,
                    msg: format!("{name}: identity key '{k}' is no longer produced by any emitter"),
                });
            }
        }
    }
    for (bname, _) in &c.baselines {
        if bname.starts_with("BENCH_") && !emitters.iter().any(|(n, _, _)| n == bname) {
            out.push(Finding {
                rule: "bench_baseline".to_string(),
                path: format!("results-baseline/{bname}"),
                line: 0,
                msg: "orphan baseline: no emitter writes this file any more".to_string(),
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::scanner::scan_source;
    use super::*;

    fn corpus_of(files: &[(&str, &str)]) -> Corpus {
        Corpus {
            files: files.iter().map(|(p, s)| scan_source(p, s)).collect(),
            registry: Vec::new(),
            registry_path: "audit.toml".to_string(),
            baselines: Vec::new(),
        }
    }

    fn findings(c: &Corpus, rule: &str) -> Vec<Finding> {
        run(c, Some(rule))
    }

    // ---- unsafe_safety ----------------------------------------------

    #[test]
    fn unsafe_without_safety_fires() {
        let c = corpus_of(&[("src/x.rs", "fn f() {\n    unsafe { danger() };\n}\n")]);
        let fs = findings(&c, "unsafe_safety");
        assert_eq!(fs.len(), 1);
        assert_eq!((fs[0].path.as_str(), fs[0].line), ("src/x.rs", 2));
    }

    #[test]
    fn unsafe_with_safety_comment_is_clean() {
        let src =
            "fn f() {\n    // SAFETY: fixture invariant holds.\n    unsafe { danger() };\n}\n";
        let c = corpus_of(&[("src/x.rs", src)]);
        assert!(findings(&c, "unsafe_safety").is_empty());
    }

    #[test]
    fn unsafe_with_waiver_is_silenced() {
        let src = "fn f() {\n    // audit:allow(unsafe_safety): fixture exercises the waiver\n    unsafe { danger() };\n}\n";
        let c = corpus_of(&[("src/x.rs", src)]);
        assert!(findings(&c, "unsafe_safety").is_empty());
    }

    #[test]
    fn unsafe_in_strings_and_tests_is_ignored() {
        let src = "fn f() { let s = \"unsafe\"; }\n#[cfg(test)]\nmod tests {\n    fn g() { unsafe { x() } }\n}\n";
        let c = corpus_of(&[("src/x.rs", src)]);
        assert!(findings(&c, "unsafe_safety").is_empty());
    }

    #[test]
    fn safety_comment_too_far_above_does_not_count() {
        let mut src = String::from("// SAFETY: far away.\n");
        src.push_str(&"fn pad() {}\n".repeat(9));
        src.push_str("fn f() { unsafe { danger() }; }\n");
        let c = corpus_of(&[("src/x.rs", &src)]);
        assert_eq!(findings(&c, "unsafe_safety").len(), 1);
    }

    // ---- atomic_registry --------------------------------------------

    const ATOMIC_SRC: &str = "\
use std::sync::atomic::{AtomicUsize, Ordering};
fn f(a: &AtomicUsize) {
    a.store(1, Ordering::SeqCst);
    let _ = a.load(Ordering::SeqCst);
}
";

    #[test]
    fn unregistered_atomic_fires() {
        let c = corpus_of(&[("src/x.rs", ATOMIC_SRC)]);
        let fs = findings(&c, "atomic_registry");
        assert_eq!(fs.len(), 1);
        assert!(fs[0].msg.contains("2 `SeqCst`"), "{}", fs[0].msg);
        assert_eq!(fs[0].line, 3, "anchored at the first site");
    }

    #[test]
    fn registered_atomic_with_matching_count_is_clean() {
        let mut c = corpus_of(&[("src/x.rs", ATOMIC_SRC)]);
        c.registry.push(AtomicEntry {
            file: "src/x.rs".into(),
            ordering: "SeqCst".into(),
            count: 2,
            why: "fixture".into(),
            line: 1,
        });
        assert!(findings(&c, "atomic_registry").is_empty());
    }

    #[test]
    fn count_drift_fires() {
        let mut c = corpus_of(&[("src/x.rs", ATOMIC_SRC)]);
        c.registry.push(AtomicEntry {
            file: "src/x.rs".into(),
            ordering: "SeqCst".into(),
            count: 1,
            why: "fixture".into(),
            line: 1,
        });
        let fs = findings(&c, "atomic_registry");
        assert_eq!(fs.len(), 1);
        assert!(fs[0].msg.contains("registers 1"), "{}", fs[0].msg);
    }

    #[test]
    fn orphan_registry_entry_fires() {
        let mut c = corpus_of(&[("src/x.rs", "fn f() {}\n")]);
        c.registry.push(AtomicEntry {
            file: "src/gone.rs".into(),
            ordering: "Relaxed".into(),
            count: 1,
            why: "stale".into(),
            line: 7,
        });
        let fs = findings(&c, "atomic_registry");
        assert_eq!(fs.len(), 1);
        assert!(fs[0].msg.contains("matches no source site"));
        assert_eq!((fs[0].path.as_str(), fs[0].line), ("audit.toml", 7));
    }

    #[test]
    fn waived_atomic_site_is_not_counted() {
        let src = "\
use std::sync::atomic::{AtomicUsize, Ordering};
fn f(a: &AtomicUsize) {
    // audit:allow(atomic_registry): fixture exercises the waiver
    a.store(1, Ordering::SeqCst);
}
";
        let c = corpus_of(&[("src/x.rs", src)]);
        assert!(findings(&c, "atomic_registry").is_empty());
    }

    #[test]
    fn bare_imported_variant_is_counted() {
        let src = "\
use std::sync::atomic::{AtomicU64, Ordering::Relaxed};
fn f(c: &AtomicU64) {
    c.fetch_add(1, Relaxed);
}
";
        let c = corpus_of(&[("src/x.rs", src)]);
        let fs = findings(&c, "atomic_registry");
        assert_eq!(fs.len(), 1);
        assert!(fs[0].msg.contains("1 `Relaxed`"), "{}", fs[0].msg);
    }

    // ---- thread_spawn -----------------------------------------------

    #[test]
    fn spawn_outside_engine_fires_all_three_forms() {
        let src = "\
fn a() { std::thread::spawn(|| {}); }
fn b() { std::thread::Builder::new(); }
fn c() { std::thread::scope(|_| {}); }
";
        let c = corpus_of(&[("src/serve/x.rs", src)]);
        assert_eq!(findings(&c, "thread_spawn").len(), 3);
    }

    #[test]
    fn spawn_inside_engine_is_sanctioned() {
        let c = corpus_of(&[("src/engine/pool.rs", "fn a() { std::thread::spawn(|| {}); }\n")]);
        assert!(findings(&c, "thread_spawn").is_empty());
    }

    #[test]
    fn spawn_with_waiver_is_silenced() {
        let src = "// audit:allow(thread_spawn): fixture exercises the waiver\nfn a() { std::thread::spawn(|| {}); }\n";
        let c = corpus_of(&[("src/serve/x.rs", src)]);
        assert!(findings(&c, "thread_spawn").is_empty());
    }

    // ---- isa_dispatch -----------------------------------------------

    #[test]
    fn intrinsics_outside_simd_fire() {
        let src = "use core::arch::x86_64::*;\nfn f() { let _ = simd::triad(); }\n";
        let c = corpus_of(&[("src/solver/x.rs", src)]);
        let fs = findings(&c, "isa_dispatch");
        assert_eq!(fs.len(), 2);
        assert!(fs[0].msg.contains("core::arch::x86_64"));
        assert!(fs[1].msg.contains("*_isa dispatch"));
    }

    #[test]
    fn intrinsics_inside_simd_and_kernels_are_clean() {
        let c = corpus_of(&[
            ("src/kernels/simd/mod.rs", "fn f() { let _ = _mm256_setzero_pd(); }\n"),
            ("src/kernels/spmv.rs", "fn g() { simd::crs_rows(); }\n"),
        ]);
        assert!(findings(&c, "isa_dispatch").is_empty());
    }

    #[test]
    fn intrinsics_with_waiver_are_silenced() {
        let src = "// audit:allow(isa_dispatch): fixture exercises the waiver\nfn f() { let _ = simd::triad(); }\n";
        let c = corpus_of(&[("src/solver/x.rs", src)]);
        assert!(findings(&c, "isa_dispatch").is_empty());
    }

    // ---- hot_path_panic ---------------------------------------------

    #[test]
    fn unwrap_on_hot_path_fires() {
        let c = corpus_of(&[("src/kernels/x.rs", "fn f(o: Option<u32>) -> u32 { o.unwrap() }\n")]);
        let fs = findings(&c, "hot_path_panic");
        assert_eq!(fs.len(), 1);
        assert!(fs[0].msg.contains(".unwrap()"));
    }

    #[test]
    fn lock_poison_propagation_and_cold_modules_are_clean() {
        let c = corpus_of(&[
            ("src/engine/x.rs", "fn f(m: &Mutex<u32>) -> u32 { *m.lock().unwrap() }\n"),
            ("src/util/x.rs", "fn g(o: Option<u32>) -> u32 { o.unwrap() }\n"),
        ]);
        assert!(findings(&c, "hot_path_panic").is_empty());
    }

    #[test]
    fn panic_with_waiver_is_silenced() {
        let src = "fn f() {\n    // audit:allow(hot_path_panic): fixture exercises the waiver\n    panic!(\"boom\");\n}\n";
        let c = corpus_of(&[("src/engine/x.rs", src)]);
        assert!(findings(&c, "hot_path_panic").is_empty());
    }

    // ---- bench_baseline ---------------------------------------------

    const EMITTER: &str = "fn main() { write_bench_json(\"BENCH_x.json\", &json); }\n";

    #[test]
    fn emitter_without_baseline_fires() {
        let c = corpus_of(&[("benches/x.rs", EMITTER)]);
        let fs = findings(&c, "bench_baseline");
        assert_eq!(fs.len(), 1);
        assert!(fs[0].msg.contains("no results-baseline/"));
    }

    #[test]
    fn baseline_with_produced_keys_is_clean() {
        let producer =
            "fn j() -> String { format!(\"{{\\\"case\\\":\\\"a\\\",\\\"mflops\\\":{m}}}\") }\n";
        let mut c = corpus_of(&[("benches/x.rs", EMITTER), ("src/util/bench.rs", producer)]);
        c.baselines.push((
            "BENCH_x.json".to_string(),
            "{\"case\":\"a\",\"mflops\":100}\n".to_string(),
        ));
        assert!(findings(&c, "bench_baseline").is_empty());
    }

    #[test]
    fn dropped_identity_key_fires() {
        let mut c = corpus_of(&[("benches/x.rs", EMITTER)]);
        c.baselines.push((
            "BENCH_x.json".to_string(),
            "{\"case\":\"a\",\"mflops\":100}\n".to_string(),
        ));
        let fs = findings(&c, "bench_baseline");
        assert_eq!(fs.len(), 1);
        assert!(fs[0].msg.contains("identity key 'case'"), "{}", fs[0].msg);
    }

    #[test]
    fn orphan_baseline_fires() {
        let mut c = corpus_of(&[("src/x.rs", "fn f() {}\n")]);
        c.baselines.push(("BENCH_gone.json".to_string(), "{}\n".to_string()));
        let fs = findings(&c, "bench_baseline");
        assert_eq!(fs.len(), 1);
        assert!(fs[0].msg.contains("orphan baseline"));
    }

    #[test]
    fn waived_emitter_is_silenced() {
        let src = "// audit:allow(bench_baseline): fixture exercises the waiver\nfn main() { write_bench_json(\"BENCH_x.json\", &json); }\n";
        let c = corpus_of(&[("benches/x.rs", src)]);
        assert!(findings(&c, "bench_baseline").is_empty());
    }

    // ---- waiver hygiene ---------------------------------------------

    #[test]
    fn empty_reason_waiver_fires() {
        let src = "// audit:allow(thread_spawn):\nfn a() { std::thread::spawn(|| {}); }\n";
        let c = corpus_of(&[("src/serve/x.rs", src)]);
        let fs = findings(&c, "thread_spawn");
        assert_eq!(fs.len(), 1, "the waiver still covers, but is itself flagged");
        assert!(fs[0].msg.contains("no reason"));
    }

    #[test]
    fn unknown_rule_waiver_fires() {
        let c = corpus_of(&[("src/x.rs", "// audit:allow(bogus_rule): whatever\n")]);
        let fs = run(&c, None);
        assert_eq!(fs.len(), 1);
        assert!(fs[0].msg.contains("unknown rule"));
    }
}
