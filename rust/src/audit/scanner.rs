//! A comment/string-aware line scanner for Rust sources.
//!
//! Deliberately *not* a parser: the audit rules only need to know, per
//! line, (a) what is code and what is comment, (b) where string/char
//! literals are (so `"unsafe"` in a message never trips a rule), and
//! (c) whether the line sits inside a `#[cfg(test)]`-gated region. A
//! line-oriented state machine answers all three without `syn` or any
//! other dependency, which keeps the pass runnable offline and fast
//! enough to be a tier-1 test.
//!
//! Known (accepted) approximations, shared with nothing else in the
//! crate and stable under `rustfmt`-formatted input:
//! - escapes inside a *continued* (multi-line) plain string are not
//!   interpreted — the continuation ends at the first `"`;
//! - `'` is treated as a char literal only for the `'x'` / `'\..'`
//!   shapes, so lifetimes (`'a`) stay visible to the code view;
//! - `#[cfg(test)]` regions are tracked by brace depth from the
//!   attribute, which is exact for the `mod tests { .. }` idiom.

use std::collections::BTreeSet;

/// One scanned source line.
pub struct Line {
    /// 1-based line number.
    pub num: usize,
    /// The untouched source line (string literals intact) — used only
    /// where literal content *is* the signal (bench filenames, JSON
    /// identity keys).
    pub raw: String,
    /// The line with comments and string/char literals blanked to
    /// spaces: what the code-facing rules match against.
    pub code: String,
    /// Concatenated comment text on this line (line + block pieces).
    pub comment: String,
    /// Inside a `#[cfg(test)]` / `#[test]` gated region.
    pub in_test: bool,
}

/// A scanned source file, path relative to the crate root.
pub struct ScannedFile {
    pub path: String,
    pub lines: Vec<Line>,
}

/// An inline waiver: `// audit:allow(<rule>): <reason>`. It silences
/// the named rule on its own line and the next [`WAIVER_SPAN`] lines.
pub struct Waiver {
    pub rule: String,
    pub reason: String,
    pub line: usize,
}

/// Lines after the waiver comment that stay covered.
pub const WAIVER_SPAN: usize = 3;

fn memfind(hay: &[u8], from: usize, needle: &[u8]) -> Option<usize> {
    if needle.is_empty() || hay.len() < needle.len() {
        return None;
    }
    (from..=hay.len() - needle.len()).find(|&i| &hay[i..i + needle.len()] == needle)
}

fn push_blank(code: &mut Vec<u8>, n: usize) {
    code.resize(code.len() + n, b' ');
}

/// `#[cfg(test)]`-family attribute on a whitespace-stripped code line.
fn has_test_attr(stripped: &str) -> bool {
    if stripped.contains("#[test]") {
        return true;
    }
    for pat in ["#[cfg(test", "#[cfg(all(test", "#[cfg_attr(test"] {
        if let Some(p) = stripped.find(pat) {
            // Boundary after `test`: `)` or `,` (or end of line), so a
            // hypothetical `cfg(testing)` never gates a region.
            match stripped.as_bytes().get(p + pat.len()) {
                None | Some(b')') | Some(b',') => return true,
                _ => {}
            }
        }
    }
    false
}

/// Scan one source file into per-line code/comment views plus
/// `cfg(test)` region marks.
pub fn scan_source(path: &str, text: &str) -> ScannedFile {
    let mut lines = Vec::new();
    let mut in_block = false;
    // An open string literal continuing onto the next line: number of
    // `#`s in its terminator (0 for plain and `r"` strings).
    let mut str_cont: Option<usize> = None;
    for (idx, rawline) in text.split('\n').enumerate() {
        let b = rawline.as_bytes();
        let n = b.len();
        let mut code: Vec<u8> = Vec::with_capacity(n);
        let mut comment: Vec<u8> = Vec::new();
        let mut i = 0;
        while i < n {
            if in_block {
                match memfind(b, i, b"*/") {
                    None => {
                        comment.extend(&b[i..]);
                        push_blank(&mut code, n - i);
                        i = n;
                    }
                    Some(j) => {
                        comment.extend(&b[i..j]);
                        push_blank(&mut code, j + 2 - i);
                        i = j + 2;
                        in_block = false;
                    }
                }
                continue;
            }
            if let Some(hashes) = str_cont {
                let mut term = vec![b'"'];
                term.resize(1 + hashes, b'#');
                match memfind(b, i, &term) {
                    None => {
                        push_blank(&mut code, n - i);
                        i = n;
                    }
                    Some(j) => {
                        push_blank(&mut code, j + term.len() - i);
                        i = j + term.len();
                        str_cont = None;
                    }
                }
                continue;
            }
            let c = b[i];
            if c == b'/' && i + 1 < n && b[i + 1] == b'/' {
                comment.extend(&b[i + 2..]);
                push_blank(&mut code, n - i);
                i = n;
                continue;
            }
            if c == b'/' && i + 1 < n && b[i + 1] == b'*' {
                in_block = true;
                push_blank(&mut code, 2);
                i += 2;
                continue;
            }
            if c == b'r' && i + 1 < n && (b[i + 1] == b'"' || b[i + 1] == b'#') {
                let mut j = i + 1;
                let mut hashes = 0;
                while j < n && b[j] == b'#' {
                    hashes += 1;
                    j += 1;
                }
                if j < n && b[j] == b'"' {
                    str_cont = Some(hashes);
                    push_blank(&mut code, j + 1 - i);
                    i = j + 1;
                    continue;
                }
                code.push(b'r');
                i += 1;
                continue;
            }
            if c == b'"' {
                let mut j = i + 1;
                let mut closed = false;
                while j < n {
                    if b[j] == b'\\' {
                        j += 2;
                        continue;
                    }
                    if b[j] == b'"' {
                        closed = true;
                        break;
                    }
                    j += 1;
                }
                if closed {
                    push_blank(&mut code, j + 1 - i);
                    i = j + 1;
                } else {
                    push_blank(&mut code, n - i);
                    i = n;
                    str_cont = Some(0);
                }
                continue;
            }
            if c == b'\'' {
                if i + 2 < n && b[i + 1] == b'\\' {
                    let mut j = i + 2;
                    while j < n && b[j] != b'\'' {
                        j += 1;
                    }
                    if j < n {
                        push_blank(&mut code, j + 1 - i);
                        i = j + 1;
                        continue;
                    }
                } else if i + 2 < n && b[i + 2] == b'\'' {
                    push_blank(&mut code, 3);
                    i += 3;
                    continue;
                }
                code.push(b'\'');
                i += 1;
                continue;
            }
            code.push(c);
            i += 1;
        }
        lines.push(Line {
            num: idx + 1,
            raw: rawline.to_string(),
            // Splits only happen at ASCII bytes, so both views stay
            // valid UTF-8; lossy is a belt-and-braces fallback.
            code: String::from_utf8_lossy(&code).into_owned(),
            comment: String::from_utf8_lossy(&comment).into_owned(),
            in_test: false,
        });
    }
    mark_test_regions(&mut lines);
    ScannedFile { path: path.to_string(), lines }
}

/// Mark lines inside `#[cfg(test)]`-gated brace regions: a matching
/// attribute arms `pending`; the next `{` opens a region popped when
/// brace depth returns to its opening level.
fn mark_test_regions(lines: &mut [Line]) {
    let mut depth: i64 = 0;
    let mut stack: Vec<i64> = Vec::new();
    let mut pending = false;
    for ln in lines.iter_mut() {
        if !stack.is_empty() {
            ln.in_test = true;
        }
        let stripped: String = ln.code.chars().filter(|c| !c.is_whitespace()).collect();
        if has_test_attr(&stripped) {
            pending = true;
            ln.in_test = true;
        }
        for ch in ln.code.chars() {
            if ch == '{' {
                if pending {
                    stack.push(depth);
                    pending = false;
                    ln.in_test = true;
                }
                depth += 1;
            } else if ch == '}' {
                depth -= 1;
                if stack.last() == Some(&depth) {
                    stack.pop();
                }
            }
        }
        if pending {
            ln.in_test = true;
        }
    }
}

/// All waivers in a file, in order — including empty-reason ones (the
/// rules report those as findings, but they still cover their span, so
/// fixing the reason is the only way out).
pub fn waivers(file: &ScannedFile) -> Vec<Waiver> {
    let mut out = Vec::new();
    for ln in &file.lines {
        let Some(p) = ln.comment.find("audit:allow(") else { continue };
        let rest = &ln.comment[p + "audit:allow(".len()..];
        let Some(close) = rest.find(')') else { continue };
        let rule = &rest[..close];
        if rule.is_empty() || !rule.bytes().all(|b| b.is_ascii_lowercase() || b == b'_') {
            continue;
        }
        let after = rest[close + 1..].trim_start();
        let Some(reason) = after.strip_prefix(':') else { continue };
        out.push(Waiver {
            rule: rule.to_string(),
            reason: reason.trim().to_string(),
            line: ln.num,
        });
    }
    out
}

/// Line numbers covered by waivers for `rule` in `file`.
pub fn waived_lines(file: &ScannedFile, rule: &str) -> BTreeSet<usize> {
    let mut out = BTreeSet::new();
    for w in waivers(file) {
        if w.rule == rule {
            out.extend(w.line..=w.line + WAIVER_SPAN);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn line_comments_move_to_comment_view() {
        let f = scan_source("t.rs", "let x = 1; // unsafe here\n");
        assert!(!f.lines[0].code.contains("unsafe"));
        assert!(f.lines[0].comment.contains("unsafe here"));
        assert!(f.lines[0].code.contains("let x = 1;"));
    }

    #[test]
    fn block_comments_span_lines() {
        let f = scan_source("t.rs", "a(); /* unsafe\nstill unsafe */ b();");
        assert!(!f.lines[0].code.contains("unsafe"));
        assert!(!f.lines[1].code.contains("unsafe"));
        assert!(f.lines[1].comment.contains("still unsafe"));
        assert!(f.lines[1].code.contains("b();"));
    }

    #[test]
    fn string_literals_are_blanked() {
        let f = scan_source("t.rs", "let s = \"unsafe { }\"; call();");
        assert!(!f.lines[0].code.contains("unsafe"));
        assert!(f.lines[0].code.contains("call();"));
        assert!(f.lines[0].raw.contains("unsafe"));
    }

    #[test]
    fn escaped_quote_does_not_end_the_literal() {
        let f = scan_source("t.rs", r#"let s = "a\"unsafe"; go();"#);
        assert!(!f.lines[0].code.contains("unsafe"));
        assert!(f.lines[0].code.contains("go();"));
    }

    #[test]
    fn raw_strings_blank_across_lines() {
        let src = "let s = r#\"unsafe {\nthread::spawn\n\"# ; tail();";
        let f = scan_source("t.rs", src);
        assert!(!f.lines[0].code.contains("unsafe"));
        assert!(!f.lines[1].code.contains("thread::spawn"));
        assert!(f.lines[2].code.contains("tail();"));
    }

    #[test]
    fn char_literals_blank_but_lifetimes_survive() {
        let f = scan_source("t.rs", "let c = 'u'; fn f<'a>(x: &'a str) {}");
        assert!(!f.lines[0].code.contains("'u'"));
        assert!(f.lines[0].code.contains("<'a>"));
    }

    #[test]
    fn cfg_test_region_is_marked() {
        let src = "fn prod() {}\n#[cfg(test)]\nmod tests {\n    fn inner() {}\n}\nfn after() {}\n";
        let f = scan_source("t.rs", src);
        assert!(!f.lines[0].in_test, "prod fn");
        assert!(f.lines[1].in_test, "attribute line");
        assert!(f.lines[2].in_test, "mod open");
        assert!(f.lines[3].in_test, "body");
        assert!(f.lines[4].in_test, "mod close");
        assert!(!f.lines[5].in_test, "after the region");
    }

    #[test]
    fn nested_braces_keep_the_region_open() {
        let src = "#[cfg(test)]\nmod tests {\n    fn a() { if x { y(); } }\n    fn b() {}\n}\n";
        let f = scan_source("t.rs", src);
        assert!(f.lines[2].in_test);
        assert!(f.lines[3].in_test);
    }

    #[test]
    fn waiver_parses_rule_reason_and_span() {
        let src =
            "// audit:allow(hot_path_panic): cold construction path\nx();\ny();\nz();\nw();\n";
        let f = scan_source("t.rs", src);
        let ws = waivers(&f);
        assert_eq!(ws.len(), 1);
        assert_eq!(ws[0].rule, "hot_path_panic");
        assert_eq!(ws[0].reason, "cold construction path");
        let covered = waived_lines(&f, "hot_path_panic");
        assert!(covered.contains(&1) && covered.contains(&4));
        assert!(!covered.contains(&5), "span is the waiver line + {WAIVER_SPAN}");
        assert!(waived_lines(&f, "unsafe_safety").is_empty(), "other rules unaffected");
    }

    #[test]
    fn waiver_without_colon_is_ignored() {
        let f = scan_source("t.rs", "// audit:allow(thread_spawn) missing colon\n");
        assert!(waivers(&f).is_empty());
    }

    #[test]
    fn waiver_with_empty_reason_still_covers_but_is_flagged_later() {
        let f = scan_source("t.rs", "// audit:allow(thread_spawn):\n");
        let ws = waivers(&f);
        assert_eq!(ws.len(), 1);
        assert!(ws[0].reason.is_empty());
        assert!(waived_lines(&f, "thread_spawn").contains(&1));
    }
}
