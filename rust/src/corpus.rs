//! Corpus arbitration benchmark: sweep a fixed set of generated
//! matrices (plus optional MatrixMarket files) through all three tuning
//! tiers and record, per matrix, what the arbitration actually decided
//! — backend, scheme, schedule — together with measured throughput and
//! the **heuristic-vs-measured agreement rate**, the standing quality
//! metric for the zero-measurement tier.
//!
//! The corpus is deliberately scenario-diverse: a scale-free power-law
//! graph and an RMAT instance (extreme row imbalance — the regime where
//! static schedules collapse), a 2-D Laplacian (regular stencil, the
//! friendly case) and a random band matrix (the paper's bandwidth-bound
//! middle ground). Every configuration self-validates before timing:
//! SpMV against the serial CRS reference, blocked-x SpMM against `k`
//! independent per-vector calls, and the CG / power-iteration /
//! PageRank solvers against their serial-operator runs — so the emitted
//! `BENCH_corpus.json` doubles as an end-to-end correctness gate.
//!
//! `spmvperf corpus [--quick]` drives [`run_corpus`] and writes
//! `results/BENCH_corpus.json` for the CI `benchdiff` gate. The
//! per-matrix decision record is also the training-set format for a
//! future learned tuning tier (see ROADMAP).

use std::fmt::Write as _;

use anyhow::{ensure, Context, Result};

use crate::eigen::{
    cg, cg_with_handle, pagerank, pagerank_with_handle, power_iteration,
    power_iteration_with_handle, transition_matrix, CgConfig, PowerConfig,
};
use crate::gen;
use crate::kernels::Precision;
use crate::matrix::{Coo, Crs, Scheme, SpMv};
use crate::sched::Schedule;
use crate::spmv::{BackendChoice, SpmvHandle};
use crate::tune::TuningPolicy;
use crate::util::bench::{Bench, BenchResult};
use crate::util::rng::Rng;
use crate::util::stats::max_abs_diff;

/// Everything `spmvperf corpus` can vary. Defaults mirror the CLI
/// defaults so library callers and the command agree.
#[derive(Debug, Clone)]
pub struct CorpusOptions {
    /// Shrink matrices and bench repetitions to a CI smoke scale.
    pub quick: bool,
    pub seed: u64,
    pub threads: usize,
    pub pin: bool,
    pub precision: Precision,
    /// SpMM width `k` for the blocked-x entries.
    pub block: usize,
    /// Power-law degree exponent for the generated graph.
    pub exponent: f64,
    /// Target average nnz/row for the power-law graph.
    pub avg_nnz: usize,
    /// Edges per vertex for the RMAT instance.
    pub edge_factor: usize,
    /// Restrict the sweep to these matrix names (empty = all).
    pub only: Vec<String>,
    /// Extra MatrixMarket files appended to the corpus.
    pub matrix_files: Vec<String>,
}

impl Default for CorpusOptions {
    fn default() -> Self {
        Self {
            quick: false,
            seed: 42,
            threads: 4,
            pin: false,
            precision: Precision::BitIdentical,
            block: 4,
            exponent: 2.2,
            avg_nnz: 8,
            edge_factor: 8,
            only: Vec::new(),
            matrix_files: Vec::new(),
        }
    }
}

/// One matrix × policy data point of the sweep.
#[derive(Debug, Clone)]
pub struct CorpusEntry {
    pub matrix: String,
    pub policy: String,
    pub backend: &'static str,
    pub scheme: String,
    pub schedule: String,
    pub mflops: f64,
    pub ns_per_nnz: f64,
}

/// The sweep's outcome: the JSON document for `BENCH_corpus.json`, the
/// flat decision records, and the headline agreement rate.
#[derive(Debug, Clone)]
pub struct CorpusReport {
    pub entries: Vec<CorpusEntry>,
    /// Fraction of corpus matrices where the heuristic tier picked the
    /// same (backend, scheme family, schedule kind) as the measured
    /// bake-off. `None` when the sweep covered no matrices.
    pub agreement_rate: Option<f64>,
    pub json: String,
}

/// The family/kind level at which heuristic and measured picks are
/// compared: chunk sizes and SELL (C, σ) parameters may legitimately
/// differ between the tiers without the decision being "wrong".
fn schedule_kind(s: Schedule) -> &'static str {
    match s {
        Schedule::Static { .. } => "static",
        Schedule::Dynamic { .. } => "dynamic",
        Schedule::Guided { .. } => "guided",
    }
}

/// The generated corpus, scaled by `quick`. Names are stable — they are
/// the benchdiff identities the committed baseline floors key on.
fn generated_corpus(opts: &CorpusOptions) -> Vec<(String, Coo)> {
    let mut rng = Rng::new(opts.seed);
    let (pl_n, rmat_scale, lap, band_n) =
        if opts.quick { (600, 8, 24, 1500) } else { (20_000, 14, 300, 40_000) };
    vec![
        (
            "power-law".to_string(),
            gen::power_law(pl_n, opts.avg_nnz, opts.exponent, &mut rng),
        ),
        (
            "rmat".to_string(),
            gen::rmat(rmat_scale, opts.edge_factor, (0.57, 0.19, 0.19, 0.05), &mut rng),
        ),
        ("laplacian-2d".to_string(), gen::laplacian_2d(lap, lap)),
        ("random-band".to_string(), gen::random_band(band_n, 10, band_n / 8, &mut rng)),
    ]
}

/// SpMV correctness bound for a tuned handle under `precision`,
/// mirroring the `spmvperf tune` spot-check contract.
fn validate_spmv(name: &str, precision: Precision, y_ref: &[f64], y: &[f64]) -> Result<()> {
    let err = match precision {
        Precision::BitIdentical => max_abs_diff(y_ref, y),
        Precision::Tolerance(_) => y
            .iter()
            .zip(y_ref)
            .map(|(g, w)| (g - w).abs() / w.abs().max(1.0))
            .fold(0.0, f64::max),
    };
    let bound = precision.tolerance().unwrap_or(1e-12);
    ensure!(
        err <= bound,
        "{name}: deviates from serial CRS by {err:.2e} (bound {bound:.1e})"
    );
    Ok(())
}

fn bench_config(quick: bool) -> Bench {
    if quick {
        Bench {
            warmup: std::time::Duration::from_millis(10),
            samples: 3,
            min_sample_time: std::time::Duration::from_millis(2),
        }
    } else {
        Bench::default()
    }
}

fn push_entry(entries: &mut Vec<String>, e: &CorpusEntry, extra: &str) {
    entries.push(format!(
        concat!(
            "    {{\"bench\": \"corpus\", \"matrix\": \"{}\", \"policy\": \"{}\", ",
            "\"backend\": \"{}\", \"scheme\": \"{}\", \"schedule\": \"{}\"{}, ",
            "\"mflops\": {:.3}, \"ns_per_nnz\": {:.4}}}"
        ),
        e.matrix, e.policy, e.backend, e.scheme, e.schedule, extra, e.mflops, e.ns_per_nnz
    ));
}

/// Sweep the corpus through the three tuning tiers plus the blocked-x
/// SpMM path, self-validating every configuration, and assemble the
/// `BENCH_corpus.json` document. Pure computation — the caller decides
/// whether to write the file.
pub fn run_corpus(opts: &CorpusOptions) -> Result<CorpusReport> {
    ensure!(opts.block >= 1, "--block must be at least 1");
    let mut matrices = generated_corpus(opts);
    for path in &opts.matrix_files {
        let coo = crate::matrix::io::read_matrix_market(std::path::Path::new(path))
            .with_context(|| format!("reading corpus matrix {path}"))?;
        let name = std::path::Path::new(path)
            .file_stem()
            .map(|s| s.to_string_lossy().into_owned())
            .unwrap_or_else(|| path.clone());
        matrices.push((name, coo));
    }
    if !opts.only.is_empty() {
        matrices.retain(|(name, _)| opts.only.iter().any(|m| m == name));
        ensure!(
            !matrices.is_empty(),
            "--matrices matched nothing (known: power-law, rmat, laplacian-2d, random-band)"
        );
    }

    let b = bench_config(opts.quick);
    let policies: [(&str, TuningPolicy); 3] = [
        ("fixed", TuningPolicy::Fixed(Scheme::Crs, Schedule::Static { chunk: None })),
        ("heuristic", TuningPolicy::Heuristic),
        ("measured", TuningPolicy::Measured),
    ];

    let mut entries: Vec<CorpusEntry> = Vec::new();
    let mut lines: Vec<String> = Vec::new();
    let mut agree = 0usize;
    let mut compared = 0usize;

    for (mname, coo) in &matrices {
        let crs = Crs::from_coo(coo);
        let n = crs.nrows;
        let nnz = crs.nnz() as u64;
        eprintln!("corpus matrix {mname}: N={n} nnz={nnz}");
        let mut rng = Rng::new(opts.seed.wrapping_add(1));
        let mut x = vec![0.0; n];
        rng.fill_f64(&mut x, -1.0, 1.0);
        let mut y_ref = vec![0.0; n];
        crs.spmv(&x, &mut y_ref);

        let mut picks: Vec<(&str, &'static str, String, &'static str)> = Vec::new();
        let mut y = vec![0.0; n];
        for (pname, policy) in &policies {
            let handle = SpmvHandle::builder_from_crs(&crs)
                .policy(*policy)
                .backend(BackendChoice::Auto)
                .threads(opts.threads)
                .quick(opts.quick)
                .pinned(opts.pin)
                .precision(opts.precision)
                .build()
                .with_context(|| format!("building {mname}/{pname}"))?;
            handle.spmv(&x, &mut y);
            validate_spmv(&format!("{mname}/{pname}"), opts.precision, &y_ref, &y)?;
            let r: BenchResult = b.run(&format!("{mname}/{pname}"), nnz, 2 * nnz, || {
                handle.spmv(&x, &mut y);
                y[0]
            });
            println!("{}", r.summary());
            let decision =
                handle.backend_decision().context("the builder records a decision")?;
            let e = CorpusEntry {
                matrix: mname.clone(),
                policy: pname.to_string(),
                backend: decision.backend,
                scheme: handle.scheme().spec(),
                schedule: handle.schedule().name(),
                mflops: r.mflops(),
                ns_per_nnz: r.ns_per_item(),
            };
            push_entry(&mut lines, &e, "");
            entries.push(e);
            picks.push((
                pname,
                handle.backend_name(),
                handle.scheme().name(),
                schedule_kind(handle.schedule()),
            ));
        }
        let find = |p: &str| picks.iter().find(|(name, ..)| *name == p);
        if let (Some(h), Some(m)) = (find("heuristic"), find("measured")) {
            compared += 1;
            if h.1 == m.1 && h.2 == m.2 && h.3 == m.3 {
                agree += 1;
            } else {
                eprintln!(
                    "{mname}: heuristic picked {}/{}/{} but measured {}/{}/{}",
                    h.1, h.2, h.3, m.1, m.2, m.3
                );
            }
        }

        // Blocked-x SpMM: validate against k independent per-vector
        // calls on the same handle, then time the multi path.
        let handle = SpmvHandle::builder_from_crs(&crs)
            .policy(TuningPolicy::Heuristic)
            .backend(BackendChoice::Auto)
            .threads(opts.threads)
            .quick(opts.quick)
            .pinned(opts.pin)
            .precision(opts.precision)
            .build()?;
        let k = opts.block;
        let xs: Vec<Vec<f64>> = (0..k)
            .map(|_| {
                let mut v = vec![0.0; n];
                rng.fill_f64(&mut v, -1.0, 1.0);
                v
            })
            .collect();
        let ys = handle.spmv_multi(&xs);
        ensure!(ys.len() == k, "{mname}: spmv_multi returned {} of {k} vectors", ys.len());
        for (xi, yi) in xs.iter().zip(&ys) {
            handle.spmv(xi, &mut y);
            let err = max_abs_diff(&y, yi);
            ensure!(
                err == 0.0 || opts.precision != Precision::BitIdentical,
                "{mname}: blocked-x SpMM deviates from per-vector spmv by {err:.2e}"
            );
        }
        let d = handle.multi_decision(k);
        let r = b.run(&format!("{mname}/blocked-x"), nnz * k as u64, 2 * nnz * k as u64, || {
            let ys = handle.spmv_multi(&xs);
            ys[0][0]
        });
        println!("{}", r.summary());
        let e = CorpusEntry {
            matrix: mname.clone(),
            policy: "blocked-x".to_string(),
            backend: handle.backend_name(),
            scheme: handle.scheme().spec(),
            schedule: handle.schedule().name(),
            mflops: r.mflops(),
            ns_per_nnz: r.ns_per_item(),
        };
        push_entry(&mut lines, &e, &format!(", \"block\": {k}, \"fused\": {}", d.blocked));
        entries.push(e);
    }

    // Solver self-validation: CG and power iteration on the SPD stencil,
    // PageRank on the scale-free graph — each handle-backed run checked
    // against its serial-operator reference. Presence-gated entries
    // (mflops 0.0) so CI notices if a solver is dropped from the sweep.
    let mut solver_lines: Vec<String> = Vec::new();
    if let Some((_, coo)) = matrices.iter().find(|(n, _)| n == "laplacian-2d") {
        let crs = Crs::from_coo(coo);
        let mut rng = Rng::new(opts.seed.wrapping_add(2));
        let mut rhs = vec![0.0; crs.nrows];
        rng.fill_f64(&mut rhs, -1.0, 1.0);
        let cfg = CgConfig { max_iters: 2 * crs.nrows, tol: 1e-10 };
        let serial = cg(&crs, &rhs, &cfg);
        ensure!(serial.converged, "serial CG failed to converge on laplacian-2d");
        let handle = SpmvHandle::builder_from_crs(&crs)
            .policy(TuningPolicy::Heuristic)
            .threads(opts.threads)
            .quick(opts.quick)
            .precision(opts.precision)
            .build()?;
        let tuned = cg_with_handle(&handle, &rhs, &cfg);
        ensure!(tuned.converged, "handle-backed CG failed to converge on laplacian-2d");
        // Under BitIdentical the whole solve reproduces serially bit for
        // bit; under Tolerance(ε) the trajectories legitimately diverge
        // and each run's converged residual is the correctness witness.
        if opts.precision == Precision::BitIdentical {
            let err = max_abs_diff(&serial.x, &tuned.x);
            ensure!(err == 0.0, "CG solutions diverge under BitIdentical: {err:.2e}");
        }
        solver_lines.push(format!(
            concat!(
                "    {{\"bench\": \"corpus\", \"name\": \"cg-laplacian-2d\", ",
                "\"iterations\": {}, \"residual\": {:.3e}, \"mflops\": 0.0}}"
            ),
            tuned.iterations, tuned.residual_norm
        ));
    }
    {
        // Power iteration on a fixed small probe: the corpus stencils'
        // spectral gap closes as they grow (λ₂/λ₁ → 1), pushing plain
        // power iteration past any fixed budget, so the solver path is
        // validated on a probe whose gap is designed (n = 20 ⇒ ratio
        // ≈ 0.983, convergence near iteration 1300).
        let probe = Crs::from_coo(&gen::laplacian_1d(20));
        let pcfg = PowerConfig::default();
        let ps = power_iteration(&probe, &pcfg);
        let handle = SpmvHandle::builder_from_crs(&probe)
            .policy(TuningPolicy::Heuristic)
            .threads(opts.threads)
            .quick(opts.quick)
            .precision(opts.precision)
            .build()?;
        let pt = power_iteration_with_handle(&handle, &pcfg);
        ensure!(
            ps.converged && pt.converged,
            "power iteration failed to converge on the laplacian-1d probe"
        );
        let rel = (ps.eigenvalue - pt.eigenvalue).abs() / ps.eigenvalue.abs().max(1.0);
        ensure!(rel <= 1e-6, "power-iteration eigenvalues diverge: {rel:.2e}");
        solver_lines.push(format!(
            concat!(
                "    {{\"bench\": \"corpus\", \"name\": \"power-iteration-probe\", ",
                "\"eigenvalue\": {:.6}, \"iterations\": {}, \"mflops\": 0.0}}"
            ),
            pt.eigenvalue, pt.iterations
        ));
    }
    if let Some((_, coo)) = matrices.iter().find(|(n, _)| n == "power-law") {
        let m = transition_matrix(coo);
        let crs = Crs::from_coo(&m);
        let pcfg = PowerConfig::default();
        let serial = pagerank(&crs, 0.85, &pcfg);
        ensure!(serial.converged, "serial PageRank failed to converge on power-law");
        let handle = SpmvHandle::builder_from_crs(&crs)
            .policy(TuningPolicy::Heuristic)
            .threads(opts.threads)
            .quick(opts.quick)
            .precision(opts.precision)
            .build()?;
        let tuned = pagerank_with_handle(&handle, 0.85, &pcfg);
        ensure!(tuned.converged, "handle-backed PageRank failed to converge on power-law");
        let err = max_abs_diff(&serial.ranks, &tuned.ranks);
        ensure!(err <= 1e-8, "PageRank vectors diverge: {err:.2e}");
        solver_lines.push(format!(
            concat!(
                "    {{\"bench\": \"corpus\", \"name\": \"pagerank-power-law\", ",
                "\"iterations\": {}, \"mflops\": 0.0}}"
            ),
            tuned.iterations
        ));
    }

    let agreement_rate = (compared > 0).then(|| agree as f64 / compared as f64);
    if let Some(rate) = agreement_rate {
        eprintln!(
            "heuristic-vs-measured agreement: {agree}/{compared} matrices ({:.0}%)",
            rate * 100.0
        );
        solver_lines.push(format!(
            concat!(
                "    {{\"bench\": \"corpus\", \"name\": \"heuristic-vs-measured-agreement\", ",
                "\"agreement_rate\": {:.4}, \"matrices\": {}, \"mflops\": 0.0}}"
            ),
            rate, compared
        ));
    }
    lines.extend(solver_lines);

    let mut json = String::new();
    let _ = writeln!(json, "{{");
    let _ = writeln!(json, "  \"bench\": \"corpus\",");
    let _ = writeln!(
        json,
        "  \"note\": \"Arbitration-quality benchmark: generated graph/stencil/band corpus \
         through all three tuning tiers plus blocked-x SpMM; solver entries and the \
         agreement-rate entry are presence-only floors (mflops 0).\","
    );
    let _ = writeln!(json, "  \"threads\": {},", opts.threads);
    let _ = writeln!(json, "  \"block\": {},", opts.block);
    let _ = writeln!(json, "  \"results\": [");
    let _ = writeln!(json, "{}", lines.join(",\n"));
    let _ = writeln!(json, "  ]");
    let _ = writeln!(json, "}}");
    Ok(CorpusReport { entries, agreement_rate, json })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::bench::parse_bench_entries;

    fn tiny_opts() -> CorpusOptions {
        CorpusOptions { quick: true, threads: 2, ..Default::default() }
    }

    /// The full quick sweep self-validates and emits the benchdiff
    /// identities the committed baseline floors key on.
    #[test]
    fn quick_sweep_emits_stable_identities_and_agreement_entry() {
        let report = run_corpus(&tiny_opts()).unwrap();
        let parsed = parse_bench_entries(&report.json);
        for m in ["power-law", "rmat", "laplacian-2d", "random-band"] {
            for p in ["fixed", "heuristic", "measured", "blocked-x"] {
                let label = format!("corpus/{m}/{p}");
                assert!(
                    parsed.iter().any(|e| e.label == label),
                    "missing bench entry {label}"
                );
            }
        }
        for solver in ["cg-laplacian-2d", "power-iteration-probe", "pagerank-power-law"] {
            let label = format!("corpus/{solver}");
            let e = parsed.iter().find(|e| e.label == label).expect("solver entry");
            assert_eq!(e.mflops, 0.0, "{label} must stay a presence-only floor");
        }
        let rate = report.agreement_rate.expect("agreement over 4 matrices");
        assert!((0.0..=1.0).contains(&rate));
        assert!(report
            .json
            .contains("\"name\": \"heuristic-vs-measured-agreement\""));
        // 4 matrices × (3 tiers + blocked-x).
        assert_eq!(report.entries.len(), 16);
        assert!(report.entries.iter().all(|e| e.mflops > 0.0));
    }

    /// `--matrices` restricts the sweep; an unknown name is an error,
    /// not an empty no-op that would vacuously pass CI.
    #[test]
    fn matrix_filter_restricts_and_rejects_unknown_names() {
        let mut opts = tiny_opts();
        opts.only = vec!["random-band".to_string()];
        let report = run_corpus(&opts).unwrap();
        assert!(report.entries.iter().all(|e| e.matrix == "random-band"));
        assert_eq!(report.entries.len(), 4);
        opts.only = vec!["no-such-matrix".to_string()];
        assert!(run_corpus(&opts).is_err());
    }
}
