//! The serving-layer bench behind `spmvperf serve [--bench]`: an
//! open-loop load generator sweeping offered load against a live
//! [`Server`], emitting `results/BENCH_serve.json` (p50/p99 latency ×
//! achieved throughput × shed rate per load point) for the CI
//! regression gate.
//!
//! Self-validating before timing: served results must be bit-identical
//! to a directly built [`crate::spmv::SpmvHandle`] with the same build
//! options, and within 1e-12 of serial CRS; repeat-tenant registrations
//! must hit the handle cache. The acceptance ratio — coalesced batched
//! dispatch vs one-request-per-dispatch at the same offered load — is
//! recorded as the `coalesce-ratio` entry.
//!
//! Latency is stamped client-side by an in-order collector thread
//! (submit time → reply received); because dispatch is FIFO per tenant
//! and oldest-head-first across tenants, the in-order wait bias is
//! bounded by one batch window.

use std::fmt::Write as _;
use std::sync::mpsc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::gen::{self, HolsteinHubbardParams};
use crate::matrix::{Crs, SpMv};
use crate::util::bench::write_bench_json;
use crate::util::report::{f, Table};
use crate::util::rng::Rng;
use crate::util::stats::{max_abs_diff, quantile};

use super::{build_handle, Server, ServeConfig, Ticket};

/// Knobs for [`run_bench`] — mirrored 1:1 by the `spmvperf serve` CLI
/// options (`--max-batch`, `--max-delay-us`, `--tenants`,
/// `--queue-cap`, `--duration`, `--quick`, `--bench`).
#[derive(Debug, Clone, Copy)]
pub struct BenchOpts {
    /// Shrink every measurement window (CI smoke).
    pub quick: bool,
    pub max_batch: usize,
    pub max_delay_us: u64,
    pub tenants: usize,
    pub queue_cap: usize,
    /// Per-load-point measurement window, milliseconds.
    pub duration_ms: u64,
    /// Emit `results/BENCH_serve.json` (the `--bench` flag).
    pub write_json: bool,
}

impl Default for BenchOpts {
    fn default() -> Self {
        BenchOpts {
            quick: false,
            max_batch: 8,
            max_delay_us: 200,
            tenants: 2,
            queue_cap: 256,
            duration_ms: 300,
            write_json: false,
        }
    }
}

/// One open-loop measurement at a fixed offered load.
struct Point {
    completed: u64,
    achieved_rps: f64,
    p50_us: f64,
    p99_us: f64,
    shed_rate: f64,
}

pub fn run_bench(o: &BenchOpts) -> Result<()> {
    anyhow::ensure!(o.tenants >= 1, "need at least one tenant");
    anyhow::ensure!(o.max_batch >= 1, "--max-batch must be at least 1");
    let crs = Crs::from_coo(&gen::holstein_hubbard(&HolsteinHubbardParams::tiny()));
    let n = crs.nrows;
    let nnz = crs.nnz();
    let point_dur =
        Duration::from_millis(if o.quick { o.duration_ms.min(80) } else { o.duration_ms });
    let cfg = ServeConfig {
        max_batch: o.max_batch,
        max_delay: Duration::from_micros(o.max_delay_us),
        queue_cap: o.queue_cap,
        ..ServeConfig::default()
    };
    let tenants: Vec<String> = (0..o.tenants).map(|t| format!("t{t}")).collect();
    eprintln!(
        "serve bench: dim {n}, nnz {nnz}, {} tenant(s), max_batch {} / max_delay {} us, \
         queue cap {}, {} ms/point",
        o.tenants,
        o.max_batch,
        o.max_delay_us,
        o.queue_cap,
        point_dur.as_millis()
    );

    let mut server = Server::start(cfg);
    for t in &tenants {
        server.register(t, crs.clone())?;
    }
    let s = server.stats();
    anyhow::ensure!(
        s.cache_misses == 1 && s.cache_hits == o.tenants as u64 - 1,
        "repeat-tenant registrations must hit the handle cache \
         (misses {}, hits {}, tenants {})",
        s.cache_misses,
        s.cache_hits,
        o.tenants
    );

    // Self-validation before any timing: the serving path must not
    // change the math.
    let mut rng = Rng::new(7);
    let mut x = vec![0.0; n];
    rng.fill_f64(&mut x, -1.0, 1.0);
    let direct = build_handle(&crs, &cfg.build_opts())?;
    let mut want = vec![0.0; n];
    direct.spmv(&x, &mut want);
    let mut want_crs = vec![0.0; n];
    crs.spmv(&x, &mut want_crs);
    for t in &tenants {
        let got = server
            .submit(t, x.clone())
            .map_err(|r| anyhow::anyhow!("validation submit rejected: {}", r.reason()))?
            .wait();
        anyhow::ensure!(
            max_abs_diff(&want, &got) == 0.0,
            "served result not bit-identical to a directly built handle"
        );
        anyhow::ensure!(
            max_abs_diff(&want_crs, &got) < 1e-12,
            "served result deviates from serial CRS"
        );
    }
    eprintln!(
        "self-validation OK: served == direct handle (bit-identical), == serial CRS (1e-12); \
         cache hits {}/{} registrations",
        s.cache_hits,
        o.tenants
    );

    // Closed-loop capacity estimate, then the open-loop sweep around it.
    let burst = (4 * o.max_batch).min(o.queue_cap).max(1);
    let cap_dur = point_dur.min(Duration::from_millis(150));
    let cap_rps = closed_loop_capacity(&server, &tenants, &x, burst, cap_dur).max(50.0);
    eprintln!("closed-loop capacity ~ {cap_rps:.0} req/s (burst {burst})");

    let mut table = Table::new(
        "serve: open-loop load sweep (Holstein-Hubbard tiny)",
        &["config", "offered req/s", "achieved req/s", "p50 us", "p99 us", "shed rate", "MFlop/s"],
    );
    let mut entries: Vec<String> = Vec::new();
    let mut push_entry = |config: &str, p: &Point, offered_rps: f64, mflops: f64| {
        entries.push(format!(
            concat!(
                "    {{\"matrix\": \"holstein-hubbard\", \"config\": \"{}\", ",
                "\"tenants\": {}, \"max_batch\": {}, \"max_delay_us\": {}, ",
                "\"queue_cap\": {}, \"offered_rps\": {:.1}, \"achieved_rps\": {:.1}, ",
                "\"p50_us\": {:.1}, \"p99_us\": {:.1}, \"shed_rate\": {:.4}, ",
                "\"completed\": {}, \"mflops\": {:.3}}}"
            ),
            config,
            o.tenants,
            o.max_batch,
            o.max_delay_us,
            o.queue_cap,
            offered_rps,
            p.achieved_rps,
            p.p50_us,
            p.p99_us,
            p.shed_rate,
            p.completed,
            mflops,
        ));
    };
    let mut batched_at_capacity = 0.0_f64;
    for (label, mult) in [("load0.5x", 0.5), ("load1x", 1.0), ("load2x", 2.0)] {
        let offered_rps = cap_rps * mult;
        let p = open_loop_point(&server, &tenants, &x, offered_rps, point_dur);
        if label == "load1x" {
            batched_at_capacity = p.achieved_rps;
        }
        let mflops = p.achieved_rps * (2 * nnz) as f64 / 1e6;
        table.row(vec![
            label.to_string(),
            f(offered_rps),
            f(p.achieved_rps),
            f(p.p50_us),
            f(p.p99_us),
            f(p.shed_rate),
            f(mflops),
        ]);
        push_entry(label, &p, offered_rps, mflops);
    }
    server.shutdown();

    // The acceptance ratio: the same offered load served with batch
    // coalescing disabled (max_batch = 1, one request per dispatch).
    let single_cfg = ServeConfig { max_batch: 1, ..cfg };
    let mut single = Server::start(single_cfg);
    for t in &tenants {
        single.register(t, crs.clone())?;
    }
    let p1 = open_loop_point(&single, &tenants, &x, cap_rps, point_dur);
    single.shutdown();
    anyhow::ensure!(p1.completed > 0, "single-dispatch run served nothing");
    let single_mflops = p1.achieved_rps * (2 * nnz) as f64 / 1e6;
    let ratio = batched_at_capacity / p1.achieved_rps.max(1e-9);
    table.row(vec![
        "coalesce-single".into(),
        f(cap_rps),
        f(p1.achieved_rps),
        f(p1.p50_us),
        f(p1.p99_us),
        f(p1.shed_rate),
        f(single_mflops),
    ]);
    push_entry("coalesce-single", &p1, cap_rps, single_mflops);
    entries.push(format!(
        concat!(
            "    {{\"matrix\": \"holstein-hubbard\", \"config\": \"coalesce-ratio\", ",
            "\"batched_rps\": {:.1}, \"single_rps\": {:.1}, \"mflops\": {:.4}}}"
        ),
        batched_at_capacity, p1.achieved_rps, ratio,
    ));
    table.print();
    println!(
        "coalesced/single-dispatch throughput at the same offered load: {ratio:.3}x \
         ({batched_at_capacity:.0} vs {:.0} req/s)",
        p1.achieved_rps
    );

    if o.write_json {
        let mut json = String::new();
        let _ = writeln!(json, "{{");
        let _ = writeln!(json, "  \"bench\": \"serve\",");
        let _ = writeln!(
            json,
            "  \"note\": \"coalesce-ratio mflops field is the batched/single throughput \
             ratio, not MFlop/s\","
        );
        let _ = writeln!(json, "  \"results\": [");
        let _ = writeln!(json, "{}", entries.join(",\n"));
        let _ = writeln!(json, "  ]");
        let _ = writeln!(json, "}}");
        write_bench_json("BENCH_serve.json", &json);
    }
    Ok(())
}

/// Saturated closed-loop bursts: submit `burst` requests round-robin
/// across tenants, wait for all, repeat — the server's sustainable
/// req/s under full batches, used to anchor the open-loop sweep.
fn closed_loop_capacity(
    server: &Server,
    tenants: &[String],
    x: &[f64],
    burst: usize,
    dur: Duration,
) -> f64 {
    let t0 = Instant::now();
    let mut done = 0u64;
    let mut ti = 0usize;
    while t0.elapsed() < dur {
        let tickets: Vec<Ticket> = (0..burst)
            .filter_map(|_| {
                let t = &tenants[ti % tenants.len()];
                ti += 1;
                server.submit(t, x.to_vec()).ok()
            })
            .collect();
        if tickets.is_empty() {
            break;
        }
        for t in tickets {
            t.wait();
            done += 1;
        }
    }
    done as f64 / t0.elapsed().as_secs_f64().max(1e-9)
}

/// One open-loop point: submit on a fixed arrival schedule regardless
/// of completions (deficit-based, so a stalled server does not slow the
/// offered load), collect per-request latency on a side thread, and
/// count shed submissions.
fn open_loop_point(
    server: &Server,
    tenants: &[String],
    x: &[f64],
    offered_rps: f64,
    dur: Duration,
) -> Point {
    let (tx, rx) = mpsc::channel::<(Instant, Ticket)>();
    // audit:allow(thread_spawn): bench harness latency collector, not a serving code path
    let collector = std::thread::spawn(move || {
        let mut lats: Vec<f64> = Vec::new();
        let mut checksum = 0.0;
        let mut last: Option<Instant> = None;
        for (t0, ticket) in rx {
            let y = ticket.wait();
            lats.push(t0.elapsed().as_secs_f64() * 1e6);
            checksum += y[0];
            last = Some(Instant::now());
        }
        (lats, last, checksum)
    });
    let start = Instant::now();
    let mut offered = 0u64;
    let mut shed = 0u64;
    let mut ti = 0usize;
    loop {
        let el = start.elapsed();
        if el >= dur {
            break;
        }
        // Open loop: arrivals due so far at this offered rate, minus
        // what we already submitted.
        let due = (el.as_secs_f64() * offered_rps) as u64 + 1;
        while offered < due {
            let t = &tenants[ti % tenants.len()];
            ti += 1;
            match server.submit(t, x.to_vec()) {
                Ok(ticket) => {
                    let _ = tx.send((Instant::now(), ticket));
                }
                Err(r) => {
                    debug_assert!(r.is_shed(), "load generator mis-submitted: {}", r.reason());
                    shed += 1;
                }
            }
            offered += 1;
        }
        std::thread::sleep(Duration::from_micros(200));
    }
    drop(tx);
    let (lats, last, _checksum) = collector.join().expect("latency collector panicked");
    let completed = lats.len() as u64;
    let wall = last
        .map(|l| l.duration_since(start))
        .unwrap_or_else(|| start.elapsed())
        .as_secs_f64()
        .max(1e-9);
    Point {
        completed,
        achieved_rps: completed as f64 / wall,
        p50_us: quantile(&lats, 0.5),
        p99_us: quantile(&lats, 0.99),
        shed_rate: shed as f64 / offered.max(1) as f64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The whole bench pipeline (validation, capacity, sweep, ratio) in
    /// a tiny quick run — no JSON side effects.
    #[test]
    fn quick_bench_runs_end_to_end() {
        let o = BenchOpts { quick: true, duration_ms: 30, ..BenchOpts::default() };
        run_bench(&o).unwrap();
    }
}
