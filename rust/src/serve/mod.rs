//! The serving layer: persistent dispatch, adaptive batching, and a
//! multi-tenant handle cache — the production face of the repo's
//! north-star ("serve heavy traffic from millions of users").
//!
//! The paper's central finding is that SpMV is bandwidth-bound, so
//! sustained service throughput comes from amortizing per-call
//! overheads. Three layers below this one already amortize — the engine
//! pays its completion latch once per batch (`spmv_batch`), the sharded
//! backend parks persistent coordinator/exchange roles between calls
//! ([`crate::engine::TaskPool`]), and the tuner's per-matrix search pays
//! off only across many calls (arXiv:1711.05487). [`Server`] is the
//! piece that turns *independent caller requests* into those amortized
//! shapes:
//!
//! - **Submission queue + deadline coalescing**: [`Server::submit`]
//!   enqueues; a persistent dispatcher thread collects same-tenant
//!   requests into one `spmv_batch` dispatch, releasing a batch when it
//!   reaches `max_batch` requests or its oldest request has waited
//!   `max_delay` — latency-bounded batching.
//! - **Multi-tenant handle cache**: [`HandleCache`] keeps an LRU of
//!   tuned [`SpmvHandle`]s keyed by [`MatrixFingerprint`], so repeat
//!   tenants (or tenants sharing a matrix) skip the tune cost entirely
//!   (full hit) or at least the tuning search (structural "plan hit":
//!   same pattern, new values ⇒ reuse scheme/schedule/backend, rebuild
//!   on the new values). Evicted handles drop their engines cleanly
//!   when the last tenant reference goes.
//! - **Admission control**: a bounded global queue plus a per-tenant
//!   quota (`queue_cap / n_tenants`) shed overload with a typed
//!   [`Rejected`] reason instead of unbounded latency, and keep one hot
//!   tenant from starving the rest; among deadline-ready tenants the
//!   dispatcher always serves the oldest head (FIFO across tenants).
//!
//! Threading: [`SpmvHandle`] is deliberately **not** `Send` (a future
//! PJRT backend won't be), so handles never cross threads — the
//! dispatcher thread builds, caches (`Rc`), and executes them; clients
//! talk to it only through the control queue and per-request reply
//! channels. See DESIGN.md §6 for the sequence diagram.

use std::collections::{HashMap, VecDeque};
use std::rc::Rc;
use std::sync::atomic::{AtomicU64, Ordering::Relaxed};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::kernels::Precision;
use crate::matrix::Crs;
use crate::spmv::{BackendChoice, SpmvHandle};
use crate::tune::{MatrixFingerprint, TuningPolicy};

mod bench;
pub use bench::{run_bench, BenchOpts};

/// How the server builds and batches. `Default` is the tuned-but-quick
/// profile the CLI and tests use.
#[derive(Debug, Clone, Copy)]
pub struct ServeConfig {
    /// Largest coalesced batch per dispatch.
    pub max_batch: usize,
    /// Longest a request may wait for co-batching before its batch is
    /// released anyway.
    pub max_delay: Duration,
    /// Global bound on queued (admitted, undispatched) requests; the
    /// per-tenant quota is `queue_cap / n_tenants` (at least 1).
    pub queue_cap: usize,
    /// Capacity of the tuned-handle LRU cache.
    pub cache_cap: usize,
    /// Engine threads per tuned handle.
    pub threads: usize,
    /// Quick tuning (short measured probes) when the policy measures.
    pub quick: bool,
    /// Pin handle engines (serving usually leaves this off — tenants
    /// share the machine).
    pub pinned: bool,
    pub precision: Precision,
    pub policy: TuningPolicy,
    pub backend: BackendChoice,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            max_batch: 8,
            max_delay: Duration::from_micros(200),
            queue_cap: 256,
            cache_cap: 8,
            threads: 2,
            quick: true,
            pinned: false,
            precision: Precision::BitIdentical,
            policy: TuningPolicy::Heuristic,
            backend: BackendChoice::Auto,
        }
    }
}

impl ServeConfig {
    fn build_opts(&self) -> BuildOpts {
        BuildOpts {
            policy: self.policy,
            backend: self.backend,
            threads: self.threads,
            quick: self.quick,
            pinned: self.pinned,
            precision: self.precision,
        }
    }
}

/// How the cache builds a handle on a miss (a [`ServeConfig`] slice,
/// separated so [`HandleCache`] is testable without a server).
#[derive(Debug, Clone, Copy)]
pub struct BuildOpts {
    pub policy: TuningPolicy,
    pub backend: BackendChoice,
    pub threads: usize,
    pub quick: bool,
    pub pinned: bool,
    pub precision: Precision,
}

impl Default for BuildOpts {
    fn default() -> Self {
        ServeConfig::default().build_opts()
    }
}

fn build_handle(crs: &Crs, opts: &BuildOpts) -> Result<SpmvHandle> {
    SpmvHandle::builder_from_crs(crs)
        .policy(opts.policy)
        .backend(opts.backend)
        .threads(opts.threads)
        .quick(opts.quick)
        .pinned(opts.pinned)
        .precision(opts.precision)
        .build()
}

/// What [`HandleCache::get_or_build`] did for a lookup.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CacheOutcome {
    /// Same structure and same values: the cached handle is reused as
    /// is — the tune cost is skipped entirely.
    Hit,
    /// Same structure, different values (a fingerprint "collision" on
    /// the tuning-relevant identity): the cached *plan* — scheme,
    /// schedule, backend — transfers, but the handle is rebuilt on the
    /// new values so results stay correct.
    PlanHit,
    /// Unknown matrix: full tuning run.
    Miss,
}

impl CacheOutcome {
    pub fn name(&self) -> &'static str {
        match self {
            CacheOutcome::Hit => "hit",
            CacheOutcome::PlanHit => "plan-hit",
            CacheOutcome::Miss => "miss",
        }
    }
}

/// LRU cache of tuned handles keyed by [`MatrixFingerprint`]. Entries
/// are `Rc` so the dispatcher's tenant registry can keep a served
/// handle alive past eviction; when the last reference drops, the
/// handle's backend (and its engine worker pools) shut down cleanly —
/// that is the whole eviction contract.
pub struct HandleCache {
    cap: usize,
    /// MRU first.
    entries: Vec<(MatrixFingerprint, Rc<SpmvHandle>)>,
    hits: u64,
    plan_hits: u64,
    misses: u64,
    evictions: u64,
}

impl HandleCache {
    pub fn new(cap: usize) -> Self {
        assert!(cap > 0, "handle cache needs capacity for at least one handle");
        HandleCache { cap, entries: Vec::new(), hits: 0, plan_hits: 0, misses: 0, evictions: 0 }
    }

    /// Look `crs` up by fingerprint; build (and insert MRU) on a miss
    /// or plan hit. See [`CacheOutcome`] for the three paths.
    pub fn get_or_build(
        &mut self,
        crs: &Crs,
        opts: &BuildOpts,
    ) -> Result<(Rc<SpmvHandle>, CacheOutcome)> {
        let fp = MatrixFingerprint::of(crs);
        if let Some(i) = self.entries.iter().position(|(k, _)| *k == fp) {
            let e = self.entries.remove(i);
            self.entries.insert(0, e);
            self.hits += 1;
            return Ok((self.entries[0].1.clone(), CacheOutcome::Hit));
        }
        if let Some(i) = self.entries.iter().position(|(k, _)| k.same_structure(&fp)) {
            // Plan hit: the tuning decisions depend only on structure,
            // so pin them from the cached handle and rebuild on the new
            // values. The value-stale entry is replaced (its engines
            // drop with the last outside reference).
            let (_, stale) = self.entries.remove(i);
            let mut pinned_opts = *opts;
            pinned_opts.policy = TuningPolicy::Fixed(stale.scheme(), stale.schedule());
            // Only replay the cached backend when the caller left the
            // choice to arbitration. An explicit request wins: replaying
            // verbatim re-asserted every backend-capability artifact of
            // the cached build with it — e.g. a serial handle's report
            // pins kernel_isa = Scalar (inline execution), and a tenant
            // asking for native would silently inherit that cap. The
            // rebuild below then re-derives kernel_isa from the rebuilt
            // backend's actual capability under the Fixed tier — since
            // ISSUE 9 the sharded split kernels vectorize too, so a
            // Tolerance tenant gets a vector ISA on sharded exactly as
            // on native.
            if opts.backend == BackendChoice::Auto {
                pinned_opts.backend =
                    BackendChoice::parse(stale.backend_name()).unwrap_or(opts.backend);
            }
            drop(stale);
            let h = Rc::new(build_handle(crs, &pinned_opts)?);
            self.entries.insert(0, (fp, h.clone()));
            self.plan_hits += 1;
            self.trim();
            return Ok((h, CacheOutcome::PlanHit));
        }
        let h = Rc::new(build_handle(crs, opts)?);
        self.entries.insert(0, (fp, h.clone()));
        self.misses += 1;
        self.trim();
        Ok((h, CacheOutcome::Miss))
    }

    fn trim(&mut self) {
        while self.entries.len() > self.cap {
            self.entries.pop();
            self.evictions += 1;
        }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
    /// Cached fingerprints, most recently used first.
    pub fn fingerprints(&self) -> Vec<MatrixFingerprint> {
        self.entries.iter().map(|(k, _)| *k).collect()
    }
    pub fn hits(&self) -> u64 {
        self.hits
    }
    pub fn plan_hits(&self) -> u64 {
        self.plan_hits
    }
    pub fn misses(&self) -> u64 {
        self.misses
    }
    pub fn evictions(&self) -> u64 {
        self.evictions
    }
}

/// Why [`Server::submit`] refused a request. Overload refusals
/// ([`Rejected::is_shed`]) are the graceful-shedding half of admission
/// control; the others are caller errors.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Rejected {
    /// No [`Server::register`] for this tenant yet.
    UnknownTenant,
    /// Input length does not match the tenant's registered matrix.
    DimMismatch { want: usize, got: usize },
    /// The global queue is at `queue_cap`.
    QueueFull,
    /// This tenant is at its fairness quota (`queue_cap / n_tenants`).
    TenantQuota,
    /// The server is draining for shutdown.
    ShuttingDown,
}

impl Rejected {
    pub fn reason(&self) -> &'static str {
        match self {
            Rejected::UnknownTenant => "unknown-tenant",
            Rejected::DimMismatch { .. } => "dim-mismatch",
            Rejected::QueueFull => "queue-full",
            Rejected::TenantQuota => "tenant-quota",
            Rejected::ShuttingDown => "shutting-down",
        }
    }

    /// Overload shedding (counted in [`ServeStats::shed`]) as opposed
    /// to a malformed request.
    pub fn is_shed(&self) -> bool {
        matches!(self, Rejected::QueueFull | Rejected::TenantQuota | Rejected::ShuttingDown)
    }
}

/// An admitted request's claim check: blocks until the dispatcher
/// serves its batch.
pub struct Ticket {
    rx: mpsc::Receiver<Vec<f64>>,
}

impl Ticket {
    /// Wait for the result. Admitted requests are always served — the
    /// dispatcher drains every queue before shutting down.
    pub fn wait(self) -> Vec<f64> {
        self.rx.recv().expect("serve dispatcher dropped an admitted request")
    }
}

/// Counters snapshot; see [`Server::stats`]. The `cache_*` fields
/// mirror the dispatcher-side [`HandleCache`] counters after each
/// registration.
#[derive(Debug, Clone, Copy, Default)]
pub struct ServeStats {
    pub submitted: u64,
    pub completed: u64,
    pub shed: u64,
    pub dispatches: u64,
    pub dispatched_requests: u64,
    pub cache_hits: u64,
    pub cache_plan_hits: u64,
    pub cache_misses: u64,
    pub cache_evictions: u64,
}

impl ServeStats {
    /// Mean coalesced batch size — the amortization the queue actually
    /// achieved.
    pub fn avg_batch(&self) -> f64 {
        if self.dispatches == 0 {
            0.0
        } else {
            self.dispatched_requests as f64 / self.dispatches as f64
        }
    }
}

#[derive(Default)]
struct StatsInner {
    submitted: AtomicU64,
    completed: AtomicU64,
    shed: AtomicU64,
    dispatches: AtomicU64,
    dispatched_requests: AtomicU64,
    cache_hits: AtomicU64,
    cache_plan_hits: AtomicU64,
    cache_misses: AtomicU64,
    cache_evictions: AtomicU64,
}

impl StatsInner {
    fn sync_cache(&self, cache: &HandleCache) {
        self.cache_hits.store(cache.hits(), Relaxed);
        self.cache_plan_hits.store(cache.plan_hits(), Relaxed);
        self.cache_misses.store(cache.misses(), Relaxed);
        self.cache_evictions.store(cache.evictions(), Relaxed);
    }
}

struct Pending {
    x: Vec<f64>,
    enqueued: Instant,
    reply: mpsc::Sender<Vec<f64>>,
}

struct TenantState {
    dim: usize,
    queue: VecDeque<Pending>,
}

enum Control {
    Register {
        tenant: String,
        crs: Box<Crs>,
        reply: mpsc::Sender<std::result::Result<CacheOutcome, String>>,
    },
}

struct Shared {
    tenants: HashMap<String, TenantState>,
    total_queued: usize,
    control: VecDeque<Control>,
    shutting_down: bool,
}

struct Inner {
    shared: Mutex<Shared>,
    work: Condvar,
}

/// The serving front end; see the module docs. Clients call
/// [`Server::register`] once per tenant and [`Server::submit`] per
/// request; one persistent dispatcher thread owns every handle.
pub struct Server {
    inner: Arc<Inner>,
    stats: Arc<StatsInner>,
    cfg: ServeConfig,
    dispatcher: Option<JoinHandle<()>>,
}

impl Server {
    pub fn start(cfg: ServeConfig) -> Server {
        assert!(cfg.max_batch > 0, "max_batch must be at least 1");
        assert!(cfg.queue_cap > 0, "queue_cap must be at least 1");
        let inner = Arc::new(Inner {
            shared: Mutex::new(Shared {
                tenants: HashMap::new(),
                total_queued: 0,
                control: VecDeque::new(),
                shutting_down: false,
            }),
            work: Condvar::new(),
        });
        let stats = Arc::new(StatsInner::default());
        let dispatcher = {
            let inner = inner.clone();
            let stats = stats.clone();
            // audit:allow(thread_spawn): one dispatcher per Server, spawned once at construction
            std::thread::Builder::new()
                .name("spmv-serve-dispatch".to_string())
                .spawn(move || dispatcher_loop(&inner, &stats, cfg))
                .expect("spawning serve dispatcher")
        };
        Server { inner, stats, cfg, dispatcher: Some(dispatcher) }
    }

    pub fn config(&self) -> &ServeConfig {
        &self.cfg
    }

    /// Register (or re-register) `tenant` to serve `crs`. Blocks until
    /// the dispatcher has a tuned handle — cached or freshly built —
    /// and returns how the cache resolved it. After `Ok`, submissions
    /// for this tenant are admitted.
    pub fn register(&self, tenant: &str, crs: Crs) -> Result<CacheOutcome> {
        let (tx, rx) = mpsc::channel();
        {
            let mut sh = self.inner.shared.lock().unwrap();
            anyhow::ensure!(!sh.shutting_down, "server is shutting down");
            sh.control.push_back(Control::Register {
                tenant: tenant.to_string(),
                crs: Box::new(crs),
                reply: tx,
            });
        }
        self.inner.work.notify_all();
        match rx.recv() {
            Ok(Ok(outcome)) => Ok(outcome),
            Ok(Err(msg)) => Err(anyhow::Error::msg(msg)),
            Err(_) => Err(anyhow::Error::msg("serve dispatcher exited during registration")),
        }
    }

    /// Admit one request, or refuse with a typed reason. Admission is
    /// O(1) under the shared lock; the returned [`Ticket`] resolves
    /// when the dispatcher serves the request's coalesced batch.
    pub fn submit(&self, tenant: &str, x: Vec<f64>) -> std::result::Result<Ticket, Rejected> {
        let mut sh = self.inner.shared.lock().unwrap();
        if sh.shutting_down {
            drop(sh);
            self.stats.shed.fetch_add(1, Relaxed);
            return Err(Rejected::ShuttingDown);
        }
        let n_tenants = sh.tenants.len().max(1);
        let quota = (self.cfg.queue_cap / n_tenants).max(1);
        let total = sh.total_queued;
        let cap = self.cfg.queue_cap;
        let Some(ts) = sh.tenants.get_mut(tenant) else {
            return Err(Rejected::UnknownTenant);
        };
        if x.len() != ts.dim {
            return Err(Rejected::DimMismatch { want: ts.dim, got: x.len() });
        }
        let refused = if total >= cap {
            Some(Rejected::QueueFull)
        } else if ts.queue.len() >= quota {
            Some(Rejected::TenantQuota)
        } else {
            None
        };
        if let Some(r) = refused {
            drop(sh);
            self.stats.shed.fetch_add(1, Relaxed);
            return Err(r);
        }
        let (tx, rx) = mpsc::channel();
        ts.queue.push_back(Pending { x, enqueued: Instant::now(), reply: tx });
        sh.total_queued += 1;
        drop(sh);
        self.stats.submitted.fetch_add(1, Relaxed);
        self.inner.work.notify_all();
        Ok(Ticket { rx })
    }

    pub fn stats(&self) -> ServeStats {
        ServeStats {
            submitted: self.stats.submitted.load(Relaxed),
            completed: self.stats.completed.load(Relaxed),
            shed: self.stats.shed.load(Relaxed),
            dispatches: self.stats.dispatches.load(Relaxed),
            dispatched_requests: self.stats.dispatched_requests.load(Relaxed),
            cache_hits: self.stats.cache_hits.load(Relaxed),
            cache_plan_hits: self.stats.cache_plan_hits.load(Relaxed),
            cache_misses: self.stats.cache_misses.load(Relaxed),
            cache_evictions: self.stats.cache_evictions.load(Relaxed),
        }
    }

    /// Admitted requests not yet dispatched.
    pub fn queue_depth(&self) -> usize {
        self.inner.shared.lock().unwrap().total_queued
    }

    /// Graceful shutdown: stop admitting, serve everything already
    /// queued, join the dispatcher. Idempotent; also run by `Drop`.
    pub fn shutdown(&mut self) {
        self.inner.shared.lock().unwrap().shutting_down = true;
        self.inner.work.notify_all();
        if let Some(h) = self.dispatcher.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// The persistent dispatcher: drain control messages, serve the oldest
/// deadline-ready tenant one coalesced batch at a time, park on the
/// condvar (bounded by the earliest batching deadline) when idle.
fn dispatcher_loop(inner: &Inner, stats: &StatsInner, cfg: ServeConfig) {
    let mut cache = HandleCache::new(cfg.cache_cap);
    let mut handles: HashMap<String, Rc<SpmvHandle>> = HashMap::new();
    let opts = cfg.build_opts();
    let mut sh = inner.shared.lock().unwrap();
    loop {
        // Registrations first: tuning runs without the lock held, so
        // admission and other tenants' dispatches are never blocked on
        // a tune.
        while let Some(Control::Register { tenant, crs, reply }) = sh.control.pop_front() {
            drop(sh);
            let dim = crs.nrows;
            let built = cache.get_or_build(&crs, &opts);
            stats.sync_cache(&cache);
            sh = inner.shared.lock().unwrap();
            match built {
                Ok((h, outcome)) => {
                    let ts = sh
                        .tenants
                        .entry(tenant.clone())
                        .or_insert_with(|| TenantState { dim, queue: VecDeque::new() });
                    if ts.dim != dim && !ts.queue.is_empty() {
                        let _ = reply.send(Err(format!(
                            "tenant '{tenant}' re-registered with dim {dim} while {} \
                             dim-{} requests are queued",
                            ts.queue.len(),
                            ts.dim
                        )));
                    } else {
                        ts.dim = dim;
                        handles.insert(tenant, h);
                        let _ = reply.send(Ok(outcome));
                    }
                }
                Err(e) => {
                    let _ = reply.send(Err(e.to_string()));
                }
            }
        }
        // Fairness: among tenants whose head batch is ready (full,
        // past its deadline, or draining for shutdown), serve the one
        // whose head request has waited longest.
        let now = Instant::now();
        let mut pick: Option<(String, Instant)> = None;
        for (name, ts) in &sh.tenants {
            if let Some(head) = ts.queue.front() {
                let ready = sh.shutting_down
                    || ts.queue.len() >= cfg.max_batch
                    || head.enqueued + cfg.max_delay <= now;
                let older = match &pick {
                    None => true,
                    Some((_, oldest)) => head.enqueued < *oldest,
                };
                if ready && older {
                    pick = Some((name.clone(), head.enqueued));
                }
            }
        }
        if let Some((name, _)) = pick {
            let ts = sh.tenants.get_mut(&name).expect("picked tenant exists");
            let take = ts.queue.len().min(cfg.max_batch);
            let batch: Vec<Pending> = ts.queue.drain(..take).collect();
            sh.total_queued -= take;
            drop(sh);
            let handle = handles.get(&name).expect("registered tenant has a handle").clone();
            let mut xs = Vec::with_capacity(take);
            let mut replies = Vec::with_capacity(take);
            for p in batch {
                xs.push(p.x);
                replies.push(p.reply);
            }
            let ys = handle.spmv_batch(&xs);
            for (y, reply) in ys.into_iter().zip(replies) {
                let _ = reply.send(y);
            }
            stats.dispatches.fetch_add(1, Relaxed);
            stats.dispatched_requests.fetch_add(take as u64, Relaxed);
            stats.completed.fetch_add(take as u64, Relaxed);
            sh = inner.shared.lock().unwrap();
            continue;
        }
        if sh.shutting_down && sh.total_queued == 0 && sh.control.is_empty() {
            return;
        }
        // Park: until the earliest head's batching deadline, or until
        // a submit/register/shutdown notifies.
        let next_deadline = sh
            .tenants
            .values()
            .filter_map(|ts| ts.queue.front().map(|p| p.enqueued + cfg.max_delay))
            .min();
        sh = match next_deadline {
            Some(d) => {
                let wait = d.saturating_duration_since(Instant::now());
                if wait.is_zero() {
                    continue; // became ready between the scan and now
                }
                inner.work.wait_timeout(sh, wait).unwrap().0
            }
            None => inner.work.wait(sh).unwrap(),
        };
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen::{self, HolsteinHubbardParams};
    use crate::matrix::Scheme;
    use crate::sched::Schedule;
    use crate::util::rng::Rng;
    use crate::util::stats::max_abs_diff;

    fn hh_crs() -> Crs {
        Crs::from_coo(&gen::holstein_hubbard(&HolsteinHubbardParams::tiny()))
    }

    fn band_crs(seed: u64, n: usize) -> Crs {
        Crs::from_coo(&gen::random_band(n, 7, 20, &mut Rng::new(seed)))
    }

    fn rand_x(seed: u64, n: usize) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        let mut x = vec![0.0; n];
        rng.fill_f64(&mut x, -1.0, 1.0);
        x
    }

    /// ISSUE-7 satellite: LRU order — a re-used entry moves to MRU and
    /// survives the insert that evicts the actual least-recently-used
    /// one; counters track every path.
    #[test]
    fn handle_cache_lru_eviction_order() {
        let opts = BuildOpts::default();
        let mut cache = HandleCache::new(2);
        let (a, b, c) = (band_crs(1, 90), band_crs(2, 100), band_crs(3, 110));
        let (fa, fb, fc) =
            (MatrixFingerprint::of(&a), MatrixFingerprint::of(&b), MatrixFingerprint::of(&c));
        assert!(cache.is_empty());
        let (_, o) = cache.get_or_build(&a, &opts).unwrap();
        assert_eq!(o, CacheOutcome::Miss);
        let (_, o) = cache.get_or_build(&b, &opts).unwrap();
        assert_eq!(o, CacheOutcome::Miss);
        // Touch A: it becomes MRU, so B is now the LRU entry.
        let (_, o) = cache.get_or_build(&a, &opts).unwrap();
        assert_eq!(o, CacheOutcome::Hit);
        assert_eq!(cache.fingerprints(), vec![fa, fb]);
        // C evicts B (the LRU), not A.
        let (_, o) = cache.get_or_build(&c, &opts).unwrap();
        assert_eq!(o, CacheOutcome::Miss);
        assert_eq!(cache.fingerprints(), vec![fc, fa]);
        assert_eq!(cache.evictions(), 1);
        // B was evicted: coming back is a fresh miss that evicts A.
        let (_, o) = cache.get_or_build(&b, &opts).unwrap();
        assert_eq!(o, CacheOutcome::Miss);
        assert_eq!(cache.fingerprints(), vec![fb, fc]);
        assert_eq!((cache.hits(), cache.misses(), cache.evictions()), (1, 4, 2));
        assert_eq!(cache.len(), 2);
    }

    /// ISSUE-7 satellite: fingerprint collision on structure — same
    /// pattern with different values must reuse the tuned *plan* but
    /// still produce correct (bit-identical-to-its-own-serial) results
    /// for the new values.
    #[test]
    fn handle_cache_plan_hit_reuses_plan_with_correct_results() {
        let opts = BuildOpts::default();
        let mut cache = HandleCache::new(4);
        let a = hh_crs();
        let mut a2 = a.clone();
        for v in &mut a2.val {
            *v *= 1.5;
        }
        let (ha, o) = cache.get_or_build(&a, &opts).unwrap();
        assert_eq!(o, CacheOutcome::Miss);
        let (ha2, o) = cache.get_or_build(&a2, &opts).unwrap();
        assert_eq!(o, CacheOutcome::PlanHit);
        assert_eq!(cache.plan_hits(), 1);
        assert_eq!(cache.len(), 1, "plan hit replaces the value-stale entry");
        // Same plan ...
        assert_eq!(ha2.scheme(), ha.scheme());
        assert_eq!(ha2.schedule(), ha.schedule());
        assert_eq!(ha2.backend_name(), ha.backend_name());
        // ... correct results for the *new* values.
        use crate::matrix::SpMv;
        let x = rand_x(21, a.nrows);
        let mut want = vec![0.0; a.nrows];
        a2.spmv(&x, &mut want);
        let mut got = vec![0.0; a.nrows];
        ha2.spmv(&x, &mut got);
        assert!(max_abs_diff(&want, &got) < 1e-12, "plan-hit handle serves wrong values");
        // And the full hit still works afterwards.
        let (_, o) = cache.get_or_build(&a2, &opts).unwrap();
        assert_eq!(o, CacheOutcome::Hit);
    }

    /// ISSUE-8 satellite, amended by ISSUE-9: the PlanHit path must
    /// honor an explicitly requested backend instead of replaying the
    /// cached decision verbatim, and the ISA must be re-derived from
    /// the rebuilt backend's actual capability. Since ISSUE 9 the
    /// sharded split kernels vectorize, so the Tolerance tenant's
    /// sharded handle itself binds the arbitrated ceiling — the old
    /// `kernel_isa = Scalar` backend-capability artifact is gone.
    #[test]
    fn plan_hit_honors_requested_backend_isa_capability() {
        use crate::kernels::IsaLevel;
        let a = band_crs(5, 160);
        let mut a2 = a.clone();
        for v in &mut a2.val {
            *v *= 2.0;
        }
        let mut cache = HandleCache::new(4);
        // A Fixed tier makes the arbitration deterministic: the
        // contract's ceiling binds whenever the scheme vectorizes.
        let sharded_opts = BuildOpts {
            policy: TuningPolicy::Fixed(Scheme::Crs, Schedule::Static { chunk: None }),
            backend: BackendChoice::Sharded,
            precision: Precision::Tolerance(1e-12),
            ..BuildOpts::default()
        };
        let (h1, o) = cache.get_or_build(&a, &sharded_opts).unwrap();
        assert_eq!(o, CacheOutcome::Miss);
        assert_eq!(h1.backend_name(), "sharded");
        assert_eq!(
            h1.kernel_isa(),
            IsaLevel::detect(),
            "a Tolerance sharded tenant binds the arbitrated vector isa (ISSUE 9)"
        );
        {
            // The vectorized split kernels still honor ε for the tenant.
            let x = rand_x(23, a.nrows);
            let mut want = vec![0.0; a.nrows];
            a.spmv(&x, &mut want);
            let mut got = vec![0.0; a.nrows];
            h1.spmv(&x, &mut got);
            assert!(max_abs_diff(&want, &got) < 1e-10, "sharded Tolerance tenant off");
        }
        // Same structure, new values, explicit native request.
        let native_opts = BuildOpts {
            backend: BackendChoice::Native,
            precision: Precision::Tolerance(1e-12),
            ..BuildOpts::default()
        };
        let (h2, o) = cache.get_or_build(&a2, &native_opts).unwrap();
        assert_eq!(o, CacheOutcome::PlanHit);
        assert_eq!(
            h2.backend_name(),
            "native",
            "an explicit backend request must win on a plan hit"
        );
        // Scheme/schedule transfer; the ISA comes from the rebuilt
        // backend's own capability, not from replaying the cached report.
        assert_eq!(h2.scheme(), h1.scheme());
        assert_eq!(h2.schedule(), h1.schedule());
        let expect = if h2.kernel().is_some_and(|k| k.has_simd_path(IsaLevel::detect())) {
            IsaLevel::detect()
        } else {
            IsaLevel::Scalar
        };
        assert_eq!(h2.kernel_isa(), expect);
        // A tenant that leaves the backend to arbitration still replays
        // the cached decision (now native).
        let mut a3 = a.clone();
        for v in &mut a3.val {
            *v *= 3.0;
        }
        let auto_opts =
            BuildOpts { precision: Precision::Tolerance(1e-12), ..BuildOpts::default() };
        assert_eq!(auto_opts.backend, BackendChoice::Auto);
        let (h3, o) = cache.get_or_build(&a3, &auto_opts).unwrap();
        assert_eq!(o, CacheOutcome::PlanHit);
        assert_eq!(h3.backend_name(), "native", "auto replays the cached backend");
        // Results stay correct for the new values.
        use crate::matrix::SpMv;
        let x = rand_x(22, a.nrows);
        let mut want = vec![0.0; a.nrows];
        a2.spmv(&x, &mut want);
        let mut got = vec![0.0; a.nrows];
        h2.spmv(&x, &mut got);
        assert!(max_abs_diff(&want, &got) < 1e-10, "plan-hit handle serves wrong values");
    }

    /// ISSUE-7 satellite: served results are bit-identical to a
    /// directly built handle with the same options (and within 1e-12 of
    /// serial CRS) under the default `Precision::BitIdentical`.
    #[test]
    fn served_results_bit_identical_to_direct_handle() {
        use crate::matrix::SpMv;
        let crs = hh_crs();
        let n = crs.nrows;
        let cfg = ServeConfig::default();
        assert_eq!(cfg.precision, Precision::BitIdentical);
        let direct = build_handle(&crs, &cfg.build_opts()).unwrap();
        let mut server = Server::start(cfg);
        server.register("t0", crs.clone()).unwrap();
        for seed in 0..3u64 {
            let x = rand_x(30 + seed, n);
            let mut want = vec![0.0; n];
            direct.spmv(&x, &mut want);
            let got = server.submit("t0", x.clone()).unwrap().wait();
            assert_eq!(
                max_abs_diff(&want, &got),
                0.0,
                "served result deviates from the direct handle"
            );
            let mut want_crs = vec![0.0; n];
            crs.spmv(&x, &mut want_crs);
            assert!(max_abs_diff(&want_crs, &got) < 1e-12);
        }
        server.shutdown();
    }

    /// ISSUE-7 acceptance: repeat-tenant registrations hit the cache —
    /// counters asserted through the server's stats mirror.
    #[test]
    fn repeat_tenants_hit_the_handle_cache() {
        let crs = hh_crs();
        let mut server = Server::start(ServeConfig::default());
        assert_eq!(server.register("t0", crs.clone()).unwrap(), CacheOutcome::Miss);
        assert_eq!(server.register("t1", crs.clone()).unwrap(), CacheOutcome::Hit);
        assert_eq!(server.register("t2", crs.clone()).unwrap(), CacheOutcome::Hit);
        let mut rescaled = crs.clone();
        for v in &mut rescaled.val {
            *v *= 0.5;
        }
        assert_eq!(server.register("t3", rescaled).unwrap(), CacheOutcome::PlanHit);
        let s = server.stats();
        assert_eq!((s.cache_misses, s.cache_hits, s.cache_plan_hits), (1, 2, 1));
        // Every tenant actually serves.
        let x = rand_x(40, crs.nrows);
        for t in ["t0", "t1", "t2", "t3"] {
            let y = server.submit(t, x.clone()).unwrap().wait();
            assert_eq!(y.len(), crs.nrows);
        }
        server.shutdown();
    }

    /// Admission control: typed rejections for caller errors, per-tenant
    /// quota before the global cap, graceful shedding counted — and the
    /// admitted requests still all get served.
    #[test]
    fn admission_sheds_overload_with_reasons() {
        let crs = hh_crs();
        let n = crs.nrows;
        // A far-off deadline keeps submissions queued deterministically;
        // the shutdown drain below releases them.
        let cfg = ServeConfig {
            max_batch: 64,
            max_delay: Duration::from_secs(30),
            queue_cap: 4,
            ..ServeConfig::default()
        };
        let mut server = Server::start(cfg);
        server.register("t0", crs.clone()).unwrap();
        server.register("t1", crs.clone()).unwrap();
        assert_eq!(server.submit("nobody", vec![0.0; n]).unwrap_err(), Rejected::UnknownTenant);
        let wrong = server.submit("t0", vec![0.0; n + 1]).unwrap_err();
        assert_eq!(wrong, Rejected::DimMismatch { want: n, got: n + 1 });
        assert_eq!(wrong.reason(), "dim-mismatch");
        assert!(!wrong.is_shed());
        // Quota = queue_cap / tenants = 2 per tenant.
        let x = rand_x(50, n);
        let a0 = server.submit("t0", x.clone()).unwrap();
        let a1 = server.submit("t0", x.clone()).unwrap();
        let q = server.submit("t0", x.clone()).unwrap_err();
        assert_eq!(q, Rejected::TenantQuota);
        assert!(q.is_shed());
        let b0 = server.submit("t1", x.clone()).unwrap();
        let b1 = server.submit("t1", x.clone()).unwrap();
        // Global queue now full: even the other tenant is refused.
        let full = server.submit("t1", x.clone()).unwrap_err();
        assert_eq!(full, Rejected::QueueFull);
        assert_eq!(full.reason(), "queue-full");
        // Shutdown drains: all four admitted requests are still served
        // correctly.
        use crate::matrix::SpMv;
        server.shutdown();
        let mut want = vec![0.0; n];
        crs.spmv(&x, &mut want);
        for t in [a0, a1, b0, b1] {
            assert!(max_abs_diff(&want, &t.wait()) < 1e-12);
        }
        let s = server.stats();
        assert_eq!(s.submitted, 4);
        assert_eq!(s.completed, 4);
        assert_eq!(s.shed, 2);
        assert_eq!(server.submit("t0", x).unwrap_err(), Rejected::ShuttingDown);
    }

    /// Deadline coalescing: several quick same-tenant submissions under
    /// `max_batch` ride one `spmv_batch` dispatch (released by the
    /// deadline), and shutdown drains instead of dropping.
    #[test]
    fn coalesces_same_tenant_requests_into_one_dispatch() {
        let crs = hh_crs();
        let n = crs.nrows;
        let cfg = ServeConfig {
            max_batch: 8,
            max_delay: Duration::from_secs(30),
            ..ServeConfig::default()
        };
        let mut server = Server::start(cfg);
        server.register("t0", crs.clone()).unwrap();
        let x = rand_x(60, n);
        let tickets: Vec<Ticket> =
            (0..4).map(|_| server.submit("t0", x.clone()).unwrap()).collect();
        // Shutdown drains the queue — the four requests must come back
        // as one coalesced dispatch, not four.
        server.shutdown();
        for t in tickets {
            assert_eq!(t.wait().len(), n);
        }
        let s = server.stats();
        assert_eq!(s.completed, 4);
        assert_eq!(s.dispatches, 1, "4 queued same-tenant requests must coalesce");
        assert_eq!(s.dispatched_requests, 4);
        assert!((s.avg_batch() - 4.0).abs() < 1e-9);
    }

    /// `max_batch` caps a dispatch: more queued requests than the batch
    /// bound split into ceil(queued / max_batch) dispatches.
    #[test]
    fn max_batch_bounds_each_dispatch() {
        let crs = hh_crs();
        let n = crs.nrows;
        let cfg = ServeConfig {
            max_batch: 3,
            max_delay: Duration::from_secs(30),
            queue_cap: 64,
            ..ServeConfig::default()
        };
        let mut server = Server::start(cfg);
        server.register("t0", crs.clone()).unwrap();
        let x = rand_x(70, n);
        let tickets: Vec<Ticket> =
            (0..7).map(|_| server.submit("t0", x.clone()).unwrap()).collect();
        server.shutdown();
        for t in tickets {
            t.wait();
        }
        let s = server.stats();
        assert_eq!(s.completed, 7);
        assert_eq!(s.dispatches, 3, "7 requests at max_batch=3 → 3+3+1");
    }
}
