//! Auto-tuned SpMV contexts: the **build→tune→plan→execute machinery**
//! behind the [`crate::spmv::SpmvHandle`] facade. Since the facade PR
//! the context types here are crate-internal — external consumers build
//! a handle ([`crate::spmv::SpmvBuilder`]), which arbitrates the
//! executor backend and drives this module for scheme/schedule tuning.
//!
//! The paper's central finding is that storage scheme × access pattern ×
//! thread scheduling must be co-designed *per matrix*. The lower layers
//! expose the ingredients ([`SpmvKernel`], [`SpmvPlan`], [`Engine`]);
//! this module is where the co-design decision is actually **made**:
//!
//! ```text
//! SpmvContext::builder(&coo)
//!     .policy(TuningPolicy::Heuristic)   // or Fixed(..) / Measured
//!     .threads(4)
//!     .build()?                          // kernel + plan + engine bundle
//! ```
//!
//! [`TuningPolicy`] has three tiers:
//!
//! - [`TuningPolicy::Fixed`]: the caller names scheme and schedule —
//!   no tuning, the zero-cost escape hatch.
//! - [`TuningPolicy::Heuristic`]: scheme, SELL (C, σ) and schedule are
//!   chosen from the matrix **stride-distribution fingerprint**
//!   ([`StrideDistribution`], Fig 6a),
//!   [`crate::matrix::SellCs::padding_overhead`], and
//!   the predictive performance model ([`crate::perfmodel::predict`]) —
//!   the feature-based selection of Elafrou et al. 2017 on top of the
//!   (C, σ) guidance of Kreutzer et al. 2013.
//! - [`TuningPolicy::Measured`]: a short candidate bake-off timed on the
//!   host — ground truth where a few milliseconds of probing are
//!   acceptable.
//!
//! Every decision is documented in a [`TuningReport`] (candidates,
//! scores, fingerprint, rationale), so a tuned context can always explain
//! itself. The resulting [`SpmvContext`] exposes [`SpmvContext::spmv`],
//! [`SpmvContext::spmv_batch`] (the whole batch fused into a single
//! engine dispatch — one completion latch per batch, not per vector) and
//! implements [`crate::matrix::SpMv`], so solvers, the coordinator
//! service, experiments and benches all consume the same tuned bundle.

use std::borrow::Cow;
use std::collections::HashMap;
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use anyhow::Result;

use crate::analysis::StrideDistribution;
use crate::engine::affinity::{PinMode, PinReport};
use crate::engine::{Engine, SpmvPlan};
use crate::kernels::microbench::cached_gather_gain;
use crate::kernels::{IsaLevel, Precision, SpmvKernel};
use crate::matrix::shard::ShardedCrs;
use crate::matrix::{Crs, Scheme, SpMv};
use crate::perfmodel::{predict, predict_with_dist, CostCurve};
use crate::sched::Schedule;
use crate::shard::{OverlapMode, ShardedSpmv};
use crate::simulator::MachineSpec;
use crate::util::report::{f, Table};
use crate::util::rng::Rng;

/// How an [`SpmvContext`] picks its (scheme, (C, σ), schedule) triple.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum TuningPolicy {
    /// No tuning: use exactly this scheme and schedule.
    Fixed(Scheme, Schedule),
    /// Pick scheme, SELL (C, σ) and schedule from the stride-distribution
    /// fingerprint + padding overhead + the predictive performance model.
    Heuristic,
    /// Short host-side bake-off: build every candidate, time it, keep the
    /// fastest.
    Measured,
}

impl TuningPolicy {
    pub fn name(&self) -> &'static str {
        match self {
            TuningPolicy::Fixed(..) => "fixed",
            TuningPolicy::Heuristic => "heuristic",
            TuningPolicy::Measured => "measured",
        }
    }
}

/// A compact identity for a matrix — the serve layer's handle-cache key
/// (arXiv:1711.05487's lesson: per-matrix tuning pays off only when its
/// cost is amortized across many calls, so repeat tenants must be able
/// to reuse tuned handles without re-hashing trust in the caller).
///
/// Two FNV-1a hashes over the CRS arrays: `structure` covers the
/// dimensions + `row_ptr` + `col_idx` (everything the tuning decisions
/// depend on), `values` additionally folds in the numeric entries
/// (everything the *results* depend on). Equal structure with different
/// values means the cached **plan** (scheme/schedule/backend) transfers,
/// but the handle must be rebuilt on the new values for correct results
/// — the serve cache's "plan hit" path.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct MatrixFingerprint {
    pub nrows: usize,
    pub ncols: usize,
    pub nnz: usize,
    /// FNV-1a over dims + `row_ptr` + `col_idx`.
    pub structure: u64,
    /// `structure` folded with the bit patterns of `val`.
    pub values: u64,
}

impl MatrixFingerprint {
    pub fn of(crs: &Crs) -> Self {
        let mut h = Fnv1a::new();
        h.write_u64(crs.nrows as u64);
        h.write_u64(crs.ncols as u64);
        for &p in &crs.row_ptr {
            h.write_u64(p as u64);
        }
        for &c in &crs.col_idx {
            h.write_u64(c as u64);
        }
        let structure = h.finish();
        let mut hv = Fnv1a::new();
        hv.write_u64(structure);
        for &v in &crs.val {
            hv.write_u64(v.to_bits());
        }
        MatrixFingerprint {
            nrows: crs.nrows,
            ncols: crs.ncols,
            nnz: crs.val.len(),
            structure,
            values: hv.finish(),
        }
    }

    /// Same sparsity pattern — the tuning-relevant identity; the full
    /// `==` (which also compares `values`) is the result-relevant one.
    pub fn same_structure(&self, other: &Self) -> bool {
        self.nrows == other.nrows
            && self.ncols == other.ncols
            && self.nnz == other.nnz
            && self.structure == other.structure
    }
}

/// Minimal FNV-1a (64-bit) so the fingerprint is stable across runs and
/// platforms — `std`'s `DefaultHasher` is explicitly not.
struct Fnv1a(u64);

impl Fnv1a {
    fn new() -> Self {
        Fnv1a(0xcbf2_9ce4_8422_2325)
    }
    fn write_u64(&mut self, v: u64) {
        for b in v.to_le_bytes() {
            self.0 = (self.0 ^ b as u64).wrapping_mul(0x100_0000_01b3);
        }
    }
    fn finish(&self) -> u64 {
        self.0
    }
}

/// The sharding dimension of the tuning space: how many in-process
/// domains to row-partition the matrix into, and whether to overlap
/// the halo exchange with the interior compute
/// ([`crate::shard::OverlapMode`]). Orthogonal to [`TuningPolicy`]
/// (which keeps picking scheme and schedule); consumed by
/// [`SpmvContextBuilder::build_sharded`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardPolicy {
    /// The caller names the shard count and overlap mode.
    Fixed { shards: usize, mode: OverlapMode },
    /// Pick both from the halo-volume vs interior-work ratio of the
    /// candidate partitions (see [`SHARD_GRID`] and the rationale the
    /// decision records).
    Heuristic,
    /// Short host bake-off over shard counts × overlap modes, timed
    /// with the same machinery as [`TuningPolicy::Measured`].
    Measured,
}

impl ShardPolicy {
    pub fn name(&self) -> &'static str {
        match self {
            ShardPolicy::Fixed { .. } => "fixed",
            ShardPolicy::Heuristic => "heuristic",
            ShardPolicy::Measured => "measured",
        }
    }
}

/// Shard counts the heuristic and measured shard tiers consider.
pub const SHARD_GRID: [usize; 4] = [1, 2, 4, 8];

/// Halo-volume viability ceiling: a partition exchanging more than this
/// fraction of the vector is never worth sharding (arXiv:1106.5908 §5).
/// Shared with the facade's backend arbitration so the two layers can
/// never disagree on what counts as a viable partition.
pub(crate) const SHARD_HALO_VIABLE_MAX: f64 = 0.5;

/// Minimum interior-nnz fraction for the overlapped mode to pay — below
/// this there is not enough halo-free work to hide the exchange behind.
pub(crate) const SHARD_OVERLAP_MIN_INTERIOR: f64 = 0.25;

/// Minimum rows a shard must keep for the partition to stay useful.
pub(crate) const SHARD_MIN_ROWS: usize = 64;

/// One (shard count, overlap mode) candidate with the partition
/// features that drove (or would drive) its selection.
#[derive(Debug, Clone)]
pub struct ShardCandidate {
    pub shards: usize,
    pub mode: OverlapMode,
    /// Exchanged vector elements / vector length for this partition.
    pub halo_fraction: f64,
    /// nnz in halo-dependent rows / total nnz (the complement is the
    /// interior work available to hide the exchange behind).
    pub boundary_nnz_fraction: f64,
    /// Host bake-off score (measured tier only).
    pub measured_ns_per_nnz: Option<f64>,
    pub chosen: bool,
}

/// The sharding decision recorded in a [`TuningReport`].
#[derive(Debug, Clone)]
pub struct ShardDecision {
    pub policy: String,
    pub n_shards: usize,
    pub mode: OverlapMode,
    pub halo_fraction: f64,
    pub boundary_nnz_fraction: f64,
    pub candidates: Vec<ShardCandidate>,
}

/// One executor backend scored during arbitration (see
/// [`crate::spmv::SpmvBuilder`]): serial kernel, native engine context,
/// or sharded executor.
#[derive(Debug, Clone, PartialEq)]
pub struct BackendCandidate {
    /// `"serial"`, `"native"` or `"sharded"`.
    pub backend: &'static str,
    /// Heuristic score: estimated nanoseconds for one whole SpMV call
    /// (perfmodel per-nnz cost / parallelism + per-call dispatch cost).
    pub predicted_ns_per_call: Option<f64>,
    /// Cross-backend bake-off score (measured tier).
    pub measured_ns_per_nnz: Option<f64>,
    pub chosen: bool,
}

/// The executor-arbitration decision recorded in a [`TuningReport`]:
/// which backend serves the matrix, which candidates it beat, and under
/// which arbitration policy. The paper's lesson extended one level up —
/// the best *executor* is a property of the matrix × machine pair, not
/// a user choice.
#[derive(Debug, Clone, PartialEq)]
pub struct BackendDecision {
    /// `"forced"`, `"fixed-default"`, `"heuristic"` or `"measured"`.
    pub policy: String,
    /// The chosen backend's name.
    pub backend: &'static str,
    pub candidates: Vec<BackendCandidate>,
}

/// Blocked-x-vs-per-vector arbitration for a `k`-wide SpMM call
/// (see [`price_multi`]): whether the column block should run through
/// the fused multi kernel or fall back to the per-vector batch path.
#[derive(Debug, Clone, PartialEq)]
pub struct MultiDecision {
    /// Column-block width the call was priced for.
    pub k: usize,
    /// Route to the fused blocked-x kernel (`spmv_multi`) rather than
    /// the per-vector batch (`spmv_batch`).
    pub blocked: bool,
    /// Modeled main-memory traffic of `k` per-vector passes (bytes).
    pub bytes_per_vector: f64,
    /// Modeled traffic of one blocked-x pass (matrix streamed once).
    pub bytes_blocked: f64,
    pub rationale: String,
}

/// Price a `k`-wide SpMM: per-vector batching streams the matrix
/// (~12 B/nnz for CRS: 8 B value + 4 B column index) once **per
/// vector**, while the blocked-x kernel streams it once for the whole
/// block and reuses each loaded entry across all `k` right-hand sides —
/// the x-reuse traffic shift of arXiv:1711.05487. Both paths move the
/// same x-read + y-write bytes (~8 B/nnz + 16 B/row per vector), so
/// blocking wins whenever `k >= 2` — including under a vector ISA
/// (`simd_active`): since ISSUE 9 the fused loop has its own vector
/// bodies (broadcast the entry, FMA across the column block), so the
/// blocked path no longer trades the SIMD win for the matrix re-read
/// saving. `simd_active` now only flavors the rationale.
pub fn price_multi(nnz: usize, nrows: usize, k: usize, simd_active: bool) -> MultiDecision {
    let (nnz, nrows, kf) = (nnz as f64, nrows as f64, k as f64);
    let per_vec = kf * (12.0 * nnz + 8.0 * nnz + 16.0 * nrows);
    let blocked = 12.0 * nnz + kf * (8.0 * nnz + 16.0 * nrows);
    let choose_blocked = k >= 2;
    let rationale = if k < 2 {
        format!("k={k}: single vector, nothing to block over")
    } else if simd_active {
        format!(
            "k={k}: blocked-x streams the matrix once and the fused \
             vector bodies keep the SIMD win ({:.0} KiB vs {:.0} KiB modeled traffic)",
            blocked / 1024.0,
            per_vec / 1024.0
        )
    } else {
        format!(
            "k={k}: blocked-x streams the matrix once ({:.0} KiB vs {:.0} KiB modeled traffic)",
            blocked / 1024.0,
            per_vec / 1024.0
        )
    };
    MultiDecision {
        k,
        blocked: choose_blocked,
        bytes_per_vector: per_vec,
        bytes_blocked: blocked,
        rationale,
    }
}

/// One candidate considered during tuning, with its score(s).
#[derive(Debug, Clone)]
pub struct CandidateReport {
    pub scheme: Scheme,
    pub schedule: Schedule,
    /// Instruction-set level this candidate would execute at. Scalar
    /// unless the [`Precision`] contract admits vector kernels.
    pub isa: IsaLevel,
    /// Performance-model score (heuristic tier), padding-adjusted.
    pub predicted_cycles_per_nnz: Option<f64>,
    /// Host bake-off score (measured tier).
    pub measured_ns_per_nnz: Option<f64>,
    pub padding_overhead: f64,
    pub chosen: bool,
}

/// The NUMA placement a context was built with: whether pinning was
/// requested, where the engine threads actually landed, and whether the
/// plan's workspace pages were first-touched by their owners. Folded
/// into [`TuningReport`] so every tuned context documents its placement
/// the same way it documents its scheme choice (paper §5.2: the two are
/// one decision).
#[derive(Debug, Clone)]
pub struct PlacementDecision {
    /// Caller asked for NUMA placement (pinning + first touch).
    pub pin_requested: bool,
    /// Realized per-thread pinning, once the engine exists. `None` for
    /// unpinned contexts whose engine is still lazy.
    pub pin: Option<PinReport>,
    /// Workspace pages first-touched by their owning engine threads.
    pub first_touch: bool,
}

impl PlacementDecision {
    /// Pinning and first touch are reported independently: an unpinned
    /// context that went through `rebalance()` has owner-touched (but
    /// unpinned, hence migratable) workspace pages, and the summary
    /// must say so rather than claim calling-thread placement.
    pub fn summary(&self) -> String {
        let pin = if !self.pin_requested {
            "unpinned".to_string()
        } else {
            match &self.pin {
                Some(r) => r.summary(),
                None => "pin pending (engine not spawned)".into(),
            }
        };
        format!("{pin}, first-touch {}", if self.first_touch { "on" } else { "off" })
    }
}

/// Why a context looks the way it does: the decision, the candidates it
/// beat, and the matrix features that drove the choice.
#[derive(Debug, Clone)]
pub struct TuningReport {
    pub policy: String,
    pub scheme: Scheme,
    pub schedule: Schedule,
    pub n_threads: usize,
    pub nrows: usize,
    pub nnz: usize,
    /// Fraction of backward jumps in the CRS-walk stride fingerprint
    /// (`None` when the policy did not analyze the matrix).
    pub backward_fraction: Option<f64>,
    /// Mean |stride| of the CRS-walk fingerprint.
    pub mean_abs_stride: Option<f64>,
    /// Fraction of strides with |stride| <= 8 elements (one cache line).
    pub small_stride_fraction: Option<f64>,
    /// Coefficient of variation of nnz per row (load-imbalance feature
    /// driving the schedule choice).
    pub row_imbalance_cv: f64,
    /// The CV threshold the schedule heuristic compared against —
    /// [`SCHEDULE_CV_THRESHOLD`] / [`SCHEDULE_CV_THRESHOLD_FIRST_TOUCH`]
    /// by default, or the caller's
    /// [`crate::spmv::SpmvBuilder::schedule_cv_threshold`] override.
    pub schedule_cv_threshold: f64,
    /// Realized padding overhead of the chosen kernel (0 for unpadded
    /// schemes).
    pub padding_overhead: f64,
    /// The numerical contract tuning ran under. `BitIdentical` (the
    /// default) excludes vector kernels from the candidate set entirely.
    pub precision: Precision,
    /// The instruction-set level the chosen plan executes at.
    pub kernel_isa: IsaLevel,
    /// NUMA placement of the engine + workspace (pinning, first touch).
    pub placement: PlacementDecision,
    /// Executor-arbitration decision (`None` until a
    /// [`crate::spmv::SpmvBuilder`] records one).
    pub backend: Option<BackendDecision>,
    /// Sharding decision (`None` for unsharded contexts).
    pub shard: Option<ShardDecision>,
    pub candidates: Vec<CandidateReport>,
    /// Human-readable decision trail.
    pub rationale: Vec<String>,
}

impl TuningReport {
    /// Render the decision and the candidate scoreboard as text tables.
    pub fn tables(&self) -> Vec<Table> {
        let mut decision = Table::new(
            &format!("tuning decision ({} policy)", self.policy),
            &["quantity", "value"],
        );
        decision.row(vec!["scheme".into(), self.scheme.name()]);
        decision.row(vec!["spec".into(), self.scheme.spec()]);
        decision.row(vec!["schedule".into(), self.schedule.name()]);
        decision.row(vec!["threads".into(), self.n_threads.to_string()]);
        decision.row(vec!["matrix".into(), format!("N={} nnz={}", self.nrows, self.nnz)]);
        if let Some(b) = self.backward_fraction {
            decision.row(vec!["backward stride fraction".into(), f(b)]);
        }
        if let Some(m) = self.mean_abs_stride {
            decision.row(vec!["mean |stride|".into(), f(m)]);
        }
        if let Some(s) = self.small_stride_fraction {
            decision.row(vec!["|stride| <= 8 fraction".into(), f(s)]);
        }
        decision.row(vec!["row imbalance (CV)".into(), f(self.row_imbalance_cv)]);
        decision.row(vec!["schedule CV threshold".into(), f(self.schedule_cv_threshold)]);
        decision.row(vec!["padding overhead".into(), f(self.padding_overhead)]);
        decision.row(vec!["precision".into(), self.precision.name()]);
        decision.row(vec!["kernel isa".into(), self.kernel_isa.name().into()]);
        decision.row(vec!["placement".into(), self.placement.summary()]);
        if let Some(bd) = &self.backend {
            let label = format!("{} ({} policy)", bd.backend, bd.policy);
            decision.row(vec!["backend".into(), label]);
        }
        if let Some(sd) = &self.shard {
            decision.row(vec!["shards".into(), format!("{} ({} policy)", sd.n_shards, sd.policy)]);
            decision.row(vec!["overlap mode".into(), sd.mode.name().into()]);
            decision.row(vec!["halo fraction".into(), f(sd.halo_fraction)]);
            decision.row(vec!["boundary nnz fraction".into(), f(sd.boundary_nnz_fraction)]);
        }
        for (i, r) in self.rationale.iter().enumerate() {
            decision.row(vec![format!("rationale {}", i + 1), r.clone()]);
        }
        let mut tables = vec![decision];
        if let Some(bd) = &self.backend {
            if !bd.candidates.is_empty() {
                let mut t = Table::new(
                    &format!("backend candidates ({} arbitration)", bd.policy),
                    &["backend", "pred ns/call", "measured ns/nnz", "chosen"],
                );
                for c in &bd.candidates {
                    t.row(vec![
                        c.backend.into(),
                        c.predicted_ns_per_call.map(f).unwrap_or_else(|| "-".into()),
                        c.measured_ns_per_nnz.map(f).unwrap_or_else(|| "-".into()),
                        if c.chosen { "<-".into() } else { String::new() },
                    ]);
                }
                tables.push(t);
            }
        }
        if let Some(sd) = &self.shard {
            if !sd.candidates.is_empty() {
                let mut t = Table::new(
                    "shard candidates",
                    &["shards", "mode", "halo frac", "boundary nnz frac", "ns/nnz", "chosen"],
                );
                for c in &sd.candidates {
                    t.row(vec![
                        c.shards.to_string(),
                        c.mode.name().into(),
                        f(c.halo_fraction),
                        f(c.boundary_nnz_fraction),
                        c.measured_ns_per_nnz.map(f).unwrap_or_else(|| "-".into()),
                        if c.chosen { "<-".into() } else { String::new() },
                    ]);
                }
                tables.push(t);
            }
        }
        if !self.candidates.is_empty() {
            let mut t = Table::new(
                "tuning candidates",
                &[
                    "scheme",
                    "schedule",
                    "isa",
                    "pred cycles/nnz",
                    "measured ns/nnz",
                    "padding",
                    "chosen",
                ],
            );
            for c in &self.candidates {
                t.row(vec![
                    c.scheme.name(),
                    c.schedule.name(),
                    c.isa.name().into(),
                    c.predicted_cycles_per_nnz.map(f).unwrap_or_else(|| "-".into()),
                    c.measured_ns_per_nnz.map(f).unwrap_or_else(|| "-".into()),
                    f(c.padding_overhead),
                    if c.chosen { "<-".into() } else { String::new() },
                ]);
            }
            tables.push(t);
        }
        tables
    }
}

/// Builder for [`SpmvContext`]; see the module docs for the lifecycle.
/// Borrows the CRS when the caller already holds one
/// ([`SpmvContext::builder_from_crs`]) — tuning only reads it.
///
/// Crate-internal since the `SpmvHandle` facade: external consumers go
/// through [`crate::spmv::SpmvBuilder`], which drives this machinery
/// and adds backend arbitration on top.
pub(crate) struct SpmvContextBuilder<'a> {
    crs: Cow<'a, Crs>,
    policy: TuningPolicy,
    threads: Option<usize>,
    machine: MachineSpec,
    quick: bool,
    pinned: bool,
    cv_threshold: Option<f64>,
    shard_policy: Option<ShardPolicy>,
    precision: Precision,
}

impl SpmvContextBuilder<'_> {
    /// Tuning tier (default: [`TuningPolicy::Heuristic`]).
    pub fn policy(mut self, policy: TuningPolicy) -> Self {
        self.policy = policy;
        self
    }

    /// Engine thread count. Defaults to the host parallelism capped at 4
    /// (SpMV saturates memory bandwidth long before core count, Fig 8).
    pub fn threads(mut self, n: usize) -> Self {
        self.threads = Some(n.max(1));
        self
    }

    /// Machine model the heuristic tier's performance model is evaluated
    /// on (default: Nehalem, the paper's newest test-bed socket).
    pub fn machine(mut self, machine: MachineSpec) -> Self {
        self.machine = machine;
        self
    }

    /// Cheapen tuning for smoke runs: a shorter cost-curve calibration
    /// and fewer bake-off repetitions.
    pub fn quick(mut self, quick: bool) -> Self {
        self.quick = quick;
        self
    }

    /// Request NUMA placement: a thread-pinned engine (compact map,
    /// worker *i* → core *i*, caller included) plus first-touch
    /// initialization of the plan's workspace by the owning workers —
    /// the host counterpart of the simulator's
    /// `Placement::FirstTouchStatic`. Forces the engine to spawn eagerly
    /// (placement cannot be deferred past the first touch); on platforms
    /// without `sched_setaffinity` it degrades to a recorded no-op and
    /// the schedule heuristic's placement penalty still applies.
    pub fn pinned(mut self, pinned: bool) -> Self {
        self.pinned = pinned;
        self
    }

    /// Override the row-imbalance CV threshold above which the schedule
    /// heuristic abandons static partitions (defaults:
    /// [`SCHEDULE_CV_THRESHOLD`], or
    /// [`SCHEDULE_CV_THRESHOLD_FIRST_TOUCH`] under first-touch
    /// placement). Recorded in the [`TuningReport`].
    pub fn schedule_cv_threshold(mut self, threshold: Option<f64>) -> Self {
        self.cv_threshold = threshold;
        self
    }

    /// Numerical contract for the tuned kernels (default:
    /// [`Precision::BitIdentical`]). Under `BitIdentical` the candidate
    /// set is scalar-only and results are bit-identical to the chosen
    /// scheme's serial kernel — the pre-SIMD behavior, unchanged. Under
    /// [`Precision::Tolerance`] the tuner also scores vector-kernel
    /// variants (FMA contraction and reordered accumulation change
    /// low-order bits; see [`crate::kernels::simd`]) and binds the
    /// winning [`IsaLevel`] onto the plan.
    pub fn precision(mut self, precision: Precision) -> Self {
        self.precision = precision;
        self
    }

    /// Add the sharding dimension: the context becomes a
    /// [`ShardedContext`] whose shard count and overlap mode come from
    /// `policy` (scheme and schedule still come from the
    /// [`TuningPolicy`]). Finish with
    /// [`SpmvContextBuilder::build_sharded`] — `build()` rejects a
    /// builder with a shard policy rather than silently ignoring it.
    pub fn sharded(mut self, policy: ShardPolicy) -> Self {
        self.shard_policy = Some(policy);
        self
    }

    /// Run the policy and bundle the winning kernel + plan + engine.
    /// Errors on non-square matrices: every scheme past CRS permutes
    /// rows and columns symmetrically, and the engine's plan/workspace
    /// machinery assumes one dimension throughout.
    pub fn build(self) -> Result<SpmvContext> {
        let SpmvContextBuilder {
            crs,
            policy,
            threads,
            machine,
            quick,
            pinned,
            cv_threshold,
            shard_policy,
            precision,
        } = self;
        anyhow::ensure!(
            shard_policy.is_none(),
            "builder has a shard policy: finish with build_sharded(), not build()"
        );
        let crs: &Crs = &crs;
        anyhow::ensure!(
            crs.nrows == crs.ncols,
            "SpmvContext requires a square matrix, got {}x{}",
            crs.nrows,
            crs.ncols
        );
        let n_threads = threads.unwrap_or_else(|| {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(4)
        });
        let nrows = crs.nrows;
        let nnz = crs.nnz();
        let row_cv = row_imbalance_cv(&crs);
        let cv_threshold_eff = cv_threshold.unwrap_or(if pinned {
            SCHEDULE_CV_THRESHOLD_FIRST_TOUCH
        } else {
            SCHEDULE_CV_THRESHOLD
        });
        let pin_mode = if pinned { PinMode::Compact } else { PinMode::Disabled };
        let mut rationale = Vec::new();
        let mut candidates = Vec::new();
        let mut fingerprint: Option<StrideDistribution> = None;
        let mut eager_engine: Option<Engine> = None;
        // The Precision contract caps the ISA: BitIdentical (default)
        // pins everything to the scalar kernels, so the candidate set —
        // and every result — is exactly the pre-SIMD behavior.
        let isa_ceiling =
            if precision.allows_simd() { IsaLevel::detect() } else { IsaLevel::Scalar };
        let isa_options = |k: &SpmvKernel| -> Vec<IsaLevel> {
            let mut v = vec![IsaLevel::Scalar];
            if k.has_simd_path(isa_ceiling) {
                v.push(IsaLevel::Avx2);
                if isa_ceiling >= IsaLevel::Avx512 {
                    v.push(IsaLevel::Avx512);
                }
            }
            v
        };

        let (kernel, schedule, chosen_isa) = match policy {
            TuningPolicy::Fixed(scheme, schedule) => {
                rationale.push(format!(
                    "fixed policy: caller requested {} under {}",
                    scheme.name(),
                    schedule.name()
                ));
                let kernel = SpmvKernel::build_from_crs(&crs, scheme);
                // Fixed skips tuning but not the precision contract:
                // the plan runs at the ISA ceiling whenever the named
                // scheme has a vector path.
                let isa = if kernel.has_simd_path(isa_ceiling) {
                    isa_ceiling
                } else {
                    IsaLevel::Scalar
                };
                (kernel, schedule, isa)
            }
            TuningPolicy::Heuristic => {
                let crs_kernel = SpmvKernel::build_from_crs(&crs, Scheme::Crs);
                let dist = StrideDistribution::from_kernel(&crs_kernel);
                let schedule =
                    pick_schedule(nrows, n_threads, row_cv, pinned, cv_threshold, &mut rationale);
                let curve = cached_curve(&machine, quick);
                // The CRS candidate reuses the fingerprint kernel, and the
                // winner is kept as built — no candidate is realized twice.
                let mut crs_kernel = Some(crs_kernel);
                let mut best: Option<(usize, f64, SpmvKernel, IsaLevel)> = None;
                for scheme in candidate_schemes(&crs) {
                    let k = if scheme == Scheme::Crs {
                        crs_kernel
                            .take()
                            .unwrap_or_else(|| SpmvKernel::build_from_crs(&crs, scheme))
                    } else {
                        SpmvKernel::build_from_crs(&crs, scheme)
                    };
                    let padding = kernel_padding(&k);
                    // The CRS candidate's stride distribution IS the
                    // fingerprint — reuse it instead of re-walking.
                    let pred = if scheme == Scheme::Crs {
                        predict_with_dist(&machine, &curve, &k, &dist)
                    } else {
                        predict(&machine, &curve, &k)
                    };
                    // Padding streams extra val/col bytes and multiplies
                    // explicit zeros: charge it proportionally.
                    let effective = pred.cycles_per_nnz * (1.0 + padding);
                    // Vector variants are priced by the measured gather
                    // gain (ISSUE 9): the kernels stream the same bytes,
                    // only the in-core gather-FMA factor changes — and
                    // the streaming triad has no indirection, so its
                    // gain overstates the SpMV payoff.
                    let mut scheme_best: Option<(usize, f64, IsaLevel)> = None;
                    for isa in isa_options(&k) {
                        let score = effective / cached_gather_gain(isa);
                        let idx = candidates.len();
                        candidates.push(CandidateReport {
                            scheme,
                            schedule,
                            isa,
                            predicted_cycles_per_nnz: Some(score),
                            measured_ns_per_nnz: None,
                            padding_overhead: padding,
                            chosen: false,
                        });
                        if scheme_best.as_ref().map(|(_, c, _)| score < *c).unwrap_or(true) {
                            scheme_best = Some((idx, score, isa));
                        }
                    }
                    let (idx, score, isa) =
                        scheme_best.expect("isa options are never empty");
                    if best.as_ref().map(|(_, c, _, _)| score < *c).unwrap_or(true) {
                        best = Some((idx, score, k, isa));
                    }
                }
                let (best_i, best_cost, kernel, isa) =
                    best.expect("candidate set is never empty");
                candidates[best_i].chosen = true;
                rationale.push(format!(
                    "perfmodel on {} picks {} ({} kernel) at {:.3} padding-adjusted cycles/nnz over {} candidates",
                    machine.name,
                    kernel.scheme().name(),
                    isa.name(),
                    best_cost,
                    candidates.len()
                ));
                fingerprint = Some(dist);
                (kernel, schedule, isa)
            }
            TuningPolicy::Measured => {
                let schedule =
                    pick_schedule(nrows, n_threads, row_cv, pinned, cv_threshold, &mut rationale);
                // Bake off on the placement the context will actually
                // run with: a pinned request times pinned candidates.
                let engine = Engine::with_pinning(n_threads, pin_mode);
                let reps = if quick { 2 } else { 5 };
                let mut x = vec![0.0; nrows];
                Rng::new(0xC0FFEE).fill_f64(&mut x, -1.0, 1.0);
                let mut y = vec![0.0; nrows];
                let mut best: Option<(usize, f64, SpmvKernel, IsaLevel)> = None;
                for scheme in candidate_schemes(&crs) {
                    let k = SpmvKernel::build_from_crs(&crs, scheme);
                    let padding = kernel_padding(&k);
                    // Each candidate is timed through its plan's own
                    // workspace under the placement the final context
                    // will deploy with (first-touched when pinned), so
                    // the ranking and the serving path agree. The ISA
                    // variants share the plan: set_kernel_isa rebinds
                    // the execute path without re-partitioning.
                    let mut plan = if pinned {
                        SpmvPlan::new_first_touch(&k, schedule, &engine)
                    } else {
                        SpmvPlan::new(&k, schedule, n_threads)
                    };
                    let mut scheme_best: Option<(usize, f64, IsaLevel)> = None;
                    for isa in isa_options(&k) {
                        plan.set_kernel_isa(isa);
                        plan.execute(&engine, &k, &x, &mut y); // warmup
                        let mut best_ns = f64::INFINITY;
                        for _ in 0..reps {
                            let t0 = Instant::now();
                            plan.execute(&engine, &k, &x, &mut y);
                            let ns = t0.elapsed().as_nanos() as f64 / k.nnz().max(1) as f64;
                            best_ns = best_ns.min(ns);
                        }
                        let idx = candidates.len();
                        candidates.push(CandidateReport {
                            scheme,
                            schedule,
                            isa,
                            predicted_cycles_per_nnz: None,
                            measured_ns_per_nnz: Some(best_ns),
                            padding_overhead: padding,
                            chosen: false,
                        });
                        if scheme_best.as_ref().map(|(_, c, _)| best_ns < *c).unwrap_or(true) {
                            scheme_best = Some((idx, best_ns, isa));
                        }
                    }
                    let (idx, ns, isa) = scheme_best.expect("isa options are never empty");
                    if best.as_ref().map(|(_, c, _, _)| ns < *c).unwrap_or(true) {
                        best = Some((idx, ns, k, isa));
                    }
                }
                let (best_i, best_ns, kernel, isa) =
                    best.expect("candidate set is never empty");
                candidates[best_i].chosen = true;
                rationale.push(format!(
                    "host bake-off ({} reps, {} threads) picks {} ({} kernel) at {:.2} ns/nnz over {} candidates",
                    reps,
                    n_threads,
                    kernel.scheme().name(),
                    isa.name(),
                    best_ns,
                    candidates.len()
                ));
                eager_engine = Some(engine);
                (kernel, schedule, isa)
            }
        };

        // NUMA placement: with pinning the engine must exist *now* so
        // the plan's workspace pages are first-touched by the pinned
        // owners; without it the engine stays lazy and the workspace is
        // placed by the building thread (the pre-NUMA behavior).
        let (mut plan, placement) = if pinned {
            let engine =
                eager_engine.get_or_insert_with(|| Engine::with_pinning(n_threads, pin_mode));
            let plan = SpmvPlan::new_first_touch(&kernel, schedule, engine);
            let placement = PlacementDecision {
                pin_requested: true,
                pin: Some(engine.pin_report().clone()),
                first_touch: true,
            };
            rationale.push(format!("placement: {}", placement.summary()));
            (plan, placement)
        } else {
            (
                SpmvPlan::new(&kernel, schedule, n_threads),
                PlacementDecision { pin_requested: false, pin: None, first_touch: false },
            )
        };
        // First touch above ran scalar (placement precedes ISA binding;
        // the vector kernels stream the same pages); the serving path
        // executes at the arbitrated level from here on.
        plan.set_kernel_isa(chosen_isa);
        rationale.push(format!(
            "precision {}: kernel isa {} (host detects {})",
            precision.name(),
            chosen_isa.name(),
            IsaLevel::detect().name()
        ));
        let report = TuningReport {
            policy: policy.name().to_string(),
            scheme: kernel.scheme(),
            schedule,
            n_threads,
            nrows,
            nnz,
            backward_fraction: fingerprint.as_ref().map(|d| d.backward_fraction()),
            mean_abs_stride: fingerprint.as_ref().map(|d| d.mean_abs_stride()),
            small_stride_fraction: fingerprint.as_ref().map(|d| d.fraction_within(8)),
            row_imbalance_cv: row_cv,
            schedule_cv_threshold: cv_threshold_eff,
            padding_overhead: kernel_padding(&kernel),
            precision,
            kernel_isa: chosen_isa,
            placement,
            backend: None,
            shard: None,
            candidates,
            rationale,
        };
        let engine = OnceLock::new();
        if let Some(e) = eager_engine {
            let _ = engine.set(e);
        }
        Ok(SpmvContext { kernel: Arc::new(kernel), plan, n_threads, pin_mode, engine, report })
    }

    /// Run the tuning policy, then the shard policy, and bundle a
    /// [`ShardedContext`]. Scheme and schedule come from the same tiers
    /// as [`SpmvContextBuilder::build`] — the existing machinery is
    /// reused verbatim on an unpinned probe (the sharded executor owns
    /// per-shard placement); the shard count and overlap mode then come
    /// from the [`ShardPolicy`] (partition features or a host
    /// bake-off). `.threads(n)` means threads **per shard** here. A
    /// tier pick without a rectangular split kernel (the JDS family)
    /// falls back to CRS halves, recorded in the rationale.
    pub fn build_sharded(self) -> Result<ShardedContext> {
        let SpmvContextBuilder {
            crs,
            policy,
            threads,
            machine,
            quick,
            pinned,
            cv_threshold,
            shard_policy,
            precision,
        } = self;
        let shard_policy = shard_policy.unwrap_or(ShardPolicy::Heuristic);
        let crs = Arc::new(crs.into_owned());
        let mut base_builder = SpmvContext::builder_from_crs(&crs)
            .policy(policy)
            .machine(machine)
            .quick(quick)
            .precision(precision)
            .schedule_cv_threshold(cv_threshold);
        if let Some(t) = threads {
            base_builder = base_builder.threads(t);
        }
        let base = base_builder.build()?;
        let mut report = base.report().clone();
        let mut scheme = base.scheme();
        let schedule = base.schedule();
        let n_threads = base.n_threads();
        drop(base);
        if !matches!(scheme, Scheme::Crs | Scheme::SellCs { .. }) {
            report.rationale.push(format!(
                "{} has no rectangular split kernel: sharded context falls back to CRS halves",
                scheme.name()
            ));
            scheme = Scheme::Crs;
            report.scheme = scheme;
            report.padding_overhead = 0.0;
            // The JDS-family pick had no vector path, so the probe's
            // arbitration was scalar-only; the CRS halves it fell back
            // to have the full gather-FMA paths, so the precision
            // ceiling applies again.
            let ceiling =
                if precision.allows_simd() { IsaLevel::detect() } else { IsaLevel::Scalar };
            if ceiling > report.kernel_isa {
                report.kernel_isa = ceiling;
                report.rationale.push(format!(
                    "CRS-halves fallback restores the vector path: kernel isa {}",
                    ceiling.name()
                ));
            }
        }
        // ISSUE 9: the split kernels have vector bodies, so the base
        // probe above (which received the caller's precision contract)
        // arbitrated ISA for the sharded candidate exactly as it does
        // natively — its tiers scored scalar and vector variants and
        // `report.kernel_isa` is the winner. The executor binds it below.
        let (decision, shard_rationale) =
            decide_shards(&crs, shard_policy, scheme, schedule, n_threads, pinned, quick)?;
        report.rationale.extend(shard_rationale);
        let mut sharded = ShardedSpmv::new(
            crs,
            scheme,
            schedule,
            decision.n_shards,
            n_threads,
            decision.mode,
            pinned,
        )?;
        sharded.set_kernel_isa(report.kernel_isa);
        report.rationale.push(format!(
            "sharded split kernels bound to the arbitrated {} isa",
            report.kernel_isa.name()
        ));
        report.placement = PlacementDecision {
            pin_requested: pinned,
            pin: if pinned { Some(sharded.aggregate_pin_report()) } else { None },
            first_touch: sharded.first_touched(),
        };
        report.rationale.push(format!(
            "sharded: {} shard(s) × {} thread(s), {} mode ({} shard policy)",
            decision.n_shards,
            n_threads,
            decision.mode.name(),
            decision.policy
        ));
        report.shard = Some(decision);
        Ok(ShardedContext { sharded, report })
    }
}

/// Resolve a [`ShardPolicy`] into a concrete (shard count, overlap
/// mode) decision with its candidate scoreboard and rationale.
fn decide_shards(
    crs: &Crs,
    policy: ShardPolicy,
    scheme: Scheme,
    schedule: Schedule,
    n_threads: usize,
    pinned: bool,
    quick: bool,
) -> Result<(ShardDecision, Vec<String>)> {
    let mut rationale = Vec::new();
    let n = crs.nrows;
    // Scan-only candidate features: no halves are packed, no nonzeros
    // copied — the chosen partition is built once, by the caller.
    let features = |shards: usize| ShardedCrs::partition_stats(crs, shards);
    let grid = SHARD_GRID;
    match policy {
        ShardPolicy::Fixed { shards, mode } => {
            anyhow::ensure!(shards > 0, "shard count must be positive");
            let (hf, bf) = features(shards);
            rationale.push(format!(
                "fixed shard policy: caller requested {shards} shard(s), {} mode",
                mode.name()
            ));
            let candidates = vec![ShardCandidate {
                shards,
                mode,
                halo_fraction: hf,
                boundary_nnz_fraction: bf,
                measured_ns_per_nnz: None,
                chosen: true,
            }];
            let d = ShardDecision {
                policy: "fixed".into(),
                n_shards: shards,
                mode,
                halo_fraction: hf,
                boundary_nnz_fraction: bf,
                candidates,
            };
            Ok((d, rationale))
        }
        ShardPolicy::Heuristic => {
            // Halo-volume vs interior-work rule (arXiv:1106.5908 §5,
            // qualitatively): more shards pay only while the exchanged
            // halo stays a small fraction of the vector and every
            // shard keeps a useful row count; overlap pays only while
            // enough interior (halo-free) work exists to hide the
            // exchange behind.
            let mut candidates: Vec<ShardCandidate> = Vec::new();
            let mut best = (1usize, OverlapMode::BulkSync, 0.0f64, 0.0f64);
            for &s in &grid {
                let (hf, bf) = features(s);
                let mode = if s > 1 && (1.0 - bf) >= SHARD_OVERLAP_MIN_INTERIOR {
                    OverlapMode::Overlapped
                } else {
                    OverlapMode::BulkSync
                };
                candidates.push(ShardCandidate {
                    shards: s,
                    mode,
                    halo_fraction: hf,
                    boundary_nnz_fraction: bf,
                    measured_ns_per_nnz: None,
                    chosen: false,
                });
                let viable = s == 1 || (hf <= SHARD_HALO_VIABLE_MAX && n >= SHARD_MIN_ROWS * s);
                if viable {
                    best = (s, mode, hf, bf);
                }
            }
            let (n_shards, mode, hf, bf) = best;
            for c in &mut candidates {
                c.chosen = c.shards == n_shards;
            }
            rationale.push(format!(
                "shard heuristic: {n_shards} shard(s) (largest with halo fraction <= \
                 {SHARD_HALO_VIABLE_MAX} and >= {SHARD_MIN_ROWS} rows/shard; halo {hf:.3}), \
                 {} mode (interior nnz fraction {:.3})",
                mode.name(),
                1.0 - bf
            ));
            let d = ShardDecision {
                policy: "heuristic".into(),
                n_shards,
                mode,
                halo_fraction: hf,
                boundary_nnz_fraction: bf,
                candidates,
            };
            Ok((d, rationale))
        }
        ShardPolicy::Measured => {
            let acrs = Arc::new(crs.clone());
            let reps = if quick { 2 } else { 5 };
            let mut x = vec![0.0; n];
            Rng::new(0xBEEF).fill_f64(&mut x, -1.0, 1.0);
            let mut y = vec![0.0; n];
            let mut candidates: Vec<ShardCandidate> = Vec::new();
            let mut best: Option<(usize, f64)> = None;
            for &s in &grid {
                // A single shard has no exchange: the modes coincide,
                // so only bulk-sync is timed for it.
                let modes: &[OverlapMode] = if s == 1 {
                    &[OverlapMode::BulkSync]
                } else {
                    &[OverlapMode::BulkSync, OverlapMode::Overlapped]
                };
                for &mode in modes {
                    let sh = ShardedSpmv::new(
                        acrs.clone(),
                        scheme,
                        schedule,
                        s,
                        n_threads,
                        mode,
                        pinned,
                    )?;
                    sh.spmv(&x, &mut y); // warmup
                    let mut best_ns = f64::INFINITY;
                    for _ in 0..reps {
                        let t0 = Instant::now();
                        sh.spmv(&x, &mut y);
                        let ns = t0.elapsed().as_nanos() as f64 / crs.nnz().max(1) as f64;
                        best_ns = best_ns.min(ns);
                    }
                    if best.map(|(_, c)| best_ns < c).unwrap_or(true) {
                        best = Some((candidates.len(), best_ns));
                    }
                    candidates.push(ShardCandidate {
                        shards: s,
                        mode,
                        halo_fraction: sh.halo_fraction(),
                        boundary_nnz_fraction: sh.boundary_nnz_fraction(),
                        measured_ns_per_nnz: Some(best_ns),
                        chosen: false,
                    });
                }
            }
            let (best_i, best_ns) = best.expect("candidate set is never empty");
            candidates[best_i].chosen = true;
            let chosen = candidates[best_i].clone();
            rationale.push(format!(
                "shard bake-off ({reps} reps) picks {} shard(s), {} mode at {:.2} ns/nnz \
                 over {} candidates",
                chosen.shards,
                chosen.mode.name(),
                best_ns,
                candidates.len()
            ));
            let d = ShardDecision {
                policy: "measured".into(),
                n_shards: chosen.shards,
                mode: chosen.mode,
                halo_fraction: chosen.halo_fraction,
                boundary_nnz_fraction: chosen.boundary_nnz_fraction,
                candidates,
            };
            Ok((d, rationale))
        }
    }
}

/// A tuned **sharded** context: a [`ShardedSpmv`] executor bundled with
/// the [`TuningReport`] that documents scheme, schedule, shard count
/// and overlap mode — the sharded sibling of [`SpmvContext`].
/// Crate-internal since the facade PR: consumers reach it as the
/// sharded backend of a [`crate::spmv::SpmvHandle`].
pub(crate) struct ShardedContext {
    sharded: ShardedSpmv,
    report: TuningReport,
}

impl ShardedContext {
    /// The executor (shards, halo maps, modes).
    pub fn sharded(&self) -> &ShardedSpmv {
        &self.sharded
    }

    pub fn report(&self) -> &TuningReport {
        &self.report
    }

    /// Mutable report access for the facade layer (backend decisions are
    /// recorded after the context is built).
    pub(crate) fn report_mut(&mut self) -> &mut TuningReport {
        &mut self.report
    }

    pub fn scheme(&self) -> Scheme {
        self.sharded.scheme()
    }

    pub fn schedule(&self) -> Schedule {
        self.sharded.schedule()
    }

    pub fn n_shards(&self) -> usize {
        self.sharded.n_shards()
    }

    pub fn mode(&self) -> OverlapMode {
        self.sharded.mode()
    }

    /// Distributed-style SpMV across every shard (original basis).
    pub fn spmv(&self, x: &[f64], y: &mut [f64]) {
        self.sharded.spmv(x, y);
    }

    /// Batched sharded SpMV — all shards serve the whole batch in one
    /// coordinator dispatch.
    pub fn spmv_batch(&self, xs: &[Vec<f64>]) -> Vec<Vec<f64>> {
        self.sharded.spmv_batch(xs)
    }

    /// Re-partition every shard's plans for a new schedule and re-home
    /// their buffers — [`SpmvContext::rebalance`] extended to shards.
    pub fn rebalance(&mut self, schedule: Schedule) {
        self.sharded.rebalance(schedule);
        self.report.schedule = schedule;
        if self.sharded.pinned() {
            self.report.placement.first_touch = true;
            self.report.placement.pin = Some(self.sharded.aggregate_pin_report());
        }
        self.report
            .rationale
            .push(format!("rebalanced shards onto {} (buffers re-homed)", schedule.name()));
    }

    /// Re-shard onto a new shard count / overlap mode; halo buffers are
    /// re-homed on the new owners (the §5.2 hazard at shard scale).
    pub fn reshard(&mut self, n_shards: usize, mode: OverlapMode) -> Result<()> {
        self.sharded.reshard(n_shards, mode)?;
        let st = self.sharded.storage();
        let (hf, bf) = (st.halo_fraction(), st.boundary_nnz_fraction());
        if let Some(sd) = &mut self.report.shard {
            sd.n_shards = n_shards;
            sd.mode = mode;
            sd.halo_fraction = hf;
            sd.boundary_nnz_fraction = bf;
        }
        if self.sharded.pinned() {
            self.report.placement.pin = Some(self.sharded.aggregate_pin_report());
        }
        self.report.rationale.push(format!(
            "resharded onto {n_shards} shard(s), {} mode (halo buffers re-homed)",
            mode.name()
        ));
        Ok(())
    }
}

/// A sharded context is itself an [`SpMv`] operator, so solvers and the
/// service layer consume it exactly like an unsharded [`SpmvContext`].
impl SpMv for ShardedContext {
    fn nrows(&self) -> usize {
        SpMv::nrows(&self.sharded)
    }
    fn ncols(&self) -> usize {
        SpMv::ncols(&self.sharded)
    }
    fn nnz(&self) -> usize {
        SpMv::nnz(&self.sharded)
    }
    fn spmv(&self, x: &[f64], y: &mut [f64]) {
        ShardedContext::spmv(self, x, y);
    }
}

/// An owned, tuned kernel + plan + engine bundle — the native execution
/// backend behind [`crate::spmv::SpmvHandle`]. Obtain via
/// [`SpmvContext::builder`]. Crate-internal since the facade PR:
/// consumers hold a handle, never this type.
///
/// The engine thread pool is spawned lazily on the first execution, so
/// simulation-only consumers (fig 8/9) never pay for host threads.
pub(crate) struct SpmvContext {
    kernel: Arc<SpmvKernel>,
    plan: SpmvPlan,
    n_threads: usize,
    pin_mode: PinMode,
    engine: OnceLock<Engine>,
    report: TuningReport,
}

impl SpmvContext {
    /// Start a builder from an assembled COO matrix (test convenience;
    /// production consumers enter through [`crate::spmv::SpmvBuilder`],
    /// which converts once and drives [`SpmvContext::builder_from_crs`]).
    #[cfg(test)]
    pub fn builder(coo: &crate::matrix::Coo) -> SpmvContextBuilder<'static> {
        Self::builder_cow(Cow::Owned(Crs::from_coo(coo)))
    }

    /// Start a builder that borrows an already-compressed CRS matrix —
    /// no conversion and no clone; tuning only reads it.
    pub fn builder_from_crs(crs: &Crs) -> SpmvContextBuilder<'_> {
        Self::builder_cow(Cow::Borrowed(crs))
    }

    fn builder_cow(crs: Cow<'_, Crs>) -> SpmvContextBuilder<'_> {
        SpmvContextBuilder {
            crs,
            policy: TuningPolicy::Heuristic,
            threads: None,
            machine: MachineSpec::nehalem(),
            quick: false,
            pinned: false,
            cv_threshold: None,
            shard_policy: None,
            precision: Precision::default(),
        }
    }

    pub fn kernel(&self) -> &SpmvKernel {
        &self.kernel
    }

    /// Shared handle to the tuned kernel — the serial backend of the
    /// facade executes it directly, without plan or engine.
    pub(crate) fn kernel_arc(&self) -> Arc<SpmvKernel> {
        self.kernel.clone()
    }

    /// The scheduling plan (also consumable by
    /// [`crate::simulator::simulate_spmv_plan`], so a tuned decision can
    /// be evaluated on the paper's machine models).
    pub fn plan(&self) -> &SpmvPlan {
        &self.plan
    }

    /// The lazily-spawned execution engine (eager — and pinned — when
    /// the context was built with [`SpmvContextBuilder::pinned`]).
    pub fn engine(&self) -> &Engine {
        self.engine.get_or_init(|| Engine::with_pinning(self.n_threads, self.pin_mode))
    }

    /// Was NUMA placement (pinning + first touch) requested?
    pub fn pinned(&self) -> bool {
        self.pin_mode != PinMode::Disabled
    }

    pub fn report(&self) -> &TuningReport {
        &self.report
    }

    /// Mutable report access for the facade layer (backend decisions are
    /// recorded after the context is built).
    pub(crate) fn report_mut(&mut self) -> &mut TuningReport {
        &mut self.report
    }

    pub fn scheme(&self) -> Scheme {
        self.kernel.scheme()
    }

    pub fn schedule(&self) -> Schedule {
        self.plan.schedule
    }

    pub fn n_threads(&self) -> usize {
        self.n_threads
    }

    /// The instruction-set level the plan executes at (Scalar unless the
    /// [`Precision`] contract admitted vector kernels and one won).
    pub fn kernel_isa(&self) -> IsaLevel {
        self.plan.kernel_isa()
    }

    /// The numerical contract this context was tuned under.
    pub fn precision(&self) -> Precision {
        self.report.precision
    }

    /// Original-basis parallel SpMV through the tuned plan.
    pub fn spmv(&self, x: &[f64], y: &mut [f64]) {
        self.plan.execute(self.engine(), &self.kernel, x, y);
    }

    /// Permuted-basis hot path (no gather/scatter, no allocation).
    pub fn spmv_permuted(&self, xp: &[f64], yp: &mut [f64]) {
        self.plan.execute_permuted(self.engine(), &self.kernel, xp, yp);
    }

    /// Batched SpMV fused into a **single** engine dispatch: the
    /// completion latch is paid once per batch, not once per vector.
    /// Each result is bit-identical to the per-vector [`Self::spmv`].
    pub fn spmv_batch(&self, xs: &[Vec<f64>]) -> Vec<Vec<f64>> {
        self.plan.execute_batch(self.engine(), &self.kernel, xs)
    }

    /// Blocked-x SpMM through the tuned plan: the matrix is streamed
    /// once per chunk and reused across the whole column block
    /// ([`SpmvPlan::execute_multi`]). Bit-identical to [`Self::spmv`]
    /// per vector when the plan executes at [`IsaLevel::Scalar`].
    pub fn spmv_multi(&self, xs: &[Vec<f64>]) -> Vec<Vec<f64>> {
        self.plan.execute_multi(self.engine(), &self.kernel, xs)
    }

    /// Re-plan the same tuned kernel for a different schedule / thread
    /// count (cheap: the kernel is shared, only the partition is
    /// rebuilt). Used by the scaling/scheduling experiments to sweep
    /// thread counts without re-tuning. The derived report keeps the
    /// fingerprint but drops the candidate scoreboard — those scores
    /// belonged to the original schedule and would contradict the new
    /// decision rows.
    pub fn replanned(&self, schedule: Schedule, n_threads: usize) -> SpmvContext {
        let n_threads = n_threads.max(1);
        let engine = OnceLock::new();
        let mut report = self.report.clone();
        // A pinned parent re-places eagerly: the new partition's pages
        // must be first-touched by the new owners (§5.2 — a thread-count
        // change is exactly the migration hazard `rebalance` covers).
        let mut plan = if self.pinned() {
            let e = Engine::with_pinning(n_threads, self.pin_mode);
            let plan = SpmvPlan::new_first_touch(&self.kernel, schedule, &e);
            report.placement = PlacementDecision {
                pin_requested: true,
                pin: Some(e.pin_report().clone()),
                first_touch: true,
            };
            let _ = engine.set(e);
            plan
        } else {
            // The sibling's plan is freshly caller-placed even if the
            // parent had been rebalanced; its record must say so.
            report.placement =
                PlacementDecision { pin_requested: false, pin: None, first_touch: false };
            SpmvPlan::new(&self.kernel, schedule, n_threads)
        };
        // The sibling keeps serving at the parent's arbitrated ISA: the
        // precision contract was decided at build time, not per plan.
        plan.set_kernel_isa(self.plan.kernel_isa());
        report.schedule = schedule;
        report.n_threads = n_threads;
        report.policy = format!("{} (replanned)", self.report.policy);
        report.candidates.clear();
        report
            .rationale
            .push(format!("replanned for {} on {} threads", schedule.name(), n_threads));
        SpmvContext {
            kernel: self.kernel.clone(),
            plan,
            n_threads,
            pin_mode: self.pin_mode,
            engine,
            report,
        }
    }

    /// Re-partition the tuned plan for a new schedule **in place** on
    /// the existing engine (spawned now if still lazy) and re-home the
    /// workspace pages under the new assignment — the context-level face
    /// of [`SpmvPlan::rebalance`]. Use this when the serving schedule
    /// changes at run time; use [`SpmvContext::replanned`] to fork a
    /// sibling context instead.
    pub fn rebalance(&mut self, schedule: Schedule) {
        let n_threads = self.n_threads;
        let pin_mode = self.pin_mode;
        let engine = self.engine.get_or_init(|| Engine::with_pinning(n_threads, pin_mode));
        self.plan.rebalance(engine, &self.kernel, schedule);
        self.report.schedule = schedule;
        self.report.placement.first_touch = true;
        self.report.placement.pin = Some(engine.pin_report().clone());
        self.report.rationale.push(format!(
            "rebalanced onto {} ({n_threads} threads, workspace re-homed)",
            schedule.name()
        ));
    }
}

/// A tuned context is itself an [`SpMv`] operator (and therefore a
/// [`crate::eigen::LinearOp`] via the blanket impl), so solvers run
/// their hot loop through the tuned parallel plan transparently.
impl SpMv for SpmvContext {
    fn nrows(&self) -> usize {
        self.plan.nrows
    }
    fn ncols(&self) -> usize {
        self.plan.nrows
    }
    fn nnz(&self) -> usize {
        self.kernel.nnz()
    }
    fn spmv(&self, x: &[f64], y: &mut [f64]) {
        SpmvContext::spmv(self, x, y);
    }
}

/// SELL-C-σ slice heights the tuner scores (ROADMAP follow-up from
/// PR 2: the grid was a single C = 32 point; it now spans the SIMD /
/// slice-granularity range of Kreutzer et al. 2013). Heights above the
/// matrix dimension are clamped, so tiny matrices see a shorter grid.
pub const SELL_C_GRID: [usize; 5] = [4, 8, 16, 32, 64];

/// Candidate scheme set shared by the heuristic and measured tiers: CRS
/// (the paper's cache-architecture winner), a blocked-JDS representative,
/// and SELL-C-σ over [`SELL_C_GRID`] × the σ locality/padding trade-off
/// (σ ∈ {C, 8C, N} per height). The builder has already rejected
/// non-square matrices; empty ones stay on CRS.
fn candidate_schemes(crs: &Crs) -> Vec<Scheme> {
    let n = crs.nrows;
    if n == 0 {
        return vec![Scheme::Crs];
    }
    let mut v = vec![Scheme::Crs, Scheme::NbJds { block: 1024 }];
    for c in SELL_C_GRID {
        let c = c.clamp(1, n);
        for sigma in [c, 8 * c, n] {
            let s = Scheme::SellCs { c, sigma: sigma.clamp(1, n) };
            // Clamping can alias grid points on small matrices; keep one.
            if !v.contains(&s) {
                v.push(s);
            }
        }
    }
    v
}

/// Default row-imbalance CV threshold above which the schedule heuristic
/// abandons static contiguous partitions for guided chunks.
pub const SCHEDULE_CV_THRESHOLD: f64 = 0.5;

/// The threshold under first-touch placement: migrating schedules are
/// penalized (§5.2), so the imbalance must be much worse before leaving
/// the placement-preserving static partition is worth it.
pub const SCHEDULE_CV_THRESHOLD_FIRST_TOUCH: f64 = 1.25;

/// Schedule heuristic (paper §5.2): static contiguous partitions preserve
/// first-touch locality and are best for balanced matrices; only strong
/// row-length imbalance justifies guided chunks. The min chunk aims at a
/// page (512 rows of 8 B, so placement is not randomized) but is clamped
/// to leave at least ~4 chunks per thread — otherwise guided scheduling
/// on a small matrix degenerates into one serial chunk.
///
/// Under NUMA placement (`first_touch`), migrating schedules are
/// **penalized**: guided chunks land on whichever thread finishes first,
/// so rows leave the domain that first-touched their pages and local
/// traffic turns remote — the paper's §5.2 collapse. The imbalance has
/// to be much worse ([`SCHEDULE_CV_THRESHOLD_FIRST_TOUCH`] instead of
/// [`SCHEDULE_CV_THRESHOLD`]) before abandoning the placement-preserving
/// static partition is worth it. `override_threshold` is the caller's
/// knob replacing both defaults (the ROADMAP follow-up toward learning
/// the threshold from measured data starts by making it settable).
fn pick_schedule(
    nrows: usize,
    n_threads: usize,
    row_cv: f64,
    first_touch: bool,
    override_threshold: Option<f64>,
    rationale: &mut Vec<String>,
) -> Schedule {
    let default = if first_touch {
        SCHEDULE_CV_THRESHOLD_FIRST_TOUCH
    } else {
        SCHEDULE_CV_THRESHOLD
    };
    let threshold = override_threshold.unwrap_or(default);
    let origin = if override_threshold.is_some() { " (caller-set)" } else { "" };
    if row_cv > threshold {
        let min_chunk = 512.min((nrows / (4 * n_threads.max(1))).max(1));
        rationale.push(format!(
            "row imbalance CV {row_cv:.2} > {threshold}{origin}: guided schedule, \
             min chunk {min_chunk}"
        ));
        Schedule::Guided { min_chunk }
    } else {
        if first_touch && override_threshold.is_none() && row_cv > SCHEDULE_CV_THRESHOLD {
            rationale.push(format!(
                "row imbalance CV {row_cv:.2} would suggest guided, but first-touch placement \
                 penalizes migrating schedules (remote-traffic hazard): keeping static"
            ));
        } else {
            rationale.push(format!(
                "row imbalance CV {row_cv:.2} <= {threshold}{origin}: static contiguous \
                 partitions (NUMA-safe default)"
            ));
        }
        Schedule::Static { chunk: None }
    }
}

fn kernel_padding(kernel: &SpmvKernel) -> f64 {
    match kernel {
        SpmvKernel::Sell(m) => m.padding_overhead(),
        _ => 0.0,
    }
}

/// Coefficient of variation (std / mean) of nnz per row.
fn row_imbalance_cv(crs: &Crs) -> f64 {
    let n = crs.nrows;
    if n == 0 {
        return 0.0;
    }
    let mean = crs.nnz() as f64 / n as f64;
    if mean == 0.0 {
        return 0.0;
    }
    let var = (0..n)
        .map(|i| {
            let d = (crs.row_ptr[i + 1] - crs.row_ptr[i]) as f64 - mean;
            d * d
        })
        .sum::<f64>()
        / n as f64;
    var.sqrt() / mean
}

/// Per-machine cost-curve cache: calibration walks the simulator, so do
/// it once per (machine, fidelity) pair per process. Shared with the
/// facade's backend-arbitration heuristic.
pub(crate) fn cached_curve(machine: &MachineSpec, quick: bool) -> Arc<CostCurve> {
    static CACHE: OnceLock<Mutex<HashMap<String, Arc<CostCurve>>>> = OnceLock::new();
    let cache = CACHE.get_or_init(|| Mutex::new(HashMap::new()));
    let key = format!("{}:{}", machine.name, quick);
    let mut guard = cache.lock().unwrap();
    guard
        .entry(key)
        .or_insert_with(|| {
            Arc::new(CostCurve::calibrate(machine, if quick { 5_000 } else { 20_000 }))
        })
        .clone()
}

/// Demote a SELL-C-σ kernel's parameters for reporting (0, 0) otherwise.
pub fn sell_params(scheme: Scheme) -> (usize, usize) {
    match scheme {
        Scheme::SellCs { c, sigma } => (c, sigma),
        _ => (0, 0),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen;
    use crate::matrix::Coo;
    use crate::util::stats::max_abs_diff;

    fn policies() -> Vec<TuningPolicy> {
        vec![
            TuningPolicy::Fixed(
                Scheme::SellCs { c: 8, sigma: 64 },
                Schedule::Dynamic { chunk: 13 },
            ),
            TuningPolicy::Heuristic,
            TuningPolicy::Measured,
        ]
    }

    fn random_coo(rng: &mut Rng, n: usize, nnz: usize) -> Coo {
        let mut coo = Coo::new(n, n);
        for _ in 0..nnz {
            coo.push(rng.index(n), rng.index(n), rng.f64() * 2.0 - 1.0);
        }
        coo.normalize();
        coo
    }

    /// ISSUE-7: the serve cache key. Identical matrices fingerprint
    /// identically; changing one value flips only the value hash (same
    /// structure ⇒ plan transfers); changing the pattern flips both.
    #[test]
    fn matrix_fingerprint_separates_structure_from_values() {
        let coo = random_coo(&mut Rng::new(83), 120, 120 * 5);
        let crs = Crs::from_coo(&coo);
        let fp = MatrixFingerprint::of(&crs);
        assert_eq!(fp, MatrixFingerprint::of(&crs), "fingerprint must be deterministic");
        assert_eq!(fp.nnz, crs.val.len());
        // Same pattern, one perturbed value: structure holds, values differ.
        let mut revalued = crs.clone();
        revalued.val[0] += 1.0;
        let fp_v = MatrixFingerprint::of(&revalued);
        assert!(fp.same_structure(&fp_v));
        assert_eq!(fp.structure, fp_v.structure);
        assert_ne!(fp.values, fp_v.values);
        assert_ne!(fp, fp_v);
        // Different pattern (extra entry off the tridiagonal band):
        // both hashes differ.
        let tri = Crs::from_coo(&gen::laplacian_1d(120));
        let mut coo2 = gen::laplacian_1d(120);
        coo2.push(7, 100, 0.5);
        coo2.normalize();
        let fp_tri = MatrixFingerprint::of(&tri);
        let fp_s = MatrixFingerprint::of(&Crs::from_coo(&coo2));
        assert!(!fp_tri.same_structure(&fp_s));
        assert_ne!(fp_tri.structure, fp_s.structure);
    }

    /// Every policy tier must agree with the serial CRS reference (1e-12:
    /// schemes may reorder per-row accumulation) and be **bit-identical**
    /// to the serial kernel of whatever scheme the tuner picked (the
    /// engine invariant).
    #[test]
    fn every_policy_matches_serial_crs_reference() {
        let matrices: Vec<(&str, Coo)> = vec![
            ("holstein-hubbard", gen::holstein_hubbard(&gen::HolsteinHubbardParams::tiny())),
            ("random-square", random_coo(&mut Rng::new(80), 160, 160 * 6)),
            ("random-band", gen::random_band(300, 9, 40, &mut Rng::new(81))),
        ];
        for (name, coo) in &matrices {
            let crs = Crs::from_coo(coo);
            let n = crs.nrows;
            let mut rng = Rng::new(82);
            let mut x = vec![0.0; n];
            rng.fill_f64(&mut x, -1.0, 1.0);
            let mut y_ref = vec![0.0; n];
            crs.spmv(&x, &mut y_ref);
            for policy in policies() {
                let ctx = SpmvContext::builder(coo)
                    .policy(policy)
                    .threads(3)
                    .quick(true)
                    .build()
                    .unwrap();
                let mut y = vec![0.0; n];
                ctx.spmv(&x, &mut y);
                assert!(
                    max_abs_diff(&y_ref, &y) < 1e-12,
                    "{name} × {}: context deviates from serial CRS",
                    policy.name()
                );
                // Bit-identity against the chosen scheme's serial kernel.
                let mut y_serial = vec![0.0; n];
                ctx.kernel().spmv(&x, &mut y_serial);
                assert_eq!(
                    max_abs_diff(&y_serial, &y),
                    0.0,
                    "{name} × {}: parallel context not bit-identical to its serial kernel",
                    policy.name()
                );
            }
        }
    }

    #[test]
    fn spmv_batch_bit_identical_to_per_vector() {
        let coo = gen::holstein_hubbard(&gen::HolsteinHubbardParams::tiny());
        let n = coo.nrows;
        let mut rng = Rng::new(83);
        let xs: Vec<Vec<f64>> = (0..6)
            .map(|_| {
                let mut x = vec![0.0; n];
                rng.fill_f64(&mut x, -1.0, 1.0);
                x
            })
            .collect();
        for policy in policies() {
            let ctx = SpmvContext::builder(&coo)
                .policy(policy)
                .threads(4)
                .quick(true)
                .build()
                .unwrap();
            let batched = ctx.spmv_batch(&xs);
            assert_eq!(batched.len(), xs.len());
            for (x, yb) in xs.iter().zip(&batched) {
                let mut y = vec![0.0; n];
                ctx.spmv(x, &mut y);
                assert_eq!(
                    max_abs_diff(&y, yb),
                    0.0,
                    "{}: batch deviates from per-vector spmv",
                    policy.name()
                );
            }
        }
    }

    #[test]
    fn fixed_policy_respects_request() {
        let coo = random_coo(&mut Rng::new(84), 120, 700);
        let scheme = Scheme::SellCs { c: 8, sigma: 64 };
        let schedule = Schedule::Dynamic { chunk: 17 };
        let ctx = SpmvContext::builder(&coo)
            .policy(TuningPolicy::Fixed(scheme, schedule))
            .threads(2)
            .build()
            .unwrap();
        assert_eq!(ctx.scheme(), scheme);
        assert_eq!(ctx.schedule(), schedule);
        assert_eq!(ctx.n_threads(), 2);
        assert_eq!(ctx.report().policy, "fixed");
        assert!(ctx.report().padding_overhead >= 0.0);
    }

    #[test]
    fn heuristic_report_documents_the_decision() {
        let coo = gen::holstein_hubbard(&gen::HolsteinHubbardParams::tiny());
        let ctx = SpmvContext::builder(&coo)
            .policy(TuningPolicy::Heuristic)
            .threads(2)
            .quick(true)
            .build()
            .unwrap();
        let r = ctx.report();
        assert_eq!(r.policy, "heuristic");
        assert!(!r.candidates.is_empty(), "heuristic must score candidates");
        assert_eq!(r.candidates.iter().filter(|c| c.chosen).count(), 1);
        let chosen = r.candidates.iter().find(|c| c.chosen).unwrap();
        assert_eq!(chosen.scheme, ctx.scheme());
        assert!(chosen.predicted_cycles_per_nnz.is_some());
        assert!(r.backward_fraction.is_some(), "fingerprint must be recorded");
        assert!(!r.rationale.is_empty(), "decision trail must be recorded");
        assert!(!r.tables().is_empty());
    }

    #[test]
    fn measured_report_has_timings() {
        let coo = random_coo(&mut Rng::new(85), 200, 1400);
        let ctx = SpmvContext::builder(&coo)
            .policy(TuningPolicy::Measured)
            .threads(2)
            .quick(true)
            .build()
            .unwrap();
        let r = ctx.report();
        assert_eq!(r.policy, "measured");
        assert!(r.candidates.iter().all(|c| c.measured_ns_per_nnz.is_some()));
        let chosen = r.candidates.iter().find(|c| c.chosen).unwrap();
        let best = r
            .candidates
            .iter()
            .map(|c| c.measured_ns_per_nnz.unwrap())
            .fold(f64::INFINITY, f64::min);
        assert_eq!(chosen.measured_ns_per_nnz.unwrap(), best);
    }

    #[test]
    fn replanned_shares_kernel_and_stays_exact() {
        let coo = random_coo(&mut Rng::new(86), 150, 900);
        let ctx = SpmvContext::builder(&coo)
            .policy(TuningPolicy::Fixed(
                Scheme::SellCs { c: 16, sigma: 64 },
                Schedule::Static { chunk: None },
            ))
            .threads(1)
            .build()
            .unwrap();
        let mut rng = Rng::new(87);
        let mut x = vec![0.0; 150];
        rng.fill_f64(&mut x, -1.0, 1.0);
        let mut y1 = vec![0.0; 150];
        ctx.spmv(&x, &mut y1);
        let re = ctx.replanned(Schedule::Guided { min_chunk: 8 }, 3);
        assert_eq!(re.scheme(), ctx.scheme());
        assert_eq!(re.n_threads(), 3);
        let mut y2 = vec![0.0; 150];
        re.spmv(&x, &mut y2);
        assert_eq!(max_abs_diff(&y1, &y2), 0.0, "replanned context deviates");
    }

    #[test]
    fn context_drives_linear_op_consumers() {
        use crate::eigen::{lanczos, LanczosConfig};
        let coo = gen::laplacian_1d(120);
        let ctx = SpmvContext::builder(&coo)
            .policy(TuningPolicy::Fixed(Scheme::Crs, Schedule::Static { chunk: None }))
            .threads(2)
            .build()
            .unwrap();
        let r = lanczos(&ctx, 1, &LanczosConfig::default());
        assert!(r.converged);
        let crs = Crs::from_coo(&coo);
        let want = lanczos(&crs, 1, &LanczosConfig::default());
        assert!((r.eigenvalues[0] - want.eigenvalues[0]).abs() < 1e-10);
    }

    #[test]
    fn non_square_matrix_is_rejected() {
        let mut coo = Coo::new(4, 7);
        coo.push(0, 6, 1.0);
        coo.normalize();
        for policy in policies() {
            let err = SpmvContext::builder(&coo).policy(policy).threads(1).build();
            assert!(err.is_err(), "{}: non-square matrix must be rejected", policy.name());
        }
    }

    #[test]
    fn threads_default_is_capped() {
        let coo = gen::laplacian_1d(64);
        let ctx = SpmvContext::builder(&coo)
            .policy(TuningPolicy::Fixed(Scheme::Crs, Schedule::Static { chunk: None }))
            .build()
            .unwrap();
        assert!(ctx.n_threads() >= 1 && ctx.n_threads() <= 4);
    }

    /// ISSUE-3 satellite: the widened candidate grid spans C ∈ SELL_C_GRID
    /// (clamped to N) and any SELL pick is on the grid.
    #[test]
    fn heuristic_candidate_grid_spans_all_c_and_pick_is_on_grid() {
        let coo = gen::holstein_hubbard(&gen::HolsteinHubbardParams::tiny());
        let n = coo.nrows;
        let ctx = SpmvContext::builder(&coo)
            .policy(TuningPolicy::Heuristic)
            .threads(2)
            .quick(true)
            .build()
            .unwrap();
        let r = ctx.report();
        for c in SELL_C_GRID {
            let c = c.clamp(1, n);
            assert!(
                r.candidates
                    .iter()
                    .any(|cand| matches!(cand.scheme, Scheme::SellCs { c: cc, .. } if cc == c)),
                "grid height C={c} missing from the heuristic candidate set"
            );
        }
        for cand in &r.candidates {
            if let Scheme::SellCs { c, .. } = cand.scheme {
                assert!(
                    SELL_C_GRID.iter().any(|&g| g.clamp(1, n) == c),
                    "candidate C={c} is off the grid"
                );
            }
        }
        if let Scheme::SellCs { c, .. } = ctx.scheme() {
            assert!(SELL_C_GRID.iter().any(|&g| g.clamp(1, n) == c), "picked C={c} off grid");
        }
    }

    /// ISSUE-3 satellite: every policy × pinning on/off stays
    /// bit-identical to the chosen scheme's serial kernel (the non-Linux
    /// fallback takes the same path with a no-op pin, so this covers it
    /// by construction).
    #[test]
    fn pinned_contexts_bit_identical_to_serial() {
        let coo = gen::holstein_hubbard(&gen::HolsteinHubbardParams::tiny());
        let n = coo.nrows;
        let mut rng = Rng::new(88);
        let mut x = vec![0.0; n];
        rng.fill_f64(&mut x, -1.0, 1.0);
        for policy in policies() {
            for pin in [false, true] {
                let ctx = SpmvContext::builder(&coo)
                    .policy(policy)
                    .threads(3)
                    .quick(true)
                    .pinned(pin)
                    .build()
                    .unwrap();
                assert_eq!(ctx.pinned(), pin);
                assert_eq!(ctx.report().placement.pin_requested, pin);
                assert_eq!(ctx.report().placement.first_touch, pin);
                assert_eq!(ctx.plan().first_touched(), pin);
                if pin {
                    let pr = ctx.report().placement.pin.as_ref().expect("pinned report");
                    assert_eq!(pr.per_thread.len(), 3);
                }
                let mut y_serial = vec![0.0; n];
                ctx.kernel().spmv(&x, &mut y_serial);
                let mut y = vec![0.0; n];
                ctx.spmv(&x, &mut y);
                assert_eq!(
                    max_abs_diff(&y_serial, &y),
                    0.0,
                    "{} × pin={pin}: deviates from its serial kernel",
                    policy.name()
                );
            }
        }
    }

    #[test]
    fn context_rebalance_rehomes_and_stays_exact() {
        let coo = random_coo(&mut Rng::new(89), 180, 1200);
        let n = 180;
        let mut rng = Rng::new(90);
        let mut x = vec![0.0; n];
        rng.fill_f64(&mut x, -1.0, 1.0);
        for pin in [false, true] {
            let mut ctx = SpmvContext::builder(&coo)
                .policy(TuningPolicy::Fixed(
                    Scheme::SellCs { c: 16, sigma: 64 },
                    Schedule::Static { chunk: None },
                ))
                .threads(3)
                .pinned(pin)
                .build()
                .unwrap();
            let mut want = vec![0.0; n];
            ctx.spmv(&x, &mut want);
            ctx.rebalance(Schedule::Dynamic { chunk: 11 });
            assert_eq!(ctx.schedule(), Schedule::Dynamic { chunk: 11 });
            assert!(ctx.plan().first_touched(), "rebalance must re-touch");
            assert!(ctx.report().rationale.iter().any(|r| r.contains("rebalanced")));
            let mut got = vec![0.0; n];
            ctx.spmv(&x, &mut got);
            assert_eq!(max_abs_diff(&want, &got), 0.0, "pin={pin}: rebalance changed results");
        }
    }

    #[test]
    fn replanned_pinned_context_keeps_placement() {
        let coo = gen::laplacian_1d(256);
        let ctx = SpmvContext::builder(&coo)
            .policy(TuningPolicy::Fixed(Scheme::Crs, Schedule::Static { chunk: None }))
            .threads(2)
            .pinned(true)
            .build()
            .unwrap();
        let re = ctx.replanned(Schedule::Static { chunk: Some(32) }, 3);
        assert!(re.pinned());
        assert!(re.plan().first_touched());
        let pr = re.report().placement.pin.as_ref().expect("replanned pin report");
        assert_eq!(pr.per_thread.len(), 3);
        let mut x = vec![0.0; 256];
        Rng::new(91).fill_f64(&mut x, -1.0, 1.0);
        let mut a = vec![0.0; 256];
        let mut b = vec![0.0; 256];
        ctx.spmv(&x, &mut a);
        re.spmv(&x, &mut b);
        assert_eq!(max_abs_diff(&a, &b), 0.0);
    }

    /// Placement is folded into the schedule choice: an imbalance that
    /// sends the unpinned heuristic to guided stays on static under
    /// first-touch placement (§5.2 migration penalty).
    #[test]
    fn placement_penalizes_migrating_schedules() {
        let mut r1 = Vec::new();
        let s1 = pick_schedule(10_000, 4, 0.8, false, None, &mut r1);
        assert!(matches!(s1, Schedule::Guided { .. }), "CV 0.8 unpinned should go guided");
        let mut r2 = Vec::new();
        let s2 = pick_schedule(10_000, 4, 0.8, true, None, &mut r2);
        assert_eq!(
            s2,
            Schedule::Static { chunk: None },
            "CV 0.8 under first-touch must keep the placement-preserving static schedule"
        );
        assert!(r2.iter().any(|s| s.contains("first-touch")));
        let mut r3 = Vec::new();
        let s3 = pick_schedule(10_000, 4, 1.5, true, None, &mut r3);
        assert!(
            matches!(s3, Schedule::Guided { .. }),
            "extreme imbalance still overrides placement"
        );
    }

    /// ISSUE-5 satellite: the CV threshold is a caller knob replacing
    /// both placement-dependent defaults, and the effective value is
    /// recorded in the report.
    #[test]
    fn schedule_cv_threshold_is_overridable_and_recorded() {
        let mut r = Vec::new();
        // CV 0.8 goes guided unpinned by default, but a raised caller
        // threshold keeps it static even there.
        let s = pick_schedule(10_000, 4, 0.8, false, Some(2.0), &mut r);
        assert_eq!(s, Schedule::Static { chunk: None });
        assert!(r.iter().any(|m| m.contains("caller-set")), "{r:?}");
        // And a lowered threshold sends even a pinned build guided.
        let mut r2 = Vec::new();
        let s2 = pick_schedule(10_000, 4, 0.8, true, Some(0.1), &mut r2);
        assert!(matches!(s2, Schedule::Guided { .. }));
        // Report plumbing: default and override both land in the report.
        let coo = gen::laplacian_1d(128);
        let ctx = SpmvContext::builder(&coo)
            .policy(TuningPolicy::Heuristic)
            .threads(2)
            .quick(true)
            .build()
            .unwrap();
        assert_eq!(ctx.report().schedule_cv_threshold, SCHEDULE_CV_THRESHOLD);
        let ctx2 = SpmvContext::builder(&coo)
            .policy(TuningPolicy::Heuristic)
            .threads(2)
            .quick(true)
            .schedule_cv_threshold(Some(3.5))
            .build()
            .unwrap();
        assert_eq!(ctx2.report().schedule_cv_threshold, 3.5);
        let pinned = SpmvContext::builder(&coo)
            .policy(TuningPolicy::Fixed(Scheme::Crs, Schedule::Static { chunk: None }))
            .threads(2)
            .pinned(true)
            .build()
            .unwrap();
        assert_eq!(
            pinned.report().schedule_cv_threshold,
            SCHEDULE_CV_THRESHOLD_FIRST_TOUCH
        );
    }

    /// ISSUE-4: the sharding dimension of the tuning space. Every shard
    /// policy yields a context that is bit-identical to the serial CRS
    /// reference and documents its decision.
    #[test]
    fn sharded_context_bit_identical_and_reported() {
        let coo = gen::holstein_hubbard(&gen::HolsteinHubbardParams::tiny());
        let n = coo.nrows;
        let mut rng = Rng::new(92);
        let mut x = vec![0.0; n];
        rng.fill_f64(&mut x, -1.0, 1.0);
        let crs = Crs::from_coo(&coo);
        let mut want = vec![0.0; n];
        crs.spmv(&x, &mut want);
        let shard_policies = [
            ShardPolicy::Fixed { shards: 3, mode: OverlapMode::Overlapped },
            ShardPolicy::Heuristic,
            ShardPolicy::Measured,
        ];
        for sp in shard_policies {
            for pin in [false, true] {
                let ctx = SpmvContext::builder(&coo)
                    .policy(TuningPolicy::Fixed(
                        Scheme::SellCs { c: 8, sigma: 64 },
                        Schedule::Static { chunk: None },
                    ))
                    .threads(2)
                    .quick(true)
                    .pinned(pin)
                    .sharded(sp)
                    .build_sharded()
                    .unwrap();
                assert_eq!(ctx.scheme(), Scheme::SellCs { c: 8, sigma: 64 });
                let sd = ctx.report().shard.as_ref().expect("shard decision recorded");
                assert_eq!(sd.policy, sp.name());
                assert_eq!(sd.n_shards, ctx.n_shards());
                assert_eq!(sd.mode, ctx.mode());
                assert!(!sd.candidates.is_empty());
                assert_eq!(sd.candidates.iter().filter(|c| c.chosen).count(), 1);
                assert_eq!(ctx.report().placement.pin_requested, pin);
                assert_eq!(ctx.sharded().first_touched(), pin);
                assert!(!ctx.report().tables().is_empty());
                let mut y = vec![0.0; n];
                ctx.spmv(&x, &mut y);
                assert_eq!(
                    max_abs_diff(&want, &y),
                    0.0,
                    "{} shard policy × pin={pin} deviates from serial CRS",
                    sp.name()
                );
                // Batched path matches too.
                let ys = ctx.spmv_batch(std::slice::from_ref(&x));
                assert_eq!(max_abs_diff(&ys[0], &y), 0.0);
            }
        }
    }

    /// The heuristic tier reads the partition features: a narrow band
    /// matrix (tiny halo per cut, interior-dominated) goes wide and
    /// overlapped; measured candidates carry timings.
    #[test]
    fn shard_heuristic_and_measured_tiers_document_candidates() {
        let coo = gen::random_band(1024, 5, 9, &mut Rng::new(93));
        let ctx = SpmvContext::builder(&coo)
            .policy(TuningPolicy::Fixed(Scheme::Crs, Schedule::Static { chunk: None }))
            .threads(1)
            .sharded(ShardPolicy::Heuristic)
            .build_sharded()
            .unwrap();
        let sd = ctx.report().shard.as_ref().unwrap();
        assert_eq!(sd.candidates.len(), SHARD_GRID.len());
        assert!(
            sd.n_shards > 1,
            "narrow band with 1024 rows should shard (picked {})",
            sd.n_shards
        );
        assert_eq!(sd.mode, OverlapMode::Overlapped, "interior-dominated band should overlap");
        assert!(sd.halo_fraction <= 0.5);
        let measured = SpmvContext::builder(&coo)
            .policy(TuningPolicy::Fixed(Scheme::Crs, Schedule::Static { chunk: None }))
            .threads(1)
            .quick(true)
            .sharded(ShardPolicy::Measured)
            .build_sharded()
            .unwrap();
        let sd = measured.report().shard.as_ref().unwrap();
        assert!(sd.candidates.iter().all(|c| c.measured_ns_per_nnz.is_some()));
        let chosen = sd.candidates.iter().find(|c| c.chosen).unwrap();
        let best = sd
            .candidates
            .iter()
            .map(|c| c.measured_ns_per_nnz.unwrap())
            .fold(f64::INFINITY, f64::min);
        assert_eq!(chosen.measured_ns_per_nnz.unwrap(), best);
    }

    /// A tier pick without a rectangular split kernel falls back to CRS
    /// halves, with the fallback recorded.
    #[test]
    fn sharded_context_falls_back_from_jds_schemes() {
        let coo = gen::holstein_hubbard(&gen::HolsteinHubbardParams::tiny());
        let ctx = SpmvContext::builder(&coo)
            .policy(TuningPolicy::Fixed(
                Scheme::NbJds { block: 64 },
                Schedule::Static { chunk: None },
            ))
            .threads(1)
            .sharded(ShardPolicy::Fixed { shards: 2, mode: OverlapMode::BulkSync })
            .build_sharded()
            .unwrap();
        assert_eq!(ctx.scheme(), Scheme::Crs);
        assert!(ctx
            .report()
            .rationale
            .iter()
            .any(|r| r.contains("falls back to CRS halves")));
    }

    /// ISSUE-4 satellite: rebalance + reshard on a tuned sharded
    /// context keep bit-identity and re-home buffers (the §5.2 hazard
    /// tests of PR 3, extended to shards).
    #[test]
    fn sharded_context_reshard_and_rebalance_stay_exact() {
        let coo = gen::holstein_hubbard(&gen::HolsteinHubbardParams::tiny());
        let n = coo.nrows;
        let mut rng = Rng::new(94);
        let mut x = vec![0.0; n];
        rng.fill_f64(&mut x, -1.0, 1.0);
        let crs = Crs::from_coo(&coo);
        let mut want = vec![0.0; n];
        crs.spmv(&x, &mut want);
        for pin in [false, true] {
            let mut ctx = SpmvContext::builder(&coo)
                .policy(TuningPolicy::Fixed(Scheme::Crs, Schedule::Static { chunk: None }))
                .threads(2)
                .pinned(pin)
                .sharded(ShardPolicy::Fixed { shards: 4, mode: OverlapMode::Overlapped })
                .build_sharded()
                .unwrap();
            let mut got = vec![0.0; n];
            ctx.spmv(&x, &mut got);
            assert_eq!(max_abs_diff(&want, &got), 0.0, "pin={pin}: pre-change");
            ctx.rebalance(Schedule::Guided { min_chunk: 4 });
            assert_eq!(ctx.schedule(), Schedule::Guided { min_chunk: 4 });
            ctx.spmv(&x, &mut got);
            assert_eq!(max_abs_diff(&want, &got), 0.0, "pin={pin}: post-rebalance");
            ctx.reshard(2, OverlapMode::BulkSync).unwrap();
            assert_eq!(ctx.n_shards(), 2);
            assert_eq!(ctx.mode(), OverlapMode::BulkSync);
            let sd = ctx.report().shard.as_ref().unwrap();
            assert_eq!(sd.n_shards, 2);
            assert_eq!(ctx.sharded().first_touched(), pin, "reshard must re-home when pinned");
            ctx.spmv(&x, &mut got);
            assert_eq!(max_abs_diff(&want, &got), 0.0, "pin={pin}: post-reshard");
            assert!(ctx.report().rationale.iter().any(|r| r.contains("resharded")));
        }
    }

    /// ISSUE-6 tentpole: the default BitIdentical contract never admits
    /// a vector kernel — every candidate and the chosen plan are scalar,
    /// so all pre-SIMD bit-identity guarantees hold unchanged.
    #[test]
    fn bit_identical_default_never_picks_simd() {
        let coo = gen::holstein_hubbard(&gen::HolsteinHubbardParams::tiny());
        for policy in policies() {
            let ctx = SpmvContext::builder(&coo)
                .policy(policy)
                .threads(2)
                .quick(true)
                .build()
                .unwrap();
            assert_eq!(ctx.precision(), Precision::BitIdentical);
            assert_eq!(ctx.kernel_isa(), IsaLevel::Scalar);
            assert_eq!(ctx.report().kernel_isa, IsaLevel::Scalar);
            assert!(
                ctx.report().candidates.iter().all(|c| c.isa == IsaLevel::Scalar),
                "{}: BitIdentical candidate set must be scalar-only",
                policy.name()
            );
        }
    }

    /// ISSUE-6 tentpole: under Tolerance(ε) the tuner scores ISA
    /// variants, binds a level no higher than the host detects, and the
    /// result stays within ε of the serial CRS reference across every
    /// policy tier.
    #[test]
    fn tolerance_contract_arbitrates_isa_within_eps() {
        let eps = 1e-12;
        let matrices: Vec<(&str, Coo)> = vec![
            ("holstein-hubbard", gen::holstein_hubbard(&gen::HolsteinHubbardParams::tiny())),
            ("random-band", gen::random_band(300, 9, 40, &mut Rng::new(95))),
        ];
        for (name, coo) in &matrices {
            let crs = Crs::from_coo(coo);
            let n = crs.nrows;
            let mut x = vec![0.0; n];
            Rng::new(96).fill_f64(&mut x, -1.0, 1.0);
            let mut want = vec![0.0; n];
            crs.spmv(&x, &mut want);
            for policy in policies() {
                let ctx = SpmvContext::builder(coo)
                    .policy(policy)
                    .threads(2)
                    .quick(true)
                    .precision(Precision::Tolerance(eps))
                    .build()
                    .unwrap();
                assert_eq!(ctx.precision(), Precision::Tolerance(eps));
                assert!(ctx.kernel_isa() <= IsaLevel::detect());
                assert_eq!(ctx.report().kernel_isa, ctx.kernel_isa());
                // On a SIMD host the tuning tiers must have *scored*
                // vector variants for the vectorizable schemes.
                if IsaLevel::detect() > IsaLevel::Scalar
                    && !matches!(policy, TuningPolicy::Fixed(..))
                {
                    assert!(
                        ctx.report().candidates.iter().any(|c| c.isa > IsaLevel::Scalar),
                        "{name} × {}: no vector candidate scored on a SIMD host",
                        policy.name()
                    );
                }
                let mut y = vec![0.0; n];
                ctx.spmv(&x, &mut y);
                for i in 0..n {
                    assert!(
                        (y[i] - want[i]).abs() <= eps * want[i].abs().max(1.0),
                        "{name} × {}: row {i} off by {} (isa {})",
                        policy.name(),
                        (y[i] - want[i]).abs(),
                        ctx.kernel_isa()
                    );
                }
                // The batch path runs the same ISA-bound plan.
                let ys = ctx.spmv_batch(std::slice::from_ref(&x));
                assert_eq!(max_abs_diff(&ys[0], &y), 0.0);
            }
        }
    }

    /// The arbitrated ISA survives replanning and rebalancing — the
    /// contract is a property of the context, not of one partition.
    #[test]
    fn kernel_isa_survives_replan_and_rebalance() {
        let coo = gen::holstein_hubbard(&gen::HolsteinHubbardParams::tiny());
        let n = coo.nrows;
        let mut ctx = SpmvContext::builder(&coo)
            .policy(TuningPolicy::Fixed(
                Scheme::SellCs { c: 8, sigma: 64 },
                Schedule::Static { chunk: None },
            ))
            .threads(2)
            .precision(Precision::Tolerance(1e-12))
            .build()
            .unwrap();
        let isa = ctx.kernel_isa();
        // Fixed + Tolerance binds the ceiling on vectorizable schemes.
        assert_eq!(isa, IsaLevel::detect());
        let mut x = vec![0.0; n];
        Rng::new(97).fill_f64(&mut x, -1.0, 1.0);
        let crs = Crs::from_coo(&coo);
        let mut want = vec![0.0; n];
        crs.spmv(&x, &mut want);
        // A schedule change re-partitions rows, moving boundary rows
        // between vector groups and the scalar remainder — so the
        // invariant across replans is the ε contract, not bit identity.
        let within_eps = |got: &[f64]| {
            got.iter()
                .zip(&want)
                .all(|(g, w)| (g - w).abs() <= 1e-12 * w.abs().max(1.0))
        };
        let re = ctx.replanned(Schedule::Guided { min_chunk: 8 }, 3);
        assert_eq!(re.kernel_isa(), isa, "replanned sibling dropped the ISA");
        let mut y = vec![0.0; n];
        re.spmv(&x, &mut y);
        assert!(within_eps(&y), "replanned sibling left the ε contract");
        ctx.rebalance(Schedule::Dynamic { chunk: 7 });
        assert_eq!(ctx.kernel_isa(), isa, "rebalance dropped the ISA");
        ctx.spmv(&x, &mut y);
        assert!(within_eps(&y), "rebalanced context left the ε contract");
    }

    /// ISSUE-9 satellite: a Tolerance sharded candidate records a
    /// non-scalar `kernel_isa` on SIMD hosts — arbitrated by the base
    /// probe's tiers (not forced) and bound onto the executor — while
    /// the sharded output stays within ε of serial CRS. The JDS
    /// fallback path re-derives the ceiling instead of inheriting the
    /// abandoned scheme's scalar-only pick.
    #[test]
    fn sharded_tolerance_arbitrates_vector_isa_within_eps() {
        let eps = 1e-12;
        let coo = gen::holstein_hubbard(&gen::HolsteinHubbardParams::tiny());
        let n = coo.nrows;
        let mut x = vec![0.0; n];
        Rng::new(98).fill_f64(&mut x, -1.0, 1.0);
        let crs = Crs::from_coo(&coo);
        let mut want = vec![0.0; n];
        crs.spmv(&x, &mut want);
        let within_eps = |got: &[f64], label: &str, isa: IsaLevel| {
            for i in 0..n {
                assert!(
                    (got[i] - want[i]).abs() <= eps * want[i].abs().max(1.0),
                    "{label}: row {i} off by {} (isa {isa})",
                    (got[i] - want[i]).abs()
                );
            }
        };
        let ctx = SpmvContext::builder(&coo)
            .policy(TuningPolicy::Fixed(
                Scheme::SellCs { c: 8, sigma: 64 },
                Schedule::Static { chunk: None },
            ))
            .threads(2)
            .quick(true)
            .precision(Precision::Tolerance(eps))
            .sharded(ShardPolicy::Fixed { shards: 2, mode: OverlapMode::Overlapped })
            .build_sharded()
            .unwrap();
        assert_eq!(ctx.report().precision, Precision::Tolerance(eps));
        assert!(ctx.report().kernel_isa <= IsaLevel::detect());
        assert_eq!(
            ctx.sharded().kernel_isa(),
            ctx.report().kernel_isa,
            "executor must run the isa the report records"
        );
        if IsaLevel::detect() > IsaLevel::Scalar {
            assert!(
                ctx.report().kernel_isa > IsaLevel::Scalar,
                "Tolerance sharded candidate must record a vector isa on a SIMD host"
            );
        }
        assert!(ctx
            .report()
            .rationale
            .iter()
            .any(|r| r.contains("sharded split kernels bound to the arbitrated")));
        let mut y = vec![0.0; n];
        ctx.spmv(&x, &mut y);
        within_eps(&y, "sell sharded", ctx.report().kernel_isa);
        // JDS tier pick: the probe arbitrated scalar-only (no vector
        // path on JDS), but the CRS halves it falls back to vectorize.
        let fb = SpmvContext::builder(&coo)
            .policy(TuningPolicy::Fixed(
                Scheme::NbJds { block: 64 },
                Schedule::Static { chunk: None },
            ))
            .threads(1)
            .precision(Precision::Tolerance(eps))
            .sharded(ShardPolicy::Fixed { shards: 2, mode: OverlapMode::BulkSync })
            .build_sharded()
            .unwrap();
        assert_eq!(fb.scheme(), Scheme::Crs);
        assert_eq!(fb.sharded().kernel_isa(), fb.report().kernel_isa);
        if IsaLevel::detect() > IsaLevel::Scalar {
            assert!(
                fb.report().kernel_isa > IsaLevel::Scalar,
                "CRS-halves fallback must restore the vector path"
            );
            assert!(fb
                .report()
                .rationale
                .iter()
                .any(|r| r.contains("CRS-halves fallback restores the vector path")));
        }
        let mut y2 = vec![0.0; n];
        fb.spmv(&x, &mut y2);
        within_eps(&y2, "jds-fallback sharded", fb.report().kernel_isa);
    }

    #[test]
    fn build_rejects_a_dangling_shard_policy() {
        let coo = gen::laplacian_1d(64);
        let err = SpmvContext::builder(&coo)
            .policy(TuningPolicy::Fixed(Scheme::Crs, Schedule::Static { chunk: None }))
            .sharded(ShardPolicy::Heuristic)
            .build();
        assert!(err.is_err(), "build() must reject a builder with a shard policy");
    }
}
