//! Real Holstein-Hubbard Hamiltonian generator — the paper's test matrix
//! (§4.2, Fig 5).
//!
//! 1D chain of `L` sites with `N↑`/`N↓` electrons and a phonon Fock space
//! truncated to at most `M` phonons in total:
//!
//! ```text
//! H = -t   Σ_{<i,j>,σ} (c†_{iσ} c_{jσ} + h.c.)        electron hopping
//!     + U   Σ_i  n_{i↑} n_{i↓}                         Hubbard repulsion
//!     + ω₀  Σ_i  b†_i b_i                              free phonons
//!     - g ω₀ Σ_i (b†_i + b_i)(n_{i↑} + n_{i↓})         Holstein coupling
//! ```
//!
//! Basis: |up mask⟩ ⊗ |down mask⟩ ⊗ |phonon occupation⟩, index
//! `(up, down) electron-major, phonon minor` — electron hops then land on
//! far secondary diagonals and local phonon excitations near the main
//! diagonal, reproducing the split structure of Fig 5 (a few rather dense
//! secondary diagonals plus a scattered band).
//!
//! The paper's matrix is exactly `L=6, N↑=N↓=3, M=8`:
//! `C(6,3)² · C(14,8) = 1,201,200` rows.

use super::basis::{BosonBasis, FermionBasis};
use crate::matrix::Coo;

/// Model and truncation parameters.
#[derive(Debug, Clone, Copy)]
pub struct HolsteinHubbardParams {
    /// Chain length L.
    pub sites: usize,
    /// Number of spin-up electrons.
    pub n_up: usize,
    /// Number of spin-down electrons.
    pub n_down: usize,
    /// Maximum total phonon number M.
    pub max_phonons: usize,
    /// Hopping amplitude t.
    pub t: f64,
    /// Hubbard repulsion U.
    pub u: f64,
    /// Dimensionless electron-phonon coupling g.
    pub g: f64,
    /// Phonon frequency ω₀.
    pub omega: f64,
    /// Periodic boundary conditions?
    pub periodic: bool,
}

impl HolsteinHubbardParams {
    /// The paper's configuration (Fig 5): N = 1,201,200.
    pub fn paper() -> Self {
        Self {
            sites: 6,
            n_up: 3,
            n_down: 3,
            max_phonons: 8,
            t: 1.0,
            u: 4.0,
            g: 1.0,
            omega: 1.0,
            periodic: true,
        }
    }

    /// A scaled-down configuration for fast experiments
    /// (L=6, 3↑3↓, M=4: N = 400 · 210 = 84,000).
    pub fn small() -> Self {
        Self { max_phonons: 4, ..Self::paper() }
    }

    /// Intermediate scale (L=6, 3↑3↓, M=6: N = 400 · 924 = 369,600,
    /// ~5M nnz). Large enough that one sweep over the result vector per
    /// jagged diagonal exceeds every simulated LLC — the regime where
    /// the paper's CRS-vs-JDS gap appears.
    pub fn medium() -> Self {
        Self { max_phonons: 6, ..Self::paper() }
    }

    /// A tiny configuration for unit tests
    /// (L=4, 2↑2↓, M=2: N = 36 · 15 = 540).
    pub fn tiny() -> Self {
        Self {
            sites: 4,
            n_up: 2,
            n_down: 2,
            max_phonons: 2,
            t: 1.0,
            u: 4.0,
            g: 0.5,
            omega: 1.0,
            periodic: true,
        }
    }

    /// Hilbert-space dimension.
    pub fn dimension(&self) -> usize {
        let up = FermionBasis::new(self.sites, self.n_up);
        let dn = FermionBasis::new(self.sites, self.n_down);
        let ph = BosonBasis::new(self.sites, self.max_phonons);
        up.len() * dn.len() * ph.len()
    }
}

/// Hop bonds of the chain: (i, i+1) plus the wrap bond under PBC.
fn bonds(sites: usize, periodic: bool) -> Vec<(usize, usize)> {
    let mut b: Vec<(usize, usize)> = (0..sites.saturating_sub(1)).map(|i| (i, i + 1)).collect();
    if periodic && sites > 2 {
        b.push((sites - 1, 0));
    }
    b
}

/// Fermionic sign for c†_a c_b acting on `mask` (a ≠ b, b occupied, a
/// empty): (-1)^(number of occupied sites strictly between a and b in the
/// canonical site ordering).
#[inline]
fn hop_sign(mask: u64, a: usize, b: usize) -> f64 {
    let (lo, hi) = if a < b { (a, b) } else { (b, a) };
    let between = if hi - lo <= 1 {
        0
    } else {
        let m = ((1u64 << hi) - 1) & !((1u64 << (lo + 1)) - 1);
        (mask & m).count_ones()
    };
    if between % 2 == 0 {
        1.0
    } else {
        -1.0
    }
}

/// Generate the Hamiltonian as COO (both triangles stored; the matrix is
/// real symmetric). Entry order is row-major after `normalize`.
pub fn holstein_hubbard(p: &HolsteinHubbardParams) -> Coo {
    let up = FermionBasis::new(p.sites, p.n_up);
    let dn = FermionBasis::new(p.sites, p.n_down);
    let ph = BosonBasis::new(p.sites, p.max_phonons);
    let (nu, nd, np) = (up.len(), dn.len(), ph.len());
    let dim = nu * nd * np;
    let bonds = bonds(p.sites, p.periodic);

    // Pre-unrank the small electron bases.
    let up_masks: Vec<u64> = (0..nu).map(|r| up.unrank(r)).collect();
    let dn_masks: Vec<u64> = (0..nd).map(|r| dn.unrank(r)).collect();

    // Rough nnz estimate for preallocation: diagonal + hops + phonon terms.
    let est = dim * (1 + 2 * bonds.len() + p.sites);
    let mut coo = Coo::with_capacity(dim, dim, est);

    let index = |u: usize, d: usize, q: usize| -> usize { (u * nd + d) * np + q };

    let mut occ = vec![0usize; p.sites];
    let mut occ2 = vec![0usize; p.sites];
    for q in 0..np {
        ph.unrank(q, &mut occ);
        let n_ph_total: usize = occ.iter().sum();
        for (u, &um) in up_masks.iter().enumerate() {
            for (d, &dm) in dn_masks.iter().enumerate() {
                let row = index(u, d, q);

                // --- diagonal: Hubbard U + free phonons ---
                let docc = (um & dm).count_ones() as f64;
                let diag = p.u * docc + p.omega * n_ph_total as f64;
                if diag != 0.0 {
                    coo.push(row, row, diag);
                }

                // --- electron hopping (same phonon state) ---
                // -t (c†_a c_b + c†_b c_a) for each bond (a,b), each spin.
                for &(a, b) in bonds.iter().filter(|_| p.t != 0.0) {
                    // spin up
                    for (from, to) in [(a, b), (b, a)] {
                        if um >> from & 1 == 1 && um >> to & 1 == 0 {
                            let nm = um & !(1u64 << from) | (1u64 << to);
                            let col = index(up.rank(nm), d, q);
                            coo.push(row, col, -p.t * hop_sign(um, to, from));
                        }
                        if dm >> from & 1 == 1 && dm >> to & 1 == 0 {
                            let nm = dm & !(1u64 << from) | (1u64 << to);
                            let col = index(u, dn.rank(nm), q);
                            coo.push(row, col, -p.t * hop_sign(dm, to, from));
                        }
                    }
                }

                // --- Holstein coupling: -g ω₀ (b†_i + b_i) n_i ---
                if p.g != 0.0 {
                    for i in 0..p.sites {
                        let n_el =
                            (um >> i & 1) as f64 + (dm >> i & 1) as f64;
                        if n_el == 0.0 {
                            continue;
                        }
                        // b†_i: m_i -> m_i + 1 (if total budget allows)
                        if n_ph_total < p.max_phonons {
                            occ2.copy_from_slice(&occ);
                            occ2[i] += 1;
                            let q2 = ph.rank(&occ2);
                            let amp = -p.g * p.omega * ((occ[i] + 1) as f64).sqrt() * n_el;
                            coo.push(row, index(u, d, q2), amp);
                        }
                        // b_i: m_i -> m_i - 1
                        if occ[i] > 0 {
                            occ2.copy_from_slice(&occ);
                            occ2[i] -= 1;
                            let q2 = ph.rank(&occ2);
                            let amp = -p.g * p.omega * (occ[i] as f64).sqrt() * n_el;
                            coo.push(row, index(u, d, q2), amp);
                        }
                    }
                }
            }
        }
    }
    coo.normalize();
    // Exact cancellations (and t = 0 bonds) leave explicit zeros behind.
    coo.prune_zeros();
    coo
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::{Crs, SpMv};

    #[test]
    fn tiny_dimension_and_symmetry() {
        let p = HolsteinHubbardParams::tiny();
        assert_eq!(p.dimension(), 540);
        let h = holstein_hubbard(&p);
        assert_eq!(h.nrows, 540);
        assert!(h.is_symmetric(), "Hamiltonian must be symmetric");
    }

    #[test]
    fn diagonal_only_when_t_and_g_vanish() {
        let p = HolsteinHubbardParams {
            t: 0.0,
            g: 0.0,
            ..HolsteinHubbardParams::tiny()
        };
        let h = holstein_hubbard(&p);
        assert!(h.entries.iter().all(|&(r, c, _)| r == c));
        // Eigenvalues are then U*docc + omega*n_ph; the minimum over the
        // tiny basis (2 up, 2 down on 4 sites) is 0 (no double occupancy,
        // no phonons) and the maximum is 2U + M*omega.
        let diag: Vec<f64> = {
            let d = h.to_dense();
            (0..h.nrows).map(|i| d[i][i]).collect()
        };
        let min = diag.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = diag.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        assert_eq!(min, 0.0);
        assert_eq!(max, 2.0 * 4.0 + 2.0 * 1.0);
    }

    #[test]
    fn hubbard_dimer_spectrum() {
        // 2-site Hubbard (no phonons), 1 up + 1 down: the singlet sector
        // gives ground energy (U - sqrt(U^2 + 16 t^2)) / 2.
        let p = HolsteinHubbardParams {
            sites: 2,
            n_up: 1,
            n_down: 1,
            max_phonons: 0,
            t: 1.0,
            u: 3.0,
            g: 0.0,
            omega: 1.0,
            periodic: false,
        };
        assert_eq!(p.dimension(), 4);
        let h = holstein_hubbard(&p);
        let d = h.to_dense();
        // Exact ground state by dense eigen decomposition of the 4x4:
        // use the known closed form instead of an eigensolver here.
        let expect = (3.0 - (9.0f64 + 16.0).sqrt()) / 2.0;
        // power iteration on (shift - H) to find the lowest eigenvalue
        let shift = 10.0;
        let mut v = vec![1.0, 0.3, -0.2, 0.5];
        let n = 4;
        for _ in 0..2000 {
            let mut w = vec![0.0; n];
            for i in 0..n {
                let mut s = shift * v[i];
                for j in 0..n {
                    s -= d[i][j] * v[j];
                }
                w[i] = s;
            }
            let norm = w.iter().map(|x| x * x).sum::<f64>().sqrt();
            for i in 0..n {
                v[i] = w[i] / norm;
            }
        }
        let mut hv = vec![0.0; n];
        for i in 0..n {
            hv[i] = (0..n).map(|j| d[i][j] * v[j]).sum();
        }
        let e0: f64 = v.iter().zip(&hv).map(|(a, b)| a * b).sum();
        assert!((e0 - expect).abs() < 1e-8, "E0 {e0} vs exact {expect}");
    }

    #[test]
    fn hop_signs_antisymmetric_consistency() {
        // H must be symmetric even with nontrivial fermionic signs (PBC
        // wrap bond crosses occupied sites).
        let p = HolsteinHubbardParams {
            sites: 5,
            n_up: 2,
            n_down: 1,
            max_phonons: 1,
            t: 0.7,
            u: 2.0,
            g: 0.3,
            omega: 0.9,
            periodic: true,
        };
        let h = holstein_hubbard(&p);
        assert!(h.is_symmetric());
    }

    #[test]
    fn spmv_against_dense() {
        let p = HolsteinHubbardParams {
            sites: 3,
            n_up: 1,
            n_down: 1,
            max_phonons: 2,
            ..HolsteinHubbardParams::tiny()
        };
        let h = holstein_hubbard(&p);
        let crs = Crs::from_coo(&h);
        let n = h.nrows;
        let d = h.to_dense();
        let mut rng = crate::util::rng::Rng::new(5);
        let mut x = vec![0.0; n];
        rng.fill_f64(&mut x, -1.0, 1.0);
        let mut y = vec![0.0; n];
        crs.spmv(&x, &mut y);
        for i in 0..n {
            let want: f64 = (0..n).map(|j| d[i][j] * x[j]).sum();
            assert!((y[i] - want).abs() < 1e-10);
        }
    }

    #[test]
    fn average_nnz_per_row_is_paperlike() {
        // The paper reports ~14 nnz/row on average at full scale; the
        // small config should be in the same regime (order 10).
        let p = HolsteinHubbardParams::tiny();
        let h = holstein_hubbard(&p);
        let avg = h.nnz() as f64 / h.nrows as f64;
        assert!(avg > 5.0 && avg < 25.0, "avg nnz/row = {avg}");
    }

    #[test]
    fn phonon_number_conservation_structure() {
        // With g = 0, phonon occupation is conserved: no entries between
        // different phonon configurations.
        let p = HolsteinHubbardParams { g: 0.0, ..HolsteinHubbardParams::tiny() };
        let h = holstein_hubbard(&p);
        let np = BosonBasis::new(p.sites, p.max_phonons).len();
        for &(r, c, _) in &h.entries {
            assert_eq!(r as usize % np, c as usize % np, "phonon block must be preserved");
        }
    }
}
