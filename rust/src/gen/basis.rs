//! Combinatorial ranking/unranking utilities for many-body basis
//! enumeration: fermion occupation bitmasks (fixed particle number) and
//! truncated bosonic Fock configurations (total occupation bounded).

/// Binomial coefficient table (Pascal's triangle), sized for the largest
/// (n, k) needed. Values as u64 (dimensions here stay far below 2^63).
#[derive(Debug, Clone)]
pub struct Binomials {
    n_max: usize,
    c: Vec<u64>,
}

impl Binomials {
    pub fn new(n_max: usize) -> Self {
        let mut c = vec![0u64; (n_max + 1) * (n_max + 1)];
        for n in 0..=n_max {
            c[n * (n_max + 1)] = 1;
            for k in 1..=n {
                let up = (n - 1) * (n_max + 1);
                c[n * (n_max + 1) + k] = c[up + k - 1]
                    .checked_add(if k <= n - 1 { c[up + k] } else { 0 })
                    .expect("binomial overflow");
            }
        }
        Self { n_max, c }
    }

    #[inline]
    pub fn get(&self, n: usize, k: usize) -> u64 {
        if k > n || n > self.n_max {
            return 0;
        }
        self.c[n * (self.n_max + 1) + k]
    }
}

/// Enumeration of `n_bits`-bit masks with exactly `n_set` bits set, in
/// lexicographic (numeric) order, with O(bits) rank/unrank.
#[derive(Debug, Clone)]
pub struct FermionBasis {
    pub n_bits: usize,
    pub n_set: usize,
    bin: Binomials,
}

impl FermionBasis {
    pub fn new(n_bits: usize, n_set: usize) -> Self {
        assert!(n_set <= n_bits && n_bits <= 62);
        Self { n_bits, n_set, bin: Binomials::new(n_bits) }
    }

    /// Number of states: C(n_bits, n_set).
    pub fn len(&self) -> usize {
        self.bin.get(self.n_bits, self.n_set) as usize
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Rank of a mask among all masks with the same popcount, numeric
    /// ascending order (combinadic).
    pub fn rank(&self, mask: u64) -> usize {
        debug_assert_eq!(mask.count_ones() as usize, self.n_set);
        let mut rank = 0u64;
        let mut seen = 0usize; // set bits encountered so far (from LSB)
        for b in 0..self.n_bits {
            if mask >> b & 1 == 1 {
                seen += 1;
            } else if seen < self.n_set {
                // A state with a set bit here (instead of a later one)
                // would precede; count masks with (n_set - seen) bits
                // among the remaining higher positions... handled via the
                // standard combinadic formula below instead.
            }
        }
        // Standard combinadic: mask = {p_1 < p_2 < ... < p_k} ranks as
        // sum C(p_i, i).
        let mut m = mask;
        let mut i = 1usize;
        while m != 0 {
            let p = m.trailing_zeros() as usize;
            rank += self.bin.get(p, i);
            i += 1;
            m &= m - 1;
        }
        let _ = seen;
        rank as usize
    }

    /// Inverse of [`FermionBasis::rank`].
    pub fn unrank(&self, mut rank: usize) -> u64 {
        let mut mask = 0u64;
        let mut k = self.n_set;
        let mut r = rank as u64;
        while k > 0 {
            // Largest p with C(p, k) <= r.
            let mut p = k - 1;
            while self.bin.get(p + 1, k) <= r {
                p += 1;
            }
            mask |= 1u64 << p;
            r -= self.bin.get(p, k);
            k -= 1;
        }
        rank = r as usize;
        debug_assert_eq!(rank, 0);
        mask
    }
}

/// Truncated bosonic Fock basis: occupation vectors `(m_0..m_{sites-1})`
/// with `sum m_i <= max_total`, ranked lexicographically (site 0 most
/// significant). Dimension `C(sites + max_total, max_total)`.
#[derive(Debug, Clone)]
pub struct BosonBasis {
    pub sites: usize,
    pub max_total: usize,
    bin: Binomials,
}

impl BosonBasis {
    pub fn new(sites: usize, max_total: usize) -> Self {
        Self { sites, max_total, bin: Binomials::new(sites + max_total) }
    }

    /// Number of configurations with total occupation <= budget over
    /// `sites_left` sites: C(sites_left + budget, sites_left).
    #[inline]
    fn count(&self, sites_left: usize, budget: usize) -> u64 {
        self.bin.get(sites_left + budget, sites_left)
    }

    pub fn len(&self) -> usize {
        self.count(self.sites, self.max_total) as usize
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Rank of an occupation vector.
    pub fn rank(&self, occ: &[usize]) -> usize {
        debug_assert_eq!(occ.len(), self.sites);
        let mut rank = 0u64;
        let mut budget = self.max_total;
        for (i, &m) in occ.iter().enumerate() {
            debug_assert!(m <= budget, "occupation exceeds truncation");
            let sites_left = self.sites - 1 - i;
            // All configs with a smaller value at site i come first.
            for v in 0..m {
                rank += self.count(sites_left, budget - v);
            }
            budget -= m;
        }
        rank as usize
    }

    /// Inverse of [`BosonBasis::rank`]; writes into `occ`.
    pub fn unrank(&self, mut rank: usize, occ: &mut [usize]) {
        debug_assert_eq!(occ.len(), self.sites);
        let mut budget = self.max_total;
        for i in 0..self.sites {
            let sites_left = self.sites - 1 - i;
            let mut v = 0usize;
            loop {
                let block = self.count(sites_left, budget - v) as usize;
                if rank < block {
                    break;
                }
                rank -= block;
                v += 1;
            }
            occ[i] = v;
            budget -= v;
        }
        debug_assert_eq!(rank, 0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn binomials_basic() {
        let b = Binomials::new(20);
        assert_eq!(b.get(6, 3), 20);
        assert_eq!(b.get(14, 8), 3003);
        assert_eq!(b.get(0, 0), 1);
        assert_eq!(b.get(5, 7), 0);
        assert_eq!(b.get(20, 10), 184_756);
    }

    #[test]
    fn fermion_rank_unrank_roundtrip() {
        let fb = FermionBasis::new(6, 3);
        assert_eq!(fb.len(), 20);
        let mut masks: Vec<u64> = Vec::new();
        for r in 0..fb.len() {
            let m = fb.unrank(r);
            assert_eq!(m.count_ones(), 3);
            assert_eq!(fb.rank(m), r);
            masks.push(m);
        }
        // ranks are in ascending numeric mask order
        assert!(masks.windows(2).all(|w| w[0] < w[1]));
        // all distinct
        let mut s = masks.clone();
        s.dedup();
        assert_eq!(s.len(), 20);
    }

    #[test]
    fn fermion_edge_cases() {
        let all = FermionBasis::new(5, 5);
        assert_eq!(all.len(), 1);
        assert_eq!(all.unrank(0), 0b11111);
        let none = FermionBasis::new(5, 0);
        assert_eq!(none.len(), 1);
        assert_eq!(none.unrank(0), 0);
    }

    #[test]
    fn boson_rank_unrank_roundtrip() {
        let bb = BosonBasis::new(3, 4);
        assert_eq!(bb.len(), 35); // C(7,3)
        let mut occ = vec![0usize; 3];
        for r in 0..bb.len() {
            bb.unrank(r, &mut occ);
            assert!(occ.iter().sum::<usize>() <= 4);
            assert_eq!(bb.rank(&occ), r);
        }
    }

    #[test]
    fn boson_paper_dimension() {
        // The paper's phonon sector: 6 sites, <= 8 phonons -> C(14,8)=3003.
        let bb = BosonBasis::new(6, 8);
        assert_eq!(bb.len(), 3003);
    }

    #[test]
    fn boson_lex_order() {
        let bb = BosonBasis::new(2, 2);
        // Lexicographic (site 0 major): (0,0),(0,1),(0,2),(1,0),(1,1),(2,0)
        let expected: Vec<Vec<usize>> =
            vec![vec![0, 0], vec![0, 1], vec![0, 2], vec![1, 0], vec![1, 1], vec![2, 0]];
        let mut occ = vec![0; 2];
        for (r, e) in expected.iter().enumerate() {
            bb.unrank(r, &mut occ);
            assert_eq!(&occ, e, "rank {r}");
        }
    }

    #[test]
    fn paper_total_dimension() {
        // N = C(6,3)^2 * C(14,8) = 20 * 20 * 3003 = 1,201,200 (Fig 5).
        let f = FermionBasis::new(6, 3);
        let b = BosonBasis::new(6, 8);
        assert_eq!(f.len() * f.len() * b.len(), 1_201_200);
    }
}
