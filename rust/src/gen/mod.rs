//! Matrix generators: the paper's Holstein-Hubbard Hamiltonian
//! ([`holstein_hubbard`]) built on combinatorial basis enumeration
//! ([`basis`]), plus synthetic workloads ([`synthetic`]).

pub mod basis;
pub mod holstein_hubbard;
pub mod synthetic;

pub use holstein_hubbard::{holstein_hubbard, HolsteinHubbardParams};
pub use synthetic::{
    banded, laplacian_1d, laplacian_2d, power_law, random_band, random_square, rmat,
};
