//! Auxiliary matrix generators: discrete Laplacians, banded matrices,
//! and random matrices with controlled sparsity — workloads for the
//! microbenchmarks and extra examples beyond the paper's Hamiltonian.

use crate::matrix::Coo;
use crate::util::rng::Rng;

/// 2D 5-point Laplacian stencil on an `nx × ny` grid (Dirichlet
/// boundaries): the classic PDE test matrix, dimension `nx*ny`.
pub fn laplacian_2d(nx: usize, ny: usize) -> Coo {
    let n = nx * ny;
    let mut coo = Coo::with_capacity(n, n, 5 * n);
    let idx = |i: usize, j: usize| i * ny + j;
    for i in 0..nx {
        for j in 0..ny {
            let r = idx(i, j);
            coo.push(r, r, 4.0);
            if i > 0 {
                coo.push(r, idx(i - 1, j), -1.0);
            }
            if i + 1 < nx {
                coo.push(r, idx(i + 1, j), -1.0);
            }
            if j > 0 {
                coo.push(r, idx(i, j - 1), -1.0);
            }
            if j + 1 < ny {
                coo.push(r, idx(i, j + 1), -1.0);
            }
        }
    }
    coo.normalize();
    coo
}

/// 1D Laplacian (tridiagonal), dimension `n`.
pub fn laplacian_1d(n: usize) -> Coo {
    let mut coo = Coo::with_capacity(n, n, 3 * n);
    for i in 0..n {
        coo.push(i, i, 2.0);
        if i > 0 {
            coo.push(i, i - 1, -1.0);
        }
        if i + 1 < n {
            coo.push(i, i + 1, -1.0);
        }
    }
    coo.normalize();
    coo
}

/// Dense band matrix: all entries within `|i-j| <= half_bandwidth` filled
/// with deterministic nonzeros (symmetric positive-ish values).
pub fn banded(n: usize, half_bandwidth: usize, rng: &mut Rng) -> Coo {
    let mut coo = Coo::new(n, n);
    for i in 0..n {
        let lo = i.saturating_sub(half_bandwidth);
        let hi = (i + half_bandwidth).min(n - 1);
        for j in lo..=hi {
            if j >= i {
                let v = if i == j { 4.0 } else { rng.f64() - 0.5 };
                coo.push(i, j, v);
                if j != i {
                    coo.push(j, i, v);
                }
            }
        }
    }
    coo.normalize();
    coo
}

/// Random symmetric matrix with ~`nnz_per_row` non-zeros per row spread
/// uniformly inside a band of half-width `half_bandwidth` (the "scattered
/// band" component of the paper's Fig 5 structure, in isolation).
pub fn random_band(n: usize, nnz_per_row: usize, half_bandwidth: usize, rng: &mut Rng) -> Coo {
    let mut coo = Coo::new(n, n);
    for i in 0..n {
        coo.push(i, i, 2.0 + rng.f64());
        // upper-triangle draws, mirrored
        for _ in 0..nnz_per_row / 2 {
            let span = half_bandwidth.min(n - 1 - i);
            if span == 0 {
                continue;
            }
            let j = i + 1 + rng.index(span);
            let v = rng.f64() - 0.5;
            coo.push(i, j, v);
            coo.push(j, i, v);
        }
    }
    coo.normalize();
    coo
}

/// Random Erdős–Rényi-style square matrix (not symmetric): `nnz` entries
/// uniformly at random. Used for format stress tests.
pub fn random_square(n: usize, nnz: usize, rng: &mut Rng) -> Coo {
    let mut coo = Coo::new(n, n);
    for _ in 0..nnz {
        coo.push(rng.index(n), rng.index(n), rng.f64() * 2.0 - 1.0);
    }
    coo.normalize();
    coo
}

/// Scale-free/power-law graph adjacency matrix. Row `i`'s out-degree
/// follows a Zipf profile `(i+1)^(-1/(exponent-1))`, giving a degree
/// distribution with tail exponent ≈ `exponent` (web/social graphs sit
/// in (2, 3]); column endpoints are drawn preferentially toward the
/// low-index hubs. The extreme row imbalance is the point: it exercises
/// dynamic/guided schedules and the backend arbitration in ways band
/// matrices never do. Entries are positive so a row-stochastic
/// normalization (PageRank's transition matrix) is well-defined.
pub fn power_law(n: usize, avg_nnz: usize, exponent: f64, rng: &mut Rng) -> Coo {
    assert!(n > 0 && exponent > 1.0, "need n > 0 and exponent > 1");
    let alpha = 1.0 / (exponent - 1.0);
    let weights: Vec<f64> = (0..n).map(|i| ((i + 1) as f64).powf(-alpha)).collect();
    let total: f64 = weights.iter().sum();
    let budget = (n * avg_nnz) as f64;
    let mut coo = Coo::with_capacity(n, n, n * avg_nnz + n);
    for (i, w) in weights.iter().enumerate() {
        let degree = ((budget * w / total).round() as usize).clamp(1, n);
        for _ in 0..degree {
            // Preferential endpoint draw: u^(1+alpha) concentrates
            // columns on the low-index hubs without an alias table.
            // Duplicate (i, j) draws are summed by `normalize`.
            let j = ((n as f64) * rng.f64().powf(1.0 + alpha)) as usize;
            coo.push(i, j.min(n - 1), 0.5 + rng.f64());
        }
    }
    coo.normalize();
    coo
}

/// RMAT-style recursive matrix (the Graph500 generator family):
/// `1 << scale` rows, `edge_factor` edges per row, each edge placed by
/// recursively descending into quadrants with probabilities
/// `(a, b, c, d)` (must sum to 1; the classic skewed setting is
/// `(0.57, 0.19, 0.19, 0.05)`). Duplicate edges are summed by
/// [`Coo::normalize`], so realized nnz sits slightly below
/// `edge_factor << scale` on skewed settings.
pub fn rmat(scale: u32, edge_factor: usize, probs: (f64, f64, f64, f64), rng: &mut Rng) -> Coo {
    let (pa, pb, pc, pd) = probs;
    let sum = pa + pb + pc + pd;
    assert!((sum - 1.0).abs() < 1e-6, "quadrant probabilities must sum to 1, got {sum}");
    let n = 1usize << scale;
    let mut coo = Coo::with_capacity(n, n, edge_factor * n);
    for _ in 0..edge_factor * n {
        let (mut row, mut col) = (0usize, 0usize);
        let mut half = n >> 1;
        while half > 0 {
            let u = rng.f64();
            if u < pa {
                // top-left: nothing to add
            } else if u < pa + pb {
                col += half;
            } else if u < pa + pb + pc {
                row += half;
            } else {
                row += half;
                col += half;
            }
            half >>= 1;
        }
        coo.push(row, col, 0.5 + rng.f64());
    }
    coo.normalize();
    coo
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::SpMv;

    #[test]
    fn laplacian_2d_structure() {
        let m = laplacian_2d(4, 5);
        assert_eq!(m.nrows, 20);
        assert!(m.is_symmetric());
        // interior rows have 5 entries
        let counts = m.row_counts();
        assert_eq!(*counts.iter().max().unwrap(), 5);
        assert_eq!(*counts.iter().min().unwrap(), 3); // corners
        // row sums: interior rows sum to 0, boundary rows > 0
        let d = m.to_dense();
        let sums: Vec<f64> = d.iter().map(|r| r.iter().sum()).collect();
        assert!(sums.iter().all(|&s| s >= -1e-12));
    }

    #[test]
    fn laplacian_1d_is_tridiagonal() {
        let m = laplacian_1d(10);
        assert_eq!(m.nnz(), 28);
        assert!(m.is_symmetric());
        for &(r, c, _) in &m.entries {
            assert!((r as i64 - c as i64).abs() <= 1);
        }
    }

    #[test]
    fn banded_is_symmetric_with_bounded_band() {
        let mut rng = Rng::new(8);
        let m = banded(30, 3, &mut rng);
        assert!(m.is_symmetric());
        for &(r, c, _) in &m.entries {
            assert!((r as i64 - c as i64).abs() <= 3);
        }
    }

    #[test]
    fn random_band_respects_band_and_symmetry() {
        let mut rng = Rng::new(9);
        let m = random_band(200, 8, 40, &mut rng);
        assert!(m.is_symmetric());
        for &(r, c, _) in &m.entries {
            assert!((r as i64 - c as i64).abs() <= 40);
        }
        let avg = m.nnz() as f64 / m.nrows as f64;
        assert!(avg > 4.0 && avg < 12.0, "avg {avg}");
    }

    #[test]
    fn generators_spmv_smoke() {
        let mut rng = Rng::new(10);
        for m in [
            laplacian_2d(6, 6),
            laplacian_1d(36),
            banded(36, 2, &mut rng),
            random_square(36, 200, &mut rng),
            power_law(36, 4, 2.3, &mut rng),
        ] {
            let x = vec![1.0; 36];
            let mut y = vec![0.0; 36];
            m.spmv(&x, &mut y);
            assert!(y.iter().all(|v| v.is_finite()));
        }
    }

    #[test]
    fn power_law_has_heavy_tail_row_imbalance() {
        let mut rng = Rng::new(11);
        let m = power_law(400, 8, 2.2, &mut rng);
        assert_eq!(m.nrows, 400);
        let counts = m.row_counts();
        let max = *counts.iter().max().unwrap() as f64;
        let avg = m.nnz() as f64 / m.nrows as f64;
        assert!(max > 4.0 * avg, "hub row {max} vs avg {avg}: no heavy tail");
        assert!(counts.iter().all(|&c| c >= 1), "every row keeps at least one entry");
        // Positive entries: a row-stochastic normalization exists.
        assert!(m.entries.iter().all(|&(_, _, v)| v > 0.0));
    }

    #[test]
    fn rmat_is_skewed_and_power_of_two_sized() {
        let mut rng = Rng::new(12);
        let m = rmat(7, 8, (0.57, 0.19, 0.19, 0.05), &mut rng);
        assert_eq!(m.nrows, 128);
        assert!(m.nnz() > 0 && m.nnz() <= 8 * 128);
        let counts = m.row_counts();
        let max = *counts.iter().max().unwrap() as f64;
        let avg = m.nnz() as f64 / m.nrows as f64;
        assert!(max > 2.0 * avg, "rmat quadrant skew should create hub rows");
        let x = vec![1.0; 128];
        let mut y = vec![0.0; 128];
        m.spmv(&x, &mut y);
        assert!(y.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn graph_generators_are_deterministic_by_seed() {
        let a = power_law(120, 6, 2.5, &mut Rng::new(42));
        let b = power_law(120, 6, 2.5, &mut Rng::new(42));
        assert_eq!(a.entries, b.entries);
        let c = rmat(6, 8, (0.57, 0.19, 0.19, 0.05), &mut Rng::new(42));
        let d = rmat(6, 8, (0.57, 0.19, 0.19, 0.05), &mut Rng::new(42));
        assert_eq!(c.entries, d.entries);
    }
}
