//! Auxiliary matrix generators: discrete Laplacians, banded matrices,
//! and random matrices with controlled sparsity — workloads for the
//! microbenchmarks and extra examples beyond the paper's Hamiltonian.

use crate::matrix::Coo;
use crate::util::rng::Rng;

/// 2D 5-point Laplacian stencil on an `nx × ny` grid (Dirichlet
/// boundaries): the classic PDE test matrix, dimension `nx*ny`.
pub fn laplacian_2d(nx: usize, ny: usize) -> Coo {
    let n = nx * ny;
    let mut coo = Coo::with_capacity(n, n, 5 * n);
    let idx = |i: usize, j: usize| i * ny + j;
    for i in 0..nx {
        for j in 0..ny {
            let r = idx(i, j);
            coo.push(r, r, 4.0);
            if i > 0 {
                coo.push(r, idx(i - 1, j), -1.0);
            }
            if i + 1 < nx {
                coo.push(r, idx(i + 1, j), -1.0);
            }
            if j > 0 {
                coo.push(r, idx(i, j - 1), -1.0);
            }
            if j + 1 < ny {
                coo.push(r, idx(i, j + 1), -1.0);
            }
        }
    }
    coo.normalize();
    coo
}

/// 1D Laplacian (tridiagonal), dimension `n`.
pub fn laplacian_1d(n: usize) -> Coo {
    let mut coo = Coo::with_capacity(n, n, 3 * n);
    for i in 0..n {
        coo.push(i, i, 2.0);
        if i > 0 {
            coo.push(i, i - 1, -1.0);
        }
        if i + 1 < n {
            coo.push(i, i + 1, -1.0);
        }
    }
    coo.normalize();
    coo
}

/// Dense band matrix: all entries within `|i-j| <= half_bandwidth` filled
/// with deterministic nonzeros (symmetric positive-ish values).
pub fn banded(n: usize, half_bandwidth: usize, rng: &mut Rng) -> Coo {
    let mut coo = Coo::new(n, n);
    for i in 0..n {
        let lo = i.saturating_sub(half_bandwidth);
        let hi = (i + half_bandwidth).min(n - 1);
        for j in lo..=hi {
            if j >= i {
                let v = if i == j { 4.0 } else { rng.f64() - 0.5 };
                coo.push(i, j, v);
                if j != i {
                    coo.push(j, i, v);
                }
            }
        }
    }
    coo.normalize();
    coo
}

/// Random symmetric matrix with ~`nnz_per_row` non-zeros per row spread
/// uniformly inside a band of half-width `half_bandwidth` (the "scattered
/// band" component of the paper's Fig 5 structure, in isolation).
pub fn random_band(n: usize, nnz_per_row: usize, half_bandwidth: usize, rng: &mut Rng) -> Coo {
    let mut coo = Coo::new(n, n);
    for i in 0..n {
        coo.push(i, i, 2.0 + rng.f64());
        // upper-triangle draws, mirrored
        for _ in 0..nnz_per_row / 2 {
            let span = half_bandwidth.min(n - 1 - i);
            if span == 0 {
                continue;
            }
            let j = i + 1 + rng.index(span);
            let v = rng.f64() - 0.5;
            coo.push(i, j, v);
            coo.push(j, i, v);
        }
    }
    coo.normalize();
    coo
}

/// Random Erdős–Rényi-style square matrix (not symmetric): `nnz` entries
/// uniformly at random. Used for format stress tests.
pub fn random_square(n: usize, nnz: usize, rng: &mut Rng) -> Coo {
    let mut coo = Coo::new(n, n);
    for _ in 0..nnz {
        coo.push(rng.index(n), rng.index(n), rng.f64() * 2.0 - 1.0);
    }
    coo.normalize();
    coo
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::SpMv;

    #[test]
    fn laplacian_2d_structure() {
        let m = laplacian_2d(4, 5);
        assert_eq!(m.nrows, 20);
        assert!(m.is_symmetric());
        // interior rows have 5 entries
        let counts = m.row_counts();
        assert_eq!(*counts.iter().max().unwrap(), 5);
        assert_eq!(*counts.iter().min().unwrap(), 3); // corners
        // row sums: interior rows sum to 0, boundary rows > 0
        let d = m.to_dense();
        let sums: Vec<f64> = d.iter().map(|r| r.iter().sum()).collect();
        assert!(sums.iter().all(|&s| s >= -1e-12));
    }

    #[test]
    fn laplacian_1d_is_tridiagonal() {
        let m = laplacian_1d(10);
        assert_eq!(m.nnz(), 28);
        assert!(m.is_symmetric());
        for &(r, c, _) in &m.entries {
            assert!((r as i64 - c as i64).abs() <= 1);
        }
    }

    #[test]
    fn banded_is_symmetric_with_bounded_band() {
        let mut rng = Rng::new(8);
        let m = banded(30, 3, &mut rng);
        assert!(m.is_symmetric());
        for &(r, c, _) in &m.entries {
            assert!((r as i64 - c as i64).abs() <= 3);
        }
    }

    #[test]
    fn random_band_respects_band_and_symmetry() {
        let mut rng = Rng::new(9);
        let m = random_band(200, 8, 40, &mut rng);
        assert!(m.is_symmetric());
        for &(r, c, _) in &m.entries {
            assert!((r as i64 - c as i64).abs() <= 40);
        }
        let avg = m.nnz() as f64 / m.nrows as f64;
        assert!(avg > 4.0 && avg < 12.0, "avg {avg}");
    }

    #[test]
    fn generators_spmv_smoke() {
        let mut rng = Rng::new(10);
        for m in [
            laplacian_2d(6, 6),
            laplacian_1d(36),
            banded(36, 2, &mut rng),
            random_square(36, 200, &mut rng),
        ] {
            let x = vec![1.0; 36];
            let mut y = vec![0.0; 36];
            m.spmv(&x, &mut y);
            assert!(y.iter().all(|v| v.is_finite()));
        }
    }
}
