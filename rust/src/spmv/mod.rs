//! The one public execution surface of the crate: [`SpmvHandle`], an
//! executor-agnostic SpMV facade built by [`SpmvBuilder`].
//!
//! The paper's central lesson is that the best SpMV strategy is a
//! property of the matrix × machine pair, not a user choice. The tuning
//! layer made scheme × (C, σ) × schedule automatic; this module extends
//! the same principle one level up, to the **executor**: the serial
//! kernel, the native parallel engine and the sharded halo-exchange
//! executor are three implementations of one object-safe [`Backend`]
//! trait, and the builder arbitrates between them per matrix — the way
//! Kreutzer et al. (arXiv:1307.6209) unify storage behind one
//! format-agnostic interface and Elafrou et al. (arXiv:1711.05487)
//! select optimizations from a matrix feature fingerprint.
//!
//! ```text
//! SpmvHandle::builder(&coo)
//!     .policy(TuningPolicy::Heuristic)   // scheme × schedule tier
//!     .backend(BackendChoice::Auto)      // executor arbitration tier (default)
//!     .threads(4)
//!     .build()?                          // -> SpmvHandle over Box<dyn Backend>
//! ```
//!
//! Arbitration follows the [`TuningPolicy`] tier:
//!
//! - [`TuningPolicy::Fixed`]: no probing — the native engine serves
//!   (force another backend with [`SpmvBuilder::backend`]);
//! - [`TuningPolicy::Heuristic`]: serial vs native vs sharded scored
//!   from the matrix fingerprints (halo volume / interior work of the
//!   candidate partitions, row-imbalance CV) and
//!   [`crate::perfmodel::predict`], plus rough per-call dispatch costs;
//! - [`TuningPolicy::Measured`]: a cross-backend bake-off on the
//!   existing timing machinery.
//!
//! The [`BackendDecision`] (candidates, scores, rationale) is recorded
//! in the [`TuningReport`], so a handle can always explain which
//! executor serves it and why. A future PJRT executor plugs in as just
//! one more [`Backend`] impl behind [`SpmvHandle::from_backend`].

use std::borrow::Cow;
use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::engine::SpmvPlan;
use crate::kernels::{IsaLevel, Precision, SpmvKernel};
use crate::matrix::shard::ShardedCrs;
use crate::matrix::{Coo, Crs, Scheme, SpMv};
use crate::perfmodel::predict;
use crate::sched::Schedule;
use crate::shard::OverlapMode;
use crate::simulator::MachineSpec;
use crate::tune::{
    self, price_multi, BackendCandidate, BackendDecision, MultiDecision, PlacementDecision,
    ShardPolicy, ShardedContext, SpmvContext, TuningPolicy, TuningReport, SHARD_GRID,
    SHARD_HALO_VIABLE_MAX, SHARD_MIN_ROWS, SHARD_OVERLAP_MIN_INTERIOR,
};
use crate::util::rng::Rng;

/// Rough cost of one fused engine dispatch (worker wakeup + completion
/// latch), charged to the native candidate per SpMV call by the
/// arbitration heuristic.
const NATIVE_DISPATCH_NS: f64 = 20_000.0;

/// Rough per-shard, per-call coordinator cost (parked-role wakeup +
/// completion latch + halo gate; the roles themselves are persistent
/// since the serve PR), charged to the sharded candidate per SpMV call.
/// Sharding only pays once the per-nnz work amortizes this — the reason
/// tiny matrices stay native or serial. Hand-set; the learned-tuning
/// ROADMAP item replaces it with measured data.
const SHARD_DISPATCH_NS: f64 = 60_000.0;

/// The object-safe executor seam: everything a consumer may do with a
/// bound SpMV operator, independent of *how* it multiplies. Implemented
/// by [`Serial`], [`Native`] and [`Sharded`]; a PJRT executor becomes
/// one more impl once real bindings land (ROADMAP).
pub trait Backend {
    /// `"serial"`, `"native"` or `"sharded"`.
    fn name(&self) -> &'static str;
    fn nrows(&self) -> usize;
    fn nnz(&self) -> usize;
    fn scheme(&self) -> Scheme;
    fn schedule(&self) -> Schedule;
    fn n_threads(&self) -> usize;
    /// Original-basis SpMV.
    fn spmv(&self, x: &[f64], y: &mut [f64]);
    /// Batched SpMV — one fused dispatch where the backend supports it.
    fn spmv_batch(&self, xs: &[Vec<f64>]) -> Vec<Vec<f64>>;
    /// Blocked-x SpMM over a column block of `k` vectors: backends with
    /// a fused multi kernel stream the matrix once and reuse each entry
    /// across the block; the default is the per-vector batch (already
    /// correct everywhere, just without the x-reuse traffic win).
    fn spmv_multi(&self, xs: &[Vec<f64>]) -> Vec<Vec<f64>> {
        self.spmv_batch(xs)
    }
    /// Re-partition for a new schedule and re-home workspace buffers
    /// (the §5.2 hazard); the serial backend records the no-op.
    fn rebalance(&mut self, schedule: Schedule);
    /// The tuning + arbitration decision trail.
    fn report(&self) -> &TuningReport;
    fn report_mut(&mut self) -> &mut TuningReport;
    /// Was NUMA placement (pinning + first touch) deployed?
    fn pinned(&self) -> bool {
        false
    }
    /// Shard count (1 for unsharded backends).
    fn n_shards(&self) -> usize {
        1
    }
    /// Overlap mode, for backends that shard.
    fn mode(&self) -> Option<OverlapMode> {
        None
    }
    /// The realized storage kernel, for backends that own exactly one.
    fn kernel(&self) -> Option<&SpmvKernel> {
        None
    }
    /// The scheduling plan, for backends that own exactly one (feeds
    /// [`crate::simulator::simulate_spmv_plan`]).
    fn plan(&self) -> Option<&SpmvPlan> {
        None
    }
    /// Permuted-basis hot path (no gather/scatter, no allocation).
    fn spmv_permuted(&self, _xp: &[f64], _yp: &mut [f64]) -> Result<()> {
        anyhow::bail!("the {} backend has no permuted-basis path", self.name())
    }
    /// Fork a sibling on a new schedule / thread count sharing storage.
    fn replanned(&self, _schedule: Schedule, _n_threads: usize) -> Result<Box<dyn Backend>> {
        anyhow::bail!("the {} backend cannot be replanned", self.name())
    }
    /// Re-shard onto a new shard count / overlap mode, re-homing halo
    /// buffers on the new owners.
    fn reshard(&mut self, _n_shards: usize, _mode: OverlapMode) -> Result<()> {
        anyhow::bail!("the {} backend has no shards", self.name())
    }
}

/// Serial backend: the chosen scheme's kernel executed inline on the
/// calling thread — no plan, no engine, no dispatch cost. Wins on
/// matrices small enough that one parallel dispatch costs more than the
/// whole multiply.
pub struct Serial {
    kernel: Arc<SpmvKernel>,
    report: TuningReport,
}

impl Backend for Serial {
    fn name(&self) -> &'static str {
        "serial"
    }
    fn nrows(&self) -> usize {
        self.kernel.nrows()
    }
    fn nnz(&self) -> usize {
        self.kernel.nnz()
    }
    fn scheme(&self) -> Scheme {
        self.kernel.scheme()
    }
    fn schedule(&self) -> Schedule {
        self.report.schedule
    }
    fn n_threads(&self) -> usize {
        1
    }
    fn spmv(&self, x: &[f64], y: &mut [f64]) {
        self.kernel.spmv(x, y);
    }
    fn spmv_batch(&self, xs: &[Vec<f64>]) -> Vec<Vec<f64>> {
        xs.iter()
            .map(|x| {
                let mut y = vec![0.0; self.kernel.nrows()];
                self.kernel.spmv(x, &mut y);
                y
            })
            .collect()
    }
    fn rebalance(&mut self, schedule: Schedule) {
        self.report.rationale.push(format!(
            "serial backend: rebalance({}) is a no-op (no partitions to re-home)",
            schedule.name()
        ));
    }
    fn report(&self) -> &TuningReport {
        &self.report
    }
    fn report_mut(&mut self) -> &mut TuningReport {
        &mut self.report
    }
    fn kernel(&self) -> Option<&SpmvKernel> {
        Some(&self.kernel)
    }
}

/// Native backend: the tuned kernel + plan + engine bundle
/// (`tune::SpmvContext` internals) behind the facade seam.
pub struct Native {
    ctx: SpmvContext,
}

impl Backend for Native {
    fn name(&self) -> &'static str {
        "native"
    }
    fn nrows(&self) -> usize {
        SpMv::nrows(&self.ctx)
    }
    fn nnz(&self) -> usize {
        SpMv::nnz(&self.ctx)
    }
    fn scheme(&self) -> Scheme {
        self.ctx.scheme()
    }
    fn schedule(&self) -> Schedule {
        self.ctx.schedule()
    }
    fn n_threads(&self) -> usize {
        self.ctx.n_threads()
    }
    fn spmv(&self, x: &[f64], y: &mut [f64]) {
        self.ctx.spmv(x, y);
    }
    fn spmv_batch(&self, xs: &[Vec<f64>]) -> Vec<Vec<f64>> {
        self.ctx.spmv_batch(xs)
    }
    fn spmv_multi(&self, xs: &[Vec<f64>]) -> Vec<Vec<f64>> {
        self.ctx.spmv_multi(xs)
    }
    fn rebalance(&mut self, schedule: Schedule) {
        self.ctx.rebalance(schedule);
    }
    fn report(&self) -> &TuningReport {
        self.ctx.report()
    }
    fn report_mut(&mut self) -> &mut TuningReport {
        self.ctx.report_mut()
    }
    fn pinned(&self) -> bool {
        self.ctx.pinned()
    }
    fn kernel(&self) -> Option<&SpmvKernel> {
        Some(self.ctx.kernel())
    }
    fn plan(&self) -> Option<&SpmvPlan> {
        Some(self.ctx.plan())
    }
    fn spmv_permuted(&self, xp: &[f64], yp: &mut [f64]) -> Result<()> {
        self.ctx.spmv_permuted(xp, yp);
        Ok(())
    }
    fn replanned(&self, schedule: Schedule, n_threads: usize) -> Result<Box<dyn Backend>> {
        Ok(Box::new(Native { ctx: self.ctx.replanned(schedule, n_threads) }))
    }
}

/// Sharded backend: the in-process distributed executor
/// (`shard::ShardedSpmv` behind a tuned `ShardedContext`) — halo
/// exchange, compute/exchange overlap, per-shard pinned engines.
pub struct Sharded {
    ctx: ShardedContext,
}

impl Backend for Sharded {
    fn name(&self) -> &'static str {
        "sharded"
    }
    fn nrows(&self) -> usize {
        SpMv::nrows(&self.ctx)
    }
    fn nnz(&self) -> usize {
        SpMv::nnz(&self.ctx)
    }
    fn scheme(&self) -> Scheme {
        self.ctx.scheme()
    }
    fn schedule(&self) -> Schedule {
        self.ctx.schedule()
    }
    fn n_threads(&self) -> usize {
        self.ctx.sharded().threads_per_shard()
    }
    fn spmv(&self, x: &[f64], y: &mut [f64]) {
        self.ctx.spmv(x, y);
    }
    fn spmv_batch(&self, xs: &[Vec<f64>]) -> Vec<Vec<f64>> {
        self.ctx.spmv_batch(xs)
    }
    fn rebalance(&mut self, schedule: Schedule) {
        self.ctx.rebalance(schedule);
    }
    fn report(&self) -> &TuningReport {
        self.ctx.report()
    }
    fn report_mut(&mut self) -> &mut TuningReport {
        self.ctx.report_mut()
    }
    fn pinned(&self) -> bool {
        self.ctx.sharded().pinned()
    }
    fn n_shards(&self) -> usize {
        self.ctx.n_shards()
    }
    fn mode(&self) -> Option<OverlapMode> {
        Some(self.ctx.mode())
    }
    fn reshard(&mut self, n_shards: usize, mode: OverlapMode) -> Result<()> {
        self.ctx.reshard(n_shards, mode)
    }
}

/// Which executor the builder binds. `Auto` (the default) arbitrates
/// per matrix; the other variants force one backend — the escape hatch
/// benches use to compare the auto pick against each executor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendChoice {
    Auto,
    Serial,
    Native,
    Sharded,
}

impl BackendChoice {
    pub fn name(&self) -> &'static str {
        match self {
            BackendChoice::Auto => "auto",
            BackendChoice::Serial => "serial",
            BackendChoice::Native => "native",
            BackendChoice::Sharded => "sharded",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        match s.trim().to_ascii_lowercase().as_str() {
            "auto" => Ok(BackendChoice::Auto),
            "serial" => Ok(BackendChoice::Serial),
            "native" => Ok(BackendChoice::Native),
            "sharded" => Ok(BackendChoice::Sharded),
            other => anyhow::bail!("unknown backend '{other}' (auto|serial|native|sharded)"),
        }
    }
}

/// An executor-agnostic, tuned SpMV operator — the crate's one public
/// execution surface. Obtain via [`SpmvHandle::builder`]; solvers, the
/// coordinator service, experiments, benches and the CLI all consume
/// this type, never a concrete backend.
pub struct SpmvHandle {
    backend: Box<dyn Backend>,
}

impl SpmvHandle {
    /// Start a builder from an assembled COO matrix.
    pub fn builder(coo: &Coo) -> SpmvBuilder<'static> {
        SpmvBuilder::from_cow(Cow::Owned(Crs::from_coo(coo)))
    }

    /// Start a builder that borrows an already-compressed CRS matrix —
    /// no conversion and no clone; tuning only reads it.
    pub fn builder_from_crs(crs: &Crs) -> SpmvBuilder<'_> {
        SpmvBuilder::from_cow(Cow::Borrowed(crs))
    }

    /// Wrap an externally built backend — the seam a PJRT executor (or
    /// any other [`Backend`] impl) plugs into.
    pub fn from_backend(backend: Box<dyn Backend>) -> Self {
        SpmvHandle { backend }
    }

    /// The serving backend's name (`"serial"`, `"native"`, `"sharded"`).
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// The arbitration decision, when the builder recorded one.
    pub fn backend_decision(&self) -> Option<&BackendDecision> {
        self.report().backend.as_ref()
    }

    pub fn report(&self) -> &TuningReport {
        self.backend.report()
    }

    pub fn scheme(&self) -> Scheme {
        self.backend.scheme()
    }

    pub fn schedule(&self) -> Schedule {
        self.backend.schedule()
    }

    pub fn n_threads(&self) -> usize {
        self.backend.n_threads()
    }

    /// Was NUMA placement (pinning + first touch) deployed?
    pub fn pinned(&self) -> bool {
        self.backend.pinned()
    }

    /// The numerical contract the handle was built under.
    pub fn precision(&self) -> Precision {
        self.report().precision
    }

    /// The instruction-set level the serving kernels execute at —
    /// `Scalar` unless the [`Precision`] contract admitted vector
    /// kernels and the tuner bound one.
    pub fn kernel_isa(&self) -> IsaLevel {
        self.report().kernel_isa
    }

    /// Shard count (1 for unsharded backends).
    pub fn n_shards(&self) -> usize {
        self.backend.n_shards()
    }

    /// Overlap mode, for the sharded backend.
    pub fn mode(&self) -> Option<OverlapMode> {
        self.backend.mode()
    }

    /// Original-basis SpMV through the bound executor.
    pub fn spmv(&self, x: &[f64], y: &mut [f64]) {
        self.backend.spmv(x, y);
    }

    /// Batched SpMV — one fused dispatch where the backend supports it;
    /// each result is bit-identical to the per-vector [`Self::spmv`].
    pub fn spmv_batch(&self, xs: &[Vec<f64>]) -> Vec<Vec<f64>> {
        self.backend.spmv_batch(xs)
    }

    /// SpMM over a column block of `k` vectors, with the tuner pricing
    /// blocked-x against the per-vector batch ([`Self::multi_decision`]):
    /// the fused multi kernel streams the matrix once per chunk and
    /// reuses every loaded entry across the block, which wins whenever
    /// `k >= 2` — since ISSUE 9 the fused loops have vector bodies too,
    /// so a bound vector ISA keeps its win instead of forcing the
    /// per-vector batch. `k < 2` routes to [`Self::spmv_batch`]. Either
    /// way each result is bit-identical to the per-vector [`Self::spmv`]
    /// under [`Precision::BitIdentical`].
    pub fn spmv_multi(&self, xs: &[Vec<f64>]) -> Vec<Vec<f64>> {
        if self.multi_decision(xs.len()).blocked {
            self.backend.spmv_multi(xs)
        } else {
            self.backend.spmv_batch(xs)
        }
    }

    /// Price a `k`-wide SpMM call on this handle: blocked-x against the
    /// per-vector batch, from the modeled memory traffic of each path
    /// ([`tune::price_multi`]) and the bound kernel ISA.
    pub fn multi_decision(&self, k: usize) -> MultiDecision {
        let nnz = self.backend.nnz();
        let nrows = self.backend.nrows();
        price_multi(nnz, nrows, k, self.kernel_isa() > IsaLevel::Scalar)
    }

    /// Permuted-basis hot path, where the backend has one (serial and
    /// sharded backends do not — they error).
    pub fn spmv_permuted(&self, xp: &[f64], yp: &mut [f64]) -> Result<()> {
        self.backend.spmv_permuted(xp, yp)
    }

    /// Re-partition for a new schedule in place, re-homing workspace
    /// buffers (§5.2) — a no-op recorded in the report for serial.
    pub fn rebalance(&mut self, schedule: Schedule) {
        self.backend.rebalance(schedule);
    }

    /// Fork a sibling handle on a new schedule / thread count sharing
    /// the tuned storage (native backend only).
    pub fn replanned(&self, schedule: Schedule, n_threads: usize) -> Result<SpmvHandle> {
        Ok(SpmvHandle { backend: self.backend.replanned(schedule, n_threads)? })
    }

    /// Re-shard onto a new shard count / overlap mode (sharded backend
    /// only).
    pub fn reshard(&mut self, n_shards: usize, mode: OverlapMode) -> Result<()> {
        self.backend.reshard(n_shards, mode)
    }

    /// The realized storage kernel, for backends that own exactly one
    /// (serial, native).
    pub fn kernel(&self) -> Option<&SpmvKernel> {
        self.backend.kernel()
    }

    /// The scheduling plan (native backend) — hand it to
    /// [`crate::simulator::simulate_spmv_plan`] to evaluate the tuned
    /// decision on the paper's machine models.
    pub fn plan(&self) -> Option<&SpmvPlan> {
        self.backend.plan()
    }
}

/// A handle is itself an [`SpMv`] operator (and therefore a
/// [`crate::eigen::LinearOp`] via the blanket impl), so solvers run
/// their hot loop through whatever backend arbitration bound.
impl SpMv for SpmvHandle {
    fn nrows(&self) -> usize {
        self.backend.nrows()
    }
    fn ncols(&self) -> usize {
        self.backend.nrows() // builders reject non-square matrices
    }
    fn nnz(&self) -> usize {
        self.backend.nnz()
    }
    fn spmv(&self, x: &[f64], y: &mut [f64]) {
        SpmvHandle::spmv(self, x, y);
    }
}

/// The one builder: scheme/schedule tuning knobs (forwarded to the
/// tuning layer) plus the backend-arbitration tier. Absorbs the former
/// `.sharded(..)` / `build_sharded()` split — sharding is just a
/// backend now, and `build()` is the only terminal.
pub struct SpmvBuilder<'a> {
    crs: Cow<'a, Crs>,
    policy: TuningPolicy,
    backend: BackendChoice,
    shard_policy: Option<ShardPolicy>,
    threads: Option<usize>,
    machine: MachineSpec,
    quick: bool,
    pinned: bool,
    cv_threshold: Option<f64>,
    precision: Precision,
}

impl<'a> SpmvBuilder<'a> {
    fn from_cow(crs: Cow<'a, Crs>) -> Self {
        SpmvBuilder {
            crs,
            policy: TuningPolicy::Heuristic,
            backend: BackendChoice::Auto,
            shard_policy: None,
            threads: None,
            machine: MachineSpec::nehalem(),
            quick: false,
            pinned: false,
            cv_threshold: None,
            precision: Precision::default(),
        }
    }

    /// Scheme/schedule tuning tier (default: [`TuningPolicy::Heuristic`]).
    pub fn policy(mut self, policy: TuningPolicy) -> Self {
        self.policy = policy;
        self
    }

    /// Executor choice (default: [`BackendChoice::Auto`] — arbitrate).
    pub fn backend(mut self, backend: BackendChoice) -> Self {
        self.backend = backend;
        self
    }

    /// Shard tier shaping the sharded backend (candidate): shard count
    /// and overlap mode come from this policy when the sharded backend
    /// is forced or wins arbitration. Defaults to
    /// [`ShardPolicy::Heuristic`].
    pub fn shard_policy(mut self, policy: ShardPolicy) -> Self {
        self.shard_policy = Some(policy);
        self
    }

    /// Engine thread count (threads **per shard** for the sharded
    /// backend). Defaults to host parallelism capped at 4.
    pub fn threads(mut self, n: usize) -> Self {
        self.threads = Some(n.max(1));
        self
    }

    /// Machine model for the heuristic tiers' performance model.
    pub fn machine(mut self, machine: MachineSpec) -> Self {
        self.machine = machine;
        self
    }

    /// Cheapen tuning and arbitration for smoke runs.
    pub fn quick(mut self, quick: bool) -> Self {
        self.quick = quick;
        self
    }

    /// Request NUMA placement: pinned engine(s) + first-touched
    /// workspace. Ignored (and recorded as such) by the serial backend.
    pub fn pinned(mut self, pinned: bool) -> Self {
        self.pinned = pinned;
        self
    }

    /// Override the schedule heuristic's row-imbalance CV threshold
    /// (defaults: [`tune::SCHEDULE_CV_THRESHOLD`] /
    /// [`tune::SCHEDULE_CV_THRESHOLD_FIRST_TOUCH`]); the effective value
    /// is recorded in the [`TuningReport`].
    pub fn schedule_cv_threshold(mut self, threshold: f64) -> Self {
        self.cv_threshold = Some(threshold);
        self
    }

    /// Numerical contract for the kernels the tuner may bind (default:
    /// [`Precision::BitIdentical`] — scalar-only candidates, results
    /// bit-identical to the chosen scheme's serial kernel, exactly the
    /// pre-SIMD behavior). [`Precision::Tolerance`] additionally admits
    /// the runtime-detected vector kernels ([`IsaLevel`]), whose FMA
    /// contraction and grouped accumulation may differ from scalar in
    /// the low-order bits; the tuner then arbitrates simd-vs-scalar per
    /// matrix and records the bound level in the [`TuningReport`].
    pub fn precision(mut self, precision: Precision) -> Self {
        self.precision = precision;
        self
    }

    /// Run the tuning policy, arbitrate (or force) the backend, and
    /// bind the handle. Errors on non-square matrices and on a shard
    /// policy combined with a non-sharded forced backend.
    pub fn build(self) -> Result<SpmvHandle> {
        let SpmvBuilder {
            crs,
            policy,
            backend,
            shard_policy,
            threads,
            machine,
            quick,
            pinned,
            cv_threshold,
            precision,
        } = self;
        let crs: &Crs = &crs;
        anyhow::ensure!(
            crs.nrows == crs.ncols,
            "SpmvHandle requires a square matrix, got {}x{}",
            crs.nrows,
            crs.ncols
        );
        if shard_policy.is_some() {
            anyhow::ensure!(
                matches!(backend, BackendChoice::Auto | BackendChoice::Sharded),
                "a shard policy only applies to the sharded or auto backend, not {}",
                backend.name()
            );
        }
        let cfg = BuildCfg {
            crs,
            policy,
            shard_policy,
            threads,
            machine,
            quick,
            pinned,
            cv_threshold,
            precision,
        };
        let (mut backend_box, decision, rationale): (Box<dyn Backend>, _, _) = match backend {
            BackendChoice::Serial => {
                // The probe only donates its kernel: unpinned (no engine
                // pool for a backend that will not use one) and at ONE
                // thread, so a measured scheme bake-off times candidates
                // the way they will actually serve — inline.
                let ctx = cfg.native(false, Some(1))?;
                (
                    Box::new(serial_from_context(&ctx, cfg.pinned, " (forced)"))
                        as Box<dyn Backend>,
                    forced_decision("serial"),
                    vec!["backend forced by caller: serial".into()],
                )
            }
            BackendChoice::Native => (
                Box::new(Native { ctx: cfg.native(cfg.pinned, cfg.threads)? })
                    as Box<dyn Backend>,
                forced_decision("native"),
                vec!["backend forced by caller: native".into()],
            ),
            BackendChoice::Sharded => (
                Box::new(Sharded { ctx: cfg.sharded()? }) as Box<dyn Backend>,
                forced_decision("sharded"),
                vec!["backend forced by caller: sharded".into()],
            ),
            BackendChoice::Auto => cfg.arbitrate()?,
        };
        let report = backend_box.report_mut();
        report.rationale.extend(rationale);
        report
            .rationale
            .push(format!("backend: {} ({} arbitration)", decision.backend, decision.policy));
        report.backend = Some(decision);
        Ok(SpmvHandle { backend: backend_box })
    }
}

/// A trivial decision record for a caller-forced backend.
fn forced_decision(backend: &'static str) -> BackendDecision {
    BackendDecision {
        policy: "forced".into(),
        backend,
        candidates: vec![BackendCandidate {
            backend,
            predicted_ns_per_call: None,
            measured_ns_per_nnz: None,
            chosen: true,
        }],
    }
}

/// Demote a tuned native context to the serial backend: the kernel is
/// shared (nothing is rebuilt), the engine is discarded, and the report
/// is corrected to the serial reality — no placement, no schedule
/// (recorded as the static default), one thread. A caller's pinning
/// request is recorded as ignored rather than silently erased.
fn serial_from_context(ctx: &SpmvContext, pin_requested: bool, note: &str) -> Serial {
    let mut report = ctx.report().clone();
    report.n_threads = 1;
    report.schedule = Schedule::Static { chunk: None };
    report.placement = PlacementDecision { pin_requested: false, pin: None, first_touch: false };
    if report.kernel_isa > IsaLevel::Scalar {
        report.rationale.push(format!(
            "serial backend executes the scalar kernel inline ({} stays a tuning-probe score)",
            report.kernel_isa.name()
        ));
    }
    report.kernel_isa = IsaLevel::Scalar;
    if pin_requested {
        report.rationale.push(
            "serial backend ignores the pinning request (no engine threads to place)".into(),
        );
    }
    report
        .rationale
        .push(format!("serial backend{note}: kernel executed inline, no engine, no schedule"));
    Serial { kernel: ctx.kernel_arc(), report }
}

/// Resolved builder inputs shared by the per-backend build paths.
struct BuildCfg<'a> {
    crs: &'a Crs,
    policy: TuningPolicy,
    shard_policy: Option<ShardPolicy>,
    threads: Option<usize>,
    machine: MachineSpec,
    quick: bool,
    pinned: bool,
    cv_threshold: Option<f64>,
    precision: Precision,
}

impl BuildCfg<'_> {
    /// Tuned native context (scheme × schedule via the tuning layer).
    /// `threads` overrides the builder's thread count — the serial
    /// backend probes at 1 thread so a measured bake-off times
    /// candidates the way they will actually serve (inline).
    fn native(&self, pinned: bool, threads: Option<usize>) -> Result<SpmvContext> {
        let mut b = SpmvContext::builder_from_crs(self.crs)
            .policy(self.policy)
            .machine(self.machine.clone())
            .quick(self.quick)
            .pinned(pinned)
            .schedule_cv_threshold(self.cv_threshold)
            .precision(self.precision);
        if let Some(t) = threads {
            b = b.threads(t);
        }
        b.build()
    }

    /// Tuned sharded context: scheme and schedule from the tuning
    /// policy, shard count and overlap mode from the shard tier.
    fn sharded(&self) -> Result<ShardedContext> {
        let mut b = SpmvContext::builder_from_crs(self.crs)
            .policy(self.policy)
            .machine(self.machine.clone())
            .quick(self.quick)
            .pinned(self.pinned)
            .schedule_cv_threshold(self.cv_threshold)
            .precision(self.precision)
            .sharded(self.shard_policy.unwrap_or(ShardPolicy::Heuristic));
        if let Some(t) = self.threads {
            b = b.threads(t);
        }
        b.build_sharded()
    }

    /// Sharded context inheriting scheme and schedule from an
    /// already-run tuning probe, carrying the probe's fingerprint and
    /// candidate scoreboard over so the final report still documents the
    /// scheme decision. The caller's shard policy wins; `default_policy`
    /// applies otherwise (the arbitration's own partition pick).
    fn sharded_from_probe(
        &self,
        probe: &SpmvContext,
        default_policy: ShardPolicy,
    ) -> Result<ShardedContext> {
        let shard_policy = self.shard_policy.unwrap_or(default_policy);
        let mut b = SpmvContext::builder_from_crs(self.crs)
            .policy(TuningPolicy::Fixed(probe.scheme(), probe.schedule()))
            .machine(self.machine.clone())
            .quick(self.quick)
            .pinned(self.pinned)
            .schedule_cv_threshold(self.cv_threshold)
            .precision(self.precision)
            .sharded(shard_policy);
        if let Some(t) = self.threads {
            b = b.threads(t);
        }
        let mut ctx = b.build_sharded()?;
        let pr = probe.report();
        let r = ctx.report_mut();
        r.policy = pr.policy.clone();
        r.backward_fraction = pr.backward_fraction;
        r.mean_abs_stride = pr.mean_abs_stride;
        r.small_stride_fraction = pr.small_stride_fraction;
        r.candidates = pr.candidates.clone();
        r.rationale.push(format!(
            "scheme/schedule inherited from the {} tuning probe",
            pr.policy
        ));
        Ok(ctx)
    }

    /// Auto mode: resolve the backend per the [`TuningPolicy`] tier.
    fn arbitrate(&self) -> Result<(Box<dyn Backend>, BackendDecision, Vec<String>)> {
        match self.policy {
            TuningPolicy::Fixed(..) => {
                let ctx = self.native(self.pinned, self.threads)?;
                let decision = BackendDecision {
                    policy: "fixed-default".into(),
                    backend: "native",
                    candidates: vec![BackendCandidate {
                        backend: "native",
                        predicted_ns_per_call: None,
                        measured_ns_per_nnz: None,
                        chosen: true,
                    }],
                };
                Ok((
                    Box::new(Native { ctx }) as Box<dyn Backend>,
                    decision,
                    vec![
                        "fixed tuning policy: no backend probing, native engine serves \
                         (force another with .backend(..))"
                            .into(),
                    ],
                ))
            }
            TuningPolicy::Heuristic => {
                // The probe doubles as the deployed native backend (the
                // common case), so it is built with the full requested
                // config — including placement. When serial or sharded
                // wins instead, the probe's engine/first-touch cost is
                // written off (one extra pass over the matrix, at most).
                let ctx = self.native(self.pinned, self.threads)?;
                let (decision, shard_pick, rationale) = self.heuristic_decision(&ctx);
                let backend: Box<dyn Backend> = match decision.backend {
                    "serial" => {
                        Box::new(serial_from_context(&ctx, self.pinned, " (heuristic pick)"))
                    }
                    "sharded" => {
                        let (shards, mode) = shard_pick.expect("sharded pick has a partition");
                        let ctx = self
                            .sharded_from_probe(&ctx, ShardPolicy::Fixed { shards, mode })?;
                        Box::new(Sharded { ctx })
                    }
                    _ => Box::new(Native { ctx }),
                };
                Ok((backend, decision, rationale))
            }
            TuningPolicy::Measured => self.measured_decision(),
        }
    }

    /// Feature-based arbitration: estimated ns per whole SpMV call for
    /// serial / native / sharded, from the perfmodel per-nnz cost, the
    /// candidate partitions' halo features, the row-imbalance CV and
    /// rough dispatch costs.
    fn heuristic_decision(
        &self,
        ctx: &SpmvContext,
    ) -> (BackendDecision, Option<(usize, OverlapMode)>, Vec<String>) {
        let curve = tune::cached_curve(&self.machine, self.quick);
        let pred = predict(&self.machine, &curve, ctx.kernel());
        let per_nnz_ns = pred.cycles_per_nnz / self.machine.freq_ghz;
        // Scan-only shard features: when the caller named a fixed shard
        // policy, arbitration must score exactly the partition that
        // would deploy; otherwise it scans the grid (matching what the
        // shard heuristic tier would then pick). Nothing is packed.
        let viable = |s: usize| s > 1 && self.crs.nrows >= SHARD_MIN_ROWS * s;
        let shard_features: Vec<(usize, f64, f64)> = match self.shard_policy {
            Some(ShardPolicy::Fixed { shards, .. }) => {
                if viable(shards) {
                    let (hf, bf) = ShardedCrs::partition_stats(self.crs, shards);
                    vec![(shards, hf, bf)]
                } else {
                    Vec::new()
                }
            }
            _ => SHARD_GRID
                .iter()
                .filter(|&&s| viable(s))
                .map(|&s| {
                    let (hf, bf) = ShardedCrs::partition_stats(self.crs, s);
                    (s, hf, bf)
                })
                .collect(),
        };
        let (candidates, shard_pick, mut rationale) = score_backends(
            self.crs.nnz() as f64,
            ctx.n_threads() as f64,
            per_nnz_ns,
            ctx.report().row_imbalance_cv,
            &shard_features,
        );
        rationale.insert(
            0,
            format!(
                "backend heuristic: perfmodel {:.2} cycles/nnz on {} -> {:.2} ns/nnz",
                pred.cycles_per_nnz, self.machine.name, per_nnz_ns
            ),
        );
        if let Some(ShardPolicy::Fixed { shards, mode }) = self.shard_policy {
            rationale.push(format!(
                "sharded candidate restricted to the caller's shard policy \
                 ({shards} shard(s), {} mode)",
                mode.name()
            ));
        }
        let backend = candidates
            .iter()
            .find(|c| c.chosen)
            .expect("one candidate is chosen")
            .backend;
        (
            BackendDecision { policy: "heuristic".into(), backend, candidates },
            shard_pick,
            rationale,
        )
    }

    /// Cross-backend bake-off: time serial / native / sharded on the
    /// host with the scheme and schedule the tuning probe picked, keep
    /// the fastest.
    fn measured_decision(&self) -> Result<(Box<dyn Backend>, BackendDecision, Vec<String>)> {
        let ctx = self.native(self.pinned, self.threads)?;
        // The sharded candidate's partition comes from the (scan-only)
        // shard heuristic unless the caller named a shard policy.
        let sharded = self.sharded_from_probe(&ctx, ShardPolicy::Heuristic)?;
        let n = self.crs.nrows;
        let nnz = self.crs.nnz().max(1) as f64;
        let reps = if self.quick { 2 } else { 5 };
        let mut x = vec![0.0; n];
        Rng::new(0xA4B17).fill_f64(&mut x, -1.0, 1.0);
        let mut y = vec![0.0; n];
        let mut time = |f: &mut dyn FnMut(&[f64], &mut [f64])| -> f64 {
            f(&x, &mut y); // warmup
            let mut best = f64::INFINITY;
            for _ in 0..reps {
                let t0 = Instant::now();
                f(&x, &mut y);
                best = best.min(t0.elapsed().as_nanos() as f64 / nnz);
            }
            best
        };
        let serial_ns = time(&mut |x, y| ctx.kernel().spmv(x, y));
        let native_ns = time(&mut |x, y| ctx.spmv(x, y));
        let sharded_ns = time(&mut |x, y| sharded.spmv(x, y));
        let mut candidates = vec![
            BackendCandidate {
                backend: "serial",
                predicted_ns_per_call: None,
                measured_ns_per_nnz: Some(serial_ns),
                chosen: false,
            },
            BackendCandidate {
                backend: "native",
                predicted_ns_per_call: None,
                measured_ns_per_nnz: Some(native_ns),
                chosen: false,
            },
            BackendCandidate {
                backend: "sharded",
                predicted_ns_per_call: None,
                measured_ns_per_nnz: Some(sharded_ns),
                chosen: false,
            },
        ];
        let best = min_index(candidates.iter().map(|c| c.measured_ns_per_nnz.unwrap()));
        candidates[best].chosen = true;
        let winner = candidates[best].backend;
        let rationale = vec![format!(
            "backend bake-off ({reps} reps) picks {winner} at {:.2} ns/nnz \
             (serial {serial_ns:.2}, native {native_ns:.2}, sharded {sharded_ns:.2})",
            candidates[best].measured_ns_per_nnz.unwrap()
        )];
        let decision = BackendDecision { policy: "measured".into(), backend: winner, candidates };
        let backend: Box<dyn Backend> = match winner {
            "serial" => Box::new(serial_from_context(&ctx, self.pinned, " (bake-off winner)")),
            "sharded" => Box::new(Sharded { ctx: sharded }),
            _ => Box::new(Native { ctx }),
        };
        Ok((backend, decision, rationale))
    }
}

/// Index of the minimum of a non-empty score iterator.
fn min_index(scores: impl Iterator<Item = f64>) -> usize {
    scores
        .enumerate()
        .min_by(|a, b| a.1.partial_cmp(&b.1).expect("scores are finite"))
        .expect("candidate set is never empty")
        .0
}

/// The pure arbitration rule (unit-testable): score each backend in
/// estimated nanoseconds per whole SpMV call.
///
/// - serial: `nnz × per_nnz_ns` — no dispatch cost, no parallelism;
/// - native: work divided by `threads`, plus one engine dispatch;
/// - sharded (best grid point): work divided by `threads × shards`,
///   inflated by the halo gather (halved when enough interior work
///   exists to overlap the exchange) and by the row-imbalance CV, plus
///   the per-shard coordinator spawn cost.
///
/// `shard_features` lists viable `(shards, halo_fraction,
/// boundary_nnz_fraction)` partitions; entries with a halo above
/// [`SHARD_HALO_VIABLE_MAX`] are discarded and the overlap mode follows
/// [`SHARD_OVERLAP_MIN_INTERIOR`] — the same constants the shard tier's
/// own heuristic uses, so the two layers cannot drift apart
/// (arXiv:1106.5908).
fn score_backends(
    nnz: f64,
    threads: f64,
    per_nnz_ns: f64,
    row_cv: f64,
    shard_features: &[(usize, f64, f64)],
) -> (Vec<BackendCandidate>, Option<(usize, OverlapMode)>, Vec<String>) {
    let serial_ns = nnz * per_nnz_ns;
    let native_ns = nnz * per_nnz_ns / threads.max(1.0) + NATIVE_DISPATCH_NS;
    let mut rationale = vec![format!(
        "serial {serial_ns:.0} ns/call; native {native_ns:.0} ns/call \
         ({threads:.0} thread(s) + {NATIVE_DISPATCH_NS:.0} ns dispatch)"
    )];
    let mut shard_pick: Option<(usize, OverlapMode, f64)> = None;
    for &(s, hf, bf) in shard_features {
        if hf > SHARD_HALO_VIABLE_MAX {
            continue;
        }
        let mode = if (1.0 - bf) >= SHARD_OVERLAP_MIN_INTERIOR {
            OverlapMode::Overlapped
        } else {
            OverlapMode::BulkSync
        };
        // Overlap hides roughly half the halo gather behind the
        // interior compute; imbalanced rows concentrate in few shards.
        let halo_cost = if mode == OverlapMode::Overlapped { 0.5 * hf } else { hf };
        let imbalance = 1.0 + 0.25 * row_cv.min(2.0);
        let ns = nnz * per_nnz_ns * (1.0 + halo_cost) * imbalance / (threads.max(1.0) * s as f64)
            + SHARD_DISPATCH_NS * s as f64;
        if shard_pick.map(|(_, _, best)| ns < best).unwrap_or(true) {
            shard_pick = Some((s, mode, ns));
        }
    }
    let mut candidates = vec![
        BackendCandidate {
            backend: "serial",
            predicted_ns_per_call: Some(serial_ns),
            measured_ns_per_nnz: None,
            chosen: false,
        },
        BackendCandidate {
            backend: "native",
            predicted_ns_per_call: Some(native_ns),
            measured_ns_per_nnz: None,
            chosen: false,
        },
    ];
    if let Some((s, mode, ns)) = shard_pick {
        rationale.push(format!(
            "sharded candidate: {s} shard(s), {} mode at {ns:.0} ns/call \
             ({SHARD_DISPATCH_NS:.0} ns/shard coordinator cost, row CV {row_cv:.2})",
            mode.name()
        ));
        candidates.push(BackendCandidate {
            backend: "sharded",
            predicted_ns_per_call: Some(ns),
            measured_ns_per_nnz: None,
            chosen: false,
        });
    } else {
        rationale.push(
            "no viable shard partition (halo > half the vector or too few rows): \
             sharded not a candidate"
                .into(),
        );
    }
    let best = min_index(candidates.iter().map(|c| c.predicted_ns_per_call.unwrap()));
    candidates[best].chosen = true;
    rationale.push(format!(
        "backend heuristic picks {} at {:.0} estimated ns/call",
        candidates[best].backend,
        candidates[best].predicted_ns_per_call.unwrap()
    ));
    (candidates, shard_pick.map(|(s, m, _)| (s, m)), rationale)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen;
    use crate::util::stats::max_abs_diff;

    fn hh() -> Coo {
        gen::holstein_hubbard(&gen::HolsteinHubbardParams::tiny())
    }

    /// Property body of the facade bit-identity tests: every backend ×
    /// scheme × schedule × pin on/off reproduces serial CRS bit for bit
    /// on `coo` (CRS and SELL-C-σ both preserve the per-row accumulation
    /// order; pinning degrades to a recorded no-op off Linux on the same
    /// code path).
    fn assert_facade_bit_identity(coo: &Coo, seed: u64) {
        let crs = Crs::from_coo(coo);
        let n = crs.nrows;
        let mut rng = Rng::new(seed);
        let mut x = vec![0.0; n];
        rng.fill_f64(&mut x, -1.0, 1.0);
        let mut want = vec![0.0; n];
        crs.spmv(&x, &mut want);
        let backends =
            [BackendChoice::Serial, BackendChoice::Native, BackendChoice::Sharded];
        let schedules = [
            Schedule::Static { chunk: None },
            Schedule::Dynamic { chunk: 13 },
            Schedule::Guided { min_chunk: 4 },
        ];
        for backend in backends {
            for scheme in [Scheme::Crs, Scheme::SellCs { c: 8, sigma: 64 }] {
                for schedule in schedules {
                    for pin in [false, true] {
                        let mut b = SpmvHandle::builder(coo)
                            .policy(TuningPolicy::Fixed(scheme, schedule))
                            .backend(backend)
                            .threads(2)
                            .pinned(pin);
                        if backend == BackendChoice::Sharded {
                            b = b.shard_policy(ShardPolicy::Fixed {
                                shards: 3,
                                mode: OverlapMode::Overlapped,
                            });
                        }
                        let handle = b.build().unwrap();
                        assert_eq!(handle.backend_name(), backend.name());
                        assert_eq!(handle.scheme(), scheme);
                        let mut got = vec![0.0; n];
                        handle.spmv(&x, &mut got);
                        assert_eq!(
                            max_abs_diff(&want, &got),
                            0.0,
                            "{} × {scheme} × {} × pin={pin} deviates from serial CRS",
                            backend.name(),
                            schedule.name()
                        );
                        let ys = handle.spmv_batch(std::slice::from_ref(&x));
                        assert_eq!(
                            max_abs_diff(&ys[0], &got),
                            0.0,
                            "{}: batch deviates from per-vector",
                            backend.name()
                        );
                    }
                }
            }
        }
    }

    /// ISSUE-5 satellite: facade bit-identity on the paper's Hamiltonian.
    #[test]
    fn facade_bit_identical_across_backends() {
        assert_facade_bit_identity(&hh(), 120);
    }

    /// ISSUE-8 satellite: the same property on a scale-free power-law
    /// instance, whose hub rows actually stress the dynamic/guided
    /// partitions (a hub row can outweigh whole chunks of tail rows).
    #[test]
    fn facade_bit_identical_across_backends_on_power_law() {
        let coo = gen::power_law(300, 6, 2.2, &mut Rng::new(77));
        assert_facade_bit_identity(&coo, 121);
    }

    /// ISSUE-8 tentpole: SpMM through the facade — `spmv_multi` is
    /// bit-identical to `k` independent `spmv` calls under the default
    /// `Precision::BitIdentical` on every backend (fused blocked-x on
    /// native, per-vector fallback on serial/sharded), and the pricing
    /// decision is recorded and sane.
    #[test]
    fn spmv_multi_bit_identical_to_per_vector_spmv() {
        let coo = hh();
        let crs = Crs::from_coo(&coo);
        let n = crs.nrows;
        let k = 4;
        let mut rng = Rng::new(123);
        let xs: Vec<Vec<f64>> = (0..k)
            .map(|_| {
                let mut x = vec![0.0; n];
                rng.fill_f64(&mut x, -1.0, 1.0);
                x
            })
            .collect();
        let backends =
            [BackendChoice::Serial, BackendChoice::Native, BackendChoice::Sharded];
        for backend in backends {
            for scheme in [Scheme::Crs, Scheme::SellCs { c: 8, sigma: 64 }] {
                let mut b = SpmvHandle::builder(&coo)
                    .policy(TuningPolicy::Fixed(scheme, Schedule::Dynamic { chunk: 13 }))
                    .backend(backend)
                    .threads(2);
                if backend == BackendChoice::Sharded {
                    b = b.shard_policy(ShardPolicy::Fixed {
                        shards: 2,
                        mode: OverlapMode::Overlapped,
                    });
                }
                let handle = b.build().unwrap();
                assert_eq!(handle.precision(), Precision::BitIdentical);
                let d = handle.multi_decision(k);
                assert!(d.blocked, "k={k} under BitIdentical must price blocked-x");
                assert!(d.bytes_blocked < d.bytes_per_vector);
                let ys = handle.spmv_multi(&xs);
                assert_eq!(ys.len(), k);
                for (x, y) in xs.iter().zip(&ys) {
                    let mut want = vec![0.0; n];
                    handle.spmv(x, &mut want);
                    assert_eq!(
                        max_abs_diff(&want, y),
                        0.0,
                        "{} × {scheme}: spmv_multi deviates from per-vector spmv",
                        backend.name()
                    );
                }
            }
        }
        // A single vector has nothing to block over.
        let h = SpmvHandle::builder(&coo)
            .policy(TuningPolicy::Fixed(Scheme::Crs, Schedule::Static { chunk: None }))
            .backend(BackendChoice::Native)
            .threads(2)
            .build()
            .unwrap();
        assert!(!h.multi_decision(1).blocked);
    }

    /// ISSUE-8 satellite: arbitration on graph-scale row imbalance — a
    /// generated power-law instance crosses the schedule heuristic's CV
    /// threshold and flips to dynamic/guided, while a regular band
    /// matrix of the same size stays static.
    #[test]
    fn power_law_flips_schedule_above_cv_threshold() {
        let mut rng = Rng::new(9);
        let skew = gen::power_law(600, 8, 2.1, &mut rng);
        let handle = SpmvHandle::builder(&skew)
            .policy(TuningPolicy::Heuristic)
            .backend(BackendChoice::Native)
            .threads(4)
            .quick(true)
            .build()
            .unwrap();
        let rep = handle.report();
        assert!(
            rep.row_imbalance_cv > rep.schedule_cv_threshold,
            "power-law CV {} must exceed the threshold {}",
            rep.row_imbalance_cv,
            rep.schedule_cv_threshold
        );
        assert!(
            matches!(handle.schedule(), Schedule::Dynamic { .. } | Schedule::Guided { .. }),
            "imbalance above the CV threshold must flip the schedule, got {}",
            handle.schedule().name()
        );
        let flat = gen::random_band(600, 8, 30, &mut rng);
        let regular = SpmvHandle::builder(&flat)
            .policy(TuningPolicy::Heuristic)
            .backend(BackendChoice::Native)
            .threads(4)
            .quick(true)
            .build()
            .unwrap();
        let rep = regular.report();
        assert!(rep.row_imbalance_cv < rep.schedule_cv_threshold);
        assert!(
            matches!(regular.schedule(), Schedule::Static { .. }),
            "a regular band matrix must stay static, got {}",
            regular.schedule().name()
        );
    }

    /// ISSUE-5 satellite: arbitration-decision determinism — the same
    /// matrix and policy must produce the same [`BackendDecision`],
    /// candidate scores included.
    #[test]
    fn heuristic_arbitration_is_deterministic_and_recorded() {
        let coo = hh();
        let build = || {
            SpmvHandle::builder(&coo)
                .policy(TuningPolicy::Heuristic)
                .threads(3)
                .quick(true)
                .build()
                .unwrap()
        };
        let a = build();
        let b = build();
        let da = a.backend_decision().expect("auto build records a decision").clone();
        let db = b.backend_decision().unwrap().clone();
        assert_eq!(da, db, "same matrix + policy must give the same decision");
        assert_eq!(a.backend_name(), b.backend_name());
        assert_eq!(da.policy, "heuristic");
        assert_eq!(da.candidates.iter().filter(|c| c.chosen).count(), 1);
        // Internal consistency: the chosen candidate has the best score.
        let chosen = da.candidates.iter().find(|c| c.chosen).unwrap();
        let best = da
            .candidates
            .iter()
            .map(|c| c.predicted_ns_per_call.unwrap())
            .fold(f64::INFINITY, f64::min);
        assert_eq!(chosen.predicted_ns_per_call.unwrap(), best);
        assert_eq!(chosen.backend, a.backend_name());
        // The decision shows up in the rendered report.
        assert!(a.report().tables().iter().any(|t| t.title.contains("backend")));
        // And the handle still reproduces the serial CRS reference.
        let crs = Crs::from_coo(&coo);
        let n = crs.nrows;
        let mut x = vec![0.0; n];
        Rng::new(121).fill_f64(&mut x, -1.0, 1.0);
        let mut want = vec![0.0; n];
        crs.spmv(&x, &mut want);
        let mut got = vec![0.0; n];
        a.spmv(&x, &mut got);
        assert!(max_abs_diff(&want, &got) < 1e-12);
    }

    /// A matrix whose whole multiply costs less than one engine dispatch
    /// must be served serially by the heuristic.
    #[test]
    fn heuristic_picks_serial_for_tiny_matrices() {
        let coo = gen::laplacian_1d(64);
        let handle = SpmvHandle::builder(&coo)
            .policy(TuningPolicy::Heuristic)
            .threads(4)
            .quick(true)
            .build()
            .unwrap();
        assert_eq!(handle.backend_name(), "serial");
        assert_eq!(handle.n_threads(), 1);
        assert!(handle.kernel().is_some());
        assert!(handle.plan().is_none(), "serial backend has no plan");
    }

    /// The pure scoring rule: dispatch costs push tiny matrices serial,
    /// parallelism pushes large ones native, and scale + small halo
    /// pushes the largest to the sharded executor.
    #[test]
    fn score_backends_crosses_over_with_scale() {
        let features = [(2usize, 0.01, 0.05), (4usize, 0.02, 0.10), (8usize, 0.04, 0.20)];
        // Tiny: 5k nnz at 2 ns/nnz = 10 us of work; one 20 us dispatch
        // can never pay off.
        let (c, _, _) = score_backends(5_000.0, 4.0, 2.0, 0.3, &features);
        assert_eq!(c.iter().find(|x| x.chosen).unwrap().backend, "serial");
        // Large: 5M nnz; threads win, shard spawn cost still dominates
        // the extra parallelism at 4 threads... until the matrix is huge.
        let (c, _, _) = score_backends(2_000_000.0, 4.0, 2.0, 0.3, &[]);
        assert_eq!(c.iter().find(|x| x.chosen).unwrap().backend, "native");
        // Huge + near-zero halo: the sharded executor's extra domains
        // beat the per-shard coordinator cost.
        let (c, pick, _) = score_backends(50_000_000.0, 4.0, 2.0, 0.3, &features);
        assert_eq!(c.iter().find(|x| x.chosen).unwrap().backend, "sharded");
        assert!(pick.is_some());
        // A huge halo disqualifies the partition entirely.
        let (c, pick, _) =
            score_backends(50_000_000.0, 4.0, 2.0, 0.3, &[(8, 0.9, 0.9)]);
        assert!(c.iter().all(|x| x.backend != "sharded"));
        assert!(pick.is_none());
    }

    /// Measured arbitration times every backend and keeps the fastest.
    #[test]
    fn measured_arbitration_times_all_backends() {
        let coo = hh();
        let handle = SpmvHandle::builder(&coo)
            .policy(TuningPolicy::Measured)
            .threads(2)
            .quick(true)
            .build()
            .unwrap();
        let d = handle.backend_decision().unwrap();
        assert_eq!(d.policy, "measured");
        assert_eq!(d.candidates.len(), 3);
        assert!(d.candidates.iter().all(|c| c.measured_ns_per_nnz.is_some()));
        let chosen = d.candidates.iter().find(|c| c.chosen).unwrap();
        let best = d
            .candidates
            .iter()
            .map(|c| c.measured_ns_per_nnz.unwrap())
            .fold(f64::INFINITY, f64::min);
        assert_eq!(chosen.measured_ns_per_nnz.unwrap(), best);
        assert_eq!(chosen.backend, handle.backend_name());
        // Whatever won, the math is unchanged.
        let crs = Crs::from_coo(&coo);
        let n = crs.nrows;
        let mut x = vec![0.0; n];
        Rng::new(122).fill_f64(&mut x, -1.0, 1.0);
        let mut want = vec![0.0; n];
        crs.spmv(&x, &mut want);
        let mut got = vec![0.0; n];
        handle.spmv(&x, &mut got);
        assert!(max_abs_diff(&want, &got) < 1e-12);
    }

    #[test]
    fn forced_backends_and_capabilities() {
        let coo = hh();
        let fixed = TuningPolicy::Fixed(Scheme::Crs, Schedule::Static { chunk: None });
        let native = SpmvHandle::builder(&coo)
            .policy(fixed)
            .backend(BackendChoice::Native)
            .threads(2)
            .build()
            .unwrap();
        assert_eq!(native.backend_name(), "native");
        assert_eq!(native.backend_decision().unwrap().policy, "forced");
        assert!(native.kernel().is_some() && native.plan().is_some());
        let re = native.replanned(Schedule::Dynamic { chunk: 7 }, 3).unwrap();
        assert_eq!(re.schedule(), Schedule::Dynamic { chunk: 7 });
        assert_eq!(re.n_threads(), 3);
        let mut sharded = SpmvHandle::builder(&coo)
            .policy(fixed)
            .backend(BackendChoice::Sharded)
            .shard_policy(ShardPolicy::Fixed { shards: 2, mode: OverlapMode::BulkSync })
            .threads(1)
            .build()
            .unwrap();
        assert_eq!(sharded.backend_name(), "sharded");
        assert_eq!(sharded.n_shards(), 2);
        assert_eq!(sharded.mode(), Some(OverlapMode::BulkSync));
        assert!(sharded.kernel().is_none() && sharded.plan().is_none());
        assert!(sharded.replanned(Schedule::Static { chunk: None }, 2).is_err());
        sharded.reshard(4, OverlapMode::Overlapped).unwrap();
        assert_eq!(sharded.n_shards(), 4);
        let serial = SpmvHandle::builder(&coo)
            .policy(fixed)
            .backend(BackendChoice::Serial)
            .build()
            .unwrap();
        assert_eq!(serial.backend_name(), "serial");
        assert!(serial.kernel().is_some());
        let mut yp = vec![0.0; 4];
        assert!(serial.spmv_permuted(&[0.0; 4], &mut yp).is_err());
    }

    #[test]
    fn shard_policy_requires_sharded_or_auto_backend() {
        let coo = gen::laplacian_1d(64);
        let err = SpmvHandle::builder(&coo)
            .backend(BackendChoice::Native)
            .shard_policy(ShardPolicy::Heuristic)
            .build();
        assert!(err.is_err(), "shard policy + forced native must be rejected");
    }

    #[test]
    fn non_square_matrix_is_rejected() {
        let mut coo = Coo::new(4, 7);
        coo.push(0, 6, 1.0);
        coo.normalize();
        assert!(SpmvHandle::builder(&coo).build().is_err());
    }

    /// ISSUE-5 satellite: the schedule CV threshold knob flows through
    /// the facade and is recorded in the report.
    #[test]
    fn schedule_cv_threshold_knob_flows_through() {
        let coo = hh();
        let handle = SpmvHandle::builder(&coo)
            .policy(TuningPolicy::Heuristic)
            .backend(BackendChoice::Native)
            .threads(2)
            .quick(true)
            .schedule_cv_threshold(9.0)
            .build()
            .unwrap();
        assert_eq!(handle.report().schedule_cv_threshold, 9.0);
        assert_eq!(
            handle.schedule(),
            Schedule::Static { chunk: None },
            "a sky-high threshold keeps every matrix static"
        );
        let default = SpmvHandle::builder(&coo)
            .policy(TuningPolicy::Heuristic)
            .backend(BackendChoice::Native)
            .threads(2)
            .quick(true)
            .build()
            .unwrap();
        assert_eq!(default.report().schedule_cv_threshold, tune::SCHEDULE_CV_THRESHOLD);
    }

    #[test]
    fn rebalance_keeps_bit_identity_on_every_backend() {
        let coo = hh();
        let crs = Crs::from_coo(&coo);
        let n = crs.nrows;
        let mut x = vec![0.0; n];
        Rng::new(123).fill_f64(&mut x, -1.0, 1.0);
        let mut want = vec![0.0; n];
        crs.spmv(&x, &mut want);
        for backend in [BackendChoice::Serial, BackendChoice::Native, BackendChoice::Sharded] {
            let mut b = SpmvHandle::builder(&coo)
                .policy(TuningPolicy::Fixed(Scheme::Crs, Schedule::Static { chunk: None }))
                .backend(backend)
                .threads(2);
            if backend == BackendChoice::Sharded {
                b = b.shard_policy(ShardPolicy::Fixed {
                    shards: 2,
                    mode: OverlapMode::Overlapped,
                });
            }
            let mut handle = b.build().unwrap();
            handle.rebalance(Schedule::Dynamic { chunk: 9 });
            let mut got = vec![0.0; n];
            handle.spmv(&x, &mut got);
            assert_eq!(
                max_abs_diff(&want, &got),
                0.0,
                "{}: rebalance changed results",
                backend.name()
            );
        }
    }

    #[test]
    fn handle_drives_linear_op_consumers() {
        use crate::eigen::{lanczos, LanczosConfig};
        let coo = gen::laplacian_1d(150);
        let crs = Crs::from_coo(&coo);
        let want = lanczos(&crs, 1, &LanczosConfig::default());
        let handle = SpmvHandle::builder(&coo)
            .policy(TuningPolicy::Fixed(Scheme::Crs, Schedule::Static { chunk: None }))
            .threads(2)
            .quick(true)
            .build()
            .unwrap();
        let got = lanczos(&handle, 1, &LanczosConfig::default());
        assert!(got.converged);
        assert!((got.eigenvalues[0] - want.eigenvalues[0]).abs() < 1e-10);
    }

    #[test]
    fn backend_choice_parse_roundtrip() {
        for c in [
            BackendChoice::Auto,
            BackendChoice::Serial,
            BackendChoice::Native,
            BackendChoice::Sharded,
        ] {
            assert_eq!(BackendChoice::parse(c.name()).unwrap(), c);
        }
        assert!(BackendChoice::parse("pjrt").is_err());
    }

    /// ISSUE-6 tentpole: the default contract is BitIdentical and no
    /// backend ever serves a vector kernel under it — the existing
    /// bit-identity suite is untouched by the SIMD layer.
    #[test]
    fn default_precision_is_bit_identical_and_scalar_on_every_backend() {
        let coo = gen::holstein_hubbard(&gen::HolsteinHubbardParams::tiny());
        for backend in [BackendChoice::Serial, BackendChoice::Native, BackendChoice::Sharded] {
            let mut b = SpmvHandle::builder(&coo).backend(backend).threads(2).quick(true);
            if backend == BackendChoice::Sharded {
                b = b.shard_policy(ShardPolicy::Fixed {
                    shards: 2,
                    mode: OverlapMode::BulkSync,
                });
            }
            let handle = b.build().unwrap();
            assert_eq!(handle.precision(), Precision::BitIdentical);
            assert_eq!(
                handle.kernel_isa(),
                IsaLevel::Scalar,
                "{}: BitIdentical must stay scalar",
                backend.name()
            );
        }
    }

    /// ISSUE-6 (amended by ISSUE-9): Tolerance(ε) results match the
    /// serial CRS reference within ε across scheme × schedule ×
    /// backend, and the report records the contract plus the bound ISA
    /// per backend honestly. Since ISSUE 9 the sharded split kernels
    /// have vector bodies, so sharded binds the same arbitrated ceiling
    /// as native; only serial still executes scalar inline.
    #[test]
    fn tolerance_contract_holds_across_scheme_schedule_backend() {
        let eps = 1e-12;
        let coo = gen::holstein_hubbard(&gen::HolsteinHubbardParams::tiny());
        let crs = Crs::from_coo(&coo);
        let n = crs.nrows;
        let mut x = vec![0.0; n];
        Rng::new(0x51D).fill_f64(&mut x, -1.0, 1.0);
        let mut want = vec![0.0; n];
        crs.spmv(&x, &mut want);
        let schemes = [Scheme::Crs, Scheme::SellCs { c: 8, sigma: 64 }];
        let schedules = [
            Schedule::Static { chunk: None },
            Schedule::Dynamic { chunk: 13 },
            Schedule::Guided { min_chunk: 8 },
        ];
        for backend in [BackendChoice::Serial, BackendChoice::Native, BackendChoice::Sharded] {
            for scheme in schemes {
                for schedule in schedules {
                    let mut b = SpmvHandle::builder(&coo)
                        .policy(TuningPolicy::Fixed(scheme, schedule))
                        .backend(backend)
                        .threads(2)
                        .quick(true)
                        .precision(Precision::Tolerance(eps));
                    if backend == BackendChoice::Sharded {
                        b = b.shard_policy(ShardPolicy::Fixed {
                            shards: 2,
                            mode: OverlapMode::Overlapped,
                        });
                    }
                    let handle = b.build().unwrap();
                    assert_eq!(handle.precision(), Precision::Tolerance(eps));
                    match backend {
                        // Serial executes scalar inline; native and
                        // sharded both run at the contract's ceiling
                        // for vectorizable schemes (the sharded split
                        // kernels gained vector bodies in ISSUE 9).
                        BackendChoice::Serial => {
                            assert_eq!(handle.kernel_isa(), IsaLevel::Scalar)
                        }
                        _ => assert_eq!(handle.kernel_isa(), IsaLevel::detect()),
                    }
                    let mut y = vec![0.0; n];
                    handle.spmv(&x, &mut y);
                    for i in 0..n {
                        assert!(
                            (y[i] - want[i]).abs() <= eps * want[i].abs().max(1.0),
                            "{} × {} × {}: row {i} off by {:.3e} (isa {})",
                            backend.name(),
                            scheme.name(),
                            schedule.name(),
                            (y[i] - want[i]).abs(),
                            handle.kernel_isa()
                        );
                    }
                    // Blocked-x SpMM keeps its win under a vector ISA
                    // (ISSUE 9 re-pricing) and stays within ε too.
                    let d = handle.multi_decision(3);
                    assert!(d.blocked, "k=3 must price blocked-x even with SIMD bound");
                    let xs = vec![x.clone(), x.clone(), x.clone()];
                    for y in handle.spmv_multi(&xs) {
                        for i in 0..n {
                            assert!(
                                (y[i] - want[i]).abs() <= eps * want[i].abs().max(1.0),
                                "{} × {} × {}: multi row {i} off by {:.3e}",
                                backend.name(),
                                scheme.name(),
                                schedule.name(),
                                (y[i] - want[i]).abs()
                            );
                        }
                    }
                }
            }
        }
    }

    /// Tolerance flows through Auto arbitration: the decision is still
    /// recorded, the ISA never exceeds the host, and results respect ε.
    #[test]
    fn auto_arbitration_respects_the_tolerance_contract() {
        let eps = 1e-12;
        let coo = gen::holstein_hubbard(&gen::HolsteinHubbardParams::tiny());
        let crs = Crs::from_coo(&coo);
        let n = crs.nrows;
        let mut x = vec![0.0; n];
        Rng::new(0x51E).fill_f64(&mut x, -1.0, 1.0);
        let mut want = vec![0.0; n];
        crs.spmv(&x, &mut want);
        for policy in [TuningPolicy::Heuristic, TuningPolicy::Measured] {
            let handle = SpmvHandle::builder(&coo)
                .policy(policy)
                .threads(2)
                .quick(true)
                .precision(Precision::Tolerance(eps))
                .build()
                .unwrap();
            assert!(handle.backend_decision().is_some());
            assert!(handle.kernel_isa() <= IsaLevel::detect());
            let mut y = vec![0.0; n];
            handle.spmv(&x, &mut y);
            for i in 0..n {
                assert!(
                    (y[i] - want[i]).abs() <= eps * want[i].abs().max(1.0),
                    "{} arbitration: row {i} off by {:.3e}",
                    policy.name(),
                    (y[i] - want[i]).abs()
                );
            }
        }
    }
}
