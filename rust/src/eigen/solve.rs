//! Iterative solvers beside Lanczos: conjugate gradients and power
//! iteration (with PageRank as its canonical consumer). Both are pure
//! SpMV + axpy loops over [`LinearOp`], so they run unchanged through
//! any [`crate::spmv::SpmvHandle`] — the solver never names a backend,
//! and every backend's bit-compatibility with the serial kernels makes
//! the handle-backed runs reproduce the serial solves exactly under
//! the default precision contract.

use crate::matrix::Coo;
use crate::util::rng::Rng;

use super::lanczos::LinearOp;

fn dot(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

fn norm(a: &[f64]) -> f64 {
    dot(a, a).sqrt()
}

/// Conjugate-gradient configuration.
#[derive(Debug, Clone)]
pub struct CgConfig {
    pub max_iters: usize,
    /// Convergence tolerance on `‖r‖ / ‖b‖`.
    pub tol: f64,
}

impl Default for CgConfig {
    fn default() -> Self {
        Self { max_iters: 500, tol: 1e-10 }
    }
}

/// Result of a CG solve.
#[derive(Debug, Clone)]
pub struct CgResult {
    pub x: Vec<f64>,
    pub iterations: usize,
    pub converged: bool,
    /// Final relative residual `‖b − Ax‖ / ‖b‖` (recurrence residual).
    pub residual_norm: f64,
    /// Number of operator applications (SpMVs) performed.
    pub spmv_count: usize,
    /// Relative residual per iteration.
    pub history: Vec<f64>,
}

/// Solve `A x = b` for symmetric positive-definite `A` by conjugate
/// gradients: one SpMV and a handful of axpy/dot passes per iteration,
/// starting from `x = 0`.
pub fn cg(op: &dyn LinearOp, b: &[f64], cfg: &CgConfig) -> CgResult {
    let n = op.dim();
    assert_eq!(b.len(), n, "rhs length must match the operator dimension");
    let nb = norm(b);
    if nb == 0.0 {
        return CgResult {
            x: vec![0.0; n],
            iterations: 0,
            converged: true,
            residual_norm: 0.0,
            spmv_count: 0,
            history: Vec::new(),
        };
    }
    let mut x = vec![0.0; n];
    let mut r = b.to_vec();
    let mut p = r.clone();
    let mut ap = vec![0.0; n];
    let mut rr = dot(&r, &r);
    let mut history = Vec::new();
    let mut spmv_count = 0usize;
    let mut converged = false;
    let mut iterations = 0usize;
    for _ in 0..cfg.max_iters {
        op.apply(&p, &mut ap);
        spmv_count += 1;
        iterations += 1;
        let alpha = rr / dot(&p, &ap);
        for i in 0..n {
            x[i] += alpha * p[i];
            r[i] -= alpha * ap[i];
        }
        let rr_next = dot(&r, &r);
        let rel = rr_next.sqrt() / nb;
        history.push(rel);
        if rel < cfg.tol {
            converged = true;
            rr = rr_next;
            break;
        }
        let beta = rr_next / rr;
        rr = rr_next;
        for i in 0..n {
            p[i] = r[i] + beta * p[i];
        }
    }
    CgResult {
        x,
        iterations,
        converged,
        residual_norm: rr.sqrt() / nb,
        spmv_count,
        history,
    }
}

/// CG with the hot-loop SpMV routed through a tuned
/// [`crate::spmv::SpmvHandle`] — the solver runs on whatever backend
/// arbitration bound.
pub fn cg_with_handle(handle: &crate::spmv::SpmvHandle, b: &[f64], cfg: &CgConfig) -> CgResult {
    cg(handle, b, cfg)
}

/// Power-iteration configuration.
#[derive(Debug, Clone)]
pub struct PowerConfig {
    pub max_iters: usize,
    /// Convergence tolerance on `‖A v − λ v‖ / |λ|`.
    pub tol: f64,
    pub seed: u64,
}

impl Default for PowerConfig {
    fn default() -> Self {
        Self { max_iters: 2000, tol: 1e-10, seed: 12345 }
    }
}

/// Result of a power-iteration run.
#[derive(Debug, Clone)]
pub struct PowerResult {
    /// Rayleigh quotient of the final iterate — the dominant eigenvalue
    /// (largest |λ|) at convergence.
    pub eigenvalue: f64,
    /// Normalized final iterate.
    pub eigenvector: Vec<f64>,
    pub iterations: usize,
    pub converged: bool,
    pub spmv_count: usize,
}

/// Plain power iteration: repeated SpMV + normalization converging to
/// the dominant eigenpair. One SpMV per iteration.
pub fn power_iteration(op: &dyn LinearOp, cfg: &PowerConfig) -> PowerResult {
    let n = op.dim();
    let mut rng = Rng::new(cfg.seed);
    let mut v = vec![0.0; n];
    rng.fill_f64(&mut v, -1.0, 1.0);
    let nv = norm(&v);
    v.iter_mut().for_each(|x| *x /= nv);
    let mut av = vec![0.0; n];
    let mut lambda = 0.0;
    let mut spmv_count = 0usize;
    let mut converged = false;
    let mut iterations = 0usize;
    for _ in 0..cfg.max_iters {
        op.apply(&v, &mut av);
        spmv_count += 1;
        iterations += 1;
        lambda = dot(&v, &av); // Rayleigh quotient (v is normalized)
        // Residual ‖A v − λ v‖ relative to |λ|.
        let mut res = 0.0;
        for i in 0..n {
            let d = av[i] - lambda * v[i];
            res += d * d;
        }
        if res.sqrt() <= cfg.tol * lambda.abs().max(1e-300) {
            converged = true;
            break;
        }
        let na = norm(&av);
        if na == 0.0 {
            break; // v in the null space: nothing dominant to find
        }
        for i in 0..n {
            v[i] = av[i] / na;
        }
    }
    PowerResult { eigenvalue: lambda, eigenvector: v, iterations, converged, spmv_count }
}

/// Power iteration through a tuned [`crate::spmv::SpmvHandle`].
pub fn power_iteration_with_handle(
    handle: &crate::spmv::SpmvHandle,
    cfg: &PowerConfig,
) -> PowerResult {
    power_iteration(handle, cfg)
}

/// Result of a PageRank run.
#[derive(Debug, Clone)]
pub struct PageRankResult {
    /// L1-normalized rank vector (sums to 1).
    pub ranks: Vec<f64>,
    pub iterations: usize,
    pub converged: bool,
    pub spmv_count: usize,
}

/// Column-stochastic transition matrix of an adjacency matrix: entry
/// `(i, j, w)` of `adj` (an edge `i → j` of weight `w > 0`) becomes
/// `M[j][i] = w / outweight(i)`, so every column of `M` sums to 1 and
/// `M · x` pushes rank mass along the edges. Dangling rows (no
/// out-edges) get a self-loop — the generated graphs
/// ([`crate::gen::power_law`], [`crate::gen::rmat`]) never produce one,
/// but MatrixMarket inputs can.
pub fn transition_matrix(adj: &Coo) -> Coo {
    let n = adj.nrows;
    let mut out_weight = vec![0.0; n];
    for &(i, _, w) in &adj.entries {
        assert!(w > 0.0, "transition_matrix needs positive edge weights");
        out_weight[i] += w;
    }
    let mut t = Coo::with_capacity(n, n, adj.nnz() + n);
    for &(i, j, w) in &adj.entries {
        t.push(j, i, w / out_weight[i]);
    }
    for (i, &ow) in out_weight.iter().enumerate() {
        if ow == 0.0 {
            t.push(i, i, 1.0);
        }
    }
    t.normalize();
    t
}

/// PageRank as damped power iteration over a column-stochastic
/// transition operator (build one with [`transition_matrix`]):
/// `x ← d·(M x) + (1−d)/n`, iterated from the uniform vector until the
/// L1 change drops below `cfg.tol`. One SpMV per iteration — the
/// canonical SpMV consumer on scale-free graphs.
pub fn pagerank(op: &dyn LinearOp, damping: f64, cfg: &PowerConfig) -> PageRankResult {
    assert!((0.0..1.0).contains(&damping), "damping must be in [0, 1)");
    let n = op.dim();
    let teleport = (1.0 - damping) / n as f64;
    let mut x = vec![1.0 / n as f64; n];
    let mut mx = vec![0.0; n];
    let mut spmv_count = 0usize;
    let mut converged = false;
    let mut iterations = 0usize;
    for _ in 0..cfg.max_iters {
        op.apply(&x, &mut mx);
        spmv_count += 1;
        iterations += 1;
        let mut delta = 0.0;
        for i in 0..n {
            let next = damping * mx[i] + teleport;
            delta += (next - x[i]).abs();
            x[i] = next;
        }
        // A column-stochastic operator keeps ‖x‖₁ = 1 exactly; re-derive
        // it anyway so float drift can't compound over long runs.
        let l1: f64 = x.iter().map(|v| v.abs()).sum();
        x.iter_mut().for_each(|v| *v /= l1);
        if delta < cfg.tol {
            converged = true;
            break;
        }
    }
    PageRankResult { ranks: x, iterations, converged, spmv_count }
}

/// PageRank with the transition SpMV routed through a tuned
/// [`crate::spmv::SpmvHandle`] built on the transition matrix.
pub fn pagerank_with_handle(
    handle: &crate::spmv::SpmvHandle,
    damping: f64,
    cfg: &PowerConfig,
) -> PageRankResult {
    pagerank(handle, damping, cfg)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen;
    use crate::matrix::{Crs, Scheme, SpMv};
    use crate::sched::Schedule;
    use crate::shard::OverlapMode;
    use crate::spmv::{BackendChoice, SpmvHandle};
    use crate::tune::{ShardPolicy, TuningPolicy};
    use crate::util::stats::max_abs_diff;

    #[test]
    fn cg_solves_laplacian_to_known_solution() {
        let n = 100;
        let a = Crs::from_coo(&gen::laplacian_1d(n));
        let x_true: Vec<f64> = (0..n).map(|i| (i as f64 * 0.37).sin()).collect();
        let mut b = vec![0.0; n];
        a.spmv(&x_true, &mut b);
        let r = cg(&a, &b, &CgConfig::default());
        assert!(r.converged, "CG must converge on an SPD Laplacian");
        assert!(r.residual_norm < 1e-10);
        assert_eq!(r.spmv_count, r.iterations);
        assert!(
            max_abs_diff(&r.x, &x_true) < 1e-6,
            "solution error {}",
            max_abs_diff(&r.x, &x_true)
        );
    }

    #[test]
    fn cg_zero_rhs_is_trivially_converged() {
        let a = Crs::from_coo(&gen::laplacian_1d(10));
        let r = cg(&a, &[0.0; 10], &CgConfig::default());
        assert!(r.converged);
        assert_eq!(r.spmv_count, 0);
        assert!(r.x.iter().all(|&v| v == 0.0));
    }

    /// ISSUE-8 tentpole: the solver loop is backend-agnostic — every
    /// backend × scheme reproduces the serial CG run bit for bit (the
    /// facade's bit-identity guarantee composed over a whole solve).
    #[test]
    fn handle_backed_cg_bit_identical_on_every_backend() {
        let coo = gen::laplacian_2d(12, 11);
        let crs = Crs::from_coo(&coo);
        let n = crs.nrows;
        let b: Vec<f64> = (0..n).map(|i| ((i % 7) as f64) - 3.0).collect();
        let serial = cg(&crs, &b, &CgConfig::default());
        assert!(serial.converged);
        for backend in [BackendChoice::Serial, BackendChoice::Native, BackendChoice::Sharded] {
            for scheme in [Scheme::Crs, Scheme::SellCs { c: 8, sigma: 64 }] {
                let mut bld = SpmvHandle::builder_from_crs(&crs)
                    .policy(TuningPolicy::Fixed(scheme, Schedule::Dynamic { chunk: 13 }))
                    .backend(backend)
                    .threads(2);
                if backend == BackendChoice::Sharded {
                    bld = bld.shard_policy(ShardPolicy::Fixed {
                        shards: 2,
                        mode: OverlapMode::Overlapped,
                    });
                }
                let handle = bld.build().unwrap();
                let r = cg_with_handle(&handle, &b, &CgConfig::default());
                assert!(r.converged);
                assert_eq!(
                    max_abs_diff(&r.x, &serial.x),
                    0.0,
                    "{} × {scheme}: handle-backed CG deviates from serial",
                    backend.name()
                );
            }
        }
    }

    /// n = 20 keeps the spectral-gap ratio λ₂/λ₁ ≈ 0.983, so the
    /// 1e-10 residual lands near iteration 1300 — comfortably inside
    /// the default budget (larger 1-D Laplacians close the gap and
    /// push plain power iteration past `max_iters`).
    #[test]
    fn power_iteration_finds_dominant_laplacian_eigenvalue() {
        let n = 20;
        let a = Crs::from_coo(&gen::laplacian_1d(n));
        let r = power_iteration(&a, &PowerConfig::default());
        assert!(r.converged);
        let exact = 2.0 - 2.0 * (n as f64 * std::f64::consts::PI / (n as f64 + 1.0)).cos();
        assert!(
            (r.eigenvalue - exact).abs() < 1e-6,
            "dominant {} vs exact {exact}",
            r.eigenvalue
        );
        assert_eq!(r.spmv_count, r.iterations);
        assert!((norm(&r.eigenvector) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn pagerank_on_power_law_graph_ranks_the_hubs() {
        let n = 200;
        let adj = gen::power_law(n, 8, 2.2, &mut Rng::new(7));
        let t = Crs::from_coo(&transition_matrix(&adj));
        let r = pagerank(&t, 0.85, &PowerConfig::default());
        assert!(r.converged, "PageRank must converge under damping 0.85");
        let sum: f64 = r.ranks.iter().sum();
        assert!((sum - 1.0).abs() < 1e-9, "ranks must sum to 1, got {sum}");
        assert!(r.ranks.iter().all(|&v| v > 0.0), "teleportation keeps every rank positive");
        // The generator aims edges at low-index hubs; node 0 must hold
        // far more than the uniform 1/n share.
        assert!(
            r.ranks[0] > 5.0 / n as f64,
            "hub rank {} is not above 5× uniform",
            r.ranks[0]
        );
    }

    /// The canonical consumer end to end: PageRank via power iteration
    /// on a row-stochastic graph, through an auto-arbitrated handle —
    /// bit-identical to the serial run.
    #[test]
    fn handle_backed_pagerank_matches_serial() {
        let adj = gen::power_law(150, 6, 2.4, &mut Rng::new(8));
        let t_coo = transition_matrix(&adj);
        let t = Crs::from_coo(&t_coo);
        let serial = pagerank(&t, 0.85, &PowerConfig::default());
        let handle = SpmvHandle::builder(&t_coo)
            .policy(TuningPolicy::Heuristic)
            .threads(2)
            .quick(true)
            .build()
            .unwrap();
        let r = pagerank_with_handle(&handle, 0.85, &PowerConfig::default());
        assert!(r.converged);
        assert_eq!(
            max_abs_diff(&r.ranks, &serial.ranks),
            0.0,
            "handle-backed PageRank ({} backend) deviates from serial",
            handle.backend_name()
        );
        let pw = power_iteration_with_handle(&handle, &PowerConfig::default());
        let pws = power_iteration(&t, &PowerConfig::default());
        assert_eq!(pw.eigenvalue.to_bits(), pws.eigenvalue.to_bits());
    }

    #[test]
    fn transition_matrix_is_column_stochastic_and_handles_dangling_rows() {
        let mut adj = Coo::new(4, 4);
        adj.push(0, 1, 2.0);
        adj.push(0, 2, 2.0);
        adj.push(1, 0, 1.0);
        // row 2 and row 3 dangle (no out-edges)
        adj.normalize();
        let t = transition_matrix(&adj);
        let mut col_sums = vec![0.0; 4];
        for &(_, c, v) in &t.entries {
            col_sums[c] += v;
        }
        for (c, s) in col_sums.iter().enumerate() {
            assert!((s - 1.0).abs() < 1e-12, "column {c} sums to {s}");
        }
    }
}
