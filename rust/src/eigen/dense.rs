//! Dense symmetric eigensolver (cyclic Jacobi rotations) — the reference
//! oracle for validating the Lanczos solver and the Hamiltonian
//! generator on small systems. O(n³) per sweep; fine up to n ≈ 1000.

/// Eigen-decomposition of a dense symmetric matrix (row-major `n × n`).
/// Returns eigenvalues in ascending order. If `want_vectors`, also
/// returns the corresponding orthonormal eigenvectors as rows.
pub fn jacobi_eigen(
    a_in: &[Vec<f64>],
    want_vectors: bool,
) -> (Vec<f64>, Option<Vec<Vec<f64>>>) {
    let n = a_in.len();
    assert!(a_in.iter().all(|r| r.len() == n), "matrix must be square");
    // Work on a flat copy.
    let mut a: Vec<f64> = a_in.iter().flatten().copied().collect();
    let mut v: Vec<f64> = if want_vectors {
        let mut id = vec![0.0; n * n];
        for i in 0..n {
            id[i * n + i] = 1.0;
        }
        id
    } else {
        Vec::new()
    };

    let idx = |i: usize, j: usize| i * n + j;
    let off = |a: &[f64]| -> f64 {
        let mut s = 0.0;
        for i in 0..n {
            for j in 0..n {
                if i != j {
                    s += a[idx(i, j)] * a[idx(i, j)];
                }
            }
        }
        s
    };

    let mut sweeps = 0;
    while off(&a) > 1e-22 * n as f64 && sweeps < 100 {
        sweeps += 1;
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = a[idx(p, q)];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = a[idx(p, p)];
                let aqq = a[idx(q, q)];
                let theta = (aqq - app) / (2.0 * apq);
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // Rotate rows/cols p and q.
                for k in 0..n {
                    let akp = a[idx(k, p)];
                    let akq = a[idx(k, q)];
                    a[idx(k, p)] = c * akp - s * akq;
                    a[idx(k, q)] = s * akp + c * akq;
                }
                for k in 0..n {
                    let apk = a[idx(p, k)];
                    let aqk = a[idx(q, k)];
                    a[idx(p, k)] = c * apk - s * aqk;
                    a[idx(q, k)] = s * apk + c * aqk;
                }
                if want_vectors {
                    for k in 0..n {
                        let vkp = v[idx(k, p)];
                        let vkq = v[idx(k, q)];
                        v[idx(k, p)] = c * vkp - s * vkq;
                        v[idx(k, q)] = s * vkp + c * vkq;
                    }
                }
            }
        }
    }

    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&i, &j| a[idx(i, i)].partial_cmp(&a[idx(j, j)]).unwrap());
    let evals: Vec<f64> = order.iter().map(|&i| a[idx(i, i)]).collect();
    let evecs = if want_vectors {
        Some(
            order
                .iter()
                .map(|&col| (0..n).map(|r| v[idx(r, col)]).collect())
                .collect(),
        )
    } else {
        None
    };
    (evals, evecs)
}

/// Eigenvalues of a symmetric tridiagonal matrix given diagonal `d` and
/// off-diagonal `e` (len n-1), via Jacobi on the dense embedding. Used
/// for the small projected matrices produced by Lanczos.
pub fn tridiag_eigenvalues(d: &[f64], e: &[f64]) -> Vec<f64> {
    let n = d.len();
    assert_eq!(e.len(), n.saturating_sub(1));
    let mut a = vec![vec![0.0; n]; n];
    for i in 0..n {
        a[i][i] = d[i];
        if i + 1 < n {
            a[i][i + 1] = e[i];
            a[i + 1][i] = e[i];
        }
    }
    jacobi_eigen(&a, false).0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn two_by_two_exact() {
        let a = vec![vec![2.0, 1.0], vec![1.0, 2.0]];
        let (ev, vecs) = jacobi_eigen(&a, true);
        assert!((ev[0] - 1.0).abs() < 1e-12);
        assert!((ev[1] - 3.0).abs() < 1e-12);
        let v = vecs.unwrap();
        // eigenvector for lambda=1 is (1,-1)/sqrt2 up to sign
        let ratio = v[0][0] / v[0][1];
        assert!((ratio + 1.0).abs() < 1e-10);
    }

    #[test]
    fn laplacian_eigenvalues_match_closed_form() {
        // 1D Dirichlet Laplacian: lambda_k = 2 - 2 cos(k pi / (n+1)).
        let n = 12;
        let mut a = vec![vec![0.0; n]; n];
        for i in 0..n {
            a[i][i] = 2.0;
            if i + 1 < n {
                a[i][i + 1] = -1.0;
                a[i + 1][i] = -1.0;
            }
        }
        let (ev, _) = jacobi_eigen(&a, false);
        for (k, &l) in ev.iter().enumerate() {
            let exact = 2.0 - 2.0 * ((k + 1) as f64 * std::f64::consts::PI / (n as f64 + 1.0)).cos();
            assert!((l - exact).abs() < 1e-10, "k={k}: {l} vs {exact}");
        }
    }

    #[test]
    fn eigenvectors_satisfy_av_equals_lv() {
        let a = vec![
            vec![4.0, 1.0, 0.5],
            vec![1.0, 3.0, -0.2],
            vec![0.5, -0.2, 1.0],
        ];
        let (ev, vecs) = jacobi_eigen(&a, true);
        let v = vecs.unwrap();
        for (k, vec_k) in v.iter().enumerate() {
            for i in 0..3 {
                let av: f64 = (0..3).map(|j| a[i][j] * vec_k[j]).sum();
                assert!((av - ev[k] * vec_k[i]).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn tridiag_helper() {
        let d = vec![2.0, 2.0, 2.0];
        let e = vec![-1.0, -1.0];
        let ev = tridiag_eigenvalues(&d, &e);
        let s = std::f64::consts::SQRT_2;
        assert!((ev[0] - (2.0 - s)).abs() < 1e-10);
        assert!((ev[1] - 2.0).abs() < 1e-10);
        assert!((ev[2] - (2.0 + s)).abs() < 1e-10);
    }
}
