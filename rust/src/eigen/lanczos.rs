//! Lanczos eigensolver — the paper's motivating application (§1: sparse
//! eigenvalue solvers spend >99% of run time in SpMVM). Works over any
//! SpMV operator so the same solver drives native Rust kernels and the
//! PJRT-executed JAX/Pallas artifacts.

use crate::util::rng::Rng;

use super::dense::tridiag_eigenvalues;

/// Abstract matrix-vector product used by the iterative solvers. Blanket
/// impl for everything implementing [`crate::matrix::SpMv`], and
/// implemented by the PJRT runtime executor as well.
pub trait LinearOp {
    fn dim(&self) -> usize;
    fn apply(&self, x: &[f64], y: &mut [f64]);
}

impl<T: crate::matrix::SpMv> LinearOp for T {
    fn dim(&self) -> usize {
        debug_assert_eq!(self.nrows(), self.ncols());
        self.nrows()
    }
    fn apply(&self, x: &[f64], y: &mut [f64]) {
        self.spmv(x, y)
    }
}

/// Lanczos configuration.
#[derive(Debug, Clone)]
pub struct LanczosConfig {
    pub max_iters: usize,
    /// Convergence tolerance on the change of the lowest Ritz value.
    pub tol: f64,
    /// Full reorthogonalization (needed for tight eigenvalue accuracy;
    /// costs O(m·n) per iteration).
    pub full_reorth: bool,
    pub seed: u64,
}

impl Default for LanczosConfig {
    fn default() -> Self {
        Self { max_iters: 300, tol: 1e-10, full_reorth: true, seed: 12345 }
    }
}

/// Result of a Lanczos run.
#[derive(Debug, Clone)]
pub struct LanczosResult {
    /// Lowest Ritz values (ascending) of the final projected matrix.
    pub eigenvalues: Vec<f64>,
    pub iterations: usize,
    pub converged: bool,
    /// Number of operator applications (SpMVs) performed.
    pub spmv_count: usize,
    /// History of the lowest Ritz value per iteration.
    pub history: Vec<f64>,
}

fn dot(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

fn norm(a: &[f64]) -> f64 {
    dot(a, a).sqrt()
}

/// Run Lanczos on `op`, returning the `n_eigs` lowest eigenvalues.
pub fn lanczos(op: &dyn LinearOp, n_eigs: usize, cfg: &LanczosConfig) -> LanczosResult {
    let n = op.dim();
    assert!(n > 0);
    let n_eigs = n_eigs.min(n);
    let mut rng = Rng::new(cfg.seed);

    // v1: random normalized start vector.
    let mut v = vec![0.0; n];
    rng.fill_f64(&mut v, -1.0, 1.0);
    let nv = norm(&v);
    v.iter_mut().for_each(|x| *x /= nv);

    let mut basis: Vec<Vec<f64>> = vec![v.clone()];
    let mut alpha: Vec<f64> = Vec::new();
    let mut beta: Vec<f64> = Vec::new();
    let mut w = vec![0.0; n];
    let mut history = Vec::new();
    let mut spmv_count = 0usize;
    let mut prev_low = f64::INFINITY;
    let mut converged = false;

    let max_m = cfg.max_iters.min(n);
    for m in 0..max_m {
        let vm = basis[m].clone();
        op.apply(&vm, &mut w);
        spmv_count += 1;
        let a = dot(&w, &vm);
        alpha.push(a);
        // w -= a*v_m + b*v_{m-1}
        if m > 0 {
            let b = beta[m - 1];
            let vprev = &basis[m - 1];
            for i in 0..n {
                w[i] -= a * vm[i] + b * vprev[i];
            }
        } else {
            for i in 0..n {
                w[i] -= a * vm[i];
            }
        }
        if cfg.full_reorth {
            // Two passes of classical Gram-Schmidt against the basis.
            for _ in 0..2 {
                for q in &basis {
                    let c = dot(&w, q);
                    for i in 0..n {
                        w[i] -= c * q[i];
                    }
                }
            }
        }
        let b = norm(&w);
        // Ritz values of the current tridiagonal.
        let evals = tridiag_eigenvalues(&alpha, &beta);
        let low = evals[0];
        history.push(low);
        if (prev_low - low).abs() < cfg.tol * (1.0 + low.abs()) && m + 1 >= n_eigs {
            converged = true;
            break;
        }
        prev_low = low;
        if b < 1e-14 {
            // Invariant subspace found: exact within this Krylov space.
            converged = true;
            break;
        }
        beta.push(b);
        let mut next = w.clone();
        next.iter_mut().for_each(|x| *x /= b);
        basis.push(next);
    }

    let evals = tridiag_eigenvalues(&alpha, &beta);
    LanczosResult {
        eigenvalues: evals.into_iter().take(n_eigs.max(1)).collect(),
        iterations: alpha.len(),
        converged,
        spmv_count,
        history,
    }
}

/// Lanczos with the hot-loop SpMV routed through a tuned
/// [`crate::spmv::SpmvHandle`]: every operator application runs on
/// whatever backend arbitration bound (serial kernel, native engine,
/// sharded executor) — the solver never names one. Results are
/// identical to the serial solver of the tuned scheme (every backend is
/// bit-compatible with the serial kernels).
pub fn lanczos_with_handle(
    handle: &crate::spmv::SpmvHandle,
    n_eigs: usize,
    cfg: &LanczosConfig,
) -> LanczosResult {
    lanczos(handle, n_eigs, cfg)
}

/// Power iteration on (shift·I − A) to find the lowest eigenvalue — a
/// slower, simpler cross-check for the Lanczos result.
pub fn inverse_shifted_power(
    op: &dyn LinearOp,
    shift: f64,
    iters: usize,
    seed: u64,
) -> f64 {
    let n = op.dim();
    let mut rng = Rng::new(seed);
    let mut v = vec![0.0; n];
    rng.fill_f64(&mut v, -1.0, 1.0);
    let mut av = vec![0.0; n];
    for _ in 0..iters {
        op.apply(&v, &mut av);
        // w = shift*v - A v
        for i in 0..n {
            av[i] = shift * v[i] - av[i];
        }
        let nv = norm(&av);
        for i in 0..n {
            v[i] = av[i] / nv;
        }
    }
    op.apply(&v, &mut av);
    dot(&v, &av)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eigen::dense::jacobi_eigen;
    use crate::gen;
    use crate::matrix::Crs;

    #[test]
    fn laplacian_ground_state() {
        let n = 200;
        let m = Crs::from_coo(&gen::laplacian_1d(n));
        let r = lanczos(&m, 3, &LanczosConfig::default());
        assert!(r.converged);
        for k in 0..3 {
            let exact =
                2.0 - 2.0 * ((k + 1) as f64 * std::f64::consts::PI / (n as f64 + 1.0)).cos();
            assert!(
                (r.eigenvalues[k] - exact).abs() < 1e-7,
                "k={k}: {} vs {exact}",
                r.eigenvalues[k]
            );
        }
    }

    #[test]
    fn holstein_hubbard_matches_dense_reference() {
        // Tiny HH system: Lanczos ground state must match dense Jacobi.
        let p = gen::HolsteinHubbardParams {
            sites: 3,
            n_up: 1,
            n_down: 1,
            max_phonons: 2,
            t: 1.0,
            u: 4.0,
            g: 0.5,
            omega: 1.0,
            periodic: true,
        };
        let h = gen::holstein_hubbard(&p);
        let dense = h.to_dense();
        let (exact, _) = jacobi_eigen(&dense, false);
        let crs = Crs::from_coo(&h);
        let r = lanczos(&crs, 1, &LanczosConfig::default());
        assert!(
            (r.eigenvalues[0] - exact[0]).abs() < 1e-8,
            "lanczos {} vs dense {}",
            r.eigenvalues[0],
            exact[0]
        );
    }

    #[test]
    fn single_site_holstein_polaron_energy() {
        // One site, one electron, M phonons: H = w b†b - g w (b†+b).
        // Exact (M -> inf): E0 = -g² w. Truncation error is tiny for
        // M >> g².
        let p = gen::HolsteinHubbardParams {
            sites: 1,
            n_up: 1,
            n_down: 0,
            max_phonons: 30,
            t: 0.0,
            u: 0.0,
            g: 0.8,
            omega: 1.0,
            periodic: false,
        };
        let h = gen::holstein_hubbard(&p);
        assert_eq!(h.nrows, 31);
        let crs = Crs::from_coo(&h);
        let r = lanczos(&crs, 1, &LanczosConfig::default());
        let exact = -0.8f64 * 0.8;
        assert!(
            (r.eigenvalues[0] - exact).abs() < 1e-6,
            "polaron E0 {} vs {exact}",
            r.eigenvalues[0]
        );
    }

    #[test]
    fn handle_backed_lanczos_matches_serial_on_every_backend() {
        use crate::matrix::Scheme;
        use crate::sched::Schedule;
        use crate::shard::OverlapMode;
        use crate::spmv::{BackendChoice, SpmvHandle};
        use crate::tune::{ShardPolicy, TuningPolicy};
        let h = gen::holstein_hubbard(&gen::HolsteinHubbardParams::tiny());
        let crs = Crs::from_coo(&h);
        let serial = lanczos(&crs, 1, &LanczosConfig::default());
        for backend in [BackendChoice::Serial, BackendChoice::Native, BackendChoice::Sharded] {
            for scheme in [Scheme::Crs, Scheme::SellCs { c: 32, sigma: 256 }] {
                let mut b = SpmvHandle::builder_from_crs(&crs)
                    .policy(TuningPolicy::Fixed(scheme, Schedule::Static { chunk: None }))
                    .backend(backend)
                    .threads(4);
                if backend == BackendChoice::Sharded {
                    b = b.shard_policy(ShardPolicy::Fixed {
                        shards: 2,
                        mode: OverlapMode::Overlapped,
                    });
                }
                let handle = b.build().unwrap();
                let r = lanczos_with_handle(&handle, 1, &LanczosConfig::default());
                assert!(r.converged);
                assert!(
                    (r.eigenvalues[0] - serial.eigenvalues[0]).abs() < 1e-10,
                    "{} × {scheme}: handle {} vs serial {}",
                    backend.name(),
                    r.eigenvalues[0],
                    serial.eigenvalues[0]
                );
            }
        }
    }

    #[test]
    fn auto_arbitrated_lanczos_matches_serial() {
        use crate::spmv::SpmvHandle;
        use crate::tune::TuningPolicy;
        let h = gen::holstein_hubbard(&gen::HolsteinHubbardParams::tiny());
        let crs = Crs::from_coo(&h);
        let serial = lanczos(&crs, 1, &LanczosConfig::default());
        let handle = SpmvHandle::builder(&h)
            .policy(TuningPolicy::Heuristic)
            .threads(2)
            .quick(true)
            .build()
            .unwrap();
        assert!(handle.backend_decision().is_some(), "arbitration must be recorded");
        let r = lanczos_with_handle(&handle, 1, &LanczosConfig::default());
        assert!(r.converged);
        assert!(
            (r.eigenvalues[0] - serial.eigenvalues[0]).abs() < 1e-10,
            "tuned ({} on {}) {} vs serial {}",
            handle.scheme(),
            handle.backend_name(),
            r.eigenvalues[0],
            serial.eigenvalues[0]
        );
    }

    #[test]
    fn pinned_first_touch_lanczos_matches_serial() {
        // The solver's hot loop over a NUMA-placed handle (pinned
        // engine + first-touched workspace) must reproduce the serial
        // result exactly — on non-Linux hosts the pin falls back to a
        // recorded no-op and takes the same code path.
        use crate::spmv::{BackendChoice, SpmvHandle};
        use crate::tune::TuningPolicy;
        let h = gen::holstein_hubbard(&gen::HolsteinHubbardParams::tiny());
        let crs = Crs::from_coo(&h);
        let serial = lanczos(&crs, 1, &LanczosConfig::default());
        let handle = SpmvHandle::builder_from_crs(&crs)
            .policy(TuningPolicy::Heuristic)
            .backend(BackendChoice::Native)
            .threads(4)
            .quick(true)
            .pinned(true)
            .build()
            .unwrap();
        assert!(handle.plan().expect("native backend has a plan").first_touched());
        let r = lanczos_with_handle(&handle, 1, &LanczosConfig::default());
        assert!(r.converged);
        assert!(
            (r.eigenvalues[0] - serial.eigenvalues[0]).abs() < 1e-10,
            "pinned ({}) {} vs serial {}",
            handle.report().placement.summary(),
            r.eigenvalues[0],
            serial.eigenvalues[0]
        );
    }

    #[test]
    fn power_iteration_agrees_with_lanczos() {
        let m = Crs::from_coo(&gen::laplacian_1d(50));
        let lo = lanczos(&m, 1, &LanczosConfig::default()).eigenvalues[0];
        let pw = inverse_shifted_power(&m, 5.0, 4000, 3);
        assert!((lo - pw).abs() < 1e-4, "lanczos {lo} vs power {pw}");
    }

    #[test]
    fn spmv_count_is_reported() {
        let m = Crs::from_coo(&gen::laplacian_1d(80));
        let r = lanczos(&m, 1, &LanczosConfig::default());
        assert_eq!(r.spmv_count, r.iterations);
        assert!(!r.history.is_empty());
    }
}
