//! Eigensolvers: the Lanczos iteration driving SpMV (the paper's
//! motivating application) and a dense Jacobi reference oracle.

pub mod dense;
pub mod lanczos;

pub use dense::{jacobi_eigen, tridiag_eigenvalues};
pub use lanczos::{
    inverse_shifted_power, lanczos, lanczos_with_handle, LanczosConfig, LanczosResult, LinearOp,
};
