//! Eigensolvers and iterative solvers: the Lanczos iteration driving
//! SpMV (the paper's motivating application), conjugate gradients and
//! power iteration / PageRank ([`solve`]) as further pure-SpMV
//! consumers, and a dense Jacobi reference oracle.

pub mod dense;
pub mod lanczos;
pub mod solve;

pub use dense::{jacobi_eigen, tridiag_eigenvalues};
pub use lanczos::{
    inverse_shifted_power, lanczos, lanczos_with_handle, LanczosConfig, LanczosResult, LinearOp,
};
pub use solve::{
    cg, cg_with_handle, pagerank, pagerank_with_handle, power_iteration,
    power_iteration_with_handle, transition_matrix, CgConfig, CgResult, PageRankResult,
    PowerConfig, PowerResult,
};
