//! The basic sparse vector operations of Table 1 — the building blocks of
//! the SpMVM inner loops (§4.1).
//!
//! | | ADD | SCP |
//! |---------|---------------------|----------------------------|
//! | PD | `s += B[i]` | `s += A[i] * B[i]` |
//! | CS | `s += B[k*i]` | `s += A[i] * B[k*i]` |
//! | IS / IR | `s += B[ind[i]]` | `s += A[i] * B[ind[i]]` |
//!
//! IS uses a constant stride stored in the index array (`ind[i] = k*i`);
//! IR draws random strides. The paper generates IR by including each
//! entry of `invec` with probability `1/k`, which makes successive strides
//! geometric with mean `k`; Fig 4 extends this to Gaussian strides with
//! independently controlled mean and variance (allowing backward jumps).
//!
//! These run both as real host kernels (wall-clock) and as logical access
//! streams through the memory-hierarchy simulator (the paper's machines).

use std::sync::OnceLock;

use crate::kernels::simd::{self, IsaLevel};
use crate::util::rng::Rng;

/// How the gather index vector is produced.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum IndexPattern {
    /// Direct, densely packed access (stride 1), no index array.
    Dense,
    /// Direct access with constant stride `k`, no index array.
    ConstStride(usize),
    /// Indirect: `ind[i] = k*i` (constant stride through an index array).
    IndexedStride(usize),
    /// Indirect: strides `1 + Geometric(1/k)`, strictly monotonic forward,
    /// mean stride `k` (the paper's IR construction).
    Geometric { mean: f64 },
    /// Indirect: strides drawn from a Gaussian with given mean/variance,
    /// rounded; backward jumps occur when the variance allows (Fig 4).
    Gaussian { mean: f64, variance: f64 },
}

/// ADD (no load of A) or SCP (loads A too).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OpKind {
    Add,
    Scp,
}

/// One microbenchmark configuration of Table 1.
#[derive(Debug, Clone, Copy)]
pub struct MicroOp {
    pub kind: OpKind,
    pub pattern: IndexPattern,
}

impl MicroOp {
    pub fn name(&self) -> String {
        let prefix = match self.pattern {
            IndexPattern::Dense => "PD".to_string(),
            IndexPattern::ConstStride(k) => format!("CS(k={k})"),
            IndexPattern::IndexedStride(k) => format!("IS(k={k})"),
            IndexPattern::Geometric { mean } => format!("IR(k={mean})"),
            IndexPattern::Gaussian { mean, variance } => {
                format!("IRG(m={mean},v={variance})")
            }
        };
        let op = match self.kind {
            OpKind::Add => "ADD",
            OpKind::Scp => "SCP",
        };
        format!("{prefix}{op}")
    }

    /// Does this op read an explicit index array?
    pub fn uses_index_array(&self) -> bool {
        matches!(
            self.pattern,
            IndexPattern::IndexedStride(_)
                | IndexPattern::Geometric { .. }
                | IndexPattern::Gaussian { .. }
        )
    }

    /// Flops per iteration (ADD: 1 add; SCP: 1 mul + 1 add).
    pub fn flops_per_iter(&self) -> u64 {
        match self.kind {
            OpKind::Add => 1,
            OpKind::Scp => 2,
        }
    }

    /// Minimum bytes that must cross the memory interface per iteration,
    /// assuming perfect spatial reuse (the algorithmic balance view).
    pub fn min_bytes_per_iter(&self) -> u64 {
        let a = if self.kind == OpKind::Scp { 8 } else { 0 };
        let ind = if self.uses_index_array() { 4 } else { 0 };
        a + ind + 8 // B element
    }
}

/// The named catalogue of Table 1 (plus CSADD, referenced in the text).
pub fn table1_ops(k: usize) -> Vec<MicroOp> {
    vec![
        MicroOp { kind: OpKind::Add, pattern: IndexPattern::Dense },
        MicroOp { kind: OpKind::Scp, pattern: IndexPattern::Dense },
        MicroOp { kind: OpKind::Add, pattern: IndexPattern::ConstStride(k) },
        MicroOp { kind: OpKind::Scp, pattern: IndexPattern::ConstStride(k) },
        MicroOp { kind: OpKind::Add, pattern: IndexPattern::IndexedStride(k) },
        MicroOp { kind: OpKind::Scp, pattern: IndexPattern::IndexedStride(k) },
        MicroOp { kind: OpKind::Add, pattern: IndexPattern::Geometric { mean: k as f64 } },
        MicroOp { kind: OpKind::Scp, pattern: IndexPattern::Geometric { mean: k as f64 } },
    ]
}

/// Build the gather index vector for `n_iters` iterations over a B array
/// of length `b_len`. Returns indices in `[0, b_len)`.
pub fn build_index(pattern: IndexPattern, n_iters: usize, b_len: usize, rng: &mut Rng) -> Vec<u32> {
    assert!(b_len > 0);
    match pattern {
        IndexPattern::Dense => (0..n_iters).map(|i| (i % b_len) as u32).collect(),
        IndexPattern::ConstStride(k) | IndexPattern::IndexedStride(k) => (0..n_iters)
            .map(|i| ((i * k) % b_len) as u32)
            .collect(),
        IndexPattern::Geometric { mean } => {
            assert!(mean >= 1.0);
            let p = 1.0 / mean;
            let mut pos = 0u64;
            (0..n_iters)
                .map(|_| {
                    pos += 1 + rng.geometric(p);
                    (pos % b_len as u64) as u32
                })
                .collect()
        }
        IndexPattern::Gaussian { mean, variance } => {
            let sd = variance.max(0.0).sqrt();
            let mut pos = 0i64;
            (0..n_iters)
                .map(|_| {
                    let stride = rng.gaussian_with(mean, sd).round() as i64;
                    pos += stride;
                    pos = pos.rem_euclid(b_len as i64);
                    pos as u32
                })
                .collect()
        }
    }
}

// ---------------------------------------------------------------------
// Host kernels (wall-clock measurement). `#[inline(never)]` keeps them
// visible in profiles; manual 4x unrolling mirrors the paper's
// "sufficiently unrolled" inner loops.
// ---------------------------------------------------------------------

#[inline(never)]
pub fn pd_add(b: &[f64]) -> f64 {
    let mut s0 = 0.0;
    let mut s1 = 0.0;
    let mut s2 = 0.0;
    let mut s3 = 0.0;
    let chunks = b.chunks_exact(4);
    let rem = chunks.remainder();
    for c in chunks {
        s0 += c[0];
        s1 += c[1];
        s2 += c[2];
        s3 += c[3];
    }
    s0 + s1 + s2 + s3 + rem.iter().sum::<f64>()
}

#[inline(never)]
pub fn pd_scp(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    let mut s0 = 0.0;
    let mut s1 = 0.0;
    let (ca, cb) = (a.chunks_exact(2), b.chunks_exact(2));
    let (ra, rb) = (ca.remainder(), cb.remainder());
    for (x, y) in ca.zip(cb) {
        s0 += x[0] * y[0];
        s1 += x[1] * y[1];
    }
    s0 + s1 + ra.iter().zip(rb).map(|(x, y)| x * y).sum::<f64>()
}

/// `s += B[k*i]` for `n` iterations (requires `b.len() >= k*(n-1)+1`).
#[inline(never)]
pub fn cs_add(b: &[f64], k: usize, n: usize) -> f64 {
    let mut s = 0.0;
    let mut idx = 0usize;
    for _ in 0..n {
        s += b[idx];
        idx += k;
    }
    s
}

/// `s += A[i] * B[k*i]`.
#[inline(never)]
pub fn cs_scp(a: &[f64], b: &[f64], k: usize) -> f64 {
    let mut s = 0.0;
    let mut idx = 0usize;
    for &x in a {
        s += x * b[idx];
        idx += k;
    }
    s
}

/// `s += B[ind[i]]`.
#[inline(never)]
pub fn is_add(b: &[f64], ind: &[u32]) -> f64 {
    let mut s = 0.0;
    for &j in ind {
        s += b[j as usize];
    }
    s
}

/// `s += A[i] * B[ind[i]]`.
#[inline(never)]
pub fn is_scp(a: &[f64], b: &[f64], ind: &[u32]) -> f64 {
    assert_eq!(a.len(), ind.len());
    let mut s = 0.0;
    for (x, &j) in a.iter().zip(ind) {
        s += x * b[j as usize];
    }
    s
}

// ---------------------------------------------------------------------
// Streaming triad, scalar vs vectorized — the ISA-gain microbenchmark.
// The SpMV heuristic tier prices simd-vs-scalar candidates with the
// measured ratio ([`cached_isa_gain`]), the same way the perf model's
// cycles/nnz constants come from the Table-1 loops.
// ---------------------------------------------------------------------

/// `a[i] = b[i] + scale * c[i]`, scalar reference (the classic STREAM
/// triad; compute-bound at the L1/L2-resident sizes used here).
#[inline(never)]
pub fn triad_scalar(a: &mut [f64], b: &[f64], c: &[f64], scale: f64) {
    assert_eq!(a.len(), b.len());
    assert_eq!(a.len(), c.len());
    for i in 0..a.len() {
        a[i] = b[i] + scale * c[i];
    }
}

/// Vectorized triad at `isa` ([`crate::kernels::simd::triad`]); the
/// `Scalar` level is the plain loop.
#[inline(never)]
pub fn triad_isa(isa: IsaLevel, a: &mut [f64], b: &[f64], c: &[f64], scale: f64) {
    simd::triad(isa, a, b, c, scale);
}

/// Measure the scalar/vector triad throughput ratio at `isa` on this
/// host. > 1.0 means the vector unit pays off; a machine where it does
/// not reports < 1.0 and the tuner scores SIMD candidates accordingly.
fn measure_triad_gain(isa: IsaLevel) -> f64 {
    let n = 16 * 1024; // L1/L2 resident: per-core compute, not bandwidth
    let mut rng = Rng::new(0x751AD);
    let mut b = vec![0.0; n];
    let mut c = vec![0.0; n];
    rng.fill_f64(&mut b, -1.0, 1.0);
    rng.fill_f64(&mut c, -1.0, 1.0);
    let mut a = vec![0.0; n];
    let reps = 50;
    let mut time = |f: &mut dyn FnMut(&mut [f64])| -> f64 {
        f(&mut a); // warmup
        let mut best = f64::INFINITY;
        for _ in 0..reps {
            let t0 = std::time::Instant::now();
            f(&mut a);
            best = best.min(t0.elapsed().as_nanos() as f64);
        }
        std::hint::black_box(&a);
        best
    };
    let scalar_ns = time(&mut |a| triad_scalar(a, &b, &c, 3.0));
    let simd_ns = time(&mut |a| triad_isa(isa, a, &b, &c, 3.0));
    let gain = scalar_ns / simd_ns;
    if gain.is_finite() && gain > 0.0 {
        gain
    } else {
        1.0
    }
}

/// Scalar/vector throughput ratio of the gather-FMA reduction
/// ([`crate::kernels::simd::gather_scp`], IS-SCP's vector twin) at
/// `isa`: index + value streams L1-sized, the gathered B array
/// L2-resident with short geometric strides — per-core gather
/// throughput, not DRAM bandwidth, exactly the regime where the SpMV
/// x-gather lives.
fn measure_gather_gain(isa: IsaLevel) -> f64 {
    let n = 16 * 1024;
    let b_len = 32 * 1024;
    let mut rng = Rng::new(0x6A74E2);
    let mut a = vec![0.0; n];
    rng.fill_f64(&mut a, -1.0, 1.0);
    let mut b = vec![0.0; b_len];
    rng.fill_f64(&mut b, -1.0, 1.0);
    let ind = build_index(IndexPattern::Geometric { mean: 4.0 }, n, b_len, &mut rng);
    let reps = 50;
    let time = |level: IsaLevel| -> f64 {
        std::hint::black_box(simd::gather_scp(level, &a, &b, &ind)); // warmup
        let mut best = f64::INFINITY;
        for _ in 0..reps {
            let t0 = std::time::Instant::now();
            std::hint::black_box(simd::gather_scp(level, &a, &b, &ind));
            best = best.min(t0.elapsed().as_nanos() as f64);
        }
        best
    };
    let scalar_ns = time(IsaLevel::Scalar);
    let simd_ns = time(isa);
    let gain = scalar_ns / simd_ns;
    if gain.is_finite() && gain > 0.0 {
        gain
    } else {
        1.0
    }
}

/// Cached per-process triad gain for `isa` — the streaming
/// simd-vs-scalar score factor. Returns 1.0 for `Scalar` and for any
/// level above [`IsaLevel::detect`] (never measured: running an
/// undetected ISA would be UB). The heuristic tier prices the
/// gather-FMA SpMV kernels by [`cached_gather_gain`] instead — the
/// triad has no indirection, so its gain is optimistic for SpMV.
pub fn cached_isa_gain(isa: IsaLevel) -> f64 {
    if isa == IsaLevel::Scalar || isa > IsaLevel::detect() {
        return 1.0;
    }
    static GAINS: OnceLock<[f64; 2]> = OnceLock::new();
    let gains = GAINS.get_or_init(|| {
        [
            measure_triad_gain(IsaLevel::Avx2),
            if IsaLevel::detect() >= IsaLevel::Avx512 {
                measure_triad_gain(IsaLevel::Avx512)
            } else {
                1.0
            },
        ]
    });
    match isa {
        IsaLevel::Scalar => 1.0,
        IsaLevel::Avx2 => gains[0],
        IsaLevel::Avx512 => gains[1],
    }
}

/// Cached per-process **gather** gain for `isa` — the factor the
/// heuristic tier prices gather-FMA SpMV candidates by (ISSUE-9: the
/// triad gain measures pure streaming FMA throughput, which overstates
/// the vector payoff once every x operand arrives through a gather).
/// Same neutrality rules as [`cached_isa_gain`]: 1.0 for `Scalar` and
/// for any level above [`IsaLevel::detect`].
pub fn cached_gather_gain(isa: IsaLevel) -> f64 {
    if isa == IsaLevel::Scalar || isa > IsaLevel::detect() {
        return 1.0;
    }
    static GAINS: OnceLock<[f64; 2]> = OnceLock::new();
    let gains = GAINS.get_or_init(|| {
        [
            measure_gather_gain(IsaLevel::Avx2),
            if IsaLevel::detect() >= IsaLevel::Avx512 {
                measure_gather_gain(IsaLevel::Avx512)
            } else {
                1.0
            },
        ]
    });
    match isa {
        IsaLevel::Scalar => 1.0,
        IsaLevel::Avx2 => gains[0],
        IsaLevel::Avx512 => gains[1],
    }
}

/// Pre-built buffers for running a microbenchmark repeatedly.
pub struct MicroBuffers {
    pub a: Vec<f64>,
    pub b: Vec<f64>,
    pub ind: Vec<u32>,
    pub n_iters: usize,
    pub op: MicroOp,
}

impl MicroBuffers {
    /// `n_iters` loop iterations over a B array of `b_len` elements.
    /// For constant-stride direct ops, B is sized to cover `k * n_iters`.
    pub fn new(op: MicroOp, n_iters: usize, b_len: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let b_needed = match op.pattern {
            IndexPattern::Dense => n_iters.max(1),
            IndexPattern::ConstStride(k) => (k * n_iters).max(1),
            _ => b_len.max(1),
        };
        let mut a = vec![0.0; if op.kind == OpKind::Scp { n_iters } else { 0 }];
        rng.fill_f64(&mut a, -1.0, 1.0);
        let mut b = vec![0.0; b_needed];
        rng.fill_f64(&mut b, -1.0, 1.0);
        let ind = if op.uses_index_array() {
            build_index(op.pattern, n_iters, b_needed, &mut rng)
        } else {
            Vec::new()
        };
        Self { a, b, ind, n_iters, op }
    }

    /// Execute once, returning the scalar result.
    #[inline]
    pub fn run(&self) -> f64 {
        match (self.op.kind, self.op.pattern) {
            (OpKind::Add, IndexPattern::Dense) => pd_add(&self.b[..self.n_iters]),
            (OpKind::Scp, IndexPattern::Dense) => pd_scp(&self.a, &self.b[..self.n_iters]),
            (OpKind::Add, IndexPattern::ConstStride(k)) => cs_add(&self.b, k, self.n_iters),
            (OpKind::Scp, IndexPattern::ConstStride(k)) => cs_scp(&self.a, &self.b, k),
            (OpKind::Add, _) => is_add(&self.b, &self.ind),
            (OpKind::Scp, _) => is_scp(&self.a, &self.b, &self.ind),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_catalogue_names() {
        let ops = table1_ops(8);
        let names: Vec<String> = ops.iter().map(|o| o.name()).collect();
        assert!(names.contains(&"PDADD".to_string()));
        assert!(names.contains(&"PDSCP".to_string()));
        assert!(names.contains(&"CS(k=8)SCP".to_string()));
        assert!(names.contains(&"IS(k=8)ADD".to_string()));
        assert!(names.contains(&"IR(k=8)SCP".to_string()));
        assert_eq!(ops.len(), 8);
    }

    #[test]
    fn kernels_compute_correct_sums() {
        let b: Vec<f64> = (0..100).map(|i| i as f64).collect();
        assert_eq!(pd_add(&b), 4950.0);
        let a = vec![2.0; 100];
        assert_eq!(pd_scp(&a, &b), 9900.0);
        assert_eq!(cs_add(&b, 10, 10), (0..10).map(|i| (i * 10) as f64).sum());
        let a3 = vec![1.0; 10];
        assert_eq!(cs_scp(&a3, &b, 10), (0..10).map(|i| (i * 10) as f64).sum());
        let ind: Vec<u32> = vec![0, 99, 50];
        assert_eq!(is_add(&b, &ind), 149.0);
        let a2 = vec![1.0, 2.0, 3.0];
        assert_eq!(is_scp(&a2, &b, &ind), 0.0 + 198.0 + 150.0);
    }

    // Miri interprets ~100x slower than native; the statistical index
    // tests keep their assertions but run on smaller samples there.
    #[cfg(miri)]
    const IDX_N: usize = 5_000;
    #[cfg(not(miri))]
    const IDX_N: usize = 50_000;

    #[test]
    fn geometric_index_is_monotone_with_mean_k() {
        let mut rng = Rng::new(99);
        let n = IDX_N;
        let k = 16.0;
        let b_len = 10_000_000;
        let ind = build_index(IndexPattern::Geometric { mean: k }, n, b_len, &mut rng);
        // strictly monotonic until wraparound (b_len large enough: no wrap)
        assert!(ind.windows(2).all(|w| w[1] > w[0]));
        let mean_stride = (ind[n - 1] as f64 - ind[0] as f64) / (n - 1) as f64;
        assert!((mean_stride - k).abs() < 0.05 * k, "mean stride {mean_stride}");
    }

    #[test]
    fn gaussian_index_allows_backward_jumps() {
        let mut rng = Rng::new(7);
        let n = IDX_N / 2;
        let ind = build_index(
            IndexPattern::Gaussian { mean: 10.0, variance: 10_000.0 },
            n,
            1_000_000,
            &mut rng,
        );
        let backward = ind.windows(2).filter(|w| w[1] < w[0]).count();
        // With σ=100 ≫ mean=10, ~46% of steps are backward; demand a
        // tenth of that so the bound scales with the sample size.
        assert!(backward > n / 20, "expected many backward jumps, got {backward}");
        // small variance: (almost) no backward jumps
        let ind2 = build_index(
            IndexPattern::Gaussian { mean: 10.0, variance: 1.0 },
            n,
            100_000_000,
            &mut rng,
        );
        let backward2 = ind2.windows(2).filter(|w| w[1] < w[0]).count();
        assert_eq!(backward2, 0);
    }

    #[test]
    fn buffers_run_all_ops() {
        let (n, b_len) = if cfg!(miri) { (100, 10_000) } else { (1000, 100_000) };
        for op in table1_ops(8) {
            let bufs = MicroBuffers::new(op, n, b_len, 42);
            let v = bufs.run();
            assert!(v.is_finite(), "{}", op.name());
        }
    }

    #[test]
    fn balance_accounting() {
        let pdadd = MicroOp { kind: OpKind::Add, pattern: IndexPattern::Dense };
        assert_eq!(pdadd.min_bytes_per_iter(), 8);
        assert_eq!(pdadd.flops_per_iter(), 1);
        let irscp = MicroOp { kind: OpKind::Scp, pattern: IndexPattern::Geometric { mean: 8.0 } };
        assert_eq!(irscp.min_bytes_per_iter(), 20);
        assert_eq!(irscp.flops_per_iter(), 2);
    }

    #[test]
    fn indexed_stride_wraps() {
        let mut rng = Rng::new(1);
        let ind = build_index(IndexPattern::IndexedStride(530), 100, 1000, &mut rng);
        assert!(ind.iter().all(|&i| (i as usize) < 1000));
        assert_eq!(ind[0], 0);
        assert_eq!(ind[1], 530);
        assert_eq!(ind[2], 60); // 1060 % 1000
    }

    #[test]
    fn triad_isa_matches_scalar_reference() {
        let n = 1031; // prime: exercises every vector tail length
        let mut rng = Rng::new(7);
        let mut b = vec![0.0; n];
        let mut c = vec![0.0; n];
        rng.fill_f64(&mut b, -1.0, 1.0);
        rng.fill_f64(&mut c, -1.0, 1.0);
        let mut want = vec![0.0; n];
        triad_scalar(&mut want, &b, &c, 3.0);
        for isa in [IsaLevel::Scalar, IsaLevel::Avx2, IsaLevel::Avx512] {
            if isa > IsaLevel::detect() {
                continue;
            }
            let mut got = vec![0.0; n];
            triad_isa(isa, &mut got, &b, &c, 3.0);
            // The triad is one mul+add per element; FMA contraction can
            // differ by at most one rounding of the product term.
            for i in 0..n {
                assert!(
                    (got[i] - want[i]).abs() <= 1e-15 * want[i].abs().max(1.0),
                    "isa {isa}: lane {i}: {} vs {}",
                    got[i],
                    want[i]
                );
            }
        }
    }

    #[test]
    fn isa_gain_is_cached_positive_and_scalar_neutral() {
        assert_eq!(cached_isa_gain(IsaLevel::Scalar), 1.0);
        for isa in [IsaLevel::Avx2, IsaLevel::Avx512] {
            let g = cached_isa_gain(isa);
            assert!(g.is_finite() && g > 0.0, "gain for {isa} was {g}");
            // Cached: a second call must reproduce the first bit-exactly.
            assert_eq!(cached_isa_gain(isa), g);
            if isa > IsaLevel::detect() {
                assert_eq!(g, 1.0, "undetected {isa} must be neutral");
            }
        }
    }

    /// ISSUE-9 satellite: the gather gain follows the same caching and
    /// neutrality rules as the triad gain, and the measured kernel
    /// agrees with the scalar IS-SCP loop.
    #[test]
    fn gather_gain_is_cached_positive_and_scalar_neutral() {
        assert_eq!(cached_gather_gain(IsaLevel::Scalar), 1.0);
        for isa in [IsaLevel::Avx2, IsaLevel::Avx512] {
            let g = cached_gather_gain(isa);
            assert!(g.is_finite() && g > 0.0, "gather gain for {isa} was {g}");
            assert_eq!(cached_gather_gain(isa), g);
            if isa > IsaLevel::detect() {
                assert_eq!(g, 1.0, "undetected {isa} must be neutral");
            }
        }
    }

    #[test]
    fn gather_scp_matches_is_scp_reference() {
        let mut rng = Rng::new(61);
        let n = 1021; // prime: exercises the vector tail
        let b_len = 4096;
        let mut a = vec![0.0; n];
        rng.fill_f64(&mut a, -1.0, 1.0);
        let mut b = vec![0.0; b_len];
        rng.fill_f64(&mut b, -1.0, 1.0);
        let ind: Vec<u32> = (0..n).map(|_| rng.index(b_len) as u32).collect();
        let want = is_scp(&a, &b, &ind);
        assert_eq!(simd::gather_scp(IsaLevel::Scalar, &a, &b, &ind), want);
        let host = IsaLevel::detect();
        if host > IsaLevel::Scalar {
            let got = simd::gather_scp(host, &a, &b, &ind);
            // Partial-sum reordering: stay relative to Σ|aᵢ·b[ind[i]]|.
            let scale: f64 =
                a.iter().zip(&ind).map(|(x, &j)| (x * b[j as usize]).abs()).sum();
            assert!(
                (want - got).abs() <= 1e-13 * scale.max(1.0),
                "gather_scp {host}: {want} vs {got}"
            );
        }
    }
}
