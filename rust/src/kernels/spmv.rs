//! Unified SpMV kernel dispatch over all storage schemes, with a
//! preallocated workspace for hot benchmark loops (a long-lived solver
//! keeps its vectors in the permuted basis; we do the same so benches
//! measure the kernel, not the gather/scatter).

use crate::matrix::jds::SpmvVisitor;
use crate::matrix::{Coo, Crs, Jds, RbJds, Scheme, SoJds, SpMv};

/// A matrix realized in a concrete storage scheme, ready for SpMV.
pub enum SpmvKernel {
    Crs(Crs),
    /// JDS storage with a JDS-family access scheme (JDS/NBJDS/NUJDS).
    Jds { jds: Jds, scheme: Scheme },
    Rb(RbJds),
    So(SoJds),
}

impl SpmvKernel {
    pub fn build(coo: &Coo, scheme: Scheme) -> Self {
        let crs = Crs::from_coo(coo);
        Self::build_from_crs(&crs, scheme)
    }

    pub fn build_from_crs(crs: &Crs, scheme: Scheme) -> Self {
        match scheme {
            Scheme::Crs => SpmvKernel::Crs(crs.clone()),
            Scheme::Jds | Scheme::NbJds { .. } | Scheme::NuJds { .. } => {
                SpmvKernel::Jds { jds: Jds::from_crs(crs), scheme }
            }
            Scheme::RbJds { block } => SpmvKernel::Rb(RbJds::from_crs(crs, block)),
            Scheme::SoJds { block } => SpmvKernel::So(SoJds::from_crs(crs, block)),
        }
    }

    pub fn scheme(&self) -> Scheme {
        match self {
            SpmvKernel::Crs(_) => Scheme::Crs,
            SpmvKernel::Jds { scheme, .. } => *scheme,
            SpmvKernel::Rb(rb) => Scheme::RbJds { block: rb.block },
            SpmvKernel::So(so) => Scheme::SoJds { block: so.0.block },
        }
    }

    pub fn nrows(&self) -> usize {
        match self {
            SpmvKernel::Crs(m) => m.nrows,
            SpmvKernel::Jds { jds, .. } => jds.nrows,
            SpmvKernel::Rb(m) => m.nrows,
            SpmvKernel::So(m) => m.0.nrows,
        }
    }

    pub fn nnz(&self) -> usize {
        match self {
            SpmvKernel::Crs(m) => m.nnz(),
            SpmvKernel::Jds { jds, .. } => jds.nnz(),
            SpmvKernel::Rb(m) => m.nnz(),
            SpmvKernel::So(m) => m.nnz(),
        }
    }

    /// SpMV in the original basis (allocates; for correctness paths).
    pub fn spmv(&self, x: &[f64], y: &mut [f64]) {
        match self {
            SpmvKernel::Crs(m) => m.spmv(x, y),
            SpmvKernel::Jds { jds, scheme } => jds.spmv_scheme(*scheme, x, y),
            SpmvKernel::Rb(m) => m.spmv(x, y),
            SpmvKernel::So(m) => m.spmv(x, y),
        }
    }

    /// Prepare a hot-loop workspace: input pre-permuted, output buffer
    /// sized. For CRS the basis is the identity.
    pub fn workspace(&self, x: &[f64]) -> Workspace {
        let xp = match self {
            SpmvKernel::Crs(_) => x.to_vec(),
            SpmvKernel::Jds { jds, .. } => jds.permute_vec(x),
            SpmvKernel::Rb(m) => m.permute_vec(x),
            SpmvKernel::So(m) => m.0.permute_vec(x),
        };
        Workspace { xp, yp: vec![0.0; self.nrows()] }
    }

    /// Hot-path SpMV: permuted-basis kernel only, no allocation.
    #[inline]
    pub fn spmv_hot(&self, ws: &mut Workspace) {
        match self {
            SpmvKernel::Crs(m) => m.spmv(&ws.xp, &mut ws.yp),
            SpmvKernel::Jds { jds, scheme } => match scheme {
                Scheme::Jds => jds.spmv_permuted_jds(&ws.xp, &mut ws.yp),
                Scheme::NbJds { block } => jds.spmv_permuted_nbjds(*block, &ws.xp, &mut ws.yp),
                Scheme::NuJds { unroll } => jds.spmv_permuted_nujds(*unroll, &ws.xp, &mut ws.yp),
                _ => unreachable!(),
            },
            SpmvKernel::Rb(m) => m.spmv_permuted(&ws.xp, &mut ws.yp),
            SpmvKernel::So(m) => m.spmv_permuted(&ws.xp, &mut ws.yp),
        }
    }

    /// Recover the original-basis result from the workspace.
    pub fn unpermute(&self, ws: &Workspace, y: &mut [f64]) {
        match self {
            SpmvKernel::Crs(_) => y.copy_from_slice(&ws.yp),
            SpmvKernel::Jds { jds, .. } => jds.unpermute_vec(&ws.yp, y),
            SpmvKernel::Rb(m) => m.unpermute_vec(&ws.yp, y),
            SpmvKernel::So(m) => m.0.unpermute_vec(&ws.yp, y),
        }
    }

    /// Drive a visitor over the kernel's logical update stream (the exact
    /// memory-touch order) — used by the simulator and stride analysis.
    pub fn walk<V: SpmvVisitor>(&self, v: &mut V) {
        match self {
            SpmvKernel::Crs(m) => {
                // CRS row-major walk: same update semantics.
                for i in 0..m.nrows {
                    for j in m.row_ptr[i]..m.row_ptr[i + 1] {
                        v.update(i, j, m.col_idx[j] as usize);
                    }
                }
            }
            SpmvKernel::Jds { jds, scheme } => match scheme {
                Scheme::Jds => jds.walk_jds(v),
                Scheme::NbJds { block } => jds.walk_nbjds(*block, v),
                Scheme::NuJds { unroll } => jds.walk_nujds(*unroll, v),
                _ => unreachable!(),
            },
            SpmvKernel::Rb(m) => m.walk(v),
            SpmvKernel::So(m) => m.walk(v),
        }
    }
}

/// Preallocated permuted-basis vectors for hot SpMV loops.
pub struct Workspace {
    pub xp: Vec<f64>,
    pub yp: Vec<f64>,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::util::stats::max_abs_diff;

    fn random_coo(rng: &mut Rng, n: usize, nnz: usize) -> Coo {
        let mut coo = Coo::new(n, n);
        for _ in 0..nnz {
            coo.push(rng.index(n), rng.index(n), rng.f64() * 2.0 - 1.0);
        }
        coo.normalize();
        coo
    }

    #[test]
    fn all_schemes_agree_with_crs() {
        let mut rng = Rng::new(30);
        let n = 150;
        let coo = random_coo(&mut rng, n, n * 7);
        let mut x = vec![0.0; n];
        rng.fill_f64(&mut x, -1.0, 1.0);
        let crs = SpmvKernel::build(&coo, Scheme::Crs);
        let mut y_ref = vec![0.0; n];
        crs.spmv(&x, &mut y_ref);
        for scheme in Scheme::all_with(32, 2) {
            let k = SpmvKernel::build(&coo, scheme);
            assert_eq!(k.nnz(), crs.nnz());
            let mut y = vec![0.0; n];
            k.spmv(&x, &mut y);
            assert!(
                max_abs_diff(&y_ref, &y) < 1e-12,
                "scheme {scheme} disagrees with CRS"
            );
        }
    }

    #[test]
    fn hot_path_matches_cold_path() {
        let mut rng = Rng::new(31);
        let n = 120;
        let coo = random_coo(&mut rng, n, n * 5);
        let mut x = vec![0.0; n];
        rng.fill_f64(&mut x, -1.0, 1.0);
        for scheme in Scheme::all_with(16, 4) {
            let k = SpmvKernel::build(&coo, scheme);
            let mut y_cold = vec![0.0; n];
            k.spmv(&x, &mut y_cold);
            let mut ws = k.workspace(&x);
            k.spmv_hot(&mut ws);
            let mut y_hot = vec![0.0; n];
            k.unpermute(&ws, &mut y_hot);
            assert!(
                max_abs_diff(&y_cold, &y_hot) < 1e-12,
                "scheme {scheme}: hot path disagrees"
            );
        }
    }

    #[test]
    fn walk_touches_every_nnz_once_for_all_schemes() {
        use crate::matrix::jds::SpmvVisitor;
        let mut rng = Rng::new(32);
        let coo = random_coo(&mut rng, 100, 600);
        struct Count(usize);
        impl SpmvVisitor for Count {
            fn update(&mut self, _r: usize, _j: usize, _c: usize) {
                self.0 += 1;
            }
        }
        for scheme in Scheme::all_with(25, 3) {
            let k = SpmvKernel::build(&coo, scheme);
            let mut c = Count(0);
            k.walk(&mut c);
            assert_eq!(c.0, k.nnz(), "scheme {scheme}");
        }
    }
}
