//! Unified SpMV kernel dispatch over all storage schemes, with a
//! preallocated workspace for hot benchmark loops (a long-lived solver
//! keeps its vectors in the permuted basis; we do the same so benches
//! measure the kernel, not the gather/scatter).
//!
//! Every scheme also exposes a **range-restricted kernel**
//! ([`SpmvKernel::spmv_rows_permuted`]): the unit of work the parallel
//! execution engine ([`crate::engine`]) schedules onto threads. The
//! restricted kernels reproduce the serial kernels' per-row accumulation
//! order, so partitioned execution is bit-compatible with serial runs.

use crate::kernels::simd::{self, IsaLevel};
use crate::matrix::jds::SpmvVisitor;
use crate::matrix::{Coo, Crs, Jds, RbJds, Scheme, SellCs, SoJds, SpMv};

/// A matrix realized in a concrete storage scheme, ready for SpMV.
pub enum SpmvKernel {
    Crs(Crs),
    /// JDS storage with a JDS-family access scheme (JDS/NBJDS/NUJDS).
    Jds { jds: Jds, scheme: Scheme },
    Rb(RbJds),
    So(SoJds),
    Sell(SellCs),
}

impl SpmvKernel {
    pub fn build(coo: &Coo, scheme: Scheme) -> Self {
        let crs = Crs::from_coo(coo);
        Self::build_from_crs(&crs, scheme)
    }

    pub fn build_from_crs(crs: &Crs, scheme: Scheme) -> Self {
        match scheme {
            Scheme::Crs => SpmvKernel::Crs(crs.clone()),
            Scheme::Jds | Scheme::NbJds { .. } | Scheme::NuJds { .. } => {
                SpmvKernel::Jds { jds: Jds::from_crs(crs), scheme }
            }
            Scheme::RbJds { block } => SpmvKernel::Rb(RbJds::from_crs(crs, block)),
            Scheme::SoJds { block } => SpmvKernel::So(SoJds::from_crs(crs, block)),
            Scheme::SellCs { c, sigma } => SpmvKernel::Sell(SellCs::from_crs(crs, c, sigma)),
        }
    }

    pub fn scheme(&self) -> Scheme {
        match self {
            SpmvKernel::Crs(_) => Scheme::Crs,
            SpmvKernel::Jds { scheme, .. } => *scheme,
            SpmvKernel::Rb(rb) => Scheme::RbJds { block: rb.block },
            SpmvKernel::So(so) => Scheme::SoJds { block: so.0.block },
            SpmvKernel::Sell(m) => Scheme::SellCs { c: m.c, sigma: m.sigma },
        }
    }

    pub fn nrows(&self) -> usize {
        match self {
            SpmvKernel::Crs(m) => m.nrows,
            SpmvKernel::Jds { jds, .. } => jds.nrows,
            SpmvKernel::Rb(m) => m.nrows,
            SpmvKernel::So(m) => m.0.nrows,
            SpmvKernel::Sell(m) => m.nrows,
        }
    }

    pub fn nnz(&self) -> usize {
        match self {
            SpmvKernel::Crs(m) => m.nnz(),
            SpmvKernel::Jds { jds, .. } => jds.nnz(),
            SpmvKernel::Rb(m) => m.nnz(),
            SpmvKernel::So(m) => m.nnz(),
            SpmvKernel::Sell(m) => m.nnz(),
        }
    }

    /// The row permutation into the kernel's working basis (`perm[new] =
    /// old`); `None` for CRS (identity).
    pub fn perm(&self) -> Option<&[u32]> {
        match self {
            SpmvKernel::Crs(_) => None,
            SpmvKernel::Jds { jds, .. } => Some(&jds.perm),
            SpmvKernel::Rb(m) => Some(&m.perm),
            SpmvKernel::So(m) => Some(&m.0.perm),
            SpmvKernel::Sell(m) => Some(&m.perm),
        }
    }

    /// Gather `x` into the permuted basis without allocating.
    pub fn permute_into(&self, x: &[f64], xp: &mut [f64]) {
        match self.perm() {
            None => xp.copy_from_slice(x),
            Some(p) => {
                for (new, &old) in p.iter().enumerate() {
                    xp[new] = x[old as usize];
                }
            }
        }
    }

    /// Scatter a permuted-basis result back without allocating.
    pub fn unpermute_into(&self, yp: &[f64], y: &mut [f64]) {
        match self.perm() {
            None => y.copy_from_slice(yp),
            Some(p) => {
                for (new, &old) in p.iter().enumerate() {
                    y[old as usize] = yp[new];
                }
            }
        }
    }

    /// Non-zeros per permuted row — the iteration weights for OpenMP-style
    /// scheduling (shared by the host engine and the simulator).
    pub fn row_weights(&self) -> Vec<f64> {
        struct W(Vec<f64>);
        impl SpmvVisitor for W {
            fn update(&mut self, row: usize, _j: usize, _c: usize) {
                if self.0.len() <= row {
                    self.0.resize(row + 1, 0.0);
                }
                self.0[row] += 1.0;
            }
        }
        let mut w = W(vec![0.0; self.nrows()]);
        self.walk(&mut w);
        w.0
    }

    /// SpMV in the original basis (allocates; for correctness paths).
    pub fn spmv(&self, x: &[f64], y: &mut [f64]) {
        match self {
            SpmvKernel::Crs(m) => m.spmv(x, y),
            SpmvKernel::Jds { jds, scheme } => jds.spmv_scheme(*scheme, x, y),
            SpmvKernel::Rb(m) => m.spmv(x, y),
            SpmvKernel::So(m) => m.spmv(x, y),
            SpmvKernel::Sell(m) => m.spmv(x, y),
        }
    }

    /// Prepare a hot-loop workspace: input pre-permuted, output buffer
    /// sized. For CRS the basis is the identity.
    pub fn workspace(&self, x: &[f64]) -> Workspace {
        let xp = match self.perm() {
            None => x.to_vec(),
            Some(p) => p.iter().map(|&old| x[old as usize]).collect(),
        };
        Workspace { xp, yp: vec![0.0; self.nrows()] }
    }

    /// Hot-path SpMV: permuted-basis kernel only, no allocation.
    #[inline]
    pub fn spmv_hot(&self, ws: &mut Workspace) {
        match self {
            SpmvKernel::Crs(m) => m.spmv(&ws.xp, &mut ws.yp),
            SpmvKernel::Jds { jds, scheme } => match scheme {
                Scheme::Jds => jds.spmv_permuted_jds(&ws.xp, &mut ws.yp),
                Scheme::NbJds { block } => jds.spmv_permuted_nbjds(*block, &ws.xp, &mut ws.yp),
                Scheme::NuJds { unroll } => jds.spmv_permuted_nujds(*unroll, &ws.xp, &mut ws.yp),
                // audit:allow(hot_path_panic): Jds variant only ever wraps JDS-family schemes
                _ => unreachable!(),
            },
            SpmvKernel::Rb(m) => m.spmv_permuted(&ws.xp, &mut ws.yp),
            SpmvKernel::So(m) => m.spmv_permuted(&ws.xp, &mut ws.yp),
            SpmvKernel::Sell(m) => m.spmv_permuted(&ws.xp, &mut ws.yp),
        }
    }

    /// Range-restricted permuted-basis SpMV — the parallel engine's unit
    /// of work. Computes permuted rows `[row_begin, row_end)` into
    /// `out[i - row_begin]`; disjoint row partitions may therefore write
    /// through disjoint output slices concurrently.
    #[inline]
    pub fn spmv_rows_permuted(&self, row_begin: usize, row_end: usize, xp: &[f64], out: &mut [f64]) {
        match self {
            SpmvKernel::Crs(m) => m.spmv_rows_into(row_begin, row_end, xp, out),
            SpmvKernel::Jds { jds, scheme } => match scheme {
                Scheme::Jds => jds.spmv_rows_jds(row_begin, row_end, xp, out),
                Scheme::NbJds { block } => jds.spmv_rows_nbjds(*block, row_begin, row_end, xp, out),
                Scheme::NuJds { unroll } => {
                    jds.spmv_rows_nujds(*unroll, row_begin, row_end, xp, out)
                }
                // audit:allow(hot_path_panic): Jds variant only ever wraps JDS-family schemes
                _ => unreachable!(),
            },
            SpmvKernel::Rb(m) => m.spmv_rows_permuted(row_begin, row_end, xp, out),
            SpmvKernel::So(m) => m.spmv_rows_permuted(row_begin, row_end, xp, out),
            SpmvKernel::Sell(m) => m.spmv_rows_permuted(row_begin, row_end, xp, out),
        }
    }

    /// Blocked-x multi-vector variant of [`Self::spmv_rows_permuted`]
    /// (SpMM with the column block of `k` vectors kept resident): computes
    /// the same permuted row range for every input vector at once,
    /// streaming each matrix entry ONCE and reusing the loaded
    /// `(val, col)` pair across all `k` vectors — the x-reuse that shifts
    /// the memory-traffic balance (cf. arXiv:1711.05487). Per vector the
    /// floating-point accumulation order is exactly the scalar kernel's,
    /// so the result is bit-identical to `k` independent
    /// [`Self::spmv_rows_permuted`] calls. CRS and SELL-C-σ have fused
    /// loops; the JDS family and the blocked schemes delegate per vector
    /// (their traversal orders give no rectangular reuse win).
    pub fn spmv_rows_multi(
        &self,
        row_begin: usize,
        row_end: usize,
        xps: &[&[f64]],
        outs: &mut [&mut [f64]],
    ) {
        debug_assert_eq!(xps.len(), outs.len());
        let k = xps.len();
        match self {
            SpmvKernel::Crs(m) => {
                let mut acc = vec![0.0; k];
                for i in row_begin..row_end {
                    let (a, b) = (m.row_ptr[i], m.row_ptr[i + 1]);
                    acc.fill(0.0);
                    for j in a..b {
                        let v = m.val[j];
                        let c = m.col_idx[j] as usize;
                        for (sum, xp) in acc.iter_mut().zip(xps) {
                            *sum += v * xp[c];
                        }
                    }
                    for (out, &sum) in outs.iter_mut().zip(acc.iter()) {
                        out[i - row_begin] = sum;
                    }
                }
            }
            SpmvKernel::Sell(m) => {
                let mut acc = vec![0.0; k];
                for i in row_begin..row_end {
                    let sl = i / m.c;
                    let (lo, hi) = m.slice_rows(sl);
                    let h = hi - lo;
                    let lane = i - lo;
                    let base = m.slice_ptr[sl];
                    acc.fill(0.0);
                    for t in 0..m.row_nnz[i] as usize {
                        let idx = base + t * h + lane;
                        let v = m.val[idx];
                        let c = m.col_idx[idx] as usize;
                        for (sum, xp) in acc.iter_mut().zip(xps) {
                            *sum += v * xp[c];
                        }
                    }
                    for (out, &sum) in outs.iter_mut().zip(acc.iter()) {
                        out[i - row_begin] = sum;
                    }
                }
            }
            _ => {
                for (xp, out) in xps.iter().zip(outs.iter_mut()) {
                    self.spmv_rows_permuted(row_begin, row_end, xp, out);
                }
            }
        }
    }

    /// ISA-dispatched variant of [`Self::spmv_rows_multi`]: the fused
    /// CRS and SELL-C-σ loops route to the vector SpMM bodies of
    /// [`crate::kernels::simd`] (broadcast each matrix entry, FMA
    /// across the column block) when `isa` is above
    /// [`IsaLevel::Scalar`]; every other scheme — and the `Scalar`
    /// level — runs the exact fused scalar loops, preserving bit
    /// identity. Per vector the vector bodies keep the scalar entry
    /// order, so [`simd::Precision::Tolerance`] bounds hold per row.
    pub fn spmv_rows_multi_isa(
        &self,
        isa: IsaLevel,
        row_begin: usize,
        row_end: usize,
        xps: &[&[f64]],
        outs: &mut [&mut [f64]],
    ) {
        match (self, isa) {
            (_, IsaLevel::Scalar) => self.spmv_rows_multi(row_begin, row_end, xps, outs),
            (SpmvKernel::Crs(m), _) => {
                simd::crs_rows_multi(isa, m, row_begin, row_end, xps, outs)
            }
            (SpmvKernel::Sell(m), _) => {
                simd::sell_rows_multi(isa, m, row_begin, row_end, xps, outs)
            }
            _ => self.spmv_rows_multi(row_begin, row_end, xps, outs),
        }
    }

    /// ISA-dispatched variant of [`Self::spmv_rows_permuted`]: CRS and
    /// SELL-C-σ rows route to the vector kernels of
    /// [`crate::kernels::simd`] when `isa` is above
    /// [`IsaLevel::Scalar`]; every other scheme (and the `Scalar`
    /// level) runs the exact scalar loops. Callers must not pass an
    /// `isa` above [`IsaLevel::detect`] — the tuner only binds detected
    /// levels, and only under [`simd::Precision::Tolerance`].
    #[inline]
    pub fn spmv_rows_permuted_isa(
        &self,
        isa: IsaLevel,
        row_begin: usize,
        row_end: usize,
        xp: &[f64],
        out: &mut [f64],
    ) {
        match (self, isa) {
            (_, IsaLevel::Scalar) => self.spmv_rows_permuted(row_begin, row_end, xp, out),
            (SpmvKernel::Crs(m), _) => simd::crs_rows_into(isa, m, row_begin, row_end, xp, out),
            (SpmvKernel::Sell(m), _) => {
                simd::sell_rows_permuted(isa, m, row_begin, row_end, xp, out)
            }
            _ => self.spmv_rows_permuted(row_begin, row_end, xp, out),
        }
    }

    /// Does this kernel have a vector path at `isa` (i.e. does
    /// [`Self::spmv_rows_permuted_isa`] differ from the scalar loop)?
    pub fn has_simd_path(&self, isa: IsaLevel) -> bool {
        isa > IsaLevel::Scalar && matches!(self, SpmvKernel::Crs(_) | SpmvKernel::Sell(_))
    }

    /// ISA-dispatched hot path: [`Self::spmv_hot`] semantics with the
    /// vector kernels where the scheme has one.
    #[inline]
    pub fn spmv_hot_isa(&self, isa: IsaLevel, ws: &mut Workspace) {
        if self.has_simd_path(isa) {
            let n = self.nrows();
            let Workspace { xp, yp } = ws;
            self.spmv_rows_permuted_isa(isa, 0, n, xp, yp);
        } else {
            self.spmv_hot(ws);
        }
    }

    /// Recover the original-basis result from the workspace.
    pub fn unpermute(&self, ws: &Workspace, y: &mut [f64]) {
        self.unpermute_into(&ws.yp, y);
    }

    /// Drive a visitor over the kernel's logical update stream (the exact
    /// memory-touch order) — used by the simulator and stride analysis.
    pub fn walk<V: SpmvVisitor>(&self, v: &mut V) {
        match self {
            SpmvKernel::Crs(m) => {
                // CRS row-major walk: same update semantics.
                for i in 0..m.nrows {
                    for j in m.row_ptr[i]..m.row_ptr[i + 1] {
                        v.update(i, j, m.col_idx[j] as usize);
                    }
                }
            }
            SpmvKernel::Jds { jds, scheme } => match scheme {
                Scheme::Jds => jds.walk_jds(v),
                Scheme::NbJds { block } => jds.walk_nbjds(*block, v),
                Scheme::NuJds { unroll } => jds.walk_nujds(*unroll, v),
                // audit:allow(hot_path_panic): Jds variant only ever wraps JDS-family schemes
                _ => unreachable!(),
            },
            SpmvKernel::Rb(m) => m.walk(v),
            SpmvKernel::So(m) => m.walk(v),
            SpmvKernel::Sell(m) => m.walk(v),
        }
    }
}

/// Preallocated permuted-basis vectors for hot SpMV loops.
pub struct Workspace {
    pub xp: Vec<f64>,
    pub yp: Vec<f64>,
}

// ---------------------------------------------------------------------
// Sharded split kernels (local / remote halves of a ShardCrs).
// ---------------------------------------------------------------------

use crate::matrix::shard::ShardCrs;
use crate::matrix::SellRect;

/// One half of a shard (interior-rows/local or boundary-rows/remote)
/// realized in a storage scheme. Rectangular by nature, so only the
/// schemes with a rectangular realization are supported: CRS and
/// SELL-C-σ (via [`SellRect`], row-sorted-only). Row output slots are
/// in *storage order*; [`HalfKernel::storage_row`] maps a slot back to
/// the half's own row id.
pub enum HalfKernel {
    Crs(Crs),
    Sell(SellRect),
}

impl HalfKernel {
    /// Realize `half` in `scheme`. Errors on schemes without a
    /// rectangular split kernel (the JDS family permutes rows and
    /// columns symmetrically and has no half-matrix form).
    pub fn build(half: &Crs, scheme: Scheme) -> anyhow::Result<Self> {
        match scheme {
            Scheme::Crs => Ok(HalfKernel::Crs(half.clone())),
            Scheme::SellCs { c, sigma } => Ok(HalfKernel::Sell(SellRect::from_crs(half, c, sigma))),
            other => anyhow::bail!(
                "sharded SpMV supports crs and sellcs halves, not {}",
                other.name()
            ),
        }
    }

    /// Rows in this half (== output slots).
    pub fn nrows(&self) -> usize {
        match self {
            HalfKernel::Crs(m) => m.nrows,
            HalfKernel::Sell(m) => m.nrows,
        }
    }

    pub fn nnz(&self) -> usize {
        match self {
            HalfKernel::Crs(m) => m.val.len(),
            HalfKernel::Sell(m) => m.nnz(),
        }
    }

    /// Half row id computed into output slot `i` (identity for CRS,
    /// the σ-window sort permutation for SELL).
    #[inline]
    pub fn storage_row(&self, i: usize) -> usize {
        match self {
            HalfKernel::Crs(_) => i,
            HalfKernel::Sell(m) => m.perm[i] as usize,
        }
    }

    /// Scheduling weights per output slot (nnz of the row in that
    /// slot) — feeds [`crate::engine::SpmvPlan::for_weights`].
    pub fn row_weights(&self) -> Vec<f64> {
        match self {
            HalfKernel::Crs(m) => {
                (0..m.nrows).map(|i| (m.row_ptr[i + 1] - m.row_ptr[i]) as f64).collect()
            }
            HalfKernel::Sell(m) => m.row_nnz.iter().map(|&w| w as f64).collect(),
        }
    }

    /// Range-restricted kernel over output slots `[row_begin,
    /// row_end)`, reading `x` in the half's own column space. Per-row
    /// accumulation order is the half's storage order — the original
    /// CRS entry order for both realizations, so every slot is
    /// bit-identical to the serial CRS kernel on its row.
    #[inline]
    pub fn spmv_rows(&self, row_begin: usize, row_end: usize, x: &[f64], out: &mut [f64]) {
        match self {
            HalfKernel::Crs(m) => m.spmv_rows_into(row_begin, row_end, x, out),
            HalfKernel::Sell(m) => m.spmv_rows(row_begin, row_end, x, out),
        }
    }

    /// ISA-dispatched variant of [`Self::spmv_rows`]: both rectangular
    /// realizations have vector bodies in [`crate::kernels::simd`] —
    /// CRS rows gather-FMA, SELL slices run lane groups over the same
    /// layout as the square kernels. `x` stays the half's own column
    /// space (the owned slice locally, the concatenated `[owned |
    /// halo]` buffer remotely); per-row entry order is preserved, so
    /// [`simd::Precision::Tolerance`] bounds hold. At `Scalar` this is
    /// exactly [`Self::spmv_rows`] (bit identity preserved).
    #[inline]
    pub fn spmv_rows_isa(
        &self,
        isa: IsaLevel,
        row_begin: usize,
        row_end: usize,
        x: &[f64],
        out: &mut [f64],
    ) {
        match (self, isa) {
            (_, IsaLevel::Scalar) => self.spmv_rows(row_begin, row_end, x, out),
            (HalfKernel::Crs(m), _) => simd::crs_rows_into(isa, m, row_begin, row_end, x, out),
            (HalfKernel::Sell(m), _) => simd::sell_rect_rows(isa, m, row_begin, row_end, x, out),
        }
    }

    /// Does this half have a vector path at `isa`? Both rectangular
    /// realizations do, so this is a pure level check — kept as a
    /// method so the tuner asks halves the same question it asks
    /// [`SpmvKernel::has_simd_path`].
    pub fn has_simd_path(&self, isa: IsaLevel) -> bool {
        isa > IsaLevel::Scalar
    }
}

/// A shard's two halves realized in one scheme — the unit the sharding
/// executor plans and dispatches. The local half multiplies the owned
/// slice of `x`; the remote half multiplies the concatenated
/// `[owned | halo]` gather buffer.
pub struct ShardKernel {
    pub scheme: Scheme,
    pub local: HalfKernel,
    pub remote: HalfKernel,
}

impl ShardKernel {
    pub fn build(shard: &ShardCrs, scheme: Scheme) -> anyhow::Result<Self> {
        Ok(ShardKernel {
            scheme,
            local: HalfKernel::build(&shard.local, scheme)?,
            remote: HalfKernel::build(&shard.remote, scheme)?,
        })
    }

    pub fn nnz(&self) -> usize {
        self.local.nnz() + self.remote.nnz()
    }

    /// Do both halves have a vector path at `isa`? (They always agree
    /// — the supported schemes both vectorize — but the sharded
    /// executor asks the kernel, not the scheme.)
    pub fn has_simd_path(&self, isa: IsaLevel) -> bool {
        self.local.has_simd_path(isa) && self.remote.has_simd_path(isa)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::util::stats::max_abs_diff;

    fn random_coo(rng: &mut Rng, n: usize, nnz: usize) -> Coo {
        let mut coo = Coo::new(n, n);
        for _ in 0..nnz {
            coo.push(rng.index(n), rng.index(n), rng.f64() * 2.0 - 1.0);
        }
        coo.normalize();
        coo
    }

    #[test]
    fn all_schemes_agree_with_crs() {
        let mut rng = Rng::new(30);
        let n = 150;
        let coo = random_coo(&mut rng, n, n * 7);
        let mut x = vec![0.0; n];
        rng.fill_f64(&mut x, -1.0, 1.0);
        let crs = SpmvKernel::build(&coo, Scheme::Crs);
        let mut y_ref = vec![0.0; n];
        crs.spmv(&x, &mut y_ref);
        let mut schemes = Scheme::all_extended(32, 2, 8, 64);
        schemes.push(Scheme::SellCs { c: 32, sigma: 32 });
        schemes.push(Scheme::SellCs { c: 1, sigma: 1 });
        for scheme in schemes {
            let k = SpmvKernel::build(&coo, scheme);
            assert_eq!(k.nnz(), crs.nnz());
            let mut y = vec![0.0; n];
            k.spmv(&x, &mut y);
            assert!(
                max_abs_diff(&y_ref, &y) < 1e-12,
                "scheme {scheme} disagrees with CRS"
            );
        }
    }

    #[test]
    fn all_schemes_agree_with_crs_on_holstein_hubbard() {
        let h = crate::gen::holstein_hubbard(&crate::gen::HolsteinHubbardParams::tiny());
        let n = h.nrows;
        let mut rng = Rng::new(33);
        let mut x = vec![0.0; n];
        rng.fill_f64(&mut x, -1.0, 1.0);
        let crs = SpmvKernel::build(&h, Scheme::Crs);
        let mut y_ref = vec![0.0; n];
        crs.spmv(&x, &mut y_ref);
        for scheme in Scheme::all_extended(64, 2, 32, 256) {
            let k = SpmvKernel::build(&h, scheme);
            let mut y = vec![0.0; n];
            k.spmv(&x, &mut y);
            assert!(
                max_abs_diff(&y_ref, &y) < 1e-12,
                "scheme {scheme} disagrees with CRS on HH"
            );
        }
    }

    #[test]
    fn hot_path_matches_cold_path() {
        let mut rng = Rng::new(31);
        let n = 120;
        let coo = random_coo(&mut rng, n, n * 5);
        let mut x = vec![0.0; n];
        rng.fill_f64(&mut x, -1.0, 1.0);
        for scheme in Scheme::all_extended(16, 4, 8, 32) {
            let k = SpmvKernel::build(&coo, scheme);
            let mut y_cold = vec![0.0; n];
            k.spmv(&x, &mut y_cold);
            let mut ws = k.workspace(&x);
            k.spmv_hot(&mut ws);
            let mut y_hot = vec![0.0; n];
            k.unpermute(&ws, &mut y_hot);
            assert!(
                max_abs_diff(&y_cold, &y_hot) < 1e-12,
                "scheme {scheme}: hot path disagrees"
            );
        }
    }

    #[test]
    fn range_restricted_dispatch_matches_hot_path_exactly() {
        let mut rng = Rng::new(34);
        let n = 141;
        let coo = random_coo(&mut rng, n, n * 6);
        for scheme in Scheme::all_extended(16, 3, 8, 32) {
            let k = SpmvKernel::build(&coo, scheme);
            let mut x = vec![0.0; n];
            rng.fill_f64(&mut x, -1.0, 1.0);
            let mut ws = k.workspace(&x);
            k.spmv_hot(&mut ws);
            let mut pieced = vec![0.0; n];
            for (a, b) in [(0usize, 1usize), (1, 52), (52, 107), (107, n)] {
                let (head, _) = pieced.split_at_mut(b);
                k.spmv_rows_permuted(a, b, &ws.xp, &mut head[a..]);
            }
            assert_eq!(
                max_abs_diff(&ws.yp, &pieced),
                0.0,
                "scheme {scheme}: restricted kernel deviates from serial"
            );
        }
    }

    /// ISSUE-8 tentpole: the blocked-x multi-vector kernel is
    /// bit-identical to `k` independent range-restricted calls for every
    /// scheme (fused CRS and SELL-C-σ loops included), over arbitrary
    /// row splits.
    #[test]
    fn multi_vector_kernel_bit_identical_to_per_vector() {
        let mut rng = Rng::new(41);
        let n = 141;
        let k_vecs = 4;
        let coo = random_coo(&mut rng, n, n * 6);
        for scheme in Scheme::all_extended(16, 3, 8, 32) {
            let k = SpmvKernel::build(&coo, scheme);
            let xs: Vec<Vec<f64>> = (0..k_vecs)
                .map(|_| {
                    let mut x = vec![0.0; n];
                    rng.fill_f64(&mut x, -1.0, 1.0);
                    x
                })
                .collect();
            let xps: Vec<Vec<f64>> = xs
                .iter()
                .map(|x| {
                    let mut xp = vec![0.0; n];
                    k.permute_into(x, &mut xp);
                    xp
                })
                .collect();
            let mut want: Vec<Vec<f64>> = vec![vec![0.0; n]; k_vecs];
            for (xp, yp) in xps.iter().zip(want.iter_mut()) {
                k.spmv_rows_permuted(0, n, xp, yp);
            }
            let mut got: Vec<Vec<f64>> = vec![vec![0.0; n]; k_vecs];
            for (a, b) in [(0usize, 1usize), (1, 52), (52, 107), (107, n)] {
                let xp_refs: Vec<&[f64]> = xps.iter().map(|x| x.as_slice()).collect();
                let mut out_refs: Vec<&mut [f64]> =
                    got.iter_mut().map(|y| &mut y[a..b]).collect();
                k.spmv_rows_multi(a, b, &xp_refs, &mut out_refs);
            }
            for (w, g) in want.iter().zip(got.iter()) {
                assert_eq!(max_abs_diff(w, g), 0.0, "scheme {scheme}: multi deviates");
            }
        }
    }

    /// ISSUE-6 tentpole: the ISA-dispatched range kernel is the exact
    /// scalar loop at `Scalar` (bit identity preserved for every
    /// scheme), and within a tight relative ε at the detected level.
    #[test]
    fn isa_dispatch_preserves_scalar_and_bounds_simd() {
        let mut rng = Rng::new(39);
        let n = 167;
        let coo = random_coo(&mut rng, n, n * 6);
        let host = IsaLevel::detect();
        for scheme in Scheme::all_extended(16, 3, 8, 32) {
            let k = SpmvKernel::build(&coo, scheme);
            let mut x = vec![0.0; n];
            rng.fill_f64(&mut x, -1.0, 1.0);
            let mut ws = k.workspace(&x);
            k.spmv_hot(&mut ws);
            let mut scalar = vec![0.0; n];
            k.spmv_rows_permuted_isa(IsaLevel::Scalar, 0, n, &ws.xp, &mut scalar);
            assert_eq!(
                max_abs_diff(&ws.yp, &scalar),
                0.0,
                "scheme {scheme}: Scalar isa deviates from the scalar loop"
            );
            if host > IsaLevel::Scalar {
                let mut vec_out = vec![0.0; n];
                k.spmv_rows_permuted_isa(host, 0, n, &ws.xp, &mut vec_out);
                assert!(
                    max_abs_diff(&ws.yp, &vec_out) < 1e-10,
                    "scheme {scheme}: {host} isa out of tolerance"
                );
                let mut ws2 = k.workspace(&x);
                k.spmv_hot_isa(host, &mut ws2);
                assert_eq!(max_abs_diff(&ws2.yp, &vec_out), 0.0, "hot isa path deviates");
            }
            assert_eq!(
                k.has_simd_path(IsaLevel::Avx2),
                matches!(scheme, Scheme::Crs | Scheme::SellCs { .. }),
                "scheme {scheme}"
            );
        }
    }

    #[test]
    fn row_weights_sum_to_nnz() {
        let mut rng = Rng::new(35);
        let coo = random_coo(&mut rng, 90, 500);
        for scheme in Scheme::all_extended(20, 2, 8, 16) {
            let k = SpmvKernel::build(&coo, scheme);
            let w = k.row_weights();
            assert_eq!(w.len(), k.nrows());
            let total: f64 = w.iter().sum();
            assert_eq!(total as usize, k.nnz(), "scheme {scheme}");
        }
    }

    #[test]
    fn permute_roundtrip() {
        let mut rng = Rng::new(36);
        let coo = random_coo(&mut rng, 70, 400);
        for scheme in Scheme::all_extended(16, 2, 8, 24) {
            let k = SpmvKernel::build(&coo, scheme);
            let mut x = vec![0.0; 70];
            rng.fill_f64(&mut x, -1.0, 1.0);
            let mut xp = vec![0.0; 70];
            k.permute_into(&x, &mut xp);
            let mut back = vec![0.0; 70];
            k.unpermute_into(&xp, &mut back);
            assert_eq!(x, back, "scheme {scheme}");
        }
    }

    #[test]
    fn walk_touches_every_nnz_once_for_all_schemes() {
        use crate::matrix::jds::SpmvVisitor;
        let mut rng = Rng::new(32);
        let coo = random_coo(&mut rng, 100, 600);
        struct Count(usize);
        impl SpmvVisitor for Count {
            fn update(&mut self, _r: usize, _j: usize, _c: usize) {
                self.0 += 1;
            }
        }
        for scheme in Scheme::all_extended(25, 3, 8, 40) {
            let k = SpmvKernel::build(&coo, scheme);
            let mut c = Count(0);
            k.walk(&mut c);
            assert_eq!(c.0, k.nnz(), "scheme {scheme}");
        }
    }

    /// Split shard kernels: every output slot of both halves, in both
    /// supported schemes, is bit-identical to the serial CRS kernel on
    /// the row the slot maps to.
    #[test]
    fn shard_half_kernels_bit_identical_to_serial_rows() {
        use crate::matrix::shard::ShardedCrs;
        let mut rng = Rng::new(37);
        let n = 180;
        let coo = random_coo(&mut rng, n, n * 6);
        let crs = crate::matrix::Crs::from_coo(&coo);
        let mut x = vec![0.0; n];
        rng.fill_f64(&mut x, -1.0, 1.0);
        let mut want = vec![0.0; n];
        crs.spmv(&x, &mut want);
        let sharded = ShardedCrs::from_crs(&crs, 4);
        for scheme in [Scheme::Crs, Scheme::SellCs { c: 8, sigma: 32 }] {
            for shard in &sharded.shards {
                let k = ShardKernel::build(shard, scheme).unwrap();
                assert_eq!(k.scheme, scheme);
                assert_eq!(k.nnz(), shard.local.val.len() + shard.remote.val.len());
                let mut concat = vec![0.0; shard.concat_len()];
                shard.gather(&x, &mut concat);
                let mut out = vec![0.0; k.local.nrows()];
                k.local.spmv_rows(0, out.len(), &concat[..shard.width()], &mut out);
                for (slot, &v) in out.iter().enumerate() {
                    let row = shard.interior_rows[k.local.storage_row(slot)] as usize;
                    assert_eq!(v, want[row], "{scheme}: interior slot {slot}");
                }
                let mut out = vec![0.0; k.remote.nrows()];
                k.remote.spmv_rows(0, out.len(), &concat, &mut out);
                for (slot, &v) in out.iter().enumerate() {
                    let row = shard.boundary_rows[k.remote.storage_row(slot)] as usize;
                    assert_eq!(v, want[row], "{scheme}: boundary slot {slot}");
                }
                // Weights line up with the slots.
                let w = k.local.row_weights();
                assert_eq!(w.len(), k.local.nrows());
                assert_eq!(w.iter().sum::<f64>() as usize, k.local.nnz());
            }
        }
    }

    #[test]
    fn shard_kernels_reject_jds_family_schemes() {
        use crate::matrix::shard::ShardedCrs;
        let mut rng = Rng::new(38);
        let coo = random_coo(&mut rng, 60, 300);
        let crs = crate::matrix::Crs::from_coo(&coo);
        let sharded = ShardedCrs::from_crs(&crs, 2);
        for scheme in [Scheme::Jds, Scheme::NbJds { block: 16 }, Scheme::RbJds { block: 16 }] {
            assert!(
                ShardKernel::build(&sharded.shards[0], scheme).is_err(),
                "{scheme} has no rectangular split kernel and must be rejected"
            );
        }
    }
}
