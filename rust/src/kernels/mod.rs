//! Compute kernels: the Table-1 microbenchmark loops ([`microbench`]),
//! the unified SpMV dispatch over all storage schemes ([`spmv`]) and
//! the runtime-ISA-dispatched vector kernels ([`simd`]).

pub mod microbench;
pub mod simd;
pub mod spmv;

pub use microbench::{build_index, table1_ops, IndexPattern, MicroBuffers, MicroOp, OpKind};
pub use simd::{IsaLevel, KernelIsa, Precision};
pub use spmv::{HalfKernel, ShardKernel, SpmvKernel, Workspace};
