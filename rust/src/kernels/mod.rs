//! Compute kernels: the Table-1 microbenchmark loops ([`microbench`]) and
//! the unified SpMV dispatch over all storage schemes ([`spmv`]).

pub mod microbench;
pub mod spmv;

pub use microbench::{build_index, table1_ops, IndexPattern, MicroBuffers, MicroOp, OpKind};
pub use spmv::{HalfKernel, ShardKernel, SpmvKernel, Workspace};
