//! Explicitly vectorized SpMV kernels behind runtime ISA detection.
//!
//! The paper's first performance limit is per-core kernel throughput,
//! and SELL-C-σ exists precisely to feed wide SIMD units (Kreutzer et
//! al., arXiv:1307.6209): a slice stores C rows column-major so one
//! vector FMA advances C rows in lockstep. This module provides those
//! kernels as `std::arch` intrinsics — a 4-lane AVX2+FMA path and an
//! 8-lane path — selected at runtime by a cached [`IsaLevel`] probe,
//! with the scalar loops in [`crate::matrix::SellCs`] /
//! [`crate::matrix::Crs`] as the portable fallback.
//!
//! ## The two 8-lane bodies: native `_mm512_*` vs paired AVX2
//!
//! The AVX-512 intrinsics stabilized in Rust 1.89; this crate builds
//! offline on whatever toolchain is present, so `build.rs` probes the
//! compiling rustc and sets the `spmv_avx512_native` cfg when the
//! floor allows. With the cfg, the [`IsaLevel::Avx512`] lane bodies
//! are **native 512-bit**: one `_mm512_fmadd_pd` per group iteration,
//! fed by two 256-bit gathers merged with `_mm512_insertf64x4` (the
//! f64 gather still indexes with `i32`, so the 256-bit gather pair is
//! the natural feeder). On older toolchains the same entry points
//! compile as **two interleaved 256-bit AVX2+FMA streams** (stable
//! since Rust 1.27) — the per-iteration accumulator group is still 8
//! lanes wide, so the tuner's `Avx512` candidate exists either way and
//! only the instruction encoding differs. The fused multi-vector
//! (SpMM) bodies stay 4-lane at every level: they pack x-values from
//! `k` separate base pointers, which no gather width accelerates.
//!
//! ## Numerical contract
//!
//! Vector kernels are **not** bit-identical to the scalar loops:
//!
//! - FMA fuses multiply and add into one rounding where the scalar code
//!   rounds twice;
//! - the SELL group kernel iterates every lane to the group's widest
//!   row, so shorter rows accumulate explicit `+ 0.0 · x[0]` padding
//!   terms (which can flip a `-0.0` sum to `+0.0`, and assumes finite
//!   `x`);
//! - the CRS gather kernel folds a row into 4/8 partial sums and
//!   reduces them at the end, reordering the row's additions.
//!
//! That is exactly why the [`Precision`] contract exists: the default
//! [`Precision::BitIdentical`] excludes every kernel in this module
//! from tuning candidacy, and [`Precision::Tolerance`] admits them with
//! an explicit ε the caller chose. The tuning layer
//! ([`crate::tune`]) arbitrates simd-vs-scalar per matrix like any
//! other candidate and records the [`KernelIsa`] pick in its report.

use std::sync::OnceLock;

use anyhow::Result;

use crate::matrix::{Crs, SellCs, SellRect};

/// Instruction-set level a kernel is dispatched at. Ordered: a level
/// compares greater than every level it strictly extends.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum IsaLevel {
    /// Portable scalar loops (the bit-identity reference).
    Scalar,
    /// 4-lane f64 vectors: AVX2 + FMA.
    Avx2,
    /// 8-lane f64 groups (native `_mm512_*` on new-enough toolchains,
    /// paired AVX2 streams otherwise; see module docs).
    Avx512,
}

/// The ISA a tuned kernel was bound to — recorded in
/// [`crate::tune::TuningReport`]. Alias of [`IsaLevel`]; the report
/// speaks of the *choice*, the probe speaks of the *capability*.
pub type KernelIsa = IsaLevel;

impl IsaLevel {
    /// The host's best supported level, probed once per process via
    /// CPUID (`is_x86_feature_detected!`) and cached.
    pub fn detect() -> IsaLevel {
        static DETECTED: OnceLock<IsaLevel> = OnceLock::new();
        *DETECTED.get_or_init(|| {
            // Under Miri there is no CPUID and intrinsic bodies cannot
            // be interpreted, so everything runs the scalar paths.
            #[cfg(all(target_arch = "x86_64", not(miri)))]
            {
                if std::is_x86_feature_detected!("avx2")
                    && std::is_x86_feature_detected!("fma")
                {
                    if std::is_x86_feature_detected!("avx512f") {
                        return IsaLevel::Avx512;
                    }
                    return IsaLevel::Avx2;
                }
            }
            IsaLevel::Scalar
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            IsaLevel::Scalar => "scalar",
            IsaLevel::Avx2 => "avx2",
            IsaLevel::Avx512 => "avx512",
        }
    }

    /// f64 lanes advanced per accumulator group.
    pub fn lanes(&self) -> usize {
        match self {
            IsaLevel::Scalar => 1,
            IsaLevel::Avx2 => 4,
            IsaLevel::Avx512 => 8,
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        match s.trim().to_ascii_lowercase().as_str() {
            "scalar" => Ok(IsaLevel::Scalar),
            "avx2" => Ok(IsaLevel::Avx2),
            "avx512" => Ok(IsaLevel::Avx512),
            other => anyhow::bail!("unknown isa level '{other}' (scalar|avx2|avx512)"),
        }
    }
}

impl std::fmt::Display for IsaLevel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// The numerical contract a handle is built under.
///
/// - [`Precision::BitIdentical`] (the default): every result is bit for
///   bit the serial CRS reference — the invariant the whole existing
///   backend × scheme × schedule × pinning matrix asserts. SIMD kernels
///   are excluded from tuning candidacy.
/// - [`Precision::Tolerance`]`(ε)`: results may deviate from the serial
///   CRS reference by reordered/fused floating-point accumulation, and
///   the caller accepts error up to `ε` relative to the row's
///   accumulation magnitude. SIMD kernels become ordinary tuning
///   candidates.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Precision {
    BitIdentical,
    Tolerance(f64),
}

impl Default for Precision {
    fn default() -> Self {
        Precision::BitIdentical
    }
}

impl Precision {
    /// May the tuner consider vectorized (add-reordering) kernels?
    pub fn allows_simd(&self) -> bool {
        matches!(self, Precision::Tolerance(_))
    }

    /// The accepted relative error, when one was granted.
    pub fn tolerance(&self) -> Option<f64> {
        match self {
            Precision::BitIdentical => None,
            Precision::Tolerance(eps) => Some(*eps),
        }
    }

    pub fn name(&self) -> String {
        match self {
            Precision::BitIdentical => "bit-identical".to_string(),
            Precision::Tolerance(eps) => format!("tolerance({eps:.1e})"),
        }
    }

    /// Parse a CLI spelling: `bit` / `bit-identical` / `bitidentical`,
    /// `tol:<eps>`, or a bare float (meaning `Tolerance`).
    pub fn parse(s: &str) -> Result<Self> {
        let t = s.trim().to_ascii_lowercase();
        match t.as_str() {
            "bit" | "bit-identical" | "bitidentical" => return Ok(Precision::BitIdentical),
            _ => {}
        }
        let eps_str = t.strip_prefix("tol:").unwrap_or(&t);
        let eps: f64 = eps_str.parse().map_err(|_| {
            anyhow::anyhow!("bad --precision '{s}' (bit | tol:<eps> | <eps>)")
        })?;
        anyhow::ensure!(
            eps.is_finite() && eps > 0.0,
            "--precision tolerance must be a positive finite number, got {eps}"
        );
        Ok(Precision::Tolerance(eps))
    }
}

/// Largest vector length the 32-bit gather index can address.
#[inline]
fn gather_indexable(len: usize) -> bool {
    len <= i32::MAX as usize
}

/// Vectorized SELL-C-σ range kernel: permuted rows `[row_begin,
/// row_end)` into `out[i - row_begin]`, same contract as
/// [`SellCs::spmv_rows_permuted`]. Falls back to the scalar loop for
/// `IsaLevel::Scalar`, off x86_64, for partial lane groups and for
/// matrices too large for 32-bit gather indices.
///
/// Callers must not pass an `isa` above [`IsaLevel::detect`] — the
/// dispatch layers ([`crate::kernels::SpmvKernel`], the tuner) only
/// ever hand down the detected level.
pub fn sell_rows_permuted(
    isa: IsaLevel,
    m: &SellCs,
    row_begin: usize,
    row_end: usize,
    xp: &[f64],
    out: &mut [f64],
) {
    #[cfg(target_arch = "x86_64")]
    if isa > IsaLevel::Scalar && gather_indexable(xp.len()) {
        x86::sell_rows(isa, m, row_begin, row_end, xp, out);
        return;
    }
    let _ = isa;
    m.spmv_rows_permuted(row_begin, row_end, xp, out);
}

/// Vectorized CRS range kernel: rows `[row_begin, row_end)` into
/// `out[i - row_begin]`, same contract as [`Crs::spmv_rows_into`].
/// Each row is folded into 4 (`Avx2`) or 8 (`Avx512`) gather-FMA
/// partial sums and reduced at the end. Fallback rules as
/// [`sell_rows_permuted`].
pub fn crs_rows_into(
    isa: IsaLevel,
    m: &Crs,
    row_begin: usize,
    row_end: usize,
    x: &[f64],
    out: &mut [f64],
) {
    #[cfg(target_arch = "x86_64")]
    if isa > IsaLevel::Scalar && gather_indexable(x.len()) {
        x86::crs_rows(isa, m, row_begin, row_end, x, out);
        return;
    }
    let _ = isa;
    m.spmv_rows_into(row_begin, row_end, x, out);
}

/// Vectorized rectangular-SELL (shard-half) range kernel over permuted
/// row **slots** — same contract as [`SellRect::spmv_rows`], reading
/// `x` in the half's own column space (the owned slice for a local
/// half, the concatenated `[owned | halo]` gather buffer for a remote
/// half). Reuses the square-SELL lane bodies: the slice layout (`idx =
/// base + k*h + lane`) is identical, per-row accumulation stays
/// ascending `k` = the original CRS entry order, so only FMA fusion
/// and explicit `+ 0.0 · x[0]` padding terms separate it from the
/// scalar loop — the [`Precision::Tolerance`] bound holds per row.
/// Fallback rules as [`sell_rows_permuted`].
pub fn sell_rect_rows(
    isa: IsaLevel,
    m: &SellRect,
    row_begin: usize,
    row_end: usize,
    x: &[f64],
    out: &mut [f64],
) {
    #[cfg(target_arch = "x86_64")]
    if isa > IsaLevel::Scalar && gather_indexable(x.len()) {
        x86::sell_rect_rows(isa, m, row_begin, row_end, x, out);
        return;
    }
    let _ = isa;
    m.spmv_rows(row_begin, row_end, x, out);
}

/// Vectorized fused blocked-x SpMM over CRS rows: every matrix entry
/// is loaded once, broadcast, and FMAed across the column block of `k`
/// vectors — the vector body behind
/// [`crate::kernels::SpmvKernel::spmv_rows_multi_isa`]. Per vector the
/// entry order is exactly the fused scalar loop's (ascending `j`), so
/// the deviation is FMA fusion only and the [`Precision::Tolerance`]
/// bound holds. Falls back to the fused scalar loop at
/// `IsaLevel::Scalar` and off x86_64.
pub fn crs_rows_multi(
    isa: IsaLevel,
    m: &Crs,
    row_begin: usize,
    row_end: usize,
    xps: &[&[f64]],
    outs: &mut [&mut [f64]],
) {
    debug_assert_eq!(xps.len(), outs.len());
    #[cfg(target_arch = "x86_64")]
    if isa > IsaLevel::Scalar {
        x86::crs_multi(m, row_begin, row_end, xps, outs);
        return;
    }
    let _ = isa;
    let mut acc = vec![0.0; xps.len()];
    for i in row_begin..row_end {
        let (a, b) = (m.row_ptr[i], m.row_ptr[i + 1]);
        acc.fill(0.0);
        for j in a..b {
            let v = m.val[j];
            let c = m.col_idx[j] as usize;
            for (sum, xp) in acc.iter_mut().zip(xps) {
                *sum += v * xp[c];
            }
        }
        for (out, &sum) in outs.iter_mut().zip(acc.iter()) {
            out[i - row_begin] = sum;
        }
    }
}

/// Vectorized fused blocked-x SpMM over SELL-C-σ rows — the SELL
/// counterpart of [`crs_rows_multi`], walking each permuted row's
/// strided slice entries (ascending `k`, the fused scalar loop's
/// order) and broadcasting each entry across the vector block.
pub fn sell_rows_multi(
    isa: IsaLevel,
    m: &SellCs,
    row_begin: usize,
    row_end: usize,
    xps: &[&[f64]],
    outs: &mut [&mut [f64]],
) {
    debug_assert_eq!(xps.len(), outs.len());
    #[cfg(target_arch = "x86_64")]
    if isa > IsaLevel::Scalar {
        x86::sell_multi(m, row_begin, row_end, xps, outs);
        return;
    }
    let _ = isa;
    let mut acc = vec![0.0; xps.len()];
    for i in row_begin..row_end {
        let s = i / m.c;
        let (lo, hi) = m.slice_rows(s);
        let h = hi - lo;
        let lane = i - lo;
        let base = m.slice_ptr[s];
        acc.fill(0.0);
        for t in 0..m.row_nnz[i] as usize {
            let idx = base + t * h + lane;
            let v = m.val[idx];
            let c = m.col_idx[idx] as usize;
            for (sum, xp) in acc.iter_mut().zip(xps) {
                *sum += v * xp[c];
            }
        }
        for (out, &sum) in outs.iter_mut().zip(acc.iter()) {
            out[i - row_begin] = sum;
        }
    }
}

/// Vectorized streaming triad `a[i] = b[i] + scale * c[i]` — the
/// microbenchmark counterpart ([`crate::kernels::microbench`]) that
/// lets the tuner's heuristic price the ISA gain on this host.
pub fn triad(isa: IsaLevel, a: &mut [f64], b: &[f64], c: &[f64], scale: f64) {
    assert_eq!(a.len(), b.len());
    assert_eq!(a.len(), c.len());
    #[cfg(target_arch = "x86_64")]
    if isa > IsaLevel::Scalar {
        // SAFETY: `isa > Scalar` is only reachable when IsaLevel::detect()
        // reported AVX2+FMA support on this CPU (caller contract), which
        // is exactly what the target_feature attribute requires.
        unsafe { x86::triad_avx2(a, b, c, scale) };
        return;
    }
    let _ = isa;
    for i in 0..a.len() {
        a[i] = b[i] + scale * c[i];
    }
}

/// Gather-FMA reduction `Σᵢ a[i]·b[ind[i]]` — the vector counterpart
/// of the Table-1 IS-SCP loop
/// ([`crate::kernels::microbench::is_scp`]). The gather-bandwidth
/// microbenchmark ([`crate::kernels::microbench::cached_gather_gain`])
/// measures it against its own `Scalar` level to price the gather-FMA
/// SpMV kernels. Indices are bounds-checked up front on **every**
/// level, so the scalar/vector timing comparison stays symmetric.
pub fn gather_scp(isa: IsaLevel, a: &[f64], b: &[f64], ind: &[u32]) -> f64 {
    assert_eq!(a.len(), ind.len());
    assert!(ind.iter().all(|&j| (j as usize) < b.len()), "gather index out of range");
    #[cfg(target_arch = "x86_64")]
    if isa > IsaLevel::Scalar && gather_indexable(b.len()) {
        // SAFETY: `isa > Scalar` is only reachable when IsaLevel::detect()
        // reported AVX2+FMA support (caller contract); every index was
        // validated in range just above.
        return unsafe { x86::gather_scp(a, b, ind) };
    }
    let _ = isa;
    let mut s = 0.0;
    for (x, &j) in a.iter().zip(ind) {
        s += x * b[j as usize];
    }
    s
}

#[cfg(target_arch = "x86_64")]
mod x86 {
    //! The intrinsics bodies. Everything here is gated on the caller
    //! having verified AVX2+FMA support via [`IsaLevel::detect`].

    use std::arch::x86_64::{
        __m128i, __m256d, _mm256_castpd256_pd128, _mm256_extractf128_pd, _mm256_fmadd_pd,
        _mm256_i32gather_pd, _mm256_loadu_pd, _mm256_set1_pd, _mm256_set_pd,
        _mm256_setzero_pd, _mm256_storeu_pd, _mm_add_pd, _mm_add_sd, _mm_cvtsd_f64,
        _mm_loadu_si128, _mm_unpackhi_pd,
    };
    #[cfg(not(spmv_avx512_native))]
    use std::arch::x86_64::_mm256_add_pd;
    #[cfg(spmv_avx512_native)]
    use std::arch::x86_64::{
        _mm512_castpd256_pd512, _mm512_fmadd_pd, _mm512_insertf64x4, _mm512_loadu_pd,
        _mm512_reduce_add_pd, _mm512_setzero_pd, _mm512_storeu_pd,
    };

    use super::IsaLevel;
    use crate::matrix::{Crs, SellCs, SellRect};

    /// Widest row (in non-zeros) of a lane group — the shared trip
    /// count; shorter lanes ride through their zero padding.
    #[inline]
    fn group_width(row_nnz: &[u32]) -> usize {
        row_nnz.iter().copied().max().unwrap_or(0) as usize
    }

    pub fn sell_rows(
        isa: IsaLevel,
        m: &SellCs,
        row_begin: usize,
        row_end: usize,
        xp: &[f64],
        out: &mut [f64],
    ) {
        debug_assert!(row_end <= m.nrows);
        debug_assert_eq!(out.len(), row_end - row_begin);
        let mut i = row_begin;
        while i < row_end {
            let s = i / m.c;
            let (lo, hi) = m.slice_rows(s);
            let h = hi - lo;
            let base = m.slice_ptr[s];
            let stop = hi.min(row_end);
            if isa >= IsaLevel::Avx512 {
                while i + 8 <= stop {
                    let w = group_width(&m.row_nnz[i..i + 8]);
                    let o = i - row_begin;
                    // SAFETY: the dispatch contract guarantees the CPU
                    // supports AVX2+FMA (IsaLevel::detect() bounded
                    // `isa`); lane bounds are argued at the callee.
                    unsafe {
                        sell_lane8(
                            &m.val,
                            &m.col_idx,
                            xp,
                            base,
                            h,
                            i - lo,
                            w,
                            &mut out[o..o + 8],
                        )
                    };
                    i += 8;
                }
            }
            while i + 4 <= stop {
                let w = group_width(&m.row_nnz[i..i + 4]);
                let o = i - row_begin;
                // SAFETY: as above — CPU support established by detect(),
                // in-bounds access argued at the callee.
                unsafe {
                    sell_lane4(&m.val, &m.col_idx, xp, base, h, i - lo, w, &mut out[o..o + 4])
                };
                i += 4;
            }
            if i < stop {
                // Partial group at the slice (or range) edge: scalar.
                m.spmv_rows_permuted(i, stop, xp, &mut out[i - row_begin..stop - row_begin]);
                i = stop;
            }
        }
    }

    /// The rectangular (shard-half) twin of [`sell_rows`]: identical
    /// slice layout, so the lane bodies are shared; only the matrix
    /// type and the column space (`x` is the half's own space, columns
    /// not relabeled) differ.
    pub fn sell_rect_rows(
        isa: IsaLevel,
        m: &SellRect,
        row_begin: usize,
        row_end: usize,
        x: &[f64],
        out: &mut [f64],
    ) {
        debug_assert!(row_end <= m.nrows);
        debug_assert_eq!(out.len(), row_end - row_begin);
        let mut i = row_begin;
        while i < row_end {
            let s = i / m.c;
            let lo = s * m.c;
            let hi = ((s + 1) * m.c).min(m.nrows);
            let h = hi - lo;
            let base = m.slice_ptr[s];
            let stop = hi.min(row_end);
            if isa >= IsaLevel::Avx512 {
                while i + 8 <= stop {
                    let w = group_width(&m.row_nnz[i..i + 8]);
                    let o = i - row_begin;
                    // SAFETY: dispatch contract (detect() bounded
                    // `isa`); lane bounds argued at the callee — the
                    // group lies inside slice `s` and `w` is its width
                    // bound, col entries are half-space ids < x.len().
                    unsafe {
                        sell_lane8(
                            &m.val,
                            &m.col_idx,
                            x,
                            base,
                            h,
                            i - lo,
                            w,
                            &mut out[o..o + 8],
                        )
                    };
                    i += 8;
                }
            }
            while i + 4 <= stop {
                let w = group_width(&m.row_nnz[i..i + 4]);
                let o = i - row_begin;
                // SAFETY: as above — CPU support established by
                // detect(), in-bounds access argued at the callee.
                unsafe {
                    sell_lane4(&m.val, &m.col_idx, x, base, h, i - lo, w, &mut out[o..o + 4])
                };
                i += 4;
            }
            if i < stop {
                // Partial group at the slice (or range) edge: scalar.
                m.spmv_rows(i, stop, x, &mut out[i - row_begin..stop - row_begin]);
                i = stop;
            }
        }
    }

    /// One 4-lane SELL accumulator group: lanes `lane..lane+4` of a
    /// slice at `base` with height `h`, iterated to width `w`.
    ///
    /// In-bounds argument (holds for every call from [`sell_rows`]):
    /// `lane + 4 <= h` (the group lies inside the slice) and `w <=
    /// slice_width[s]`, so every touched index `base + k*h + lane + t`
    /// (`k < w`, `t < 4`) is below `slice_ptr[s+1] <= val.len()`; and
    /// `col_idx` entries are permuted column ids `< xp.len()`.
    ///
    /// SAFETY: caller must ensure AVX2+FMA support (dispatch contract)
    /// and the in-bounds argument above.
    #[target_feature(enable = "avx2", enable = "fma")]
    #[allow(clippy::too_many_arguments)]
    unsafe fn sell_lane4(
        val: &[f64],
        col: &[u32],
        xp: &[f64],
        base: usize,
        h: usize,
        lane: usize,
        w: usize,
        out: &mut [f64],
    ) {
        let mut acc = _mm256_setzero_pd();
        for k in 0..w {
            let idx = base + k * h + lane;
            // SAFETY: idx + 3 < val.len() and col[idx..idx+4] < xp.len()
            // per the function-level in-bounds argument.
            let v = _mm256_loadu_pd(val.as_ptr().add(idx));
            let ci = _mm_loadu_si128(col.as_ptr().add(idx) as *const __m128i);
            let xv = _mm256_i32gather_pd::<8>(xp.as_ptr(), ci);
            acc = _mm256_fmadd_pd(v, xv, acc);
        }
        _mm256_storeu_pd(out.as_mut_ptr(), acc);
    }

    /// One 8-lane SELL group as two interleaved 256-bit streams (the
    /// `Avx512` level on pre-1.89 toolchains; see module docs).
    /// Requires `lane + 8 <= h`; the in-bounds argument of
    /// [`sell_lane4`] applies to both streams.
    ///
    /// SAFETY: caller must ensure AVX2+FMA support (dispatch contract)
    /// and the in-bounds argument above.
    #[cfg(not(spmv_avx512_native))]
    #[target_feature(enable = "avx2", enable = "fma")]
    #[allow(clippy::too_many_arguments)]
    unsafe fn sell_lane8(
        val: &[f64],
        col: &[u32],
        xp: &[f64],
        base: usize,
        h: usize,
        lane: usize,
        w: usize,
        out: &mut [f64],
    ) {
        let mut acc0 = _mm256_setzero_pd();
        let mut acc1 = _mm256_setzero_pd();
        for k in 0..w {
            let idx = base + k * h + lane;
            // SAFETY: idx + 7 < val.len() and col[idx..idx+8] < xp.len()
            // per the function-level in-bounds argument.
            let v0 = _mm256_loadu_pd(val.as_ptr().add(idx));
            let v1 = _mm256_loadu_pd(val.as_ptr().add(idx + 4));
            let c0 = _mm_loadu_si128(col.as_ptr().add(idx) as *const __m128i);
            let c1 = _mm_loadu_si128(col.as_ptr().add(idx + 4) as *const __m128i);
            let x0 = _mm256_i32gather_pd::<8>(xp.as_ptr(), c0);
            let x1 = _mm256_i32gather_pd::<8>(xp.as_ptr(), c1);
            acc0 = _mm256_fmadd_pd(v0, x0, acc0);
            acc1 = _mm256_fmadd_pd(v1, x1, acc1);
        }
        _mm256_storeu_pd(out.as_mut_ptr(), acc0);
        _mm256_storeu_pd(out.as_mut_ptr().add(4), acc1);
    }

    /// One 8-lane SELL group, native 512-bit (the `Avx512` level when
    /// `build.rs` found a 1.89+ toolchain; see module docs): one
    /// `_mm512_fmadd_pd` per slice column, fed by a pair of 256-bit
    /// gathers merged with `_mm512_insertf64x4`. Requires `lane + 8 <=
    /// h`; the in-bounds argument of [`sell_lane4`] applies to both
    /// gather halves. Per-lane accumulation order is unchanged from the
    /// paired-stream body (each lane owns one row), so the Tolerance
    /// bound is identical.
    ///
    /// SAFETY: caller must ensure AVX-512F+AVX2+FMA support (dispatch
    /// contract) and the in-bounds argument of [`sell_lane4`].
    #[cfg(spmv_avx512_native)]
    #[target_feature(enable = "avx512f", enable = "avx2", enable = "fma")]
    #[allow(clippy::too_many_arguments)]
    unsafe fn sell_lane8(
        val: &[f64],
        col: &[u32],
        xp: &[f64],
        base: usize,
        h: usize,
        lane: usize,
        w: usize,
        out: &mut [f64],
    ) {
        let mut acc = _mm512_setzero_pd();
        for k in 0..w {
            let idx = base + k * h + lane;
            // SAFETY: idx + 7 < val.len() and col[idx..idx+8] < xp.len()
            // per the function-level in-bounds argument; avx512f support
            // established by IsaLevel::detect() (dispatch contract).
            let v = _mm512_loadu_pd(val.as_ptr().add(idx));
            let c0 = _mm_loadu_si128(col.as_ptr().add(idx) as *const __m128i);
            let c1 = _mm_loadu_si128(col.as_ptr().add(idx + 4) as *const __m128i);
            let x0 = _mm256_i32gather_pd::<8>(xp.as_ptr(), c0);
            let x1 = _mm256_i32gather_pd::<8>(xp.as_ptr(), c1);
            let xv = _mm512_insertf64x4::<1>(_mm512_castpd256_pd512(x0), x1);
            acc = _mm512_fmadd_pd(v, xv, acc);
        }
        _mm512_storeu_pd(out.as_mut_ptr(), acc);
    }

    pub fn crs_rows(
        isa: IsaLevel,
        m: &Crs,
        row_begin: usize,
        row_end: usize,
        x: &[f64],
        out: &mut [f64],
    ) {
        debug_assert_eq!(out.len(), row_end - row_begin);
        for i in row_begin..row_end {
            let (a, b) = (m.row_ptr[i], m.row_ptr[i + 1]);
            let (val, col) = (&m.val[a..b], &m.col_idx[a..b]);
            // SAFETY: CPU support established by detect() per the
            // dispatch contract; the callee only touches val/col in
            // bounds and gathers x at column ids < x.len().
            out[i - row_begin] = if isa >= IsaLevel::Avx512 {
                unsafe { crs_row8(val, col, x) }
            } else {
                unsafe { crs_row4(val, col, x) }
            };
        }
    }

    /// Horizontal sum of a 4-lane accumulator.
    ///
    /// SAFETY: caller must ensure AVX2 support (dispatch contract —
    /// every path here is gated on `IsaLevel::detect()`); the body only
    /// touches its value argument.
    #[target_feature(enable = "avx2")]
    unsafe fn hsum4(v: __m256d) -> f64 {
        let hi = _mm256_extractf128_pd::<1>(v);
        let lo = _mm256_castpd256_pd128(v);
        let s = _mm_add_pd(lo, hi);
        let shuf = _mm_unpackhi_pd(s, s);
        _mm_cvtsd_f64(_mm_add_sd(s, shuf))
    }

    /// One CRS row as 4 gather-FMA partial sums + scalar tail.
    ///
    /// SAFETY: caller must ensure AVX2+FMA support (dispatch contract)
    /// and `col` entries validated `< x.len()`; `val.len() == col.len()`.
    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn crs_row4(val: &[f64], col: &[u32], x: &[f64]) -> f64 {
        let n = val.len();
        let n4 = n & !3;
        let mut acc = _mm256_setzero_pd();
        let mut j = 0;
        while j < n4 {
            // SAFETY: j + 3 < n4 <= val.len() == col.len(); col entries
            // are validated column ids < x.len().
            let v = _mm256_loadu_pd(val.as_ptr().add(j));
            let ci = _mm_loadu_si128(col.as_ptr().add(j) as *const __m128i);
            let xv = _mm256_i32gather_pd::<8>(x.as_ptr(), ci);
            acc = _mm256_fmadd_pd(v, xv, acc);
            j += 4;
        }
        let mut s = hsum4(acc);
        while j < n {
            s += val[j] * x[col[j] as usize];
            j += 1;
        }
        s
    }

    /// One CRS row as 8 partial sums in two 256-bit streams + tail
    /// (the `Avx512` level on pre-1.89 toolchains).
    ///
    /// SAFETY: caller must ensure AVX2+FMA support (dispatch contract)
    /// and `col` entries validated `< x.len()`; `val.len() == col.len()`.
    #[cfg(not(spmv_avx512_native))]
    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn crs_row8(val: &[f64], col: &[u32], x: &[f64]) -> f64 {
        let n = val.len();
        let n8 = n & !7;
        let mut acc0 = _mm256_setzero_pd();
        let mut acc1 = _mm256_setzero_pd();
        let mut j = 0;
        while j < n8 {
            // SAFETY: j + 7 < n8 <= val.len() == col.len(); col entries
            // are validated column ids < x.len().
            let v0 = _mm256_loadu_pd(val.as_ptr().add(j));
            let v1 = _mm256_loadu_pd(val.as_ptr().add(j + 4));
            let c0 = _mm_loadu_si128(col.as_ptr().add(j) as *const __m128i);
            let c1 = _mm_loadu_si128(col.as_ptr().add(j + 4) as *const __m128i);
            acc0 = _mm256_fmadd_pd(v0, _mm256_i32gather_pd::<8>(x.as_ptr(), c0), acc0);
            acc1 = _mm256_fmadd_pd(v1, _mm256_i32gather_pd::<8>(x.as_ptr(), c1), acc1);
            j += 8;
        }
        let mut s = hsum4(_mm256_add_pd(acc0, acc1));
        while j < n {
            s += val[j] * x[col[j] as usize];
            j += 1;
        }
        s
    }

    /// One CRS row as 8 native 512-bit partial sums + tail (the
    /// `Avx512` level when `build.rs` found a 1.89+ toolchain). The
    /// final `_mm512_reduce_add_pd` reorders the lane reduction vs the
    /// paired-stream body — both are within the same Tolerance bound
    /// (the row is already folded into 8 reordered partials either
    /// way).
    ///
    /// SAFETY: caller must ensure AVX-512F+AVX2+FMA support (dispatch
    /// contract) and `col` entries validated `< x.len()`; `val.len() ==
    /// col.len()`.
    #[cfg(spmv_avx512_native)]
    #[target_feature(enable = "avx512f", enable = "avx2", enable = "fma")]
    unsafe fn crs_row8(val: &[f64], col: &[u32], x: &[f64]) -> f64 {
        let n = val.len();
        let n8 = n & !7;
        let mut acc = _mm512_setzero_pd();
        let mut j = 0;
        while j < n8 {
            // SAFETY: j + 7 < n8 <= val.len() == col.len(); col entries
            // are validated column ids < x.len(); avx512f support
            // established by IsaLevel::detect() (dispatch contract).
            let v = _mm512_loadu_pd(val.as_ptr().add(j));
            let c0 = _mm_loadu_si128(col.as_ptr().add(j) as *const __m128i);
            let c1 = _mm_loadu_si128(col.as_ptr().add(j + 4) as *const __m128i);
            let x0 = _mm256_i32gather_pd::<8>(x.as_ptr(), c0);
            let x1 = _mm256_i32gather_pd::<8>(x.as_ptr(), c1);
            let xv = _mm512_insertf64x4::<1>(_mm512_castpd256_pd512(x0), x1);
            acc = _mm512_fmadd_pd(v, xv, acc);
            j += 8;
        }
        let mut s = _mm512_reduce_add_pd(acc);
        while j < n {
            s += val[j] * x[col[j] as usize];
            j += 1;
        }
        s
    }

    /// Fused vectors per pass of the blocked-x SpMM bodies: 8 groups ×
    /// 4 lanes = 32 vectors share one load of each matrix entry before
    /// a (never-in-practice) wider block re-streams the row.
    const MULTI_GROUPS: usize = 8;

    pub fn crs_multi(
        m: &Crs,
        row_begin: usize,
        row_end: usize,
        xps: &[&[f64]],
        outs: &mut [&mut [f64]],
    ) {
        for i in row_begin..row_end {
            let (a, b) = (m.row_ptr[i], m.row_ptr[i + 1]);
            // SAFETY: dispatch contract (IsaLevel::detect() bounded the
            // ISA ⇒ AVX2+FMA present); the callee touches val/col only
            // inside [a, b) and x-values at validated column ids.
            unsafe { row_multi(&m.val[a..b], &m.col_idx[a..b], xps, outs, i - row_begin) };
        }
    }

    pub fn sell_multi(
        m: &SellCs,
        row_begin: usize,
        row_end: usize,
        xps: &[&[f64]],
        outs: &mut [&mut [f64]],
    ) {
        for i in row_begin..row_end {
            let s = i / m.c;
            let (lo, hi) = m.slice_rows(s);
            let h = hi - lo;
            let lane = i - lo;
            let base = m.slice_ptr[s];
            let nnz = m.row_nnz[i] as usize;
            let o = i - row_begin;
            // SAFETY: dispatch contract as in crs_multi; the callee
            // walks only this row's real entries (k < row_nnz[i], all
            // inside slice s) with bounds-checked slice indexing.
            unsafe { sell_row_multi(&m.val, &m.col_idx, base, h, lane, nnz, xps, outs, o) };
        }
    }

    /// One row × k-vector fused pass over contiguous entries: broadcast
    /// each matrix entry, pack 4 x-values from 4 separate vector base
    /// pointers (`_mm256_set_pd` — separate allocations forbid a single
    /// gather), FMA into per-group accumulators. Vectors beyond
    /// 4·[`MULTI_GROUPS`] re-stream the row; the `k % 4` remainder runs
    /// the fused scalar order. Per-vector entry order is ascending `j`
    /// in every path, so only FMA fusion separates this from the scalar
    /// fused loop.
    ///
    /// SAFETY: caller must ensure AVX2+FMA support (dispatch contract);
    /// all slice access is bounds-checked.
    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn row_multi(
        val: &[f64],
        col: &[u32],
        xps: &[&[f64]],
        outs: &mut [&mut [f64]],
        o: usize,
    ) {
        let mut v0 = 0;
        while v0 < xps.len() {
            let vend = (v0 + 4 * MULTI_GROUPS).min(xps.len());
            let groups = (vend - v0) / 4;
            let mut acc = [_mm256_setzero_pd(); MULTI_GROUPS];
            for (&vj, &cj) in val.iter().zip(col.iter()) {
                let v = _mm256_set1_pd(vj);
                let c = cj as usize;
                for (g, a) in acc.iter_mut().take(groups).enumerate() {
                    let t = v0 + 4 * g;
                    let xv = _mm256_set_pd(xps[t + 3][c], xps[t + 2][c], xps[t + 1][c], xps[t][c]);
                    *a = _mm256_fmadd_pd(v, xv, *a);
                }
            }
            for (g, a) in acc.iter().take(groups).enumerate() {
                let mut tmp = [0.0f64; 4];
                // SAFETY: tmp is a 4-element f64 array — exactly one
                // 256-bit store.
                _mm256_storeu_pd(tmp.as_mut_ptr(), *a);
                for (t, &s) in tmp.iter().enumerate() {
                    outs[v0 + 4 * g + t][o] = s;
                }
            }
            for t in (v0 + 4 * groups)..vend {
                let mut s = 0.0;
                for (&vj, &cj) in val.iter().zip(col.iter()) {
                    s += vj * xps[t][cj as usize];
                }
                outs[t][o] = s;
            }
            v0 = vend;
        }
    }

    /// The SELL twin of [`row_multi`]: the row's entries sit at `base +
    /// k·h + lane` for ascending `k` (the original entry order); only
    /// the real entries (`k < nnz`) are walked, so padding never enters
    /// the sum and the result matches the fused scalar SELL loop up to
    /// FMA fusion.
    ///
    /// SAFETY: caller must ensure AVX2+FMA support (dispatch contract);
    /// all slice access is bounds-checked.
    #[target_feature(enable = "avx2", enable = "fma")]
    #[allow(clippy::too_many_arguments)]
    unsafe fn sell_row_multi(
        val: &[f64],
        col: &[u32],
        base: usize,
        h: usize,
        lane: usize,
        nnz: usize,
        xps: &[&[f64]],
        outs: &mut [&mut [f64]],
        o: usize,
    ) {
        let mut v0 = 0;
        while v0 < xps.len() {
            let vend = (v0 + 4 * MULTI_GROUPS).min(xps.len());
            let groups = (vend - v0) / 4;
            let mut acc = [_mm256_setzero_pd(); MULTI_GROUPS];
            for k in 0..nnz {
                let idx = base + k * h + lane;
                let v = _mm256_set1_pd(val[idx]);
                let c = col[idx] as usize;
                for (g, a) in acc.iter_mut().take(groups).enumerate() {
                    let t = v0 + 4 * g;
                    let xv = _mm256_set_pd(xps[t + 3][c], xps[t + 2][c], xps[t + 1][c], xps[t][c]);
                    *a = _mm256_fmadd_pd(v, xv, *a);
                }
            }
            for (g, a) in acc.iter().take(groups).enumerate() {
                let mut tmp = [0.0f64; 4];
                // SAFETY: tmp is a 4-element f64 array — exactly one
                // 256-bit store.
                _mm256_storeu_pd(tmp.as_mut_ptr(), *a);
                for (t, &s) in tmp.iter().enumerate() {
                    outs[v0 + 4 * g + t][o] = s;
                }
            }
            for t in (v0 + 4 * groups)..vend {
                let mut s = 0.0;
                for k in 0..nnz {
                    let idx = base + k * h + lane;
                    s += val[idx] * xps[t][col[idx] as usize];
                }
                outs[t][o] = s;
            }
            v0 = vend;
        }
    }

    /// `Σ a[i]·b[ind[i]]` as 4 gather-FMA partial sums + scalar tail —
    /// the measured kernel behind the gather-bandwidth microbenchmark.
    ///
    /// SAFETY: caller must ensure AVX2+FMA support (dispatch contract),
    /// `a.len() == ind.len()`, and every `ind` entry `< b.len()`.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn gather_scp(a: &[f64], b: &[f64], ind: &[u32]) -> f64 {
        let n = a.len();
        let n4 = n & !3;
        let mut acc = _mm256_setzero_pd();
        let mut j = 0;
        while j < n4 {
            // SAFETY: j + 3 < n4 <= a.len() == ind.len(); every ind
            // entry is < b.len() (validated by the safe wrapper).
            let v = _mm256_loadu_pd(a.as_ptr().add(j));
            let ci = _mm_loadu_si128(ind.as_ptr().add(j) as *const __m128i);
            let xv = _mm256_i32gather_pd::<8>(b.as_ptr(), ci);
            acc = _mm256_fmadd_pd(v, xv, acc);
            j += 4;
        }
        let mut s = hsum4(acc);
        while j < n {
            s += a[j] * b[ind[j] as usize];
            j += 1;
        }
        s
    }

    /// Streaming triad `a[i] = b[i] + scale * c[i]`, 4 lanes per FMA.
    ///
    /// SAFETY: caller must ensure AVX2+FMA support (dispatch contract)
    /// and `a.len() == b.len() == c.len()`.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn triad_avx2(a: &mut [f64], b: &[f64], c: &[f64], scale: f64) {
        let n = a.len();
        let n4 = n & !3;
        let s = _mm256_set1_pd(scale);
        let mut j = 0;
        while j < n4 {
            // SAFETY: j + 3 < n4 <= a.len() == b.len() == c.len()
            // (asserted by the safe wrapper).
            let bv = _mm256_loadu_pd(b.as_ptr().add(j));
            let cv = _mm256_loadu_pd(c.as_ptr().add(j));
            _mm256_storeu_pd(a.as_mut_ptr().add(j), _mm256_fmadd_pd(s, cv, bv));
            j += 4;
        }
        while j < n {
            a[j] = b[j] + scale * c[j];
            j += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::Coo;
    use crate::util::rng::Rng;

    fn random_crs(rng: &mut Rng, n: usize, nnz: usize) -> Crs {
        let mut coo = Coo::new(n, n);
        for _ in 0..nnz {
            coo.push(rng.index(n), rng.index(n), rng.f64() * 2.0 - 1.0);
        }
        coo.normalize();
        Crs::from_coo(&coo)
    }

    /// Per-row relative comparison: |got - want| ≤ ε · max(1, Σ|aᵢxᵢ|).
    fn assert_rows_close(crs: &Crs, x: &[f64], want: &[f64], got: &[f64], eps: f64, tag: &str) {
        for i in 0..crs.nrows {
            let scale: f64 = crs
                .row(i)
                .0
                .iter()
                .zip(crs.row(i).1)
                .map(|(&c, &v)| (v * x[c as usize]).abs())
                .sum();
            let bound = eps * scale.max(1.0);
            assert!(
                (want[i] - got[i]).abs() <= bound,
                "{tag}: row {i} off by {} (bound {bound})",
                (want[i] - got[i]).abs()
            );
        }
    }

    #[test]
    fn detect_is_cached_and_stable() {
        let a = IsaLevel::detect();
        let b = IsaLevel::detect();
        assert_eq!(a, b);
        assert!(a.lanes() >= 1);
    }

    #[test]
    fn isa_level_orders_and_parses() {
        assert!(IsaLevel::Scalar < IsaLevel::Avx2);
        assert!(IsaLevel::Avx2 < IsaLevel::Avx512);
        for l in [IsaLevel::Scalar, IsaLevel::Avx2, IsaLevel::Avx512] {
            assert_eq!(IsaLevel::parse(l.name()).unwrap(), l);
        }
        assert!(IsaLevel::parse("sse9").is_err());
    }

    #[test]
    fn precision_contract_semantics() {
        assert_eq!(Precision::default(), Precision::BitIdentical);
        assert!(!Precision::BitIdentical.allows_simd());
        assert!(Precision::Tolerance(1e-12).allows_simd());
        assert_eq!(Precision::Tolerance(1e-12).tolerance(), Some(1e-12));
        assert_eq!(Precision::BitIdentical.tolerance(), None);
        assert_eq!(Precision::parse("bit").unwrap(), Precision::BitIdentical);
        assert_eq!(Precision::parse("bit-identical").unwrap(), Precision::BitIdentical);
        assert_eq!(Precision::parse("tol:1e-12").unwrap(), Precision::Tolerance(1e-12));
        assert_eq!(Precision::parse("1e-10").unwrap(), Precision::Tolerance(1e-10));
        assert!(Precision::parse("-1.0").is_err());
        assert!(Precision::parse("wat").is_err());
    }

    #[test]
    fn scalar_level_is_bit_identical_passthrough() {
        let mut rng = Rng::new(50);
        let n = 130;
        let crs = random_crs(&mut rng, n, n * 6);
        let sell = SellCs::from_crs(&crs, 8, 64);
        let mut xp = vec![0.0; n];
        rng.fill_f64(&mut xp, -1.0, 1.0);
        let mut want = vec![0.0; n];
        sell.spmv_rows_permuted(0, n, &xp, &mut want);
        let mut got = vec![0.0; n];
        sell_rows_permuted(IsaLevel::Scalar, &sell, 0, n, &xp, &mut got);
        assert_eq!(want, got, "Scalar level must be the exact scalar loop");
        let mut want = vec![0.0; n];
        crs.spmv_rows_into(0, n, &xp, &mut want);
        let mut got = vec![0.0; n];
        crs_rows_into(IsaLevel::Scalar, &crs, 0, n, &xp, &mut got);
        assert_eq!(want, got);
    }

    /// SIMD SELL and CRS kernels agree with the scalar loops within a
    /// tight relative ε over a C grid and ragged row ranges. Skips
    /// silently on hosts without AVX2 (the only honest option: running
    /// an undetected ISA would be UB).
    #[test]
    fn simd_kernels_match_scalar_within_eps() {
        let host = IsaLevel::detect();
        if host == IsaLevel::Scalar {
            return;
        }
        let mut rng = Rng::new(51);
        let n = 173; // not a multiple of any lane width
        let crs = random_crs(&mut rng, n, n * 7);
        let mut xp = vec![0.0; n];
        rng.fill_f64(&mut xp, -1.0, 1.0);
        for isa in [IsaLevel::Avx2, IsaLevel::Avx512] {
            if isa > host {
                continue;
            }
            let mut want = vec![0.0; n];
            crs.spmv_rows_into(0, n, &xp, &mut want);
            let mut got = vec![0.0; n];
            crs_rows_into(isa, &crs, 0, n, &xp, &mut got);
            assert_rows_close(&crs, &xp, &want, &got, 1e-13, &format!("crs {isa}"));
            for (c, sigma) in [(1, 1), (4, 16), (8, 64), (16, 16), (32, 173), (64, 1000)] {
                let sell = SellCs::from_crs(&crs, c, sigma);
                let mut want = vec![0.0; n];
                sell.spmv_rows_permuted(0, n, &xp, &mut want);
                let mut got = vec![0.0; n];
                sell_rows_permuted(isa, &sell, 0, n, &xp, &mut got);
                let d: f64 = want
                    .iter()
                    .zip(&got)
                    .map(|(a, b)| (a - b).abs())
                    .fold(0.0, f64::max);
                assert!(d <= 1e-12, "sell {c}/{sigma} {isa}: max diff {d}");
                // Ragged piecewise dispatch (partial lane groups at
                // every cut) agrees with the one-shot pass exactly.
                let mut pieced = vec![0.0; n];
                for (a, b) in [(0usize, 3usize), (3, 62), (62, 65), (65, n)] {
                    let (head, _) = pieced.split_at_mut(b);
                    sell_rows_permuted(isa, &sell, a, b, &xp, &mut head[a..]);
                }
                assert_eq!(pieced, got, "sell {c}/{sigma} {isa}: piecewise deviates");
            }
        }
    }

    /// Cancellation probe: rows built from ±1e16 pairs that cancel to
    /// O(1). The SIMD result must stay within ε of the scalar result
    /// *relative to the accumulation magnitude* (~1e16) — the exact
    /// semantics [`Precision::Tolerance`] promises.
    #[test]
    fn cancellation_probe_stays_within_relative_eps() {
        let host = IsaLevel::detect();
        if host == IsaLevel::Scalar {
            return;
        }
        let n = 64;
        let mut coo = Coo::new(n, n);
        let mut rng = Rng::new(52);
        for i in 0..n {
            // A near-cancelling pair plus small entries.
            let big = 1e16 * (1.0 + rng.f64());
            coo.push(i, (i + 1) % n, big);
            coo.push(i, (i + 2) % n, -big);
            for _ in 0..5 {
                coo.push(i, rng.index(n), rng.f64() * 2.0 - 1.0);
            }
        }
        coo.normalize();
        let crs = Crs::from_coo(&coo);
        let mut x = vec![0.0; n];
        rng.fill_f64(&mut x, 0.5, 1.5);
        let mut want = vec![0.0; n];
        crs.spmv_rows_into(0, n, &x, &mut want);
        for isa in [IsaLevel::Avx2, IsaLevel::Avx512] {
            if isa > host {
                continue;
            }
            let mut got = vec![0.0; n];
            crs_rows_into(isa, &crs, 0, n, &x, &mut got);
            assert_rows_close(&crs, &x, &want, &got, 1e-14, &format!("cancel crs {isa}"));
            let sell = SellCs::from_crs(&crs, 8, 32);
            let xp = sell.permute_vec(&x);
            let mut wantp = vec![0.0; n];
            sell.spmv_rows_permuted(0, n, &xp, &mut wantp);
            let mut gotp = vec![0.0; n];
            sell_rows_permuted(isa, &sell, 0, n, &xp, &mut gotp);
            for i in 0..n {
                assert!(
                    (wantp[i] - gotp[i]).abs() <= 1e-14 * 1e17,
                    "cancel sell {isa}: row {i} off by {}",
                    (wantp[i] - gotp[i]).abs()
                );
            }
        }
    }

    /// ISSUE-9 tentpole: the rectangular (shard-half) SELL kernel is
    /// the exact scalar loop at `Scalar`, matches it within ε at every
    /// detected vector level, and its ragged piecewise dispatch (the
    /// engine's chunk boundaries) reproduces the one-shot pass exactly.
    #[test]
    fn sell_rect_simd_matches_scalar_within_eps() {
        let mut rng = Rng::new(54);
        let n = 151; // not a multiple of any lane width
        let crs = random_crs(&mut rng, n, n * 7);
        let rect = SellRect::from_crs(&crs, 8, 32);
        let mut x = vec![0.0; n];
        rng.fill_f64(&mut x, -1.0, 1.0);
        let mut want = vec![0.0; n];
        rect.spmv_rows(0, n, &x, &mut want);
        let mut got = vec![0.0; n];
        sell_rect_rows(IsaLevel::Scalar, &rect, 0, n, &x, &mut got);
        assert_eq!(want, got, "Scalar level must be the exact scalar loop");
        let host = IsaLevel::detect();
        for isa in [IsaLevel::Avx2, IsaLevel::Avx512] {
            if isa > host {
                continue;
            }
            let mut got = vec![0.0; n];
            sell_rect_rows(isa, &rect, 0, n, &x, &mut got);
            let d: f64 = want.iter().zip(&got).map(|(a, b)| (a - b).abs()).fold(0.0, f64::max);
            assert!(d <= 1e-12, "rect {isa}: max diff {d}");
            let mut pieced = vec![0.0; n];
            for (a, b) in [(0usize, 5usize), (5, 77), (77, 80), (80, n)] {
                let (head, _) = pieced.split_at_mut(b);
                sell_rect_rows(isa, &rect, a, b, &x, &mut head[a..]);
            }
            assert_eq!(pieced, got, "rect {isa}: piecewise deviates");
        }
    }

    /// The ±1e16 cancellation probe through the shard-half kernel: the
    /// rect path preserves per-row entry order, so its vector deviation
    /// stays within ε relative to the ~1e16 accumulation magnitude —
    /// the bound the sharded `Tolerance` contract relies on.
    #[test]
    fn sell_rect_cancellation_probe_stays_within_relative_eps() {
        let host = IsaLevel::detect();
        if host == IsaLevel::Scalar {
            return;
        }
        let n = 96;
        let mut coo = Coo::new(n, n);
        let mut rng = Rng::new(55);
        for i in 0..n {
            let big = 1e16 * (1.0 + rng.f64());
            coo.push(i, (i + 1) % n, big);
            coo.push(i, (i + 2) % n, -big);
            for _ in 0..5 {
                coo.push(i, rng.index(n), rng.f64() * 2.0 - 1.0);
            }
        }
        coo.normalize();
        let crs = Crs::from_coo(&coo);
        let rect = SellRect::from_crs(&crs, 8, 32);
        let mut x = vec![0.0; n];
        rng.fill_f64(&mut x, 0.5, 1.5);
        let mut want = vec![0.0; n];
        rect.spmv_rows(0, n, &x, &mut want);
        for isa in [IsaLevel::Avx2, IsaLevel::Avx512] {
            if isa > host {
                continue;
            }
            let mut got = vec![0.0; n];
            sell_rect_rows(isa, &rect, 0, n, &x, &mut got);
            for i in 0..n {
                assert!(
                    (want[i] - got[i]).abs() <= 1e-14 * 1e17,
                    "rect cancel {isa}: slot {i} off by {}",
                    (want[i] - got[i]).abs()
                );
            }
        }
    }

    /// ISSUE-9 tentpole: the fused blocked-x SpMM dispatchers are the
    /// exact fused scalar loops at `Scalar` — and the fused scalar CRS
    /// loop is itself bit-identical per vector to the serial CRS kernel
    /// (same ascending-`j` order), the SpMM half of the BitIdentical
    /// contract.
    #[test]
    fn multi_fused_scalar_is_bit_identical_per_vector() {
        let mut rng = Rng::new(56);
        let n = 120;
        let crs = random_crs(&mut rng, n, n * 6);
        let k = 5;
        let xs: Vec<Vec<f64>> = (0..k)
            .map(|_| {
                let mut x = vec![0.0; n];
                rng.fill_f64(&mut x, -1.0, 1.0);
                x
            })
            .collect();
        let xrefs: Vec<&[f64]> = xs.iter().map(|x| x.as_slice()).collect();
        let mut got = vec![vec![0.0; n]; k];
        {
            let mut outs: Vec<&mut [f64]> = got.iter_mut().map(|y| y.as_mut_slice()).collect();
            crs_rows_multi(IsaLevel::Scalar, &crs, 0, n, &xrefs, &mut outs);
        }
        for (x, y) in xs.iter().zip(&got) {
            let mut want = vec![0.0; n];
            crs.spmv_rows_into(0, n, x, &mut want);
            assert_eq!(&want, y, "fused scalar CRS must equal serial CRS per vector");
        }
    }

    /// ISSUE-9 tentpole: the fused SpMM vector bodies equal the fused
    /// scalar loops within ε, for block sizes across the lane and
    /// re-stream boundaries (k % 4 remainders, and k > 4·MULTI_GROUPS
    /// forcing a second pass), for both CRS and SELL.
    #[test]
    fn multi_simd_matches_scalar_fused_within_eps() {
        let host = IsaLevel::detect();
        if host == IsaLevel::Scalar {
            return;
        }
        let mut rng = Rng::new(58);
        let n = 149;
        let crs = random_crs(&mut rng, n, n * 6);
        let sell = SellCs::from_crs(&crs, 8, 64);
        for k in [1usize, 2, 3, 4, 7, 8, 32, 37] {
            let xs: Vec<Vec<f64>> = (0..k)
                .map(|_| {
                    let mut x = vec![0.0; n];
                    rng.fill_f64(&mut x, -1.0, 1.0);
                    x
                })
                .collect();
            let xrefs: Vec<&[f64]> = xs.iter().map(|x| x.as_slice()).collect();
            for isa in [IsaLevel::Avx2, IsaLevel::Avx512] {
                if isa > host {
                    continue;
                }
                let mut want = vec![vec![0.0; n]; k];
                let mut got = vec![vec![0.0; n]; k];
                {
                    let mut outs: Vec<&mut [f64]> =
                        want.iter_mut().map(|y| y.as_mut_slice()).collect();
                    crs_rows_multi(IsaLevel::Scalar, &crs, 0, n, &xrefs, &mut outs);
                }
                {
                    let mut outs: Vec<&mut [f64]> =
                        got.iter_mut().map(|y| y.as_mut_slice()).collect();
                    crs_rows_multi(isa, &crs, 0, n, &xrefs, &mut outs);
                }
                for t in 0..k {
                    assert_rows_close(
                        &crs,
                        &xs[t],
                        &want[t],
                        &got[t],
                        1e-13,
                        &format!("multi crs {isa} k={k} v={t}"),
                    );
                }
                {
                    let mut outs: Vec<&mut [f64]> =
                        want.iter_mut().map(|y| y.as_mut_slice()).collect();
                    sell_rows_multi(IsaLevel::Scalar, &sell, 0, n, &xrefs, &mut outs);
                }
                {
                    let mut outs: Vec<&mut [f64]> =
                        got.iter_mut().map(|y| y.as_mut_slice()).collect();
                    sell_rows_multi(isa, &sell, 0, n, &xrefs, &mut outs);
                }
                for t in 0..k {
                    let d: f64 = want[t]
                        .iter()
                        .zip(&got[t])
                        .map(|(a, b)| (a - b).abs())
                        .fold(0.0, f64::max);
                    assert!(d <= 1e-12, "multi sell {isa} k={k} v={t}: max diff {d}");
                }
            }
        }
    }

    #[test]
    fn triad_matches_scalar_reference() {
        let host = IsaLevel::detect();
        let n = 1027;
        let mut rng = Rng::new(53);
        let mut b = vec![0.0; n];
        let mut c = vec![0.0; n];
        rng.fill_f64(&mut b, -1.0, 1.0);
        rng.fill_f64(&mut c, -1.0, 1.0);
        let mut want = vec![0.0; n];
        triad(IsaLevel::Scalar, &mut want, &b, &c, 3.25);
        for i in 0..n {
            assert_eq!(want[i], b[i] + 3.25 * c[i]);
        }
        if host > IsaLevel::Scalar {
            let mut got = vec![0.0; n];
            triad(host, &mut got, &b, &c, 3.25);
            for i in 0..n {
                // FMA may round differently from mul+add; stay relative.
                assert!((want[i] - got[i]).abs() <= 1e-15 * want[i].abs().max(1.0));
            }
        }
    }
}
