//! Sharded SpMV execution: in-process distributed domains with halo
//! exchange and compute/exchange overlap (arXiv:1106.5908,
//! arXiv:1101.0091).
//!
//! [`ShardedSpmv`] turns one process into a small cluster: the matrix
//! is row-partitioned into shards ([`crate::matrix::shard::ShardedCrs`])
//! and every shard gets its own engine thread pool, plans for its two
//! halves, and buffers — optionally pinned to a disjoint core range and
//! first-touched by its own workers, so each shard behaves like a NUMA
//! domain of a real distributed run. Execution offers the two modes the
//! papers compare:
//!
//! - [`OverlapMode::BulkSync`] (*vector mode*): gather the full halo,
//!   then run both halves back to back;
//! - [`OverlapMode::Overlapped`] (*task mode*): a **persistent** exchange
//!   role per shard copies the halo segments while the shard's engine
//!   computes the interior rows, and the boundary rows run once the
//!   [`HaloGate`] opens ([`crate::engine::TwoPhasePlan`]).
//!
//! Coordinator and exchange roles live in a [`TaskPool`] spawned once at
//! construction and parked between calls — the hot path wakes them
//! through channels and **spawns no thread per call** (PR 4's recorded
//! follow-up, retired). [`ShardedSpmv::coordinator_spawns`] exposes the
//! lifetime spawn count so tests can assert exactly that.
//!
//! Both modes drive identical kernels in identical per-row order, so
//! sharded output is **bit-identical to the serial CRS kernel** for
//! every shard count × scheme × schedule × overlap mode × pinning
//! choice — asserted exhaustively in the tests below.
//!
//! The transport is abstracted behind [`HaloExchange`]; the in-process
//! [`SharedVecExchange`] simply copies out of the shared input vector,
//! one segment per source shard (exactly the per-neighbour messages a
//! real transport would post). Swapping in an inter-process transport
//! is the recorded ROADMAP follow-up.

use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::engine::affinity::{self, PinMode};
use crate::engine::{first_touch_buffers, Engine, HaloGate, SpmvPlan, TaskPool, TwoPhasePlan};
use crate::kernels::{IsaLevel, ShardKernel};
use crate::matrix::shard::{ShardCrs, ShardedCrs};
use crate::matrix::{Crs, Scheme, SpMv};
use crate::sched::Schedule;

/// How a sharded SpMV schedules the halo exchange against compute.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OverlapMode {
    /// Vector mode: exchange the full halo, then compute both halves.
    BulkSync,
    /// Task mode: exchange concurrently with the interior compute;
    /// boundary rows wait on the halo-ready gate.
    Overlapped,
}

impl OverlapMode {
    pub fn name(&self) -> &'static str {
        match self {
            OverlapMode::BulkSync => "bulk-sync",
            OverlapMode::Overlapped => "overlapped",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        match s.trim().to_ascii_lowercase().as_str() {
            "bulk" | "bulk-sync" | "bulksync" | "vector" => Ok(OverlapMode::BulkSync),
            "overlap" | "overlapped" | "task" => Ok(OverlapMode::Overlapped),
            other => anyhow::bail!("unknown overlap mode '{other}' (bulk-sync|overlapped)"),
        }
    }
}

/// The halo transport seam: fill a shard's halo buffer (one slot per
/// [`ShardCrs::halo_cols`] entry) from wherever the neighbours' vector
/// slices live. Implementations must walk the per-source
/// [`ShardCrs::halo_segments`] — that is the message structure a real
/// transport preserves.
pub trait HaloExchange: Sync {
    fn exchange(&self, shard: &ShardCrs, halo: &mut [f64]);
}

/// In-process transport: every shard reads the shared input vector
/// directly, one contiguous-run copy per source shard.
pub struct SharedVecExchange<'a>(pub &'a [f64]);

impl HaloExchange for SharedVecExchange<'_> {
    fn exchange(&self, shard: &ShardCrs, halo: &mut [f64]) {
        debug_assert_eq!(halo.len(), shard.halo_len());
        for &(_src, a, b) in &shard.halo_segments {
            for j in a..b {
                halo[j] = self.0[shard.halo_cols[j] as usize];
            }
        }
    }
}

/// Per-shard execution state: the split kernels, one plan per half, the
/// shard's own engine, and its (optionally first-touched) buffers.
struct ShardUnit {
    kernel: ShardKernel,
    local_plan: SpmvPlan,
    remote_plan: SpmvPlan,
    engine: Engine,
    bufs: Mutex<ShardBufs>,
}

struct ShardBufs {
    /// `[owned | halo]` gather buffer the remote half multiplies.
    concat: Vec<f64>,
    /// Output slots of the local (interior-rows) half.
    local_out: Vec<f64>,
    /// Output slots of the remote (boundary-rows) half.
    remote_out: Vec<f64>,
    /// Were these buffers first-touched by their owning shard threads?
    first_touched: bool,
}

/// Shard-parallel SpMV executor; see the module docs. Build via
/// [`ShardedSpmv::new`] or, tuned, via the facade
/// ([`crate::spmv::SpmvBuilder`] with a sharded backend).
/// Crate-internal since the facade PR: consumers hold an
/// [`crate::spmv::SpmvHandle`], never this type.
pub(crate) struct ShardedSpmv {
    crs: Arc<Crs>,
    scheme: Scheme,
    schedule: Schedule,
    mode: OverlapMode,
    threads_per_shard: usize,
    pinned: bool,
    storage: ShardedCrs,
    units: Vec<ShardUnit>,
    /// ISA every shard's split kernels execute at. Defaults to scalar
    /// ([`crate::kernels::Precision::BitIdentical`]'s only admissible
    /// level); the tuner binds a vector level under `Tolerance` via
    /// [`ShardedSpmv::set_kernel_isa`]. A kernel property, not a
    /// partition property: [`ShardedSpmv::rebalance`] and
    /// [`ShardedSpmv::reshard`] both preserve it (every supported
    /// scheme's halves have the same vector paths at any shard count).
    kernel_isa: IsaLevel,
    /// Persistent coordinator + exchange role threads, spawned once and
    /// parked between calls (PR 4's spawn-per-call follow-up, retired):
    /// slot `s` coordinates shard `s`, slot `n_shards + s` is shard
    /// `s`'s exchange role (dispatched only in overlapped mode).
    pool: TaskPool,
}

/// Raw output pointer shared across shard coordinators: every global
/// row has exactly one writing shard (row partition) and one writing
/// phase (interior XOR boundary), so the scatters never alias.
#[derive(Clone, Copy)]
struct SharedOut(*mut f64);
// SAFETY: every global row has exactly one writing shard and one
// writing phase (doc above), so concurrent scatters never alias; the
// dispatcher keeps the output borrow alive for the whole call.
unsafe impl Send for SharedOut {}
// SAFETY: shared access is address arithmetic; writes land on the
// disjoint per-shard rows described above.
unsafe impl Sync for SharedOut {}

/// Raw gather-buffer pointer handed to the exchange thread: the gate
/// orders its writes before every remote-phase read, and no Rust
/// reference to the buffer is alive while it is being written.
#[derive(Clone, Copy)]
struct SharedBuf(*mut f64);
// SAFETY: the HaloGate orders the exchange thread's writes before
// every remote-phase read (doc above), and no Rust reference to the
// buffer is alive while it is being written.
unsafe impl Send for SharedBuf {}
// SAFETY: cross-thread use is write-then-gate-then-read; the gate's
// mutex hand-off makes the writes happen-before the reads.
unsafe impl Sync for SharedBuf {}

/// Raw views of one shard's buffers, captured while the caller holds the
/// shard's buffer lock, so the persistent coordinator and exchange roles
/// can reach them without taking the mutex themselves (the lock lives on
/// the dispatching thread for the whole call; see [`ShardedSpmv::run_calls`]).
#[derive(Clone, Copy)]
struct ShardPtrs {
    concat: SharedBuf,
    concat_len: usize,
    local: SharedBuf,
    local_len: usize,
    remote: SharedBuf,
    remote_len: usize,
}

impl ShardedSpmv {
    /// Shard `crs` and bundle per-shard kernels/plans/engines. With
    /// `pinned`, shard `s`'s engine is pinned to the core range
    /// starting at `s × threads_per_shard` and its buffers are
    /// first-touched by their owning workers.
    pub fn new(
        crs: Arc<Crs>,
        scheme: Scheme,
        schedule: Schedule,
        n_shards: usize,
        threads_per_shard: usize,
        mode: OverlapMode,
        pinned: bool,
    ) -> Result<Self> {
        anyhow::ensure!(
            crs.nrows == crs.ncols,
            "sharded SpMV requires a square matrix, got {}x{}",
            crs.nrows,
            crs.ncols
        );
        let threads_per_shard = threads_per_shard.max(1);
        let storage = ShardedCrs::from_crs(&crs, n_shards);
        let units = Self::build_units(&storage, scheme, schedule, threads_per_shard, pinned)?;
        let pool = Self::build_pool(units.len(), threads_per_shard, pinned);
        Ok(ShardedSpmv {
            crs,
            scheme,
            schedule,
            mode,
            threads_per_shard,
            pinned,
            storage,
            units,
            kernel_isa: IsaLevel::Scalar,
            pool,
        })
    }

    /// The persistent role pool: `2 × n_shards` slots so a mode flip to
    /// overlapped never needs a rebuild; under pinning both of shard
    /// `s`'s roles land on the shard's base core — exactly where the
    /// retired ephemeral coordinators used to pin themselves per call
    /// (the nested exchange thread inherited that mask).
    fn build_pool(n_shards: usize, threads_per_shard: usize, pinned: bool) -> TaskPool {
        let n_cpus = affinity::n_cpus();
        TaskPool::with_pin(2 * n_shards.max(1), move |i| {
            pinned.then(|| affinity::cpu_for((i % n_shards.max(1)) * threads_per_shard, n_cpus))
        })
    }

    /// Build every shard's unit on its own setup thread: first-touch
    /// passes run in parallel, and each pinned engine's caller-pin
    /// applies to the short-lived setup thread instead of confining the
    /// builder (coordinators re-pin themselves per call).
    fn build_units(
        storage: &ShardedCrs,
        scheme: Scheme,
        schedule: Schedule,
        threads: usize,
        pinned: bool,
    ) -> Result<Vec<ShardUnit>> {
        // audit:allow(thread_spawn): one-shot setup fan-out so first-touch runs in parallel
        std::thread::scope(|scope| {
            let handles: Vec<_> = storage
                .shards
                .iter()
                .enumerate()
                .map(|(s, shard)| {
                    scope.spawn(move || -> Result<ShardUnit> {
                        let engine = if pinned {
                            Engine::with_pinning_offset(threads, PinMode::Compact, s * threads)
                        } else {
                            Engine::new(threads)
                        };
                        let kernel = ShardKernel::build(shard, scheme)?;
                        let local_plan = SpmvPlan::for_weights(
                            scheme,
                            schedule,
                            threads,
                            kernel.local.row_weights(),
                        );
                        let remote_plan = SpmvPlan::for_weights(
                            scheme,
                            schedule,
                            threads,
                            kernel.remote.row_weights(),
                        );
                        let bufs =
                            Self::make_bufs(shard, &engine, &local_plan, &remote_plan, pinned);
                        Ok(ShardUnit {
                            kernel,
                            local_plan,
                            remote_plan,
                            engine,
                            bufs: Mutex::new(bufs),
                        })
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("shard setup thread panicked"))
                .collect()
        })
    }

    /// Allocate (and, when pinned, first-touch under the exact phase
    /// assignments) a shard's buffers. The halo gather buffer has no
    /// per-row owner, so it is homed by an even split across the
    /// shard's threads — all on the shard's domain either way.
    fn make_bufs(
        shard: &ShardCrs,
        engine: &Engine,
        local_plan: &SpmvPlan,
        remote_plan: &SpmvPlan,
        pinned: bool,
    ) -> ShardBufs {
        if !pinned {
            return ShardBufs {
                concat: vec![0.0; shard.concat_len()],
                local_out: vec![0.0; local_plan.nrows],
                remote_out: vec![0.0; remote_plan.nrows],
                first_touched: false,
            };
        }
        let local_out = first_touch_buffers(engine, local_plan.partitions(), local_plan.nrows, 1)
            .pop()
            .expect("one buffer requested");
        let remote_out =
            first_touch_buffers(engine, remote_plan.partitions(), remote_plan.nrows, 1)
                .pop()
                .expect("one buffer requested");
        let even = even_ranges(engine.n_threads(), shard.concat_len());
        let concat = first_touch_buffers(engine, &even, shard.concat_len(), 1)
            .pop()
            .expect("one buffer requested");
        ShardBufs { concat, local_out, remote_out, first_touched: true }
    }

    pub fn n_shards(&self) -> usize {
        self.units.len()
    }

    pub fn mode(&self) -> OverlapMode {
        self.mode
    }

    pub fn scheme(&self) -> Scheme {
        self.scheme
    }

    pub fn schedule(&self) -> Schedule {
        self.schedule
    }

    pub fn threads_per_shard(&self) -> usize {
        self.threads_per_shard
    }

    pub fn pinned(&self) -> bool {
        self.pinned
    }

    /// ISA the split kernels execute at (see the field docs).
    pub fn kernel_isa(&self) -> IsaLevel {
        self.kernel_isa
    }

    /// Bind the split kernels' ISA. The caller (the tuner) owns the
    /// precision contract: scalar keeps every path bit-identical to
    /// serial CRS, vector levels reorder each row's FMA reduction
    /// within the `Tolerance(ε)` bound (per-row entry order is
    /// preserved by both halves, including the remote half's gathers
    /// from the `[owned | halo]` concat space).
    pub fn set_kernel_isa(&mut self, isa: IsaLevel) {
        self.kernel_isa = isa;
    }

    /// The sharded storage (halo maps, fractions) backing this executor.
    pub fn storage(&self) -> &ShardedCrs {
        &self.storage
    }

    pub fn halo_fraction(&self) -> f64 {
        self.storage.halo_fraction()
    }

    pub fn boundary_nnz_fraction(&self) -> f64 {
        self.storage.boundary_nnz_fraction()
    }

    /// Were every shard's buffers first-touched by their owners?
    pub fn first_touched(&self) -> bool {
        self.units.iter().all(|u| u.bufs.lock().unwrap().first_touched)
    }

    /// Realized placement across all shards: the per-thread pin
    /// statuses of every shard engine concatenated in shard-major order
    /// (shard 0 threads first). Feeds `TuningReport.placement`.
    pub fn aggregate_pin_report(&self) -> affinity::PinReport {
        let mode = if self.pinned { PinMode::Compact } else { PinMode::Disabled };
        let per_thread = self
            .units
            .iter()
            .flat_map(|u| u.engine.pin_report().per_thread.iter().copied())
            .collect();
        affinity::PinReport { mode, per_thread }
    }

    /// Re-partition every shard's plans for a new schedule **and
    /// re-home its buffers** under the new assignments — the §5.2
    /// hazard ([`SpmvPlan::rebalance`]) extended to the sharded
    /// executor: after a schedule change, boundary and interior slots
    /// would otherwise keep being served from pages homed for the old
    /// owners.
    pub fn rebalance(&mut self, schedule: Schedule) {
        self.schedule = schedule;
        for (unit, shard) in self.units.iter_mut().zip(&self.storage.shards) {
            unit.local_plan = SpmvPlan::for_weights(
                self.scheme,
                schedule,
                self.threads_per_shard,
                unit.kernel.local.row_weights(),
            );
            unit.remote_plan = SpmvPlan::for_weights(
                self.scheme,
                schedule,
                self.threads_per_shard,
                unit.kernel.remote.row_weights(),
            );
            let bufs = Self::make_bufs(
                shard,
                &unit.engine,
                &unit.local_plan,
                &unit.remote_plan,
                self.pinned,
            );
            unit.bufs = Mutex::new(bufs);
        }
    }

    /// Re-shard onto a new shard count (and overlap mode): partition,
    /// halo maps, kernels, plans, engines and buffers are all rebuilt,
    /// so halo buffers are re-homed on the new owners' domains and
    /// pinned engines move to the new core ranges. Bit-identity is
    /// preserved across any re-shard (tested below).
    pub fn reshard(&mut self, n_shards: usize, mode: OverlapMode) -> Result<()> {
        let storage = ShardedCrs::from_crs(&self.crs, n_shards);
        let units = Self::build_units(
            &storage,
            self.scheme,
            self.schedule,
            self.threads_per_shard,
            self.pinned,
        )?;
        if units.len() != self.units.len() {
            // Role threads are per-shard; only a shard-count change
            // needs a new pool (mode flips reuse the parked slots).
            self.pool = Self::build_pool(units.len(), self.threads_per_shard, self.pinned);
        }
        self.storage = storage;
        self.units = units;
        self.mode = mode;
        Ok(())
    }

    /// Threads ever spawned for coordination (coordinator + exchange
    /// roles). Fixed at construction/reshard — the no-spawn-on-hot-path
    /// regression test snapshots it around repeated `spmv` calls.
    pub fn coordinator_spawns(&self) -> usize {
        self.pool.spawned()
    }

    /// Distributed-style SpMV: every shard runs concurrently on its own
    /// persistent coordinator + engine; see the module docs for the two
    /// modes. **No thread is spawned here** — the roles were spawned at
    /// construction and are parked between calls.
    pub fn spmv(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.storage.nrows);
        assert_eq!(y.len(), self.storage.nrows);
        self.run_calls(&[x], &[SharedOut(y.as_mut_ptr())]);
    }

    /// Batched sharded SpMV in **one** dispatch: the parked coordinators
    /// wake once per batch and stream every vector through their
    /// engines, so the per-call wakeup cost is paid per batch — the
    /// sharded counterpart of [`crate::engine::Engine::run_chunks_batch`].
    pub fn spmv_batch(&self, xs: &[Vec<f64>]) -> Vec<Vec<f64>> {
        let n = self.storage.nrows;
        for x in xs {
            assert_eq!(x.len(), n);
        }
        let mut ys: Vec<Vec<f64>> = xs.iter().map(|_| vec![0.0; n]).collect();
        if xs.is_empty() {
            return ys;
        }
        let xrefs: Vec<&[f64]> = xs.iter().map(|x| x.as_slice()).collect();
        let ybases: Vec<SharedOut> = ys.iter_mut().map(|y| SharedOut(y.as_mut_ptr())).collect();
        self.run_calls(&xrefs, &ybases);
        ys
    }

    /// The one dispatch path under `spmv` and `spmv_batch`: wake the
    /// parked roles, stream every vector through every shard, return
    /// when all shards scattered all vectors.
    fn run_calls(&self, xs: &[&[f64]], ybases: &[SharedOut]) {
        debug_assert_eq!(xs.len(), ybases.len());
        if xs.is_empty() {
            return;
        }
        let n = self.units.len();
        let b = xs.len();
        // Hold every shard's buffer lock for the whole dispatch: this
        // serializes concurrent `&self` callers (what the per-call
        // coordinator locks used to do) and keeps the buffer storage
        // addresses stable while the roles reach them through the raw
        // views below.
        let mut guards: Vec<std::sync::MutexGuard<'_, ShardBufs>> =
            self.units.iter().map(|u| u.bufs.lock().unwrap()).collect();
        let ptrs: Vec<ShardPtrs> = guards
            .iter_mut()
            .map(|g| ShardPtrs {
                concat: SharedBuf(g.concat.as_mut_ptr()),
                concat_len: g.concat.len(),
                local: SharedBuf(g.local_out.as_mut_ptr()),
                local_len: g.local_out.len(),
                remote: SharedBuf(g.remote_out.as_mut_ptr()),
                remote_len: g.remote_out.len(),
            })
            .collect();
        // One exchange→compute gate per (shard, vector); in overlapped
        // mode also one compute→exchange gate per (shard, vector) so the
        // parked exchange role never refills a gather buffer the remote
        // phase is still reading.
        let ready: Vec<HaloGate> = (0..n * b).map(|_| HaloGate::new()).collect();
        let free: Vec<HaloGate> = (0..n * b).map(|_| HaloGate::new()).collect();
        let slots = match self.mode {
            OverlapMode::BulkSync => n,
            OverlapMode::Overlapped => 2 * n,
        };
        self.pool.run(slots, |i| {
            let s = i % n;
            let (ready, free) = (&ready[s * b..(s + 1) * b], &free[s * b..(s + 1) * b]);
            if i < n {
                self.coordinate(s, xs, ybases, &ptrs[s], ready, free);
            } else {
                self.exchange_role(s, xs, &ptrs[s], ready, free);
            }
        });
        drop(guards);
    }

    /// The coordinator role for shard `s`: per vector, (bulk-sync only)
    /// gather, then two-phase compute + scatter into the global output.
    fn coordinate(
        &self,
        s: usize,
        xs: &[&[f64]],
        ybases: &[SharedOut],
        ptrs: &ShardPtrs,
        ready: &[HaloGate],
        free: &[HaloGate],
    ) {
        let unit = &self.units[s];
        let shard = &self.storage.shards[s];
        let kernel = &unit.kernel;
        let isa = self.kernel_isa;
        let w = shard.width();
        let two = TwoPhasePlan { local: &unit.local_plan, remote: &unit.remote_plan };
        for (bi, x) in xs.iter().enumerate() {
            let x_local = &x[shard.row_begin..shard.row_end];
            // SAFETY: the dispatching thread holds this shard's buffer
            // lock for the whole call and only this coordinator role
            // touches the output halves, so these views are exclusive.
            let local_out =
                unsafe { std::slice::from_raw_parts_mut(ptrs.local.0, ptrs.local_len) };
            let remote_out =
                unsafe { std::slice::from_raw_parts_mut(ptrs.remote.0, ptrs.remote_len) };
            match self.mode {
                OverlapMode::BulkSync => {
                    // Vector mode: full gather inline, then both phases.
                    // SAFETY: no exchange role is dispatched in
                    // bulk-sync — this coordinator is the gather
                    // buffer's only user.
                    let concat = unsafe {
                        std::slice::from_raw_parts_mut(ptrs.concat.0, ptrs.concat_len)
                    };
                    concat[..w].copy_from_slice(x_local);
                    SharedVecExchange(x).exchange(shard, &mut concat[w..]);
                    ready[bi].signal();
                    let concat_ref: &[f64] = concat;
                    two.execute(
                        &unit.engine,
                        &ready[bi],
                        local_out,
                        remote_out,
                        |a, b, out| kernel.local.spmv_rows_isa(isa, a, b, x_local, out),
                        |a, b, out| kernel.remote.spmv_rows_isa(isa, a, b, concat_ref, out),
                    );
                }
                OverlapMode::Overlapped => {
                    // Task mode: the exchange role fills the gather
                    // buffer while the engine computes interior rows;
                    // boundary rows wait on the ready gate.
                    let (cptr, clen) = (ptrs.concat, ptrs.concat_len);
                    two.execute(
                        &unit.engine,
                        &ready[bi],
                        local_out,
                        remote_out,
                        |a, b, out| kernel.local.spmv_rows_isa(isa, a, b, x_local, out),
                        move |a, b, out| {
                            // SAFETY: runs strictly after `ready[bi]`
                            // opened (TwoPhasePlan waits before
                            // dispatching), so the exchange role's
                            // writes are complete and ordered before
                            // this read.
                            let cbuf = unsafe { std::slice::from_raw_parts(cptr.0, clen) };
                            kernel.remote.spmv_rows_isa(isa, a, b, cbuf, out)
                        },
                    );
                    // The remote phase is done with the gather buffer:
                    // let the exchange role refill it for the next
                    // vector while this one is scattered.
                    free[bi].signal();
                }
            }
            // Scatter both halves' slots to their global rows. SAFETY:
            // each global row has exactly one writer (row partition
            // across shards, interior XOR boundary within the shard).
            let ybase = ybases[bi];
            for (slot, &v) in local_out.iter().enumerate() {
                let row = shard.interior_rows[kernel.local.storage_row(slot)] as usize;
                unsafe { *ybase.0.add(row) = v };
            }
            for (slot, &v) in remote_out.iter().enumerate() {
                let row = shard.boundary_rows[kernel.remote.storage_row(slot)] as usize;
                // SAFETY: single writer per global row, as above.
                unsafe { *ybase.0.add(row) = v };
            }
        }
    }

    /// The exchange role for shard `s` (overlapped mode only): fill the
    /// `[owned | halo]` gather buffer for each vector concurrently with
    /// the coordinator's interior compute, pipelined one vector ahead at
    /// most (the `free` gates hold it back until the previous remote
    /// phase finished reading).
    fn exchange_role(
        &self,
        s: usize,
        xs: &[&[f64]],
        ptrs: &ShardPtrs,
        ready: &[HaloGate],
        free: &[HaloGate],
    ) {
        let shard = &self.storage.shards[s];
        let w = shard.width();
        for (bi, x) in xs.iter().enumerate() {
            if bi > 0 {
                free[bi - 1].wait();
            }
            // SAFETY: before `ready[bi]` opens the compute side never
            // touches the gather buffer, and the `free[bi-1]` wait
            // above orders this fill after every read of the previous
            // one; both gates' mutex hand-offs order the accesses.
            let cbuf = unsafe { std::slice::from_raw_parts_mut(ptrs.concat.0, ptrs.concat_len) };
            cbuf[..w].copy_from_slice(&x[shard.row_begin..shard.row_end]);
            SharedVecExchange(x).exchange(shard, &mut cbuf[w..]);
            ready[bi].signal();
        }
    }
}

/// Even contiguous per-thread split of `[0, n)` — the ownerless-buffer
/// first-touch partition.
fn even_ranges(threads: usize, n: usize) -> Vec<Vec<(usize, usize)>> {
    let per = n.div_ceil(threads.max(1));
    (0..threads)
        .map(|t| {
            let a = (t * per).min(n);
            let b = ((t + 1) * per).min(n);
            if a < b {
                vec![(a, b)]
            } else {
                Vec::new()
            }
        })
        .collect()
}

impl SpMv for ShardedSpmv {
    fn nrows(&self) -> usize {
        self.storage.nrows
    }
    fn ncols(&self) -> usize {
        self.storage.ncols
    }
    fn nnz(&self) -> usize {
        SpMv::nnz(&self.storage)
    }
    fn spmv(&self, x: &[f64], y: &mut [f64]) {
        ShardedSpmv::spmv(self, x, y);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen;
    use crate::util::rng::Rng;
    use crate::util::stats::max_abs_diff;

    fn hh_crs() -> Crs {
        Crs::from_coo(&gen::holstein_hubbard(&gen::HolsteinHubbardParams::tiny()))
    }

    fn modes() -> [OverlapMode; 2] {
        [OverlapMode::BulkSync, OverlapMode::Overlapped]
    }

    /// The ISSUE-4 acceptance grid: every shard count ∈ {1, 2, 4, 8} ×
    /// {CRS, SELL-C-σ} × {bulk-sync, overlapped} × pinning on/off is
    /// bit-identical to the serial CRS kernel (non-Linux pinning is a
    /// recorded no-op on the same code path).
    #[test]
    fn sharded_spmv_bit_identical_to_serial_crs_exhaustive() {
        let crs = Arc::new(hh_crs());
        let n = crs.nrows;
        let mut rng = Rng::new(110);
        let mut x = vec![0.0; n];
        rng.fill_f64(&mut x, -1.0, 1.0);
        let mut want = vec![0.0; n];
        crs.spmv(&x, &mut want);
        for n_shards in [1usize, 2, 4, 8] {
            for scheme in [Scheme::Crs, Scheme::SellCs { c: 8, sigma: 32 }] {
                for pinned in [false, true] {
                    for mode in modes() {
                        let sh = ShardedSpmv::new(
                            crs.clone(),
                            scheme,
                            Schedule::Static { chunk: None },
                            n_shards,
                            2,
                            mode,
                            pinned,
                        )
                        .unwrap();
                        assert_eq!(sh.first_touched(), pinned);
                        let mut got = vec![0.0; n];
                        sh.spmv(&x, &mut got);
                        assert_eq!(
                            max_abs_diff(&want, &got),
                            0.0,
                            "{n_shards} shards × {scheme} × {} × pin={pinned} deviates",
                            mode.name()
                        );
                    }
                }
            }
        }
    }

    /// Schedules partition rows only — every schedule × mode stays
    /// bit-identical too.
    #[test]
    fn sharded_spmv_bit_identical_across_schedules() {
        let mut rng = Rng::new(111);
        let mut coo = crate::matrix::Coo::new(260, 260);
        for _ in 0..260 * 7 {
            coo.push(rng.index(260), rng.index(260), rng.f64() * 2.0 - 1.0);
        }
        coo.normalize();
        let crs = Arc::new(Crs::from_coo(&coo));
        let mut x = vec![0.0; 260];
        rng.fill_f64(&mut x, -1.0, 1.0);
        let mut want = vec![0.0; 260];
        crs.spmv(&x, &mut want);
        for schedule in [
            Schedule::Static { chunk: None },
            Schedule::Static { chunk: Some(7) },
            Schedule::Dynamic { chunk: 13 },
            Schedule::Guided { min_chunk: 4 },
        ] {
            for mode in modes() {
                let sh = ShardedSpmv::new(
                    crs.clone(),
                    Scheme::SellCs { c: 4, sigma: 16 },
                    schedule,
                    4,
                    3,
                    mode,
                    false,
                )
                .unwrap();
                let mut got = vec![0.0; 260];
                sh.spmv(&x, &mut got);
                assert_eq!(
                    max_abs_diff(&want, &got),
                    0.0,
                    "{} × {} deviates",
                    schedule.name(),
                    mode.name()
                );
            }
        }
    }

    /// ISSUE-9 tentpole: with a vector ISA bound, every shard count ×
    /// scheme × schedule × overlap mode stays within the
    /// `Tolerance(ε)` bound of serial CRS — probed with ±1e16
    /// cancelling rows so a kernel that broke per-row entry order (or
    /// the remote half's `[owned | halo]` concat-space gather) would
    /// blow past the bound instead of landing near it. The default
    /// scalar binding stays exactly bit-identical (the exhaustive
    /// grids above).
    #[test]
    fn vector_isa_stays_within_tolerance_across_grid() {
        let host = IsaLevel::detect();
        if host == IsaLevel::Scalar {
            return;
        }
        let n = 200;
        let mut coo = crate::matrix::Coo::new(n, n);
        let mut rng = Rng::new(115);
        for i in 0..n {
            // A near-cancelling pair plus small entries per row.
            let big = 1e16 * (1.0 + rng.f64());
            coo.push(i, (i + 1) % n, big);
            coo.push(i, (i + 2) % n, -big);
            for _ in 0..6 {
                coo.push(i, rng.index(n), rng.f64() * 2.0 - 1.0);
            }
        }
        coo.normalize();
        let crs = Arc::new(Crs::from_coo(&coo));
        let mut x = vec![0.0; n];
        rng.fill_f64(&mut x, 0.5, 1.5);
        let mut want = vec![0.0; n];
        crs.spmv(&x, &mut want);
        // Accumulations reach ~1e16, so ε relative to the accumulation
        // magnitude means ~ε × 1e17 absolute.
        let bound = 1e-14 * 1e17;
        for n_shards in [1usize, 2, 4] {
            for scheme in [Scheme::Crs, Scheme::SellCs { c: 8, sigma: 32 }] {
                for schedule in
                    [Schedule::Static { chunk: None }, Schedule::Dynamic { chunk: 13 }]
                {
                    for mode in modes() {
                        let mut sh = ShardedSpmv::new(
                            crs.clone(),
                            scheme,
                            schedule,
                            n_shards,
                            2,
                            mode,
                            false,
                        )
                        .unwrap();
                        sh.set_kernel_isa(host);
                        let mut got = vec![0.0; n];
                        sh.spmv(&x, &mut got);
                        let diff = max_abs_diff(&want, &got);
                        assert!(
                            diff <= bound,
                            "{n_shards} shards × {scheme} × {} × {}: off by {diff}",
                            schedule.name(),
                            mode.name()
                        );
                    }
                }
            }
        }
    }

    /// The ISA binding is a kernel property, not a partition property:
    /// both rebalance and reshard preserve it.
    #[test]
    fn kernel_isa_survives_rebalance_and_reshard() {
        let crs = Arc::new(hh_crs());
        let mut sh = ShardedSpmv::new(
            crs,
            Scheme::Crs,
            Schedule::Static { chunk: None },
            4,
            2,
            OverlapMode::BulkSync,
            false,
        )
        .unwrap();
        assert_eq!(sh.kernel_isa(), IsaLevel::Scalar, "scalar until the tuner binds");
        sh.set_kernel_isa(IsaLevel::Avx2);
        sh.rebalance(Schedule::Dynamic { chunk: 9 });
        assert_eq!(sh.kernel_isa(), IsaLevel::Avx2, "rebalance must preserve the binding");
        sh.reshard(2, OverlapMode::Overlapped).unwrap();
        assert_eq!(sh.kernel_isa(), IsaLevel::Avx2, "reshard must preserve the binding");
    }

    #[test]
    fn batch_identical_to_per_vector() {
        let crs = Arc::new(hh_crs());
        let n = crs.nrows;
        let mut rng = Rng::new(112);
        let xs: Vec<Vec<f64>> = (0..5)
            .map(|_| {
                let mut x = vec![0.0; n];
                rng.fill_f64(&mut x, -1.0, 1.0);
                x
            })
            .collect();
        for mode in modes() {
            let sh = ShardedSpmv::new(
                crs.clone(),
                Scheme::Crs,
                Schedule::Static { chunk: None },
                4,
                2,
                mode,
                false,
            )
            .unwrap();
            let ys = sh.spmv_batch(&xs);
            assert_eq!(ys.len(), xs.len());
            for (x, yb) in xs.iter().zip(&ys) {
                let mut y = vec![0.0; n];
                sh.spmv(x, &mut y);
                assert_eq!(
                    max_abs_diff(&y, yb),
                    0.0,
                    "{}: batch deviates from per-vector",
                    mode.name()
                );
            }
            assert!(sh.spmv_batch(&[]).is_empty());
        }
    }

    /// ISSUE-7 satellite — PR 4's named follow-up retired: the
    /// coordinator + exchange roles are spawned once at construction and
    /// parked between calls, so repeated `spmv`/`spmv_batch` calls spawn
    /// **zero** threads on the hot path, in both overlap modes, while
    /// staying bit-identical to serial CRS.
    #[test]
    fn repeated_spmv_spawns_no_coordinator_threads() {
        let crs = Arc::new(hh_crs());
        let n = crs.nrows;
        let mut rng = Rng::new(114);
        let mut x = vec![0.0; n];
        rng.fill_f64(&mut x, -1.0, 1.0);
        let mut want = vec![0.0; n];
        crs.spmv(&x, &mut want);
        for mode in modes() {
            let sh = ShardedSpmv::new(
                crs.clone(),
                Scheme::Crs,
                Schedule::Static { chunk: None },
                4,
                2,
                mode,
                false,
            )
            .unwrap();
            let spawned = sh.coordinator_spawns();
            assert_eq!(spawned, 2 * sh.n_shards(), "{}: one pair of roles per shard", mode.name());
            let mut got = vec![0.0; n];
            for _ in 0..10 {
                sh.spmv(&x, &mut got);
                assert_eq!(max_abs_diff(&want, &got), 0.0, "{}: spmv deviates", mode.name());
            }
            let ys = sh.spmv_batch(&[x.clone(), x.clone(), x.clone()]);
            for y in &ys {
                assert_eq!(max_abs_diff(&want, y), 0.0, "{}: batch deviates", mode.name());
            }
            assert_eq!(
                sh.coordinator_spawns(),
                spawned,
                "{}: hot path must not spawn coordinator threads",
                mode.name()
            );
        }
    }

    /// ISSUE-4 satellite — the §5.2 hazard composed with sharding:
    /// re-planning onto a new schedule and re-sharding onto a new shard
    /// count both keep bit-identity and re-home the halo/output buffers
    /// on the new owners (extends the PR 3 rebalance tests).
    #[test]
    fn reshard_and_rebalance_keep_bit_identity_and_rehome_buffers() {
        let crs = Arc::new(hh_crs());
        let n = crs.nrows;
        let mut rng = Rng::new(113);
        let mut x = vec![0.0; n];
        rng.fill_f64(&mut x, -1.0, 1.0);
        let mut want = vec![0.0; n];
        crs.spmv(&x, &mut want);
        for pinned in [false, true] {
            let mut sh = ShardedSpmv::new(
                crs.clone(),
                Scheme::Crs,
                Schedule::Static { chunk: None },
                4,
                2,
                OverlapMode::Overlapped,
                pinned,
            )
            .unwrap();
            let mut got = vec![0.0; n];
            sh.spmv(&x, &mut got);
            assert_eq!(max_abs_diff(&want, &got), 0.0, "pin={pinned}: pre-rebalance");
            let before: Vec<Vec<(usize, usize)>> =
                sh.units.iter().map(|u| u.local_plan.partitions().concat()).collect();
            // Schedule change: plans re-partition, buffers re-home.
            sh.rebalance(Schedule::Dynamic { chunk: 9 });
            assert_eq!(sh.schedule(), Schedule::Dynamic { chunk: 9 });
            assert_eq!(sh.first_touched(), pinned, "rebalance must re-home when pinned");
            let after: Vec<Vec<(usize, usize)>> =
                sh.units.iter().map(|u| u.local_plan.partitions().concat()).collect();
            assert_ne!(before, after, "pin={pinned}: rebalance must re-partition");
            sh.spmv(&x, &mut got);
            assert_eq!(max_abs_diff(&want, &got), 0.0, "pin={pinned}: post-rebalance");
            // Shard-count change: everything rebuilt, halo buffers
            // re-sized and re-homed for the new partition.
            let halo4 = sh.storage().halo_cols_total();
            sh.reshard(2, OverlapMode::BulkSync).unwrap();
            assert_eq!(sh.n_shards(), 2);
            assert_eq!(sh.mode(), OverlapMode::BulkSync);
            assert_eq!(sh.first_touched(), pinned, "reshard must re-home when pinned");
            let halo2 = sh.storage().halo_cols_total();
            for (unit, shard) in sh.units.iter().zip(&sh.storage().shards) {
                assert_eq!(unit.bufs.lock().unwrap().concat.len(), shard.concat_len());
            }
            assert!(halo2 <= halo4, "fewer cuts cannot need more halo ({halo2} vs {halo4})");
            sh.spmv(&x, &mut got);
            assert_eq!(max_abs_diff(&want, &got), 0.0, "pin={pinned}: post-reshard");
        }
    }

    #[test]
    fn overlap_mode_parse_roundtrip() {
        assert_eq!(OverlapMode::parse("bulk-sync").unwrap(), OverlapMode::BulkSync);
        assert_eq!(OverlapMode::parse("bulk").unwrap(), OverlapMode::BulkSync);
        assert_eq!(OverlapMode::parse("overlapped").unwrap(), OverlapMode::Overlapped);
        assert_eq!(OverlapMode::parse("task").unwrap(), OverlapMode::Overlapped);
        assert!(OverlapMode::parse("bogus").is_err());
        assert_eq!(OverlapMode::BulkSync.name(), "bulk-sync");
        assert_eq!(OverlapMode::Overlapped.name(), "overlapped");
    }

    #[test]
    fn sharded_spmv_is_an_spmv_operator() {
        // A sharded executor drives operator consumers (Lanczos) and
        // reproduces the serial solver exactly.
        use crate::eigen::{lanczos, LanczosConfig};
        let crs = Arc::new(Crs::from_coo(&gen::laplacian_1d(150)));
        let serial = lanczos(&*crs, 1, &LanczosConfig::default());
        let sh = ShardedSpmv::new(
            crs.clone(),
            Scheme::Crs,
            Schedule::Static { chunk: None },
            3,
            2,
            OverlapMode::Overlapped,
            false,
        )
        .unwrap();
        assert_eq!(SpMv::nnz(&sh), crs.nnz());
        let r = lanczos(&sh, 1, &LanczosConfig::default());
        assert!(r.converged);
        assert!(
            (r.eigenvalues[0] - serial.eigenvalues[0]).abs() < 1e-12,
            "sharded-backed Lanczos deviates: {} vs {}",
            r.eigenvalues[0],
            serial.eigenvalues[0]
        );
    }

    #[test]
    fn rejects_non_square_and_unshardable_schemes() {
        let mut coo = crate::matrix::Coo::new(4, 7);
        coo.push(0, 6, 1.0);
        coo.normalize();
        let rect = Arc::new(Crs::from_coo(&coo));
        assert!(ShardedSpmv::new(
            rect,
            Scheme::Crs,
            Schedule::Static { chunk: None },
            2,
            1,
            OverlapMode::BulkSync,
            false,
        )
        .is_err());
        let crs = Arc::new(hh_crs());
        assert!(ShardedSpmv::new(
            crs,
            Scheme::NbJds { block: 64 },
            Schedule::Static { chunk: None },
            2,
            1,
            OverlapMode::BulkSync,
            false,
        )
        .is_err());
    }
}
