//! PJRT runtime: loads the AOT-compiled HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the CPU PJRT client.
//! Python never runs here — the artifacts are self-contained.
//!
//! Artifact naming convention (shapes are static in XLA):
//! `spmv_d{D}_n{N}.hlo.txt`, `spmv_b{B}_d{D}_n{N}.hlo.txt`,
//! `lanczos_step_d{D}_n{N}.hlo.txt`, `power_step_d{D}_n{N}.hlo.txt`.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::eigen::LinearOp;
use crate::matrix::EllMatrix;

/// Shape metadata parsed from an artifact file name.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArtifactMeta {
    pub kind: String,
    pub batch: Option<usize>,
    pub d: usize,
    pub n: usize,
}

/// Parse e.g. "spmv_b8_d24_n540.hlo.txt".
pub fn parse_artifact_name(name: &str) -> Result<ArtifactMeta> {
    let stem = name
        .strip_suffix(".hlo.txt")
        .with_context(|| format!("artifact '{name}' must end in .hlo.txt"))?;
    let mut batch = None;
    let mut d = None;
    let mut n = None;
    let mut kind_parts: Vec<&str> = Vec::new();
    for part in stem.split('_') {
        if let Some(v) = part.strip_prefix('b').and_then(|v| v.parse::<usize>().ok()) {
            batch = Some(v);
        } else if let Some(v) = part.strip_prefix('d').and_then(|v| v.parse::<usize>().ok()) {
            d = Some(v);
        } else if let Some(v) = part.strip_prefix('n').and_then(|v| v.parse::<usize>().ok()) {
            n = Some(v);
        } else {
            kind_parts.push(part);
        }
    }
    Ok(ArtifactMeta {
        kind: kind_parts.join("_"),
        batch,
        d: d.context("artifact name missing d<depth>")?,
        n: n.context("artifact name missing n<dim>")?,
    })
}

/// Default artifacts directory (./artifacts, overridable via
/// SPMVPERF_ARTIFACTS).
pub fn default_artifacts_dir() -> PathBuf {
    std::env::var("SPMVPERF_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

/// The PJRT CPU runtime: one client, many loaded executables.
pub struct Runtime {
    client: xla::PjRtClient,
    pub artifacts_dir: PathBuf,
}

impl Runtime {
    pub fn new(artifacts_dir: &Path) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client, artifacts_dir: artifacts_dir.to_path_buf() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one artifact by file name.
    pub fn load(&self, file_name: &str) -> Result<Loaded> {
        let meta = parse_artifact_name(file_name)?;
        let path = self.artifacts_dir.join(file_name);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).context("PJRT compile")?;
        Ok(Loaded { exe, meta })
    }

    /// List artifact file names available in the artifacts directory.
    pub fn available(&self) -> Vec<String> {
        let mut v: Vec<String> = std::fs::read_dir(&self.artifacts_dir)
            .map(|rd| {
                rd.filter_map(|e| e.ok())
                    .filter_map(|e| e.file_name().into_string().ok())
                    .filter(|n| n.ends_with(".hlo.txt"))
                    .collect()
            })
            .unwrap_or_default();
        v.sort();
        v
    }

    /// Bind an SpMV-family artifact to a concrete matrix (uploads the
    /// `val`/`col` planes once; they are reused across requests).
    pub fn bind(&self, ell: &EllMatrix, loaded: Loaded) -> Result<BoundSpmv> {
        let meta = &loaded.meta;
        if !matches!(meta.kind.as_str(), "spmv" | "lanczos_step" | "power_step") {
            bail!("cannot bind '{}' as an SpMV-family module", meta.kind);
        }
        if meta.d != ell.d || meta.n != ell.n {
            bail!(
                "artifact shape (d={}, n={}) does not match matrix (d={}, n={})",
                meta.d,
                meta.n,
                ell.d,
                ell.n
            );
        }
        let val = xla::Literal::vec1(&ell.val).reshape(&[ell.d as i64, ell.n as i64])?;
        let col = xla::Literal::vec1(&ell.col).reshape(&[ell.d as i64, ell.n as i64])?;
        Ok(BoundSpmv { exe: loaded.exe, meta: loaded.meta, val, col, n: ell.n })
    }
}

/// One compiled artifact.
pub struct Loaded {
    pub exe: xla::PjRtLoadedExecutable,
    pub meta: ArtifactMeta,
}

/// An SpMV-family executable with the matrix operands prepared.
/// Operates in the *permuted* basis (like all hot-path kernels).
pub struct BoundSpmv {
    exe: xla::PjRtLoadedExecutable,
    pub meta: ArtifactMeta,
    val: xla::Literal,
    col: xla::Literal,
    pub n: usize,
}

impl BoundSpmv {
    /// y = A x (single vector; requires a `spmv` artifact without batch).
    pub fn spmv(&self, x: &[f64]) -> Result<Vec<f64>> {
        anyhow::ensure!(x.len() == self.n, "input length {} != {}", x.len(), self.n);
        let xl = xla::Literal::vec1(x);
        let result = self.exe.execute::<&xla::Literal>(&[&self.val, &self.col, &xl])?[0][0]
            .to_literal_sync()?;
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f64>()?)
    }

    /// Batched SpMV (requires a batched `spmv` artifact). Short batches
    /// are padded with zero vectors and truncated on return.
    pub fn spmv_batched(&self, xs: &[Vec<f64>]) -> Result<Vec<Vec<f64>>> {
        let b = self
            .meta
            .batch
            .context("artifact was not built with a batch dimension")?;
        anyhow::ensure!(
            xs.len() <= b,
            "batch size {} exceeds artifact batch {b}",
            xs.len()
        );
        let mut flat = Vec::with_capacity(b * self.n);
        for x in xs {
            anyhow::ensure!(x.len() == self.n);
            flat.extend_from_slice(x);
        }
        flat.resize(b * self.n, 0.0); // pad
        let xl = xla::Literal::vec1(&flat).reshape(&[b as i64, self.n as i64])?;
        let result = self.exe.execute::<&xla::Literal>(&[&self.val, &self.col, &xl])?[0][0]
            .to_literal_sync()?;
        let out = result.to_tuple1()?;
        let flat_out = out.to_vec::<f64>()?;
        Ok(flat_out
            .chunks(self.n)
            .take(xs.len())
            .map(|c| c.to_vec())
            .collect())
    }

    /// One Lanczos step (requires a `lanczos_step` artifact):
    /// returns (alpha, beta_new, v_next).
    pub fn lanczos_step(
        &self,
        v_prev: &[f64],
        v_cur: &[f64],
        beta: f64,
    ) -> Result<(f64, f64, Vec<f64>)> {
        anyhow::ensure!(self.meta.kind == "lanczos_step");
        let vp = xla::Literal::vec1(v_prev);
        let vc = xla::Literal::vec1(v_cur);
        let b = xla::Literal::scalar(beta);
        let result = self
            .exe
            .execute::<&xla::Literal>(&[&self.val, &self.col, &vp, &vc, &b])?[0][0]
            .to_literal_sync()?;
        let (a, bn, vn) = result.to_tuple3()?;
        Ok((
            a.to_vec::<f64>()?[0],
            bn.to_vec::<f64>()?[0],
            vn.to_vec::<f64>()?,
        ))
    }

    /// One power-iteration step (requires a `power_step` artifact):
    /// returns (v_next, rayleigh).
    pub fn power_step(&self, v: &[f64], shift: f64) -> Result<(Vec<f64>, f64)> {
        anyhow::ensure!(self.meta.kind == "power_step");
        let vl = xla::Literal::vec1(v);
        let s = xla::Literal::scalar(shift);
        let result = self
            .exe
            .execute::<&xla::Literal>(&[&self.val, &self.col, &vl, &s])?[0][0]
            .to_literal_sync()?;
        let (vn, r) = result.to_tuple2()?;
        Ok((vn.to_vec::<f64>()?, r.to_vec::<f64>()?[0]))
    }
}

/// Original-basis linear operator over a PJRT-bound SpMV: lets the Rust
/// Lanczos drive the AOT'd Pallas kernel transparently.
pub struct PjrtOp<'a> {
    pub bound: &'a BoundSpmv,
    pub ell: &'a EllMatrix,
}

impl<'a> LinearOp for PjrtOp<'a> {
    fn dim(&self) -> usize {
        self.ell.n
    }
    fn apply(&self, x: &[f64], y: &mut [f64]) {
        let xp = self.ell.permute_vec(x);
        let yp = self.bound.spmv(&xp).expect("PJRT SpMV failed");
        self.ell.unpermute_vec(&yp, y);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_artifact_names() {
        let m = parse_artifact_name("spmv_d24_n540.hlo.txt").unwrap();
        assert_eq!(m, ArtifactMeta { kind: "spmv".into(), batch: None, d: 24, n: 540 });
        let m = parse_artifact_name("spmv_b8_d24_n540.hlo.txt").unwrap();
        assert_eq!(m.batch, Some(8));
        assert_eq!(m.kind, "spmv");
        let m = parse_artifact_name("lanczos_step_d24_n540.hlo.txt").unwrap();
        assert_eq!(m.kind, "lanczos_step");
        assert!(parse_artifact_name("bogus.txt").is_err());
        assert!(parse_artifact_name("spmv_n540.hlo.txt").is_err());
    }

    // Execution tests live in rust/tests/runtime_integration.rs (they
    // need artifacts built by `make artifacts`).
}
