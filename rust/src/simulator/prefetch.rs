//! Hardware prefetcher models (§4.1, Fig 3):
//!
//! - **SP** (strided/stream prefetcher): a table of per-page stream
//!   detectors tracking the last line and delta; two consecutive equal
//!   deltas arm the stream and prefetches are issued ahead. Hides DRAM
//!   latency on regular streams; on *moderately* random gathers it fires
//!   spuriously, wasting bandwidth and polluting the cache (the paper's
//!   k < 25 "bulge" on Woodcrest).
//! - **AP** (adjacent cache line prefetch): handled in the core model —
//!   every demand miss also fetches the buddy line (128 B granularity).

/// Upper bound on prefetches issued per observation.
pub const MAX_DEGREE: usize = 4;

/// One detected stream.
#[derive(Debug, Clone, Copy, Default)]
struct StreamEntry {
    page: u64,
    last_line: i64,
    delta: i64,
    confidence: u8,
    valid: bool,
    stamp: u64,
}

/// Strided prefetcher with an LRU stream table.
#[derive(Debug, Clone)]
pub struct StridePrefetcher {
    table: Vec<StreamEntry>,
    clock: u64,
    /// Number of line-deltas to run ahead once armed.
    pub degree: usize,
    /// Max |delta| (in lines) the detector will follow.
    pub max_delta: i64,
    pub issued: u64,
}

impl Default for StridePrefetcher {
    fn default() -> Self {
        Self::new(16, 2, 8)
    }
}

impl StridePrefetcher {
    pub fn new(streams: usize, degree: usize, max_delta: i64) -> Self {
        StridePrefetcher {
            table: vec![StreamEntry::default(); streams],
            clock: 0,
            degree,
            max_delta,
            issued: 0,
        }
    }

    /// Observe a demand L1 miss (line number = addr / line_bytes, page =
    /// addr / page_bytes). Writes line numbers to prefetch into `out`
    /// and returns how many (0..=degree). Alloc-free: this sits on the
    /// simulator's hottest path.
    pub fn observe_into(&mut self, page: u64, line: i64, out: &mut [i64; MAX_DEGREE]) -> usize {
        self.clock += 1;
        // Find the stream for this page.
        let mut idx = None;
        let mut lru = 0;
        let mut oldest = u64::MAX;
        for (i, e) in self.table.iter().enumerate() {
            if e.valid && e.page == page {
                idx = Some(i);
                break;
            }
            if e.stamp < oldest {
                oldest = e.stamp;
                lru = i;
            }
        }
        let i = match idx {
            Some(i) => i,
            None => {
                self.table[lru] = StreamEntry {
                    page,
                    last_line: line,
                    delta: 0,
                    confidence: 0,
                    valid: true,
                    stamp: self.clock,
                };
                return 0;
            }
        };
        let e = &mut self.table[i];
        e.stamp = self.clock;
        let new_delta = line - e.last_line;
        if new_delta == 0 {
            // Same line again: no new information.
            return 0;
        }
        let mut count = 0usize;
        // Real stream detectors tolerate jitter of about one line and
        // track ascending streams only (x86 prefetchers are much weaker
        // on descending patterns — this is what makes backward jumps
        // expensive for the JDS-family kernels, §4.1/Fig 6a).
        let matches = new_delta > 0
            && e.delta > 0
            && (new_delta - e.delta).abs() <= 1
            && new_delta <= self.max_delta;
        if matches {
            e.confidence = e.confidence.saturating_add(1);
            if e.confidence >= 1 {
                // Armed: run ahead of the stream.
                for step in 1..=self.degree.min(MAX_DEGREE) as i64 {
                    out[count] = line + new_delta * step;
                    count += 1;
                }
                self.issued += count as u64;
            }
        } else {
            e.confidence = 0;
        }
        e.delta = new_delta;
        e.last_line = line;
        count
    }

    /// Convenience wrapper used by tests.
    pub fn observe(&mut self, page: u64, line: i64) -> Vec<i64> {
        let mut buf = [0i64; MAX_DEGREE];
        let n = self.observe_into(page, line, &mut buf);
        buf[..n].to_vec()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequential_stream_is_detected() {
        let mut sp = StridePrefetcher::default();
        let mut prefetched = Vec::new();
        for line in 0..10i64 {
            prefetched.extend(sp.observe(0, line));
        }
        // After lines 0,1 (delta 1) and 1,2 (confirmation) the stream is
        // armed; subsequent accesses prefetch ahead.
        assert!(prefetched.contains(&3));
        assert!(prefetched.contains(&10));
        assert!(sp.issued > 0);
    }

    #[test]
    fn constant_large_stride_detected_within_limit() {
        let mut sp = StridePrefetcher::new(16, 2, 8);
        let mut got = Vec::new();
        for i in 0..8i64 {
            got.extend(sp.observe(0, i * 4));
        }
        assert!(got.contains(&16), "stride-4 stream should be prefetched");
        // stride beyond max_delta is not followed
        let mut sp2 = StridePrefetcher::new(16, 2, 8);
        let mut got2 = Vec::new();
        for i in 0..8i64 {
            got2.extend(sp2.observe(0, i * 100));
        }
        assert!(got2.is_empty());
    }

    #[test]
    fn random_stream_rarely_fires() {
        let mut sp = StridePrefetcher::default();
        let mut rng = crate::util::rng::Rng::new(5);
        let mut count = 0usize;
        for _ in 0..10_000 {
            let line = rng.index(1 << 20) as i64;
            count += sp.observe((line / 64) as u64, line).len();
        }
        // Random lines on random pages: arming is rare.
        assert!(count < 500, "spurious prefetches {count}");
    }

    #[test]
    fn streams_tracked_per_page() {
        let mut sp = StridePrefetcher::default();
        // Interleave two independent streams on different pages; both
        // must be detected.
        let mut a = Vec::new();
        let mut b = Vec::new();
        for i in 0..6i64 {
            a.extend(sp.observe(1, 1000 + i));
            b.extend(sp.observe(2, 5000 + 2 * i));
        }
        assert!(!a.is_empty());
        assert!(!b.is_empty());
    }

    #[test]
    fn table_evicts_lru() {
        let mut sp = StridePrefetcher::new(2, 2, 8);
        sp.observe(1, 0);
        sp.observe(2, 0);
        sp.observe(3, 0); // evicts page 1
        sp.observe(1, 1); // re-allocated, no history
        let out = sp.observe(1, 2);
        assert!(out.is_empty(), "fresh stream must need re-confirmation");
    }
}
