//! Trace-driven multicore memory-hierarchy simulator — the substitute
//! for the paper's 2009 test bed (see DESIGN.md §1 and §4).
//!
//! - [`topology`]: machine models (Woodcrest, Shanghai, Nehalem, HLRB-II)
//!   calibrated to §3 of the paper.
//! - [`cache`] / [`tlb`]: set-associative LRU caches with write-back and
//!   prefetch tagging; a 4-way data TLB.
//! - [`prefetch`]: the strided stream prefetcher (SP); the adjacent-line
//!   prefetcher (AP) lives in the core model.
//! - [`core`]: per-thread hierarchy + cycle/traffic accounting.
//! - [`engine`]: kernel walks → per-thread traces → roofline combination
//!   (CPU vs per-thread MLP vs socket/node/link bandwidth), with ccNUMA
//!   first-touch placement and OpenMP scheduling.

pub mod cache;
pub mod core;
pub mod engine;
pub mod prefetch;
pub mod tlb;
pub mod topology;

pub use engine::{
    pin_threads, simulate_microbench, simulate_spmv, simulate_spmv_plan, simulate_stream_triad,
    Placement, SimOptions, SimResult,
};
pub use topology::MachineSpec;
