//! Per-thread core model: cache hierarchy + TLB + prefetchers + cycle
//! and traffic accounting. Shared caches are modeled with their capacity
//! divided among the active sharers.

use super::cache::{Cache, Lookup};
use super::prefetch::StridePrefetcher;
use super::tlb::Tlb;
use super::topology::MachineSpec;

/// Traffic and stall statistics of one simulated thread.
#[derive(Debug, Clone, Default)]
pub struct CoreStats {
    /// CPU-side issue cycles (updates × issue cost + loop overheads).
    pub issue_cycles: f64,
    /// Latency stall cycles (unhidden cache/DRAM/TLB latencies).
    pub stall_cycles: f64,
    /// Demand lines fetched from local / remote DRAM.
    pub lines_local: u64,
    pub lines_remote: u64,
    /// Prefetch lines fetched from local / remote DRAM.
    pub pf_lines_local: u64,
    pub pf_lines_remote: u64,
    /// Dirty lines written back to DRAM.
    pub writeback_lines: u64,
    pub tlb_misses: u64,
    pub updates: u64,
    pub loop_starts: u64,
}

impl CoreStats {
    /// Total DRAM bytes moved (demand + prefetch + writeback).
    pub fn dram_bytes(&self, line_bytes: usize) -> f64 {
        (self.lines_local + self.lines_remote + self.pf_lines_local + self.pf_lines_remote
            + self.writeback_lines) as f64
            * line_bytes as f64
    }

    pub fn remote_bytes(&self, line_bytes: usize) -> f64 {
        (self.lines_remote + self.pf_lines_remote) as f64 * line_bytes as f64
    }
}

/// One simulated hardware thread (core) with its cache hierarchy.
pub struct CoreSim {
    machine: MachineSpec,
    /// NUMA domain (socket) this core belongs to.
    pub domain: u8,
    l1: Cache,
    l2: Cache,
    l3: Option<Cache>,
    tlb: Tlb,
    sp: Option<StridePrefetcher>,
    ap: bool,
    pub stats: CoreStats,
    /// When false, cache state evolves but no cycles/traffic are
    /// accounted (warm-up pass).
    pub accounting: bool,
}

impl CoreSim {
    /// `sharers_l2`/`sharers_l3`: active threads sharing this core's L2 /
    /// L3 instance (capacity splitting).
    pub fn new(
        machine: &MachineSpec,
        domain: u8,
        sharers_l2: usize,
        sharers_l3: usize,
        sp_on: bool,
        ap_on: bool,
    ) -> Self {
        CoreSim {
            machine: machine.clone(),
            domain,
            l1: Cache::new(&machine.l1, 1),
            l2: Cache::new(&machine.l2, sharers_l2.max(1)),
            l3: machine.l3.as_ref().map(|s| Cache::new(s, sharers_l3.max(1))),
            tlb: Tlb::new(machine.tlb_entries, machine.page_bytes),
            sp: if sp_on { Some(StridePrefetcher::default()) } else { None },
            ap: ap_on,
            stats: CoreStats::default(),
            accounting: true,
        }
    }

    #[inline]
    fn charge(&mut self, cycles: f64) {
        if self.accounting {
            self.stats.stall_cycles += cycles;
        }
    }

    /// Charge CPU issue work (updates, loop starts).
    #[inline]
    pub fn issue(&mut self, cycles: f64) {
        if self.accounting {
            self.stats.issue_cycles += cycles;
        }
    }

    /// A dirty line evicted from L2 sinks into L3 (marked dirty there) or
    /// — if L3 is absent or no longer holds it — goes to DRAM.
    fn sink_l2_eviction(&mut self, ev: crate::simulator::cache::Eviction) {
        if !ev.dirty {
            return;
        }
        let absorbed = match &mut self.l3 {
            Some(l3) => l3.mark_dirty(ev.addr),
            None => false,
        };
        if !absorbed && self.accounting {
            self.stats.writeback_lines += 1;
        }
    }

    /// A dirty line evicted from L3 always goes to DRAM.
    fn sink_l3_eviction(&mut self, ev: Option<crate::simulator::cache::Eviction>) {
        if let Some(ev) = ev {
            if ev.dirty && self.accounting {
                self.stats.writeback_lines += 1;
            }
        }
    }

    /// Fetch a line into the hierarchy on behalf of a prefetch;
    /// `remote`: the page's home is another domain.
    fn prefetch_line(&mut self, addr: u64, remote: bool) {
        // Insert into L2 (and L3): only count traffic if the line was
        // actually absent.
        let mut new = false;
        if self.l3.is_some() {
            let (ins, ev) = self.l3.as_mut().unwrap().prefetch(addr);
            new |= ins;
            self.sink_l3_eviction(ev);
        }
        let (ins, ev) = self.l2.prefetch(addr);
        new |= ins;
        if let Some(ev) = ev {
            self.sink_l2_eviction(ev);
        }
        if new && self.accounting {
            if remote {
                self.stats.pf_lines_remote += 1;
            } else {
                self.stats.pf_lines_local += 1;
            }
        }
    }

    /// One demand access of `size` bytes at `addr` (assumed not to cross
    /// a line boundary for accounting purposes). `home_remote`: page home
    /// is on another NUMA domain.
    pub fn access(&mut self, addr: u64, write: bool, home_remote: bool) {
        let mlp = self.machine.mlp_demand;
        let line_bytes = self.machine.l1.line_bytes as u64;
        let page_bytes = self.machine.page_bytes as u64;
        let tlb_pen = self.machine.tlb_miss_cycles;
        // TLB
        if !self.tlb.access(addr) {
            if self.accounting {
                self.stats.tlb_misses += 1;
            }
            self.charge(tlb_pen);
        }
        // L1 (dirty L1 victims are absorbed by L2: mark there).
        let (l1_res, l1_ev) = self.l1.access(addr, write);
        // The strided prefetcher observes the L1 miss stream (line
        // granular), as real L2 prefetchers do.
        if l1_res == Lookup::Miss {
            if self.sp.is_some() {
                let lineno = (addr / line_bytes) as i64;
                let page = addr / page_bytes;
                let mut buf = [0i64; crate::simulator::prefetch::MAX_DEGREE];
                let n = self.sp.as_mut().unwrap().observe_into(page, lineno, &mut buf);
                for &t in &buf[..n] {
                    if t >= 0 {
                        self.prefetch_line(t as u64 * line_bytes, home_remote);
                    }
                }
            }
        }
        if let Some(ev) = l1_ev {
            if ev.dirty && !self.l2.mark_dirty(ev.addr) {
                // L2 no longer holds it (non-inclusive artifact): push on.
                self.sink_l2_eviction(ev);
            }
        }
        match l1_res {
            Lookup::Hit | Lookup::HitPrefetched => {
                return; // covered by issue cost
            }
            Lookup::Miss => {}
        }
        // L2
        let (l2_res, l2_ev) = self.l2.access(addr, write);
        if let Some(ev) = l2_ev {
            self.sink_l2_eviction(ev);
        }
        match l2_res {
            Lookup::Hit | Lookup::HitPrefetched => {
                // (Prefetched line: already on its way; only L2 latency.)
                self.charge(self.machine.l2.latency_cycles / mlp);
                return;
            }
            Lookup::Miss => {}
        }
        // L3
        if self.l3.is_some() {
            let (l3_res, l3_ev) = self.l3.as_mut().unwrap().access(addr, write);
            self.sink_l3_eviction(l3_ev);
            match l3_res {
                Lookup::Hit | Lookup::HitPrefetched => {
                    let lat = self.machine.l3.as_ref().unwrap().latency_cycles;
                    self.charge(lat / mlp);
                    return;
                }
                Lookup::Miss => {}
            }
        }
        // DRAM demand miss.
        let lat_factor = if home_remote { self.machine.remote_latency_factor } else { 1.0 };
        let lat = self.machine.dram_latency_cycles * lat_factor / mlp;
        self.charge(lat);
        if self.accounting {
            if home_remote {
                self.stats.lines_remote += 1;
            } else {
                self.stats.lines_local += 1;
            }
        }
        // Adjacent-line prefetch: fetch the buddy line too.
        if self.ap {
            let buddy = addr ^ line_bytes;
            self.prefetch_line(buddy & !(line_bytes - 1), home_remote);
        }
    }

    /// Flush residual dirty lines at the end of a measured run into the
    /// writeback account (a steady-state solver eventually writes them).
    /// Writebacks caused by evictions were already counted online.
    pub fn harvest_writebacks(&mut self) {
        // Online accounting covers evictions; residual dirty lines in the
        // hierarchy are left uncounted deliberately: in steady state they
        // are re-dirtied every iteration and never reach DRAM.
    }

    pub fn reset_stats(&mut self) {
        self.stats = CoreStats::default();
        self.l1.reset_stats();
        self.l2.reset_stats();
        if let Some(l3) = &mut self.l3 {
            l3.reset_stats();
        }
        self.tlb.reset_stats();
    }

    pub fn line_bytes(&self) -> usize {
        self.machine.l1.line_bytes
    }

    /// L1/L2 hit rates for diagnostics.
    pub fn hit_rates(&self) -> (f64, f64) {
        (self.l1.hit_rate(), self.l2.hit_rate())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn core(sp: bool, ap: bool) -> CoreSim {
        CoreSim::new(&MachineSpec::woodcrest(), 0, 1, 1, sp, ap)
    }

    #[test]
    fn sequential_stream_hides_latency_with_sp() {
        let n = 100_000u64;
        let mut with_sp = core(true, false);
        let mut without = core(false, false);
        for c in [&mut with_sp, &mut without] {
            for i in 0..n {
                c.access(i * 8, false, false);
            }
        }
        assert!(
            with_sp.stats.stall_cycles < 0.3 * without.stats.stall_cycles,
            "SP must hide most DRAM latency: {} vs {}",
            with_sp.stats.stall_cycles,
            without.stats.stall_cycles
        );
        // Same total lines moved (demand vs prefetch).
        let t1 = with_sp.stats.lines_local + with_sp.stats.pf_lines_local;
        let t2 = without.stats.lines_local;
        assert!((t1 as f64 - t2 as f64).abs() / (t2 as f64) < 0.05);
    }

    #[test]
    fn ap_doubles_traffic_for_isolated_accesses() {
        let mut with_ap = core(false, true);
        let mut without = core(false, false);
        // Sparse pseudo-random isolated lines.
        let mut rng = crate::util::rng::Rng::new(9);
        let addrs: Vec<u64> = (0..20_000).map(|_| rng.below(1 << 30) & !63).collect();
        for c in [&mut with_ap, &mut without] {
            for &a in &addrs {
                c.access(a, false, false);
            }
        }
        let t_ap = with_ap.stats.dram_bytes(64);
        let t_no = without.stats.dram_bytes(64);
        assert!(
            t_ap > 1.7 * t_no,
            "AP should nearly double traffic: {t_ap} vs {t_no}"
        );
    }

    #[test]
    fn tlb_misses_counted_for_page_strides() {
        let mut c = core(false, false);
        for i in 0..10_000u64 {
            c.access(i * 4096 * 7, false, false);
        }
        assert!(c.stats.tlb_misses > 9000);
    }

    #[test]
    fn remote_accesses_cost_more() {
        // NUMA machine: remote latency factor > 1.
        let m = MachineSpec::nehalem();
        let mut local = CoreSim::new(&m, 0, 1, 1, false, false);
        let mut remote = CoreSim::new(&m, 0, 1, 1, false, false);
        for i in 0..10_000u64 {
            local.access(i * 64, false, false);
            remote.access(i * 64, false, true);
        }
        assert!(remote.stats.stall_cycles > local.stats.stall_cycles);
        assert_eq!(remote.stats.lines_remote, 10_000);
        assert_eq!(local.stats.lines_local, 10_000);
    }

    #[test]
    fn warmup_pass_accounts_nothing() {
        let mut c = core(true, true);
        c.accounting = false;
        for i in 0..1000u64 {
            c.access(i * 64, false, false);
        }
        assert_eq!(c.stats.lines_local, 0);
        assert_eq!(c.stats.stall_cycles, 0.0);
        assert_eq!(c.stats.tlb_misses, 0);
    }

    #[test]
    fn writeback_harvest() {
        let mut c = core(false, false);
        // Write a stream larger than all caches, then evict by reading
        // another large stream.
        for i in 0..200_000u64 {
            c.access(i * 64, true, false);
        }
        for i in 0..200_000u64 {
            c.access((1 << 34) + i * 64, false, false);
        }
        c.harvest_writebacks();
        assert!(c.stats.writeback_lines > 100_000);
    }
}
