//! Data-TLB model: 4-way set-associative, LRU, configurable entry count
//! and page size. Captures the paper's k=530 stride penalty (one entry
//! per memory page exceeds TLB reach — Fig 2).

#[derive(Debug, Clone, Copy, Default)]
struct Entry {
    page: u64,
    valid: bool,
    stamp: u64,
}

#[derive(Debug, Clone)]
pub struct Tlb {
    sets: usize,
    assoc: usize,
    page_shift: u32,
    entries: Vec<Entry>,
    clock: u64,
    pub hits: u64,
    pub misses: u64,
}

impl Tlb {
    pub fn new(n_entries: usize, page_bytes: usize) -> Self {
        assert!(page_bytes.is_power_of_two());
        let assoc = 4.min(n_entries.max(1));
        let sets = (n_entries / assoc).max(1);
        let sets = if sets.is_power_of_two() {
            sets
        } else {
            1 << (usize::BITS - 1 - sets.leading_zeros())
        };
        Tlb {
            sets,
            assoc,
            page_shift: page_bytes.trailing_zeros(),
            entries: vec![Entry::default(); sets * assoc],
            clock: 0,
            hits: 0,
            misses: 0,
        }
    }

    /// Translate an address; returns true on TLB hit.
    #[inline]
    pub fn access(&mut self, addr: u64) -> bool {
        self.clock += 1;
        let page = addr >> self.page_shift;
        let set = (page as usize) & (self.sets - 1);
        let base = set * self.assoc;
        let ways = &mut self.entries[base..base + self.assoc];
        for e in ways.iter_mut() {
            if e.valid && e.page == page {
                e.stamp = self.clock;
                self.hits += 1;
                return true;
            }
        }
        self.misses += 1;
        // LRU replace
        let mut victim = 0;
        let mut oldest = u64::MAX;
        for (i, e) in ways.iter().enumerate() {
            if !e.valid {
                victim = i;
                break;
            }
            if e.stamp < oldest {
                oldest = e.stamp;
                victim = i;
            }
        }
        ways[victim] = Entry { page, valid: true, stamp: self.clock };
        false
    }

    pub fn miss_rate(&self) -> f64 {
        let t = self.hits + self.misses;
        if t == 0 {
            0.0
        } else {
            self.misses as f64 / t as f64
        }
    }

    pub fn reset_stats(&mut self) {
        self.hits = 0;
        self.misses = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequential_pages_mostly_hit() {
        let mut t = Tlb::new(64, 4096);
        for addr in (0..4096 * 16u64).step_by(64) {
            t.access(addr);
        }
        // 16 pages, 64 accesses each: 16 misses out of 1024
        assert_eq!(t.misses, 16);
        assert!(t.miss_rate() < 0.02);
    }

    #[test]
    fn page_stride_thrashes_small_tlb() {
        let mut t = Tlb::new(64, 4096);
        // 128 distinct pages round-robin: exceeds 64 entries -> ~all miss
        for rep in 0..3 {
            for i in 0..128u64 {
                t.access(i * 4096);
            }
            if rep == 0 {
                t.reset_stats();
            }
        }
        assert!(t.miss_rate() > 0.9, "miss rate {}", t.miss_rate());
    }

    #[test]
    fn fits_in_tlb_hits() {
        let mut t = Tlb::new(64, 4096);
        for rep in 0..2 {
            for i in 0..32u64 {
                t.access(i * 4096);
            }
            if rep == 0 {
                t.reset_stats();
            }
        }
        // 32 pages across 16 sets x 4 ways: all retained
        assert_eq!(t.misses, 0);
    }
}
