//! Set-associative LRU cache model with dirty lines (write-back,
//! write-allocate) and prefetch tagging, used for every level of the
//! simulated hierarchy.

use super::topology::CacheSpec;

/// Outcome of a cache lookup.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Lookup {
    Hit,
    /// Hit on a line brought in by the prefetcher and not yet used.
    HitPrefetched,
    Miss,
}

/// A line evicted by an insertion; `addr` is the line's base address.
/// Dirty evictions must be propagated to the next level (or DRAM).
#[derive(Debug, Clone, Copy)]
pub struct Eviction {
    pub addr: u64,
    pub dirty: bool,
}

#[derive(Debug, Clone, Copy, Default)]
struct Line {
    tag: u64,
    valid: bool,
    dirty: bool,
    /// Inserted by prefetch, not yet demanded.
    prefetched: bool,
    stamp: u64,
}

/// One cache instance.
#[derive(Debug, Clone)]
pub struct Cache {
    sets: usize,
    assoc: usize,
    line_shift: u32,
    lines: Vec<Line>,
    clock: u64,
    // statistics
    pub hits: u64,
    pub misses: u64,
    pub prefetch_inserts: u64,
    pub prefetch_useful: u64,
    pub prefetch_wasted: u64,
    pub writebacks: u64,
}

impl Cache {
    /// Build from a spec with an optional capacity divisor (shared caches
    /// are modeled per-thread with `capacity / sharers`).
    pub fn new(spec: &CacheSpec, capacity_divisor: usize) -> Self {
        let line = spec.line_bytes;
        assert!(line.is_power_of_two());
        let size = (spec.size_bytes / capacity_divisor.max(1)).max(line * spec.assoc);
        let sets = (size / line / spec.assoc).max(1);
        // Round set count down to a power of two for cheap indexing (real
        // caches have power-of-two sets as well).
        let sets = if sets.is_power_of_two() {
            sets
        } else {
            1 << (usize::BITS - 1 - sets.leading_zeros())
        };
        Cache {
            sets,
            assoc: spec.assoc,
            line_shift: line.trailing_zeros(),
            lines: vec![Line::default(); sets * spec.assoc],
            clock: 0,
            hits: 0,
            misses: 0,
            prefetch_inserts: 0,
            prefetch_useful: 0,
            prefetch_wasted: 0,
            writebacks: 0,
        }
    }

    pub fn line_bytes(&self) -> usize {
        1usize << self.line_shift
    }

    #[inline]
    fn set_and_tag(&self, addr: u64) -> (usize, u64) {
        let lineno = addr >> self.line_shift;
        ((lineno as usize) & (self.sets - 1), lineno)
    }

    /// Demand access to `addr`. On a miss the line is inserted (write
    /// allocate); the victim's dirty state increments `writebacks` and is
    /// returned so the caller can propagate it down the hierarchy.
    pub fn access(&mut self, addr: u64, write: bool) -> (Lookup, Option<Eviction>) {
        self.clock += 1;
        let (set, tag) = self.set_and_tag(addr);
        let base = set * self.assoc;
        let ways = &mut self.lines[base..base + self.assoc];
        for l in ways.iter_mut() {
            if l.valid && l.tag == tag {
                l.stamp = self.clock;
                l.dirty |= write;
                if l.prefetched {
                    l.prefetched = false;
                    self.prefetch_useful += 1;
                    self.hits += 1;
                    return (Lookup::HitPrefetched, None);
                }
                self.hits += 1;
                return (Lookup::Hit, None);
            }
        }
        self.misses += 1;
        let ev = self.insert(set, tag, write, false);
        (Lookup::Miss, ev)
    }

    /// Mark a resident line dirty (a dirty eviction from the level above
    /// landed here). Returns false if the line is not present — the
    /// caller should then treat it as a DRAM writeback.
    pub fn mark_dirty(&mut self, addr: u64) -> bool {
        let (set, tag) = self.set_and_tag(addr);
        let base = set * self.assoc;
        for l in self.lines[base..base + self.assoc].iter_mut() {
            if l.valid && l.tag == tag {
                l.dirty = true;
                return true;
            }
        }
        false
    }

    /// Probe without modifying state (used by inclusive-hierarchy checks).
    pub fn probe(&self, addr: u64) -> bool {
        let (set, tag) = self.set_and_tag(addr);
        let base = set * self.assoc;
        self.lines[base..base + self.assoc]
            .iter()
            .any(|l| l.valid && l.tag == tag)
    }

    /// Prefetch insert: brings the line in marked `prefetched` unless
    /// already present. Returns (inserted?, eviction): `inserted` means a
    /// new line actually arrived (i.e. memory traffic happened).
    pub fn prefetch(&mut self, addr: u64) -> (bool, Option<Eviction>) {
        self.clock += 1;
        let (set, tag) = self.set_and_tag(addr);
        let base = set * self.assoc;
        if self.lines[base..base + self.assoc]
            .iter()
            .any(|l| l.valid && l.tag == tag)
        {
            return (false, None);
        }
        self.prefetch_inserts += 1;
        let ev = self.insert(set, tag, false, true);
        (true, ev)
    }

    fn insert(&mut self, set: usize, tag: u64, dirty: bool, prefetched: bool) -> Option<Eviction> {
        let base = set * self.assoc;
        let ways = &mut self.lines[base..base + self.assoc];
        // LRU victim (or first invalid way).
        let mut victim = 0;
        let mut oldest = u64::MAX;
        for (i, l) in ways.iter().enumerate() {
            if !l.valid {
                victim = i;
                break;
            }
            if l.stamp < oldest {
                oldest = l.stamp;
                victim = i;
            }
        }
        let v = &mut ways[victim];
        let mut ev = None;
        if v.valid {
            if v.dirty {
                self.writebacks += 1;
            }
            if v.prefetched {
                self.prefetch_wasted += 1;
            }
            ev = Some(Eviction { addr: v.tag << self.line_shift, dirty: v.dirty });
        }
        *v = Line { tag, valid: true, dirty, prefetched, stamp: self.clock };
        ev
    }

    /// Fraction of demand accesses that hit.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    pub fn reset_stats(&mut self) {
        self.hits = 0;
        self.misses = 0;
        self.prefetch_inserts = 0;
        self.prefetch_useful = 0;
        self.prefetch_wasted = 0;
        self.writebacks = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simulator::topology::CacheSpec;

    fn spec(size: usize, assoc: usize) -> CacheSpec {
        CacheSpec { size_bytes: size, assoc, line_bytes: 64, latency_cycles: 1.0, shared_by: 1 }
    }

    #[test]
    fn hits_within_line() {
        let mut c = Cache::new(&spec(4096, 4), 1);
        assert_eq!(c.access(0, false).0, Lookup::Miss);
        assert_eq!(c.access(8, false).0, Lookup::Hit);
        assert_eq!(c.access(63, false).0, Lookup::Hit);
        assert_eq!(c.access(64, false).0, Lookup::Miss);
    }

    #[test]
    fn lru_eviction_order() {
        // 2 sets x 2 ways x 64B = 256B cache. Addresses in the same set
        // differ by 128.
        let mut c = Cache::new(&spec(256, 2), 1);
        assert_eq!(c.access(0, false).0, Lookup::Miss);
        assert_eq!(c.access(128, false).0, Lookup::Miss);
        assert_eq!(c.access(0, false).0, Lookup::Hit); // refresh 0
        assert_eq!(c.access(256, false).0, Lookup::Miss); // evicts 128 (LRU)
        assert_eq!(c.access(0, false).0, Lookup::Hit);
        assert_eq!(c.access(128, false).0, Lookup::Miss);
    }

    #[test]
    fn writeback_counting_and_eviction_propagation() {
        let mut c = Cache::new(&spec(128, 1), 1); // 2 sets, direct mapped
        c.access(0, true); // dirty
        let (_, ev) = c.access(128, false); // evicts dirty line 0
        assert_eq!(c.writebacks, 1);
        let ev = ev.expect("eviction expected");
        assert!(ev.dirty);
        assert_eq!(ev.addr, 0);
        let (_, ev2) = c.access(256, false); // evicts clean 128
        assert_eq!(c.writebacks, 1);
        assert!(!ev2.unwrap().dirty);
    }

    #[test]
    fn mark_dirty_propagation() {
        let mut c = Cache::new(&spec(4096, 4), 1);
        c.access(0, false);
        assert!(c.mark_dirty(0));
        assert!(!c.mark_dirty(64)); // absent line
    }

    #[test]
    fn prefetch_tracking() {
        let mut c = Cache::new(&spec(4096, 4), 1);
        assert!(c.prefetch(0).0);
        assert!(!c.prefetch(0).0); // already present
        assert_eq!(c.access(0, false).0, Lookup::HitPrefetched);
        assert_eq!(c.access(0, false).0, Lookup::Hit); // flag cleared
        assert_eq!(c.prefetch_useful, 1);
        // wasted prefetch: insert then evict before use
        let mut c2 = Cache::new(&spec(128, 1), 1);
        c2.prefetch(0);
        c2.access(128, false); // same set, evicts the prefetched line
        assert_eq!(c2.prefetch_wasted, 1);
    }

    #[test]
    fn capacity_divisor_shrinks() {
        let full = Cache::new(&spec(1 << 20, 8), 1);
        let half = Cache::new(&spec(1 << 20, 8), 2);
        assert_eq!(half.sets * 2, full.sets);
    }

    #[test]
    fn power_of_two_stride_causes_conflicts() {
        // 32 KiB, 8-way, 64B lines: 64 sets. Stride 4096 maps every
        // access to the same set -> only 8 lines retained.
        let mut c = Cache::new(&spec(32 << 10, 8), 1);
        for rep in 0..2 {
            for i in 0..16u64 {
                c.access(i * 4096, false);
            }
            if rep == 0 {
                c.reset_stats();
            }
        }
        assert_eq!(c.hits, 0, "16 conflicting lines in an 8-way set must all miss");
        // Non-power-of-two stride of similar size spreads across sets.
        let mut c2 = Cache::new(&spec(32 << 10, 8), 1);
        for rep in 0..2 {
            for i in 0..16u64 {
                c2.access(i * 4160, false); // 4096 + 64
            }
            if rep == 0 {
                c2.reset_stats();
            }
        }
        assert_eq!(c2.misses, 0, "spread lines must all be retained");
    }
}
