//! Machine models of the paper's test bed (§3). Parameters are taken
//! from the paper where given (clock, core counts, cache sizes/sharing,
//! measured STREAM triad bandwidths) and from contemporary (2009)
//! documentation otherwise (latencies, associativities, TLBs).

/// One cache level.
#[derive(Debug, Clone, Copy)]
pub struct CacheSpec {
    pub size_bytes: usize,
    pub assoc: usize,
    pub line_bytes: usize,
    /// Load-to-use latency in core cycles.
    pub latency_cycles: f64,
    /// Number of cores sharing one instance of this cache.
    pub shared_by: usize,
}

/// A ccNUMA (or UMA) multicore node.
#[derive(Debug, Clone)]
pub struct MachineSpec {
    pub name: &'static str,
    pub freq_ghz: f64,
    pub sockets: usize,
    pub cores_per_socket: usize,
    pub l1: CacheSpec,
    pub l2: CacheSpec,
    pub l3: Option<CacheSpec>,
    /// DRAM (local) access latency in cycles.
    pub dram_latency_cycles: f64,
    /// Sustainable memory bandwidth of one NUMA domain (socket), GB/s.
    /// For the UMA Woodcrest this is the per-socket FSB limit.
    pub socket_bw_gbs: f64,
    /// Whole-node bandwidth ceiling, GB/s (= measured STREAM triad).
    pub node_bw_gbs: f64,
    /// ccNUMA? (false = UMA/FSB: all memory equally distant, shared bus)
    pub numa: bool,
    /// Latency multiplier for remote-domain accesses.
    pub remote_latency_factor: f64,
    /// Bandwidth ceiling of the inter-socket link, GB/s (per direction).
    pub interconnect_bw_gbs: f64,
    /// Data TLB: entry count (4 KiB pages) and miss penalty in cycles.
    pub tlb_entries: usize,
    pub page_bytes: usize,
    pub tlb_miss_cycles: f64,
    /// Memory-level parallelism: outstanding demand misses, and the
    /// (higher) effective depth when the hardware prefetcher runs ahead.
    pub mlp_demand: f64,
    pub mlp_prefetch: f64,
    /// Core-side issue cost per SpMV update (mult-add + address
    /// generation + loads from L1), cycles.
    pub issue_cycles_per_update: f64,
    /// Extra cycles at each inner-loop start (loop control, pipeline
    /// drain). Large on the in-order Itanium2 — the effect that makes
    /// short CRS rows slow on HLRB-II (§5.3).
    pub loop_overhead_cycles: f64,
    /// Hardware prefetcher defaults (paper toggles these on Woodcrest).
    pub sp_default: bool,
    pub ap_default: bool,
}

impl MachineSpec {
    pub fn cores(&self) -> usize {
        self.sockets * self.cores_per_socket
    }

    /// Cycles per second.
    pub fn hz(&self) -> f64 {
        self.freq_ghz * 1e9
    }

    /// Effective per-thread streaming bandwidth cap (GB/s): line size ×
    /// outstanding misses / latency. This is why one thread cannot
    /// saturate a Nehalem/Shanghai socket (§5.1).
    pub fn per_thread_bw_gbs(&self, prefetch_on: bool) -> f64 {
        let mlp = if prefetch_on { self.mlp_prefetch } else { self.mlp_demand };
        let latency_s = self.dram_latency_cycles / self.hz();
        self.l1.line_bytes as f64 * mlp / latency_s / 1e9
    }

    /// Intel Xeon 5160 "Woodcrest": 2 × dual-core, 3.0 GHz, shared 4 MB
    /// L2 per socket, UMA frontside bus, STREAM triad ≈ 6.5 GB/s.
    pub fn woodcrest() -> Self {
        MachineSpec {
            name: "Woodcrest",
            freq_ghz: 3.0,
            sockets: 2,
            cores_per_socket: 2,
            l1: CacheSpec { size_bytes: 32 << 10, assoc: 8, line_bytes: 64, latency_cycles: 3.0, shared_by: 1 },
            l2: CacheSpec { size_bytes: 4 << 20, assoc: 16, line_bytes: 64, latency_cycles: 14.0, shared_by: 2 },
            l3: None,
            dram_latency_cycles: 300.0, // ~100 ns FSB round trip
            socket_bw_gbs: 4.3,         // one socket cannot use the full FSB
            node_bw_gbs: 6.5,           // measured STREAM triad (§3)
            numa: false,
            remote_latency_factor: 1.0, // UMA: no remote distinction
            interconnect_bw_gbs: 6.5,
            tlb_entries: 256,
            page_bytes: 4096,
            tlb_miss_cycles: 30.0,
            mlp_demand: 4.0,
            mlp_prefetch: 8.0,
            issue_cycles_per_update: 2.0,
            loop_overhead_cycles: 4.0,
            sp_default: true,
            ap_default: true,
        }
    }

    /// AMD Opteron 2378 "Shanghai": 2 × quad-core, 2.4 GHz, 6 MB shared
    /// L3 per socket, ccNUMA DDR2-800, STREAM ≈ 20 GB/s per node.
    pub fn shanghai() -> Self {
        MachineSpec {
            name: "Shanghai",
            freq_ghz: 2.4,
            sockets: 2,
            cores_per_socket: 4,
            l1: CacheSpec { size_bytes: 64 << 10, assoc: 2, line_bytes: 64, latency_cycles: 3.0, shared_by: 1 },
            l2: CacheSpec { size_bytes: 512 << 10, assoc: 16, line_bytes: 64, latency_cycles: 12.0, shared_by: 1 },
            l3: Some(CacheSpec { size_bytes: 6 << 20, assoc: 48, line_bytes: 64, latency_cycles: 40.0, shared_by: 4 }),
            dram_latency_cycles: 170.0, // ~70 ns
            socket_bw_gbs: 10.0,
            node_bw_gbs: 20.0, // measured STREAM triad (§3)
            numa: true,
            remote_latency_factor: 1.7,
            interconnect_bw_gbs: 6.0, // HyperTransport
            tlb_entries: 512,
            page_bytes: 4096,
            tlb_miss_cycles: 25.0,
            mlp_demand: 4.0,
            mlp_prefetch: 9.0,
            issue_cycles_per_update: 2.0,
            loop_overhead_cycles: 3.0,
            sp_default: true,
            ap_default: true,
        }
    }

    /// Intel Xeon X5550 "Nehalem": 2 × quad-core, 2.66 GHz, 8 MB shared
    /// L3 per socket, ccNUMA DDR3-1333, STREAM ≈ 35 GB/s per node.
    pub fn nehalem() -> Self {
        MachineSpec {
            name: "Nehalem",
            freq_ghz: 2.66,
            sockets: 2,
            cores_per_socket: 4,
            l1: CacheSpec { size_bytes: 32 << 10, assoc: 8, line_bytes: 64, latency_cycles: 4.0, shared_by: 1 },
            l2: CacheSpec { size_bytes: 256 << 10, assoc: 8, line_bytes: 64, latency_cycles: 10.0, shared_by: 1 },
            l3: Some(CacheSpec { size_bytes: 8 << 20, assoc: 16, line_bytes: 64, latency_cycles: 38.0, shared_by: 4 }),
            dram_latency_cycles: 160.0, // ~60 ns integrated controller
            socket_bw_gbs: 17.5,
            node_bw_gbs: 35.0, // measured STREAM triad (§3)
            numa: true,
            remote_latency_factor: 1.6,
            interconnect_bw_gbs: 11.0, // QPI
            tlb_entries: 512,
            page_bytes: 4096,
            tlb_miss_cycles: 25.0,
            mlp_demand: 5.0,
            mlp_prefetch: 10.0,
            issue_cycles_per_update: 2.0,
            loop_overhead_cycles: 3.0,
            sp_default: true,
            ap_default: true,
        }
    }

    /// One HLRB-II node (SGI Altix 4700 "bandwidth partition"): Itanium2
    /// Montecito, 1.6 GHz, 9 MB L3 per core, two cores per locality
    /// domain (§5.3). Modeled with up to 128 domains; in-order core with
    /// heavy loop startup cost (short CRS inner loops hurt).
    pub fn hlrb2(domains: usize) -> Self {
        MachineSpec {
            name: "HLRB-II",
            freq_ghz: 1.6,
            sockets: domains,
            cores_per_socket: 2,
            l1: CacheSpec { size_bytes: 16 << 10, assoc: 4, line_bytes: 64, latency_cycles: 1.0, shared_by: 1 },
            l2: CacheSpec { size_bytes: 256 << 10, assoc: 8, line_bytes: 128, latency_cycles: 6.0, shared_by: 1 },
            l3: Some(CacheSpec { size_bytes: 9 << 20, assoc: 12, line_bytes: 128, latency_cycles: 14.0, shared_by: 1 }),
            dram_latency_cycles: 300.0, // NUMAlink fabric
            socket_bw_gbs: 8.5,
            node_bw_gbs: 8.5 * domains as f64,
            numa: true,
            remote_latency_factor: 2.5,
            interconnect_bw_gbs: 3.2, // NUMAlink 4 per direction
            tlb_entries: 128,
            page_bytes: 16384, // Itanium larger pages (SGI default 16K)
            tlb_miss_cycles: 40.0,
            mlp_demand: 4.0,
            mlp_prefetch: 8.0,
            issue_cycles_per_update: 2.5,
            // In-order EPIC: software-pipelined long loops are fine, but
            // every loop start/drain costs dearly.
            loop_overhead_cycles: 24.0,
            sp_default: false, // Itanium relies on software prefetch
            ap_default: false,
        }
    }

    pub fn by_name(name: &str) -> anyhow::Result<Self> {
        Ok(match name.to_ascii_lowercase().as_str() {
            "woodcrest" => Self::woodcrest(),
            "shanghai" => Self::shanghai(),
            "nehalem" => Self::nehalem(),
            "hlrb2" | "hlrb-ii" => Self::hlrb2(64),
            other => anyhow::bail!("unknown machine '{other}' (woodcrest|shanghai|nehalem|hlrb2)"),
        })
    }

    pub fn all_x86() -> Vec<Self> {
        vec![Self::woodcrest(), Self::shanghai(), Self::nehalem()]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_have_paper_bandwidths() {
        assert_eq!(MachineSpec::woodcrest().node_bw_gbs, 6.5);
        assert_eq!(MachineSpec::shanghai().node_bw_gbs, 20.0);
        assert_eq!(MachineSpec::nehalem().node_bw_gbs, 35.0);
    }

    #[test]
    fn per_thread_bw_below_socket_bw_on_numa() {
        // One thread must not be able to saturate a socket (§5.1).
        for m in [MachineSpec::shanghai(), MachineSpec::nehalem()] {
            let bw1 = m.per_thread_bw_gbs(true);
            assert!(
                bw1 < m.socket_bw_gbs,
                "{}: one thread {bw1:.1} GB/s must be < socket {:.1}",
                m.name,
                m.socket_bw_gbs
            );
            // ...but 3 threads should reach/saturate it (paper: scales up
            // to three threads per socket).
            assert!(3.0 * bw1 >= m.socket_bw_gbs * 0.95, "{}", m.name);
        }
    }

    #[test]
    fn woodcrest_socket_saturated_by_one_thread() {
        // On Woodcrest a single thread's achievable bandwidth already
        // reaches the per-socket FSB share (§5.1: no gain from the 2nd
        // thread).
        let m = MachineSpec::woodcrest();
        assert!(m.per_thread_bw_gbs(true) >= m.socket_bw_gbs);
    }

    #[test]
    fn by_name_roundtrip() {
        assert_eq!(MachineSpec::by_name("nehalem").unwrap().name, "Nehalem");
        assert_eq!(MachineSpec::by_name("HLRB2").unwrap().name, "HLRB-II");
        assert!(MachineSpec::by_name("pentium").is_err());
    }

    #[test]
    fn core_counts() {
        assert_eq!(MachineSpec::woodcrest().cores(), 4);
        assert_eq!(MachineSpec::nehalem().cores(), 8);
        assert_eq!(MachineSpec::hlrb2(128).cores(), 256);
    }
}
