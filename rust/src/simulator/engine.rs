//! Trace-driven simulation engine: maps kernel walks onto per-thread
//! core models, applies ccNUMA first-touch page placement and OpenMP
//! scheduling, and combines per-thread cycle/traffic accounts into a
//! roofline-style total (compute vs per-thread MLP vs socket/node/link
//! bandwidth — whichever binds).

use crate::engine::SpmvPlan;
use crate::kernels::{IndexPattern, MicroOp, OpKind, SpmvKernel};
use crate::matrix::jds::SpmvVisitor;
use crate::matrix::Scheme;
use crate::sched::{assign, Schedule};
use crate::util::rng::Rng;

use super::core::CoreSim;
use super::topology::MachineSpec;

/// Disjoint address regions for the simulated arrays, 4 GiB apart so the
/// region id is `addr >> 32`.
pub const REGION_SHIFT: u32 = 32;
pub const BASE_VAL: u64 = 1 << REGION_SHIFT;
pub const BASE_COL: u64 = 2 << REGION_SHIFT;
pub const BASE_X: u64 = 3 << REGION_SHIFT;
pub const BASE_Y: u64 = 4 << REGION_SHIFT;
pub const BASE_AUX: u64 = 5 << REGION_SHIFT; // row_ptr / index vector
pub const BASE_A: u64 = 6 << REGION_SHIFT;

/// STREAM-measured bandwidth numbers include only "useful" bytes; with
/// write-allocate the raw transfer is 4/3 higher for triad-like kernels.
/// Our caps act on raw line traffic, so scale the measured figures up.
const WRITE_ALLOCATE_FACTOR: f64 = 4.0 / 3.0;

/// Simulation options.
#[derive(Debug, Clone)]
pub struct SimOptions {
    /// Override machine default prefetcher settings.
    pub sp: Option<bool>,
    pub ap: Option<bool>,
    /// Run one unaccounted warm-up pass before the measured pass
    /// (steady-state solver behaviour; matters when working sets fit in
    /// cache, e.g. HLRB-II §5.3).
    pub warmup: bool,
}

impl Default for SimOptions {
    fn default() -> Self {
        Self { sp: None, ap: None, warmup: true }
    }
}

/// Aggregated result of a simulated kernel execution.
#[derive(Debug, Clone)]
pub struct SimResult {
    pub cycles: f64,
    pub seconds: f64,
    pub updates: u64,
    pub cycles_per_update: f64,
    pub mflops: f64,
    /// Total DRAM traffic (demand + prefetch + writeback), bytes.
    pub dram_bytes: f64,
    /// Fraction of node bandwidth used during the run.
    pub bw_utilization: f64,
    /// Which term bound the runtime: "cpu", "thread-bw", "socket-bw",
    /// "node-bw", "link-bw".
    pub bounded_by: &'static str,
    pub per_thread_cpu_cycles: Vec<f64>,
    pub tlb_misses: u64,
    pub remote_fraction: f64,
}

/// Placement policy for the paper's ccNUMA experiments.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Placement {
    /// Pages homed by a first-touch *parallel* initialization with the
    /// default static schedule (the paper's proper init, §5.2).
    FirstTouchStatic,
    /// All pages on domain 0 (serial initialization — the ccNUMA
    /// anti-pattern).
    Serial,
}

/// Per-region page→domain maps.
struct PlacementMap {
    page_shift: u32,
    /// region id (1..=6) → page domains
    regions: Vec<Vec<u8>>,
}

impl PlacementMap {
    fn new(page_bytes: usize) -> Self {
        PlacementMap {
            page_shift: page_bytes.trailing_zeros(),
            regions: vec![Vec::new(); 7],
        }
    }

    /// Record a first touch (no-op if the page is already homed).
    fn touch(&mut self, addr: u64, domain: u8) {
        let region = (addr >> REGION_SHIFT) as usize;
        let page = ((addr & ((1u64 << REGION_SHIFT) - 1)) >> self.page_shift) as usize;
        let v = &mut self.regions[region];
        if v.len() <= page {
            v.resize(page + 1, u8::MAX);
        }
        if v[page] == u8::MAX {
            v[page] = domain;
        }
    }

    #[inline]
    fn home(&self, addr: u64) -> u8 {
        let region = (addr >> REGION_SHIFT) as usize;
        let page = ((addr & ((1u64 << REGION_SHIFT) - 1)) >> self.page_shift) as usize;
        let v = &self.regions[region];
        if page < v.len() && v[page] != u8::MAX {
            v[page]
        } else {
            0
        }
    }
}

/// Maps one thread's SpMV update stream to memory accesses on its core.
struct SpmvAdapter<'a> {
    core: &'a mut CoreSim,
    placement: &'a PlacementMap,
    machine: &'a MachineSpec,
    /// Row-major schemes (CRS, NUJDS) start an inner loop per row;
    /// diagonal-major schemes start one whenever the vertical run breaks.
    row_major: bool,
    /// CRS reads row_ptr at every row change.
    has_row_ptr: bool,
    prev_row: usize,
    my_thread: u16,
    owner: &'a [u16],
}

impl<'a> SpmvAdapter<'a> {
    #[inline]
    fn touch(&mut self, addr: u64, write: bool) {
        let home = self.placement.home(addr);
        self.core.access(addr, write, home != self.core.domain);
    }
}

impl<'a> SpmvVisitor for SpmvAdapter<'a> {
    #[inline]
    fn update(&mut self, row: usize, j: usize, col: usize) {
        if self.owner[row] != self.my_thread {
            return;
        }
        let new_loop = if self.row_major {
            row != self.prev_row
        } else {
            row != self.prev_row.wrapping_add(1)
        };
        let row_change = row != self.prev_row;
        self.core.issue(self.machine.issue_cycles_per_update);
        if new_loop || self.prev_row == usize::MAX {
            self.core.issue(self.machine.loop_overhead_cycles);
            if self.core.accounting {
                self.core.stats.loop_starts += 1;
            }
        }
        if self.core.accounting {
            self.core.stats.updates += 1;
        }
        // val and col_idx streams
        self.touch(BASE_VAL + (j as u64) * 8, false);
        self.touch(BASE_COL + (j as u64) * 4, false);
        // input vector gather
        self.touch(BASE_X + (col as u64) * 8, false);
        // result vector: register-held within a run of equal rows
        if row_change {
            let ya = BASE_Y + (row as u64) * 8;
            self.touch(ya, false);
            self.touch(ya, true);
            if self.has_row_ptr {
                self.touch(BASE_AUX + (row as u64) * 4, false);
            }
        }
        self.prev_row = row;
    }
}

/// Record which thread first touches each element (for first-touch
/// placement): walks the kernel with the *initialization* assignment.
struct PlacementVisitor<'a> {
    placement: &'a mut PlacementMap,
    owner: &'a [u16],
    domain_of_thread: &'a [u8],
}

impl<'a> SpmvVisitor for PlacementVisitor<'a> {
    #[inline]
    fn update(&mut self, row: usize, j: usize, col: usize) {
        let d = self.domain_of_thread[self.owner[row] as usize];
        self.placement.touch(BASE_VAL + (j as u64) * 8, d);
        self.placement.touch(BASE_COL + (j as u64) * 4, d);
        self.placement.touch(BASE_Y + (row as u64) * 8, d);
        self.placement.touch(BASE_AUX + (row as u64) * 4, d);
        // The input vector is placed like the result vector (x[i] homed
        // with row i — the paper's "placement of the input vector is
        // imperfect by design" for gathers into other threads' partitions).
        self.placement.touch(BASE_X + (row as u64) * 8, d);
        let _ = col;
    }
}

/// Thread→socket pinning: fill each used socket with `threads_per_socket`
/// threads (the paper pins explicitly; §5).
pub fn pin_threads(threads_per_socket: usize, sockets: usize) -> Vec<u8> {
    let mut v = Vec::new();
    for s in 0..sockets {
        for _ in 0..threads_per_socket {
            v.push(s as u8);
        }
    }
    v
}

/// Active sharers of one L2/L3 instance given threads pinned per socket.
fn sharers(machine: &MachineSpec, spec_shared_by: usize, tps: usize) -> usize {
    let instances_per_socket = (machine.cores_per_socket / spec_shared_by).max(1);
    tps.div_ceil(instances_per_socket).clamp(1, spec_shared_by)
}

/// Simulate a (possibly multi-threaded) SpMV on a machine model.
///
/// Thin wrapper: builds the same [`SpmvPlan`] the host engine executes
/// and hands it to [`simulate_spmv_plan`] — one scheduling decision for
/// both measured and simulated runs.
#[allow(clippy::too_many_arguments)]
pub fn simulate_spmv(
    machine: &MachineSpec,
    kernel: &SpmvKernel,
    threads_per_socket: usize,
    sockets_used: usize,
    schedule: Schedule,
    placement_policy: Placement,
    opts: &SimOptions,
) -> SimResult {
    let plan = SpmvPlan::new(kernel, schedule, threads_per_socket * sockets_used);
    simulate_spmv_plan(
        machine,
        kernel,
        &plan,
        threads_per_socket,
        sockets_used,
        placement_policy,
        opts,
    )
}

/// Simulate a partitioned SpMV from a prebuilt execution plan — the
/// plan/execute API shared with the host engine ([`crate::engine`]).
#[allow(clippy::too_many_arguments)]
pub fn simulate_spmv_plan(
    machine: &MachineSpec,
    kernel: &SpmvKernel,
    plan: &SpmvPlan,
    threads_per_socket: usize,
    sockets_used: usize,
    placement_policy: Placement,
    opts: &SimOptions,
) -> SimResult {
    assert!(sockets_used >= 1 && sockets_used <= machine.sockets);
    assert!(threads_per_socket >= 1 && threads_per_socket <= machine.cores_per_socket);
    let domains = pin_threads(threads_per_socket, sockets_used);
    let n_threads = domains.len();
    assert_eq!(
        plan.n_threads, n_threads,
        "plan was built for {} threads, topology pins {n_threads}",
        plan.n_threads
    );
    let nrows = kernel.nrows();
    assert_eq!(plan.nrows, nrows, "plan/kernel row mismatch");

    // Compute-loop assignment comes from the plan.
    let assignment = &plan.assignment;
    // Initialization (first-touch) assignment: default static.
    let init_assignment = assign(Schedule::Static { chunk: None }, nrows, &plan.weights, n_threads);

    // Build page placement.
    let mut placement = PlacementMap::new(machine.page_bytes);
    match placement_policy {
        Placement::Serial => {
            // Everything homed on domain 0: emulate by touching with a
            // single pseudo-thread on domain 0.
            let owner = vec![0u16; nrows];
            let dom = vec![0u8];
            let mut pv = PlacementVisitor {
                placement: &mut placement,
                owner: &owner,
                domain_of_thread: &dom,
            };
            kernel.walk(&mut pv);
        }
        Placement::FirstTouchStatic => {
            let mut pv = PlacementVisitor {
                placement: &mut placement,
                owner: &init_assignment.owner,
                domain_of_thread: &domains,
            };
            kernel.walk(&mut pv);
        }
    }

    // Cores.
    let sp_on = opts.sp.unwrap_or(machine.sp_default);
    let ap_on = opts.ap.unwrap_or(machine.ap_default);
    let l2_sharers = sharers(machine, machine.l2.shared_by, threads_per_socket);
    let l3_sharers = machine
        .l3
        .as_ref()
        .map(|l3| sharers(machine, l3.shared_by, threads_per_socket))
        .unwrap_or(1);
    let mut cores: Vec<CoreSim> = domains
        .iter()
        .map(|&d| CoreSim::new(machine, d, l2_sharers, l3_sharers, sp_on, ap_on))
        .collect();

    let (row_major, has_row_ptr) = match kernel.scheme() {
        Scheme::Crs => (true, true),
        Scheme::NuJds { .. } => (true, false),
        _ => (false, false),
    };

    let passes: &[bool] = if opts.warmup { &[false, true] } else { &[true] };
    for &accounted in passes {
        for (t, core) in cores.iter_mut().enumerate() {
            core.accounting = accounted;
            let mut adapter = SpmvAdapter {
                core,
                placement: &placement,
                machine,
                row_major,
                has_row_ptr,
                prev_row: usize::MAX,
                my_thread: t as u16,
                owner: &assignment.owner,
            };
            kernel.walk(&mut adapter);
        }
    }
    for core in cores.iter_mut() {
        core.harvest_writebacks();
    }

    combine(machine, &domains, &cores, kernel.nnz() as u64 * 2)
}

/// Simulate one of the Table-1 microbenchmarks (single thread).
pub fn simulate_microbench(
    machine: &MachineSpec,
    op: MicroOp,
    n_iters: usize,
    b_len: usize,
    opts: &SimOptions,
    seed: u64,
) -> SimResult {
    let mut rng = Rng::new(seed);
    let b_elems = match op.pattern {
        IndexPattern::Dense => n_iters.max(1),
        IndexPattern::ConstStride(k) => (k * n_iters).max(1),
        _ => b_len.max(1),
    };
    let ind = if op.uses_index_array() {
        crate::kernels::build_index(op.pattern, n_iters, b_elems, &mut rng)
    } else {
        Vec::new()
    };
    let sp_on = opts.sp.unwrap_or(machine.sp_default);
    let ap_on = opts.ap.unwrap_or(machine.ap_default);
    let mut core = CoreSim::new(machine, 0, 1, 1, sp_on, ap_on);
    let placement = PlacementMap::new(machine.page_bytes); // all local

    let passes: &[bool] = if opts.warmup { &[false, true] } else { &[true] };
    for &accounted in passes {
        core.accounting = accounted;
        core.issue(machine.loop_overhead_cycles);
        for i in 0..n_iters {
            core.issue(machine.issue_cycles_per_update);
            if core.accounting {
                core.stats.updates += 1;
            }
            if op.kind == OpKind::Scp {
                let a = BASE_A + (i as u64) * 8;
                core.access(a, false, placement.home(a) != 0);
            }
            let idx = match op.pattern {
                IndexPattern::Dense => i as u64,
                IndexPattern::ConstStride(k) => ((i * k) % b_elems) as u64,
                _ => {
                    let a = BASE_AUX + (i as u64) * 4;
                    core.access(a, false, false);
                    ind[i] as u64
                }
            };
            core.access(BASE_X + idx * 8, false, false);
        }
    }
    core.harvest_writebacks();
    let flops = op.flops_per_iter() * n_iters as u64;
    combine(machine, &[0], std::slice::from_ref(&core), flops)
}

/// Combine per-thread accounts into the total runtime (roofline max).
fn combine(
    machine: &MachineSpec,
    domains: &[u8],
    cores: &[CoreSim],
    flops: u64,
) -> SimResult {
    let hz = machine.hz();
    let line = machine.l1.line_bytes;
    let n_domains = machine.sockets;
    let raw_socket_bpc = machine.socket_bw_gbs * WRITE_ALLOCATE_FACTOR * 1e9 / hz;
    let raw_node_bpc = machine.node_bw_gbs * WRITE_ALLOCATE_FACTOR * 1e9 / hz;
    let link_bpc = machine.interconnect_bw_gbs * WRITE_ALLOCATE_FACTOR * 1e9 / hz;

    let mut t_cpu_max = 0.0f64;
    let mut t_thread_bw_max = 0.0f64;
    let mut per_thread_cpu = Vec::with_capacity(cores.len());
    let mut bytes_total = 0.0f64;
    let mut bytes_remote = 0.0f64;
    let mut bytes_by_requester_socket = vec![0.0f64; n_domains];
    let mut tlb_misses = 0u64;
    let mut updates = 0u64;

    let sp_on = cores
        .first()
        .map(|_| true)
        .unwrap_or(true);
    let bw_thread_bpc = machine.per_thread_bw_gbs(sp_on) * 1e9 / hz;

    for (i, core) in cores.iter().enumerate() {
        let s = &core.stats;
        let t_cpu = s.issue_cycles + s.stall_cycles;
        per_thread_cpu.push(t_cpu);
        t_cpu_max = t_cpu_max.max(t_cpu);
        let bytes = s.dram_bytes(line);
        bytes_total += bytes;
        bytes_remote += s.remote_bytes(line);
        bytes_by_requester_socket[domains[i] as usize] += bytes;
        t_thread_bw_max = t_thread_bw_max.max(bytes / bw_thread_bpc);
        tlb_misses += s.tlb_misses;
        updates += s.updates;
    }

    // Socket bandwidth: for NUMA machines local traffic is served by the
    // requester's own domain (placement makes most traffic local); remote
    // traffic additionally crosses the link. UMA (FSB) machines cap the
    // per-socket bus share and the chipset total.
    let t_socket = bytes_by_requester_socket
        .iter()
        .cloned()
        .fold(0.0, f64::max)
        / raw_socket_bpc;
    let t_node = bytes_total / raw_node_bpc;
    let t_link = if machine.numa && bytes_remote > 0.0 {
        bytes_remote / link_bpc
    } else {
        0.0
    };

    let candidates = [
        (t_cpu_max, "cpu"),
        (t_thread_bw_max, "thread-bw"),
        (t_socket, "socket-bw"),
        (t_node, "node-bw"),
        (t_link, "link-bw"),
    ];
    let (cycles, bounded_by) = candidates
        .iter()
        .cloned()
        .fold((0.0, "cpu"), |acc, c| if c.0 > acc.0 { c } else { acc });

    let seconds = cycles / hz;
    SimResult {
        cycles,
        seconds,
        updates,
        cycles_per_update: if updates > 0 { cycles / updates as f64 } else { 0.0 },
        mflops: if seconds > 0.0 { flops as f64 / seconds / 1e6 } else { 0.0 },
        dram_bytes: bytes_total,
        bw_utilization: if cycles > 0.0 {
            (bytes_total / cycles) / raw_node_bpc
        } else {
            0.0
        },
        bounded_by,
        per_thread_cpu_cycles: per_thread_cpu,
        tlb_misses,
        remote_fraction: if bytes_total > 0.0 { bytes_remote / bytes_total } else { 0.0 },
    }
}

/// Simulated STREAM triad (a[i] = b[i] + s*c[i]) for calibration: the
/// reported *useful* bandwidth (24 B/iter) should match the paper's
/// measured numbers within tolerance.
pub fn simulate_stream_triad(
    machine: &MachineSpec,
    threads_per_socket: usize,
    sockets_used: usize,
    n: usize,
) -> f64 {
    let domains = pin_threads(threads_per_socket, sockets_used);
    let n_threads = domains.len();
    let l2_sharers = sharers(machine, machine.l2.shared_by, threads_per_socket);
    let l3_sharers = machine
        .l3
        .as_ref()
        .map(|l3| sharers(machine, l3.shared_by, threads_per_socket))
        .unwrap_or(1);
    let mut cores: Vec<CoreSim> = domains
        .iter()
        .map(|&d| CoreSim::new(machine, d, l2_sharers, l3_sharers, machine.sp_default, machine.ap_default))
        .collect();
    // Static contiguous partition; first-touch => all local.
    let per = n.div_ceil(n_threads);
    for (t, core) in cores.iter_mut().enumerate() {
        let lo = (t * per).min(n);
        let hi = ((t + 1) * per).min(n);
        for i in lo..hi {
            core.issue(machine.issue_cycles_per_update);
            core.access(BASE_X + (i as u64) * 8, false, false); // b
            core.access(BASE_A + (i as u64) * 8, false, false); // c
            core.access(BASE_Y + (i as u64) * 8, true, false); // a (WA)
        }
    }
    for core in cores.iter_mut() {
        core.harvest_writebacks();
    }
    let r = combine(machine, &domains, &cores, 2 * n as u64);
    // useful bytes: 24 per iteration
    24.0 * n as f64 / r.seconds / 1e9
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen;
    use crate::kernels::SpmvKernel;
    use crate::matrix::Scheme;

    /// A memory-scale banded matrix in the paper's regime (~14 nnz/row,
    /// working set tens of MB — far beyond the LLC). Cached via
    /// `OnceLock` because generation dominates test time.
    fn big_kernel(scheme: Scheme) -> SpmvKernel {
        use std::sync::OnceLock;
        static COO: OnceLock<crate::matrix::Coo> = OnceLock::new();
        let coo = COO.get_or_init(|| {
            let mut rng = crate::util::rng::Rng::new(77);
            gen::random_band(150_000, 14, 3000, &mut rng)
        });
        SpmvKernel::build(coo, scheme)
    }

    #[test]
    fn stream_triad_calibration() {
        // Full-node simulated STREAM must land near the paper's §3
        // numbers (±25%).
        for (m, tps) in [
            (MachineSpec::woodcrest(), 2),
            (MachineSpec::shanghai(), 4),
            (MachineSpec::nehalem(), 4),
        ] {
            let bw = simulate_stream_triad(&m, tps, 2, 2_000_000);
            let expect = m.node_bw_gbs;
            assert!(
                (bw - expect).abs() / expect < 0.25,
                "{}: simulated triad {bw:.1} GB/s vs measured {expect}",
                m.name
            );
        }
    }

    #[test]
    fn spmv_single_thread_is_memory_bound_and_slow() {
        let m = MachineSpec::nehalem();
        let k = big_kernel(Scheme::Crs);
        let r = simulate_spmv(
            &m,
            &k,
            1,
            1,
            Schedule::Static { chunk: None },
            Placement::FirstTouchStatic,
            &SimOptions::default(),
        );
        // far below peak (peak = 4 flop/cycle * 2.66 GHz = 10640 MFlop/s)
        assert!(r.mflops < 2000.0, "mflops {}", r.mflops);
        assert!(r.mflops > 50.0, "mflops {}", r.mflops);
        assert!(r.updates as usize == k.nnz());
    }

    #[test]
    fn multithread_scales_until_bandwidth() {
        let m = MachineSpec::nehalem();
        let k = big_kernel(Scheme::Crs);
        let opts = SimOptions::default();
        let mf: Vec<f64> = [1usize, 2, 4]
            .iter()
            .map(|&tps| {
                simulate_spmv(
                    &m,
                    &k,
                    tps,
                    1,
                    Schedule::Static { chunk: None },
                    Placement::FirstTouchStatic,
                    &opts,
                )
                .mflops
            })
            .collect();
        assert!(mf[1] > mf[0] * 1.3, "2 threads {:.0} vs 1 thread {:.0}", mf[1], mf[0]);
        assert!(mf[2] >= mf[1] * 0.95, "4 threads should not regress");
    }

    #[test]
    fn two_sockets_beat_one_on_numa() {
        let m = MachineSpec::shanghai();
        let k = big_kernel(Scheme::Crs);
        let opts = SimOptions::default();
        let one = simulate_spmv(&m, &k, 4, 1, Schedule::Static { chunk: None }, Placement::FirstTouchStatic, &opts);
        let two = simulate_spmv(&m, &k, 4, 2, Schedule::Static { chunk: None }, Placement::FirstTouchStatic, &opts);
        assert!(
            two.mflops > 1.5 * one.mflops,
            "ccNUMA scaling: 2 sockets {:.0} vs 1 socket {:.0}",
            two.mflops,
            one.mflops
        );
    }

    #[test]
    fn serial_placement_hurts_two_socket_numa() {
        let m = MachineSpec::nehalem();
        let k = big_kernel(Scheme::Crs);
        let opts = SimOptions::default();
        let good = simulate_spmv(&m, &k, 4, 2, Schedule::Static { chunk: None }, Placement::FirstTouchStatic, &opts);
        let bad = simulate_spmv(&m, &k, 4, 2, Schedule::Static { chunk: None }, Placement::Serial, &opts);
        assert!(
            bad.mflops < 0.8 * good.mflops,
            "serial init {:.0} must trail first-touch {:.0}",
            bad.mflops,
            good.mflops
        );
        assert!(bad.remote_fraction > good.remote_fraction);
    }

    #[test]
    fn microbench_dense_faster_than_indirect() {
        let m = MachineSpec::woodcrest();
        let opts = SimOptions::default();
        let n = 200_000;
        let blen = 4_000_000;
        let pd = simulate_microbench(
            &m,
            MicroOp { kind: OpKind::Scp, pattern: IndexPattern::Dense },
            n,
            blen,
            &opts,
            1,
        );
        let ir = simulate_microbench(
            &m,
            MicroOp { kind: OpKind::Scp, pattern: IndexPattern::Geometric { mean: 8.0 } },
            n,
            blen,
            &opts,
            1,
        );
        assert!(
            ir.cycles_per_update > 2.0 * pd.cycles_per_update,
            "IRSCP(k=8) {:.1} cyc must be much slower than PDSCP {:.1} cyc",
            ir.cycles_per_update,
            pd.cycles_per_update
        );
    }
}
