//! Compressed row storage (CRS/CSR) — §2 of the paper.
//!
//! The SpMV inner loop is a sparse scalar product per row: the result stays
//! in a register and is written once per row, giving the 10 bytes/flop
//! algorithmic balance (8 B value + 4 B index per nnz, amortized row
//! pointer and result traffic) that makes CRS the winner on cache
//! architectures (Fig 6b).

use super::{Coo, SpMv};

#[derive(Debug, Clone)]
pub struct Crs {
    pub nrows: usize,
    pub ncols: usize,
    /// Offsets into `val`/`col_idx`; length `nrows + 1`.
    pub row_ptr: Vec<usize>,
    pub col_idx: Vec<u32>,
    pub val: Vec<f64>,
}

impl Crs {
    /// Build from COO (normalizes: sorts row-major, sums duplicates).
    pub fn from_coo(coo: &Coo) -> Self {
        let mut c = coo.clone();
        c.normalize();
        let mut row_ptr = vec![0usize; c.nrows + 1];
        for &(r, _, _) in &c.entries {
            row_ptr[r as usize + 1] += 1;
        }
        for i in 0..c.nrows {
            row_ptr[i + 1] += row_ptr[i];
        }
        let mut col_idx = Vec::with_capacity(c.entries.len());
        let mut val = Vec::with_capacity(c.entries.len());
        for &(_, cidx, v) in &c.entries {
            col_idx.push(cidx);
            val.push(v);
        }
        Crs { nrows: c.nrows, ncols: c.ncols, row_ptr, col_idx, val }
    }

    /// Non-zeros in row `i` as (col, val) slices.
    pub fn row(&self, i: usize) -> (&[u32], &[f64]) {
        let (a, b) = (self.row_ptr[i], self.row_ptr[i + 1]);
        (&self.col_idx[a..b], &self.val[a..b])
    }

    /// Mean non-zeros per row.
    pub fn avg_nnz_per_row(&self) -> f64 {
        self.val.len() as f64 / self.nrows.max(1) as f64
    }

    /// SpMV restricted to a row range — the unit of work for OpenMP-style
    /// loop scheduling in the parallel experiments (§5).
    #[inline]
    pub fn spmv_rows(&self, x: &[f64], y: &mut [f64], row_begin: usize, row_end: usize) {
        self.spmv_rows_into(row_begin, row_end, x, &mut y[row_begin..row_end]);
    }

    /// Range-restricted kernel for the parallel engine: computes rows
    /// `[row_begin, row_end)` into `out[i - row_begin]`, so disjoint row
    /// partitions can write through disjoint output slices.
    #[inline]
    pub fn spmv_rows_into(&self, row_begin: usize, row_end: usize, x: &[f64], out: &mut [f64]) {
        debug_assert_eq!(out.len(), row_end - row_begin);
        for i in row_begin..row_end {
            let (a, b) = (self.row_ptr[i], self.row_ptr[i + 1]);
            let mut sum = 0.0;
            for j in a..b {
                // Safety: col_idx entries are validated < ncols at build.
                sum += self.val[j] * x[self.col_idx[j] as usize];
            }
            out[i - row_begin] = sum;
        }
    }

    /// Convert back to COO.
    pub fn to_coo(&self) -> Coo {
        let mut coo = Coo::with_capacity(self.nrows, self.ncols, self.val.len());
        for i in 0..self.nrows {
            for j in self.row_ptr[i]..self.row_ptr[i + 1] {
                coo.push(i, self.col_idx[j] as usize, self.val[j]);
            }
        }
        coo
    }

    /// Bytes touched per SpMV under the paper's traffic model:
    /// 12 B per nnz (val + col_idx) + 8 B per input-vector element read
    /// (best case) + 8+4 B per row (result write + row_ptr).
    pub fn min_bytes_per_spmv(&self) -> u64 {
        (12 * self.val.len() + 8 * self.ncols + 12 * self.nrows) as u64
    }
}

impl SpMv for Crs {
    fn nrows(&self) -> usize {
        self.nrows
    }
    fn ncols(&self) -> usize {
        self.ncols
    }
    fn nnz(&self) -> usize {
        self.val.len()
    }
    fn spmv(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.ncols);
        assert_eq!(y.len(), self.nrows);
        self.spmv_rows(x, y, 0, self.nrows);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_coo(rng: &mut Rng, n: usize, nnz: usize) -> Coo {
        let mut coo = Coo::new(n, n);
        for _ in 0..nnz {
            coo.push(rng.index(n), rng.index(n), rng.f64() * 2.0 - 1.0);
        }
        coo.normalize();
        coo
    }

    #[test]
    fn from_coo_sorted_rows() {
        let mut rng = Rng::new(1);
        let coo = random_coo(&mut rng, 50, 300);
        let crs = Crs::from_coo(&coo);
        assert_eq!(crs.row_ptr.len(), 51);
        assert_eq!(*crs.row_ptr.last().unwrap(), crs.nnz());
        for i in 0..50 {
            let (cols, _) = crs.row(i);
            for w in cols.windows(2) {
                assert!(w[0] < w[1], "columns must be strictly increasing");
            }
        }
    }

    #[test]
    fn spmv_matches_coo() {
        let mut rng = Rng::new(2);
        for trial in 0..20 {
            let n = 10 + rng.index(90);
            let coo = random_coo(&mut rng, n, n * 5);
            let crs = Crs::from_coo(&coo);
            let mut x = vec![0.0; n];
            rng.fill_f64(&mut x, -1.0, 1.0);
            let mut y1 = vec![0.0; n];
            let mut y2 = vec![0.0; n];
            coo.spmv(&x, &mut y1);
            crs.spmv(&x, &mut y2);
            let d = crate::util::stats::max_abs_diff(&y1, &y2);
            assert!(d < 1e-12, "trial {trial}: diff {d}");
        }
    }

    #[test]
    fn empty_rows_are_fine() {
        let mut coo = Coo::new(4, 4);
        coo.push(0, 0, 1.0);
        coo.push(3, 3, 2.0);
        let crs = Crs::from_coo(&coo);
        let x = [1.0, 1.0, 1.0, 1.0];
        let mut y = [9.0; 4];
        crs.spmv(&x, &mut y);
        assert_eq!(y, [1.0, 0.0, 0.0, 2.0]);
    }

    #[test]
    fn coo_roundtrip() {
        let mut rng = Rng::new(3);
        let coo = random_coo(&mut rng, 30, 100);
        let crs = Crs::from_coo(&coo);
        let back = Crs::from_coo(&crs.to_coo());
        assert_eq!(back.row_ptr, crs.row_ptr);
        assert_eq!(back.col_idx, crs.col_idx);
        assert_eq!(back.val, crs.val);
    }

    #[test]
    fn partial_rows_spmv() {
        let mut rng = Rng::new(4);
        let coo = random_coo(&mut rng, 40, 200);
        let crs = Crs::from_coo(&coo);
        let mut x = vec![0.0; 40];
        rng.fill_f64(&mut x, -1.0, 1.0);
        let mut y_full = vec![0.0; 40];
        crs.spmv(&x, &mut y_full);
        let mut y_parts = vec![0.0; 40];
        crs.spmv_rows(&x, &mut y_parts, 0, 13);
        crs.spmv_rows(&x, &mut y_parts, 13, 40);
        assert!(crate::util::stats::max_abs_diff(&y_full, &y_parts) < 1e-15);
    }
}
