//! Sparse matrix storage schemes from §2 of the paper.
//!
//! - [`coo`]: coordinate triples — the assembly/interchange format.
//! - [`crs`]: compressed row storage — the cache-architecture workhorse
//!   (10 bytes/flop algorithmic balance).
//! - [`jds`]: jagged diagonals storage — the vector-architecture layout
//!   (18 bytes/flop), shared by the JDS / NBJDS / NUJDS access schemes.
//! - [`blocked`]: the paper's refined layouts RBJDS (block-consecutive
//!   storage) and SOJDS (stride-sorted block storage).
//! - [`io`]: MatrixMarket read/write.
//!
//! All formats store values as `f64` and column indices as `u32`, matching
//! the 8-byte value + 4-byte index assumption behind the paper's balance
//! numbers.

pub mod blocked;
pub mod ell;
pub mod coo;
pub mod crs;
pub mod io;
pub mod jds;

pub use blocked::{RbJds, SoJds};
pub use coo::Coo;
pub use ell::EllMatrix;
pub use crs::Crs;
pub use jds::Jds;

/// The storage/access scheme taxonomy of the paper (§2, Fig 1).
///
/// JDS, NBJDS and NUJDS share the *storage* layout of [`Jds`] and differ in
/// access pattern only; RBJDS and SOJDS change the storage order itself.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scheme {
    /// Compressed row storage.
    Crs,
    /// Plain jagged diagonals: diagonal-major traversal.
    Jds,
    /// JDS with outer (diagonal) loop unrolling by the given factor.
    NuJds { unroll: usize },
    /// JDS blocked over rows with the given block size.
    NbJds { block: usize },
    /// Block-reordered JDS storage (elements of a block stored
    /// consecutively), given block size.
    RbJds { block: usize },
    /// Stride-sorted block JDS storage, given block size.
    SoJds { block: usize },
}

impl Scheme {
    pub fn name(&self) -> String {
        match self {
            Scheme::Crs => "CRS".to_string(),
            Scheme::Jds => "JDS".to_string(),
            Scheme::NuJds { unroll } => format!("NUJDS(u={unroll})"),
            Scheme::NbJds { block } => format!("NBJDS(b={block})"),
            Scheme::RbJds { block } => format!("RBJDS(b={block})"),
            Scheme::SoJds { block } => format!("SOJDS(b={block})"),
        }
    }

    /// Parse e.g. "crs", "jds", "nbjds:1000", "nujds:2".
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        let (name, param) = match s.split_once(':') {
            Some((n, p)) => (n, Some(p.parse::<usize>()?)),
            None => (s, None),
        };
        Ok(match name.to_ascii_lowercase().as_str() {
            "crs" | "csr" => Scheme::Crs,
            "jds" => Scheme::Jds,
            "nujds" => Scheme::NuJds { unroll: param.unwrap_or(2) },
            "nbjds" => Scheme::NbJds { block: param.unwrap_or(1000) },
            "rbjds" => Scheme::RbJds { block: param.unwrap_or(1000) },
            "sojds" => Scheme::SoJds { block: param.unwrap_or(1000) },
            other => anyhow::bail!("unknown scheme '{other}'"),
        })
    }

    /// All schemes evaluated in Fig 6/7, with a given block/unroll choice.
    pub fn all_with(block: usize, unroll: usize) -> Vec<Scheme> {
        vec![
            Scheme::Crs,
            Scheme::Jds,
            Scheme::NuJds { unroll },
            Scheme::NbJds { block },
            Scheme::RbJds { block },
            Scheme::SoJds { block },
        ]
    }
}

impl std::fmt::Display for Scheme {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.name())
    }
}

/// Matrix-vector product interface implemented by every storage scheme.
pub trait SpMv {
    fn nrows(&self) -> usize;
    fn ncols(&self) -> usize;
    fn nnz(&self) -> usize;
    /// y = A x. `x.len() == ncols`, `y.len() == nrows`. Overwrites `y`.
    fn spmv(&self, x: &[f64], y: &mut [f64]);
    /// Flops per SpMV (2 per stored non-zero; padding does not count).
    fn flops(&self) -> u64 {
        2 * self.nnz() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scheme_parse_roundtrip() {
        assert_eq!(Scheme::parse("crs").unwrap(), Scheme::Crs);
        assert_eq!(Scheme::parse("CSR").unwrap(), Scheme::Crs);
        assert_eq!(Scheme::parse("nbjds:64").unwrap(), Scheme::NbJds { block: 64 });
        assert_eq!(Scheme::parse("nujds:4").unwrap(), Scheme::NuJds { unroll: 4 });
        assert!(Scheme::parse("bogus").is_err());
    }

    #[test]
    fn scheme_names() {
        assert_eq!(Scheme::Crs.name(), "CRS");
        assert_eq!(Scheme::NbJds { block: 1000 }.name(), "NBJDS(b=1000)");
    }
}
