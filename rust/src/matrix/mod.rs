//! Sparse matrix storage schemes: §2 of the paper plus the post-paper
//! SELL-C-σ layout.
//!
//! - [`blocked`]: the paper's refined layouts RBJDS (block-consecutive
//!   storage) and SOJDS (stride-sorted block storage).
//! - [`coo`]: coordinate triples — the assembly/interchange format.
//! - [`crs`]: compressed row storage — the cache-architecture workhorse
//!   (10 bytes/flop algorithmic balance).
//! - [`ell`]: padded JDS (ELL) — the dense-plane interchange format
//!   between the Rust coordinator and the AOT-compiled Pallas kernel.
//! - [`io`]: MatrixMarket read/write.
//! - [`jds`]: jagged diagonals storage — the vector-architecture layout
//!   (18 bytes/flop), shared by the JDS / NBJDS / NUJDS access schemes.
//! - [`sell`]: SELL-C-σ — sliced, σ-window-sorted ELL (Kreutzer et al.
//!   2013), the modern successor of the JDS refinements and the layout
//!   the parallel execution engine targets; plus [`SellRect`], the
//!   rectangular row-sorted-only variant used for shard halves.
//! - [`shard`]: row-sharded CRS with per-shard local/remote halves and
//!   halo index maps (arXiv:1106.5908) — the storage side of the
//!   distributed-style SpMV in [`crate::shard`].
//!
//! All formats store values as `f64` and column indices as `u32`, matching
//! the 8-byte value + 4-byte index assumption behind the paper's balance
//! numbers.

pub mod blocked;
pub mod coo;
pub mod crs;
pub mod ell;
pub mod io;
pub mod jds;
pub mod sell;
pub mod shard;

pub use blocked::{RbJds, SoJds};
pub use coo::Coo;
pub use crs::Crs;
pub use ell::EllMatrix;
pub use jds::Jds;
pub use sell::{SellCs, SellRect};
pub use shard::{ShardCrs, ShardedCrs};

/// The storage/access scheme taxonomy of the paper (§2, Fig 1), extended
/// with SELL-C-σ.
///
/// JDS, NBJDS and NUJDS share the *storage* layout of [`Jds`] and differ in
/// access pattern only; RBJDS and SOJDS change the storage order itself.
///
/// # SELL-C-σ and the padding-vs-locality trade-off
///
/// [`Scheme::SellCs`] cuts the matrix into slices of `c` rows, each padded
/// to its own longest row, after sorting rows by length within windows of
/// `sigma` rows. The two parameters span a design space:
///
/// - **σ = 1** keeps the original row order: gather locality of the input
///   vector is untouched, but a single long row inflates its whole slice
///   (padding overhead up to `c × max_len / nnz`).
/// - **σ = nrows** is a full JDS-style sort: slices are length-uniform and
///   padding is minimal, but the symmetric permutation scrambles the
///   input-vector access pattern (the paper's Fig 6a effect).
/// - In between, σ (a small multiple of `c`, e.g. `σ = 8·c`) keeps the
///   permutation local to σ-row neighbourhoods while removing most
///   padding — the setting Kreutzer et al. recommend and the default
///   here. `SellCs::padding_overhead` reports the realized cost.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scheme {
    /// Compressed row storage.
    Crs,
    /// Plain jagged diagonals: diagonal-major traversal.
    Jds,
    /// JDS with outer (diagonal) loop unrolling by the given factor.
    NuJds { unroll: usize },
    /// JDS blocked over rows with the given block size.
    NbJds { block: usize },
    /// Block-reordered JDS storage (elements of a block stored
    /// consecutively), given block size.
    RbJds { block: usize },
    /// Stride-sorted block JDS storage, given block size.
    SoJds { block: usize },
    /// SELL-C-σ: slice height `c`, sort window `sigma`.
    SellCs { c: usize, sigma: usize },
}

impl Scheme {
    pub fn name(&self) -> String {
        match self {
            Scheme::Crs => "CRS".to_string(),
            Scheme::Jds => "JDS".to_string(),
            Scheme::NuJds { unroll } => format!("NUJDS(u={unroll})"),
            Scheme::NbJds { block } => format!("NBJDS(b={block})"),
            Scheme::RbJds { block } => format!("RBJDS(b={block})"),
            Scheme::SoJds { block } => format!("SOJDS(b={block})"),
            Scheme::SellCs { c, sigma } => format!("SELL-{c}-{sigma}"),
        }
    }

    /// Canonical parseable spec string: `Scheme::parse(&s.spec()) == s`.
    pub fn spec(&self) -> String {
        match self {
            Scheme::Crs => "crs".to_string(),
            Scheme::Jds => "jds".to_string(),
            Scheme::NuJds { unroll } => format!("nujds:{unroll}"),
            Scheme::NbJds { block } => format!("nbjds:{block}"),
            Scheme::RbJds { block } => format!("rbjds:{block}"),
            Scheme::SoJds { block } => format!("sojds:{block}"),
            Scheme::SellCs { c, sigma } => format!("sellcs:{c}:{sigma}"),
        }
    }

    /// Parse e.g. "crs", "jds", "nbjds:1000", "nujds:2", "sellcs:32:256".
    /// SELL-C-σ defaults: c = 32; σ = 8·c when omitted. Surplus
    /// parameters are an error, not silently dropped.
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        let mut parts = s.split(':');
        let name = parts.next().unwrap_or("");
        let params = parts
            .map(|p| p.trim().parse::<usize>())
            .collect::<Result<Vec<usize>, _>>()?;
        let p0 = params.first().copied();
        let name = name.trim().to_ascii_lowercase();
        let max_params = match name.as_str() {
            "crs" | "csr" | "jds" => 0,
            "nujds" | "nbjds" | "rbjds" | "sojds" => 1,
            "sellcs" | "sell" => 2,
            _ => usize::MAX, // unknown name: the match below reports it
        };
        anyhow::ensure!(
            params.len() <= max_params,
            "scheme '{name}' takes at most {max_params} parameter(s), got {} in '{s}'",
            params.len()
        );
        Ok(match name.as_str() {
            "crs" | "csr" => Scheme::Crs,
            "jds" => Scheme::Jds,
            "nujds" => Scheme::NuJds { unroll: p0.unwrap_or(2) },
            "nbjds" => Scheme::NbJds { block: p0.unwrap_or(1000) },
            "rbjds" => Scheme::RbJds { block: p0.unwrap_or(1000) },
            "sojds" => Scheme::SoJds { block: p0.unwrap_or(1000) },
            "sellcs" | "sell" => {
                let c = p0.unwrap_or(32).max(1);
                let sigma = params.get(1).copied().unwrap_or(8 * c).max(1);
                Scheme::SellCs { c, sigma }
            }
            other => anyhow::bail!("unknown scheme '{other}'"),
        })
    }

    /// The paper's scheme set of Fig 6/7, with a given block/unroll choice.
    pub fn all_with(block: usize, unroll: usize) -> Vec<Scheme> {
        vec![
            Scheme::Crs,
            Scheme::Jds,
            Scheme::NuJds { unroll },
            Scheme::NbJds { block },
            Scheme::RbJds { block },
            Scheme::SoJds { block },
        ]
    }

    /// Every scheme including SELL-C-σ — the set the parallel engine and
    /// its tests/benches sweep.
    pub fn all_extended(block: usize, unroll: usize, c: usize, sigma: usize) -> Vec<Scheme> {
        let mut v = Self::all_with(block, unroll);
        v.push(Scheme::SellCs { c, sigma });
        v
    }
}

impl std::fmt::Display for Scheme {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.name())
    }
}

/// Matrix-vector product interface implemented by every storage scheme.
pub trait SpMv {
    fn nrows(&self) -> usize;
    fn ncols(&self) -> usize;
    fn nnz(&self) -> usize;
    /// y = A x. `x.len() == ncols`, `y.len() == nrows`. Overwrites `y`.
    fn spmv(&self, x: &[f64], y: &mut [f64]);
    /// Flops per SpMV (2 per stored non-zero; padding does not count).
    fn flops(&self) -> u64 {
        2 * self.nnz() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scheme_parse_roundtrip() {
        assert_eq!(Scheme::parse("crs").unwrap(), Scheme::Crs);
        assert_eq!(Scheme::parse("CSR").unwrap(), Scheme::Crs);
        assert_eq!(Scheme::parse("nbjds:64").unwrap(), Scheme::NbJds { block: 64 });
        assert_eq!(Scheme::parse("nujds:4").unwrap(), Scheme::NuJds { unroll: 4 });
        assert!(Scheme::parse("bogus").is_err());
    }

    #[test]
    fn sellcs_parse_roundtrip() {
        assert_eq!(
            Scheme::parse("sellcs:32:256").unwrap(),
            Scheme::SellCs { c: 32, sigma: 256 }
        );
        assert_eq!(
            Scheme::parse("sell:8").unwrap(),
            Scheme::SellCs { c: 8, sigma: 64 }
        );
        assert_eq!(
            Scheme::parse("sellcs").unwrap(),
            Scheme::SellCs { c: 32, sigma: 256 }
        );
        assert!(Scheme::parse("sellcs:0:x").is_err());
    }

    #[test]
    fn surplus_parameters_are_rejected() {
        assert!(Scheme::parse("crs:1").is_err());
        assert!(Scheme::parse("nbjds:1000:5").is_err());
        assert!(Scheme::parse("sellcs:32:256:7").is_err());
        assert!(Scheme::parse("bogus:1:2:3").is_err());
    }

    #[test]
    fn spec_roundtrips_for_all_schemes() {
        for s in Scheme::all_extended(1000, 2, 32, 256) {
            let spec = s.spec();
            assert_eq!(Scheme::parse(&spec).unwrap(), s, "spec '{spec}'");
        }
    }

    #[test]
    fn scheme_names() {
        assert_eq!(Scheme::Crs.name(), "CRS");
        assert_eq!(Scheme::NbJds { block: 1000 }.name(), "NBJDS(b=1000)");
        assert_eq!(Scheme::SellCs { c: 32, sigma: 256 }.name(), "SELL-32-256");
    }
}
