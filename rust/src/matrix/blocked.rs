//! The paper's refined JDS storage layouts (§2, Fig 1):
//!
//! - **RBJDS** ("reordered blocked JDS"): NBJDS cuts jagged diagonals into
//!   row blocks, but accessing a new diagonal inside a block *skips*
//!   through `val`/`col_idx`. RBJDS stores all elements of a block
//!   consecutively, so the kernel streams `val`/`col_idx` with stride one.
//! - **SOJDS** ("sorted JDS"): same block-consecutive layout, but within
//!   each row the assignment of elements to jagged-diagonal slots is
//!   re-sorted so that, walking down a jagged-diagonal column of a block,
//!   the input vector is accessed with stride one (or as close as
//!   possible).

use super::jds::{Compute, SpmvVisitor};
use super::{Coo, Crs, SpMv};

/// Block-consecutive JDS storage. Shared by RBJDS and SOJDS (which differ
/// only in the within-row element-to-slot assignment chosen at build).
#[derive(Debug, Clone)]
pub struct RbJds {
    pub nrows: usize,
    pub ncols: usize,
    pub block: usize,
    /// `perm[new] = old` (same convention as [`Jds`]).
    pub perm: Vec<u32>,
    pub inv_perm: Vec<u32>,
    /// Per-diagonal lengths (non-increasing); defines block coverage.
    pub diag_len: Vec<usize>,
    /// Offset into `val`/`col_idx` where each block's elements begin;
    /// length `n_blocks + 1`.
    pub block_ptr: Vec<usize>,
    /// Column indices in the permuted basis, block-consecutive order.
    pub col_idx: Vec<u32>,
    pub val: Vec<f64>,
}

impl RbJds {
    /// Build from permuted per-row (col, val) lists (lengths
    /// non-increasing), laying elements out block-consecutively.
    fn from_rows(
        nrows: usize,
        ncols: usize,
        block: usize,
        perm: Vec<u32>,
        inv_perm: Vec<u32>,
        rows: &[Vec<(u32, f64)>],
    ) -> Self {
        assert!(block > 0);
        let nnz: usize = rows.iter().map(|r| r.len()).sum();
        let max_nnz = rows.first().map_or(0, |r| r.len());
        let mut diag_len = vec![0usize; max_nnz];
        for row in rows {
            for d in 0..row.len() {
                diag_len[d] += 1;
            }
        }
        debug_assert!(diag_len.windows(2).all(|w| w[0] >= w[1]));
        let longest = diag_len.first().copied().unwrap_or(0);
        let mut block_ptr = vec![0usize];
        let mut col_idx = Vec::with_capacity(nnz);
        let mut val = Vec::with_capacity(nnz);
        let mut b0 = 0;
        while b0 < longest {
            let b1 = (b0 + block).min(longest);
            for (d, &len) in diag_len.iter().enumerate() {
                if len <= b0 {
                    break;
                }
                let end = b1.min(len);
                for row in rows.iter().take(end).skip(b0) {
                    let (c, v) = row[d];
                    col_idx.push(c);
                    val.push(v);
                }
            }
            block_ptr.push(col_idx.len());
            b0 = b1;
        }
        RbJds { nrows, ncols, block, perm, inv_perm, diag_len, block_ptr, col_idx, val }
    }

    /// RBJDS: keep each row's ascending-column order (as plain JDS does).
    pub fn from_crs(crs: &Crs, block: usize) -> Self {
        let (perm, inv_perm, rows) = permuted_rows(crs);
        Self::from_rows(crs.nrows, crs.ncols, block, perm, inv_perm, &rows)
    }

    pub fn from_coo(coo: &Coo, block: usize) -> Self {
        Self::from_crs(&Crs::from_coo(coo), block)
    }

    pub fn n_diag(&self) -> usize {
        self.diag_len.len()
    }

    pub fn n_blocks(&self) -> usize {
        self.block_ptr.len() - 1
    }

    /// Walk in storage order: per block, per diagonal, down the rows.
    /// `val`/`col_idx` are touched with stride one throughout — the whole
    /// point of the layout.
    pub fn walk<V: SpmvVisitor>(&self, v: &mut V) {
        let longest = self.diag_len.first().copied().unwrap_or(0);
        let mut ptr = 0usize;
        let mut b0 = 0;
        while b0 < longest {
            let b1 = (b0 + self.block).min(longest);
            for &len in &self.diag_len {
                if len <= b0 {
                    break;
                }
                let end = b1.min(len);
                for i in b0..end {
                    v.update(i, ptr, self.col_idx[ptr] as usize);
                    ptr += 1;
                }
            }
            b0 = b1;
        }
        debug_assert_eq!(ptr, self.val.len());
    }

    pub fn permute_vec(&self, x: &[f64]) -> Vec<f64> {
        self.perm.iter().map(|&old| x[old as usize]).collect()
    }

    pub fn unpermute_vec(&self, yp: &[f64], y: &mut [f64]) {
        for (new, &old) in self.perm.iter().enumerate() {
            y[old as usize] = yp[new];
        }
    }

    /// Permuted-basis kernel.
    pub fn spmv_permuted(&self, xp: &[f64], yp: &mut [f64]) {
        let mut c = Compute::new(&self.val, xp, yp);
        self.walk(&mut c);
        c.finish();
    }

    /// Range-restricted permuted-basis kernel for the parallel engine:
    /// computes permuted rows `[row_begin, row_end)` into
    /// `out[i - row_begin]`, touching only the blocks that intersect the
    /// range and skipping over non-intersecting diagonal segments in the
    /// block-consecutive storage. Per-row accumulation order (ascending
    /// diagonal) matches the serial kernel, including its register runs:
    /// in a block of width > 1, diagonal segments that cover only the
    /// block's first row emit it consecutively, so the serial
    /// [`Compute`] visitor pre-sums them before a single flush —
    /// replicated via `tail_acc` so results are identical.
    pub fn spmv_rows_permuted(&self, row_begin: usize, row_end: usize, xp: &[f64], out: &mut [f64]) {
        debug_assert_eq!(out.len(), row_end - row_begin);
        out.fill(0.0);
        let longest = self.diag_len.first().copied().unwrap_or(0);
        let mut bi = row_begin / self.block;
        loop {
            let b0 = bi * self.block;
            if b0 >= longest || b0 >= row_end {
                break;
            }
            let b1 = (b0 + self.block).min(longest);
            let width = b1 - b0;
            let lo = row_begin.max(b0);
            let hi = row_end.min(b1);
            let mut seg_start = self.block_ptr[bi];
            let mut tail_acc = 0.0;
            for &len in &self.diag_len {
                if len <= b0 {
                    break;
                }
                let end = b1.min(len);
                // Rows b0..end of this diagonal occupy
                // seg_start..seg_start + (end - b0) consecutively.
                let e = hi.min(end);
                for i in lo..e {
                    let off = seg_start + (i - b0);
                    let p = self.val[off] * xp[self.col_idx[off] as usize];
                    if width > 1 && i == b0 && len == b0 + 1 {
                        tail_acc += p; // register run onto the block's first row
                    } else {
                        out[i - row_begin] += p;
                    }
                }
                seg_start += end - b0;
            }
            if width > 1 && b0 >= row_begin && b0 < row_end {
                out[b0 - row_begin] += tail_acc;
            }
            bi += 1;
        }
    }
}

impl SpMv for RbJds {
    fn nrows(&self) -> usize {
        self.nrows
    }
    fn ncols(&self) -> usize {
        self.ncols
    }
    fn nnz(&self) -> usize {
        self.val.len()
    }
    fn spmv(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.ncols);
        assert_eq!(y.len(), self.nrows);
        let xp = self.permute_vec(x);
        let mut yp = vec![0.0; self.nrows];
        self.spmv_permuted(&xp, &mut yp);
        self.unpermute_vec(&yp, y);
    }
}

/// SOJDS: block-consecutive storage with stride-optimized within-row
/// element ordering.
#[derive(Debug, Clone)]
pub struct SoJds(pub RbJds);

impl SoJds {
    pub fn from_crs(crs: &Crs, block: usize) -> Self {
        let (perm, inv_perm, mut rows) = permuted_rows(crs);
        sort_rows_for_stride(&mut rows, block);
        SoJds(RbJds::from_rows(crs.nrows, crs.ncols, block, perm, inv_perm, &rows))
    }

    pub fn from_coo(coo: &Coo, block: usize) -> Self {
        Self::from_crs(&Crs::from_coo(coo), block)
    }

    pub fn walk<V: SpmvVisitor>(&self, v: &mut V) {
        self.0.walk(v)
    }

    pub fn spmv_permuted(&self, xp: &[f64], yp: &mut [f64]) {
        self.0.spmv_permuted(xp, yp)
    }

    pub fn spmv_rows_permuted(&self, row_begin: usize, row_end: usize, xp: &[f64], out: &mut [f64]) {
        self.0.spmv_rows_permuted(row_begin, row_end, xp, out)
    }
}

impl SpMv for SoJds {
    fn nrows(&self) -> usize {
        self.0.nrows
    }
    fn ncols(&self) -> usize {
        self.0.ncols
    }
    fn nnz(&self) -> usize {
        self.0.val.len()
    }
    fn spmv(&self, x: &[f64], y: &mut [f64]) {
        self.0.spmv(x, y)
    }
}

/// JDS row permutation shared by all JDS-family builders: returns
/// (perm, inv_perm, permuted rows as (col, val) lists with ascending
/// columns in the permuted basis and non-increasing lengths).
fn permuted_rows(crs: &Crs) -> (Vec<u32>, Vec<u32>, Vec<Vec<(u32, f64)>>) {
    assert_eq!(crs.nrows, crs.ncols, "JDS-family formats require a square matrix");
    let n = crs.nrows;
    let mut order: Vec<u32> = (0..n as u32).collect();
    order.sort_by_key(|&i| {
        let i = i as usize;
        std::cmp::Reverse(crs.row_ptr[i + 1] - crs.row_ptr[i])
    });
    let perm = order;
    let mut inv_perm = vec![0u32; n];
    for (new, &old) in perm.iter().enumerate() {
        inv_perm[old as usize] = new as u32;
    }
    let rows: Vec<Vec<(u32, f64)>> = perm
        .iter()
        .map(|&old| {
            let (cols, vals) = crs.row(old as usize);
            let mut row: Vec<(u32, f64)> = cols
                .iter()
                .zip(vals)
                .map(|(&c, &v)| (inv_perm[c as usize], v))
                .collect();
            row.sort_unstable_by_key(|&(c, _)| c);
            row
        })
        .collect();
    (perm, inv_perm, rows)
}

/// The SOJDS ordering pass: within each block, re-order each row's
/// elements across jagged-diagonal slots so that column indices along a
/// slot are as close to stride one as possible (§2).
///
/// Starting from the ascending-column baseline (= RBJDS), rows are swept
/// top-down and element pairs within a row are swapped whenever the total
/// within-slot stride deviation (to both vertical neighbours) decreases.
/// Monotone improvement guarantees the SOJDS objective is never worse
/// than the RBJDS baseline — matching the paper's observation that the
/// resulting stride distribution barely changes for matrices whose rows
/// are already quantile-aligned (Fig 6a).
fn sort_rows_for_stride(rows: &mut [Vec<(u32, f64)>], block: usize) {
    // Deviation of row r's slot-d column from a stride-1 continuation of
    // its vertical neighbour in the same slot.
    #[inline]
    fn dev(up: Option<u32>, c: u32) -> i64 {
        match up {
            Some(u) => (c as i64 - u as i64 - 1).abs(),
            None => 0,
        }
    }
    let n = rows.len();
    let mut b0 = 0;
    while b0 < n {
        let b1 = (b0 + block).min(n);
        for _pass in 0..4 {
            let mut improved = false;
            for r in b0..b1 {
                let len = rows[r].len();
                if len < 2 {
                    continue;
                }
                for d1 in 0..len {
                    for d2 in (d1 + 1)..len {
                        // Vertical neighbours for slots d1/d2 (prev row has
                        // a slot d iff its length > d; rows above are
                        // longer, rows below shorter within a block).
                        let above = |d: usize| -> Option<u32> {
                            if r > b0 && rows[r - 1].len() > d {
                                Some(rows[r - 1][d].0)
                            } else {
                                None
                            }
                        };
                        let below = |d: usize| -> Option<u32> {
                            if r + 1 < b1 && rows[r + 1].len() > d {
                                Some(rows[r + 1][d].0)
                            } else {
                                None
                            }
                        };
                        let (c1, c2) = (rows[r][d1].0, rows[r][d2].0);
                        let cost = |a: u32, b: u32| -> i64 {
                            dev(above(d1), a)
                                + dev(above(d2), b)
                                + below(d1).map_or(0, |c| (c as i64 - a as i64 - 1).abs())
                                + below(d2).map_or(0, |c| (c as i64 - b as i64 - 1).abs())
                        };
                        if cost(c2, c1) < cost(c1, c2) {
                            rows[r].swap(d1, d2);
                            improved = true;
                        }
                    }
                }
            }
            if !improved {
                break;
            }
        }
        b0 = b1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::matrix::jds::SpmvVisitor;
    use crate::util::rng::Rng;
    use crate::util::stats::max_abs_diff;

    fn random_square(rng: &mut Rng, n: usize, nnz: usize) -> Crs {
        let mut coo = Coo::new(n, n);
        for _ in 0..nnz {
            coo.push(rng.index(n), rng.index(n), rng.f64() * 2.0 - 1.0);
        }
        coo.normalize();
        Crs::from_coo(&coo)
    }

    #[test]
    fn rbjds_matches_crs_for_blocks() {
        let mut rng = Rng::new(20);
        let n = 100;
        let crs = random_square(&mut rng, n, n * 6);
        let mut x = vec![0.0; n];
        rng.fill_f64(&mut x, -1.0, 1.0);
        let mut y_ref = vec![0.0; n];
        crs.spmv(&x, &mut y_ref);
        for block in [1, 3, 16, 99, 100, 5000] {
            let rb = RbJds::from_crs(&crs, block);
            assert_eq!(rb.nnz(), crs.nnz(), "block {block}");
            let mut y = vec![0.0; n];
            rb.spmv(&x, &mut y);
            assert!(max_abs_diff(&y_ref, &y) < 1e-12, "block {block}");
        }
    }

    #[test]
    fn sojds_matches_crs_for_blocks() {
        let mut rng = Rng::new(21);
        let n = 100;
        let crs = random_square(&mut rng, n, n * 6);
        let mut x = vec![0.0; n];
        rng.fill_f64(&mut x, -1.0, 1.0);
        let mut y_ref = vec![0.0; n];
        crs.spmv(&x, &mut y_ref);
        for block in [1, 8, 50, 100, 1000] {
            let so = SoJds::from_crs(&crs, block);
            assert_eq!(so.nnz(), crs.nnz());
            let mut y = vec![0.0; n];
            so.spmv(&x, &mut y);
            assert!(max_abs_diff(&y_ref, &y) < 1e-12, "block {block}");
        }
    }

    #[test]
    fn rbjds_storage_is_walked_sequentially() {
        let mut rng = Rng::new(22);
        let crs = random_square(&mut rng, 64, 300);
        let rb = RbJds::from_crs(&crs, 16);
        struct Seq {
            next: usize,
            ok: bool,
        }
        impl SpmvVisitor for Seq {
            fn update(&mut self, _row: usize, j: usize, _col: usize) {
                self.ok &= j == self.next;
                self.next += 1;
            }
        }
        let mut s = Seq { next: 0, ok: true };
        rb.walk(&mut s);
        assert!(s.ok, "RBJDS must touch val/col_idx with stride one");
        assert_eq!(s.next, rb.nnz());
    }

    #[test]
    fn rbjds_block_ptr_consistent() {
        let mut rng = Rng::new(23);
        let crs = random_square(&mut rng, 64, 400);
        let rb = RbJds::from_crs(&crs, 10);
        assert_eq!(*rb.block_ptr.last().unwrap(), rb.nnz());
        assert!(rb.block_ptr.windows(2).all(|w| w[0] <= w[1]));
        // 64 rows sorted by nnz; longest diag = #rows with >=1 nnz <= 64
        assert!(rb.n_blocks() >= 1);
    }

    #[test]
    fn sojds_improves_slot_stride() {
        // On a matrix with shuffled within-row columns, SOJDS should make
        // column sequences along each slot no worse (typically better)
        // than the ascending-order RBJDS baseline.
        let mut rng = Rng::new(24);
        let n = 200;
        let crs = random_square(&mut rng, n, n * 8);
        let block = 50;
        let rb = RbJds::from_crs(&crs, block);
        let so = SoJds::from_crs(&crs, block);
        // Sum |col - (col_above + 1)| over vertical neighbours within a
        // jagged-diagonal slot — exactly the quantity SOJDS minimizes.
        // Both layouts share block/diagonal structure, so the same set of
        // transitions (row == prev_row + 1) is measured for both.
        fn total_jump(m: &RbJds) -> i64 {
            struct Jump {
                prev: Option<(usize, usize)>,
                total: i64,
            }
            impl SpmvVisitor for Jump {
                fn update(&mut self, row: usize, _j: usize, col: usize) {
                    if let Some((prow, pcol)) = self.prev {
                        if row == prow + 1 {
                            self.total += (col as i64 - pcol as i64 - 1).abs();
                        }
                    }
                    self.prev = Some((row, col));
                }
            }
            let mut j = Jump { prev: None, total: 0 };
            m.walk(&mut j);
            j.total
        }
        let jump_rb = total_jump(&rb);
        let jump_so = total_jump(&so.0);
        assert!(
            jump_so <= jump_rb,
            "SOJDS total stride deviation {jump_so} should not exceed RBJDS {jump_rb}"
        );
    }

    #[test]
    fn range_restricted_kernel_matches_serial_exactly() {
        let mut rng = Rng::new(25);
        let n = 120;
        let crs = random_square(&mut rng, n, n * 6);
        let mut xp = vec![0.0; n];
        rng.fill_f64(&mut xp, -1.0, 1.0);
        for block in [1, 7, 16, 120, 1000] {
            let rb = RbJds::from_crs(&crs, block);
            let mut serial = vec![0.0; n];
            rb.spmv_permuted(&xp, &mut serial);
            let mut pieced = vec![0.0; n];
            for (a, b) in [(0usize, 5usize), (5, 64), (64, 65), (65, n)] {
                let (head, _) = pieced.split_at_mut(b);
                rb.spmv_rows_permuted(a, b, &xp, &mut head[a..]);
            }
            assert_eq!(
                crate::util::stats::max_abs_diff(&serial, &pieced),
                0.0,
                "block {block}"
            );
        }
    }

    #[test]
    fn empty_and_tiny() {
        let coo = Coo::new(3, 3);
        let rb = RbJds::from_coo(&coo, 2);
        assert_eq!(rb.nnz(), 0);
        let mut y = vec![1.0; 3];
        rb.spmv(&[1.0, 1.0, 1.0], &mut y);
        assert_eq!(y, vec![0.0; 3]);

        let mut one = Coo::new(1, 1);
        one.push(0, 0, 5.0);
        let so = SoJds::from_coo(&one, 4);
        let mut y = vec![0.0];
        so.spmv(&[2.0], &mut y);
        assert_eq!(y, vec![10.0]);
    }
}
