//! MatrixMarket (.mtx) coordinate-format reader/writer, so test matrices
//! can be exchanged with external tools. Supports `matrix coordinate
//! real/integer/pattern general/symmetric`.

use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::Coo;

/// Write a COO matrix as MatrixMarket `coordinate real general`.
pub fn write_matrix_market(coo: &Coo, path: &Path) -> Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let f = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    let mut w = BufWriter::new(f);
    writeln!(w, "%%MatrixMarket matrix coordinate real general")?;
    writeln!(w, "% written by spmvperf")?;
    writeln!(w, "{} {} {}", coo.nrows, coo.ncols, coo.nnz())?;
    for &(r, c, v) in &coo.entries {
        writeln!(w, "{} {} {:.17e}", r + 1, c + 1, v)?;
    }
    Ok(())
}

/// Read a MatrixMarket file into COO. Symmetric matrices are expanded to
/// general storage (both triangles materialized).
pub fn read_matrix_market(path: &Path) -> Result<Coo> {
    let f = std::fs::File::open(path)
        .with_context(|| format!("opening {}", path.display()))?;
    let mut lines = BufReader::new(f).lines();

    let header = lines
        .next()
        .context("empty file")??
        .to_ascii_lowercase();
    if !header.starts_with("%%matrixmarket") {
        bail!("not a MatrixMarket file: bad header");
    }
    let toks: Vec<&str> = header.split_whitespace().collect();
    if toks.len() < 5 || toks[1] != "matrix" || toks[2] != "coordinate" {
        bail!("unsupported MatrixMarket header '{header}' (need matrix coordinate)");
    }
    let field = toks[3]; // real | integer | pattern
    let symmetry = toks[4]; // general | symmetric
    if !matches!(field, "real" | "integer" | "pattern") {
        bail!("unsupported field type '{field}'");
    }
    if !matches!(symmetry, "general" | "symmetric") {
        bail!("unsupported symmetry '{symmetry}'");
    }

    // Skip comments, read size line.
    let size_line = loop {
        let line = lines.next().context("missing size line")??;
        let t = line.trim();
        if t.is_empty() || t.starts_with('%') {
            continue;
        }
        break t.to_string();
    };
    let dims: Vec<usize> = size_line
        .split_whitespace()
        .map(|s| s.parse::<usize>().context("bad size line"))
        .collect::<Result<_>>()?;
    if dims.len() != 3 {
        bail!("size line must have 3 fields, got '{size_line}'");
    }
    let (nrows, ncols, nnz) = (dims[0], dims[1], dims[2]);
    let mut coo = Coo::with_capacity(nrows, ncols, nnz);
    let mut read = 0usize;
    for line in lines {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('%') {
            continue;
        }
        let mut it = t.split_whitespace();
        let r: usize = it.next().context("missing row")?.parse()?;
        let c: usize = it.next().context("missing col")?.parse()?;
        let v: f64 = match field {
            "pattern" => 1.0,
            _ => it.next().context("missing value")?.parse()?,
        };
        if r < 1 || r > nrows || c < 1 || c > ncols {
            bail!("entry ({r},{c}) out of bounds {nrows}x{ncols}");
        }
        coo.push(r - 1, c - 1, v);
        if symmetry == "symmetric" && r != c {
            coo.push(c - 1, r - 1, v);
        }
        read += 1;
    }
    if read != nnz {
        bail!("expected {nnz} entries, found {read}");
    }
    Ok(coo)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("spmvperf-io-tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn roundtrip_general() {
        let mut rng = Rng::new(1);
        let mut coo = Coo::new(20, 30);
        for _ in 0..100 {
            coo.push(rng.index(20), rng.index(30), rng.f64() * 10.0 - 5.0);
        }
        coo.normalize();
        let p = tmpfile("rt.mtx");
        write_matrix_market(&coo, &p).unwrap();
        let back = read_matrix_market(&p).unwrap();
        assert_eq!(back.nrows, 20);
        assert_eq!(back.ncols, 30);
        assert_eq!(back.nnz(), coo.nnz());
        let d1 = coo.to_dense();
        let d2 = back.to_dense();
        for i in 0..20 {
            for j in 0..30 {
                assert!((d1[i][j] - d2[i][j]).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn reads_symmetric_and_pattern() {
        let p = tmpfile("sym.mtx");
        std::fs::write(
            &p,
            "%%MatrixMarket matrix coordinate real symmetric\n3 3 2\n2 1 5.0\n3 3 1.0\n",
        )
        .unwrap();
        let m = read_matrix_market(&p).unwrap();
        assert_eq!(m.nnz(), 3); // off-diagonal mirrored
        let d = m.to_dense();
        assert_eq!(d[1][0], 5.0);
        assert_eq!(d[0][1], 5.0);

        let p2 = tmpfile("pat.mtx");
        std::fs::write(
            &p2,
            "%%MatrixMarket matrix coordinate pattern general\n2 2 1\n1 2\n",
        )
        .unwrap();
        let m2 = read_matrix_market(&p2).unwrap();
        assert_eq!(m2.to_dense()[0][1], 1.0);
    }

    #[test]
    fn rejects_garbage() {
        let p = tmpfile("bad.mtx");
        std::fs::write(&p, "hello world\n").unwrap();
        assert!(read_matrix_market(&p).is_err());

        let p2 = tmpfile("oob.mtx");
        std::fs::write(
            &p2,
            "%%MatrixMarket matrix coordinate real general\n2 2 1\n5 5 1.0\n",
        )
        .unwrap();
        assert!(read_matrix_market(&p2).is_err());
    }

    #[test]
    fn rejects_count_mismatch() {
        let p = tmpfile("cnt.mtx");
        std::fs::write(
            &p,
            "%%MatrixMarket matrix coordinate real general\n2 2 2\n1 1 1.0\n",
        )
        .unwrap();
        assert!(read_matrix_market(&p).is_err());
    }
}
