//! Padded-JDS (ELL) storage: the interchange format between the Rust
//! coordinator and the AOT-compiled JAX/Pallas kernel.
//!
//! Rows are JDS-permuted (non-increasing non-zero counts) and each
//! jagged diagonal is padded to the full matrix dimension, yielding two
//! dense `(D, N)` planes (`val`, `col`) that map directly onto the
//! Pallas kernel's VMEM tiles. Padding slots have `val = 0`, `col = 0`.

use super::{Crs, Jds, SpMv};

#[derive(Debug, Clone)]
pub struct EllMatrix {
    pub n: usize,
    /// Number of (padded) diagonals = max non-zeros per row, possibly
    /// padded up to an artifact's static depth.
    pub d: usize,
    /// Row-major `(d, n)`: `val[dd * n + i]`.
    pub val: Vec<f64>,
    /// Row-major `(d, n)`, permuted-basis column indices.
    pub col: Vec<i32>,
    /// `perm[new] = old` row permutation (same convention as [`Jds`]).
    pub perm: Vec<u32>,
}

impl EllMatrix {
    /// Pack from CRS. `pad_d`: pad the diagonal count up to this depth
    /// (required to match a fixed artifact shape); must be >= the true
    /// max row count.
    pub fn from_crs(crs: &Crs, pad_d: Option<usize>) -> anyhow::Result<Self> {
        let jds = Jds::from_crs(crs);
        let n = jds.nrows;
        let true_d = jds.n_diag();
        let d = match pad_d {
            Some(p) => {
                anyhow::ensure!(
                    p >= true_d,
                    "matrix needs {true_d} diagonals but artifact depth is {p}"
                );
                p
            }
            None => true_d,
        };
        let mut val = vec![0.0; d * n];
        let mut col = vec![0i32; d * n];
        for dd in 0..true_d {
            let off = jds.jd_ptr[dd];
            let len = jds.diag_len(dd);
            for i in 0..len {
                val[dd * n + i] = jds.val[off + i];
                col[dd * n + i] = jds.col_idx[off + i] as i32;
            }
        }
        Ok(EllMatrix { n, d, val, col, perm: jds.perm })
    }

    /// Gather a vector into the permuted basis.
    pub fn permute_vec(&self, x: &[f64]) -> Vec<f64> {
        self.perm.iter().map(|&old| x[old as usize]).collect()
    }

    /// Scatter a permuted-basis vector back.
    pub fn unpermute_vec(&self, yp: &[f64], y: &mut [f64]) {
        for (new, &old) in self.perm.iter().enumerate() {
            y[old as usize] = yp[new];
        }
    }

    /// Native ELL SpMV in the permuted basis (reference / fallback for
    /// the runtime executor).
    pub fn spmv_permuted(&self, xp: &[f64], yp: &mut [f64]) {
        assert_eq!(xp.len(), self.n);
        assert_eq!(yp.len(), self.n);
        yp.fill(0.0);
        for dd in 0..self.d {
            let base = dd * self.n;
            for i in 0..self.n {
                yp[i] += self.val[base + i] * xp[self.col[base + i] as usize];
            }
        }
    }

    /// Range-restricted permuted-basis kernel for the parallel engine:
    /// computes permuted rows `[row_begin, row_end)` into
    /// `out[i - row_begin]`. Per-row accumulation order (ascending
    /// diagonal, padding included) matches [`EllMatrix::spmv_permuted`],
    /// so partitioned and serial runs agree exactly.
    pub fn spmv_rows_permuted(&self, row_begin: usize, row_end: usize, xp: &[f64], out: &mut [f64]) {
        debug_assert!(row_end <= self.n);
        debug_assert_eq!(out.len(), row_end - row_begin);
        for i in row_begin..row_end {
            let mut acc = 0.0;
            for dd in 0..self.d {
                let idx = dd * self.n + i;
                acc += self.val[idx] * xp[self.col[idx] as usize];
            }
            out[i - row_begin] = acc;
        }
    }

    /// Stored non-zeros (excluding padding).
    pub fn nnz(&self) -> usize {
        self.val.iter().filter(|&&v| v != 0.0).count()
    }
}

impl SpMv for EllMatrix {
    fn nrows(&self) -> usize {
        self.n
    }
    fn ncols(&self) -> usize {
        self.n
    }
    fn nnz(&self) -> usize {
        EllMatrix::nnz(self)
    }
    fn spmv(&self, x: &[f64], y: &mut [f64]) {
        let xp = self.permute_vec(x);
        let mut yp = vec![0.0; self.n];
        self.spmv_permuted(&xp, &mut yp);
        self.unpermute_vec(&yp, y);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen;
    use crate::matrix::Coo;
    use crate::util::rng::Rng;
    use crate::util::stats::max_abs_diff;

    #[test]
    fn ell_matches_crs_spmv() {
        let mut rng = Rng::new(60);
        let mut coo = Coo::new(50, 50);
        for _ in 0..300 {
            coo.push(rng.index(50), rng.index(50), rng.f64() - 0.5);
        }
        coo.normalize();
        let crs = Crs::from_coo(&coo);
        let ell = EllMatrix::from_crs(&crs, None).unwrap();
        let mut x = vec![0.0; 50];
        rng.fill_f64(&mut x, -1.0, 1.0);
        let mut y1 = vec![0.0; 50];
        let mut y2 = vec![0.0; 50];
        crs.spmv(&x, &mut y1);
        ell.spmv(&x, &mut y2);
        assert!(max_abs_diff(&y1, &y2) < 1e-12);
    }

    #[test]
    fn padding_depth_respected() {
        let h = gen::holstein_hubbard(&gen::HolsteinHubbardParams::tiny());
        let crs = Crs::from_coo(&h);
        let ell = EllMatrix::from_crs(&crs, Some(24)).unwrap();
        assert_eq!(ell.d, 24);
        assert_eq!(ell.n, 540);
        // too-small padding must fail
        assert!(EllMatrix::from_crs(&crs, Some(2)).is_err());
        // padded result still correct
        let mut rng = Rng::new(61);
        let mut x = vec![0.0; 540];
        rng.fill_f64(&mut x, -1.0, 1.0);
        let mut y1 = vec![0.0; 540];
        let mut y2 = vec![0.0; 540];
        crs.spmv(&x, &mut y1);
        ell.spmv(&x, &mut y2);
        assert!(max_abs_diff(&y1, &y2) < 1e-12);
    }

    #[test]
    fn range_restricted_kernel_matches_full() {
        let mut rng = Rng::new(62);
        let mut coo = Coo::new(60, 60);
        for _ in 0..400 {
            coo.push(rng.index(60), rng.index(60), rng.f64() - 0.5);
        }
        coo.normalize();
        let ell = EllMatrix::from_crs(&Crs::from_coo(&coo), None).unwrap();
        let mut xp = vec![0.0; 60];
        rng.fill_f64(&mut xp, -1.0, 1.0);
        let mut full = vec![0.0; 60];
        ell.spmv_permuted(&xp, &mut full);
        let mut pieced = vec![0.0; 60];
        for (a, b) in [(0usize, 17usize), (17, 40), (40, 60)] {
            let (head, _) = pieced.split_at_mut(b);
            ell.spmv_rows_permuted(a, b, &xp, &mut head[a..]);
        }
        assert_eq!(max_abs_diff(&full, &pieced), 0.0);
    }

    #[test]
    fn nnz_excludes_padding() {
        let mut coo = Coo::new(3, 3);
        coo.push(0, 0, 1.0);
        coo.push(1, 2, 2.0);
        let crs = Crs::from_coo(&coo);
        let ell = EllMatrix::from_crs(&crs, Some(5)).unwrap();
        assert_eq!(SpMv::nnz(&ell), 2);
        assert_eq!(ell.val.len(), 15);
    }
}
