//! SELL-C-σ storage — the modern successor of the paper's JDS
//! refinements (Kreutzer, Hager, Wellein, Fehske, Bishop 2013: *"A
//! unified sparse matrix data format for efficient general sparse
//! matrix-vector multiply on modern processors with wide SIMD units"*).
//!
//! Rows are sorted by descending non-zero count **within windows of σ
//! rows** (σ = `sigma`), then cut into **slices of C rows** (C = `c`).
//! Each slice is padded to the width of its longest row and stored
//! column-major within the slice, so a SIMD unit (or the engine's
//! per-thread loop) streams `val`/`col_idx` with stride one while C rows
//! advance in lockstep — the paper's NBJDS blocking and RBJDS
//! block-consecutive storage rolled into one layout.
//!
//! The σ knob trades permutation locality against padding: σ = 1 keeps
//! the original row order (padding up to the slice maximum, like a
//! per-slice ELL), σ = nrows is a full JDS sort (minimal padding, fully
//! scrambled gather locality). `padding_overhead` quantifies the cost.
//!
//! Like the JDS family, rows and columns are permuted symmetrically so
//! all kernels run in the permuted basis; [`SpMv`] wraps gather/scatter.

use super::jds::SpmvVisitor;
use super::{Coo, Crs, SpMv};
use crate::util::alloc::AlignedVec;

/// A matrix in SELL-C-σ storage.
#[derive(Debug, Clone)]
pub struct SellCs {
    pub nrows: usize,
    pub ncols: usize,
    /// Slice height C.
    pub c: usize,
    /// Sort-window size σ.
    pub sigma: usize,
    /// `perm[new] = old` (same convention as [`super::Jds`]).
    pub perm: Vec<u32>,
    /// `inv_perm[old] = new`.
    pub inv_perm: Vec<u32>,
    /// Offset of each slice into `val`/`col_idx`; length `n_slices + 1`.
    pub slice_ptr: Vec<usize>,
    /// Width (padded row length) of each slice.
    pub slice_width: Vec<usize>,
    /// Non-zeros per permuted row (distinguishes entries from padding).
    pub row_nnz: Vec<u32>,
    /// Column indices in the permuted basis; padding slots hold 0.
    /// 64-byte-aligned so SIMD lane groups start on a cache-line /
    /// full-vector boundary ([`crate::kernels::simd`]); the kernels
    /// still use unaligned-tolerant loads (partial slices offset them).
    pub col_idx: AlignedVec<u32>,
    /// Values; padding slots hold 0.0. Aligned like `col_idx`.
    pub val: AlignedVec<f64>,
    nnz: usize,
}

impl SellCs {
    /// Build from CRS with slice height `c` and sort window `sigma`.
    /// Requires a square matrix (rows and columns are permuted
    /// symmetrically, as in the JDS family).
    pub fn from_crs(crs: &Crs, c: usize, sigma: usize) -> Self {
        assert!(c > 0, "SELL-C-σ slice height must be positive");
        assert!(sigma > 0, "SELL-C-σ sort window must be positive");
        assert_eq!(crs.nrows, crs.ncols, "SELL-C-σ requires a square matrix");
        let n = crs.nrows;

        // Sort rows by descending nnz within each σ window (stable).
        let mut perm: Vec<u32> = (0..n as u32).collect();
        for win in perm.chunks_mut(sigma) {
            win.sort_by_key(|&i| {
                let i = i as usize;
                std::cmp::Reverse(crs.row_ptr[i + 1] - crs.row_ptr[i])
            });
        }
        let mut inv_perm = vec![0u32; n];
        for (new, &old) in perm.iter().enumerate() {
            inv_perm[old as usize] = new as u32;
        }

        // Permuted rows with relabeled, ascending columns.
        let rows: Vec<Vec<(u32, f64)>> = perm
            .iter()
            .map(|&old| {
                let (cols, vals) = crs.row(old as usize);
                let mut row: Vec<(u32, f64)> = cols
                    .iter()
                    .zip(vals)
                    .map(|(&cc, &v)| (inv_perm[cc as usize], v))
                    .collect();
                row.sort_unstable_by_key(|&(cc, _)| cc);
                row
            })
            .collect();
        let row_nnz: Vec<u32> = rows.iter().map(|r| r.len() as u32).collect();

        // Pack slices column-major, padded to the slice maximum.
        let n_slices = n.div_ceil(c);
        let mut slice_ptr = Vec::with_capacity(n_slices + 1);
        let mut slice_width = Vec::with_capacity(n_slices);
        slice_ptr.push(0);
        let mut col_idx = Vec::new();
        let mut val = Vec::new();
        for s in 0..n_slices {
            let lo = s * c;
            let hi = ((s + 1) * c).min(n);
            let h = hi - lo;
            let w = rows[lo..hi].iter().map(|r| r.len()).max().unwrap_or(0);
            for k in 0..w {
                for row in &rows[lo..hi] {
                    if let Some(&(cc, v)) = row.get(k) {
                        col_idx.push(cc);
                        val.push(v);
                    } else {
                        col_idx.push(0);
                        val.push(0.0);
                    }
                }
            }
            debug_assert_eq!(col_idx.len() - slice_ptr[s], w * h);
            slice_ptr.push(col_idx.len());
            slice_width.push(w);
        }

        SellCs {
            nrows: n,
            ncols: crs.ncols,
            c,
            sigma,
            perm,
            inv_perm,
            slice_ptr,
            slice_width,
            row_nnz,
            col_idx: AlignedVec::from(col_idx),
            val: AlignedVec::from(val),
            nnz: crs.nnz(),
        }
    }

    pub fn from_coo(coo: &Coo, c: usize, sigma: usize) -> Self {
        Self::from_crs(&Crs::from_coo(coo), c, sigma)
    }

    pub fn n_slices(&self) -> usize {
        self.slice_ptr.len() - 1
    }

    /// Permuted row range `[lo, hi)` of slice `s`.
    #[inline]
    pub fn slice_rows(&self, s: usize) -> (usize, usize) {
        (s * self.c, ((s + 1) * self.c).min(self.nrows))
    }

    /// Stored non-zeros (excluding padding).
    pub fn nnz(&self) -> usize {
        self.nnz
    }

    /// Total stored slots, padding included.
    pub fn padded_len(&self) -> usize {
        self.val.len()
    }

    /// Padding overhead `padded/nnz - 1` — the σ-vs-padding trade-off
    /// metric (0.0 = no padding, as with c = 1 or a fully sorted σ on a
    /// row-uniform matrix).
    pub fn padding_overhead(&self) -> f64 {
        if self.nnz == 0 {
            return 0.0;
        }
        self.padded_len() as f64 / self.nnz as f64 - 1.0
    }

    /// Gather a vector into the permuted basis.
    pub fn permute_vec(&self, x: &[f64]) -> Vec<f64> {
        self.perm.iter().map(|&old| x[old as usize]).collect()
    }

    /// Scatter a permuted-basis vector back.
    pub fn unpermute_vec(&self, yp: &[f64], y: &mut [f64]) {
        for (new, &old) in self.perm.iter().enumerate() {
            y[old as usize] = yp[new];
        }
    }

    /// Permuted-basis SpMV, slice-major (the SIMD-friendly order).
    /// Per-row accumulation order is ascending `k`, identical to
    /// [`SellCs::spmv_rows_permuted`], so serial and engine-partitioned
    /// runs produce identical results.
    pub fn spmv_permuted(&self, xp: &[f64], yp: &mut [f64]) {
        assert_eq!(xp.len(), self.nrows);
        assert_eq!(yp.len(), self.nrows);
        self.spmv_rows_permuted(0, self.nrows, xp, yp);
    }

    /// Range-restricted permuted-basis kernel for the parallel engine:
    /// computes permuted rows `[row_begin, row_end)` into
    /// `out[i - row_begin]`. Touches only those rows' slices.
    pub fn spmv_rows_permuted(&self, row_begin: usize, row_end: usize, xp: &[f64], out: &mut [f64]) {
        debug_assert!(row_end <= self.nrows);
        debug_assert_eq!(out.len(), row_end - row_begin);
        for i in row_begin..row_end {
            let s = i / self.c;
            let (lo, hi) = self.slice_rows(s);
            let h = hi - lo;
            let lane = i - lo;
            let base = self.slice_ptr[s];
            let mut acc = 0.0;
            for k in 0..self.row_nnz[i] as usize {
                let idx = base + k * h + lane;
                acc += self.val[idx] * xp[self.col_idx[idx] as usize];
            }
            out[i - row_begin] = acc;
        }
    }

    /// Drive a visitor over the non-padding entries in storage (slice-
    /// major) order — feeds the simulator and stride analysis.
    pub fn walk<V: SpmvVisitor>(&self, v: &mut V) {
        for s in 0..self.n_slices() {
            let (lo, hi) = self.slice_rows(s);
            let h = hi - lo;
            let base = self.slice_ptr[s];
            for k in 0..self.slice_width[s] {
                for lane in 0..h {
                    let row = lo + lane;
                    if (k as u32) < self.row_nnz[row] {
                        let idx = base + k * h + lane;
                        v.update(row, idx, self.col_idx[idx] as usize);
                    }
                }
            }
        }
    }
}

impl SpMv for SellCs {
    fn nrows(&self) -> usize {
        self.nrows
    }
    fn ncols(&self) -> usize {
        self.ncols
    }
    fn nnz(&self) -> usize {
        SellCs::nnz(self)
    }
    fn spmv(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.ncols);
        assert_eq!(y.len(), self.nrows);
        let xp = self.permute_vec(x);
        let mut yp = vec![0.0; self.nrows];
        self.spmv_permuted(&xp, &mut yp);
        self.unpermute_vec(&yp, y);
    }
}

/// Rectangular, **row-sorted-only** SELL-C-σ for the shard halves of
/// [`crate::matrix::shard::ShardedCrs`].
///
/// A shard's local/remote half is a rectangular matrix (its rows
/// against the owned / concatenated column space), so the square
/// symmetric permutation of [`SellCs`] does not apply. This variant
/// keeps the SELL storage idea — σ-window row sorting, slices of C rows
/// padded to their own widest row, column-major within the slice — but:
///
/// - columns are **not relabeled** (the kernel reads `x` in the half's
///   own index space), and
/// - each row's entries keep their **original CRS order** instead of
///   being re-sorted by column: the remote half interleaves owned and
///   halo columns in ascending *global* order, and re-sorting by the
///   concatenated index would change the accumulation order and break
///   the bit-identity invariant.
///
/// Only rows are permuted; `perm[slot] = original half row` maps kernel
/// output slots back.
#[derive(Debug, Clone)]
pub struct SellRect {
    pub nrows: usize,
    pub ncols: usize,
    pub c: usize,
    pub sigma: usize,
    /// `perm[slot] = original half row`.
    pub perm: Vec<u32>,
    /// Offset of each slice into `val`/`col_idx`; length `n_slices + 1`.
    pub slice_ptr: Vec<usize>,
    /// Width (padded row length) of each slice.
    pub slice_width: Vec<usize>,
    /// Non-zeros per permuted row slot.
    pub row_nnz: Vec<u32>,
    /// Column indices in the half's own space; padding slots hold 0.
    /// 64-byte-aligned like [`SellCs::col_idx`] so the split vector
    /// kernels ([`crate::kernels::simd`]) stream slice storage from a
    /// cache-line / full-vector boundary.
    pub col_idx: AlignedVec<u32>,
    /// Values; padding slots hold 0.0. Aligned like `col_idx`.
    pub val: AlignedVec<f64>,
    nnz: usize,
}

impl SellRect {
    /// Build from a (possibly rectangular) CRS half. Row order within σ
    /// windows is sorted by descending nnz; entry order within each row
    /// is preserved verbatim.
    pub fn from_crs(crs: &Crs, c: usize, sigma: usize) -> Self {
        assert!(c > 0, "SELL slice height must be positive");
        assert!(sigma > 0, "SELL sort window must be positive");
        let n = crs.nrows;
        let mut perm: Vec<u32> = (0..n as u32).collect();
        for win in perm.chunks_mut(sigma) {
            win.sort_by_key(|&i| {
                let i = i as usize;
                std::cmp::Reverse(crs.row_ptr[i + 1] - crs.row_ptr[i])
            });
        }
        let row_nnz: Vec<u32> = perm
            .iter()
            .map(|&old| (crs.row_ptr[old as usize + 1] - crs.row_ptr[old as usize]) as u32)
            .collect();

        let n_slices = n.div_ceil(c);
        let mut slice_ptr = Vec::with_capacity(n_slices + 1);
        let mut slice_width = Vec::with_capacity(n_slices);
        slice_ptr.push(0);
        let mut col_idx = Vec::new();
        let mut val = Vec::new();
        for s in 0..n_slices {
            let lo = s * c;
            let hi = ((s + 1) * c).min(n);
            let h = hi - lo;
            let w = row_nnz[lo..hi].iter().max().copied().unwrap_or(0) as usize;
            for k in 0..w {
                for slot in lo..hi {
                    let old = perm[slot] as usize;
                    if (k as u32) < row_nnz[slot] {
                        let j = crs.row_ptr[old] + k;
                        col_idx.push(crs.col_idx[j]);
                        val.push(crs.val[j]);
                    } else {
                        col_idx.push(0);
                        val.push(0.0);
                    }
                }
            }
            slice_ptr.push(col_idx.len());
            slice_width.push(w);
        }

        SellRect {
            nrows: n,
            ncols: crs.ncols,
            c,
            sigma,
            perm,
            slice_ptr,
            slice_width,
            row_nnz,
            col_idx: AlignedVec::from(col_idx),
            val: AlignedVec::from(val),
            nnz: crs.nnz(),
        }
    }

    pub fn n_slices(&self) -> usize {
        self.slice_ptr.len() - 1
    }

    /// Stored non-zeros (excluding padding).
    pub fn nnz(&self) -> usize {
        self.nnz
    }

    /// Padding overhead `padded/nnz - 1`.
    pub fn padding_overhead(&self) -> f64 {
        if self.nnz == 0 {
            return 0.0;
        }
        self.val.len() as f64 / self.nnz as f64 - 1.0
    }

    /// Range-restricted kernel over permuted row **slots**: computes
    /// slots `[row_begin, row_end)` into `out[i - row_begin]`, reading
    /// `x` in the half's own column space. Per-row accumulation order
    /// is ascending `k` = the original CRS entry order, so output slot
    /// `i` is bit-identical to the serial CRS kernel on half row
    /// `perm[i]`.
    pub fn spmv_rows(&self, row_begin: usize, row_end: usize, x: &[f64], out: &mut [f64]) {
        debug_assert!(row_end <= self.nrows);
        debug_assert_eq!(out.len(), row_end - row_begin);
        for i in row_begin..row_end {
            let s = i / self.c;
            let lo = s * self.c;
            let h = ((s + 1) * self.c).min(self.nrows) - lo;
            let lane = i - lo;
            let base = self.slice_ptr[s];
            let mut acc = 0.0;
            for k in 0..self.row_nnz[i] as usize {
                let idx = base + k * h + lane;
                acc += self.val[idx] * x[self.col_idx[idx] as usize];
            }
            out[i - row_begin] = acc;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen;
    use crate::util::rng::Rng;
    use crate::util::stats::max_abs_diff;

    fn random_square(rng: &mut Rng, n: usize, nnz: usize) -> Crs {
        let mut coo = Coo::new(n, n);
        for _ in 0..nnz {
            coo.push(rng.index(n), rng.index(n), rng.f64() * 2.0 - 1.0);
        }
        coo.normalize();
        Crs::from_coo(&coo)
    }

    #[test]
    fn sell_matches_crs_over_c_sigma_grid() {
        let mut rng = Rng::new(40);
        let n = 150;
        let crs = random_square(&mut rng, n, n * 7);
        let mut x = vec![0.0; n];
        rng.fill_f64(&mut x, -1.0, 1.0);
        let mut y_ref = vec![0.0; n];
        crs.spmv(&x, &mut y_ref);
        for c in [1, 2, 7, 32, 150, 1000] {
            for sigma in [1, 8, 64, 150, 4096] {
                let sell = SellCs::from_crs(&crs, c, sigma);
                assert_eq!(sell.nnz(), crs.nnz(), "c={c} sigma={sigma}");
                let mut y = vec![0.0; n];
                sell.spmv(&x, &mut y);
                assert!(
                    max_abs_diff(&y_ref, &y) < 1e-12,
                    "SELL-{c}-{sigma} disagrees with CRS"
                );
            }
        }
    }

    #[test]
    fn sell_matches_crs_on_holstein_hubbard() {
        let h = gen::holstein_hubbard(&gen::HolsteinHubbardParams::tiny());
        let crs = Crs::from_coo(&h);
        let n = crs.nrows;
        let mut rng = Rng::new(41);
        let mut x = vec![0.0; n];
        rng.fill_f64(&mut x, -1.0, 1.0);
        let mut y_ref = vec![0.0; n];
        crs.spmv(&x, &mut y_ref);
        for (c, sigma) in [(32, 256), (8, 64), (64, 540)] {
            let sell = SellCs::from_crs(&crs, c, sigma);
            let mut y = vec![0.0; n];
            sell.spmv(&x, &mut y);
            assert!(max_abs_diff(&y_ref, &y) < 1e-12, "SELL-{c}-{sigma} on HH");
        }
    }

    #[test]
    fn perm_is_windowed_sort() {
        let mut rng = Rng::new(42);
        let n = 120;
        let crs = random_square(&mut rng, n, n * 5);
        let sigma = 30;
        let sell = SellCs::from_crs(&crs, 8, sigma);
        // perm is a permutation
        let mut seen = vec![false; n];
        for &p in &sell.perm {
            assert!(!seen[p as usize]);
            seen[p as usize] = true;
        }
        // nnz non-increasing within each σ window, and windows keep
        // their original row population.
        for (w, win) in sell.perm.chunks(sigma).enumerate() {
            let counts: Vec<usize> = win
                .iter()
                .map(|&old| crs.row_ptr[old as usize + 1] - crs.row_ptr[old as usize])
                .collect();
            assert!(counts.windows(2).all(|p| p[0] >= p[1]), "window {w} not sorted");
            for &old in win {
                let home = old as usize / sigma;
                assert_eq!(home, w, "row {old} escaped its σ window");
            }
        }
    }

    #[test]
    fn padding_shrinks_as_sigma_grows() {
        // Wider sort windows group similar row lengths into slices, so
        // padding must be monotonically non-increasing in σ (for σ a
        // multiple of C) and minimal at σ = n.
        let mut rng = Rng::new(43);
        let n = 256;
        let crs = random_square(&mut rng, n, n * 6);
        let c = 16;
        let mut prev = f64::INFINITY;
        for sigma in [16, 64, 256] {
            let sell = SellCs::from_crs(&crs, c, sigma);
            let ovh = sell.padding_overhead();
            assert!(
                ovh <= prev + 1e-12,
                "padding overhead grew from {prev:.4} to {ovh:.4} at sigma={sigma}"
            );
            prev = ovh;
        }
        // c = 1 is padding-free regardless of σ.
        let unit = SellCs::from_crs(&crs, 1, 1);
        assert_eq!(unit.padded_len(), unit.nnz());
        assert_eq!(unit.padding_overhead(), 0.0);
    }

    #[test]
    fn walk_touches_every_nnz_once() {
        let mut rng = Rng::new(44);
        let crs = random_square(&mut rng, 100, 600);
        let sell = SellCs::from_crs(&crs, 8, 32);
        struct Count(Vec<u32>, usize);
        impl SpmvVisitor for Count {
            fn update(&mut self, _row: usize, j: usize, _col: usize) {
                self.0[j] += 1;
                self.1 += 1;
            }
        }
        let mut c = Count(vec![0; sell.padded_len()], 0);
        sell.walk(&mut c);
        assert_eq!(c.1, sell.nnz());
        assert!(c.0.iter().all(|&k| k <= 1));
    }

    #[test]
    fn range_restricted_kernel_matches_full() {
        let mut rng = Rng::new(45);
        let n = 131; // deliberately not a multiple of any slice height
        let crs = random_square(&mut rng, n, n * 6);
        let sell = SellCs::from_crs(&crs, 16, 64);
        let mut xp = vec![0.0; n];
        rng.fill_f64(&mut xp, -1.0, 1.0);
        let mut full = vec![0.0; n];
        sell.spmv_permuted(&xp, &mut full);
        let mut pieced = vec![0.0; n];
        for (a, b) in [(0usize, 13usize), (13, 16), (16, 97), (97, n)] {
            let (head, _) = pieced.split_at_mut(b);
            sell.spmv_rows_permuted(a, b, &xp, &mut head[a..]);
        }
        assert_eq!(max_abs_diff(&full, &pieced), 0.0, "must be bit-identical");
    }

    /// ISSUE-6 tentpole: slice storage starts on a 64-byte boundary so
    /// vector kernels stream it cache-line-aligned.
    #[test]
    fn sell_storage_is_simd_aligned() {
        let mut rng = Rng::new(48);
        let crs = random_square(&mut rng, 100, 600);
        let sell = SellCs::from_crs(&crs, 8, 32);
        let a = crate::util::alloc::SIMD_ALIGN;
        assert_eq!(sell.val.as_ptr() as usize % a, 0);
        assert_eq!(sell.col_idx.as_ptr() as usize % a, 0);
    }

    #[test]
    fn empty_matrix() {
        let coo = Coo::new(5, 5);
        let sell = SellCs::from_coo(&coo, 4, 16);
        assert_eq!(sell.nnz(), 0);
        assert_eq!(sell.padded_len(), 0);
        let x = vec![1.0; 5];
        let mut y = vec![9.0; 5];
        sell.spmv(&x, &mut y);
        assert_eq!(y, vec![0.0; 5]);
    }

    /// Rectangular CRS half with more columns than rows: every SellRect
    /// output slot must be bit-identical to the serial CRS kernel on
    /// the row its `perm` names.
    #[test]
    fn sell_rect_slots_bit_identical_to_crs_rows() {
        let mut rng = Rng::new(46);
        let (nrows, ncols) = (90, 140);
        let mut coo = Coo::new(nrows, ncols);
        for _ in 0..nrows * 6 {
            coo.push(rng.index(nrows), rng.index(ncols), rng.f64() * 2.0 - 1.0);
        }
        coo.normalize();
        let crs = Crs::from_coo(&coo);
        let mut x = vec![0.0; ncols];
        rng.fill_f64(&mut x, -1.0, 1.0);
        let mut want = vec![0.0; nrows];
        crs.spmv_rows_into(0, nrows, &x, &mut want);
        for (c, sigma) in [(1, 1), (4, 16), (8, 8), (32, 90), (16, 1000)] {
            let rect = SellRect::from_crs(&crs, c, sigma);
            assert_eq!(rect.nnz(), crs.nnz());
            let mut slots = vec![0.0; nrows];
            rect.spmv_rows(0, nrows, &x, &mut slots);
            for (i, &old) in rect.perm.iter().enumerate() {
                assert_eq!(
                    slots[i], want[old as usize],
                    "SELL-rect {c}/{sigma}: slot {i} (row {old}) not bit-identical"
                );
            }
            // Piecewise dispatch matches the full pass exactly.
            let mut pieced = vec![0.0; nrows];
            for (a, b) in [(0usize, 7usize), (7, 41), (41, nrows)] {
                let (head, _) = pieced.split_at_mut(b);
                rect.spmv_rows(a, b, &x, &mut head[a..]);
            }
            assert_eq!(max_abs_diff(&slots, &pieced), 0.0);
        }
    }

    /// SellRect must pack each row's entries in storage order, NOT
    /// re-sorted by column — the remote shard half depends on it.
    #[test]
    fn sell_rect_preserves_unsorted_entry_order() {
        // Hand-built CRS with deliberately descending column order.
        let crs = Crs {
            nrows: 2,
            ncols: 4,
            row_ptr: vec![0, 3, 4],
            col_idx: vec![3, 1, 0, 2],
            val: vec![1.0, 1e16, -1e16, 2.0],
        };
        let rect = SellRect::from_crs(&crs, 2, 2);
        let x = [1.0, 1.0, 1.0, 1.0];
        let mut want = vec![0.0; 2];
        crs.spmv_rows_into(0, 2, &x, &mut want);
        let mut slots = vec![0.0; 2];
        rect.spmv_rows(0, 2, &x, &mut slots);
        for (i, &old) in rect.perm.iter().enumerate() {
            // Storage order (1.0 + 1e16) - 1e16 == 0.0 in f64, while
            // the column-sorted order (-1e16 + 1e16) + 1.0 == 1.0:
            // bit-equality here proves the storage order survived.
            assert_eq!(slots[i], want[old as usize]);
        }
    }

    /// ISSUE-9 tentpole: SellRect storage is 64-byte aligned like
    /// SellCs, so the split vector kernels stream it from a cache-line
    /// boundary.
    #[test]
    fn sell_rect_storage_is_simd_aligned() {
        let mut rng = Rng::new(49);
        let crs = random_square(&mut rng, 100, 600);
        let rect = SellRect::from_crs(&crs, 8, 32);
        let a = crate::util::alloc::SIMD_ALIGN;
        assert_eq!(rect.val.as_ptr() as usize % a, 0);
        assert_eq!(rect.col_idx.as_ptr() as usize % a, 0);
    }

    #[test]
    fn sell_rect_sigma_windows_and_padding() {
        let mut rng = Rng::new(47);
        let crs = random_square(&mut rng, 128, 900);
        let rect = SellRect::from_crs(&crs, 8, 32);
        // perm is a permutation that keeps rows inside their σ window.
        let mut seen = vec![false; 128];
        for (slot, &old) in rect.perm.iter().enumerate() {
            assert!(!seen[old as usize]);
            seen[old as usize] = true;
            assert_eq!(slot / 32, old as usize / 32, "row escaped its σ window");
        }
        // Wider σ ⇒ no more padding (same argument as SellCs).
        let tight = SellRect::from_crs(&crs, 8, 8);
        let full = SellRect::from_crs(&crs, 8, 128);
        assert!(full.padding_overhead() <= tight.padding_overhead() + 1e-12);
        // Empty half degenerates cleanly.
        let empty = SellRect::from_crs(
            &Crs { nrows: 0, ncols: 7, row_ptr: vec![0], col_idx: vec![], val: vec![] },
            8,
            8,
        );
        assert_eq!(empty.nnz(), 0);
        assert_eq!(empty.n_slices(), 0);
        empty.spmv_rows(0, 0, &[0.0; 7], &mut []);
    }
}
