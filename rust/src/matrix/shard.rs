//! Row-sharded CRS storage with halo maps — the distributed-memory
//! decomposition of Schubert/Hager/Fehske/Wellein (arXiv:1106.5908,
//! arXiv:1101.0091) realized in one process. The matrix is
//! row-partitioned into shards; shard *s* owns the row range
//! `[row_begin, row_end)` **and** the matching slice of the input/output
//! vectors (the symmetric partition every row-distributed SpMV uses).
//! Columns outside the owned range are **halo** columns: their vector
//! entries live on another shard and must be exchanged before they can
//! be multiplied.
//!
//! # The local/remote split and bit-reproducibility
//!
//! The classic column split (`y = A_local x_local; y += A_remote
//! x_halo`) cannot reproduce the serial CRS kernel bit for bit: a row's
//! halo columns interleave with its owned columns in ascending global
//! order, and floating-point accumulation is not associative across
//! that interleaving. This layer therefore splits **by row class**, the
//! task-mode decomposition of arXiv:1106.5908 §3:
//!
//! - **interior rows** touch only owned columns; they form the
//!   [`ShardCrs::local`] half (columns renumbered by `-row_begin`, a
//!   monotone shift that preserves the entry order) and need no halo —
//!   they are the work the engine overlaps with the exchange;
//! - **boundary rows** touch at least one halo column; they form the
//!   [`ShardCrs::remote`] half over the concatenated `[owned | halo]`
//!   index space, with every row's entries kept in their **original CRS
//!   order** (owned and halo columns interleaved exactly as the serial
//!   kernel walks them — the half is packed directly, never re-sorted).
//!
//! Each row is thus computed exactly once, with exactly the serial
//! kernel's per-row accumulation order, so sharded output is
//! bit-identical to serial CRS for every shard count, scheme, schedule
//! and overlap mode ([`crate::shard`] tests assert this exhaustively).
//!
//! The halo side is described by [`ShardCrs::halo_cols`] (ascending
//! global columns to gather) and [`ShardCrs::halo_segments`]
//! (per-source-shard contiguous runs of that list — one message per
//! neighbour under a real transport, one `memcpy` per neighbour under
//! the in-process one).

use super::{Crs, SpMv};

/// A CRS matrix row-partitioned into shards with per-shard local/remote
/// halves and halo index maps. Pure storage: execution lives in the
/// [`crate::shard`] module, behind the sharded backend of a
/// [`crate::spmv::SpmvHandle`].
#[derive(Debug, Clone)]
pub struct ShardedCrs {
    pub nrows: usize,
    pub ncols: usize,
    nnz: usize,
    /// Shard row boundaries; length `n_shards + 1`, `boundaries[s]..
    /// boundaries[s+1]` is shard `s`'s row (and vector) range.
    pub boundaries: Vec<usize>,
    pub shards: Vec<ShardCrs>,
}

/// One shard: an owned row/vector range plus the split halves and halo
/// maps described in the module docs.
#[derive(Debug, Clone)]
pub struct ShardCrs {
    pub row_begin: usize,
    pub row_end: usize,
    /// Global row ids of rows touching only owned columns (ascending).
    pub interior_rows: Vec<u32>,
    /// Global row ids of rows touching at least one halo column
    /// (ascending).
    pub boundary_rows: Vec<u32>,
    /// Interior rows over owned columns, renumbered by `-row_begin`.
    /// `nrows = interior_rows.len()`, `ncols = width()`.
    pub local: Crs,
    /// Boundary rows over the concatenated `[owned | halo]` space: an
    /// owned column `c` maps to `c - row_begin`, a halo column to
    /// `width() + its position in halo_cols`. Entry order within each
    /// row is the original CRS (ascending global column) order.
    /// `nrows = boundary_rows.len()`, `ncols = width() + halo_len()`.
    pub remote: Crs,
    /// Ascending global columns this shard gathers from other shards.
    pub halo_cols: Vec<u32>,
    /// `(source_shard, begin, end)` runs of `halo_cols` owned by one
    /// source shard each — the per-neighbour exchange messages.
    pub halo_segments: Vec<(usize, usize, usize)>,
}

impl ShardCrs {
    /// Owned rows (== owned vector elements).
    pub fn width(&self) -> usize {
        self.row_end - self.row_begin
    }

    /// Halo vector elements gathered per SpMV.
    pub fn halo_len(&self) -> usize {
        self.halo_cols.len()
    }

    /// Length of the concatenated `[owned | halo]` input the remote
    /// half multiplies.
    pub fn concat_len(&self) -> usize {
        self.width() + self.halo_len()
    }

    /// Fill `concat` (length [`ShardCrs::concat_len`]) with the owned
    /// slice of `x` followed by the gathered halo values, walking the
    /// per-source segments exactly as a real transport would.
    pub fn gather(&self, x: &[f64], concat: &mut [f64]) {
        let w = self.width();
        debug_assert_eq!(concat.len(), self.concat_len());
        concat[..w].copy_from_slice(&x[self.row_begin..self.row_end]);
        for &(_src, a, b) in &self.halo_segments {
            for j in a..b {
                concat[w + j] = x[self.halo_cols[j] as usize];
            }
        }
    }
}

impl ShardedCrs {
    /// Row-partition `crs` into `n_shards` contiguous, nnz-balanced
    /// shards and split each into its local/remote halves. Requires a
    /// square matrix: rows and vector are partitioned symmetrically.
    pub fn from_crs(crs: &Crs, n_shards: usize) -> Self {
        assert_eq!(crs.nrows, crs.ncols, "sharded SpMV requires a square matrix");
        let boundaries = Self::partition_boundaries(crs, n_shards);
        let shards = (0..n_shards)
            .map(|s| Self::build_shard(crs, &boundaries, boundaries[s], boundaries[s + 1]))
            .collect();
        ShardedCrs { nrows: crs.nrows, ncols: crs.ncols, nnz: crs.nnz(), boundaries, shards }
    }

    /// The nnz-balanced contiguous row boundaries `from_crs` partitions
    /// on: `row_ptr` is the cumulative-nnz prefix, so boundary `s` is
    /// the first row at or past `s/n_shards` of the total and shards
    /// carry near-equal nnz (empty shards are fine on tiny matrices).
    fn partition_boundaries(crs: &Crs, n_shards: usize) -> Vec<usize> {
        assert!(n_shards > 0, "need at least one shard");
        let n = crs.nrows;
        let mut boundaries = Vec::with_capacity(n_shards + 1);
        boundaries.push(0usize);
        for s in 1..n_shards {
            let target = crs.nnz() * s / n_shards;
            let at = crs.row_ptr.partition_point(|&p| p < target).min(n);
            boundaries.push(at.max(boundaries[s - 1]));
        }
        boundaries.push(n);
        boundaries
    }

    /// The (halo-volume fraction, boundary-nnz fraction) a `n_shards`
    /// partition of `crs` would have — what the shard tuner scores
    /// candidates with — computed by a scan only: no local/remote
    /// halves are packed and no nonzeros are copied.
    pub fn partition_stats(crs: &Crs, n_shards: usize) -> (f64, f64) {
        assert_eq!(crs.nrows, crs.ncols, "sharded SpMV requires a square matrix");
        let boundaries = Self::partition_boundaries(crs, n_shards);
        let mut halo_total = 0usize;
        let mut boundary_nnz = 0usize;
        for s in 0..n_shards {
            let (rb, re) = (boundaries[s], boundaries[s + 1]);
            let mut halo: Vec<u32> = Vec::new();
            for i in rb..re {
                let (cols, _) = crs.row(i);
                let before = halo.len();
                halo.extend(
                    cols.iter().copied().filter(|&c| !(rb..re).contains(&(c as usize))),
                );
                if halo.len() > before {
                    boundary_nnz += cols.len();
                }
            }
            halo.sort_unstable();
            halo.dedup();
            halo_total += halo.len();
        }
        let hf = if crs.nrows == 0 { 0.0 } else { halo_total as f64 / crs.nrows as f64 };
        let bf = if crs.nnz() == 0 { 0.0 } else { boundary_nnz as f64 / crs.nnz() as f64 };
        (hf, bf)
    }

    fn build_shard(crs: &Crs, boundaries: &[usize], rb: usize, re: usize) -> ShardCrs {
        let w = re - rb;
        let in_range = |c: usize| c >= rb && c < re;
        // Classify rows and collect the halo column set.
        let mut interior_rows = Vec::new();
        let mut boundary_rows = Vec::new();
        let mut halo_cols: Vec<u32> = Vec::new();
        for i in rb..re {
            let (cols, _) = crs.row(i);
            if cols.iter().all(|&c| in_range(c as usize)) {
                interior_rows.push(i as u32);
            } else {
                boundary_rows.push(i as u32);
                halo_cols.extend(cols.iter().copied().filter(|&c| !in_range(c as usize)));
            }
        }
        halo_cols.sort_unstable();
        halo_cols.dedup();

        // Local half: interior rows, columns shifted into [0, w).
        let mut local = Crs {
            nrows: interior_rows.len(),
            ncols: w,
            row_ptr: vec![0],
            col_idx: Vec::new(),
            val: Vec::new(),
        };
        for &r in &interior_rows {
            let (cols, vals) = crs.row(r as usize);
            for (&c, &v) in cols.iter().zip(vals) {
                local.col_idx.push(c - rb as u32);
                local.val.push(v);
            }
            local.row_ptr.push(local.val.len());
        }

        // Remote half: boundary rows over [owned | halo], packed
        // directly from the CRS walk so each row keeps its original
        // (ascending global column) entry order — the
        // bit-reproducibility invariant. NOT built via Coo::normalize,
        // which would re-sort by concatenated index and put halo terms
        // after owned ones.
        let mut remote = Crs {
            nrows: boundary_rows.len(),
            ncols: w + halo_cols.len(),
            row_ptr: vec![0],
            col_idx: Vec::new(),
            val: Vec::new(),
        };
        for &r in &boundary_rows {
            let (cols, vals) = crs.row(r as usize);
            for (&c, &v) in cols.iter().zip(vals) {
                let cc = if in_range(c as usize) {
                    c - rb as u32
                } else {
                    let h = halo_cols.binary_search(&c).expect("halo column was collected");
                    (w + h) as u32
                };
                remote.col_idx.push(cc);
                remote.val.push(v);
            }
            remote.row_ptr.push(remote.val.len());
        }

        // Per-source-shard contiguous runs of the (sorted) halo list.
        let owner = |c: u32| boundaries.partition_point(|&b| b <= c as usize) - 1;
        let mut halo_segments = Vec::new();
        let mut seg_start = 0usize;
        while seg_start < halo_cols.len() {
            let src = owner(halo_cols[seg_start]);
            let mut seg_end = seg_start + 1;
            while seg_end < halo_cols.len() && owner(halo_cols[seg_end]) == src {
                seg_end += 1;
            }
            halo_segments.push((src, seg_start, seg_end));
            seg_start = seg_end;
        }

        ShardCrs {
            row_begin: rb,
            row_end: re,
            interior_rows,
            boundary_rows,
            local,
            remote,
            halo_cols,
            halo_segments,
        }
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Vector elements exchanged per SpMV, all shards.
    pub fn halo_cols_total(&self) -> usize {
        self.shards.iter().map(|s| s.halo_len()).sum()
    }

    /// Exchanged vector elements as a fraction of the vector length —
    /// the halo-volume fraction the tuner and benches record.
    pub fn halo_fraction(&self) -> f64 {
        if self.nrows == 0 {
            return 0.0;
        }
        self.halo_cols_total() as f64 / self.nrows as f64
    }

    /// Non-zeros in boundary (halo-dependent) rows.
    pub fn boundary_nnz(&self) -> usize {
        self.shards.iter().map(|s| s.remote.val.len()).sum()
    }

    /// Fraction of nnz that must wait for the halo — the complement is
    /// the interior work available to hide the exchange behind.
    pub fn boundary_nnz_fraction(&self) -> f64 {
        if self.nnz == 0 {
            return 0.0;
        }
        self.boundary_nnz() as f64 / self.nnz as f64
    }
}

impl SpMv for ShardedCrs {
    fn nrows(&self) -> usize {
        self.nrows
    }
    fn ncols(&self) -> usize {
        self.ncols
    }
    fn nnz(&self) -> usize {
        self.nnz
    }
    /// Serial reference execution: gather + local + remote per shard,
    /// through the same halves and maps the parallel executor uses.
    fn spmv(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.ncols);
        assert_eq!(y.len(), self.nrows);
        for shard in &self.shards {
            let mut concat = vec![0.0; shard.concat_len()];
            shard.gather(x, &mut concat);
            let mut out = vec![0.0; shard.local.nrows];
            shard.local.spmv_rows_into(0, shard.local.nrows, &concat[..shard.width()], &mut out);
            for (i, &r) in shard.interior_rows.iter().enumerate() {
                y[r as usize] = out[i];
            }
            let mut out = vec![0.0; shard.remote.nrows];
            shard.remote.spmv_rows_into(0, shard.remote.nrows, &concat, &mut out);
            for (i, &r) in shard.boundary_rows.iter().enumerate() {
                y[r as usize] = out[i];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen;
    use crate::matrix::Coo;
    use crate::util::rng::Rng;
    use crate::util::stats::max_abs_diff;

    fn random_crs(rng: &mut Rng, n: usize, nnz: usize) -> Crs {
        let mut coo = Coo::new(n, n);
        for _ in 0..nnz {
            coo.push(rng.index(n), rng.index(n), rng.f64() * 2.0 - 1.0);
        }
        coo.normalize();
        Crs::from_coo(&coo)
    }

    #[test]
    fn partition_tiles_rows_and_balances_nnz() {
        let mut rng = Rng::new(100);
        let crs = random_crs(&mut rng, 500, 4000);
        for n_shards in [1usize, 2, 4, 8] {
            let sh = ShardedCrs::from_crs(&crs, n_shards);
            assert_eq!(sh.n_shards(), n_shards);
            assert_eq!(sh.boundaries.len(), n_shards + 1);
            assert_eq!(sh.boundaries[0], 0);
            assert_eq!(*sh.boundaries.last().unwrap(), 500);
            assert!(sh.boundaries.windows(2).all(|w| w[0] <= w[1]));
            // Every row lands in exactly one shard, as interior XOR
            // boundary, and total nnz is conserved.
            let mut seen = vec![0u8; 500];
            let mut nnz = 0usize;
            for s in &sh.shards {
                for &r in s.interior_rows.iter().chain(&s.boundary_rows) {
                    seen[r as usize] += 1;
                    assert!((s.row_begin..s.row_end).contains(&(r as usize)));
                }
                nnz += s.local.val.len() + s.remote.val.len();
            }
            assert!(seen.iter().all(|&c| c == 1), "{n_shards} shards: row multiplicity");
            assert_eq!(nnz, crs.nnz());
            // nnz balance: no shard holds more than ~2x its fair share
            // (+ the largest single row, which cannot be split).
            if n_shards > 1 {
                let max_row =
                    (0..500).map(|i| crs.row_ptr[i + 1] - crs.row_ptr[i]).max().unwrap();
                let fair = crs.nnz() / n_shards;
                for (i, s) in sh.shards.iter().enumerate() {
                    let got = s.local.val.len() + s.remote.val.len();
                    assert!(
                        got <= 2 * fair + max_row,
                        "{n_shards} shards: shard {i} holds {got} nnz (fair {fair})"
                    );
                }
            }
        }
    }

    #[test]
    fn halo_maps_are_consistent() {
        let mut rng = Rng::new(101);
        let crs = random_crs(&mut rng, 300, 2400);
        let sh = ShardedCrs::from_crs(&crs, 4);
        for (si, s) in sh.shards.iter().enumerate() {
            // halo columns: sorted, unique, never owned.
            assert!(s.halo_cols.windows(2).all(|w| w[0] < w[1]));
            for &c in &s.halo_cols {
                assert!(!(s.row_begin..s.row_end).contains(&(c as usize)));
            }
            // segments tile the halo list and name the true owner.
            let mut pos = 0;
            for &(src, a, b) in &s.halo_segments {
                assert_eq!(a, pos);
                assert!(b > a);
                assert_ne!(src, si, "a shard cannot be its own halo source");
                for &c in &s.halo_cols[a..b] {
                    let o = &sh.shards[src];
                    assert!((o.row_begin..o.row_end).contains(&(c as usize)));
                }
                pos = b;
            }
            assert_eq!(pos, s.halo_cols.len());
            // remote half: concatenated index space, interleaved order
            // preserved (strictly ascending global column per row).
            assert_eq!(s.remote.ncols, s.width() + s.halo_len());
            for r in 0..s.remote.nrows {
                let (cols, _) = s.remote.row(r);
                let global: Vec<u32> = cols
                    .iter()
                    .map(|&cc| {
                        if (cc as usize) < s.width() {
                            cc + s.row_begin as u32
                        } else {
                            s.halo_cols[cc as usize - s.width()]
                        }
                    })
                    .collect();
                assert!(
                    global.windows(2).all(|w| w[0] < w[1]),
                    "remote row {r} lost the serial (ascending global) entry order"
                );
            }
        }
    }

    #[test]
    fn sharded_serial_reference_is_bit_identical_to_crs() {
        let hh = Crs::from_coo(&gen::holstein_hubbard(&gen::HolsteinHubbardParams::tiny()));
        let matrices = [
            ("hh-tiny", hh),
            ("random", random_crs(&mut Rng::new(102), 257, 1800)),
            ("band", Crs::from_coo(&gen::random_band(400, 7, 90, &mut Rng::new(103)))),
        ];
        for (name, crs) in &matrices {
            let n = crs.nrows;
            let mut rng = Rng::new(104);
            let mut x = vec![0.0; n];
            rng.fill_f64(&mut x, -1.0, 1.0);
            let mut want = vec![0.0; n];
            crs.spmv(&x, &mut want);
            for n_shards in [1usize, 2, 3, 4, 8, 16] {
                let sh = ShardedCrs::from_crs(crs, n_shards);
                let mut got = vec![0.0; n];
                sh.spmv(&x, &mut got);
                assert_eq!(
                    max_abs_diff(&want, &got),
                    0.0,
                    "{name} × {n_shards} shards deviates from serial CRS"
                );
            }
        }
    }

    /// The scan-only tuner features must agree exactly with the fully
    /// built partition's fractions.
    #[test]
    fn partition_stats_match_built_partition() {
        let mut rng = Rng::new(109);
        let crs = random_crs(&mut rng, 350, 2600);
        for n_shards in [1usize, 2, 4, 8] {
            let (hf, bf) = ShardedCrs::partition_stats(&crs, n_shards);
            let built = ShardedCrs::from_crs(&crs, n_shards);
            assert_eq!(hf, built.halo_fraction(), "{n_shards} shards: halo fraction");
            assert_eq!(bf, built.boundary_nnz_fraction(), "{n_shards} shards: boundary nnz");
        }
    }

    #[test]
    fn single_shard_has_no_halo() {
        let crs = random_crs(&mut Rng::new(105), 120, 700);
        let sh = ShardedCrs::from_crs(&crs, 1);
        let s = &sh.shards[0];
        assert_eq!(s.halo_len(), 0);
        assert!(s.boundary_rows.is_empty());
        assert_eq!(s.local.val.len(), crs.nnz());
        assert_eq!(sh.halo_fraction(), 0.0);
        assert_eq!(sh.boundary_nnz_fraction(), 0.0);
    }

    #[test]
    fn halo_grows_with_shard_count_on_a_band() {
        // A fixed-bandwidth band matrix: more shards -> more cuts ->
        // more exchanged elements, while each cut's halo stays ~band.
        let crs = Crs::from_coo(&gen::random_band(600, 6, 24, &mut Rng::new(106)));
        let h2 = ShardedCrs::from_crs(&crs, 2).halo_cols_total();
        let h4 = ShardedCrs::from_crs(&crs, 4).halo_cols_total();
        let h8 = ShardedCrs::from_crs(&crs, 8).halo_cols_total();
        assert!(h2 > 0);
        assert!(h2 <= h4 && h4 <= h8, "halo volume must grow with cuts: {h2} {h4} {h8}");
    }

    #[test]
    fn more_shards_than_rows_degenerates_cleanly() {
        let crs = random_crs(&mut Rng::new(107), 5, 20);
        let sh = ShardedCrs::from_crs(&crs, 8);
        assert_eq!(sh.n_shards(), 8);
        let mut x = vec![0.0; 5];
        Rng::new(108).fill_f64(&mut x, -1.0, 1.0);
        let mut want = vec![0.0; 5];
        crs.spmv(&x, &mut want);
        let mut got = vec![0.0; 5];
        sh.spmv(&x, &mut got);
        assert_eq!(max_abs_diff(&want, &got), 0.0);
    }

    #[test]
    fn empty_matrix_shards() {
        let crs = Crs::from_coo(&Coo::new(10, 10));
        let sh = ShardedCrs::from_crs(&crs, 4);
        assert_eq!(sh.halo_cols_total(), 0);
        let x = vec![1.0; 10];
        let mut y = vec![9.0; 10];
        sh.spmv(&x, &mut y);
        assert_eq!(y, vec![0.0; 10]);
    }
}
