//! Coordinate (COO) format: the assembly and interchange representation.
//! All generators produce COO; all compute formats convert from it.

use super::SpMv;

/// A sparse matrix as (row, col, value) triples.
#[derive(Debug, Clone)]
pub struct Coo {
    pub nrows: usize,
    pub ncols: usize,
    /// Entries; duplicates are summed on conversion to CRS.
    pub entries: Vec<(u32, u32, f64)>,
}

impl Coo {
    pub fn new(nrows: usize, ncols: usize) -> Self {
        Self { nrows, ncols, entries: Vec::new() }
    }

    pub fn with_capacity(nrows: usize, ncols: usize, cap: usize) -> Self {
        Self { nrows, ncols, entries: Vec::with_capacity(cap) }
    }

    /// Add an entry. Zero values are kept (some benchmarks want explicit
    /// zeros); use [`Coo::prune_zeros`] to drop them.
    pub fn push(&mut self, row: usize, col: usize, val: f64) {
        debug_assert!(row < self.nrows && col < self.ncols, "({row},{col}) out of bounds");
        self.entries.push((row as u32, col as u32, val));
    }

    pub fn nnz(&self) -> usize {
        self.entries.len()
    }

    /// Drop explicitly stored zeros.
    pub fn prune_zeros(&mut self) {
        self.entries.retain(|&(_, _, v)| v != 0.0);
    }

    /// Sort entries row-major (row, then column) and sum duplicates.
    pub fn normalize(&mut self) {
        self.entries
            .sort_unstable_by(|a, b| (a.0, a.1).cmp(&(b.0, b.1)));
        let mut out: Vec<(u32, u32, f64)> = Vec::with_capacity(self.entries.len());
        for &(r, c, v) in &self.entries {
            if let Some(last) = out.last_mut() {
                if last.0 == r && last.1 == c {
                    last.2 += v;
                    continue;
                }
            }
            out.push((r, c, v));
        }
        self.entries = out;
    }

    /// Build from a dense row-major matrix, dropping zeros.
    pub fn from_dense(dense: &[Vec<f64>]) -> Self {
        let nrows = dense.len();
        let ncols = dense.first().map_or(0, |r| r.len());
        let mut coo = Coo::new(nrows, ncols);
        for (i, row) in dense.iter().enumerate() {
            for (j, &v) in row.iter().enumerate() {
                if v != 0.0 {
                    coo.push(i, j, v);
                }
            }
        }
        coo
    }

    /// Materialize as dense rows (for small-matrix tests only).
    pub fn to_dense(&self) -> Vec<Vec<f64>> {
        let mut d = vec![vec![0.0; self.ncols]; self.nrows];
        for &(r, c, v) in &self.entries {
            d[r as usize][c as usize] += v;
        }
        d
    }

    /// Transpose.
    pub fn transpose(&self) -> Coo {
        Coo {
            nrows: self.ncols,
            ncols: self.nrows,
            entries: self.entries.iter().map(|&(r, c, v)| (c, r, v)).collect(),
        }
    }

    /// Check symmetry (exact value match) — Hamiltonians must satisfy this.
    pub fn is_symmetric(&self) -> bool {
        if self.nrows != self.ncols {
            return false;
        }
        let mut a = self.clone();
        a.normalize();
        let mut b = self.transpose();
        b.normalize();
        a.entries.len() == b.entries.len()
            && a.entries
                .iter()
                .zip(&b.entries)
                .all(|(x, y)| x.0 == y.0 && x.1 == y.1 && (x.2 - y.2).abs() < 1e-12)
    }

    /// Number of non-zeros per row.
    pub fn row_counts(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.nrows];
        for &(r, _, _) in &self.entries {
            counts[r as usize] += 1;
        }
        counts
    }
}

impl SpMv for Coo {
    fn nrows(&self) -> usize {
        self.nrows
    }
    fn ncols(&self) -> usize {
        self.ncols
    }
    fn nnz(&self) -> usize {
        self.entries.len()
    }
    fn spmv(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.ncols);
        assert_eq!(y.len(), self.nrows);
        y.fill(0.0);
        for &(r, c, v) in &self.entries {
            y[r as usize] += v * x[c as usize];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Coo {
        // [[1, 0, 2],
        //  [0, 3, 0],
        //  [4, 0, 5]]
        let mut m = Coo::new(3, 3);
        m.push(0, 0, 1.0);
        m.push(0, 2, 2.0);
        m.push(1, 1, 3.0);
        m.push(2, 0, 4.0);
        m.push(2, 2, 5.0);
        m
    }

    #[test]
    fn spmv_matches_dense() {
        let m = sample();
        let x = [1.0, 2.0, 3.0];
        let mut y = [0.0; 3];
        m.spmv(&x, &mut y);
        assert_eq!(y, [7.0, 6.0, 19.0]);
    }

    #[test]
    fn normalize_sums_duplicates() {
        let mut m = Coo::new(2, 2);
        m.push(0, 0, 1.0);
        m.push(0, 0, 2.0);
        m.push(1, 1, 1.0);
        m.normalize();
        assert_eq!(m.nnz(), 2);
        assert_eq!(m.entries[0], (0, 0, 3.0));
    }

    #[test]
    fn dense_roundtrip() {
        let m = sample();
        let d = m.to_dense();
        let m2 = Coo::from_dense(&d);
        assert_eq!(m2.nnz(), m.nnz());
        assert_eq!(m2.to_dense(), d);
    }

    #[test]
    fn symmetry_detection() {
        let mut s = Coo::new(2, 2);
        s.push(0, 1, 2.0);
        s.push(1, 0, 2.0);
        s.push(0, 0, 1.0);
        assert!(s.is_symmetric());
        let mut a = Coo::new(2, 2);
        a.push(0, 1, 2.0);
        assert!(!a.is_symmetric());
    }

    #[test]
    fn transpose_works() {
        let m = sample();
        let t = m.transpose();
        assert_eq!(t.to_dense()[2][0], 2.0);
        assert_eq!(t.to_dense()[0][2], 4.0);
    }

    #[test]
    fn prune_zeros() {
        let mut m = Coo::new(2, 2);
        m.push(0, 0, 0.0);
        m.push(1, 1, 2.0);
        m.prune_zeros();
        assert_eq!(m.nnz(), 1);
    }

    #[test]
    fn row_counts() {
        let m = sample();
        assert_eq!(m.row_counts(), vec![2, 1, 2]);
    }
}
