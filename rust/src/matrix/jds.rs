//! Jagged diagonals storage (JDS) — §2 of the paper — plus the NBJDS
//! (blocked) and NUJDS (outer-loop-unrolled) *access* schemes that share
//! its storage layout.
//!
//! Construction: rows and columns are symmetrically permuted such that row
//! non-zero counts decrease with row index; each row's entries are shifted
//! left; the columns of the resulting staircase ("jagged diagonals") are
//! stored consecutively. The inner loop is a sparse vector triad
//! (18 bytes/flop balance): the whole result vector is read+written once
//! per jagged diagonal.
//!
//! All kernels run in the *permuted* basis. The [`SpMv`] impl wraps the
//! kernel with gather/scatter of the input/output vectors so callers see
//! the original basis; benchmark paths use the raw `spmv_permuted_*`
//! kernels with pre-permuted vectors, as a long-lived solver would.

use super::{Coo, Crs, SpMv};

/// Visitor over the logical SpMV update stream of a kernel.
///
/// Each call means `y[row] += val[j] * x[col]` where `j` is the storage
/// offset into `val`/`col_idx`. The *order* of calls is exactly the order
/// the kernel touches memory, so the same walk drives both the compute
/// kernels and the memory-hierarchy simulator's trace generation.
/// Consecutive calls with equal `row` model a register-held accumulator.
pub trait SpmvVisitor {
    fn update(&mut self, row: usize, j: usize, col: usize);
}

/// Compute visitor: performs the actual arithmetic with a register
/// accumulator for runs of equal `row` (matching CRS/NUJDS codegen).
pub struct Compute<'a> {
    pub val: &'a [f64],
    pub x: &'a [f64],
    pub y: &'a mut [f64],
    acc: f64,
    cur_row: usize,
}

impl<'a> Compute<'a> {
    pub fn new(val: &'a [f64], x: &'a [f64], y: &'a mut [f64]) -> Self {
        y.fill(0.0);
        Self { val, x, y, acc: 0.0, cur_row: usize::MAX }
    }

    #[inline]
    pub fn finish(mut self) {
        if self.cur_row != usize::MAX {
            self.y[self.cur_row] += self.acc;
        }
        self.cur_row = usize::MAX;
    }
}

impl<'a> SpmvVisitor for Compute<'a> {
    #[inline(always)]
    fn update(&mut self, row: usize, j: usize, col: usize) {
        if row != self.cur_row {
            if self.cur_row != usize::MAX {
                self.y[self.cur_row] += self.acc;
            }
            self.cur_row = row;
            self.acc = 0.0;
        }
        self.acc += self.val[j] * self.x[col];
    }
}

/// JDS storage. Shared by the JDS / NBJDS / NUJDS access schemes.
#[derive(Debug, Clone)]
pub struct Jds {
    pub nrows: usize,
    pub ncols: usize,
    /// `perm[new] = old`: row `new` of the permuted matrix is row `old`
    /// of the original.
    pub perm: Vec<u32>,
    /// `inv_perm[old] = new`.
    pub inv_perm: Vec<u32>,
    /// Offsets of each jagged diagonal into `val`/`col_idx`; length
    /// `n_diag + 1`. Diagonal lengths are non-increasing.
    pub jd_ptr: Vec<usize>,
    /// Column indices in the permuted basis.
    pub col_idx: Vec<u32>,
    pub val: Vec<f64>,
}

impl Jds {
    /// Build from CRS. Requires a square matrix (the paper permutes rows
    /// and columns symmetrically).
    pub fn from_crs(crs: &Crs) -> Self {
        assert_eq!(crs.nrows, crs.ncols, "JDS requires a square matrix");
        let n = crs.nrows;
        // Sort rows by descending nnz (stable: ties keep original order).
        let mut order: Vec<u32> = (0..n as u32).collect();
        order.sort_by_key(|&i| {
            let i = i as usize;
            std::cmp::Reverse(crs.row_ptr[i + 1] - crs.row_ptr[i])
        });
        let perm = order;
        let mut inv_perm = vec![0u32; n];
        for (new, &old) in perm.iter().enumerate() {
            inv_perm[old as usize] = new as u32;
        }
        // Permuted rows with relabeled, re-sorted columns.
        let mut rows: Vec<Vec<(u32, f64)>> = Vec::with_capacity(n);
        for &old in &perm {
            let (cols, vals) = crs.row(old as usize);
            let mut row: Vec<(u32, f64)> = cols
                .iter()
                .zip(vals)
                .map(|(&c, &v)| (inv_perm[c as usize], v))
                .collect();
            row.sort_unstable_by_key(|&(c, _)| c);
            rows.push(row);
        }
        Self::from_permuted_rows(n, crs.ncols, perm, inv_perm, &rows)
    }

    /// Assemble jagged diagonals from permuted per-row (col, val) lists
    /// whose lengths are non-increasing.
    pub(crate) fn from_permuted_rows(
        nrows: usize,
        ncols: usize,
        perm: Vec<u32>,
        inv_perm: Vec<u32>,
        rows: &[Vec<(u32, f64)>],
    ) -> Self {
        let max_nnz = rows.first().map_or(0, |r| r.len());
        debug_assert!(rows.windows(2).all(|w| w[0].len() >= w[1].len()));
        let nnz: usize = rows.iter().map(|r| r.len()).sum();
        let mut jd_ptr = Vec::with_capacity(max_nnz + 1);
        let mut col_idx = Vec::with_capacity(nnz);
        let mut val = Vec::with_capacity(nnz);
        jd_ptr.push(0);
        for d in 0..max_nnz {
            for row in rows {
                if row.len() > d {
                    col_idx.push(row[d].0);
                    val.push(row[d].1);
                } else {
                    break; // row lengths are non-increasing
                }
            }
            jd_ptr.push(col_idx.len());
        }
        Jds { nrows, ncols, perm, inv_perm, jd_ptr, col_idx, val }
    }

    pub fn from_coo(coo: &Coo) -> Self {
        Self::from_crs(&Crs::from_coo(coo))
    }

    /// Number of jagged diagonals.
    pub fn n_diag(&self) -> usize {
        self.jd_ptr.len() - 1
    }

    /// Length of diagonal `d`.
    #[inline]
    pub fn diag_len(&self, d: usize) -> usize {
        self.jd_ptr[d + 1] - self.jd_ptr[d]
    }

    /// Gather `x` into the permuted basis.
    pub fn permute_vec(&self, x: &[f64]) -> Vec<f64> {
        self.perm.iter().map(|&old| x[old as usize]).collect()
    }

    /// Scatter a permuted-basis result back to the original basis.
    pub fn unpermute_vec(&self, yp: &[f64], y: &mut [f64]) {
        for (new, &old) in self.perm.iter().enumerate() {
            y[old as usize] = yp[new];
        }
    }

    // ---------------------------------------------------------------
    // Access schemes. Each `walk_*` drives a visitor in the exact order
    // the corresponding kernel touches memory.
    // ---------------------------------------------------------------

    /// Plain JDS: diagonal-major traversal (the vector-machine kernel).
    pub fn walk_jds<V: SpmvVisitor>(&self, v: &mut V) {
        for d in 0..self.n_diag() {
            let off = self.jd_ptr[d];
            let len = self.diag_len(d);
            for i in 0..len {
                v.update(i, off + i, self.col_idx[off + i] as usize);
            }
        }
    }

    /// NBJDS: diagonals cut into row blocks of `block`; the block of the
    /// result vector stays in cache across diagonals (§2).
    pub fn walk_nbjds<V: SpmvVisitor>(&self, block: usize, v: &mut V) {
        assert!(block > 0);
        let nd = self.n_diag();
        let longest = if nd == 0 { 0 } else { self.diag_len(0) };
        let mut b0 = 0;
        while b0 < longest {
            let b1 = (b0 + block).min(longest);
            for d in 0..nd {
                let len = self.diag_len(d);
                if len <= b0 {
                    break; // lengths non-increasing: no later diag reaches
                }
                let off = self.jd_ptr[d];
                let end = b1.min(len);
                for i in b0..end {
                    v.update(i, off + i, self.col_idx[off + i] as usize);
                }
            }
            b0 = b1;
        }
    }

    /// NUJDS: outer (diagonal) loop unrolled by `unroll`; each result
    /// element is updated by several diagonals at once and held in a
    /// register. With `unroll >= n_diag` this degenerates to CRS order in
    /// the permuted basis (§2).
    pub fn walk_nujds<V: SpmvVisitor>(&self, unroll: usize, v: &mut V) {
        assert!(unroll > 0);
        let nd = self.n_diag();
        let mut d = 0;
        while d < nd {
            let dmax = (d + unroll).min(nd);
            // Shortest diagonal in the group bounds the fused range.
            let common = self.diag_len(dmax - 1);
            for i in 0..common {
                for dd in d..dmax {
                    let off = self.jd_ptr[dd];
                    v.update(i, off + i, self.col_idx[off + i] as usize);
                }
            }
            // Tails where only a prefix of the group has entries: keep
            // row-major order (as the unrolled remainder loop does) so a
            // register still accumulates each result element.
            let longest = self.diag_len(d);
            for i in common..longest {
                for dd in d..dmax {
                    if self.diag_len(dd) <= i {
                        break; // lengths non-increasing within the group
                    }
                    let off = self.jd_ptr[dd];
                    v.update(i, off + i, self.col_idx[off + i] as usize);
                }
            }
            d = dmax;
        }
    }

    // ---------------------------------------------------------------
    // Permuted-basis SpMV kernels (no gather/scatter).
    // ---------------------------------------------------------------

    pub fn spmv_permuted_jds(&self, xp: &[f64], yp: &mut [f64]) {
        let mut c = Compute::new(&self.val, xp, yp);
        self.walk_jds(&mut c);
        c.finish();
    }

    pub fn spmv_permuted_nbjds(&self, block: usize, xp: &[f64], yp: &mut [f64]) {
        let mut c = Compute::new(&self.val, xp, yp);
        self.walk_nbjds(block, &mut c);
        c.finish();
    }

    pub fn spmv_permuted_nujds(&self, unroll: usize, xp: &[f64], yp: &mut [f64]) {
        let mut c = Compute::new(&self.val, xp, yp);
        self.walk_nujds(unroll, &mut c);
        c.finish();
    }

    // ---------------------------------------------------------------
    // Range-restricted permuted-basis kernels (per-diagonal-segment) for
    // the parallel execution engine. Each computes permuted rows
    // [row_begin, row_end) into out[i - row_begin], touching only the
    // diagonal segments that intersect the range, and reproduces the
    // serial kernels' per-row accumulation order (ascending diagonal,
    // grouped by `unroll` for NUJDS) so partitioned and serial runs
    // produce identical results.
    // ---------------------------------------------------------------

    /// Plain JDS restricted to a row range. Per-row accumulation is
    /// ascending-diagonal, with one exception mirroring the serial
    /// walk's register runs: trailing length-1 diagonals all emit row 0
    /// consecutively, so the serial [`Compute`] visitor pre-sums them in
    /// a register before a single flush — replicated here so the result
    /// is identical to [`Jds::spmv_permuted_jds`].
    pub fn spmv_rows_jds(&self, row_begin: usize, row_end: usize, xp: &[f64], out: &mut [f64]) {
        debug_assert_eq!(out.len(), row_end - row_begin);
        let nd = self.n_diag();
        let longest = if nd == 0 { 0 } else { self.diag_len(0) };
        for i in row_begin..row_end {
            let mut y = 0.0;
            let mut d = 0;
            while d < nd {
                let len = self.diag_len(d);
                if len <= i {
                    break; // lengths non-increasing
                }
                if longest > 1 && i == 0 && len == 1 {
                    break; // register-run tail handled below
                }
                let off = self.jd_ptr[d] + i;
                y += self.val[off] * xp[self.col_idx[off] as usize];
                d += 1;
            }
            // Register-run tail: length-1 diagonals accumulate before a
            // single flush onto row 0.
            let mut acc = 0.0;
            while d < nd && self.diag_len(d) > i {
                let off = self.jd_ptr[d] + i;
                acc += self.val[off] * xp[self.col_idx[off] as usize];
                d += 1;
            }
            y += acc;
            out[i - row_begin] = y;
        }
    }

    /// NBJDS restricted to a row range. Mirrors the serial blocked
    /// walk's register runs: within a block `[b0, b1)` of width > 1,
    /// diagonals ending exactly at row `b0` emit that row consecutively
    /// and accumulate in a register before one flush.
    pub fn spmv_rows_nbjds(
        &self,
        block: usize,
        row_begin: usize,
        row_end: usize,
        xp: &[f64],
        out: &mut [f64],
    ) {
        assert!(block > 0);
        debug_assert_eq!(out.len(), row_end - row_begin);
        let nd = self.n_diag();
        let longest = if nd == 0 { 0 } else { self.diag_len(0) };
        for i in row_begin..row_end {
            let b0 = (i / block) * block;
            let width = (b0 + block).min(longest).saturating_sub(b0);
            let mut y = 0.0;
            let mut d = 0;
            while d < nd {
                let len = self.diag_len(d);
                if len <= i {
                    break;
                }
                if width > 1 && i == b0 && len == i + 1 {
                    break; // register-run tail handled below
                }
                let off = self.jd_ptr[d] + i;
                y += self.val[off] * xp[self.col_idx[off] as usize];
                d += 1;
            }
            let mut acc = 0.0;
            while d < nd && self.diag_len(d) > i {
                let off = self.jd_ptr[d] + i;
                acc += self.val[off] * xp[self.col_idx[off] as usize];
                d += 1;
            }
            y += acc;
            out[i - row_begin] = y;
        }
    }

    /// NUJDS restricted to a row range: per row, diagonals are grouped by
    /// `unroll` with a register accumulator per group, matching the
    /// unrolled kernel's rounding exactly. Groups made up entirely of
    /// length-1 diagonals emit row 0 back-to-back in the serial walk and
    /// therefore merge into one register run.
    pub fn spmv_rows_nujds(
        &self,
        unroll: usize,
        row_begin: usize,
        row_end: usize,
        xp: &[f64],
        out: &mut [f64],
    ) {
        assert!(unroll > 0);
        debug_assert_eq!(out.len(), row_end - row_begin);
        let nd = self.n_diag();
        for i in row_begin..row_end {
            let mut total = 0.0;
            let mut d = 0;
            while d < nd && self.diag_len(d) > i {
                if i == 0 && self.diag_len(d) == 1 {
                    // Trailing all-length-1 groups: one merged run.
                    let mut acc = 0.0;
                    while d < nd {
                        let off = self.jd_ptr[d];
                        acc += self.val[off] * xp[self.col_idx[off] as usize];
                        d += 1;
                    }
                    total += acc;
                    break;
                }
                let dmax = (d + unroll).min(nd);
                let mut acc = 0.0;
                for dd in d..dmax {
                    if self.diag_len(dd) <= i {
                        break; // lengths non-increasing within the group
                    }
                    let off = self.jd_ptr[dd] + i;
                    acc += self.val[off] * xp[self.col_idx[off] as usize];
                }
                total += acc;
                d = dmax;
            }
            out[i - row_begin] = total;
        }
    }

    /// Full SpMV in the original basis via a chosen access scheme.
    pub fn spmv_scheme(&self, scheme: super::Scheme, x: &[f64], y: &mut [f64]) {
        let xp = self.permute_vec(x);
        let mut yp = vec![0.0; self.nrows];
        match scheme {
            super::Scheme::Jds => self.spmv_permuted_jds(&xp, &mut yp),
            super::Scheme::NbJds { block } => self.spmv_permuted_nbjds(block, &xp, &mut yp),
            super::Scheme::NuJds { unroll } => self.spmv_permuted_nujds(unroll, &xp, &mut yp),
            other => panic!("scheme {other} does not use Jds storage"),
        }
        self.unpermute_vec(&yp, y);
    }
}

impl SpMv for Jds {
    fn nrows(&self) -> usize {
        self.nrows
    }
    fn ncols(&self) -> usize {
        self.ncols
    }
    fn nnz(&self) -> usize {
        self.val.len()
    }
    fn spmv(&self, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.ncols);
        assert_eq!(y.len(), self.nrows);
        self.spmv_scheme(super::Scheme::Jds, x, y);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;
    use crate::util::stats::max_abs_diff;

    fn random_square(rng: &mut Rng, n: usize, nnz: usize) -> (Coo, Crs) {
        let mut coo = Coo::new(n, n);
        for _ in 0..nnz {
            coo.push(rng.index(n), rng.index(n), rng.f64() * 2.0 - 1.0);
        }
        coo.normalize();
        let crs = Crs::from_coo(&coo);
        (coo, crs)
    }

    #[test]
    fn diag_lengths_non_increasing() {
        let mut rng = Rng::new(10);
        let (_, crs) = random_square(&mut rng, 80, 500);
        let jds = Jds::from_crs(&crs);
        for d in 1..jds.n_diag() {
            assert!(jds.diag_len(d) <= jds.diag_len(d - 1));
        }
        assert_eq!(jds.nnz(), crs.nnz());
    }

    #[test]
    fn perm_is_permutation_sorted_by_nnz() {
        let mut rng = Rng::new(11);
        let (_, crs) = random_square(&mut rng, 60, 400);
        let jds = Jds::from_crs(&crs);
        let mut seen = vec![false; 60];
        for &p in &jds.perm {
            assert!(!seen[p as usize]);
            seen[p as usize] = true;
        }
        // nnz per permuted row non-increasing
        let counts: Vec<usize> = jds
            .perm
            .iter()
            .map(|&old| crs.row_ptr[old as usize + 1] - crs.row_ptr[old as usize])
            .collect();
        assert!(counts.windows(2).all(|w| w[0] >= w[1]));
    }

    #[test]
    fn jds_spmv_matches_crs() {
        let mut rng = Rng::new(12);
        for _ in 0..10 {
            let n = 20 + rng.index(100);
            let (_, crs) = random_square(&mut rng, n, n * 6);
            let jds = Jds::from_crs(&crs);
            let mut x = vec![0.0; n];
            rng.fill_f64(&mut x, -1.0, 1.0);
            let mut y1 = vec![0.0; n];
            let mut y2 = vec![0.0; n];
            crs.spmv(&x, &mut y1);
            jds.spmv(&x, &mut y2);
            assert!(max_abs_diff(&y1, &y2) < 1e-12);
        }
    }

    #[test]
    fn nbjds_matches_for_various_blocks() {
        let mut rng = Rng::new(13);
        let n = 120;
        let (_, crs) = random_square(&mut rng, n, n * 5);
        let jds = Jds::from_crs(&crs);
        let mut x = vec![0.0; n];
        rng.fill_f64(&mut x, -1.0, 1.0);
        let mut y_ref = vec![0.0; n];
        crs.spmv(&x, &mut y_ref);
        for block in [1, 2, 7, 16, 64, 119, 120, 1000] {
            let mut y = vec![0.0; n];
            jds.spmv_scheme(crate::matrix::Scheme::NbJds { block }, &x, &mut y);
            assert!(max_abs_diff(&y_ref, &y) < 1e-12, "block {block}");
        }
    }

    #[test]
    fn nujds_matches_for_various_unrolls() {
        let mut rng = Rng::new(14);
        let n = 90;
        let (_, crs) = random_square(&mut rng, n, n * 4);
        let jds = Jds::from_crs(&crs);
        let mut x = vec![0.0; n];
        rng.fill_f64(&mut x, -1.0, 1.0);
        let mut y_ref = vec![0.0; n];
        crs.spmv(&x, &mut y_ref);
        for unroll in [1, 2, 3, 4, 8, 1000] {
            let mut y = vec![0.0; n];
            jds.spmv_scheme(crate::matrix::Scheme::NuJds { unroll }, &x, &mut y);
            assert!(max_abs_diff(&y_ref, &y) < 1e-12, "unroll {unroll}");
        }
    }

    #[test]
    fn nujds_full_unroll_is_row_major() {
        // With unroll >= n_diag, the update order must be row-major in the
        // permuted basis, i.e. CRS order (§2).
        let mut rng = Rng::new(15);
        let (_, crs) = random_square(&mut rng, 40, 200);
        let jds = Jds::from_crs(&crs);
        struct Rows(Vec<usize>);
        impl SpmvVisitor for Rows {
            fn update(&mut self, row: usize, _j: usize, _col: usize) {
                self.0.push(row);
            }
        }
        let mut rows = Rows(Vec::new());
        jds.walk_nujds(jds.n_diag().max(1), &mut rows);
        assert!(rows.0.windows(2).all(|w| w[0] <= w[1]), "row order must be monotone");
    }

    #[test]
    fn walk_visits_each_nnz_once() {
        let mut rng = Rng::new(16);
        let (_, crs) = random_square(&mut rng, 70, 350);
        let jds = Jds::from_crs(&crs);
        struct Count(Vec<u32>);
        impl SpmvVisitor for Count {
            fn update(&mut self, _row: usize, j: usize, _col: usize) {
                self.0[j] += 1;
            }
        }
        for walk in 0..3 {
            let mut c = Count(vec![0; jds.nnz()]);
            match walk {
                0 => jds.walk_jds(&mut c),
                1 => jds.walk_nbjds(13, &mut c),
                _ => jds.walk_nujds(3, &mut c),
            }
            assert!(c.0.iter().all(|&n| n == 1), "walk {walk} must touch each nnz once");
        }
    }

    #[test]
    fn range_restricted_kernels_match_serial_exactly() {
        let mut rng = Rng::new(17);
        let n = 113;
        let (_, crs) = random_square(&mut rng, n, n * 6);
        let jds = Jds::from_crs(&crs);
        let mut xp = vec![0.0; n];
        rng.fill_f64(&mut xp, -1.0, 1.0);
        let cuts = [(0usize, 31usize), (31, 32), (32, 90), (90, n)];
        // (serial kernel, pieced kernel) per access scheme
        let mut serial = vec![0.0; n];
        let mut pieced = vec![0.0; n];

        jds.spmv_permuted_jds(&xp, &mut serial);
        for &(a, b) in &cuts {
            let (head, _) = pieced.split_at_mut(b);
            jds.spmv_rows_jds(a, b, &xp, &mut head[a..]);
        }
        assert_eq!(max_abs_diff(&serial, &pieced), 0.0, "JDS");

        jds.spmv_permuted_nbjds(13, &xp, &mut serial);
        for &(a, b) in &cuts {
            let (head, _) = pieced.split_at_mut(b);
            jds.spmv_rows_nbjds(13, a, b, &xp, &mut head[a..]);
        }
        assert_eq!(max_abs_diff(&serial, &pieced), 0.0, "NBJDS");

        for unroll in [1, 3, 8] {
            jds.spmv_permuted_nujds(unroll, &xp, &mut serial);
            for &(a, b) in &cuts {
                let (head, _) = pieced.split_at_mut(b);
                jds.spmv_rows_nujds(unroll, a, b, &xp, &mut head[a..]);
            }
            assert_eq!(max_abs_diff(&serial, &pieced), 0.0, "NUJDS u={unroll}");
        }
    }

    #[test]
    fn empty_matrix() {
        let coo = Coo::new(5, 5);
        let jds = Jds::from_coo(&coo);
        assert_eq!(jds.n_diag(), 0);
        let x = vec![1.0; 5];
        let mut y = vec![9.0; 5];
        jds.spmv(&x, &mut y);
        assert_eq!(y, vec![0.0; 5]);
    }
}
