//! Predictive SpMV performance model — the paper's stated goal (§1):
//! *"A successful performance model will be predictive for the expected
//! performance of various SpMVM implementations for a given matrix on the
//! basis of its sparsity pattern, and give a hint to the respective
//! optimal storage scheme."*
//!
//! The model combines:
//! 1. a **machine cost curve** `c(k)`: cycles per update of the IRSCP
//!    microbenchmark at mean gather stride `k` (calibrated once per
//!    machine on the simulator — on real hardware this would be a
//!    measured curve, Fig 3a);
//! 2. the **matrix fingerprint**: the stride distribution of the chosen
//!    storage scheme's access pattern (Fig 6a);
//! 3. scheme-dependent overheads: result-vector traffic per row-run and
//!    inner-loop startup costs.

use crate::analysis::StrideDistribution;
use crate::kernels::{IndexPattern, MicroOp, OpKind, SpmvKernel};
use crate::matrix::jds::SpmvVisitor;
use crate::simulator::{simulate_microbench, MachineSpec, SimOptions};

/// Calibrated per-machine gather cost curve.
#[derive(Debug, Clone)]
pub struct CostCurve {
    pub machine: String,
    /// (mean stride, cycles per IRSCP update)
    pub points: Vec<(f64, f64)>,
    /// Dense-stream baseline (PDSCP cycles per update).
    pub dense: f64,
}

impl CostCurve {
    /// Calibrate on the simulator with geometric-stride IRSCP runs.
    pub fn calibrate(machine: &MachineSpec, n_iters: usize) -> Self {
        let opts = SimOptions { warmup: false, ..Default::default() };
        let strides = [1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0, 256.0, 512.0];
        let b_len = (n_iters * 600).max(1 << 20);
        let points = strides
            .iter()
            .map(|&k| {
                let op = MicroOp { kind: OpKind::Scp, pattern: IndexPattern::Geometric { mean: k } };
                let r = simulate_microbench(machine, op, n_iters, b_len, &opts, 42);
                (k, r.cycles_per_update)
            })
            .collect();
        let dense = simulate_microbench(
            machine,
            MicroOp { kind: OpKind::Scp, pattern: IndexPattern::Dense },
            n_iters,
            b_len,
            &opts,
            42,
        )
        .cycles_per_update;
        CostCurve { machine: machine.name.to_string(), points, dense }
    }

    /// Interpolated cycles/update at mean |stride| `k` (log-linear).
    pub fn cost(&self, k: f64) -> f64 {
        let k = k.max(1.0);
        let pts = &self.points;
        if k <= pts[0].0 {
            return pts[0].1;
        }
        if k >= pts[pts.len() - 1].0 {
            return pts[pts.len() - 1].1;
        }
        for w in pts.windows(2) {
            let (k0, c0) = w[0];
            let (k1, c1) = w[1];
            if k >= k0 && k <= k1 {
                let t = (k.ln() - k0.ln()) / (k1.ln() - k0.ln());
                return c0 + t * (c1 - c0);
            }
        }
        pts[pts.len() - 1].1
    }
}

/// Prediction for one storage scheme on one machine.
#[derive(Debug, Clone)]
pub struct Prediction {
    pub scheme: String,
    pub cycles_per_nnz: f64,
    pub mflops: f64,
}

/// Row-run statistics of a kernel walk (how often the result register is
/// flushed, and how many inner loops start).
fn run_stats(kernel: &SpmvKernel) -> (u64, u64) {
    struct S {
        prev: usize,
        row_changes: u64,
        loop_starts: u64,
        row_major: bool,
    }
    impl SpmvVisitor for S {
        fn update(&mut self, row: usize, _j: usize, _c: usize) {
            if row != self.prev {
                self.row_changes += 1;
            }
            let new_loop = if self.row_major {
                row != self.prev
            } else {
                row != self.prev.wrapping_add(1)
            };
            if new_loop {
                self.loop_starts += 1;
            }
            self.prev = row;
        }
    }
    let row_major = matches!(
        kernel.scheme(),
        crate::matrix::Scheme::Crs | crate::matrix::Scheme::NuJds { .. }
    );
    let mut s = S { prev: usize::MAX, row_changes: 0, loop_starts: 0, row_major };
    kernel.walk(&mut s);
    (s.row_changes, s.loop_starts)
}

/// Predict cycles/nnz for `kernel` on `machine` from its stride
/// distribution alone (no full simulation).
pub fn predict(machine: &MachineSpec, curve: &CostCurve, kernel: &SpmvKernel) -> Prediction {
    let dist = StrideDistribution::from_kernel(kernel);
    predict_with_dist(machine, curve, kernel, &dist)
}

/// [`predict`] with a caller-supplied stride distribution — avoids a
/// redundant O(nnz) kernel walk when the fingerprint is already in hand
/// (the tuning layer computes it once per matrix).
pub fn predict_with_dist(
    machine: &MachineSpec,
    curve: &CostCurve,
    kernel: &SpmvKernel,
    dist: &StrideDistribution,
) -> Prediction {
    let nnz = kernel.nnz().max(1) as f64;

    // Gather cost: expectation of the cost curve over the |stride|
    // distribution. Backward jumps break prefetch streams — charge them
    // at the random-access end of the curve.
    let worst = curve.points.last().map(|p| p.1).unwrap_or(0.0);
    let mut gather = 0.0;
    for (&s, &c) in &dist.counts {
        let frac = c as f64 / dist.total.max(1) as f64;
        let cost = if s < 0 {
            worst.max(curve.cost(s.unsigned_abs() as f64))
        } else {
            curve.cost(s as f64)
        };
        gather += frac * cost;
    }

    // Result-vector traffic: each row-run flush is a read+write of 8 B
    // (16 B of traffic) — but only if the line was evicted since its
    // last touch. The reuse span of a diag-major scheme is its block
    // (plain JDS: the whole matrix); if one sweep over that span fits in
    // the LLC, repeated flushes are free and y streams only once.
    let (row_changes, loop_starts) = run_stats(kernel);
    let hz = machine.hz();
    let bw_bytes_per_cycle = machine.node_bw_gbs / machine.sockets as f64 * 1e9 / hz;
    let nrows = kernel.nrows() as f64;
    let span_rows = match kernel.scheme() {
        crate::matrix::Scheme::Jds => nrows,
        crate::matrix::Scheme::NbJds { block }
        | crate::matrix::Scheme::RbJds { block }
        | crate::matrix::Scheme::SoJds { block } => (block as f64).min(nrows),
        // SELL-C-σ revisits a slice of C rows across its diagonals.
        crate::matrix::Scheme::SellCs { c, .. } => (c as f64).min(nrows),
        _ => 1.0, // CRS/NUJDS hold the row in a register
    };
    let llc = machine.l3.map(|c| c.size_bytes).unwrap_or(machine.l2.size_bytes) as f64;
    let sweep_bytes = span_rows * (nnz / nrows * 12.0 + 16.0);
    let y_flushes = if sweep_bytes > llc { row_changes as f64 } else { nrows };
    let y_cycles = y_flushes * 16.0 / bw_bytes_per_cycle / nnz;
    let loop_cycles = loop_starts as f64 * machine.loop_overhead_cycles / nnz;

    let cycles_per_nnz = gather + y_cycles + loop_cycles;
    Prediction {
        scheme: kernel.scheme().name(),
        cycles_per_nnz,
        mflops: 2.0 * hz / cycles_per_nnz / 1e6,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen;
    use crate::matrix::Scheme;
    use crate::sched::Schedule;
    use crate::simulator::{simulate_spmv, Placement};

    #[test]
    fn cost_curve_is_monotone_enough() {
        let m = MachineSpec::nehalem();
        let c = CostCurve::calibrate(&m, 20_000);
        // dense much cheaper than sparse gather at k=8
        assert!(c.dense < c.cost(8.0));
        // large strides cost more than unit stride
        assert!(c.cost(256.0) > c.cost(1.0));
        // interpolation between calibrated points is bounded
        let mid = c.cost(12.0);
        assert!(mid >= c.cost(8.0).min(c.cost(16.0)) - 1e-9);
        assert!(mid <= c.cost(8.0).max(c.cost(16.0)) + 1e-9);
    }

    use std::sync::OnceLock;

    /// Memory-bound validation workload: the input vector alone exceeds
    /// the Woodcrest LLC, and gather strides are wide — the regime the
    /// fingerprint model is built for.
    fn big_band() -> &'static crate::matrix::Coo {
        static COO: OnceLock<crate::matrix::Coo> = OnceLock::new();
        COO.get_or_init(|| {
            let mut rng = crate::util::rng::Rng::new(3);
            gen::random_band(700_000, 14, 400_000, &mut rng)
        })
    }

    #[test]
    fn model_predicts_scheme_ordering() {
        // The model must reproduce the paper's central result: CRS is
        // the fastest scheme and blocking recovers most of JDS's loss
        // (Fig 6b) — in the memory-bound regime.
        let m = MachineSpec::woodcrest();
        let curve = CostCurve::calibrate(&m, 20_000);
        let crs = predict(&m, &curve, &SpmvKernel::build(big_band(), Scheme::Crs));
        let jds = predict(&m, &curve, &SpmvKernel::build(big_band(), Scheme::Jds));
        assert!(
            crs.cycles_per_nnz < jds.cycles_per_nnz,
            "CRS {:.2} must beat plain JDS {:.2}",
            crs.cycles_per_nnz,
            jds.cycles_per_nnz
        );
        let nb = predict(
            &m,
            &curve,
            &SpmvKernel::build(big_band(), Scheme::NbJds { block: 1000 }),
        );
        assert!(
            nb.cycles_per_nnz < jds.cycles_per_nnz,
            "NBJDS {:.2} must beat plain JDS {:.2}",
            nb.cycles_per_nnz,
            jds.cycles_per_nnz
        );
    }

    #[test]
    fn prediction_within_factor_of_simulation() {
        let m = MachineSpec::woodcrest();
        let curve = CostCurve::calibrate(&m, 20_000);
        for scheme in [Scheme::Crs, Scheme::NbJds { block: 1000 }] {
            let k = SpmvKernel::build(big_band(), scheme);
            let pred = predict(&m, &curve, &k);
            let sim = simulate_spmv(
                &m,
                &k,
                1,
                1,
                Schedule::Static { chunk: None },
                Placement::FirstTouchStatic,
                &SimOptions { warmup: false, ..Default::default() },
            );
            let ratio = pred.cycles_per_nnz / (sim.cycles / sim.updates as f64);
            assert!(
                (0.33..3.0).contains(&ratio),
                "{scheme:?}: prediction/simulation ratio {ratio:.2}"
            );
        }
    }
}
