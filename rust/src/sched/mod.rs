//! OpenMP-style loop scheduling (§5): static / dynamic / guided with
//! chunk sizes, plus a real thread-pool executor for wall-clock parallel
//! SpMV on the host.
//!
//! The simulator consumes the *assignment* (which thread owns which
//! iteration); the host executor actually runs it with `std::thread`.

use crate::matrix::Crs;

/// OpenMP-like scheduling policy.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Schedule {
    /// `schedule(static[, chunk])`. `chunk = None` means contiguous
    /// near-equal blocks (the OpenMP default).
    Static { chunk: Option<usize> },
    /// `schedule(dynamic, chunk)`: threads grab the next chunk when idle.
    Dynamic { chunk: usize },
    /// `schedule(guided, min_chunk)`: exponentially shrinking chunks.
    Guided { min_chunk: usize },
}

impl Schedule {
    pub fn name(&self) -> String {
        match self {
            Schedule::Static { chunk: None } => "static".to_string(),
            Schedule::Static { chunk: Some(c) } => format!("static,{c}"),
            Schedule::Dynamic { chunk } => format!("dynamic,{chunk}"),
            Schedule::Guided { min_chunk } => format!("guided,{min_chunk}"),
        }
    }

    pub fn parse(s: &str) -> anyhow::Result<Self> {
        let (name, chunk) = match s.split_once(',') {
            Some((n, c)) => (n, Some(c.trim().parse::<usize>()?)),
            None => (s, None),
        };
        Ok(match name.trim().to_ascii_lowercase().as_str() {
            "static" => Schedule::Static { chunk },
            "dynamic" => Schedule::Dynamic { chunk: chunk.unwrap_or(1) },
            "guided" => Schedule::Guided { min_chunk: chunk.unwrap_or(1) },
            other => anyhow::bail!("unknown schedule '{other}'"),
        })
    }
}

/// The result of scheduling `n_items` iterations onto `n_threads`.
#[derive(Debug, Clone)]
pub struct Assignment {
    /// Owner thread of each iteration.
    pub owner: Vec<u16>,
    pub n_threads: usize,
    /// Chunks as (start, end, thread), in dispatch order.
    pub chunks: Vec<(usize, usize, u16)>,
}

impl Assignment {
    /// Iterations owned by `t`, as ranges.
    pub fn ranges_of(&self, t: u16) -> Vec<(usize, usize)> {
        self.chunks
            .iter()
            .filter(|&&(_, _, th)| th == t)
            .map(|&(a, b, _)| (a, b))
            .collect()
    }

    /// Total weight per thread (for imbalance diagnostics).
    pub fn load_per_thread(&self, weights: &[f64]) -> Vec<f64> {
        let mut load = vec![0.0; self.n_threads];
        for (i, &t) in self.owner.iter().enumerate() {
            load[t as usize] += weights[i];
        }
        load
    }
}

/// Build the iteration→thread assignment for a policy. `weights[i]` is
/// the estimated cost of iteration `i` (e.g. nnz of row i); dynamic and
/// guided policies dispatch each chunk to the earliest-finishing thread,
/// which is the deterministic idealization of work stealing.
pub fn assign(policy: Schedule, n_items: usize, weights: &[f64], n_threads: usize) -> Assignment {
    assert!(n_threads > 0);
    assert_eq!(weights.len(), n_items);
    let mut owner = vec![0u16; n_items];
    let mut chunks = Vec::new();
    match policy {
        Schedule::Static { chunk: None } => {
            // Contiguous blocks of ~n/threads.
            let per = n_items.div_ceil(n_threads.max(1));
            for t in 0..n_threads {
                let a = (t * per).min(n_items);
                let b = ((t + 1) * per).min(n_items);
                if a < b {
                    owner[a..b].fill(t as u16);
                    chunks.push((a, b, t as u16));
                }
            }
        }
        Schedule::Static { chunk: Some(c) } => {
            let c = c.max(1);
            let mut start = 0;
            let mut idx = 0usize;
            while start < n_items {
                let end = (start + c).min(n_items);
                let t = (idx % n_threads) as u16;
                owner[start..end].fill(t);
                chunks.push((start, end, t));
                start = end;
                idx += 1;
            }
        }
        Schedule::Dynamic { chunk } => {
            let c = chunk.max(1);
            let mut busy = vec![0.0f64; n_threads];
            let mut start = 0;
            while start < n_items {
                let end = (start + c).min(n_items);
                // earliest-finishing thread takes the next chunk
                let t = (0..n_threads)
                    .min_by(|&a, &b| busy[a].partial_cmp(&busy[b]).unwrap())
                    .unwrap();
                let w: f64 = weights[start..end].iter().sum();
                busy[t] += w;
                owner[start..end].fill(t as u16);
                chunks.push((start, end, t as u16));
                start = end;
            }
        }
        Schedule::Guided { min_chunk } => {
            let mc = min_chunk.max(1);
            let mut busy = vec![0.0f64; n_threads];
            let mut start = 0;
            while start < n_items {
                let remaining = n_items - start;
                let c = (remaining.div_ceil(n_threads)).max(mc);
                let end = (start + c).min(n_items);
                let t = (0..n_threads)
                    .min_by(|&a, &b| busy[a].partial_cmp(&busy[b]).unwrap())
                    .unwrap();
                let w: f64 = weights[start..end].iter().sum();
                busy[t] += w;
                owner[start..end].fill(t as u16);
                chunks.push((start, end, t as u16));
                start = end;
            }
        }
    }
    Assignment { owner, n_threads, chunks }
}

/// Row weights for SpMV scheduling: nnz per row.
pub fn row_weights(crs: &Crs) -> Vec<f64> {
    (0..crs.nrows)
        .map(|i| (crs.row_ptr[i + 1] - crs.row_ptr[i]) as f64)
        .collect()
}

/// Real OpenMP-style parallel CRS SpMV on host threads. Each row has
/// exactly one owner, so per-thread writes to `y` are disjoint.
pub fn parallel_spmv(crs: &Crs, x: &[f64], y: &mut [f64], assignment: &Assignment) {
    assert_eq!(x.len(), crs.ncols);
    assert_eq!(y.len(), crs.nrows);
    struct SendPtr(*mut f64);
    // SAFETY: each row index has exactly one owning thread (the
    // assignment partitions rows), so writes through the pointer are
    // disjoint; the scope below keeps `y` alive past every write.
    unsafe impl Send for SendPtr {}
    // SAFETY: shared access is address arithmetic only; writes land on
    // the disjoint per-owner rows described above.
    unsafe impl Sync for SendPtr {}
    let y_ptr = SendPtr(y.as_mut_ptr());
    let y_ref = &y_ptr;
    // audit:allow(thread_spawn): legacy scoped-thread reference path, benchmarked against Engine
    std::thread::scope(|scope| {
        for t in 0..assignment.n_threads as u16 {
            let ranges = assignment.ranges_of(t);
            if ranges.is_empty() {
                continue;
            }
            scope.spawn(move || {
                for (a, b) in ranges {
                    for i in a..b {
                        let mut sum = 0.0;
                        for j in crs.row_ptr[i]..crs.row_ptr[i + 1] {
                            sum += crs.val[j] * x[crs.col_idx[j] as usize];
                        }
                        // SAFETY: row ownership is disjoint across threads.
                        unsafe { *y_ref.0.add(i) = sum };
                    }
                }
            });
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen;
    use crate::util::rng::Rng;

    #[test]
    fn static_default_is_contiguous() {
        let w = vec![1.0; 10];
        let a = assign(Schedule::Static { chunk: None }, 10, &w, 3);
        assert_eq!(a.owner, vec![0, 0, 0, 0, 1, 1, 1, 1, 2, 2]);
        assert_eq!(a.chunks.len(), 3);
    }

    #[test]
    fn static_chunked_round_robin() {
        let w = vec![1.0; 8];
        let a = assign(Schedule::Static { chunk: Some(2) }, 8, &w, 2);
        assert_eq!(a.owner, vec![0, 0, 1, 1, 0, 0, 1, 1]);
    }

    #[test]
    fn dynamic_balances_skewed_weights() {
        // One heavy iteration; dynamic should not pile more work on the
        // thread that got it.
        let mut w = vec![1.0; 100];
        w[0] = 200.0;
        let a = assign(Schedule::Dynamic { chunk: 1 }, 100, &w, 4);
        let load = a.load_per_thread(&w);
        let heavy = load.iter().cloned().fold(f64::MIN, f64::max);
        let light: f64 = load.iter().sum::<f64>() - heavy;
        // heavy thread got essentially just the big item
        assert!(heavy <= 201.0);
        assert!(light >= 98.0);
    }

    #[test]
    fn guided_chunks_shrink() {
        let w = vec![1.0; 1000];
        let a = assign(Schedule::Guided { min_chunk: 4 }, 1000, &w, 4);
        let sizes: Vec<usize> = a.chunks.iter().map(|&(s, e, _)| e - s).collect();
        assert!(sizes[0] > *sizes.last().unwrap());
        assert!(*sizes.last().unwrap() >= 4 || sizes.iter().sum::<usize>() == 1000);
        assert!(sizes.windows(2).all(|p| p[0] >= p[1] || p[1] >= 4));
    }

    #[test]
    fn every_item_owned_once() {
        let w = vec![1.0; 777];
        for pol in [
            Schedule::Static { chunk: None },
            Schedule::Static { chunk: Some(10) },
            Schedule::Dynamic { chunk: 16 },
            Schedule::Guided { min_chunk: 8 },
        ] {
            let a = assign(pol, 777, &w, 5);
            let total: usize = a.chunks.iter().map(|&(s, e, _)| e - s).sum();
            assert_eq!(total, 777, "{pol:?}");
            // chunks cover [0,777) in order without overlap
            let mut pos = 0;
            for &(s, e, _) in &a.chunks {
                assert_eq!(s, pos);
                pos = e;
            }
        }
    }

    #[test]
    fn parallel_spmv_matches_serial() {
        use crate::matrix::{Crs, SpMv};
        let mut rng = Rng::new(50);
        let coo = gen::random_band(500, 8, 60, &mut rng);
        let crs = Crs::from_coo(&coo);
        let mut x = vec![0.0; 500];
        rng.fill_f64(&mut x, -1.0, 1.0);
        let mut y_ser = vec![0.0; 500];
        crs.spmv(&x, &mut y_ser);
        let w = row_weights(&crs);
        for pol in [
            Schedule::Static { chunk: None },
            Schedule::Static { chunk: Some(7) },
            Schedule::Dynamic { chunk: 13 },
            Schedule::Guided { min_chunk: 2 },
        ] {
            let a = assign(pol, 500, &w, 4);
            let mut y_par = vec![0.0; 500];
            parallel_spmv(&crs, &x, &mut y_par, &a);
            assert!(
                crate::util::stats::max_abs_diff(&y_ser, &y_par) < 1e-14,
                "{pol:?}"
            );
        }
    }

    #[test]
    fn schedule_parse() {
        assert_eq!(Schedule::parse("static").unwrap(), Schedule::Static { chunk: None });
        assert_eq!(
            Schedule::parse("static,100").unwrap(),
            Schedule::Static { chunk: Some(100) }
        );
        assert_eq!(Schedule::parse("dynamic,8").unwrap(), Schedule::Dynamic { chunk: 8 });
        assert_eq!(Schedule::parse("guided").unwrap(), Schedule::Guided { min_chunk: 1 });
        assert!(Schedule::parse("bogus").is_err());
    }
}
