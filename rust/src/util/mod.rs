//! Shared infrastructure: RNG, statistics, bench harness, CLI parsing,
//! report/table rendering. All built from scratch — no external crates for
//! these exist in the offline vendor set.

pub mod alloc;
pub mod bench;
pub mod cli;
pub mod report;
pub mod rng;
pub mod stats;
