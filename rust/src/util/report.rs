//! Text table / CSV reporters. Every experiment regenerates its paper
//! table or figure as an aligned text table (human) and optionally CSV
//! (machine), so figures can be re-plotted externally.

use std::fmt::Write as _;
use std::io::Write as _;
use std::path::Path;

/// A simple column-aligned table builder.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width {} != header width {}",
            cells.len(),
            self.header.len()
        );
        self.rows.push(cells);
        self
    }

    /// Render as an aligned text table.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            let _ = writeln!(out, "## {}", self.title);
        }
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for i in 0..ncol {
                if i > 0 {
                    line.push_str("  ");
                }
                let _ = write!(line, "{:<width$}", cells[i], width = widths[i]);
            }
            line
        };
        let _ = writeln!(out, "{}", fmt_row(&self.header, &widths));
        let total: usize = widths.iter().sum::<usize>() + 2 * (ncol - 1);
        let _ = writeln!(out, "{}", "-".repeat(total));
        for row in &self.rows {
            let _ = writeln!(out, "{}", fmt_row(row, &widths));
        }
        out
    }

    /// Render as CSV (RFC-4180-ish; quotes cells containing commas).
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| -> String {
            if s.contains(',') || s.contains('"') || s.contains('\n') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        let _ = writeln!(
            out,
            "{}",
            self.header.iter().map(|h| esc(h)).collect::<Vec<_>>().join(",")
        );
        for row in &self.rows {
            let _ = writeln!(
                out,
                "{}",
                row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(",")
            );
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }

    /// Write CSV alongside printing if `path` is Some.
    pub fn maybe_write_csv(&self, path: Option<&str>) -> anyhow::Result<()> {
        if let Some(p) = path {
            if let Some(parent) = Path::new(p).parent() {
                std::fs::create_dir_all(parent)?;
            }
            let mut f = std::fs::File::create(p)?;
            f.write_all(self.to_csv().as_bytes())?;
            eprintln!("wrote {p}");
        }
        Ok(())
    }
}

/// Format a f64 with engineering-friendly precision.
pub fn f(x: f64) -> String {
    if x == 0.0 {
        "0".to_string()
    } else if x.abs() >= 1000.0 {
        format!("{x:.0}")
    } else if x.abs() >= 10.0 {
        format!("{x:.1}")
    } else if x.abs() >= 0.01 {
        format!("{x:.3}")
    } else {
        format!("{x:.2e}")
    }
}

/// Format MFlop/s or similar large rates.
pub fn rate(x: f64) -> String {
    if x >= 1e9 {
        format!("{:.2} G", x / 1e9)
    } else if x >= 1e6 {
        format!("{:.1} M", x / 1e6)
    } else if x >= 1e3 {
        format!("{:.1} k", x / 1e3)
    } else {
        format!("{x:.1} ")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("demo", &["op", "cycles"]);
        t.row(vec!["PDADD".into(), "2.1".into()]);
        t.row(vec!["IRSCP".into(), "31.5".into()]);
        let s = t.render();
        assert!(s.contains("## demo"));
        assert!(s.contains("PDADD"));
        // header and rows aligned: 'op' column padded to 5 (PDADD)
        assert!(s.lines().count() >= 4);
    }

    #[test]
    fn csv_escapes() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(vec!["x,y".into(), "z\"q".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"x,y\""));
        assert!(csv.contains("\"z\"\"q\""));
    }

    #[test]
    #[should_panic]
    fn row_width_mismatch_panics() {
        let mut t = Table::new("", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(f(0.0), "0");
        assert_eq!(f(1234.0), "1234");
        assert_eq!(f(12.34), "12.3");
        assert!(rate(2.5e9).starts_with("2.50 G"));
        assert!(rate(3.0e6).starts_with("3.0 M"));
    }
}
