//! Minimal wall-clock benchmark harness (criterion is not available in this
//! offline environment). Provides warmup, repeated timed runs, and robust
//! summary statistics. All `cargo bench` targets are `harness = false`
//! binaries built on this module.

use std::time::{Duration, Instant};

use super::stats;

/// Result of one benchmark: per-iteration wall times in seconds.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    /// Seconds per timed run (each run may wrap `inner_iters` kernel calls).
    pub samples: Vec<f64>,
    /// Number of kernel invocations folded into each sample.
    pub inner_iters: usize,
    /// Work items (e.g. non-zeros) processed per kernel invocation; used for
    /// derived throughput metrics.
    pub items_per_iter: u64,
    /// Floating-point operations per kernel invocation.
    pub flops_per_iter: u64,
}

impl BenchResult {
    /// Median seconds for a single kernel invocation.
    pub fn median_secs(&self) -> f64 {
        stats::median(&self.samples) / self.inner_iters as f64
    }

    pub fn min_secs(&self) -> f64 {
        stats::min(&self.samples) / self.inner_iters as f64
    }

    /// Median absolute deviation of the per-invocation time.
    pub fn mad_secs(&self) -> f64 {
        stats::mad(&self.samples) / self.inner_iters as f64
    }

    /// MFlop/s at the median.
    pub fn mflops(&self) -> f64 {
        if self.flops_per_iter == 0 {
            return 0.0;
        }
        self.flops_per_iter as f64 / self.median_secs() / 1e6
    }

    /// Items (nnz, elements) per second at the median.
    pub fn items_per_sec(&self) -> f64 {
        self.items_per_iter as f64 / self.median_secs()
    }

    /// Nanoseconds per item at the median.
    pub fn ns_per_item(&self) -> f64 {
        if self.items_per_iter == 0 {
            return 0.0;
        }
        self.median_secs() * 1e9 / self.items_per_iter as f64
    }

    /// One-line human-readable summary.
    pub fn summary(&self) -> String {
        format!(
            "{:<40} median {:>10.3} us  (mad {:>8.3} us)  {:>10.1} MFlop/s  {:>8.2} ns/item",
            self.name,
            self.median_secs() * 1e6,
            self.mad_secs() * 1e6,
            self.mflops(),
            self.ns_per_item()
        )
    }
}

/// Benchmark configuration.
#[derive(Debug, Clone)]
pub struct Bench {
    pub warmup: Duration,
    pub samples: usize,
    pub min_sample_time: Duration,
}

impl Default for Bench {
    fn default() -> Self {
        Self {
            warmup: Duration::from_millis(200),
            samples: 11,
            min_sample_time: Duration::from_millis(20),
        }
    }
}

impl Bench {
    pub fn quick() -> Self {
        Self {
            warmup: Duration::from_millis(50),
            samples: 5,
            min_sample_time: Duration::from_millis(5),
        }
    }

    /// Run `f` under this configuration. `f` must perform one logical kernel
    /// invocation per call and return a value that is consumed via
    /// `std::hint::black_box` to defeat dead-code elimination.
    pub fn run<T, F: FnMut() -> T>(
        &self,
        name: &str,
        items_per_iter: u64,
        flops_per_iter: u64,
        mut f: F,
    ) -> BenchResult {
        // Warmup, and measure single-call cost to size inner_iters.
        let warm_start = Instant::now();
        let mut calls = 0u64;
        let mut one = Duration::from_secs(0);
        while warm_start.elapsed() < self.warmup || calls < 3 {
            let t = Instant::now();
            std::hint::black_box(f());
            one = t.elapsed();
            calls += 1;
            if calls > 1_000_000 {
                break;
            }
        }
        let inner_iters = if one >= self.min_sample_time {
            1
        } else {
            ((self.min_sample_time.as_secs_f64() / one.as_secs_f64().max(1e-9)).ceil() as usize)
                .clamp(1, 1_000_000)
        };
        let mut samples = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t = Instant::now();
            for _ in 0..inner_iters {
                std::hint::black_box(f());
            }
            samples.push(t.elapsed().as_secs_f64());
        }
        BenchResult {
            name: name.to_string(),
            samples,
            inner_iters,
            items_per_iter,
            flops_per_iter,
        }
    }
}

/// Convenience: is the process running in "quick bench" mode? Set by the
/// Makefile / CI via SPMVPERF_BENCH_QUICK=1 to keep bench suites fast.
pub fn quick_mode() -> bool {
    std::env::var("SPMVPERF_BENCH_QUICK").map(|v| v == "1").unwrap_or(false)
}

/// Standard bench configuration honoring quick mode.
pub fn default_bench() -> Bench {
    if quick_mode() {
        Bench::quick()
    } else {
        Bench::default()
    }
}

/// Write a `BENCH_*.json` perf-trajectory file under `results/` and log
/// the outcome — the one place the bench binaries' emission contract
/// (location + error handling) lives.
pub fn write_bench_json(filename: &str, json: &str) {
    let path = format!("results/{filename}");
    if let Err(e) =
        std::fs::create_dir_all("results").and_then(|_| std::fs::write(&path, json.as_bytes()))
    {
        eprintln!("could not write {path}: {e}");
    } else {
        eprintln!("wrote {path}");
    }
}

// ---------------------------------------------------------------------
// BENCH_*.json trajectory comparison (the CI regression gate).
//
// The bench binaries emit flat one-object-per-line entries inside a
// `"results"` array; no JSON library exists offline, so the comparator
// parses exactly that shape: a line is an entry iff it contains an
// `"mflops"` field, its identity is the values of the known identity
// keys below, and everything else on the line is ignored. Auto-picked
// fields (scheme, σ, schedule) deliberately do NOT identify an entry —
// they may legitimately differ between baseline and current runs.
// ---------------------------------------------------------------------

/// Keys whose values identify a bench entry across runs. `pub` because
/// the audit's `bench_baseline` rule checks every committed baseline's
/// identity keys are still produced by some emitter.
pub const BENCH_IDENT_KEYS: &[&str] = &["bench", "matrix", "name", "case", "config", "policy"];

/// One comparable data point extracted from a `BENCH_*.json` file.
#[derive(Debug, Clone, PartialEq)]
pub struct BenchEntry {
    /// `/`-joined values of the identity keys, e.g.
    /// `holstein-hubbard/heuristic`.
    pub label: String,
    pub mflops: f64,
}

/// Pull `"key": "value"` string pairs and the `"mflops"` number out of a
/// single flat JSON object line. Returns `None` for lines that are not
/// bench entries.
fn parse_entry_line(line: &str) -> Option<BenchEntry> {
    let mflops = extract_number(line, "mflops")?;
    let mut parts = Vec::new();
    for key in BENCH_IDENT_KEYS {
        if let Some(v) = extract_string(line, key) {
            parts.push(v);
        }
    }
    if parts.is_empty() {
        return None;
    }
    Some(BenchEntry { label: parts.join("/"), mflops })
}

/// Value of `"key": <number>` in `line`, if present.
fn extract_number(line: &str, key: &str) -> Option<f64> {
    let pat = format!("\"{key}\":");
    let at = line.find(&pat)? + pat.len();
    let rest = line[at..].trim_start();
    let end = rest
        .find(|c: char| !(c.is_ascii_digit() || c == '.' || c == '-' || c == '+' || c == 'e'))
        .unwrap_or(rest.len());
    rest[..end].parse().ok()
}

/// Value of `"key": "value"` in `line`, if present.
fn extract_string(line: &str, key: &str) -> Option<String> {
    let pat = format!("\"{key}\":");
    let at = line.find(&pat)? + pat.len();
    let rest = line[at..].trim_start().strip_prefix('"')?;
    Some(rest[..rest.find('"')?].to_string())
}

/// All comparable entries of a `BENCH_*.json` document.
pub fn parse_bench_entries(json: &str) -> Vec<BenchEntry> {
    json.lines().filter_map(parse_entry_line).collect()
}

/// One row of a baseline-vs-current comparison.
#[derive(Debug, Clone)]
pub struct BenchComparison {
    pub label: String,
    /// `None` when the entry exists only in `current` (new coverage —
    /// reported so renames/additions are visible, never failing).
    pub baseline_mflops: Option<f64>,
    /// `None` when the current run lost this entry entirely.
    pub current_mflops: Option<f64>,
    pub ok: bool,
}

/// Compare two trajectory documents. Every baseline entry must exist in
/// `current`: positive-throughput entries must also reach at least
/// `(1 - tolerance) ×` their baseline GFlop/s, while `mflops <= 0`
/// placeholders are presence-only floors — a silently dropped config
/// used to pass the gate through the old skip-placeholders rule, and
/// now fails as MISSING. Entries only present in `current` are reported
/// as new coverage (passing), so renamed configs show up as a
/// MISSING/new pair instead of vanishing.
pub fn compare_bench_json(baseline: &str, current: &str, tolerance: f64) -> Vec<BenchComparison> {
    let base = parse_bench_entries(baseline);
    let cur = parse_bench_entries(current);
    let mut rows: Vec<BenchComparison> = base
        .iter()
        .map(|b| {
            let found = cur.iter().find(|c| c.label == b.label).map(|c| c.mflops);
            let ok = if b.mflops > 0.0 {
                found.is_some_and(|m| m >= b.mflops * (1.0 - tolerance))
            } else {
                found.is_some()
            };
            BenchComparison {
                label: b.label.clone(),
                baseline_mflops: Some(b.mflops),
                current_mflops: found,
                ok,
            }
        })
        .collect();
    for c in &cur {
        let known = base.iter().any(|b| b.label == c.label)
            || rows.iter().any(|r| r.label == c.label && r.baseline_mflops.is_none());
        if !known {
            rows.push(BenchComparison {
                label: c.label.clone(),
                baseline_mflops: None,
                current_mflops: Some(c.mflops),
                ok: true,
            });
        }
    }
    rows
}

/// Rewrite a measured trajectory document into a committable baseline:
/// every positive-throughput entry's `"mflops"` becomes `factor ×` the
/// measured value (a floor with regression headroom, e.g. 0.7×), while
/// placeholder entries (`mflops <= 0`) and non-entry lines pass through
/// untouched. Behind `spmvperf benchdiff --suggest-floors` — the one
/// sanctioned way to refresh `results-baseline/` off a real run instead
/// of hand-editing numbers.
pub fn suggest_floors(current: &str, factor: f64) -> String {
    let mut out: String = current
        .lines()
        .map(|line| match parse_entry_line(line) {
            Some(e) if e.mflops > 0.0 => rewrite_mflops(line, e.mflops * factor),
            _ => line.to_string(),
        })
        .collect::<Vec<_>>()
        .join("\n");
    if current.ends_with('\n') {
        out.push('\n');
    }
    out
}

/// Replace the number following `"mflops":` on `line` with `floor`
/// (one decimal, matching the bench emitters), preserving everything
/// else byte-for-byte.
fn rewrite_mflops(line: &str, floor: f64) -> String {
    let pat = "\"mflops\":";
    let Some(at) = line.find(pat) else {
        return line.to_string();
    };
    let start = at + pat.len();
    let rest = &line[start..];
    let num_start = start + (rest.len() - rest.trim_start().len());
    let tail = &line[num_start..];
    let end = tail
        .find(|c: char| !(c.is_ascii_digit() || c == '.' || c == '-' || c == '+' || c == 'e'))
        .unwrap_or(tail.len());
    format!("{}{:.1}{}", &line[..num_start], floor, &tail[end..])
}

/// File-level face of [`suggest_floors`]: reads a measured trajectory
/// and returns the floored baseline text for the caller to print or
/// write.
pub fn suggest_floors_file(current: &std::path::Path, factor: f64) -> anyhow::Result<String> {
    use anyhow::Context;
    anyhow::ensure!(
        factor > 0.0 && factor <= 1.0,
        "--factor must be in (0, 1], got {factor}"
    );
    let c = std::fs::read_to_string(current)
        .with_context(|| format!("reading current {}", current.display()))?;
    let entries = parse_bench_entries(&c);
    anyhow::ensure!(
        !entries.is_empty(),
        "{} holds no bench entries to floor",
        current.display()
    );
    Ok(suggest_floors(&c, factor))
}

/// File-level comparator behind `spmvperf benchdiff`: prints one line
/// per entry (including current-only "new" entries) and returns whether
/// every baseline entry passed.
pub fn compare_bench_files(
    baseline: &std::path::Path,
    current: &std::path::Path,
    tolerance: f64,
) -> anyhow::Result<bool> {
    use anyhow::Context;
    let b = std::fs::read_to_string(baseline)
        .with_context(|| format!("reading baseline {}", baseline.display()))?;
    let c = std::fs::read_to_string(current)
        .with_context(|| format!("reading current {}", current.display()))?;
    let rows = compare_bench_json(&b, &c, tolerance);
    anyhow::ensure!(
        rows.iter().any(|r| r.baseline_mflops.is_some()),
        "baseline {} holds no comparable entries",
        baseline.display()
    );
    let mut all_ok = true;
    for r in &rows {
        match (r.baseline_mflops, r.current_mflops) {
            (Some(b), Some(m)) if b > 0.0 => println!(
                "{:>10}  {:<50} baseline {b:>10.1} MFlop/s  current {m:>10.1} MFlop/s ({:+.1}%)",
                if r.ok { "ok" } else { "REGRESSION" },
                r.label,
                (m / b - 1.0) * 100.0
            ),
            (Some(_), Some(m)) => println!(
                "{:>10}  {:<50} placeholder baseline       current {m:>10.1} MFlop/s",
                "present", r.label
            ),
            (Some(b), None) => println!(
                "{:>10}  {:<50} baseline {b:>10.1} MFlop/s  current MISSING",
                "MISSING", r.label
            ),
            (None, Some(m)) => println!(
                "{:>10}  {:<50} not in baseline            current {m:>10.1} MFlop/s",
                "new", r.label
            ),
            (None, None) => unreachable!("a comparison row names at least one side"),
        }
        all_ok &= r.ok;
    }
    Ok(all_ok)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let b = Bench {
            warmup: Duration::from_millis(1),
            samples: 3,
            min_sample_time: Duration::from_micros(200),
        };
        let data: Vec<f64> = (0..1000).map(|i| i as f64).collect();
        let r = b.run("sum", 1000, 1000, || data.iter().sum::<f64>());
        assert_eq!(r.samples.len(), 3);
        assert!(r.median_secs() > 0.0);
        assert!(r.mflops() > 0.0);
    }

    const BASELINE: &str = r#"{
  "bench": "tune_policies",
  "results": [
    {"matrix": "hh", "policy": "heuristic", "scheme": "sellcs", "mflops": 100.0},
    {"matrix": "hh", "policy": "fixed", "scheme": "sellcs", "mflops": 80.0},
    {"matrix": "band", "policy": "heuristic", "mflops": 0.0}
  ]
}"#;

    #[test]
    fn parses_flat_entry_lines() {
        let entries = parse_bench_entries(BASELINE);
        assert_eq!(entries.len(), 3);
        assert_eq!(entries[0].label, "hh/heuristic");
        assert_eq!(entries[0].mflops, 100.0);
        // Auto-picked fields (scheme) must not enter the identity.
        assert!(!entries[0].label.contains("sellcs"));
        // Lines without mflops are not entries.
        assert!(parse_bench_entries("{\n  \"bench\": \"x\"\n}").is_empty());
    }

    #[test]
    fn comparator_passes_within_tolerance_and_reports_added_keys() {
        let current = r#"{"results": [
    {"matrix": "hh", "policy": "heuristic", "scheme": "crs", "mflops": 85.0},
    {"matrix": "hh", "policy": "fixed", "mflops": 95.0},
    {"matrix": "band", "policy": "heuristic", "mflops": 1.0},
    {"matrix": "new", "policy": "extra", "mflops": 1.0}
]}"#;
        let rows = compare_bench_json(BASELINE, current, 0.20);
        // 3 baseline rows (the placeholder is a presence-only floor and
        // is satisfied) + 1 reported added-key row.
        assert_eq!(rows.len(), 4);
        assert!(rows.iter().all(|r| r.ok), "{rows:?}");
        let band = rows.iter().find(|r| r.label == "band/heuristic").unwrap();
        assert_eq!(band.baseline_mflops, Some(0.0));
        assert_eq!(band.current_mflops, Some(1.0));
        // The added-key case: current-only entries are reported (so a
        // rename is visible as a MISSING/new pair), never failing.
        let new = rows.iter().find(|r| r.label == "new/extra").unwrap();
        assert_eq!(new.baseline_mflops, None);
        assert!(new.ok);
    }

    #[test]
    fn comparator_flags_regressions_and_missing_keys() {
        let current = r#"{"results": [
    {"matrix": "hh", "policy": "heuristic", "mflops": 70.0}
]}"#;
        let rows = compare_bench_json(BASELINE, current, 0.20);
        let heur = rows.iter().find(|r| r.label == "hh/heuristic").unwrap();
        assert!(!heur.ok, "70 < 100 * 0.8 must fail");
        let fixed = rows.iter().find(|r| r.label == "hh/fixed").unwrap();
        assert!(!fixed.ok, "missing entry must fail");
        assert_eq!(fixed.current_mflops, None);
        // The missing-key case the old comparator let through: a config
        // whose baseline is a placeholder floor, silently dropped from
        // the current run, must fail rather than pass via the
        // skip-placeholders rule.
        let band = rows.iter().find(|r| r.label == "band/heuristic").unwrap();
        assert!(!band.ok, "dropped placeholder config must fail the gate");
        assert_eq!(band.current_mflops, None);
    }

    /// ISSUE-6 satellite: `--suggest-floors` turns a measured run into a
    /// committable baseline — positive entries floored at `factor ×`,
    /// placeholders and structure untouched, and the output must
    /// round-trip through the comparator against the run it came from.
    #[test]
    fn suggest_floors_rewrites_measured_entries_only() {
        let current = r#"{
  "bench": "tune_policies",
  "results": [
    {"matrix": "hh", "policy": "heuristic", "scheme": "sellcs", "mflops": 100.0},
    {"matrix": "hh", "policy": "fixed", "scheme": "sellcs", "mflops": 80.5},
    {"matrix": "band", "policy": "heuristic", "mflops": 0.0}
  ]
}"#;
        let floored = suggest_floors(current, 0.7);
        let entries = parse_bench_entries(&floored);
        assert_eq!(entries.len(), 3);
        assert_eq!(entries[0].mflops, 70.0);
        assert!(
            (56.2..=56.5).contains(&entries[1].mflops),
            "80.5 × 0.7 floored to {}",
            entries[1].mflops
        );
        assert_eq!(entries[2].mflops, 0.0, "placeholders stay presence-only floors");
        // Identity and structure survive byte-for-byte outside the number.
        assert!(floored.contains("\"bench\": \"tune_policies\""));
        assert!(floored.contains("\"scheme\": \"sellcs\""));
        // The floored file passes the gate against the run it came from.
        let rows = compare_bench_json(&floored, current, 0.20);
        assert!(rows.iter().all(|r| r.ok), "{rows:?}");
    }

    #[test]
    fn number_extraction_handles_spacing_and_prefixed_keys() {
        let line = r#"  {"matrix": "m", "batch8_fused_mflops": 500.0, "mflops": 42.5},"#;
        assert_eq!(extract_number(line, "mflops"), Some(42.5));
        assert_eq!(extract_string(line, "matrix").as_deref(), Some("m"));
        assert_eq!(extract_number("no fields here", "mflops"), None);
    }

    #[test]
    fn summary_formats() {
        let r = BenchResult {
            name: "x".into(),
            samples: vec![0.001, 0.001, 0.001],
            inner_iters: 10,
            items_per_iter: 100,
            flops_per_iter: 200,
        };
        let s = r.summary();
        assert!(s.contains("median"));
        assert!(s.contains("MFlop/s"));
    }
}
