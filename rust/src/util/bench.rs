//! Minimal wall-clock benchmark harness (criterion is not available in this
//! offline environment). Provides warmup, repeated timed runs, and robust
//! summary statistics. All `cargo bench` targets are `harness = false`
//! binaries built on this module.

use std::time::{Duration, Instant};

use super::stats;

/// Result of one benchmark: per-iteration wall times in seconds.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    /// Seconds per timed run (each run may wrap `inner_iters` kernel calls).
    pub samples: Vec<f64>,
    /// Number of kernel invocations folded into each sample.
    pub inner_iters: usize,
    /// Work items (e.g. non-zeros) processed per kernel invocation; used for
    /// derived throughput metrics.
    pub items_per_iter: u64,
    /// Floating-point operations per kernel invocation.
    pub flops_per_iter: u64,
}

impl BenchResult {
    /// Median seconds for a single kernel invocation.
    pub fn median_secs(&self) -> f64 {
        stats::median(&self.samples) / self.inner_iters as f64
    }

    pub fn min_secs(&self) -> f64 {
        stats::min(&self.samples) / self.inner_iters as f64
    }

    /// Median absolute deviation of the per-invocation time.
    pub fn mad_secs(&self) -> f64 {
        stats::mad(&self.samples) / self.inner_iters as f64
    }

    /// MFlop/s at the median.
    pub fn mflops(&self) -> f64 {
        if self.flops_per_iter == 0 {
            return 0.0;
        }
        self.flops_per_iter as f64 / self.median_secs() / 1e6
    }

    /// Items (nnz, elements) per second at the median.
    pub fn items_per_sec(&self) -> f64 {
        self.items_per_iter as f64 / self.median_secs()
    }

    /// Nanoseconds per item at the median.
    pub fn ns_per_item(&self) -> f64 {
        if self.items_per_iter == 0 {
            return 0.0;
        }
        self.median_secs() * 1e9 / self.items_per_iter as f64
    }

    /// One-line human-readable summary.
    pub fn summary(&self) -> String {
        format!(
            "{:<40} median {:>10.3} us  (mad {:>8.3} us)  {:>10.1} MFlop/s  {:>8.2} ns/item",
            self.name,
            self.median_secs() * 1e6,
            self.mad_secs() * 1e6,
            self.mflops(),
            self.ns_per_item()
        )
    }
}

/// Benchmark configuration.
#[derive(Debug, Clone)]
pub struct Bench {
    pub warmup: Duration,
    pub samples: usize,
    pub min_sample_time: Duration,
}

impl Default for Bench {
    fn default() -> Self {
        Self {
            warmup: Duration::from_millis(200),
            samples: 11,
            min_sample_time: Duration::from_millis(20),
        }
    }
}

impl Bench {
    pub fn quick() -> Self {
        Self {
            warmup: Duration::from_millis(50),
            samples: 5,
            min_sample_time: Duration::from_millis(5),
        }
    }

    /// Run `f` under this configuration. `f` must perform one logical kernel
    /// invocation per call and return a value that is consumed via
    /// `std::hint::black_box` to defeat dead-code elimination.
    pub fn run<T, F: FnMut() -> T>(
        &self,
        name: &str,
        items_per_iter: u64,
        flops_per_iter: u64,
        mut f: F,
    ) -> BenchResult {
        // Warmup, and measure single-call cost to size inner_iters.
        let warm_start = Instant::now();
        let mut calls = 0u64;
        let mut one = Duration::from_secs(0);
        while warm_start.elapsed() < self.warmup || calls < 3 {
            let t = Instant::now();
            std::hint::black_box(f());
            one = t.elapsed();
            calls += 1;
            if calls > 1_000_000 {
                break;
            }
        }
        let inner_iters = if one >= self.min_sample_time {
            1
        } else {
            ((self.min_sample_time.as_secs_f64() / one.as_secs_f64().max(1e-9)).ceil() as usize)
                .clamp(1, 1_000_000)
        };
        let mut samples = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t = Instant::now();
            for _ in 0..inner_iters {
                std::hint::black_box(f());
            }
            samples.push(t.elapsed().as_secs_f64());
        }
        BenchResult {
            name: name.to_string(),
            samples,
            inner_iters,
            items_per_iter,
            flops_per_iter,
        }
    }
}

/// Convenience: is the process running in "quick bench" mode? Set by the
/// Makefile / CI via SPMVPERF_BENCH_QUICK=1 to keep bench suites fast.
pub fn quick_mode() -> bool {
    std::env::var("SPMVPERF_BENCH_QUICK").map(|v| v == "1").unwrap_or(false)
}

/// Standard bench configuration honoring quick mode.
pub fn default_bench() -> Bench {
    if quick_mode() {
        Bench::quick()
    } else {
        Bench::default()
    }
}

/// Write a `BENCH_*.json` perf-trajectory file under `results/` and log
/// the outcome — the one place the bench binaries' emission contract
/// (location + error handling) lives.
pub fn write_bench_json(filename: &str, json: &str) {
    let path = format!("results/{filename}");
    if let Err(e) =
        std::fs::create_dir_all("results").and_then(|_| std::fs::write(&path, json.as_bytes()))
    {
        eprintln!("could not write {path}: {e}");
    } else {
        eprintln!("wrote {path}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let b = Bench {
            warmup: Duration::from_millis(1),
            samples: 3,
            min_sample_time: Duration::from_micros(200),
        };
        let data: Vec<f64> = (0..1000).map(|i| i as f64).collect();
        let r = b.run("sum", 1000, 1000, || data.iter().sum::<f64>());
        assert_eq!(r.samples.len(), 3);
        assert!(r.median_secs() > 0.0);
        assert!(r.mflops() > 0.0);
    }

    #[test]
    fn summary_formats() {
        let r = BenchResult {
            name: "x".into(),
            samples: vec![0.001, 0.001, 0.001],
            inner_iters: 10,
            items_per_iter: 100,
            flops_per_iter: 200,
        };
        let s = r.summary();
        assert!(s.contains("median"));
        assert!(s.contains("MFlop/s"));
    }
}
