//! Pseudo-random number generation and distributions.
//!
//! No external `rand` crate is available in this environment, so we carry
//! our own generators: [`SplitMix64`] for seeding and [`Xoshiro256StarStar`]
//! as the workhorse. Both are well-known public-domain algorithms
//! (Blackman & Vigna). Determinism matters: every experiment seeds its RNG
//! explicitly so tables regenerate bit-identically.

/// SplitMix64: tiny, fast, used to expand a single `u64` seed into the
/// 256-bit state of Xoshiro (recommended seeding procedure).
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// Xoshiro256**: the general-purpose generator used throughout spmvperf.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second Gaussian variate from Box-Muller.
    gauss_spare: Option<f64>,
}

impl Rng {
    /// Seed deterministically from a single u64.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
            gauss_spare: None,
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, n)`. Uses Lemire's multiply-shift rejection
    /// method for unbiased results.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    #[inline]
    pub fn range_inclusive(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// Uniform usize in `[0, n)`.
    #[inline]
    pub fn index(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (polar form avoided for determinism
    /// of consumed stream length; the trig form consumes exactly one pair
    /// per two variates).
    pub fn gaussian(&mut self) -> f64 {
        if let Some(z) = self.gauss_spare.take() {
            return z;
        }
        // Avoid u1 == 0 exactly (log would be -inf).
        let u1 = loop {
            let u = self.f64();
            if u > 1e-300 {
                break u;
            }
        };
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.gauss_spare = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Normal with given mean and standard deviation.
    #[inline]
    pub fn gaussian_with(&mut self, mean: f64, std_dev: f64) -> f64 {
        mean + std_dev * self.gaussian()
    }

    /// Geometric distribution: number of Bernoulli(p) failures before the
    /// first success, i.e. support {0, 1, 2, ...}. Sampled by inversion.
    /// The paper's IRSCP benchmark draws a non-zero "for each entry of
    /// invec for which a drawn random number is smaller than 1/k", which
    /// makes successive strides geometric with mean k.
    pub fn geometric(&mut self, p: f64) -> u64 {
        debug_assert!(p > 0.0 && p <= 1.0);
        if p >= 1.0 {
            return 0;
        }
        let u = loop {
            let u = self.f64();
            if u > 1e-300 {
                break u;
            }
        };
        (u.ln() / (1.0 - p).ln()).floor() as u64
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Fill a slice with uniform f64 in [lo, hi).
    pub fn fill_f64(&mut self, xs: &mut [f64], lo: f64, hi: f64) {
        for x in xs.iter_mut() {
            *x = lo + (hi - lo) * self.f64();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 3);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut r = Rng::new(3);
        let n = 10u64;
        let mut counts = [0usize; 10];
        let trials = 100_000;
        for _ in 0..trials {
            counts[r.below(n) as usize] += 1;
        }
        let expect = trials as f64 / n as f64;
        for &c in &counts {
            assert!((c as f64 - expect).abs() < expect * 0.1, "count {c} vs {expect}");
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let z = r.gaussian();
            sum += z;
            sq += z * z;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn geometric_mean_matches() {
        let mut r = Rng::new(13);
        // mean stride k: success prob p = 1/k, mean failures = (1-p)/p = k-1,
        // so stride = 1 + failures has mean k.
        for &k in &[2u64, 8, 32, 128] {
            let p = 1.0 / k as f64;
            let n = 100_000;
            let total: u64 = (0..n).map(|_| 1 + r.geometric(p)).sum();
            let mean = total as f64 / n as f64;
            assert!(
                (mean - k as f64).abs() < 0.05 * k as f64 + 0.2,
                "k={k} mean={mean}"
            );
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn gaussian_with_scales() {
        let mut r = Rng::new(17);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            sum += r.gaussian_with(50.0, 10.0);
        }
        let mean = sum / n as f64;
        assert!((mean - 50.0).abs() < 0.2, "mean {mean}");
    }
}
