//! Tiny command-line argument parser (clap is not available offline).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and positional
//! arguments. Subcommand dispatch is handled by the caller (main.rs) by
//! peeling the first positional.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

/// Parsed arguments: options (`--key ...`) and positionals, in order.
#[derive(Debug, Clone, Default)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    positionals: Vec<String>,
    /// Keys that were actually consumed via get_*; used by `finish()` to
    /// reject typos.
    consumed: std::cell::RefCell<Vec<String>>,
}

/// Option names that take a value. Anything else starting with `--` is a
/// boolean flag. Keeping a central registry avoids `--size 100` being
/// parsed as flag `--size` + positional `100`.
const VALUE_OPTS: &[&str] = &[
    "size", "n", "nnz-per-row", "seed", "machine", "scheme", "schemes", "block",
    "blocks", "threads", "sockets", "chunk", "schedule", "stride", "strides",
    "mean", "variance", "k", "len", "reps", "out", "format", "artifact",
    "artifacts-dir", "matrix", "sites", "electrons", "phonons", "max-phonons",
    "t", "u", "g", "omega", "iters", "tol", "port", "batch", "batch-window-us",
    "requests", "workers", "op", "ops", "dim", "bandwidth", "density",
    "block-size", "chunk-sizes", "threads-per-socket", "output", "scale",
    "eigenvalues", "csv", "policy", "tolerance", "shards", "mode", "backend",
    "cv-threshold", "precision", "factor", "max-batch", "max-delay-us", "tenants",
    "queue-cap", "duration", "exponent", "avg-nnz", "edge-factor", "matrices",
    "rule",
];

impl Args {
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Result<Self> {
        let mut out = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(rest) = tok.strip_prefix("--") {
                if rest.is_empty() {
                    // `--` separator: rest are positionals
                    out.positionals.extend(it);
                    break;
                }
                if let Some((k, v)) = rest.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if VALUE_OPTS.contains(&rest) {
                    let v = it
                        .next()
                        .with_context(|| format!("option --{rest} expects a value"))?;
                    out.opts.insert(rest.to_string(), v);
                } else {
                    out.flags.push(rest.to_string());
                }
            } else {
                out.positionals.push(tok);
            }
        }
        Ok(out)
    }

    pub fn from_env() -> Result<Self> {
        Self::parse(std::env::args().skip(1))
    }

    /// First positional (subcommand), removed from the list.
    pub fn take_subcommand(&mut self) -> Option<String> {
        if self.positionals.is_empty() {
            None
        } else {
            Some(self.positionals.remove(0))
        }
    }

    pub fn positionals(&self) -> &[String] {
        &self.positionals
    }

    pub fn flag(&self, name: &str) -> bool {
        self.consumed.borrow_mut().push(name.to_string());
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.consumed.borrow_mut().push(name.to_string());
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn get_str(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse::<usize>()
                .with_context(|| format!("--{name} expects an unsigned integer, got '{v}'")),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse::<u64>()
                .with_context(|| format!("--{name} expects an unsigned integer, got '{v}'")),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse::<f64>()
                .with_context(|| format!("--{name} expects a number, got '{v}'")),
        }
    }

    /// Comma-separated list of usizes, e.g. `--blocks 16,64,256`.
    pub fn get_usize_list(&self, name: &str, default: &[usize]) -> Result<Vec<usize>> {
        match self.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|s| {
                    s.trim()
                        .parse::<usize>()
                        .with_context(|| format!("--{name}: bad element '{s}'"))
                })
                .collect(),
        }
    }

    /// Comma-separated list of strings.
    pub fn get_str_list(&self, name: &str, default: &[&str]) -> Vec<String> {
        match self.get(name) {
            None => default.iter().map(|s| s.to_string()).collect(),
            Some(v) => v.split(',').map(|s| s.trim().to_string()).collect(),
        }
    }

    /// Error on unknown options that were never consumed (catches typos).
    pub fn finish(&self) -> Result<()> {
        let consumed = self.consumed.borrow();
        for k in self.opts.keys() {
            if !consumed.iter().any(|c| c == k) {
                bail!("unknown option --{k}");
            }
        }
        for f in &self.flags {
            if !consumed.iter().any(|c| c == f) {
                bail!("unknown flag --{f}");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string())).unwrap()
    }

    #[test]
    fn parses_kv_and_flags() {
        let a = parse("experiment fig2 --machine nehalem --full --size=1000");
        let mut a = a;
        assert_eq!(a.take_subcommand().as_deref(), Some("experiment"));
        assert_eq!(a.take_subcommand().as_deref(), Some("fig2"));
        assert_eq!(a.get("machine"), Some("nehalem"));
        assert!(a.flag("full"));
        assert_eq!(a.get_usize("size", 0).unwrap(), 1000);
    }

    #[test]
    fn value_opts_consume_next_token() {
        let a = parse("--threads 8 pos");
        assert_eq!(a.get_usize("threads", 0).unwrap(), 8);
        assert_eq!(a.positionals(), &["pos".to_string()]);
    }

    /// Regression: the facade PR's options must be registered, or the
    /// space-separated form (`--backend sharded`) silently parses as a
    /// boolean flag + stray positional and the caller sees the default.
    #[test]
    fn facade_options_take_values() {
        let a = parse("--backend sharded --cv-threshold 0.8 --matrix m.mtx");
        assert_eq!(a.get_str("backend", "auto"), "sharded");
        assert_eq!(a.get_f64("cv-threshold", 0.0).unwrap(), 0.8);
        assert_eq!(a.get("matrix"), Some("m.mtx"));
        assert!(a.positionals().is_empty(), "no stray positionals");
        assert!(a.finish().is_ok());
    }

    /// Regression: the SIMD PR's options must be registered too —
    /// `--precision tol:1e-12` would otherwise parse as a flag + stray
    /// positional and the tuner would silently stay on BitIdentical.
    #[test]
    fn precision_and_factor_options_take_values() {
        let a = parse("--precision tol:1e-12 --factor 0.7");
        assert_eq!(a.get_str("precision", "bit"), "tol:1e-12");
        assert_eq!(a.get_f64("factor", 0.0).unwrap(), 0.7);
        assert!(a.positionals().is_empty(), "no stray positionals");
        assert!(a.finish().is_ok());
    }

    /// Regression: the serving-layer PR's options must be registered —
    /// `--max-batch 8` would otherwise parse as a flag + stray positional
    /// and the server would silently run with the default batch size.
    #[test]
    fn serve_options_take_values() {
        let a = parse(
            "--max-batch 16 --max-delay-us 500 --tenants 4 --queue-cap 128 --duration 1000",
        );
        assert_eq!(a.get_usize("max-batch", 8).unwrap(), 16);
        assert_eq!(a.get_u64("max-delay-us", 200).unwrap(), 500);
        assert_eq!(a.get_usize("tenants", 2).unwrap(), 4);
        assert_eq!(a.get_usize("queue-cap", 256).unwrap(), 128);
        assert_eq!(a.get_u64("duration", 300).unwrap(), 1000);
        assert!(a.positionals().is_empty(), "no stray positionals");
        assert!(a.finish().is_ok());
    }

    /// Regression: the corpus/generator PR's options must be registered —
    /// `--exponent 2.2` would otherwise parse as a flag + stray positional
    /// and the sweep would silently use the default degree exponent.
    #[test]
    fn corpus_and_generator_options_take_values() {
        let a = parse(
            "--exponent 2.5 --avg-nnz 12 --edge-factor 16 --matrices power-law,rmat --block 8",
        );
        assert_eq!(a.get_f64("exponent", 2.2).unwrap(), 2.5);
        assert_eq!(a.get_usize("avg-nnz", 8).unwrap(), 12);
        assert_eq!(a.get_usize("edge-factor", 8).unwrap(), 16);
        assert_eq!(a.get_str_list("matrices", &[]), vec!["power-law", "rmat"]);
        assert_eq!(a.get_usize("block", 4).unwrap(), 8);
        assert!(a.positionals().is_empty(), "no stray positionals");
        assert!(a.finish().is_ok());
    }

    /// Regression: the SIMD-SpMM PR mixes `--block` (column-block width)
    /// with `--precision` (vector-kernel contract) on the same command
    /// line. Both must stay registered as value options — if either
    /// degrades to a flag, the other's value is swallowed as a stray
    /// positional and the run silently uses defaults.
    #[test]
    fn spmm_block_and_precision_combine() {
        let a = parse("--block 8 --precision tol:1e-12 --backend sharded --policy fixed");
        assert_eq!(a.get_usize("block", 4).unwrap(), 8);
        assert_eq!(a.get_str("precision", "bit"), "tol:1e-12");
        assert_eq!(a.get_str("backend", "auto"), "sharded");
        assert_eq!(a.get_str("policy", "heuristic"), "fixed");
        assert!(a.positionals().is_empty(), "no stray positionals");
        assert!(a.finish().is_ok());
    }

    /// Regression: the audit PR's `--rule` must be registered — the
    /// space-separated form (`spmvperf audit --rule thread_spawn`) would
    /// otherwise parse as a boolean flag + stray positional and the audit
    /// would silently run all rules instead of the requested one.
    #[test]
    fn audit_options_take_values() {
        let a = parse("--rule thread_spawn");
        assert_eq!(a.get("rule"), Some("thread_spawn"));
        assert!(a.positionals().is_empty(), "no stray positionals");
        assert!(a.finish().is_ok());
    }

    #[test]
    fn lists_parse() {
        let a = parse("--blocks 1,2,4");
        assert_eq!(a.get_usize_list("blocks", &[]).unwrap(), vec![1, 2, 4]);
        let b = parse("--schemes crs,jds");
        assert_eq!(b.get_str_list("schemes", &[]), vec!["crs", "jds"]);
    }

    #[test]
    fn defaults_apply() {
        let a = parse("");
        assert_eq!(a.get_usize("size", 42).unwrap(), 42);
        assert_eq!(a.get_str("machine", "woodcrest"), "woodcrest");
        assert!(!a.flag("full"));
    }

    #[test]
    fn finish_rejects_unknown() {
        let a = parse("--machine x --bogus-value=1");
        let _ = a.get("machine");
        assert!(a.finish().is_err());
    }

    #[test]
    fn bad_int_is_error() {
        let a = parse("--size abc");
        assert!(a.get_usize("size", 0).is_err());
    }
}
