//! Small statistics helpers used by the bench harness and the analysis
//! modules (no external stats crates available).

/// Arithmetic mean. Returns 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population variance.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Median (copies + sorts).
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// Median absolute deviation (robust spread estimate).
pub fn mad(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let m = median(xs);
    let devs: Vec<f64> = xs.iter().map(|x| (x - m).abs()).collect();
    median(&devs)
}

/// Minimum (NaN-free input assumed).
pub fn min(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::INFINITY, f64::min)
}

/// Maximum (NaN-free input assumed).
pub fn max(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
}

/// Quantile with linear interpolation, q in [0,1].
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = q.clamp(0.0, 1.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let frac = pos - lo as f64;
        v[lo] * (1.0 - frac) + v[hi] * frac
    }
}

/// Relative difference |a-b| / max(|a|,|b|, eps).
pub fn rel_diff(a: f64, b: f64) -> f64 {
    let denom = a.abs().max(b.abs()).max(1e-300);
    (a - b).abs() / denom
}

/// Assert two f64 slices are element-wise close; returns the max abs diff.
pub fn max_abs_diff(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len(), "length mismatch {} vs {}", a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_median_basic() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert_eq!(median(&xs), 2.5);
        assert_eq!(median(&[1.0, 5.0, 2.0]), 2.0);
    }

    #[test]
    fn variance_and_std() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((variance(&xs) - 4.0).abs() < 1e-12);
        assert!((std_dev(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn mad_robust() {
        let xs = [1.0, 1.0, 2.0, 2.0, 4.0, 6.0, 9.0];
        assert_eq!(mad(&xs), 1.0);
    }

    #[test]
    fn quantiles() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 5.0);
        assert_eq!(quantile(&xs, 0.5), 3.0);
        assert_eq!(quantile(&xs, 0.25), 2.0);
    }

    #[test]
    fn empty_slices_are_safe() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(median(&[]), 0.0);
        assert_eq!(mad(&[]), 0.0);
    }

    #[test]
    fn rel_diff_symmetric() {
        assert!((rel_diff(1.0, 1.1) - rel_diff(1.1, 1.0)).abs() < 1e-15);
        assert_eq!(rel_diff(0.0, 0.0), 0.0);
    }
}
