//! 64-byte-aligned buffer storage for SIMD-fed kernel arrays.
//!
//! SELL-C-σ slice storage is streamed by vector loads
//! ([`crate::kernels::simd`]); starting `val`/`col_idx` on a cache-line
//! (and full AVX-512 vector) boundary keeps the first lane group of
//! every matrix load-aligned and the arrays split cleanly across cache
//! lines. The kernels themselves use unaligned-*tolerant* loads —
//! partial slices and odd lane offsets make per-access alignment
//! impossible to guarantee — so this is a throughput nicety, not a
//! correctness requirement, and [`AlignedVec`] stays a drop-in
//! read-only replacement for `Vec` via `Deref<Target = [T]>`.

use std::alloc::{alloc_zeroed, dealloc, Layout};
use std::ops::{Deref, DerefMut};
use std::ptr::NonNull;

/// Alignment of the buffer start, in bytes (one x86 cache line, one
/// AVX-512 vector).
pub const SIMD_ALIGN: usize = 64;

/// A fixed-length, 64-byte-aligned buffer of plain-old-data elements.
/// Built once from a `Vec` (or slice) and then used as a slice.
pub struct AlignedVec<T: Copy> {
    ptr: NonNull<T>,
    len: usize,
}

// SAFETY: AlignedVec owns its allocation exclusively and T: Copy holds
// no interior mutability or thread affinity — moving or sharing the
// buffer across threads is as safe as for Vec<T>.
unsafe impl<T: Copy + Send> Send for AlignedVec<T> {}
// SAFETY: shared access is read-only through &self (Deref to &[T]).
unsafe impl<T: Copy + Sync> Sync for AlignedVec<T> {}

impl<T: Copy> AlignedVec<T> {
    /// Copy `src` into a fresh 64-byte-aligned allocation.
    pub fn from_slice(src: &[T]) -> Self {
        let len = src.len();
        if len == 0 || std::mem::size_of::<T>() == 0 {
            // A dangling, well-aligned pointer is valid for empty
            // slices (same trick Vec uses).
            return AlignedVec { ptr: NonNull::dangling(), len };
        }
        let layout = Layout::from_size_align(len * std::mem::size_of::<T>(), SIMD_ALIGN)
            .expect("aligned layout");
        // SAFETY: layout has non-zero size (len > 0, size_of::<T>() > 0).
        let raw = unsafe { alloc_zeroed(layout) } as *mut T;
        let ptr = NonNull::new(raw).unwrap_or_else(|| std::alloc::handle_alloc_error(layout));
        // SAFETY: the allocation holds exactly `len` T slots, src and
        // dst cannot overlap (dst is freshly allocated), and T: Copy.
        unsafe { std::ptr::copy_nonoverlapping(src.as_ptr(), ptr.as_ptr(), len) };
        AlignedVec { ptr, len }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

impl<T: Copy> From<Vec<T>> for AlignedVec<T> {
    fn from(v: Vec<T>) -> Self {
        AlignedVec::from_slice(&v)
    }
}

impl<T: Copy> Drop for AlignedVec<T> {
    fn drop(&mut self) {
        if self.len > 0 && std::mem::size_of::<T>() > 0 {
            let layout =
                Layout::from_size_align(self.len * std::mem::size_of::<T>(), SIMD_ALIGN)
                    .expect("aligned layout");
            // SAFETY: ptr was returned by alloc_zeroed with this exact
            // layout in from_slice, and is freed exactly once.
            unsafe { dealloc(self.ptr.as_ptr() as *mut u8, layout) };
        }
    }
}

impl<T: Copy> Deref for AlignedVec<T> {
    type Target = [T];
    fn deref(&self) -> &[T] {
        // SAFETY: ptr is valid for `len` initialized T (copied in
        // from_slice; dangling only when len == 0, where the empty
        // slice constructor accepts any well-aligned pointer).
        unsafe { std::slice::from_raw_parts(self.ptr.as_ptr(), self.len) }
    }
}

impl<T: Copy> DerefMut for AlignedVec<T> {
    fn deref_mut(&mut self) -> &mut [T] {
        // SAFETY: as in Deref, plus &mut self guarantees exclusivity.
        unsafe { std::slice::from_raw_parts_mut(self.ptr.as_ptr(), self.len) }
    }
}

impl<T: Copy> Clone for AlignedVec<T> {
    fn clone(&self) -> Self {
        AlignedVec::from_slice(self)
    }
}

impl<T: Copy + std::fmt::Debug> std::fmt::Debug for AlignedVec<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        self.deref().fmt(f)
    }
}

impl<T: Copy + PartialEq> PartialEq for AlignedVec<T> {
    fn eq(&self, other: &Self) -> bool {
        self.deref() == other.deref()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buffer_start_is_64_byte_aligned() {
        for n in [1usize, 3, 64, 1000] {
            let v: Vec<f64> = (0..n).map(|i| i as f64).collect();
            let a = AlignedVec::from_slice(&v);
            assert_eq!(a.as_ptr() as usize % SIMD_ALIGN, 0, "n={n}");
            assert_eq!(&a[..], &v[..]);
        }
        let u: Vec<u32> = (0..97).collect();
        let a: AlignedVec<u32> = u.clone().into();
        assert_eq!(a.as_ptr() as usize % SIMD_ALIGN, 0);
        assert_eq!(&a[..], &u[..]);
    }

    #[test]
    fn empty_clone_and_eq() {
        let e = AlignedVec::<f64>::from_slice(&[]);
        assert!(e.is_empty());
        assert_eq!(e.len(), 0);
        assert_eq!(&e[..], &[] as &[f64]);
        let a = AlignedVec::from_slice(&[1.0, 2.0, 3.0]);
        let b = a.clone();
        assert_eq!(a, b);
        assert_ne!(a, AlignedVec::from_slice(&[1.0, 2.0]));
        assert_eq!(format!("{a:?}"), "[1.0, 2.0, 3.0]");
    }

    #[test]
    fn deref_mut_writes_stick() {
        let mut a = AlignedVec::from_slice(&[0u32; 8]);
        a[3] = 7;
        assert_eq!(a[3], 7);
        assert_eq!(a.iter().sum::<u32>(), 7);
    }
}
