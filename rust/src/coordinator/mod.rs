//! L3 coordinator: an SpMV service with request routing and dynamic
//! batching, in the style of an inference router. Requests (input
//! vectors) arrive on a queue; a worker thread coalesces them into
//! batches (up to the artifact's batch size, within a latency window)
//! and dispatches them to an executor — either the PJRT-compiled
//! JAX/Pallas artifact or the backend-agnostic [`Executor`] over a
//! tuned [`crate::spmv::SpmvHandle`], which serves each coalesced batch
//! in one fused dispatch on whatever backend (serial, native engine,
//! sharded) arbitration bound. Python is never on this path.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::spmv::SpmvHandle;

/// Batch executor abstraction: the service is agnostic of what actually
/// multiplies. Executors are constructed *inside* the worker thread (a
/// PJRT client is not `Send`).
///
/// The working basis is executor-defined and part of each executor's
/// contract: [`Executor`] serves the **original** basis (the handle
/// gathers/scatters internally), while [`PjrtExecutor`] serves the ELL
/// **permuted** basis of its artifact. A deployment must pick one
/// executor per service and submit vectors in that executor's basis.
pub trait BatchExecutor {
    fn dim(&self) -> usize;
    fn max_batch(&self) -> usize;
    /// Multiply each input vector (in the executor's working basis).
    fn run_batch(&self, xs: &[Vec<f64>]) -> Result<Vec<Vec<f64>>>;
}

/// The one native-side executor: **backend-generic** over a tuned
/// [`SpmvHandle`] — any storage scheme, schedule, thread count and
/// executor backend the tuning/arbitration layers can produce is
/// servable, and no call site names a concrete backend. Whole batches
/// run as a single fused dispatch ([`SpmvHandle::spmv_batch`]): one
/// engine completion latch (native) or one coordinator spawn across all
/// shards (sharded) per batch, not per vector.
pub struct Executor {
    handle: SpmvHandle,
    pub max_batch: usize,
}

impl Executor {
    /// Wrap any tuned handle as a batch executor. NUMA deployments build
    /// the handle with `.pinned(true)` *inside* the service's
    /// `make_executor` closure: it runs on the worker thread, so pinned
    /// engines and first-touched buffers belong to the thread that will
    /// serve every batch.
    pub fn from_handle(handle: SpmvHandle, max_batch: usize) -> Self {
        Executor { handle, max_batch: max_batch.max(1) }
    }

    /// The tuned handle serving this executor.
    pub fn handle(&self) -> &SpmvHandle {
        &self.handle
    }
}

impl BatchExecutor for Executor {
    fn dim(&self) -> usize {
        crate::matrix::SpMv::nrows(&self.handle)
    }
    fn max_batch(&self) -> usize {
        self.max_batch
    }
    fn run_batch(&self, xs: &[Vec<f64>]) -> Result<Vec<Vec<f64>>> {
        Ok(self.handle.spmv_batch(xs))
    }
}

/// PJRT executor over a batched artifact.
pub struct PjrtExecutor {
    pub bound: crate::runtime::BoundSpmv,
}

impl BatchExecutor for PjrtExecutor {
    fn dim(&self) -> usize {
        self.bound.n
    }
    fn max_batch(&self) -> usize {
        self.bound.meta.batch.unwrap_or(1)
    }
    fn run_batch(&self, xs: &[Vec<f64>]) -> Result<Vec<Vec<f64>>> {
        self.bound.spmv_batched(xs)
    }
}

/// Service metrics (lock-free counters).
#[derive(Debug, Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub batches: AtomicU64,
    pub errors: AtomicU64,
    /// Sum of end-to-end request latencies, microseconds.
    pub latency_us_sum: AtomicU64,
    pub latency_us_max: AtomicU64,
}

impl Metrics {
    pub fn avg_batch(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            0.0
        } else {
            self.requests.load(Ordering::Relaxed) as f64 / b as f64
        }
    }

    pub fn avg_latency_us(&self) -> f64 {
        let r = self.requests.load(Ordering::Relaxed);
        if r == 0 {
            0.0
        } else {
            self.latency_us_sum.load(Ordering::Relaxed) as f64 / r as f64
        }
    }

    fn record_latency(&self, us: u64) {
        self.latency_us_sum.fetch_add(us, Ordering::Relaxed);
        self.latency_us_max.fetch_max(us, Ordering::Relaxed);
    }
}

struct Request {
    x: Vec<f64>,
    enqueued: Instant,
    reply: mpsc::Sender<Result<Vec<f64>, String>>,
}

/// Service configuration.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// Max time the batcher waits for more requests once one is pending.
    pub batch_window: Duration,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        Self { batch_window: Duration::from_micros(500) }
    }
}

/// A running SpMV service (one matrix, one worker thread).
pub struct Service {
    tx: Option<mpsc::Sender<Request>>,
    worker: Option<std::thread::JoinHandle<()>>,
    pub metrics: Arc<Metrics>,
    pub dim: usize,
}

impl Service {
    /// Start a service. `make_executor` runs on the worker thread (PJRT
    /// handles are not `Send`); its `dim` must equal `dim`.
    pub fn start<F>(cfg: ServiceConfig, dim: usize, make_executor: F) -> Result<Self>
    where
        F: FnOnce() -> Result<Box<dyn BatchExecutor>> + Send + 'static,
    {
        let (tx, rx) = mpsc::channel::<Request>();
        let metrics = Arc::new(Metrics::default());
        let m2 = metrics.clone();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<(), String>>();
        // audit:allow(thread_spawn): one worker per Service, spawned once at start (executor is !Send)
        let worker = std::thread::Builder::new()
            .name("spmv-service".into())
            .spawn(move || {
                let exec = match make_executor() {
                    Ok(e) => {
                        let _ = ready_tx.send(Ok(()));
                        e
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(format!("{e:#}")));
                        return;
                    }
                };
                worker_loop(rx, exec, cfg, m2);
            })
            .context("spawning service worker")?;
        ready_rx
            .recv()
            .context("service worker died during startup")?
            .map_err(|e| anyhow::anyhow!("executor init failed: {e}"))?;
        Ok(Service { tx: Some(tx), worker: Some(worker), metrics, dim })
    }

    /// Submit a request; returns a receiver for the result.
    pub fn submit(&self, x: Vec<f64>) -> Result<mpsc::Receiver<Result<Vec<f64>, String>>> {
        anyhow::ensure!(x.len() == self.dim, "input length {} != {}", x.len(), self.dim);
        let (reply, rx) = mpsc::channel();
        self.tx
            .as_ref()
            .context("service stopped")?
            .send(Request { x, enqueued: Instant::now(), reply })
            .map_err(|_| anyhow::anyhow!("service worker gone"))?;
        Ok(rx)
    }

    /// Submit and block for the result.
    pub fn submit_wait(&self, x: Vec<f64>) -> Result<Vec<f64>> {
        let rx = self.submit(x)?;
        rx.recv()
            .context("service dropped the request")?
            .map_err(|e| anyhow::anyhow!(e))
    }
}

impl Drop for Service {
    fn drop(&mut self) {
        drop(self.tx.take()); // close queue; worker drains and exits
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

fn worker_loop(
    rx: mpsc::Receiver<Request>,
    exec: Box<dyn BatchExecutor>,
    cfg: ServiceConfig,
    metrics: Arc<Metrics>,
) {
    let max_batch = exec.max_batch().max(1);
    loop {
        // Block for the first request of the batch.
        let first = match rx.recv() {
            Ok(r) => r,
            Err(_) => return, // queue closed
        };
        let mut batch = vec![first];
        // Coalesce: take whatever arrives within the window, up to the
        // executor's batch capacity.
        let deadline = Instant::now() + cfg.batch_window;
        while batch.len() < max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(r) => batch.push(r),
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => break,
            }
        }
        let xs: Vec<Vec<f64>> = batch.iter().map(|r| r.x.clone()).collect();
        let result = exec.run_batch(&xs);
        metrics.batches.fetch_add(1, Ordering::Relaxed);
        match result {
            Ok(ys) => {
                for (req, y) in batch.into_iter().zip(ys) {
                    metrics.requests.fetch_add(1, Ordering::Relaxed);
                    metrics.record_latency(req.enqueued.elapsed().as_micros() as u64);
                    let _ = req.reply.send(Ok(y));
                }
            }
            Err(e) => {
                let msg = format!("{e:#}");
                for req in batch {
                    metrics.errors.fetch_add(1, Ordering::Relaxed);
                    let _ = req.reply.send(Err(msg.clone()));
                }
            }
        }
    }
}

/// Router over several named services (one per matrix / artifact).
#[derive(Default)]
pub struct Coordinator {
    services: HashMap<String, Service>,
}

impl Coordinator {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn register(&mut self, name: &str, service: Service) {
        self.services.insert(name.to_string(), service);
    }

    pub fn route(&self, name: &str) -> Result<&Service> {
        self.services
            .get(name)
            .with_context(|| format!("no service '{name}' registered"))
    }

    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.services.keys().map(|s| s.as_str()).collect();
        v.sort();
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen;
    use crate::matrix::{Crs, Scheme, SpMv};
    use crate::sched::Schedule;
    use crate::shard::OverlapMode;
    use crate::spmv::BackendChoice;
    use crate::tune::{ShardPolicy, TuningPolicy};

    fn tiny_crs() -> Crs {
        let h = gen::holstein_hubbard(&gen::HolsteinHubbardParams::tiny());
        Crs::from_coo(&h)
    }

    /// A CRS fixed-policy handle service. Original-basis contract.
    fn start_native(max_batch: usize, window: Duration) -> (Service, Crs) {
        let crs = tiny_crs();
        let dim = crs.nrows;
        let crs2 = crs.clone();
        let svc = Service::start(ServiceConfig { batch_window: window }, dim, move || {
            let handle = SpmvHandle::builder_from_crs(&crs2)
                .policy(TuningPolicy::Fixed(Scheme::Crs, Schedule::Static { chunk: None }))
                .backend(BackendChoice::Native)
                .threads(1)
                .build()?;
            Ok(Box::new(Executor::from_handle(handle, max_batch)) as Box<dyn BatchExecutor>)
        })
        .unwrap();
        (svc, crs)
    }

    /// ISSUE-5: one executor serves every backend — whole batches run in
    /// one dispatch, bit-identical to the serial CRS kernel, whether the
    /// handle is serial, native or sharded (× overlap modes).
    #[test]
    fn executor_serves_batches_on_every_backend() {
        let crs = tiny_crs();
        let n = crs.nrows;
        let mut rng = crate::util::rng::Rng::new(14);
        let xs: Vec<Vec<f64>> = (0..6)
            .map(|_| {
                let mut x = vec![0.0; n];
                rng.fill_f64(&mut x, -1.0, 1.0);
                x
            })
            .collect();
        let mut cases: Vec<(BackendChoice, Option<ShardPolicy>)> = vec![
            (BackendChoice::Serial, None),
            (BackendChoice::Native, None),
        ];
        for mode in [OverlapMode::BulkSync, OverlapMode::Overlapped] {
            cases.push((
                BackendChoice::Sharded,
                Some(ShardPolicy::Fixed { shards: 3, mode }),
            ));
        }
        for (backend, shard_policy) in cases {
            let mut b = SpmvHandle::builder_from_crs(&crs)
                .policy(TuningPolicy::Fixed(Scheme::Crs, Schedule::Static { chunk: None }))
                .backend(backend)
                .threads(2);
            if let Some(sp) = shard_policy {
                b = b.shard_policy(sp);
            }
            let handle = b.build().unwrap();
            let exec = Executor::from_handle(handle, 8);
            assert_eq!(exec.dim(), n);
            assert_eq!(exec.handle().backend_name(), backend.name());
            let got = exec.run_batch(&xs).unwrap();
            let mut want = vec![0.0; n];
            for (x, y) in xs.iter().zip(&got) {
                crs.spmv(x, &mut want);
                assert_eq!(
                    crate::util::stats::max_abs_diff(y, &want),
                    0.0,
                    "{}: executor deviates from serial CRS",
                    backend.name()
                );
            }
        }
    }

    #[test]
    fn service_over_sharded_handle() {
        let crs = tiny_crs();
        let n = crs.nrows;
        let crs2 = crs.clone();
        let svc = Service::start(
            ServiceConfig { batch_window: Duration::from_micros(100) },
            n,
            move || {
                // Built on the worker thread, like every NUMA-placed
                // executor: shard engines and first-touched buffers
                // belong to the serving side.
                let handle = SpmvHandle::builder_from_crs(&crs2)
                    .policy(TuningPolicy::Fixed(Scheme::Crs, Schedule::Static { chunk: None }))
                    .backend(BackendChoice::Sharded)
                    .shard_policy(ShardPolicy::Fixed {
                        shards: 2,
                        mode: OverlapMode::Overlapped,
                    })
                    .threads(2)
                    .build()?;
                Ok(Box::new(Executor::from_handle(handle, 8)) as Box<dyn BatchExecutor>)
            },
        )
        .unwrap();
        let mut rng = crate::util::rng::Rng::new(15);
        let mut want = vec![0.0; n];
        for _ in 0..4 {
            let mut x = vec![0.0; n];
            rng.fill_f64(&mut x, -1.0, 1.0);
            let y = svc.submit_wait(x.clone()).unwrap();
            crs.spmv(&x, &mut want);
            assert_eq!(
                crate::util::stats::max_abs_diff(&y, &want),
                0.0,
                "sharded service deviates from serial CRS"
            );
        }
    }

    #[test]
    fn executor_serves_any_scheme() {
        // The service layer is scheme-generic: a SELL-C-σ tuned handle
        // (original basis) is just as servable, and its batched path is
        // bit-identical to per-vector execution.
        let h = gen::holstein_hubbard(&gen::HolsteinHubbardParams::tiny());
        let crs = Crs::from_coo(&h);
        let n = crs.nrows;
        let handle = SpmvHandle::builder(&h)
            .policy(TuningPolicy::Fixed(
                Scheme::SellCs { c: 32, sigma: 256 },
                Schedule::Static { chunk: None },
            ))
            .backend(BackendChoice::Native)
            .threads(4)
            .build()
            .unwrap();
        let exec = Executor::from_handle(handle, 8);
        assert_eq!(exec.dim(), n);
        let mut rng = crate::util::rng::Rng::new(11);
        let xs: Vec<Vec<f64>> = (0..5)
            .map(|_| {
                let mut x = vec![0.0; n];
                rng.fill_f64(&mut x, -1.0, 1.0);
                x
            })
            .collect();
        let got = exec.run_batch(&xs).unwrap();
        let mut want = vec![0.0; n];
        for (x, y) in xs.iter().zip(&got) {
            crs.spmv(x, &mut want);
            assert!(
                crate::util::stats::max_abs_diff(y, &want) < 1e-12,
                "SELL-backed executor deviates from CRS reference"
            );
        }
    }

    #[test]
    fn service_over_auto_arbitrated_handle() {
        // The service no longer names a backend at all: arbitration
        // binds one on the worker thread, and the decision is recorded.
        let h = gen::holstein_hubbard(&gen::HolsteinHubbardParams::tiny());
        let crs = Crs::from_coo(&h);
        let n = crs.nrows;
        let svc = Service::start(
            ServiceConfig { batch_window: Duration::from_micros(100) },
            n,
            move || {
                let handle = SpmvHandle::builder_from_crs(&crs)
                    .policy(TuningPolicy::Heuristic)
                    .threads(2)
                    .quick(true)
                    .build()?;
                assert!(handle.backend_decision().is_some());
                Ok(Box::new(Executor::from_handle(handle, 8)) as Box<dyn BatchExecutor>)
            },
        )
        .unwrap();
        let crs2 = Crs::from_coo(&h);
        let mut rng = crate::util::rng::Rng::new(12);
        let mut want = vec![0.0; n];
        for _ in 0..4 {
            let mut x = vec![0.0; n];
            rng.fill_f64(&mut x, -1.0, 1.0);
            let y = svc.submit_wait(x.clone()).unwrap();
            crs2.spmv(&x, &mut want);
            assert!(crate::util::stats::max_abs_diff(&y, &want) < 1e-12);
        }
    }

    #[test]
    fn service_over_pinned_handle() {
        // NUMA-placed serving: the executor is built inside the worker
        // thread with a pinned engine + first-touched plan, and results
        // stay exact (on non-Linux the pin is a recorded no-op).
        let h = gen::holstein_hubbard(&gen::HolsteinHubbardParams::tiny());
        let crs = Crs::from_coo(&h);
        let n = crs.nrows;
        let svc = Service::start(
            ServiceConfig { batch_window: Duration::from_micros(100) },
            n,
            move || {
                let handle = SpmvHandle::builder_from_crs(&crs)
                    .policy(TuningPolicy::Fixed(Scheme::Crs, Schedule::Static { chunk: None }))
                    .backend(BackendChoice::Native)
                    .threads(2)
                    .pinned(true)
                    .build()?;
                assert!(handle.plan().expect("native backend has a plan").first_touched());
                Ok(Box::new(Executor::from_handle(handle, 8)) as Box<dyn BatchExecutor>)
            },
        )
        .unwrap();
        let crs2 = Crs::from_coo(&h);
        let mut rng = crate::util::rng::Rng::new(13);
        let mut want = vec![0.0; n];
        for _ in 0..3 {
            let mut x = vec![0.0; n];
            rng.fill_f64(&mut x, -1.0, 1.0);
            let y = svc.submit_wait(x.clone()).unwrap();
            crs2.spmv(&x, &mut want);
            assert_eq!(
                crate::util::stats::max_abs_diff(&y, &want),
                0.0,
                "pinned service deviates from serial CRS"
            );
        }
    }

    #[test]
    fn single_request_roundtrip() {
        let (svc, crs) = start_native(8, Duration::from_micros(100));
        let mut rng = crate::util::rng::Rng::new(1);
        let mut x = vec![0.0; crs.nrows];
        rng.fill_f64(&mut x, -1.0, 1.0);
        let y = svc.submit_wait(x.clone()).unwrap();
        let mut want = vec![0.0; crs.nrows];
        crs.spmv(&x, &mut want);
        assert_eq!(crate::util::stats::max_abs_diff(&y, &want), 0.0);
        assert_eq!(svc.metrics.requests.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn concurrent_requests_get_batched() {
        let (svc, crs) = start_native(16, Duration::from_millis(20));
        let n = crs.nrows;
        let svc = Arc::new(svc);
        let mut rng = crate::util::rng::Rng::new(2);
        let xs: Vec<Vec<f64>> = (0..32)
            .map(|_| {
                let mut x = vec![0.0; n];
                rng.fill_f64(&mut x, -1.0, 1.0);
                x
            })
            .collect();
        // Fire all requests from threads, then collect.
        let rxs: Vec<_> = xs.iter().map(|x| svc.submit(x.clone()).unwrap()).collect();
        let mut want = vec![0.0; n];
        for (x, rx) in xs.iter().zip(rxs) {
            let y = rx.recv().unwrap().unwrap();
            crs.spmv(x, &mut want);
            assert_eq!(crate::util::stats::max_abs_diff(&y, &want), 0.0);
        }
        assert_eq!(svc.metrics.requests.load(Ordering::Relaxed), 32);
        // 32 requests in << 20ms window with capacity 16: far fewer than
        // 32 batches.
        let batches = svc.metrics.batches.load(Ordering::Relaxed);
        assert!(batches <= 16, "expected batching, got {batches} batches");
        assert!(svc.metrics.avg_batch() >= 2.0);
    }

    #[test]
    fn wrong_length_rejected() {
        let (svc, _) = start_native(4, Duration::from_micros(10));
        assert!(svc.submit(vec![0.0; 3]).is_err());
    }

    #[test]
    fn coordinator_routes_by_name() {
        let (a, _) = start_native(4, Duration::from_micros(10));
        let (b, _) = start_native(4, Duration::from_micros(10));
        let mut c = Coordinator::new();
        c.register("hh-tiny", a);
        c.register("hh-tiny-2", b);
        assert_eq!(c.names(), vec!["hh-tiny", "hh-tiny-2"]);
        assert!(c.route("hh-tiny").is_ok());
        assert!(c.route("missing").is_err());
    }

    #[test]
    fn executor_init_failure_is_reported() {
        let r = Service::start(ServiceConfig::default(), 8, || {
            anyhow::bail!("boom")
        });
        assert!(r.is_err());
        assert!(format!("{:#}", r.err().unwrap()).contains("boom"));
    }

    #[test]
    fn shutdown_joins_worker() {
        let (svc, crs) = start_native(4, Duration::from_micros(10));
        let x = vec![1.0; crs.nrows];
        let _ = svc.submit_wait(x).unwrap();
        drop(svc); // must not hang
    }
}
