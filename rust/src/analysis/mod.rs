//! Sparsity-pattern analysis: the diagonal occupation profile of Fig 5
//! (bottom) and the input-vector stride distributions of Fig 6a that feed
//! the predictive performance model.

pub mod diag_profile;
pub mod stride_dist;

pub use diag_profile::{diag_profile, DiagProfile};
pub use stride_dist::StrideDistribution;
