//! Input-vector stride distributions (Fig 6a): the successive differences
//! of `invec` access indices during an SpMV kernel walk, split into
//! forward and backward jumps. This is the matrix "fingerprint" the
//! paper's performance model consumes.

use std::collections::BTreeMap;

use crate::kernels::SpmvKernel;
use crate::matrix::jds::SpmvVisitor;

/// Histogram of signed strides (in elements) between successive input
/// vector accesses.
#[derive(Debug, Clone, Default)]
pub struct StrideDistribution {
    /// stride (elements, signed; 0 = revisit) -> count
    pub counts: BTreeMap<i64, u64>,
    pub total: u64,
}

struct StrideVisitor {
    prev: Option<usize>,
    dist: StrideDistribution,
}

impl SpmvVisitor for StrideVisitor {
    #[inline]
    fn update(&mut self, _row: usize, _j: usize, col: usize) {
        if let Some(p) = self.prev {
            let d = col as i64 - p as i64;
            *self.dist.counts.entry(d).or_insert(0) += 1;
            self.dist.total += 1;
        }
        self.prev = Some(col);
    }
}

impl StrideDistribution {
    /// Collect the stride distribution of a kernel's access order.
    pub fn from_kernel(kernel: &SpmvKernel) -> Self {
        let mut v = StrideVisitor { prev: None, dist: StrideDistribution::default() };
        kernel.walk(&mut v);
        v.dist
    }

    /// Accumulated weight of backward jumps (negative strides) — ~7% for
    /// CRS on the paper's Hamiltonian, roughly tripled for plain JDS.
    pub fn backward_fraction(&self) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let back: u64 = self
            .counts
            .iter()
            .filter(|&(&s, _)| s < 0)
            .map(|(_, &c)| c)
            .sum();
        back as f64 / self.total as f64
    }

    /// Fraction of strides with |stride| <= `limit` elements. The paper
    /// quotes "almost 60% of the strides are smaller than 64 bytes" for
    /// JDS, i.e. |stride| < 8 elements of 8 bytes.
    pub fn fraction_within(&self, limit: i64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let within: u64 = self
            .counts
            .iter()
            .filter(|&(&s, _)| s.abs() <= limit)
            .map(|(_, &c)| c)
            .sum();
        within as f64 / self.total as f64
    }

    /// Mean of |stride|.
    pub fn mean_abs_stride(&self) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let sum: i64 = self
            .counts
            .iter()
            .map(|(&s, &c)| s.abs() * c as i64)
            .sum();
        sum as f64 / self.total as f64
    }

    /// Distribution function (CDF) over |stride| for forward (positive)
    /// or backward (negative) jumps separately, as (stride, cumulative
    /// fraction of total) points — the solid/dashed curves of Fig 6a.
    pub fn cdf(&self, forward: bool) -> Vec<(i64, f64)> {
        let mut pts = Vec::new();
        let mut acc = 0u64;
        let entries: Vec<(i64, u64)> = self
            .counts
            .iter()
            .filter(|&(&s, _)| if forward { s > 0 } else { s < 0 })
            .map(|(&s, &c)| (s.abs(), c))
            .collect();
        let mut sorted = entries;
        sorted.sort_by_key(|&(s, _)| s);
        for (s, c) in sorted {
            acc += c;
            pts.push((s, acc as f64 / self.total.max(1) as f64));
        }
        pts
    }

    /// Weighted histogram over |stride| buckets (powers of two), useful
    /// for compact reporting.
    pub fn bucketed(&self) -> Vec<(String, f64)> {
        let mut buckets: Vec<(i64, u64)> = Vec::new(); // (upper bound, count)
        let bounds = [1i64, 2, 4, 8, 16, 32, 64, 128, 256, 1024, 4096, i64::MAX];
        for &b in &bounds {
            buckets.push((b, 0));
        }
        for (&s, &c) in &self.counts {
            let a = s.abs();
            for bucket in buckets.iter_mut() {
                if a <= bucket.0 {
                    bucket.1 += c;
                    break;
                }
            }
        }
        buckets
            .into_iter()
            .map(|(b, c)| {
                let label = if b == i64::MAX { ">4096".to_string() } else { format!("<={b}") };
                (label, c as f64 / self.total.max(1) as f64)
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen;
    use crate::matrix::Scheme;
    use crate::util::rng::Rng;

    #[test]
    fn crs_stride_dist_of_tridiagonal() {
        // Tridiagonal CRS: within a row strides are +1; row changes jump
        // back by 1 (from col i+1 to col i) — mostly small strides.
        let m = gen::laplacian_1d(500);
        let k = SpmvKernel::build(&m, Scheme::Crs);
        let d = StrideDistribution::from_kernel(&k);
        assert!(d.fraction_within(2) > 0.99);
        assert!(d.backward_fraction() > 0.2); // one back-jump per row
    }

    #[test]
    fn crs_backward_fraction_is_one_per_row() {
        // For a banded random matrix, CRS jumps backward once per row
        // (start of a new row), so backward fraction ~ nrows / nnz.
        let mut rng = Rng::new(40);
        let m = gen::random_band(400, 10, 60, &mut rng);
        let k = SpmvKernel::build(&m, Scheme::Crs);
        let d = StrideDistribution::from_kernel(&k);
        let expect = m.nrows as f64 / m.nnz() as f64;
        let got = d.backward_fraction();
        assert!(
            (got - expect).abs() < 0.3 * expect,
            "backward {got} vs expected ~{expect}"
        );
    }

    #[test]
    fn jds_has_more_backward_jumps_than_crs() {
        // The paper: JDS roughly triples the backward weight vs CRS on
        // the Hamiltonian.
        let params = gen::HolsteinHubbardParams::tiny();
        let h = gen::holstein_hubbard(&params);
        let crs = SpmvKernel::build(&h, Scheme::Crs);
        let jds = SpmvKernel::build(&h, Scheme::Jds);
        let d_crs = StrideDistribution::from_kernel(&crs);
        let d_jds = StrideDistribution::from_kernel(&jds);
        assert!(
            d_jds.backward_fraction() > 1.5 * d_crs.backward_fraction(),
            "JDS backward {:.3} vs CRS {:.3}",
            d_jds.backward_fraction(),
            d_crs.backward_fraction()
        );
    }

    #[test]
    fn cdf_is_monotone_and_bounded() {
        let params = gen::HolsteinHubbardParams::tiny();
        let h = gen::holstein_hubbard(&params);
        let k = SpmvKernel::build(&h, Scheme::NbJds { block: 64 });
        let d = StrideDistribution::from_kernel(&k);
        for fwd in [true, false] {
            let cdf = d.cdf(fwd);
            assert!(cdf.windows(2).all(|w| w[0].1 <= w[1].1));
            if let Some(&(_, last)) = cdf.last() {
                assert!(last <= 1.0 + 1e-12);
            }
        }
        let f = d.cdf(true).last().map(|x| x.1).unwrap_or(0.0);
        let b = d.cdf(false).last().map(|x| x.1).unwrap_or(0.0);
        let z = d.fraction_within(0);
        assert!((f + b + z - 1.0).abs() < 1e-9, "f{f}+b{b}+z{z} != 1");
    }

    #[test]
    fn bucketed_sums_to_one() {
        let mut rng = Rng::new(41);
        let m = gen::random_square(300, 2500, &mut rng);
        let k = SpmvKernel::build(&m, Scheme::Jds);
        let d = StrideDistribution::from_kernel(&k);
        let total: f64 = d.bucketed().iter().map(|(_, f)| f).sum();
        assert!((total - 1.0).abs() < 1e-9);
    }
}
