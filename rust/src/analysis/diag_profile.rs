//! Diagonal occupation profile (Fig 5, bottom panel): the number of
//! non-zero elements as a function of their distance to the main
//! diagonal, plus the derived statistics the paper quotes (e.g. "about
//! 60% of the non-zero elements are contained in the twelve outermost
//! secondary diagonals").

use std::collections::BTreeMap;

use crate::matrix::Coo;

/// Occupation statistics of the (sub)diagonals of a symmetric matrix.
#[derive(Debug, Clone)]
pub struct DiagProfile {
    /// nnz per |col - row| offset (0 = main diagonal). For symmetric
    /// matrices, upper and lower contributions are merged.
    pub counts: BTreeMap<u64, u64>,
    /// Total (possible) elements per offset: `n - offset` for the upper
    /// triangle — the paper's dashed "total elements" line.
    pub capacity: BTreeMap<u64, u64>,
    pub nnz_total: u64,
    pub n: u64,
}

impl DiagProfile {
    /// Occupation fraction of an offset (0..=1).
    pub fn occupation(&self, offset: u64) -> f64 {
        let cnt = self.counts.get(&offset).copied().unwrap_or(0);
        let cap = self.capacity.get(&offset).copied().unwrap_or(0);
        if cap == 0 {
            0.0
        } else {
            cnt as f64 / cap as f64
        }
    }

    /// Offsets sorted by descending nnz count.
    pub fn densest_offsets(&self) -> Vec<(u64, u64)> {
        let mut v: Vec<(u64, u64)> = self.counts.iter().map(|(&o, &c)| (o, c)).collect();
        v.sort_by_key(|&(o, c)| (std::cmp::Reverse(c), o));
        v
    }

    /// Fraction of nnz contained in the `k` most populated non-main
    /// (secondary) diagonals — the paper's "60% in twelve diagonals".
    pub fn fraction_in_top_secondary(&self, k: usize) -> f64 {
        let top: u64 = self
            .densest_offsets()
            .into_iter()
            .filter(|&(o, _)| o != 0)
            .take(k)
            .map(|(_, c)| c)
            .sum();
        if self.nnz_total == 0 {
            0.0
        } else {
            top as f64 / self.nnz_total as f64
        }
    }

    /// Cumulative nnz fraction for offsets >= the given offset ("outer"
    /// part of the band).
    pub fn fraction_beyond(&self, offset: u64) -> f64 {
        let outer: u64 = self
            .counts
            .iter()
            .filter(|&(&o, _)| o >= offset)
            .map(|(_, &c)| c)
            .sum();
        if self.nnz_total == 0 {
            0.0
        } else {
            outer as f64 / self.nnz_total as f64
        }
    }

    /// Matrix bandwidth (largest occupied offset).
    pub fn bandwidth(&self) -> u64 {
        self.counts.keys().next_back().copied().unwrap_or(0)
    }
}

/// Compute the diagonal profile of a matrix. Entries from both triangles
/// are merged into their |col - row| offset (the paper shows only the
/// upper subdiagonals of the symmetric Hamiltonian). The main diagonal is
/// counted once per stored entry.
pub fn diag_profile(coo: &Coo) -> DiagProfile {
    let mut counts: BTreeMap<u64, u64> = BTreeMap::new();
    for &(r, c, _) in &coo.entries {
        let off = (c as i64 - r as i64).unsigned_abs();
        *counts.entry(off).or_insert(0) += 1;
    }
    // Symmetric merge: off-diagonal offsets were counted from both
    // triangles; halve to describe the upper triangle like the paper.
    for (&off, cnt) in counts.iter_mut() {
        if off != 0 {
            *cnt = (*cnt).div_ceil(2);
        }
    }
    let n = coo.nrows as u64;
    let capacity: BTreeMap<u64, u64> = counts
        .keys()
        .map(|&o| (o, n.saturating_sub(o)))
        .collect();
    let nnz_total: u64 = counts.values().sum();
    DiagProfile { counts, capacity, nnz_total, n }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen;
    use crate::util::rng::Rng;

    #[test]
    fn tridiagonal_profile() {
        let m = gen::laplacian_1d(100);
        let p = diag_profile(&m);
        assert_eq!(p.counts.get(&0).copied(), Some(100));
        assert_eq!(p.counts.get(&1).copied(), Some(99));
        assert_eq!(p.bandwidth(), 1);
        assert_eq!(p.occupation(1), 1.0);
        assert!(p.fraction_in_top_secondary(1) > 0.0);
    }

    #[test]
    fn laplacian_2d_has_two_secondary_diagonals() {
        let m = gen::laplacian_2d(10, 10);
        let p = diag_profile(&m);
        // offsets 1 and 10 (within-row and across-row neighbours)
        assert!(p.counts.contains_key(&1));
        assert!(p.counts.contains_key(&10));
        assert_eq!(p.bandwidth(), 10);
        // all nnz in 2 secondary diagonals + main
        assert!((p.fraction_in_top_secondary(2) + p.occupation(0) * 100.0 / p.nnz_total as f64
            - 1.0)
            .abs()
            < 1e-9);
    }

    #[test]
    fn holstein_hubbard_split_structure() {
        // The HH matrix must show the paper's split structure: a few
        // dense secondary diagonals holding a large nnz share.
        let params = gen::HolsteinHubbardParams::tiny();
        let h = gen::holstein_hubbard(&params);
        let p = diag_profile(&h);
        let frac12 = p.fraction_in_top_secondary(12);
        assert!(
            frac12 > 0.35,
            "top-12 secondary diagonals hold only {frac12:.2} of nnz"
        );
        // band is much narrower than the dimension
        assert!(p.bandwidth() < h.nrows as u64);
    }

    #[test]
    fn random_matrix_has_flat_profile() {
        let mut rng = Rng::new(3);
        let m = gen::random_square(200, 3000, &mut rng);
        let p = diag_profile(&m);
        // no single secondary diagonal dominates
        assert!(p.fraction_in_top_secondary(1) < 0.05);
    }
}
