//! spmvperf CLI — the launcher for experiments, simulation, solvers and
//! the SpMV service.
//!
//! ```text
//! spmvperf experiment <fig2..fig9|all> [--full|--quick] [--machine m1,m2] [--csv DIR]
//! spmvperf simulate   [--machine nehalem] [--scheme crs|nbjds:1000|...]
//!                     [--threads-per-socket T] [--sockets S] [--schedule static|dynamic,C]
//! spmvperf predict    [--machine nehalem] — perf-model prediction per scheme
//! spmvperf tune       [--policy heuristic|measured|fixed] [--threads T] [--pin|--no-pin]
//!                     [--backend auto|serial|native|sharded] [--matrix FILE.mtx]
//!                     [--cv-threshold X] [--machine nehalem] [--quick]
//!                     [--precision bit|tol:EPS]
//!                     — tuned SpmvHandle: scheme/schedule/placement/backend/isa report
//! spmvperf lanczos    [--sites 6 --electrons 3 --max-phonons 4] [--eigenvalues 1]
//!                     [--threads T] [--pin|--no-pin] [--scheme auto|crs|sellcs:32:256|...]
//!                     [--backend auto|serial|native|sharded]
//! spmvperf shard      [--shards 1,2,4,8] [--mode bulk|overlap] [--threads T]
//!                     [--scheme crs|sellcs:32:256] [--pin|--no-pin]
//!                     [--policy heuristic|measured] [--quick|--full]
//!                     — sharded SpMV scaling table: shards × overlap mode
//! spmvperf benchdiff  <baseline.json> <current.json> [--tolerance 0.2]
//!                     — BENCH_*.json regression gate (CI)
//! spmvperf benchdiff  --suggest-floors <current.json> [--factor 0.7]
//!                     — print a committable baseline floored at factor × measured
//! spmvperf serve      [--bench] [--quick] [--max-batch 8 --max-delay-us 200]
//!                     [--tenants 2 --queue-cap 256 --duration 300]
//!                     — serving-layer load sweep (p50/p99 × throughput × shed);
//!                       --bench writes results/BENCH_serve.json for CI
//! spmvperf corpus     [--quick] [--seed 42] [--threads 4] [--pin|--no-pin]
//!                     [--precision bit|tol:EPS] [--block 4] [--exponent 2.2]
//!                     [--avg-nnz 8] [--edge-factor 8] [--matrices a,b] [--matrix FILE.mtx]
//!                     — corpus arbitration sweep; writes results/BENCH_corpus.json for CI
//! spmvperf audit      [--rule NAME] [--list]
//!                     — static analysis of the crate's own sources: SAFETY
//!                       comments, the atomic-ordering registry, spawn/ISA
//!                       containment, hot-path panics, bench baselines (CI gate)
//! spmvperf matrix     [--out FILE.mtx] — generate + analyze the test matrix
//! spmvperf info       — platform, machines, artifacts
//! ```

use anyhow::{bail, Context, Result};
use spmvperf::eigen::LanczosConfig;
use spmvperf::experiments::{self, ExpOptions};
use spmvperf::gen::{self, HolsteinHubbardParams};
use spmvperf::kernels::{IsaLevel, Precision, SpmvKernel};
use spmvperf::matrix::{Crs, Scheme, SpMv};
use spmvperf::perfmodel::{predict, CostCurve};
use spmvperf::runtime::{default_artifacts_dir, Runtime};
use spmvperf::sched::Schedule;
use spmvperf::shard::OverlapMode;
use spmvperf::simulator::{simulate_spmv, MachineSpec, Placement, SimOptions};
use spmvperf::spmv::{BackendChoice, SpmvHandle};
use spmvperf::tune::{ShardPolicy, TuningPolicy};
use spmvperf::util::cli::Args;
use spmvperf::util::report::{f, Table};

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let mut args = Args::from_env()?;
    let cmd = args.take_subcommand().unwrap_or_else(|| "help".to_string());
    match cmd.as_str() {
        "experiment" => cmd_experiment(&mut args),
        "simulate" => cmd_simulate(&args),
        "predict" => cmd_predict(&args),
        "tune" => cmd_tune(&args),
        "lanczos" => cmd_lanczos(&args),
        "shard" => cmd_shard(&args),
        "benchdiff" => cmd_benchdiff(&mut args),
        "serve" => cmd_serve(&args),
        "corpus" => cmd_corpus(&args),
        "matrix" => cmd_matrix(&args),
        "audit" => cmd_audit(&args),
        "info" => cmd_info(&args),
        "help" | "--help" | "-h" => {
            println!("{}", HELP);
            Ok(())
        }
        other => bail!("unknown command '{other}' (try `spmvperf help`)"),
    }
}

const HELP: &str = r#"spmvperf — SpMV multicore performance study (Schubert/Hager/Fehske 2009)

USAGE:
  spmvperf experiment <fig2|fig3|fig4|fig5|fig6|fig7|fig8|fig9|all>
                      [--full|--quick] [--machine woodcrest,nehalem] [--csv DIR]
  spmvperf simulate   [--machine nehalem] [--scheme crs] [--threads-per-socket 4]
                      [--sockets 2] [--schedule static] [--block 1000]
  spmvperf predict    [--machine nehalem] [--block 1000]
  spmvperf tune       [--policy heuristic|measured|fixed] [--scheme sellcs:32:256]
                      [--schedule static] [--threads 4] [--machine nehalem]
                      [--backend auto|serial|native|sharded] [--matrix FILE.mtx]
                      [--cv-threshold X] [--pin|--no-pin] [--quick|--full]
                      [--precision bit|tol:EPS]
  spmvperf lanczos    [--sites 6 --electrons 3 --max-phonons 4 --eigenvalues 1]
                      [--threads T] [--pin|--no-pin] [--scheme auto|crs|sellcs:32:256]
                      [--backend auto|serial|native|sharded] [--quick]
                      [--precision bit|tol:EPS]
  spmvperf shard      [--shards 1,2,4,8] [--mode bulk|overlap] [--threads 1]
                      [--scheme crs|sellcs:32:256] [--pin|--no-pin]
                      [--policy heuristic|measured] [--quick|--full]
  spmvperf benchdiff  <baseline.json> <current.json> [--tolerance 0.2]
  spmvperf benchdiff  --suggest-floors <current.json> [--factor 0.7]
  spmvperf serve      [--bench] [--quick] [--max-batch 8] [--max-delay-us 200]
                      [--tenants 2] [--queue-cap 256] [--duration 300]
  spmvperf corpus     [--quick] [--seed 42] [--threads 4] [--pin|--no-pin]
                      [--precision bit|tol:EPS] [--block 4] [--exponent 2.2]
                      [--avg-nnz 8] [--edge-factor 8]
                      [--matrices power-law,rmat,...] [--matrix FILE.mtx]
  spmvperf matrix     [--out FILE.mtx] [--full|--quick]
  spmvperf audit      [--rule NAME] [--list]
  spmvperf info
"#;

fn machines_from(args: &Args) -> Result<Vec<MachineSpec>> {
    let names = args.get_str_list("machine", &[]);
    if names.is_empty() {
        Ok(MachineSpec::all_x86())
    } else {
        names.iter().map(|n| MachineSpec::by_name(n)).collect()
    }
}

/// `--pin` / `--no-pin` (default: unpinned). Both spellings exist so
/// scripts can be explicit about either choice; combining them is an
/// error rather than a silent priority rule.
fn pin_flag(args: &Args) -> Result<bool> {
    let pin = args.flag("pin");
    let no_pin = args.flag("no-pin");
    anyhow::ensure!(!(pin && no_pin), "--pin and --no-pin are mutually exclusive");
    Ok(pin)
}

fn exp_options(args: &Args) -> Result<ExpOptions> {
    Ok(ExpOptions {
        full: args.flag("full"),
        quick: args.flag("quick"),
        machines: machines_from(args)?,
        csv_dir: args.get("csv").map(|s| s.to_string()),
    })
}

fn cmd_experiment(args: &mut Args) -> Result<()> {
    let id = args
        .take_subcommand()
        .context("experiment id required (fig2..fig9 or all)")?;
    let opts = exp_options(args)?;
    args.finish()?;
    experiments::run(&id, &opts)
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let machine = MachineSpec::by_name(&args.get_str("machine", "nehalem"))?;
    let scheme = Scheme::parse(&args.get_str("scheme", "crs"))?;
    let tps = args.get_usize("threads-per-socket", 1)?;
    let sockets = args.get_usize("sockets", 1)?;
    let schedule = Schedule::parse(&args.get_str("schedule", "static"))?;
    let opts = ExpOptions {
        full: args.flag("full"),
        quick: args.flag("quick"),
        ..Default::default()
    };
    args.finish()?;
    let coo = opts.test_matrix();
    eprintln!(
        "matrix: N={} nnz={} ({:.1} nnz/row)",
        coo.nrows,
        coo.nnz(),
        coo.nnz() as f64 / coo.nrows as f64
    );
    let kernel = SpmvKernel::build(&coo, scheme);
    let r = simulate_spmv(
        &machine,
        &kernel,
        tps,
        sockets,
        schedule,
        Placement::FirstTouchStatic,
        &SimOptions::default(),
    );
    let mut t = Table::new(
        &format!(
            "simulated SpMV: {} on {} ({tps} thr/socket x {sockets} sockets, {})",
            scheme.name(),
            machine.name,
            schedule.name()
        ),
        &["metric", "value"],
    );
    t.row(vec!["MFlop/s".into(), f(r.mflops)]);
    t.row(vec!["cycles/nnz".into(), f(r.cycles_per_update)]);
    t.row(vec!["time (ms)".into(), f(r.seconds * 1e3)]);
    t.row(vec!["DRAM traffic (MB)".into(), f(r.dram_bytes / 1e6)]);
    t.row(vec!["bandwidth utilization".into(), f(r.bw_utilization)]);
    t.row(vec!["remote traffic fraction".into(), f(r.remote_fraction)]);
    t.row(vec!["bound by".into(), r.bounded_by.to_string()]);
    t.row(vec!["TLB misses".into(), r.tlb_misses.to_string()]);
    t.print();
    Ok(())
}

fn cmd_predict(args: &Args) -> Result<()> {
    let machine = MachineSpec::by_name(&args.get_str("machine", "nehalem"))?;
    let block = args.get_usize("block", 1000)?;
    let opts = ExpOptions {
        full: args.flag("full"),
        quick: args.flag("quick"),
        ..Default::default()
    };
    args.finish()?;
    let coo = opts.test_matrix();
    let crs = Crs::from_coo(&coo);
    eprintln!("calibrating cost curve on {} ...", machine.name);
    let curve = CostCurve::calibrate(&machine, 40_000);
    let mut t = Table::new(
        &format!("performance-model predictions on {} (paper §1 goal)", machine.name),
        &["scheme", "pred cycles/nnz", "pred MFlop/s"],
    );
    for scheme in Scheme::all_extended(block, 2, 32, 256) {
        let k = SpmvKernel::build_from_crs(&crs, scheme);
        let p = predict(&machine, &curve, &k);
        t.row(vec![p.scheme.clone(), f(p.cycles_per_nnz), f(p.mflops)]);
    }
    t.print();
    Ok(())
}

/// `spmvperf tune` — run a tuning policy on the test matrix (or an
/// external MatrixMarket file via `--matrix`), print the decision +
/// candidate scoreboards — scheme, placement, shard AND backend — and
/// spot-check the tuned handle against the serial CRS reference.
fn cmd_tune(args: &Args) -> Result<()> {
    let quick = args.flag("quick");
    let full = args.flag("full");
    let pin = pin_flag(args)?;
    let policy_name = args.get_str("policy", "heuristic");
    let backend = BackendChoice::parse(&args.get_str("backend", "auto"))?;
    let threads = args.get_usize("threads", 4)?.max(1);
    let machine_arg = args.get("machine").map(str::to_string);
    let scheme_arg = args.get("scheme").map(str::to_string);
    let schedule_arg = args.get("schedule").map(str::to_string);
    let matrix_arg = args.get("matrix").map(str::to_string);
    let cv_threshold = match args.get("cv-threshold") {
        Some(_) => Some(args.get_f64("cv-threshold", 0.0)?),
        None => None,
    };
    let precision = Precision::parse(&args.get_str("precision", "bit"))?;
    args.finish()?;
    // Each flag belongs to one tier; reject combinations that would be
    // silently ignored: --scheme/--schedule feed only the fixed policy,
    // --machine only the heuristic's performance model.
    let fixed_only_flags = scheme_arg.is_none() && schedule_arg.is_none();
    let policy = match policy_name.as_str() {
        "heuristic" => {
            anyhow::ensure!(
                fixed_only_flags,
                "--scheme/--schedule only apply to --policy fixed (heuristic picks them itself)"
            );
            TuningPolicy::Heuristic
        }
        "measured" => {
            anyhow::ensure!(
                fixed_only_flags,
                "--scheme/--schedule only apply to --policy fixed (measured picks them itself)"
            );
            anyhow::ensure!(
                machine_arg.is_none(),
                "--machine only applies to --policy heuristic (measured times the host itself)"
            );
            TuningPolicy::Measured
        }
        "fixed" => {
            anyhow::ensure!(
                machine_arg.is_none(),
                "--machine only applies to --policy heuristic (fixed does no tuning)"
            );
            anyhow::ensure!(
                cv_threshold.is_none(),
                "--cv-threshold only applies to --policy heuristic|measured (fixed names \
                 the schedule itself)"
            );
            TuningPolicy::Fixed(
                Scheme::parse(scheme_arg.as_deref().unwrap_or("sellcs:32:256"))?,
                Schedule::parse(schedule_arg.as_deref().unwrap_or("static"))?,
            )
        }
        other => bail!("unknown policy '{other}' (expected heuristic|measured|fixed)"),
    };
    let machine = MachineSpec::by_name(machine_arg.as_deref().unwrap_or("nehalem"))?;
    let opts = ExpOptions { full, quick, ..Default::default() };
    // `--matrix FILE.mtx` tunes (and arbitrates) an external matrix
    // instead of the built-in Hamiltonian.
    let (coo, matrix_name) = match &matrix_arg {
        Some(path) => (
            spmvperf::matrix::io::read_matrix_market(std::path::Path::new(path))?,
            path.clone(),
        ),
        None => (opts.test_matrix(), "Holstein-Hubbard test matrix".to_string()),
    };
    eprintln!(
        "tuning on {matrix_name}: N={} nnz={} ({} policy, {} backend, {threads} threads)",
        coo.nrows,
        coo.nnz(),
        policy_name,
        backend.name()
    );
    let t0 = std::time::Instant::now();
    let mut builder = SpmvHandle::builder(&coo)
        .policy(policy)
        .backend(backend)
        .threads(threads)
        .machine(machine)
        .quick(quick)
        .pinned(pin)
        .precision(precision);
    if let Some(cv) = cv_threshold {
        builder = builder.schedule_cv_threshold(cv);
    }
    let handle = builder.build()?;
    let tune_time = t0.elapsed();
    eprintln!(
        "detected isa: {} (serving at {}, precision {})",
        IsaLevel::detect().name(),
        handle.kernel_isa().name(),
        handle.precision().name()
    );
    for t in handle.report().tables() {
        t.print();
    }
    let decision = handle.backend_decision().expect("the builder records a decision");
    eprintln!(
        "backend: {} ({} arbitration, {} candidate(s))",
        decision.backend,
        decision.policy,
        decision.candidates.len()
    );
    // Spot-check the tuned handle against the serial CRS reference.
    let crs = Crs::from_coo(&coo);
    let n = crs.nrows;
    let mut rng = spmvperf::util::rng::Rng::new(5);
    let mut x = vec![0.0; n];
    rng.fill_f64(&mut x, -1.0, 1.0);
    let mut y_ref = vec![0.0; n];
    crs.spmv(&x, &mut y_ref);
    let mut y = vec![0.0; n];
    handle.spmv(&x, &mut y);
    // The spot-check bound follows the contract: BitIdentical keeps the
    // historical absolute bound; Tolerance(ε) checks ε per row relative
    // to the reference magnitude.
    let err = match precision {
        Precision::BitIdentical => spmvperf::util::stats::max_abs_diff(&y_ref, &y),
        Precision::Tolerance(_) => y
            .iter()
            .zip(&y_ref)
            .map(|(g, w)| (g - w).abs() / w.abs().max(1.0))
            .fold(0.0, f64::max),
    };
    let bound = precision.tolerance().unwrap_or(1e-12);
    anyhow::ensure!(
        err <= bound,
        "tuned handle deviates from serial CRS by {err:.2e} (bound {bound:.1e})"
    );
    // Quick throughput sample of the tuned pick, through the serving
    // path so a pinned handle's first-touched workspace is what is
    // actually exercised.
    let reps = if quick { 5 } else { 20 };
    let t0 = std::time::Instant::now();
    for _ in 0..reps {
        handle.spmv(&x, &mut y);
        std::hint::black_box(y[0]);
    }
    let dt = t0.elapsed().as_secs_f64() / reps as f64;
    let mut t = Table::new("tuned handle", &["metric", "value"]);
    t.row(vec!["matrix".into(), matrix_name]);
    t.row(vec!["backend".into(), handle.backend_name().into()]);
    t.row(vec!["precision".into(), handle.precision().name()]);
    t.row(vec!["kernel isa".into(), handle.kernel_isa().name().into()]);
    t.row(vec!["tuning wall time (ms)".into(), f(tune_time.as_secs_f64() * 1e3)]);
    t.row(vec!["max err vs serial CRS".into(), format!("{err:.2e}")]);
    t.row(vec![
        "tuned SpMV throughput (MFlop/s)".into(),
        f(2.0 * SpMv::nnz(&handle) as f64 / dt / 1e6),
    ]);
    t.print();
    Ok(())
}

fn cmd_lanczos(args: &Args) -> Result<()> {
    let p = HolsteinHubbardParams {
        sites: args.get_usize("sites", 6)?,
        n_up: args.get_usize("electrons", 3)?,
        n_down: args.get_usize("electrons", 3)?,
        max_phonons: args.get_usize("max-phonons", 4)?,
        t: args.get_f64("t", 1.0)?,
        u: args.get_f64("u", 4.0)?,
        g: args.get_f64("g", 1.0)?,
        omega: args.get_f64("omega", 1.0)?,
        periodic: true,
    };
    let n_eigs = args.get_usize("eigenvalues", 1)?;
    let iters = args.get_usize("iters", 300)?;
    let threads = args.get_usize("threads", 1)?.max(1);
    let pin = pin_flag(args)?;
    let scheme_arg = args.get_str("scheme", "crs");
    let backend = BackendChoice::parse(&args.get_str("backend", "auto"))?;
    let quick = args.flag("quick");
    let precision = Precision::parse(&args.get_str("precision", "bit"))?;
    args.finish()?;
    eprintln!("building Holstein-Hubbard Hamiltonian: dim = {}", p.dimension());
    let h = gen::holstein_hubbard(&p);
    let crs = Crs::from_coo(&h);
    let cfg = LanczosConfig { max_iters: iters, ..Default::default() };
    // Hot loop through a tuned SpmvHandle: the solver never names a
    // backend — arbitration (or `--backend`) binds one. `--scheme auto`
    // additionally hands the scheme choice to the tuning layer. A fixed
    // scheme keeps the backend tier on its zero-probing default unless
    // `--backend` says otherwise.
    let policy = if scheme_arg == "auto" {
        TuningPolicy::Heuristic
    } else {
        TuningPolicy::Fixed(Scheme::parse(&scheme_arg)?, Schedule::Static { chunk: None })
    };
    let handle = SpmvHandle::builder_from_crs(&crs)
        .policy(policy)
        .backend(backend)
        .threads(threads)
        .quick(quick)
        .pinned(pin)
        .precision(precision)
        .build()?;
    if pin {
        eprintln!("placement: {}", handle.report().placement.summary());
    }
    if precision.allows_simd() {
        eprintln!(
            "precision {}: serving at {} (host detects {})",
            handle.precision().name(),
            handle.kernel_isa().name(),
            IsaLevel::detect().name()
        );
    }
    if scheme_arg == "auto" {
        eprintln!(
            "auto-tuned: {} ({}) on the {} backend",
            handle.scheme().name(),
            handle.schedule().name(),
            handle.backend_name()
        );
        for t in handle.report().tables() {
            t.print();
        }
    }
    let t0 = std::time::Instant::now();
    let r = spmvperf::eigen::lanczos_with_handle(&handle, n_eigs, &cfg);
    let dt = t0.elapsed();
    let mut t = Table::new(
        &format!(
            "Lanczos ground state ({} SpMV on {} backend, {threads} thread(s))",
            handle.scheme().name(),
            handle.backend_name()
        ),
        &["metric", "value"],
    );
    for (i, e) in r.eigenvalues.iter().enumerate() {
        t.row(vec![format!("E{i}"), format!("{e:.10}")]);
    }
    t.row(vec!["iterations".into(), r.iterations.to_string()]);
    t.row(vec!["converged".into(), r.converged.to_string()]);
    t.row(vec!["SpMVs".into(), r.spmv_count.to_string()]);
    t.row(vec!["wall time (s)".into(), f(dt.as_secs_f64())]);
    t.row(vec![
        "SpMV throughput (MFlop/s)".into(),
        f(2.0 * crs.nnz() as f64 * r.spmv_count as f64 / dt.as_secs_f64() / 1e6),
    ]);
    t.print();
    Ok(())
}

/// `spmvperf shard` — the fig-style sharded-SpMV scaling table: shard
/// counts × overlap modes on the Holstein-Hubbard test matrix, each
/// configuration self-validated against the serial CRS kernel before it
/// is timed (the shards-as-domains replay of arXiv:1106.5908's vector-
/// vs task-mode comparison). Every configuration is a forced-sharded
/// [`SpmvHandle`] — the CLI never names the executor type. `--policy
/// heuristic|measured` additionally runs the shard tuning tier and
/// prints its decision.
fn cmd_shard(args: &Args) -> Result<()> {
    let quick = args.flag("quick");
    let full = args.flag("full");
    let pin = pin_flag(args)?;
    let threads = args.get_usize("threads", 1)?.max(1);
    let scheme = Scheme::parse(&args.get_str("scheme", "crs"))?;
    let shards_list = args.get_usize_list("shards", &[1, 2, 4, 8])?;
    // `--mode bulk|overlap` restricts the sweep to one overlap mode
    // (default: both, side by side).
    let modes: Vec<OverlapMode> = match args.get("mode") {
        None => vec![OverlapMode::BulkSync, OverlapMode::Overlapped],
        Some(m) => vec![OverlapMode::parse(m)?],
    };
    let policy_arg = args.get("policy").map(str::to_string);
    args.finish()?;
    anyhow::ensure!(!shards_list.is_empty(), "--shards needs at least one count");
    anyhow::ensure!(
        shards_list.iter().all(|&s| s > 0),
        "--shards counts must be positive"
    );
    let opts = ExpOptions { full, quick, ..Default::default() };
    let coo = opts.test_matrix();
    let crs = std::sync::Arc::new(Crs::from_coo(&coo));
    let n = crs.nrows;
    let nnz = crs.nnz();
    eprintln!("sharding the Holstein-Hubbard test matrix: N={n} nnz={nnz}");
    let mut rng = spmvperf::util::rng::Rng::new(6);
    let mut x = vec![0.0; n];
    rng.fill_f64(&mut x, -1.0, 1.0);
    let mut y_ref = vec![0.0; n];
    crs.spmv(&x, &mut y_ref);
    let reps = if quick { 5 } else { 20 };
    let mut t = Table::new(
        &format!(
            "sharded SpMV scaling — {} ({threads} thread(s)/shard, {}): shards × overlap mode",
            scheme.name(),
            if pin { "pinned" } else { "unpinned" }
        ),
        &["shards", "mode", "halo frac", "boundary nnz frac", "MFlop/s", "vs first config"],
    );
    // Speedups are relative to the first measured configuration (the
    // first --shards entry in its first mode).
    let mut base = 0.0f64;
    let mut y = vec![0.0; n];
    for &s in &shards_list {
        for &mode in &modes {
            let handle = SpmvHandle::builder_from_crs(&crs)
                .policy(TuningPolicy::Fixed(scheme, Schedule::Static { chunk: None }))
                .backend(BackendChoice::Sharded)
                .shard_policy(ShardPolicy::Fixed { shards: s, mode })
                .threads(threads)
                .pinned(pin)
                .build()?;
            // Self-validate before timing: sharding must never change
            // the math.
            handle.spmv(&x, &mut y);
            let err = spmvperf::util::stats::max_abs_diff(&y_ref, &y);
            anyhow::ensure!(
                err == 0.0,
                "{s} shards × {} deviates from serial CRS by {err:.2e}",
                mode.name()
            );
            let t0 = std::time::Instant::now();
            for _ in 0..reps {
                handle.spmv(&x, &mut y);
                std::hint::black_box(y[0]);
            }
            let dt = t0.elapsed().as_secs_f64() / reps as f64;
            let mflops = 2.0 * nnz as f64 / dt / 1e6;
            if base == 0.0 {
                base = mflops;
            }
            let sd = handle
                .report()
                .shard
                .as_ref()
                .context("sharded handle records a shard decision")?;
            t.row(vec![
                s.to_string(),
                mode.name().into(),
                f(sd.halo_fraction),
                f(sd.boundary_nnz_fraction),
                f(mflops),
                f(mflops / base),
            ]);
        }
    }
    t.print();
    if let Some(p) = policy_arg {
        let shard_policy = match p.as_str() {
            "heuristic" => ShardPolicy::Heuristic,
            "measured" => ShardPolicy::Measured,
            other => bail!("unknown shard policy '{other}' (expected heuristic|measured)"),
        };
        let handle = SpmvHandle::builder_from_crs(&crs)
            .policy(TuningPolicy::Fixed(scheme, Schedule::Static { chunk: None }))
            .backend(BackendChoice::Sharded)
            .shard_policy(shard_policy)
            .threads(threads)
            .quick(quick)
            .pinned(pin)
            .build()?;
        for table in handle.report().tables() {
            table.print();
        }
        let mut yp = vec![0.0; n];
        handle.spmv(&x, &mut yp);
        let err = spmvperf::util::stats::max_abs_diff(&y_ref, &yp);
        anyhow::ensure!(err == 0.0, "tuned sharded handle deviates by {err:.2e}");
        eprintln!(
            "tuned: {} shard(s), {} mode — bit-identical to serial CRS",
            handle.n_shards(),
            handle.mode().map(|m| m.name()).unwrap_or("?")
        );
    }
    Ok(())
}

/// `spmvperf benchdiff` — compare a freshly generated `BENCH_*.json`
/// against the committed baseline and fail (exit 1) when any entry's
/// GFlop/s regressed past the tolerance. CI runs this as a blocking
/// step after the quick bench trajectory.
fn cmd_benchdiff(args: &mut Args) -> Result<()> {
    // `--suggest-floors CURRENT.json [--factor 0.7]`: instead of gating,
    // print a committable baseline with every measured entry floored at
    // factor × its throughput — the sanctioned way to refresh
    // `results-baseline/` off a real run.
    if args.flag("suggest-floors") {
        let current = args.take_subcommand().context("current BENCH_*.json path required")?;
        let factor = args.get_f64("factor", 0.7)?;
        args.finish()?;
        let floored = spmvperf::util::bench::suggest_floors_file(
            std::path::Path::new(&current),
            factor,
        )?;
        print!("{floored}");
        return Ok(());
    }
    let baseline = args.take_subcommand().context("baseline BENCH_*.json path required")?;
    let current = args.take_subcommand().context("current BENCH_*.json path required")?;
    let tolerance = args.get_f64("tolerance", 0.20)?;
    args.finish()?;
    anyhow::ensure!(
        (0.0..1.0).contains(&tolerance),
        "--tolerance must be a fraction in [0, 1), got {tolerance}"
    );
    let ok = spmvperf::util::bench::compare_bench_files(
        std::path::Path::new(&baseline),
        std::path::Path::new(&current),
        tolerance,
    )?;
    anyhow::ensure!(ok, "bench regression gate failed ({baseline} vs {current})");
    println!("bench trajectory OK within {:.0}% of baseline", tolerance * 100.0);
    Ok(())
}

/// The serving-layer bench/demo over `serve::Server` (persistent
/// dispatcher, deadline coalescing, multi-tenant handle cache,
/// admission control). Always runs the self-validated load sweep;
/// `--bench` additionally emits `results/BENCH_serve.json` for the CI
/// regression gate.
fn cmd_serve(args: &Args) -> Result<()> {
    let opts = spmvperf::serve::BenchOpts {
        quick: args.flag("quick"),
        max_batch: args.get_usize("max-batch", 8)?,
        max_delay_us: args.get_u64("max-delay-us", 200)?,
        tenants: args.get_usize("tenants", 2)?,
        queue_cap: args.get_usize("queue-cap", 256)?,
        duration_ms: args.get_u64("duration", 300)?,
        write_json: args.flag("bench"),
    };
    args.finish()?;
    spmvperf::serve::run_bench(&opts)
}

/// `spmvperf corpus` — sweep the generated graph/stencil/band corpus
/// (plus optional `--matrix FILE.mtx`) through all three tuning tiers
/// and the blocked-x SpMM path, self-validating every configuration,
/// then write `results/BENCH_corpus.json` — the standing
/// arbitration-quality benchmark gated by `benchdiff` in CI.
fn cmd_corpus(args: &Args) -> Result<()> {
    let mut opts = spmvperf::corpus::CorpusOptions {
        quick: args.flag("quick"),
        seed: args.get_u64("seed", 42)?,
        threads: args.get_usize("threads", 4)?.max(1),
        pin: pin_flag(args)?,
        precision: Precision::parse(&args.get_str("precision", "bit"))?,
        block: args.get_usize("block", 4)?,
        exponent: args.get_f64("exponent", 2.2)?,
        avg_nnz: args.get_usize("avg-nnz", 8)?,
        edge_factor: args.get_usize("edge-factor", 8)?,
        only: args.get_str_list("matrices", &[]),
        matrix_files: Vec::new(),
    };
    if let Some(path) = args.get("matrix") {
        opts.matrix_files.push(path.to_string());
    }
    args.finish()?;
    let report = spmvperf::corpus::run_corpus(&opts)?;
    let mut t = Table::new(
        &format!("corpus arbitration sweep ({} threads, block {})", opts.threads, opts.block),
        &["matrix", "policy", "backend", "scheme", "schedule", "MFlop/s"],
    );
    for e in &report.entries {
        t.row(vec![
            e.matrix.clone(),
            e.policy.clone(),
            e.backend.into(),
            e.scheme.clone(),
            e.schedule.clone(),
            f(e.mflops),
        ]);
    }
    t.print();
    if let Some(rate) = report.agreement_rate {
        println!("heuristic-vs-measured agreement rate: {:.0}%", rate * 100.0);
    }
    spmvperf::util::bench::write_bench_json("BENCH_corpus.json", &report.json);
    Ok(())
}

fn cmd_matrix(args: &Args) -> Result<()> {
    let opts = ExpOptions {
        full: args.flag("full"),
        quick: args.flag("quick"),
        ..Default::default()
    };
    let out = args.get("out").map(|s| s.to_string());
    args.finish()?;
    let coo = opts.test_matrix();
    let profile = spmvperf::analysis::diag_profile(&coo);
    let mut t = Table::new("Holstein-Hubbard test matrix", &["quantity", "value"]);
    t.row(vec!["dimension".into(), coo.nrows.to_string()]);
    t.row(vec!["non-zeros".into(), coo.nnz().to_string()]);
    t.row(vec!["avg nnz/row".into(), f(coo.nnz() as f64 / coo.nrows as f64)]);
    t.row(vec!["bandwidth".into(), profile.bandwidth().to_string()]);
    t.row(vec![
        "top-12 secondary diag share".into(),
        f(profile.fraction_in_top_secondary(12)),
    ]);
    t.print();
    if let Some(path) = out {
        spmvperf::matrix::io::write_matrix_market(&coo, std::path::Path::new(&path))?;
        eprintln!("wrote {path}");
    }
    Ok(())
}

/// `spmvperf audit [--rule NAME] [--list]` — run the in-repo static
/// analysis (see `src/audit/`) over the sources this binary was built
/// from. Exits non-zero on any finding, which is what makes it a CI
/// gate: `cargo build --release && ./target/release/spmvperf audit`.
fn cmd_audit(args: &Args) -> Result<()> {
    let list = args.flag("list");
    let rule = args.get("rule").map(|s| s.to_string());
    args.finish()?;
    if list {
        let mut t = Table::new("audit rules (waive with `// audit:allow(rule): reason`)", &[
            "rule", "contract",
        ]);
        for r in spmvperf::audit::RULES {
            t.row(vec![r.name.to_string(), r.desc.to_string()]);
        }
        t.print();
        return Ok(());
    }
    let report = spmvperf::audit::audit_crate(&spmvperf::audit::crate_root(), rule.as_deref())?;
    if report.findings.is_empty() {
        println!(
            "audit: {} files clean ({})",
            report.files,
            rule.as_deref().unwrap_or("all rules")
        );
        return Ok(());
    }
    for finding in &report.findings {
        println!("{finding}");
    }
    bail!(
        "audit: {} finding(s) in {} files — fix the site, or waive it with `// audit:allow(rule): reason`",
        report.findings.len(),
        report.files
    );
}

fn cmd_info(args: &Args) -> Result<()> {
    args.finish()?;
    let mut t = Table::new("machines (paper §3 test bed)", &[
        "machine", "sockets x cores", "freq GHz", "LLC", "STREAM GB/s", "NUMA",
    ]);
    for m in MachineSpec::all_x86().iter().chain([MachineSpec::hlrb2(64)].iter()) {
        let llc = m.l3.as_ref().map(|c| c.size_bytes).unwrap_or(m.l2.size_bytes);
        t.row(vec![
            m.name.to_string(),
            format!("{} x {}", m.sockets, m.cores_per_socket),
            f(m.freq_ghz),
            format!("{} MB", llc >> 20),
            f(m.node_bw_gbs),
            m.numa.to_string(),
        ]);
    }
    t.print();
    let dir = default_artifacts_dir();
    match Runtime::new(&dir) {
        Ok(rt) => {
            println!("PJRT platform: {}", rt.platform());
            println!("artifacts in {}:", dir.display());
            for a in rt.available() {
                println!("  {a}");
            }
        }
        Err(e) => println!("PJRT runtime unavailable: {e:#}"),
    }
    Ok(())
}
