//! # spmvperf
//!
//! Reproduction of *“Performance limitations for sparse matrix-vector
//! multiplications on current multicore environments”* (G. Schubert,
//! G. Hager, H. Fehske, 2009).
//!
//! The library provides:
//!
//! - all sparse storage schemes from the paper ([`matrix`]): CRS, JDS and
//!   the blocked/unrolled/reordered/sorted JDS refinements, plus the
//!   post-paper SELL-C-σ layout;
//! - a parallel SpMV **execution engine** ([`engine`]) with a
//!   plan/execute split: a persistent [`engine::SpmvPlan`] binds scheme ×
//!   schedule × thread count to per-thread partitions, and a long-lived
//!   [`engine::Engine`] thread pool runs the partitioned kernels with no
//!   per-call spawn — optionally **NUMA-placed** ([`engine::affinity`]):
//!   workers pinned to cores, workspace pages first-touched by their
//!   owners, and [`engine::SpmvPlan::rebalance`] re-homing them when the
//!   schedule changes;
//! - an **auto-tuning layer** ([`tune`]): a [`tune::TuningPolicy`] that
//!   picks scheme, SELL (C, σ) and schedule per matrix (fixed /
//!   fingerprint-heuristic / measured bake-off) and a
//!   [`tune::TuningReport`] explaining the decision;
//! - the **execution facade** ([`spmv`]): one [`spmv::SpmvHandle`] built
//!   by [`spmv::SpmvBuilder`], fronting the object-safe
//!   [`spmv::Backend`] trait whose impls are the serial kernel, the
//!   native parallel engine and the sharded executor — with a
//!   backend-arbitration tier ([`tune::BackendDecision`]) that picks the
//!   executor per matrix the same way the tuner picks the scheme;
//! - the paper's test matrix — a real Holstein-Hubbard Hamiltonian
//!   generator — plus auxiliary generators ([`gen`]);
//! - the microbenchmark kernels of Table 1 ([`kernels`]);
//! - a trace-driven multicore **memory-hierarchy simulator** standing in
//!   for the paper's 2009 test bed ([`simulator`]): caches, TLB, hardware
//!   prefetchers, ccNUMA, OpenMP-style scheduling;
//! - sparsity/stride analysis and a predictive performance model
//!   ([`analysis`], [`perfmodel`]);
//! - solvers as the motivating applications ([`eigen`]): the Lanczos
//!   eigensolver plus conjugate gradients, power iteration and PageRank
//!   ([`eigen::solve`]) — all pure SpMV+axpy loops over
//!   [`eigen::LinearOp`] so they run through any [`spmv::SpmvHandle`];
//! - a **corpus arbitration benchmark** ([`corpus`]): generated
//!   graph/stencil/band matrices swept through all three tuning tiers
//!   plus blocked-x SpMM, recording per-matrix decisions and the
//!   heuristic-vs-measured agreement rate (`BENCH_corpus.json`);
//! - a **sharding layer** ([`matrix::shard`], [`shard`]): the matrix
//!   row-partitioned into in-process domains with per-shard local/halo
//!   splits, halo exchange behind a transport trait, and bulk-synchronous
//!   vs compute/exchange-overlapped execution (arXiv:1106.5908) — each
//!   shard backed by its own pinned engine and first-touched buffers;
//! - a **serving layer** ([`serve`]): a [`serve::Server`] with one
//!   persistent dispatcher thread, deadline-based batch coalescing into
//!   `spmv_batch`, a multi-tenant LRU cache of tuned handles keyed by
//!   [`tune::MatrixFingerprint`] ([`serve::HandleCache`]), and admission
//!   control with per-tenant fairness and typed overload shedding
//!   ([`serve::Rejected`]);
//! - a PJRT runtime that loads the AOT-compiled JAX/Pallas SpMV artifacts
//!   and a coordinator serving batched SpMV requests ([`runtime`],
//!   [`coordinator`]) through one backend-agnostic
//!   [`coordinator::Executor`] over [`spmv::SpmvHandle`];
//! - experiment drivers regenerating every figure of the paper's
//!   evaluation ([`experiments`]).
//!
//! See DESIGN.md for the architecture and EXPERIMENTS.md for
//! paper-vs-measured results.

// CI runs `cargo clippy --all-targets -- -D warnings`. One style lint is
// allowed crate-wide by design: this codebase reproduces index-driven
// kernels from a performance paper, and rewriting stencil loops into
// iterator chains hides exactly the access order the study is about.
// Re-audited 2026-08: ~170 `for i in 0..n` sites across the kernels,
// storage schemes, simulator and experiment drivers still depend on
// explicit index order, so the allow stays — but it is a kernel-layer
// dispensation, not a precedent: new non-kernel modules opt back into
// the lint (see [`audit`] below).
#![allow(clippy::needless_range_loop)]

pub mod analysis;
// The audit layer is bookkeeping, not a kernel: the crate-wide range-loop
// dispensation does not apply to it.
#[deny(clippy::needless_range_loop)]
pub mod audit;
pub mod coordinator;
pub mod corpus;
pub mod eigen;
pub mod engine;
pub mod experiments;
pub mod gen;
pub mod kernels;
pub mod matrix;
pub mod perfmodel;
pub mod runtime;
pub mod sched;
pub mod serve;
pub mod shard;
pub mod simulator;
pub mod spmv;
pub mod tune;
pub mod util;
