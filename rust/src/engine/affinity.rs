//! Thread→core affinity for the host engine (the paper's §5.2 NUMA
//! lesson): first-touch page placement is only worth anything if worker
//! *i* **stays** on the domain that touched partition *i*. This module
//! pins engine threads with `sched_setaffinity` on Linux and degrades to
//! a clean, reported no-op everywhere else — non-Linux builds compile
//! and run unpinned, and the [`PinStatus`] they record says so.
//!
//! No `libc` crate is available offline; on Linux the three calls we
//! need (`sched_setaffinity`, `sched_getaffinity`, `sched_getcpu`) are
//! declared directly against the C library that `std` already links.

/// How an [`crate::engine::Engine`] pool maps threads onto cores.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PinMode {
    /// No pinning: threads roam wherever the OS scheduler puts them
    /// (the pre-NUMA behavior, and the paper's dynamic-schedule hazard).
    #[default]
    Disabled,
    /// Worker `tid` is pinned to CPU `tid % n_cpus`: a compact fill that
    /// keeps partition owners on fixed cores, so the pages they
    /// first-touch stay local for every later `execute`.
    Compact,
}

impl PinMode {
    pub fn name(&self) -> &'static str {
        match self {
            PinMode::Disabled => "unpinned",
            PinMode::Compact => "compact",
        }
    }
}

/// Outcome of one thread's pin attempt, recorded per engine thread and
/// surfaced through `TuningReport` so a tuned context can always say
/// where its workers actually ran.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PinStatus {
    /// Pinning was not requested for this pool.
    Disabled,
    /// The thread is bound to `cpu`.
    Pinned { cpu: usize },
    /// The platform has no thread-affinity syscall (non-Linux builds):
    /// the request degrades to a no-op and execution stays correct.
    Unsupported,
    /// `sched_setaffinity` itself failed (e.g. a cgroup cpuset excludes
    /// the requested CPU); the thread runs unpinned.
    Failed { errno: i32 },
}

impl PinStatus {
    pub fn label(&self) -> String {
        match self {
            PinStatus::Disabled => "unpinned".into(),
            PinStatus::Pinned { cpu } => format!("cpu{cpu}"),
            PinStatus::Unsupported => "unsupported".into(),
            PinStatus::Failed { errno } => format!("failed(errno {errno})"),
        }
    }
}

/// Realized placement of an engine pool: the requested mode plus the
/// per-thread outcomes (index = engine thread id, 0 = the caller).
#[derive(Debug, Clone)]
pub struct PinReport {
    pub mode: PinMode,
    pub per_thread: Vec<PinStatus>,
}

impl PinReport {
    pub fn unpinned(n_threads: usize) -> Self {
        PinReport { mode: PinMode::Disabled, per_thread: vec![PinStatus::Disabled; n_threads] }
    }

    /// Did every thread land on its requested CPU?
    pub fn all_pinned(&self) -> bool {
        self.mode != PinMode::Disabled
            && self
                .per_thread
                .iter()
                .all(|s| matches!(s, PinStatus::Pinned { .. }))
    }

    /// One-line summary for reports: `compact: cpu0 cpu1 cpu2 cpu3`.
    pub fn summary(&self) -> String {
        let threads: Vec<String> = self.per_thread.iter().map(|s| s.label()).collect();
        format!("{}: {}", self.mode.name(), threads.join(" "))
    }
}

/// Does this build have a real thread-affinity syscall?
pub fn pin_supported() -> bool {
    cfg!(target_os = "linux")
}

/// Online CPUs visible to this process (>= 1).
pub fn n_cpus() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// The compact-mode CPU for engine thread `tid`.
pub fn cpu_for(tid: usize, n_cpus: usize) -> usize {
    tid % n_cpus.max(1)
}

#[cfg(target_os = "linux")]
mod sys {
    use super::PinStatus;

    /// Matches glibc's fixed 1024-bit `cpu_set_t`.
    const CPU_SET_WORDS: usize = 1024 / (usize::BITS as usize);
    pub type CpuSet = [usize; CPU_SET_WORDS];

    extern "C" {
        fn sched_setaffinity(pid: i32, cpusetsize: usize, mask: *const usize) -> i32;
        fn sched_getaffinity(pid: i32, cpusetsize: usize, mask: *mut usize) -> i32;
        fn sched_getcpu() -> i32;
        fn __errno_location() -> *mut i32;
    }

    fn errno() -> i32 {
        // SAFETY: `__errno_location` returns a valid, thread-local
        // pointer for the lifetime of the calling thread (glibc ABI).
        unsafe { *__errno_location() }
    }

    pub fn pin_current_thread(cpu: usize) -> PinStatus {
        let mut set: CpuSet = [0; CPU_SET_WORDS];
        let word = cpu / usize::BITS as usize;
        if word >= CPU_SET_WORDS {
            return PinStatus::Failed { errno: 0 };
        }
        set[word] |= 1usize << (cpu % usize::BITS as usize);
        // pid 0 = the calling thread (per sched_setaffinity(2), the call
        // affects a single thread, not the whole process).
        // SAFETY: `set` is a live `CpuSet` and the size argument is
        // exactly its byte length; the kernel only reads the mask.
        let r = unsafe {
            sched_setaffinity(0, std::mem::size_of::<CpuSet>(), set.as_ptr())
        };
        if r == 0 {
            PinStatus::Pinned { cpu }
        } else {
            PinStatus::Failed { errno: errno() }
        }
    }

    /// The calling thread's current affinity mask, for restore-on-drop.
    pub fn get_affinity() -> Option<CpuSet> {
        let mut set: CpuSet = [0; CPU_SET_WORDS];
        // SAFETY: `set` is a live, writable `CpuSet` and the size
        // argument is exactly its byte length (the kernel fills it).
        let r = unsafe {
            sched_getaffinity(0, std::mem::size_of::<CpuSet>(), set.as_mut_ptr())
        };
        if r == 0 {
            Some(set)
        } else {
            None
        }
    }

    pub fn set_affinity(set: &CpuSet) -> bool {
        // SAFETY: `set` is a live `CpuSet` borrowed for the call and the
        // size argument is exactly its byte length; the kernel reads it.
        unsafe { sched_setaffinity(0, std::mem::size_of::<CpuSet>(), set.as_ptr()) == 0 }
    }

    pub fn current_cpu() -> Option<usize> {
        // SAFETY: `sched_getcpu` takes no arguments and only returns a
        // cpu id (or -1); there is no memory to get wrong.
        let c = unsafe { sched_getcpu() };
        if c >= 0 {
            Some(c as usize)
        } else {
            None
        }
    }
}

#[cfg(not(target_os = "linux"))]
mod sys {
    use super::PinStatus;

    /// Placeholder so the restore-on-drop plumbing typechecks off-Linux.
    pub type CpuSet = [usize; 0];

    pub fn pin_current_thread(_cpu: usize) -> PinStatus {
        PinStatus::Unsupported
    }

    pub fn get_affinity() -> Option<CpuSet> {
        None
    }

    pub fn set_affinity(_set: &CpuSet) -> bool {
        false
    }

    pub fn current_cpu() -> Option<usize> {
        None
    }
}

/// Bind the calling thread to `cpu`. On non-Linux targets this is a
/// no-op that reports [`PinStatus::Unsupported`].
pub fn pin_current_thread(cpu: usize) -> PinStatus {
    sys::pin_current_thread(cpu)
}

/// CPU the calling thread is currently running on (`None` off-Linux).
pub fn current_cpu() -> Option<usize> {
    sys::current_cpu()
}

std::thread_local! {
    /// Per-thread (original mask, live guard count). Only the **first**
    /// guard on a thread snapshots the mask and only the **last** one
    /// restores it: a nested pinned engine (e.g. `replanned` while the
    /// parent context is alive) would otherwise snapshot the
    /// already-pinned mask and "restore" the confinement on drop.
    static SAVED_MASK: std::cell::RefCell<(Option<sys::CpuSet>, usize)> =
        const { std::cell::RefCell::new((None, 0)) };
}

/// Saved affinity of the calling thread, restored when the last live
/// guard on that thread drops. The engine pins the *caller* (it
/// executes partition 0), and dropping the engine must not leave the
/// application's main thread stuck on one core.
///
/// Restoration is per-thread state: a guard dropped on a different
/// thread than it was created on is a no-op there (never a wrong
/// restore), at the cost of leaving the origin thread pinned.
pub struct AffinityGuard {
    active: bool,
    /// Thread the guard registered on: a guard dropped on any other
    /// thread must not touch that thread's nesting count (it would
    /// prematurely restore a mask belonging to someone else's guard).
    owner: std::thread::ThreadId,
}

impl AffinityGuard {
    /// Register a pinning guard, capturing the thread's affinity if it
    /// is the outermost one.
    pub fn save() -> Self {
        SAVED_MASK.with(|s| {
            let mut s = s.borrow_mut();
            if s.1 == 0 {
                s.0 = sys::get_affinity();
            }
            s.1 += 1;
        });
        AffinityGuard { active: true, owner: std::thread::current().id() }
    }

    /// A guard that restores nothing (unpinned engines).
    pub fn noop() -> Self {
        AffinityGuard { active: false, owner: std::thread::current().id() }
    }
}

impl Drop for AffinityGuard {
    fn drop(&mut self) {
        if !self.active || std::thread::current().id() != self.owner {
            // Foreign-thread drop (a pinned Engine moved across
            // threads): never a wrong restore; the origin thread simply
            // stays pinned.
            return;
        }
        SAVED_MASK.with(|s| {
            let mut s = s.borrow_mut();
            s.1 -= 1;
            if s.1 == 0 {
                if let Some(set) = s.0.take() {
                    let _ = sys::set_affinity(&set);
                }
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pin_support_matches_platform() {
        assert_eq!(pin_supported(), cfg!(target_os = "linux"));
    }

    #[test]
    fn cpu_for_wraps_compactly() {
        assert_eq!(cpu_for(0, 4), 0);
        assert_eq!(cpu_for(3, 4), 3);
        assert_eq!(cpu_for(5, 4), 1);
        assert_eq!(cpu_for(7, 1), 0);
        assert_eq!(cpu_for(2, 0), 0); // degenerate count clamps
    }

    #[test]
    fn pin_current_thread_reports_platform_truthfully() {
        let saved = AffinityGuard::save();
        let status = pin_current_thread(0);
        if pin_supported() {
            // CPU 0 may legitimately be excluded by a cpuset; accept
            // either outcome but never the `Unsupported` lie.
            assert!(
                matches!(status, PinStatus::Pinned { cpu: 0 } | PinStatus::Failed { .. }),
                "Linux pin attempt reported {status:?}"
            );
            if status == (PinStatus::Pinned { cpu: 0 }) {
                // After a successful pin, the thread must in fact be on 0.
                assert_eq!(current_cpu(), Some(0));
            }
        } else {
            assert_eq!(status, PinStatus::Unsupported);
            assert_eq!(current_cpu(), None);
        }
        drop(saved); // restore the test runner's mask
    }

    #[test]
    fn affinity_guard_restores_mask() {
        if !pin_supported() {
            return; // nothing to save/restore off-Linux
        }
        let before = sys::get_affinity().expect("read affinity");
        {
            let _guard = AffinityGuard::save();
            let _ = pin_current_thread(0);
        }
        let after = sys::get_affinity().expect("read affinity");
        assert_eq!(before, after, "guard must restore the original mask");
    }

    #[test]
    fn nested_guards_restore_the_outermost_mask() {
        if !pin_supported() {
            return;
        }
        // A second pinned engine while the first is alive (e.g. a
        // `replanned` context) must not adopt the already-pinned mask.
        let before = sys::get_affinity().expect("read affinity");
        {
            let _outer = AffinityGuard::save();
            let _ = pin_current_thread(0);
            {
                let _inner = AffinityGuard::save();
                let _ = pin_current_thread(0);
            }
            // inner dropped: still confined (outer is alive) — that is
            // the correct intermediate state, not a restore point.
        }
        let after = sys::get_affinity().expect("read affinity");
        assert_eq!(before, after, "only the outermost guard restores");
    }

    #[test]
    fn pin_report_summary_reads_well() {
        let r = PinReport {
            mode: PinMode::Compact,
            per_thread: vec![
                PinStatus::Pinned { cpu: 0 },
                PinStatus::Pinned { cpu: 1 },
                PinStatus::Failed { errno: 22 },
            ],
        };
        assert!(!r.all_pinned());
        let s = r.summary();
        assert!(s.contains("compact"));
        assert!(s.contains("cpu0"));
        assert!(s.contains("errno 22"));
        let ok = PinReport {
            mode: PinMode::Compact,
            per_thread: vec![PinStatus::Pinned { cpu: 0 }],
        };
        assert!(ok.all_pinned());
        assert!(!PinReport::unpinned(2).all_pinned());
    }
}
