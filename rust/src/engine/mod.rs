//! Parallel SpMV execution engine: the **plan / execute** split.
//!
//! The paper's central result is that storage scheme × access scheme ×
//! thread scheduling must be co-designed; this layer is where the three
//! meet at run time:
//!
//! - [`SpmvPlan`] (**plan**, built once): binds a [`Scheme`] +
//!   [`Schedule`] + thread count to concrete per-thread row partitions
//!   (per-diagonal-segment for the JDS family, per-slice-row for
//!   SELL-C-σ) and a preallocated permuted-basis [`Workspace`]. The
//!   *same* plan drives the host threads here and the machine-model
//!   simulator ([`crate::simulator::engine::simulate_spmv_plan`]), so
//!   measured and simulated runs share one scheduling decision.
//! - [`Engine`] (**execute**, long-lived): a scoped pool of worker
//!   threads parked on channels. `execute` dispatches the partitioned
//!   range-restricted kernels ([`SpmvKernel::spmv_rows_permuted`]) with
//!   no per-call thread spawn and no allocation beyond a completion
//!   latch.
//!
//! Because every range-restricted kernel reproduces its serial kernel's
//! per-row accumulation order, engine output is identical to the serial
//! reference for every scheme under every schedule — floating-point
//! reproducibility is a property of the plan, not of thread timing.

pub mod affinity;

use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use crate::eigen::LinearOp;
use crate::kernels::{IsaLevel, SpmvKernel, Workspace};
use crate::matrix::Scheme;
use crate::sched::{assign, Assignment, Schedule};

use affinity::{AffinityGuard, PinMode, PinReport, PinStatus};

/// Completion latch: `run` waits until every dispatched job finished.
/// `poisoned` records whether any job panicked.
struct Latch {
    remaining: Mutex<usize>,
    cv: Condvar,
    poisoned: std::sync::atomic::AtomicBool,
}

impl Latch {
    fn new(n: usize) -> Self {
        Latch {
            remaining: Mutex::new(n),
            cv: Condvar::new(),
            poisoned: std::sync::atomic::AtomicBool::new(false),
        }
    }

    fn count_down(&self) {
        let mut r = self.remaining.lock().unwrap();
        *r -= 1;
        if *r == 0 {
            self.cv.notify_all();
        }
    }

    fn wait(&self) {
        let mut r = self.remaining.lock().unwrap();
        while *r > 0 {
            r = self.cv.wait(r).unwrap();
        }
    }
}

/// Waits for the latch even when the caller's own partition panics:
/// workers still hold the lifetime-erased closure borrow, so `run`
/// must not unwind past them.
struct WaitOnDrop<'a>(&'a Latch);

impl Drop for WaitOnDrop<'_> {
    fn drop(&mut self) {
        self.0.wait();
    }
}

/// One dispatched unit: run the shared closure as thread `tid`.
struct Job {
    /// Borrow of the caller's closure with the lifetime erased; `run`
    /// blocks on the latch before returning, which keeps it valid.
    f: &'static (dyn Fn(usize) + Sync),
    tid: usize,
    done: Arc<Latch>,
}

/// A long-lived scoped thread pool for partitioned SpMV execution.
///
/// `Engine::new(t)` spawns `t - 1` workers (the calling thread executes
/// partition 0 itself); `run(f)` invokes `f(tid)` for every
/// `tid in 0..t` and returns when all are done. With `t == 1` everything
/// runs inline and no threads exist.
pub struct Engine {
    senders: Vec<mpsc::Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
    /// Realized thread→core placement (index = engine thread id).
    pin: PinReport,
    /// Restores the caller's affinity mask when the pool is dropped —
    /// pinning the engine must not permanently confine the main thread.
    _caller_affinity: AffinityGuard,
}

impl Engine {
    pub fn new(n_threads: usize) -> Self {
        Self::with_pinning(n_threads, PinMode::Disabled)
    }

    /// An engine whose threads are pinned per `mode`. The **calling
    /// thread is pinned too** (it executes partition 0, exactly like an
    /// OpenMP master under `OMP_PROC_BIND`); its previous affinity mask
    /// is restored when the engine is dropped. On platforms without
    /// `sched_setaffinity` the request degrades to a recorded no-op —
    /// see [`Engine::pin_report`].
    pub fn with_pinning(n_threads: usize, mode: PinMode) -> Self {
        Self::with_pinning_offset(n_threads, mode, 0)
    }

    /// Like [`Engine::with_pinning`], but thread `tid` lands on core
    /// `(core_offset + tid) % n_cpus`: several engines can coexist on
    /// disjoint core ranges. The sharding layer pins shard `s`'s engine
    /// at offset `s × threads_per_shard`, so in-process domains get the
    /// separate-socket placement of a real distributed run.
    pub fn with_pinning_offset(n_threads: usize, mode: PinMode, core_offset: usize) -> Self {
        assert!(n_threads > 0, "engine needs at least one thread");
        let n_cpus = affinity::n_cpus();
        let (caller_guard, caller_status) = match mode {
            PinMode::Disabled => (AffinityGuard::noop(), PinStatus::Disabled),
            PinMode::Compact => {
                let guard = AffinityGuard::save();
                (guard, affinity::pin_current_thread(affinity::cpu_for(core_offset, n_cpus)))
            }
        };
        let n_workers = n_threads - 1;
        let mut senders = Vec::with_capacity(n_workers);
        let mut workers = Vec::with_capacity(n_workers);
        let mut statuses = vec![caller_status];
        let (pin_tx, pin_rx) = mpsc::channel::<(usize, PinStatus)>();
        for w in 0..n_workers {
            let (tx, rx) = mpsc::channel::<Job>();
            senders.push(tx);
            let tid = w + 1;
            let pin_tx = pin_tx.clone();
            let handle = std::thread::Builder::new()
                .name(format!("spmv-engine-{tid}"))
                .spawn(move || {
                    // Pin before the first job so even the first-touch
                    // pass of a fresh plan runs on the final core.
                    let status = match mode {
                        PinMode::Disabled => PinStatus::Disabled,
                        PinMode::Compact => affinity::pin_current_thread(affinity::cpu_for(
                            core_offset + tid,
                            n_cpus,
                        )),
                    };
                    let _ = pin_tx.send((tid, status));
                    drop(pin_tx);
                    for job in rx {
                        // Contain panics so the worker survives, the
                        // dispatcher never deadlocks, and the failure is
                        // propagated (not swallowed) after the latch.
                        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                            (job.f)(job.tid)
                        }));
                        if r.is_err() {
                            job.done.poisoned.store(true, std::sync::atomic::Ordering::SeqCst);
                        }
                        job.done.count_down();
                    }
                })
                // audit:allow(hot_path_panic): construction-time spawn failure is unrecoverable
                .expect("spawning engine worker");
            workers.push(handle);
        }
        drop(pin_tx);
        statuses.resize(n_threads, PinStatus::Disabled);
        for _ in 0..n_workers {
            // audit:allow(hot_path_panic): a worker dying before its pin report is a startup bug
            let (tid, status) = pin_rx.recv().expect("engine worker died before reporting pin");
            statuses[tid] = status;
        }
        Engine {
            senders,
            workers,
            pin: PinReport { mode, per_thread: statuses },
            _caller_affinity: caller_guard,
        }
    }

    /// Where each engine thread is (or is not) pinned.
    pub fn pin_report(&self) -> &PinReport {
        &self.pin
    }

    /// An engine sized to the host (capped — SpMV saturates memory
    /// bandwidth long before core count, per the paper's Fig 8).
    pub fn with_host_threads(cap: usize) -> Self {
        let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        Self::new(hw.min(cap.max(1)))
    }

    pub fn n_threads(&self) -> usize {
        self.senders.len() + 1
    }

    /// Run `f(tid)` for every thread id, caller included, and return
    /// once all invocations completed. No thread spawn on this path.
    pub fn run<F: Fn(usize) + Sync>(&self, f: F) {
        if self.senders.is_empty() {
            f(0);
            return;
        }
        let latch = Arc::new(Latch::new(self.senders.len()));
        let fr: &(dyn Fn(usize) + Sync) = &f;
        // SAFETY: `latch.wait()` below blocks until every worker dropped
        // its job guard, so the erased borrow cannot outlive `f`.
        let fr = unsafe {
            std::mem::transmute::<&(dyn Fn(usize) + Sync), &'static (dyn Fn(usize) + Sync)>(fr)
        };
        for (i, tx) in self.senders.iter().enumerate() {
            let job = Job { f: fr, tid: i + 1, done: latch.clone() };
            if let Err(mpsc::SendError(job)) = tx.send(job) {
                // Worker gone (should not happen: panics are contained):
                // degrade to inline execution, containing panics so the
                // dispatch loop itself never unwinds mid-flight.
                let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    (job.f)(job.tid)
                }));
                if r.is_err() {
                    job.done.poisoned.store(true, std::sync::atomic::Ordering::SeqCst);
                }
                job.done.count_down();
            }
        }
        {
            // If f(0) panics below, this guard still waits for the
            // workers before the unwind tears down the caller's frame —
            // they hold the lifetime-erased borrow of `f` and its
            // captures.
            let guard = WaitOnDrop(&latch);
            f(0);
            drop(guard); // normal path: wait here
        }
        if latch.poisoned.load(std::sync::atomic::Ordering::SeqCst) {
            // audit:allow(hot_path_panic): re-raises a contained worker panic to the caller
            panic!("engine worker panicked during partitioned execution");
        }
    }
}

impl Engine {
    /// Partitioned dispatch over one output vector: for every chunk
    /// `(a, b)` of partition `t`, calls `f(a, b, out)` on thread `t`
    /// with `out = &mut y[a..b]`. [`SpmvPlan`] and the coordinator's
    /// executors dispatch through this (or its batched sibling
    /// [`Engine::run_chunks_batch`]); both funnel into the shared
    /// [`Engine::run_chunks_ptrs`] carving.
    ///
    /// Requirements (checked in debug builds): `partitions.len() ==
    /// n_threads()`, every chunk in bounds, and chunks disjoint across
    /// the whole partition set — which `sched::assign` guarantees.
    pub fn run_chunks<F>(&self, partitions: &[Vec<(usize, usize)>], y: &mut [f64], f: F)
    where
        F: Fn(usize, usize, &mut [f64]) + Sync,
    {
        let n = y.len();
        let bases = [SendPtr(y.as_mut_ptr())];
        self.run_chunks_ptrs(partitions, n, &bases, |_bi, a, b, out| f(a, b, out));
    }

    /// The per-base disjoint-write raw-pointer carving (its blocked-x
    /// sibling [`Engine::run_chunks_multi`] carves all bases per chunk;
    /// both validate through [`Engine::validate_chunks`]): checks the
    /// partition set against length `n` (bounds always; chunk
    /// disjointness in debug builds), then runs `f(bi, a, b, out)` on
    /// the owning thread for every chunk `(a, b)` × output base `bi`.
    fn run_chunks_ptrs<F>(
        &self,
        partitions: &[Vec<(usize, usize)>],
        n: usize,
        bases: &[SendPtr],
        f: F,
    ) where
        F: Fn(usize, usize, usize, &mut [f64]) + Sync,
    {
        self.validate_chunks(partitions, n);
        self.run(|t| {
            for &(a, b) in &partitions[t] {
                for (bi, base) in bases.iter().enumerate() {
                    // SAFETY: chunks are disjoint across threads (caller
                    // contract, validated in debug builds) and in bounds
                    // (checked above), and every base points at its own
                    // allocation — each sub-slice has exactly one owner.
                    let out = unsafe { std::slice::from_raw_parts_mut(base.0.add(a), b - a) };
                    f(bi, a, b, out);
                }
            }
        });
    }

    /// Shared precondition check for the carving dispatches: partition
    /// count matches the pool, chunks in bounds for length `n` (always),
    /// chunks disjoint across the whole partition set (debug builds).
    fn validate_chunks(&self, partitions: &[Vec<(usize, usize)>], n: usize) {
        assert_eq!(partitions.len(), self.n_threads());
        for part in partitions {
            for &(a, b) in part {
                assert!(a <= b && b <= n, "chunk ({a}, {b}) out of bounds for len {n}");
            }
        }
        #[cfg(debug_assertions)]
        {
            let mut seen = vec![false; n];
            for part in partitions {
                for &(a, b) in part {
                    for s in seen.iter_mut().take(b).skip(a) {
                        assert!(!*s, "overlapping chunks in partitioned dispatch");
                        *s = true;
                    }
                }
            }
        }
    }
}

impl Engine {
    /// Batched partitioned dispatch: like [`Engine::run_chunks`] but over
    /// `ys.len()` output vectors in **one** dispatch — the completion
    /// latch is paid once per batch, not once per vector. For every chunk
    /// `(a, b)` of partition `t` and every batch index `bi`, calls
    /// `f(bi, a, b, out)` on thread `t` with `out = &mut ys[bi][a..b]`.
    ///
    /// Requirements mirror `run_chunks` (all vectors share one length,
    /// chunks in bounds and disjoint across the partition set).
    pub fn run_chunks_batch<F>(&self, partitions: &[Vec<(usize, usize)>], ys: &mut [Vec<f64>], f: F)
    where
        F: Fn(usize, usize, usize, &mut [f64]) + Sync,
    {
        if ys.is_empty() {
            return;
        }
        let n = ys[0].len();
        for y in ys.iter() {
            assert_eq!(y.len(), n, "batch outputs must share one length");
        }
        let bases: Vec<SendPtr> = ys.iter_mut().map(|y| SendPtr(y.as_mut_ptr())).collect();
        self.run_chunks_ptrs(partitions, n, &bases, f);
    }

    /// Blocked-x partitioned dispatch: like [`Engine::run_chunks_batch`]
    /// but each chunk receives ALL `k` output slices in **one** call —
    /// `f(a, b, outs)` with `outs[bi] = &mut ys[bi][a..b]` — so the
    /// worker can stream the matrix rows once and reuse every loaded
    /// entry across the whole column block. Requirements mirror
    /// `run_chunks_batch` (one shared length, chunks in bounds and
    /// disjoint across the partition set).
    pub fn run_chunks_multi<F>(&self, partitions: &[Vec<(usize, usize)>], ys: &mut [Vec<f64>], f: F)
    where
        F: Fn(usize, usize, &mut [&mut [f64]]) + Sync,
    {
        if ys.is_empty() {
            return;
        }
        let n = ys[0].len();
        for y in ys.iter() {
            assert_eq!(y.len(), n, "multi outputs must share one length");
        }
        self.validate_chunks(partitions, n);
        let bases: Vec<SendPtr> = ys.iter_mut().map(|y| SendPtr(y.as_mut_ptr())).collect();
        self.run(|t| {
            for &(a, b) in &partitions[t] {
                // SAFETY: chunks are disjoint across threads (caller
                // contract, validated above in debug builds) and in
                // bounds (checked above), and every base points at its
                // own allocation — so each (chunk, base) sub-slice has
                // exactly one owner, and the k slices handed to one
                // call come from k distinct allocations.
                let mut outs: Vec<&mut [f64]> = bases
                    .iter()
                    .map(|base| unsafe { std::slice::from_raw_parts_mut(base.0.add(a), b - a) })
                    .collect();
                f(a, b, &mut outs);
            }
        });
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        self.senders.clear(); // close channels; workers drain and exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Pointer wrapper so disjoint row partitions can write one output
/// vector from several threads.
struct SendPtr(*mut f64);
// SAFETY: the pointer is only dereferenced inside `run_chunks_ptrs` /
// `run_chunks_multi`, which carve it into per-thread sub-slices over
// chunks proven disjoint and in bounds — no two threads alias a byte.
unsafe impl Send for SendPtr {}
// SAFETY: shared access is read-only pointer arithmetic; writes go
// through the disjoint sub-slices described above.
unsafe impl Sync for SendPtr {}

/// A pool of long-lived *role* threads parked on their channels between
/// dispatches — the coordinator-side sibling of [`Engine`]'s worker
/// pool. Where the engine partitions one kernel across its threads (and
/// the caller executes partition 0 itself), a `TaskPool` runs `count`
/// independent roles — shard coordinators, exchange threads — while the
/// caller only waits, so the caller's own affinity is never touched and
/// **no thread is ever spawned on a hot path**: every slot is spawned
/// once at construction, parks on a blocking `recv` when idle (no
/// spinning), and is reused by every subsequent [`TaskPool::run`].
///
/// [`TaskPool::spawned`] exposes the lifetime spawn count so callers can
/// assert the no-spawn-per-call contract in regression tests.
pub struct TaskPool {
    senders: Vec<mpsc::Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
    spawned: usize,
}

impl TaskPool {
    /// A pool of `n_slots` unpinned role threads.
    pub fn new(n_slots: usize) -> Self {
        Self::with_pin(n_slots, |_| None)
    }

    /// A pool whose slot `i` pins itself to `pin(i)` (when `Some`) once
    /// at spawn — persistent coordinators pay the pin syscall once, not
    /// per call. On platforms without affinity support the pin degrades
    /// to a recorded no-op exactly like [`Engine`] workers.
    pub fn with_pin<P: Fn(usize) -> Option<usize>>(n_slots: usize, pin: P) -> Self {
        assert!(n_slots > 0, "task pool needs at least one slot");
        let mut senders = Vec::with_capacity(n_slots);
        let mut workers = Vec::with_capacity(n_slots);
        for i in 0..n_slots {
            let (tx, rx) = mpsc::channel::<Job>();
            senders.push(tx);
            let cpu = pin(i);
            let handle = std::thread::Builder::new()
                .name(format!("spmv-coord-{i}"))
                .spawn(move || {
                    if let Some(c) = cpu {
                        let _ = affinity::pin_current_thread(c);
                    }
                    // Parked here between dispatches; exits when the
                    // pool drops its sender.
                    for job in rx {
                        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                            (job.f)(job.tid)
                        }));
                        if r.is_err() {
                            job.done.poisoned.store(true, std::sync::atomic::Ordering::SeqCst);
                        }
                        job.done.count_down();
                    }
                })
                // audit:allow(hot_path_panic): construction-time spawn failure is unrecoverable
                .expect("spawning task-pool role thread");
            workers.push(handle);
        }
        TaskPool { senders, workers, spawned: n_slots }
    }

    pub fn n_slots(&self) -> usize {
        self.senders.len()
    }

    /// Threads ever spawned by this pool — fixed at construction, so a
    /// test that snapshots it before a burst of calls and compares after
    /// proves the hot path spawns nothing.
    pub fn spawned(&self) -> usize {
        self.spawned
    }

    /// Run `f(i)` for every `i in 0..count` concurrently on the parked
    /// slots and return once all completed. Unlike [`Engine::run`] the
    /// caller executes nothing itself — it only blocks on the completion
    /// latch — so pinned slots keep their placement and the caller's
    /// affinity mask is untouched.
    pub fn run<F: Fn(usize) + Sync>(&self, count: usize, f: F) {
        assert!(
            count <= self.senders.len(),
            "dispatching {count} roles on a {}-slot pool",
            self.senders.len()
        );
        if count == 0 {
            return;
        }
        let latch = Arc::new(Latch::new(count));
        let fr: &(dyn Fn(usize) + Sync) = &f;
        // SAFETY: `latch.wait()` below blocks until every slot dropped
        // its job, so the erased borrow cannot outlive `f` (the same
        // contract as [`Engine::run`]).
        let fr = unsafe {
            std::mem::transmute::<&(dyn Fn(usize) + Sync), &'static (dyn Fn(usize) + Sync)>(fr)
        };
        for (i, tx) in self.senders[..count].iter().enumerate() {
            let job = Job { f: fr, tid: i, done: latch.clone() };
            if let Err(mpsc::SendError(job)) = tx.send(job) {
                // Slot gone (contained panics make this unreachable in
                // practice): degrade to inline execution so the latch
                // still resolves.
                let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    (job.f)(job.tid)
                }));
                if r.is_err() {
                    job.done.poisoned.store(true, std::sync::atomic::Ordering::SeqCst);
                }
                job.done.count_down();
            }
        }
        latch.wait();
        if latch.poisoned.load(std::sync::atomic::Ordering::SeqCst) {
            // audit:allow(hot_path_panic): re-raises a contained role-thread panic to the caller
            panic!("task-pool role thread panicked during dispatch");
        }
    }
}

impl Drop for TaskPool {
    fn drop(&mut self) {
        self.senders.clear(); // close channels; slots drain and exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// A persistent, reusable execution plan for one kernel: scheme +
/// schedule + thread count resolved to per-thread row partitions, plus a
/// preallocated permuted-basis workspace for original-basis calls.
pub struct SpmvPlan {
    pub scheme: Scheme,
    pub schedule: Schedule,
    pub n_threads: usize,
    pub nrows: usize,
    /// The iteration→thread assignment (also consumed by the simulator).
    pub assignment: Assignment,
    /// Per-row scheduling weights (nnz per permuted row).
    pub weights: Vec<f64>,
    /// Per-thread chunk lists in dispatch order.
    ranges: Vec<Vec<(usize, usize)>>,
    /// Preallocated workspace for the original-basis `execute` path.
    ws: Mutex<Workspace>,
    /// Whether the workspace pages were first-touched by their owning
    /// engine threads (NUMA placement) rather than by the building
    /// thread.
    first_touched: bool,
    /// ISA the range kernels dispatch at ([`SpmvKernel::spmv_rows_permuted_isa`]).
    /// Defaults to `Scalar` (bit-identical); the tuner raises it only
    /// under a `Tolerance` precision contract.
    kernel_isa: IsaLevel,
}

impl SpmvPlan {
    /// Plan `kernel` for `schedule` on `n_threads` threads.
    pub fn new(kernel: &SpmvKernel, schedule: Schedule, n_threads: usize) -> Self {
        let mut plan = Self::skeleton(kernel, schedule, n_threads);
        let n = plan.nrows;
        plan.ws = Mutex::new(Workspace { xp: vec![0.0; n], yp: vec![0.0; n] });
        plan
    }

    /// Everything but a usable workspace — `new` fills it on the calling
    /// thread, `new_first_touch` has the owning workers place it instead
    /// (no throwaway caller-touched allocation in between).
    fn skeleton(kernel: &SpmvKernel, schedule: Schedule, n_threads: usize) -> Self {
        assert!(n_threads > 0);
        let nrows = kernel.nrows();
        let weights = kernel.row_weights();
        let assignment = assign(schedule, nrows, &weights, n_threads);
        let ranges: Vec<Vec<(usize, usize)>> =
            (0..n_threads).map(|t| assignment.ranges_of(t as u16)).collect();
        SpmvPlan {
            scheme: kernel.scheme(),
            schedule,
            n_threads,
            nrows,
            assignment,
            weights,
            ranges,
            ws: Mutex::new(Workspace { xp: Vec::new(), yp: Vec::new() }),
            first_touched: false,
            kernel_isa: IsaLevel::Scalar,
        }
    }

    /// Plan `kernel` on the engine's thread count with **NUMA
    /// first-touch placement**: the permuted-basis workspace pages are
    /// touched by the engine thread that owns them under the exact
    /// assignment [`SpmvPlan::execute`] replays, so on a first-touch OS
    /// (Linux) each partition's pages home on the owning thread's
    /// domain. A second pass streams every thread's own rows of the
    /// kernel's `val`/`col_idx` arrays in kernel order, pre-faulting and
    /// warming them from the owning core. Pair with a pinned engine
    /// ([`Engine::with_pinning`]) — placement is meaningless if workers
    /// migrate afterwards.
    pub fn new_first_touch(kernel: &SpmvKernel, schedule: Schedule, engine: &Engine) -> Self {
        let mut plan = Self::skeleton(kernel, schedule, engine.n_threads());
        plan.first_touch(engine, kernel);
        plan
    }

    /// Re-partition this plan for a (possibly) new schedule on `engine`'s
    /// thread count and **re-home** the workspace: fresh pages are
    /// first-touched under the new assignment. This is the host-side
    /// answer to the paper's §5.2 hazard — after a schedule or thread
    /// count change, rows would otherwise keep being served from pages
    /// homed for the *old* owners, turning local traffic remote.
    pub fn rebalance(&mut self, engine: &Engine, kernel: &SpmvKernel, schedule: Schedule) {
        assert_eq!(kernel.nrows(), self.nrows, "rebalance got a different kernel");
        assert_eq!(kernel.scheme(), self.scheme, "rebalance got a different scheme");
        let n_threads = engine.n_threads();
        self.schedule = schedule;
        self.n_threads = n_threads;
        self.assignment = assign(schedule, self.nrows, &self.weights, n_threads);
        self.ranges = (0..n_threads).map(|t| self.assignment.ranges_of(t as u16)).collect();
        self.first_touch(engine, kernel);
    }

    /// Were the workspace pages first-touched by their owning threads?
    pub fn first_touched(&self) -> bool {
        self.first_touched
    }

    /// The ISA the range kernels dispatch at.
    pub fn kernel_isa(&self) -> IsaLevel {
        self.kernel_isa
    }

    /// Bind the range kernels to `isa`
    /// ([`SpmvKernel::spmv_rows_permuted_isa`]). The caller owns the
    /// numerical contract: anything above `Scalar` reorders/fuses FP
    /// accumulation and must only be bound under
    /// [`crate::kernels::Precision::Tolerance`], with `isa` at or below
    /// [`IsaLevel::detect`]. Survives [`SpmvPlan::rebalance`] — the ISA
    /// is a kernel property, not a partition property.
    pub fn set_kernel_isa(&mut self, isa: IsaLevel) {
        self.kernel_isa = isa;
    }

    /// First-touch the plan's workspace under the current assignment and
    /// stream the kernel's own rows from each owner. Two engine passes:
    ///
    /// 1. every thread zero-fills its chunks of freshly allocated
    ///    (never-written) `xp`/`yp` buffers ([`first_touch_buffers`]) —
    ///    the defining first touch that homes those pages on the
    ///    toucher's domain;
    /// 2. every thread runs its range-restricted kernel over the
    ///    now-zero input, touching exactly its rows' `val`/`col_idx` in
    ///    the order `execute` will replay.
    ///
    /// Already-resident matrix pages cannot be re-homed this way (that
    /// would need `migrate_pages(2)`); the workspace, which is allocated
    /// here, is placed for real, and the matrix pass still prefaults and
    /// warms the owner's caches/TLB.
    fn first_touch(&mut self, engine: &Engine, kernel: &SpmvKernel) {
        let mut bufs = first_touch_buffers(engine, &self.ranges, self.nrows, 2);
        // audit:allow(hot_path_panic): count is a literal two lines up; setup path, not execute
        let mut yp = bufs.pop().expect("two buffers requested");
        let xp = bufs.pop().expect("two buffers requested");
        // Scalar on purpose: the vector kernels touch the same
        // val/col_idx pages, and placement runs before any ISA binding.
        engine.run_chunks(&self.ranges, &mut yp, |a, b, out| {
            kernel.spmv_rows_permuted(a, b, &xp, out);
        });
        // x was all-zero, so yp is zero again: same state `new` leaves.
        self.ws = Mutex::new(Workspace { xp, yp });
        self.first_touched = true;
    }

    /// Chunks owned by thread `t`, in dispatch order.
    pub fn ranges_of(&self, t: usize) -> &[(usize, usize)] {
        &self.ranges[t]
    }

    /// Per-thread chunk lists in dispatch order, all threads — the
    /// partition set [`first_touch_buffers`] homes buffers under.
    pub fn partitions(&self) -> &[Vec<(usize, usize)>] {
        &self.ranges
    }

    /// Plan an arbitrary weighted row set: same schedules, same
    /// partitioning, no kernel and no workspace. The sharding layer
    /// plans each shard half this way — halves are not [`SpmvKernel`]s,
    /// but they are scheduled and carved identically. Execute through
    /// [`SpmvPlan::execute_partitioned`].
    pub fn for_weights(
        scheme: Scheme,
        schedule: Schedule,
        n_threads: usize,
        weights: Vec<f64>,
    ) -> Self {
        assert!(n_threads > 0);
        let nrows = weights.len();
        let assignment = assign(schedule, nrows, &weights, n_threads);
        let ranges: Vec<Vec<(usize, usize)>> =
            (0..n_threads).map(|t| assignment.ranges_of(t as u16)).collect();
        SpmvPlan {
            scheme,
            schedule,
            n_threads,
            nrows,
            assignment,
            weights,
            ranges,
            ws: Mutex::new(Workspace { xp: Vec::new(), yp: Vec::new() }),
            first_touched: false,
            kernel_isa: IsaLevel::Scalar,
        }
    }

    /// Partitioned dispatch of an arbitrary row-range closure over this
    /// plan's chunks: `f(a, b, out)` runs on the owning thread with
    /// `out = &mut out_vec[a..b]`. This is the execution surface for
    /// [`SpmvPlan::for_weights`] plans (shard halves); kernel-bound
    /// plans keep using [`SpmvPlan::execute`]/`execute_permuted`.
    pub fn execute_partitioned<F>(&self, engine: &Engine, out: &mut [f64], f: F)
    where
        F: Fn(usize, usize, &mut [f64]) + Sync,
    {
        assert_eq!(
            engine.n_threads(),
            self.n_threads,
            "plan was built for {} threads, engine has {}",
            self.n_threads,
            engine.n_threads()
        );
        assert_eq!(out.len(), self.nrows);
        engine.run_chunks(&self.ranges, out, f);
    }

    fn check(&self, engine: &Engine, kernel: &SpmvKernel) {
        assert_eq!(
            kernel.nrows(),
            self.nrows,
            "plan was built for a {}-row kernel",
            self.nrows
        );
        assert_eq!(
            kernel.scheme(),
            self.scheme,
            "plan was built for scheme {}",
            self.scheme
        );
        assert_eq!(
            engine.n_threads(),
            self.n_threads,
            "plan was built for {} threads, engine has {}",
            self.n_threads,
            engine.n_threads()
        );
    }

    /// Permuted-basis parallel SpMV (the hot path: no allocation, no
    /// gather/scatter). `yp` is fully overwritten.
    pub fn execute_permuted(
        &self,
        engine: &Engine,
        kernel: &SpmvKernel,
        xp: &[f64],
        yp: &mut [f64],
    ) {
        self.check(engine, kernel);
        assert_eq!(xp.len(), self.nrows);
        assert_eq!(yp.len(), self.nrows);
        engine.run_chunks(&self.ranges, yp, |a, b, out| {
            kernel.spmv_rows_permuted_isa(self.kernel_isa, a, b, xp, out);
        });
    }

    /// Original-basis parallel SpMV through the plan's preallocated
    /// workspace: gather, partitioned kernel, scatter.
    pub fn execute(&self, engine: &Engine, kernel: &SpmvKernel, x: &[f64], y: &mut [f64]) {
        assert_eq!(x.len(), self.nrows);
        assert_eq!(y.len(), self.nrows);
        let mut guard = self.ws.lock().unwrap();
        let Workspace { xp, yp } = &mut *guard;
        kernel.permute_into(x, xp);
        self.execute_permuted(engine, kernel, xp, yp);
        kernel.unpermute_into(yp, y);
    }

    /// Batched permuted-basis parallel SpMV: every vector of the batch is
    /// computed in a **single** engine dispatch
    /// ([`Engine::run_chunks_batch`]), amortizing the completion latch
    /// over the batch instead of paying it per vector. Each `yps[i]` is
    /// bit-identical to a per-vector [`SpmvPlan::execute_permuted`] call
    /// (same chunks, same range-restricted kernels).
    pub fn execute_batch_permuted(
        &self,
        engine: &Engine,
        kernel: &SpmvKernel,
        xps: &[Vec<f64>],
        yps: &mut [Vec<f64>],
    ) {
        self.check(engine, kernel);
        assert_eq!(xps.len(), yps.len());
        for (xp, yp) in xps.iter().zip(yps.iter()) {
            assert_eq!(xp.len(), self.nrows);
            assert_eq!(yp.len(), self.nrows);
        }
        engine.run_chunks_batch(&self.ranges, yps, |bi, a, b, out| {
            kernel.spmv_rows_permuted_isa(self.kernel_isa, a, b, &xps[bi], out);
        });
    }

    /// Original-basis batched SpMV: gathers every input into the permuted
    /// basis, runs one fused engine dispatch, scatters every result back.
    /// Identity-permutation kernels (CRS) read the callers' inputs
    /// directly and skip the gather/scatter copies entirely; permuted
    /// kernels scatter back into the already-consumed gather buffers, so
    /// at most two batch-sized buffer sets are ever allocated.
    pub fn execute_batch(
        &self,
        engine: &Engine,
        kernel: &SpmvKernel,
        xs: &[Vec<f64>],
    ) -> Vec<Vec<f64>> {
        if xs.is_empty() {
            return Vec::new();
        }
        for x in xs {
            assert_eq!(x.len(), self.nrows);
        }
        let mut yps: Vec<Vec<f64>> = xs.iter().map(|_| vec![0.0; self.nrows]).collect();
        if kernel.perm().is_none() {
            self.check(engine, kernel);
            engine.run_chunks_batch(&self.ranges, &mut yps, |bi, a, b, out| {
                kernel.spmv_rows_permuted_isa(self.kernel_isa, a, b, &xs[bi], out);
            });
            return yps;
        }
        let mut xps: Vec<Vec<f64>> = xs
            .iter()
            .map(|x| {
                let mut xp = vec![0.0; self.nrows];
                kernel.permute_into(x, &mut xp);
                xp
            })
            .collect();
        self.execute_batch_permuted(engine, kernel, &xps, &mut yps);
        for (xp, yp) in xps.iter_mut().zip(&yps) {
            kernel.unpermute_into(yp, xp);
        }
        xps
    }

    /// Blocked-x SpMM: the whole column block of `k` vectors is computed
    /// in a single engine dispatch that streams each matrix chunk ONCE
    /// ([`Engine::run_chunks_multi`] + [`SpmvKernel::spmv_rows_multi`]),
    /// reusing every loaded matrix entry across all `k` vectors — where
    /// [`SpmvPlan::execute_batch`] re-reads the matrix per vector. At
    /// [`IsaLevel::Scalar`] the fused loops keep the exact scalar
    /// accumulation order per vector, so each output is bit-identical
    /// to a per-vector [`SpmvPlan::execute`]; when a vector ISA is
    /// bound the fused vector bodies ([`crate::kernels::simd`]
    /// `*_rows_multi`) broadcast each matrix entry and FMA it across
    /// the column block, preserving per-vector entry order so the
    /// deviation stays within the [`Precision::Tolerance`] contraction
    /// bound.
    ///
    /// [`Precision::Tolerance`]: crate::kernels::Precision::Tolerance
    pub fn execute_multi(
        &self,
        engine: &Engine,
        kernel: &SpmvKernel,
        xs: &[Vec<f64>],
    ) -> Vec<Vec<f64>> {
        if xs.is_empty() {
            return Vec::new();
        }
        for x in xs {
            assert_eq!(x.len(), self.nrows);
        }
        self.check(engine, kernel);
        let mut yps: Vec<Vec<f64>> = xs.iter().map(|_| vec![0.0; self.nrows]).collect();
        if kernel.perm().is_none() {
            let xrefs: Vec<&[f64]> = xs.iter().map(|x| x.as_slice()).collect();
            engine.run_chunks_multi(&self.ranges, &mut yps, |a, b, outs| {
                kernel.spmv_rows_multi_isa(self.kernel_isa, a, b, &xrefs, outs);
            });
            return yps;
        }
        let mut xps: Vec<Vec<f64>> = xs
            .iter()
            .map(|x| {
                let mut xp = vec![0.0; self.nrows];
                kernel.permute_into(x, &mut xp);
                xp
            })
            .collect();
        {
            let xrefs: Vec<&[f64]> = xps.iter().map(|x| x.as_slice()).collect();
            engine.run_chunks_multi(&self.ranges, &mut yps, |a, b, outs| {
                kernel.spmv_rows_multi_isa(self.kernel_isa, a, b, &xrefs, outs);
            });
        }
        for (xp, yp) in xps.iter_mut().zip(&yps) {
            kernel.unpermute_into(yp, xp);
        }
        xps
    }
}

/// First-touch-allocate `count` zero-filled `f64` buffers of length
/// `n`: every element is written exactly once by the engine thread that
/// owns it under `partitions`, so on a first-touch OS each chunk's
/// pages home on the owning thread's NUMA domain. Used by
/// [`SpmvPlan::new_first_touch`] for the permuted-basis workspace and
/// by the sharding layer ([`crate::shard`]) to home each shard's
/// local/remote outputs and halo gather buffer.
#[allow(clippy::uninit_vec)] // the tiling check below proves every index is written once
pub fn first_touch_buffers(
    engine: &Engine,
    partitions: &[Vec<(usize, usize)>],
    n: usize,
    count: usize,
) -> Vec<Vec<f64>> {
    assert_eq!(partitions.len(), engine.n_threads());
    // `set_len` below is only sound if the pass writes EVERY element
    // exactly once, so prove the chunk set tiles [0, n): sorted, each
    // chunk must start where the previous ended. (A mere
    // sum-of-lengths check would accept overlapping chunks that leave
    // holes of uninitialized memory.)
    let mut spans: Vec<(usize, usize)> =
        partitions.iter().flatten().copied().filter(|&(a, b)| a < b).collect();
    spans.sort_unstable();
    let mut pos = 0;
    for &(a, b) in &spans {
        assert!(
            a == pos && b <= n,
            "partitions must tile [0, {n}) exactly to first-touch buffers \
             (chunk ({a}, {b}) after position {pos})"
        );
        pos = b;
    }
    assert_eq!(pos, n, "partitions must cover every element to first-touch buffers");
    let mut bufs: Vec<Vec<f64>> = (0..count).map(|_| Vec::with_capacity(n)).collect();
    {
        let bases: Vec<SendPtr> = bufs.iter_mut().map(|b| SendPtr(b.as_mut_ptr())).collect();
        let bases = &bases;
        engine.run(|t| {
            for &(a, b) in &partitions[t] {
                for base in bases.iter() {
                    // SAFETY: chunks are disjoint across threads and
                    // within capacity; each index has one writer.
                    unsafe { std::ptr::write_bytes(base.0.add(a), 0, b - a) };
                }
            }
        });
    }
    // SAFETY: the tiling check above proves the chunks partition [0, n)
    // with no overlap and no hole, so every element of every buffer was
    // initialized by exactly one thread.
    for b in &mut bufs {
        unsafe { b.set_len(n) };
    }
    bufs
}

/// A one-shot readiness latch ordering the halo exchange before the
/// remote phase of a sharded SpMV. The exchange side fills the halo
/// buffer and calls [`HaloGate::signal`]; the compute side calls
/// [`HaloGate::wait`] between its local and remote phases. The mutex
/// hand-off makes the exchange's writes happen-before every
/// post-`wait` read, which is what lets the remote kernel read the
/// gather buffer through a shared pointer without holding a Rust
/// borrow across the concurrent write (see `crate::shard`).
#[derive(Default)]
pub struct HaloGate {
    ready: Mutex<bool>,
    cv: Condvar,
}

impl HaloGate {
    pub fn new() -> Self {
        Self::default()
    }

    /// Open the gate: the halo buffer is fully written.
    pub fn signal(&self) {
        *self.ready.lock().unwrap() = true;
        self.cv.notify_all();
    }

    /// Block until the gate opens (returns immediately if already open).
    pub fn wait(&self) {
        let mut r = self.ready.lock().unwrap();
        while !*r {
            r = self.cv.wait(r).unwrap();
        }
    }

    pub fn is_open(&self) -> bool {
        *self.ready.lock().unwrap()
    }
}

/// Two-phase execution with a halo-ready dependency — the engine-level
/// shape of the compute/exchange overlap in arXiv:1106.5908: the
/// `local` plan (interior rows, no halo inputs) dispatches immediately
/// and is the work that hides the exchange; the `remote` plan
/// (boundary rows) dispatches only once `halo_ready` opens. In
/// bulk-synchronous mode the caller performs the exchange first,
/// signals the gate, and the phases simply run back to back — same
/// kernels, same order, bit-identical output either way.
pub struct TwoPhasePlan<'a> {
    pub local: &'a SpmvPlan,
    pub remote: &'a SpmvPlan,
}

impl TwoPhasePlan<'_> {
    pub fn execute<FL, FR>(
        &self,
        engine: &Engine,
        halo_ready: &HaloGate,
        local_out: &mut [f64],
        remote_out: &mut [f64],
        fl: FL,
        fr: FR,
    ) where
        FL: Fn(usize, usize, &mut [f64]) + Sync,
        FR: Fn(usize, usize, &mut [f64]) + Sync,
    {
        self.local.execute_partitioned(engine, local_out, fl);
        halo_ready.wait();
        self.remote.execute_partitioned(engine, remote_out, fr);
    }
}

/// A kernel + engine + plan bound together as a [`LinearOp`], so the
/// Lanczos solver (and anything else operator-driven) runs its hot loop
/// through the parallel engine.
pub struct EngineOp<'a> {
    pub kernel: &'a SpmvKernel,
    pub engine: &'a Engine,
    pub plan: &'a SpmvPlan,
}

impl LinearOp for EngineOp<'_> {
    fn dim(&self) -> usize {
        self.kernel.nrows()
    }

    fn apply(&self, x: &[f64], y: &mut [f64]) {
        self.plan.execute(self.engine, self.kernel, x, y);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen;
    use crate::matrix::Coo;
    use crate::util::rng::Rng;
    use crate::util::stats::max_abs_diff;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn engine_runs_every_partition_exactly_once() {
        let engine = Engine::new(4);
        assert_eq!(engine.n_threads(), 4);
        let mask = AtomicUsize::new(0);
        engine.run(|t| {
            mask.fetch_or(1 << t, Ordering::SeqCst);
        });
        assert_eq!(mask.load(Ordering::SeqCst), 0b1111);
        // Reuse without respawn.
        let count = AtomicUsize::new(0);
        for _ in 0..50 {
            engine.run(|_| {
                count.fetch_add(1, Ordering::SeqCst);
            });
        }
        assert_eq!(count.load(Ordering::SeqCst), 200);
    }

    #[test]
    fn worker_panic_is_propagated_and_engine_survives() {
        let engine = Engine::new(3);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            engine.run(|t| {
                if t == 2 {
                    panic!("boom");
                }
            });
        }));
        assert!(r.is_err(), "worker panic must propagate to the dispatcher");
        // The pool survives a poisoned dispatch and stays usable.
        let count = AtomicUsize::new(0);
        engine.run(|_| {
            count.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(count.load(Ordering::SeqCst), 3);
    }

    #[test]
    fn single_thread_engine_runs_inline() {
        let engine = Engine::new(1);
        assert_eq!(engine.n_threads(), 1);
        let count = AtomicUsize::new(0);
        engine.run(|t| {
            assert_eq!(t, 0);
            count.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(count.load(Ordering::SeqCst), 1);
    }

    fn random_coo(rng: &mut Rng, n: usize, nnz: usize) -> Coo {
        let mut coo = Coo::new(n, n);
        for _ in 0..nnz {
            coo.push(rng.index(n), rng.index(n), rng.f64() * 2.0 - 1.0);
        }
        coo.normalize();
        coo
    }

    fn schedules() -> Vec<Schedule> {
        vec![
            Schedule::Static { chunk: None },
            Schedule::Static { chunk: Some(7) },
            Schedule::Dynamic { chunk: 13 },
            Schedule::Guided { min_chunk: 4 },
        ]
    }

    #[test]
    fn parallel_identical_to_serial_all_schemes_schedules_threads() {
        let mut rng = Rng::new(70);
        let n = 160;
        let coo = random_coo(&mut rng, n, n * 6);
        let mut x = vec![0.0; n];
        rng.fill_f64(&mut x, -1.0, 1.0);
        for n_threads in [1usize, 2, 4] {
            let engine = Engine::new(n_threads);
            for scheme in Scheme::all_extended(16, 3, 8, 32) {
                let kernel = SpmvKernel::build(&coo, scheme);
                let mut y_serial = vec![0.0; n];
                kernel.spmv(&x, &mut y_serial);
                for schedule in schedules() {
                    let plan = SpmvPlan::new(&kernel, schedule, n_threads);
                    let mut y_par = vec![0.0; n];
                    plan.execute(&engine, &kernel, &x, &mut y_par);
                    assert_eq!(
                        max_abs_diff(&y_serial, &y_par),
                        0.0,
                        "{scheme} × {} × {n_threads} threads deviates from serial",
                        schedule.name()
                    );
                }
            }
        }
    }

    #[test]
    fn parallel_identical_to_serial_on_holstein_hubbard() {
        let h = gen::holstein_hubbard(&gen::HolsteinHubbardParams::tiny());
        let n = h.nrows;
        let mut rng = Rng::new(71);
        let mut x = vec![0.0; n];
        rng.fill_f64(&mut x, -1.0, 1.0);
        let engine = Engine::new(4);
        for scheme in Scheme::all_extended(64, 2, 32, 256) {
            let kernel = SpmvKernel::build(&h, scheme);
            let mut y_serial = vec![0.0; n];
            kernel.spmv(&x, &mut y_serial);
            let plan = SpmvPlan::new(&kernel, Schedule::Static { chunk: None }, 4);
            let mut y_par = vec![0.0; n];
            plan.execute(&engine, &kernel, &x, &mut y_par);
            assert_eq!(max_abs_diff(&y_serial, &y_par), 0.0, "{scheme} on HH");
        }
    }

    #[test]
    fn plan_is_reusable_across_calls() {
        let mut rng = Rng::new(72);
        let n = 100;
        let coo = random_coo(&mut rng, n, 700);
        let kernel = SpmvKernel::build(&coo, Scheme::SellCs { c: 8, sigma: 32 });
        let engine = Engine::new(3);
        let plan = SpmvPlan::new(&kernel, Schedule::Dynamic { chunk: 9 }, 3);
        let mut want = vec![0.0; n];
        let mut got = vec![0.0; n];
        for trial in 0..10 {
            let mut x = vec![0.0; n];
            rng.fill_f64(&mut x, -1.0, 1.0);
            kernel.spmv(&x, &mut want);
            plan.execute(&engine, &kernel, &x, &mut got);
            assert_eq!(max_abs_diff(&want, &got), 0.0, "trial {trial}");
        }
    }

    #[test]
    fn plan_partitions_cover_all_rows_once() {
        let mut rng = Rng::new(73);
        let coo = random_coo(&mut rng, 211, 1500);
        let kernel = SpmvKernel::build(&coo, Scheme::Crs);
        for schedule in schedules() {
            for n_threads in [1usize, 2, 4, 7] {
                let plan = SpmvPlan::new(&kernel, schedule, n_threads);
                let mut seen = vec![0u8; 211];
                for t in 0..n_threads {
                    for &(a, b) in plan.ranges_of(t) {
                        for s in seen.iter_mut().take(b).skip(a) {
                            *s += 1;
                        }
                    }
                }
                assert!(
                    seen.iter().all(|&c| c == 1),
                    "{} × {n_threads}: rows not covered exactly once",
                    schedule.name()
                );
            }
        }
    }

    #[test]
    fn batched_execute_identical_to_per_vector() {
        let mut rng = Rng::new(74);
        let n = 137;
        let coo = random_coo(&mut rng, n, n * 6);
        let xs: Vec<Vec<f64>> = (0..5)
            .map(|_| {
                let mut x = vec![0.0; n];
                rng.fill_f64(&mut x, -1.0, 1.0);
                x
            })
            .collect();
        for n_threads in [1usize, 3] {
            let engine = Engine::new(n_threads);
            for scheme in Scheme::all_extended(16, 3, 8, 32) {
                let kernel = SpmvKernel::build(&coo, scheme);
                for schedule in schedules() {
                    let plan = SpmvPlan::new(&kernel, schedule, n_threads);
                    let batched = plan.execute_batch(&engine, &kernel, &xs);
                    assert_eq!(batched.len(), xs.len());
                    for (x, yb) in xs.iter().zip(&batched) {
                        let mut y = vec![0.0; n];
                        plan.execute(&engine, &kernel, x, &mut y);
                        assert_eq!(
                            max_abs_diff(&y, yb),
                            0.0,
                            "{scheme} × {} × {n_threads}T: batch deviates from per-vector",
                            schedule.name()
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn empty_batch_is_a_noop() {
        let mut rng = Rng::new(75);
        let coo = random_coo(&mut rng, 40, 200);
        let kernel = SpmvKernel::build(&coo, Scheme::Crs);
        let engine = Engine::new(2);
        let plan = SpmvPlan::new(&kernel, Schedule::Static { chunk: None }, 2);
        assert!(plan.execute_batch(&engine, &kernel, &[]).is_empty());
    }

    #[test]
    fn pinned_engine_reports_placement() {
        let engine = Engine::with_pinning(3, PinMode::Compact);
        let r = engine.pin_report();
        assert_eq!(r.mode, PinMode::Compact);
        assert_eq!(r.per_thread.len(), 3);
        for (tid, s) in r.per_thread.iter().enumerate() {
            if affinity::pin_supported() {
                assert!(
                    matches!(s, PinStatus::Pinned { .. } | PinStatus::Failed { .. }),
                    "thread {tid}: Linux pin attempt reported {s:?}"
                );
            } else {
                assert_eq!(*s, PinStatus::Unsupported, "thread {tid}");
            }
        }
        // An unpinned engine records that nothing was requested.
        let plain = Engine::new(2);
        assert_eq!(plain.pin_report().mode, PinMode::Disabled);
        assert!(plain.pin_report().per_thread.iter().all(|s| *s == PinStatus::Disabled));
    }

    /// The ISSUE-3 invariant: parallel output stays bit-identical to the
    /// serial kernels across schemes × schedules × pinning on/off, with
    /// first-touch placement, on every platform (non-Linux pinning falls
    /// back to a no-op and must change nothing).
    #[test]
    fn first_touch_pinned_identical_to_serial_all_schemes_schedules() {
        let mut rng = Rng::new(76);
        let n = 160;
        let coo = random_coo(&mut rng, n, n * 6);
        let mut x = vec![0.0; n];
        rng.fill_f64(&mut x, -1.0, 1.0);
        for pin in [PinMode::Disabled, PinMode::Compact] {
            let engine = Engine::with_pinning(4, pin);
            for scheme in Scheme::all_extended(16, 3, 8, 32) {
                let kernel = SpmvKernel::build(&coo, scheme);
                let mut y_serial = vec![0.0; n];
                kernel.spmv(&x, &mut y_serial);
                for schedule in schedules() {
                    let plan = SpmvPlan::new_first_touch(&kernel, schedule, &engine);
                    assert!(plan.first_touched());
                    let mut y_par = vec![0.0; n];
                    plan.execute(&engine, &kernel, &x, &mut y_par);
                    assert_eq!(
                        max_abs_diff(&y_serial, &y_par),
                        0.0,
                        "{scheme} × {} × pin {}: first-touch plan deviates from serial",
                        schedule.name(),
                        pin.name()
                    );
                }
            }
        }
    }

    #[test]
    fn rebalance_repartitions_and_stays_bit_identical() {
        let mut rng = Rng::new(77);
        let n = 211;
        let coo = random_coo(&mut rng, n, n * 7);
        let mut x = vec![0.0; n];
        rng.fill_f64(&mut x, -1.0, 1.0);
        for pin in [PinMode::Disabled, PinMode::Compact] {
            let engine = Engine::with_pinning(4, pin);
            for scheme in [Scheme::Crs, Scheme::SellCs { c: 8, sigma: 32 }] {
                let kernel = SpmvKernel::build(&coo, scheme);
                let mut want = vec![0.0; n];
                kernel.spmv(&x, &mut want);
                let mut plan =
                    SpmvPlan::new_first_touch(&kernel, Schedule::Static { chunk: None }, &engine);
                let before: Vec<Vec<(usize, usize)>> =
                    (0..4).map(|t| plan.ranges_of(t).to_vec()).collect();
                let mut got = vec![0.0; n];
                plan.execute(&engine, &kernel, &x, &mut got);
                assert_eq!(max_abs_diff(&want, &got), 0.0, "{scheme}: pre-rebalance");
                for schedule in [
                    Schedule::Dynamic { chunk: 9 },
                    Schedule::Guided { min_chunk: 3 },
                    Schedule::Static { chunk: Some(5) },
                ] {
                    plan.rebalance(&engine, &kernel, schedule);
                    assert_eq!(plan.schedule, schedule);
                    assert!(plan.first_touched());
                    let after: Vec<Vec<(usize, usize)>> =
                        (0..4).map(|t| plan.ranges_of(t).to_vec()).collect();
                    assert_ne!(before, after, "{scheme}: {} must re-partition", schedule.name());
                    let mut got = vec![0.0; n];
                    plan.execute(&engine, &kernel, &x, &mut got);
                    assert_eq!(
                        max_abs_diff(&want, &got),
                        0.0,
                        "{scheme} × {} × pin {}: rebalanced plan deviates",
                        schedule.name(),
                        pin.name()
                    );
                }
            }
        }
    }

    #[test]
    fn rebalance_adapts_to_a_different_engine_size() {
        let mut rng = Rng::new(78);
        let coo = random_coo(&mut rng, 150, 900);
        let kernel = SpmvKernel::build(&coo, Scheme::Crs);
        let e4 = Engine::new(4);
        let mut plan = SpmvPlan::new_first_touch(&kernel, Schedule::Static { chunk: None }, &e4);
        assert_eq!(plan.n_threads, 4);
        let e2 = Engine::new(2);
        plan.rebalance(&e2, &kernel, Schedule::Dynamic { chunk: 16 });
        assert_eq!(plan.n_threads, 2);
        let mut x = vec![0.0; 150];
        rng.fill_f64(&mut x, -1.0, 1.0);
        let mut want = vec![0.0; 150];
        kernel.spmv(&x, &mut want);
        let mut got = vec![0.0; 150];
        plan.execute(&e2, &kernel, &x, &mut got);
        assert_eq!(max_abs_diff(&want, &got), 0.0);
    }

    #[test]
    fn for_weights_plan_partitions_and_executes() {
        let weights: Vec<f64> = (0..97).map(|i| 1.0 + (i % 5) as f64).collect();
        for n_threads in [1usize, 3] {
            let engine = Engine::new(n_threads);
            for schedule in schedules() {
                let plan =
                    SpmvPlan::for_weights(Scheme::Crs, schedule, n_threads, weights.clone());
                assert_eq!(plan.nrows, 97);
                assert_eq!(plan.partitions().len(), n_threads);
                let mut out = vec![0.0; 97];
                plan.execute_partitioned(&engine, &mut out, |a, b, out| {
                    for (off, o) in out.iter_mut().enumerate() {
                        *o = (a + off) as f64;
                    }
                    assert_eq!(a + out.len(), b);
                });
                for (i, &v) in out.iter().enumerate() {
                    assert_eq!(v, i as f64, "{} × {n_threads}T", schedule.name());
                }
            }
        }
        // The empty row set is planable and executable.
        let engine = Engine::new(2);
        let plan = SpmvPlan::for_weights(Scheme::Crs, Schedule::Dynamic { chunk: 4 }, 2, vec![]);
        plan.execute_partitioned(&engine, &mut [], |_, _, _| unreachable!());
    }

    #[test]
    fn first_touch_buffers_are_zeroed_and_sized() {
        let engine = Engine::new(3);
        let plan = SpmvPlan::for_weights(
            Scheme::Crs,
            Schedule::Static { chunk: Some(7) },
            3,
            vec![1.0; 101],
        );
        let bufs = first_touch_buffers(&engine, plan.partitions(), 101, 3);
        assert_eq!(bufs.len(), 3);
        for b in &bufs {
            assert_eq!(b.len(), 101);
            assert!(b.iter().all(|&v| v == 0.0));
        }
        let none = first_touch_buffers(&engine, plan.partitions(), 101, 0);
        assert!(none.is_empty());
    }

    #[test]
    fn first_touch_buffers_reject_non_tiling_partitions() {
        let engine = Engine::new(2);
        // A hole at [5, 10): must be refused, not left uninitialized.
        let partitions = vec![vec![(0usize, 5usize)], vec![(10usize, 20usize)]];
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            first_touch_buffers(&engine, &partitions, 20, 1)
        }));
        assert!(r.is_err(), "non-tiling partitions must be rejected");
    }

    #[test]
    fn halo_gate_orders_exchange_before_wait() {
        let gate = HaloGate::new();
        assert!(!gate.is_open());
        let payload = std::sync::atomic::AtomicUsize::new(0);
        std::thread::scope(|s| {
            s.spawn(|| {
                payload.store(42, Ordering::Relaxed);
                gate.signal();
            });
            gate.wait();
            // signal()'s mutex release happens-before wait()'s return.
            assert_eq!(payload.load(Ordering::Relaxed), 42);
        });
        assert!(gate.is_open());
        gate.wait(); // reopening is a no-op: already-open gates return
    }

    #[test]
    fn two_phase_plan_runs_remote_only_after_gate() {
        let engine = Engine::new(2);
        let local = SpmvPlan::for_weights(
            Scheme::Crs,
            Schedule::Static { chunk: None },
            2,
            vec![1.0; 40],
        );
        let remote = SpmvPlan::for_weights(
            Scheme::Crs,
            Schedule::Static { chunk: None },
            2,
            vec![1.0; 10],
        );
        let two = TwoPhasePlan { local: &local, remote: &remote };
        let gate = HaloGate::new();
        let halo = std::sync::atomic::AtomicUsize::new(0);
        let mut lo = vec![0.0; 40];
        let mut ro = vec![0.0; 10];
        std::thread::scope(|s| {
            s.spawn(|| {
                // "Exchange": publish the halo value, then open the gate.
                halo.store(7, Ordering::Relaxed);
                gate.signal();
            });
            two.execute(
                &engine,
                &gate,
                &mut lo,
                &mut ro,
                |_a, _b, out| out.fill(1.0),
                |_a, _b, out| {
                    // The remote phase must observe the exchanged halo.
                    out.fill(halo.load(Ordering::Relaxed) as f64);
                },
            );
        });
        assert!(lo.iter().all(|&v| v == 1.0));
        assert!(ro.iter().all(|&v| v == 7.0), "remote phase ran before the halo arrived");
    }

    #[test]
    fn pinning_offset_is_recorded() {
        let engine = Engine::with_pinning_offset(2, PinMode::Compact, 1);
        let r = engine.pin_report();
        assert_eq!(r.per_thread.len(), 2);
        if affinity::pin_supported() {
            let n_cpus = affinity::n_cpus();
            for (tid, s) in r.per_thread.iter().enumerate() {
                if let PinStatus::Pinned { cpu } = s {
                    assert_eq!(*cpu, affinity::cpu_for(1 + tid, n_cpus));
                }
            }
        }
    }

    /// ISSUE-7: the role pool runs `count ≤ n_slots` concurrent roles,
    /// reuses the same parked threads across dispatches (spawn count is
    /// fixed at construction), and leaves slots beyond `count` parked.
    #[test]
    fn task_pool_runs_roles_without_respawning() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let pool = TaskPool::new(4);
        assert_eq!(pool.n_slots(), 4);
        assert_eq!(pool.spawned(), 4);
        let hits = [AtomicUsize::new(0), AtomicUsize::new(0), AtomicUsize::new(0)];
        for round in 1..=5usize {
            pool.run(3, |i| {
                hits[i].fetch_add(1, Ordering::SeqCst);
            });
            for h in &hits {
                assert_eq!(h.load(Ordering::SeqCst), round);
            }
        }
        pool.run(0, |_| unreachable!("zero-role dispatch runs nothing"));
        assert_eq!(pool.spawned(), 4, "dispatches must not spawn");
    }

    /// Roles on distinct slots genuinely overlap: two roles that each
    /// wait for the other's gate would deadlock on a single thread.
    #[test]
    fn task_pool_roles_run_concurrently() {
        let pool = TaskPool::new(2);
        let a = HaloGate::new();
        let b = HaloGate::new();
        pool.run(2, |i| {
            if i == 0 {
                a.signal();
                b.wait();
            } else {
                a.wait();
                b.signal();
            }
        });
        assert!(a.is_open() && b.is_open());
    }

    #[test]
    #[should_panic(expected = "task-pool role thread panicked")]
    fn task_pool_propagates_role_panics() {
        let pool = TaskPool::new(2);
        pool.run(2, |i| {
            if i == 1 {
                panic!("role boom");
            }
        });
    }

    #[test]
    fn engine_op_drives_linear_op_consumers() {
        let coo = gen::laplacian_1d(120);
        let kernel = SpmvKernel::build(&coo, Scheme::SellCs { c: 16, sigma: 64 });
        let engine = Engine::new(2);
        let plan = SpmvPlan::new(&kernel, Schedule::Static { chunk: None }, 2);
        let op = EngineOp { kernel: &kernel, engine: &engine, plan: &plan };
        assert_eq!(op.dim(), 120);
        let x = vec![1.0; 120];
        let mut y = vec![0.0; 120];
        op.apply(&x, &mut y);
        let mut want = vec![0.0; 120];
        kernel.spmv(&x, &mut want);
        assert_eq!(max_abs_diff(&want, &y), 0.0);
    }
}
