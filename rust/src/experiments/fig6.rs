//! Fig 6 — left: stride distribution function per storage scheme on the
//! Hamiltonian; right: serial SpMVM performance of every scheme.
//!
//! Paper shapes: CRS backward-jump weight ≈ nrows/nnz (~7%); plain JDS
//! triples it but concentrates ~60% of strides below 64 B; SOJDS barely
//! changes the distribution; CRS outperforms every JDS flavor by ≥20%;
//! NBJDS ≥ RBJDS/SOJDS at optimal block size.

use crate::analysis::StrideDistribution;
use crate::kernels::SpmvKernel;
use crate::matrix::{Crs, Scheme};
use crate::sched::Schedule;
use crate::simulator::{simulate_spmv, Placement, SimOptions};
use crate::util::bench;
use crate::util::report::{f, Table};

use super::ExpOptions;

/// The scheme set of Fig 6 with the paper's block-size choices, extended
/// by SELL-C-σ (the modern layout the engine targets; σ = 8·C keeps the
/// permutation window-local, see the `matrix::sell` docs).
pub fn schemes(block: usize) -> Vec<Scheme> {
    vec![
        Scheme::Crs,
        Scheme::Jds,
        Scheme::NuJds { unroll: 2 },
        Scheme::NbJds { block },
        Scheme::RbJds { block },
        Scheme::SoJds { block },
        Scheme::SellCs { c: 32, sigma: 256 },
    ]
}

pub fn run(opts: &ExpOptions) -> Vec<Table> {
    let coo = opts.test_matrix();
    let crs = Crs::from_coo(&coo);
    let block = if opts.quick { 64 } else { 1000 };
    let mut tables = Vec::new();

    // --- Fig 6a: stride distributions ---
    let mut t = Table::new(
        "Fig 6a — input-vector stride distribution per scheme",
        &[
            "scheme",
            "backward frac",
            "|s|<=1",
            "|s|<=8 (64B)",
            "|s|<=64",
            "mean |s|",
        ],
    );
    let mut kernels = Vec::new();
    for scheme in schemes(block) {
        let k = SpmvKernel::build_from_crs(&crs, scheme);
        let d = StrideDistribution::from_kernel(&k);
        t.row(vec![
            scheme.name(),
            f(d.backward_fraction()),
            f(d.fraction_within(1)),
            f(d.fraction_within(8)),
            f(d.fraction_within(64)),
            f(d.mean_abs_stride()),
        ]);
        kernels.push(k);
    }
    tables.push(t);

    // --- Fig 6b: serial performance per scheme and machine ---
    let mut header: Vec<String> = vec!["scheme".into()];
    for m in &opts.machines {
        header.push(format!("{} MFlop/s", m.name));
        header.push(format!("{} cyc/nnz", m.name));
    }
    header.push("host MFlop/s".into());
    let href: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t2 = Table::new(
        "Fig 6b — serial SpMVM performance (simulated machines + real host)",
        &href,
    );
    let sim_opts = SimOptions::default();
    for k in &kernels {
        let mut row = vec![k.scheme().name()];
        for m in &opts.machines {
            let r = simulate_spmv(
                m,
                k,
                1,
                1,
                Schedule::Static { chunk: None },
                Placement::FirstTouchStatic,
                &sim_opts,
            );
            row.push(f(r.mflops));
            row.push(f(r.cycles_per_update));
        }
        // Host wall-clock on the permuted hot path.
        let x = vec![1.0; k.nrows()];
        let mut ws = k.workspace(&x);
        let b = if opts.quick { bench::Bench::quick() } else { bench::default_bench() };
        let res = b.run(&k.scheme().name(), k.nnz() as u64, 2 * k.nnz() as u64, || {
            k.spmv_hot(&mut ws);
            ws.yp[0]
        });
        row.push(f(res.mflops()));
        t2.row(row);
    }
    tables.push(t2);
    tables
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen;
    use crate::simulator::MachineSpec;

    use std::sync::OnceLock;

    /// Shared medium Hamiltonian for the Fig 6 assertions: the paper's
    /// matrix scaled to M=6 (369,600 rows, ~5M nnz) — large enough that
    /// the per-diagonal sweep exceeds every simulated LLC, as at paper
    /// scale.
    fn medium_crs() -> &'static Crs {
        static CRS: OnceLock<Crs> = OnceLock::new();
        CRS.get_or_init(|| {
            Crs::from_coo(&gen::holstein_hubbard(
                &gen::HolsteinHubbardParams::medium(),
            ))
        })
    }

    #[test]
    fn crs_beats_all_jds_flavors_on_x86() {
        // The paper's central Fig 6b result, on the simulated Woodcrest
        // (4 MB LLC — firmly memory-bound at this matrix size).
        let crs = medium_crs();
        let m = MachineSpec::woodcrest();
        let opts = SimOptions::default();
        let perf = |scheme| {
            let k = SpmvKernel::build_from_crs(crs, scheme);
            simulate_spmv(
                &m,
                &k,
                1,
                1,
                Schedule::Static { chunk: None },
                Placement::FirstTouchStatic,
                &opts,
            )
            .mflops
        };
        let crs_perf = perf(Scheme::Crs);
        for scheme in [
            Scheme::Jds,
            Scheme::NbJds { block: 1000 },
            Scheme::RbJds { block: 1000 },
            Scheme::SoJds { block: 1000 },
        ] {
            let p = perf(scheme);
            assert!(
                crs_perf > p,
                "CRS {crs_perf:.0} MFlop/s must beat {scheme:?} {p:.0}"
            );
        }
        // ...and by a meaningful margin over plain JDS (paper: >= 20%).
        assert!(crs_perf > 1.15 * perf(Scheme::Jds));
    }

    #[test]
    fn blocking_recovers_jds_performance() {
        // NBJDS at a good block size must clearly beat plain JDS (Fig 6b/7).
        let crs = medium_crs();
        let m = MachineSpec::woodcrest();
        let opts = SimOptions::default();
        let perf = |scheme| {
            let k = SpmvKernel::build_from_crs(crs, scheme);
            simulate_spmv(&m, &k, 1, 1, Schedule::Static { chunk: None }, Placement::FirstTouchStatic, &opts).mflops
        };
        assert!(perf(Scheme::NbJds { block: 1000 }) > 1.2 * perf(Scheme::Jds));
    }

    #[test]
    fn driver_quick() {
        let opts = ExpOptions { quick: true, ..Default::default() };
        let tables = run(&opts);
        assert_eq!(tables.len(), 2);
        assert_eq!(tables[0].rows.len(), 7); // paper's six schemes + SELL-C-σ
    }
}
