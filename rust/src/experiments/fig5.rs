//! Fig 5 — the Holstein-Hubbard test matrix: dimension, sparsity
//! pattern summary, and the diagonal occupation profile (bottom panel).
//! Paper facts to reproduce: N = 1,201,200 at full scale, ~14 nnz/row on
//! average, split structure (a few rather dense secondary diagonals plus
//! a scattered band), ~60% of nnz in the twelve most populated secondary
//! diagonals, Hermitian (real symmetric).

use crate::analysis::diag_profile;
use crate::util::report::{f, Table};

use super::ExpOptions;

pub fn run(opts: &ExpOptions) -> Vec<Table> {
    let params = opts.test_params();
    let h = opts.test_matrix();
    let profile = diag_profile(&h);
    let mut tables = Vec::new();

    let mut t = Table::new(
        "Fig 5 — Holstein-Hubbard Hamiltonian summary",
        &["quantity", "value"],
    );
    t.row(vec!["sites L".into(), params.sites.to_string()]);
    t.row(vec!["electrons (up,down)".into(), format!("({},{})", params.n_up, params.n_down)]);
    t.row(vec!["max phonons M".into(), params.max_phonons.to_string()]);
    t.row(vec!["dimension N".into(), h.nrows.to_string()]);
    t.row(vec!["paper dimension".into(), "1201200 (L=6, 3+3 el., M=8)".into()]);
    t.row(vec!["non-zeros".into(), h.nnz().to_string()]);
    t.row(vec![
        "avg nnz/row".into(),
        f(h.nnz() as f64 / h.nrows as f64),
    ]);
    t.row(vec!["symmetric".into(), if opts.full { "yes (by construction)".into() } else { h.is_symmetric().to_string() }]);
    t.row(vec!["bandwidth (max |i-j|)".into(), profile.bandwidth().to_string()]);
    t.row(vec![
        "nnz fraction in top-12 secondary diagonals".into(),
        f(profile.fraction_in_top_secondary(12)),
    ]);
    tables.push(t);

    let mut t2 = Table::new(
        "Fig 5 (bottom) — subdiagonal occupation (top 20 by population)",
        &["offset", "nnz", "capacity", "occupation"],
    );
    for (off, cnt) in profile.densest_offsets().into_iter().take(20) {
        t2.row(vec![
            off.to_string(),
            cnt.to_string(),
            profile.capacity.get(&off).copied().unwrap_or(0).to_string(),
            f(profile.occupation(off)),
        ]);
    }
    tables.push(t2);

    // Cumulative distribution function over diagonal distance (the
    // paper's red dashed / solid distribution curves).
    let mut t3 = Table::new(
        "Fig 5 (bottom) — cumulative nnz fraction beyond offset",
        &["offset >=", "fraction of nnz"],
    );
    let bw = profile.bandwidth();
    let mut marks: Vec<u64> = vec![1];
    let mut o = 4u64;
    while o < bw {
        marks.push(o);
        o *= 4;
    }
    marks.push(bw);
    for off in marks {
        t3.row(vec![off.to_string(), f(profile.fraction_beyond(off))]);
    }
    tables.push(t3);
    tables
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gen;

    #[test]
    fn small_config_is_paperlike() {
        // The small config keeps the paper's structural fingerprint.
        let h = gen::holstein_hubbard(&gen::HolsteinHubbardParams::small());
        assert_eq!(h.nrows, 84_000); // 400 * C(10,4)
        let avg = h.nnz() as f64 / h.nrows as f64;
        assert!((8.0..20.0).contains(&avg), "avg nnz/row {avg}");
        let p = diag_profile(&h);
        let frac = p.fraction_in_top_secondary(12);
        assert!(
            frac > 0.4,
            "top-12 secondary diagonals hold {frac:.2}, expected a dominant share"
        );
    }

    #[test]
    fn paper_scale_dimension_formula() {
        let p = gen::HolsteinHubbardParams::paper();
        assert_eq!(p.dimension(), 1_201_200);
    }

    #[test]
    fn driver_runs_quick() {
        let opts = ExpOptions { quick: true, ..Default::default() };
        let tables = run(&opts);
        assert_eq!(tables.len(), 3);
    }
}
